//! Fig. 2 reproduction: generate the paper's SBM (10,000 nodes, classes
//! [0.2, 0.3, 0.5], within 0.13 / between 0.10) and print the data behind
//! all four panels — block densities, block probabilities (empirical edge
//! counts), label counts, class percentages.
//!
//! Run with: `cargo run --release --example sbm_stats [nodes]`

use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::stats::{degree_stats, fig2_stats};

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);
    let params = SbmParams::paper(n);
    let g = generate_sbm(&params, 42);
    let s = fig2_stats(&g);
    let k = g.k;

    println!("SBM with node size {n} (paper Fig. 2), seed 42");
    println!("generated edges: {} (expected {:.0})\n", g.num_edges(), params.expected_edges());

    println!("[upper left] empirical block edge densities (target: 0.13 diag / 0.10 off):");
    for a in 0..k {
        let row: Vec<String> = (0..k)
            .map(|b| format!("{:.4}", s.block_density[a * k + b]))
            .collect();
        println!("  class {a}: [{}]", row.join(", "));
    }

    println!("\n[upper right] model block probabilities used for generation:");
    for a in 0..k {
        let row: Vec<String> = (0..k)
            .map(|b| format!("{:.2}", params.block_probs[a * k + b]))
            .collect();
        println!("  class {a}: [{}]", row.join(", "));
    }

    println!("\n[lower left] label counts (priors {:?}):", params.class_probs);
    for (c, count) in s.class_counts.iter().enumerate() {
        println!("  class {c}: {count} nodes");
    }

    println!("\n[lower right] class percentage of population:");
    for (c, pct) in s.class_percent.iter().enumerate() {
        println!("  class {c}: {pct:.1}%");
    }

    let d = degree_stats(&g);
    println!(
        "\ndegrees: min {:.0}, mean {:.1}, max {:.0}, isolated {}",
        d.min, d.mean, d.max, d.isolated
    );
    println!("edge density (Eq. 2): {:.5}", g.density());
}
