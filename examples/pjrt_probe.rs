//! §Perf probe: PJRT execute latency per bucket (after warm compile).
use gee_sparse::gee::{Engine, GeeOptions};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::runtime::Runtime;
use std::time::Instant;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::new(&dir).unwrap();
    // (label, n, target undirected edges) sized to land in each bucket
    for (bucket, n, e) in [("s", 120, 800), ("m", 1_000, 7_000), ("l", 6_000, 60_000)] {
        let g = generate_sbm(
            &SbmParams::fitted(n, 3, e, 3.0, vec![0.2, 0.3, 0.5]),
            42,
        );
        let opts = GeeOptions::ALL;
        rt.embed(&g, &opts).unwrap(); // warm: compile + first run
        let t0 = Instant::now();
        let reps = 5;
        for _ in 0..reps {
            std::hint::black_box(rt.embed(&g, &opts).unwrap());
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        let native = {
            let t = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(Engine::SparseFast.embed(&g, &opts).unwrap());
            }
            t.elapsed().as_secs_f64() / reps as f64
        };
        println!(
            "bucket {bucket}: graph n={n} e={} -> pjrt {:.4}s/embed, native {:.5}s ({}x)",
            g.num_edges(),
            per,
            native,
            (per / native.max(1e-9)) as u64
        );
    }
}
