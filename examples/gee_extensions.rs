//! The GEE line's companion capabilities (refs [11]-[13] of the paper),
//! built on the sparse pipeline:
//!
//! 1. **Unsupervised ensemble** — community detection with no labels
//!    (embed ↔ cluster refinement, best-of-R replicates);
//! 2. **Vertex dynamics** — time-series of graphs, per-vertex pattern
//!    shift detection;
//! 3. **Graph fusion** — multi-modal graphs over one vertex set,
//!    concatenated embeddings.
//!
//! Run with: `cargo run --release --example gee_extensions`

use gee_sparse::gee::ensemble::{gee_ensemble, EnsembleConfig};
use gee_sparse::gee::fusion::gee_fuse;
use gee_sparse::gee::GeeOptions;
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::Graph;
use gee_sparse::tasks::dynamics::{shifted_vertices, vertex_dynamics};
use gee_sparse::tasks::knn::loo_1nn_accuracy;
use gee_sparse::tasks::metrics::adjusted_rand_index;
use gee_sparse::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // ---------------- 1. unsupervised ensemble
    let mut params = SbmParams::paper(1_000);
    for i in 0..3 {
        params.block_probs[i * 3 + i] = 0.3;
    }
    let g = generate_sbm(&params, 17);
    let truth: Vec<usize> = g.labels.iter().map(|&l| l as usize).collect();
    let res = gee_ensemble(&g, 3, &EnsembleConfig::new(5));
    let pred: Vec<usize> = res.labels.iter().map(|&l| l as usize).collect();
    println!("== unsupervised GEE ensemble (no labels given) ==");
    println!(
        "SBM n=1000: ARI vs hidden truth = {:.4} (objective {:.4}, rounds {:?})\n",
        adjusted_rand_index(&pred, &truth),
        res.objective,
        res.rounds
    );

    // ---------------- 2. vertex dynamics over a time series
    let windows = drifting_series(60, 4, 31);
    let refs: Vec<&Graph> = windows.iter().collect();
    let dyn_res = vertex_dynamics(&refs, &GeeOptions::new(false, true, true));
    let shifts = shifted_vertices(&dyn_res, 0.3);
    println!("== vertex dynamics (pattern-shift detection) ==");
    println!(
        "{} windows, {} vertices flagged (threshold 0.3); top movers: {:?}\n",
        windows.len(),
        shifts.len(),
        &shifts[..shifts.len().min(5)]
    );

    // ---------------- 3. multi-graph fusion
    let (g1, g2) = complementary_views(200, 41);
    let opts = GeeOptions::new(true, true, false);
    let zf = gee_fuse(&[&g1, &g2], &opts)?;
    println!("== synergistic graph fusion ==");
    println!(
        "view1 1-NN acc {:.3} | view2 {:.3} | fused {:.3} (N x {} embedding)",
        loo_1nn_accuracy(
            &gee_sparse::gee::Engine::SparseFast.embed(&g1, &opts)?,
            &g1.labels
        ),
        loo_1nn_accuracy(
            &gee_sparse::gee::Engine::SparseFast.embed(&g2, &opts)?,
            &g2.labels
        ),
        loo_1nn_accuracy(&zf, &g1.labels),
        zf.ncols
    );
    Ok(())
}

/// Time series where vertices 0..6 migrate to the other community midway.
fn drifting_series(n: usize, windows: usize, seed: u64) -> Vec<Graph> {
    let mut rng = Rng::new(seed);
    let labels: Vec<i32> = (0..n).map(|i| (i % 2) as i32).collect();
    (0..windows)
        .map(|t| {
            let flipped = t >= windows / 2;
            let mut g = Graph::new(n, 2);
            g.labels = labels.clone();
            for _ in 0..n * 8 {
                let a = rng.below(n);
                let b = rng.below(n);
                if a == b {
                    continue;
                }
                let eff = |v: usize| -> i32 {
                    if flipped && v < 6 {
                        1 - labels[v]
                    } else {
                        labels[v]
                    }
                };
                let p = if eff(a) == eff(b) { 0.65 } else { 0.08 };
                if rng.f64() < p {
                    g.add_edge(a as u32, b as u32, 1.0);
                }
            }
            g
        })
        .collect()
}

/// Two weak complementary views of one 2-block vertex set.
fn complementary_views(n: usize, seed: u64) -> (Graph, Graph) {
    let mut rng = Rng::new(seed);
    let labels: Vec<i32> = (0..n).map(|i| (i % 2) as i32).collect();
    let mut mk = |within_axis: bool| {
        let mut g = Graph::new(n, 2);
        g.labels = labels.clone();
        for _ in 0..n * 5 {
            let a = rng.below(n);
            let b = rng.below(n);
            if a == b {
                continue;
            }
            let same = labels[a] == labels[b];
            let p = if same == within_axis { 0.7 } else { 0.25 };
            if rng.f64() < p {
                g.add_edge(a as u32, b as u32, 1.0);
            }
        }
        g
    };
    (mk(true), mk(false))
}
