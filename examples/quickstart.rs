//! Quickstart: build a small labeled graph, embed it with every engine,
//! verify they agree, and show the effect of each option.
//!
//! Run with: `cargo run --release --example quickstart`

use gee_sparse::gee::{Engine, GeeOptions};
use gee_sparse::graph::Graph;

fn main() -> anyhow::Result<()> {
    // A toy "two communities" graph: vertices 0-3 (class 0) form a clique,
    // vertices 4-7 (class 1) form a clique, one bridge edge 3-4.
    let mut g = Graph::new(8, 2);
    g.labels = vec![0, 0, 0, 0, 1, 1, 1, 1];
    for a in 0..4u32 {
        for b in (a + 1)..4 {
            g.add_edge(a, b, 1.0);
        }
    }
    for a in 4..8u32 {
        for b in (a + 1)..8 {
            g.add_edge(a, b, 1.0);
        }
    }
    g.add_edge(3, 4, 1.0);

    println!(
        "graph: n={} edges={} k={} density={:.3}\n",
        g.n,
        g.num_edges(),
        g.k,
        g.density()
    );

    // 1. Plain GEE with the paper's sparse pipeline.
    let opts = GeeOptions::NONE;
    let z = Engine::Sparse.embed(&g, &opts)?;
    println!("sparse GEE embedding (plain), rows = vertices, cols = classes:");
    for v in 0..g.n {
        println!(
            "  v{} (class {}): [{:.3}, {:.3}]",
            v,
            g.labels[v],
            z.get(v, 0),
            z.get(v, 1)
        );
    }
    println!("  -> same-class mass dominates; the bridge endpoints (v3, v4) see both.\n");

    // 2. All engines produce identical numerics.
    for opts in GeeOptions::table_order() {
        let base = Engine::Dense.embed(&g, &opts)?;
        for e in Engine::ALL {
            let zi = e.embed(&g, &opts)?;
            assert!(base.max_abs_diff(&zi) < 1e-10, "{} diverged", e.name());
        }
    }
    println!("all 4 engines agree on all 8 option combinations ✓\n");

    // 3. What the options do.
    let z_lap = Engine::Sparse.embed(&g, &GeeOptions::new(true, false, false))?;
    let z_cor = Engine::Sparse.embed(&g, &GeeOptions::new(false, false, true))?;
    println!("with Laplacian normalization, v0 row: [{:.3}, {:.3}] (degree-scaled)", z_lap.get(0, 0), z_lap.get(0, 1));
    let norm: f64 = z_cor.row(0).iter().map(|x| x * x).sum::<f64>().sqrt();
    println!("with correlation, every row has unit norm: |Z_0| = {norm:.6}");
    Ok(())
}
