//! Community detection + vertex classification on GEE embeddings — the
//! downstream applications the GEE line of work (refs [10-13] of the
//! paper) targets. Demonstrates that the sparse pipeline's embeddings are
//! not just fast but *useful*: k-means on Z recovers SBM communities
//! (ARI/NMI), and k-NN / LDA classify held-out vertices.
//!
//! Run with: `cargo run --release --example community_detection`

use gee_sparse::gee::{Engine, GeeOptions};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::Graph;
use gee_sparse::tasks::kmeans::{kmeans, KMeansConfig};
use gee_sparse::tasks::knn::knn_classify;
use gee_sparse::tasks::lda::Lda;
use gee_sparse::tasks::metrics::{accuracy, adjusted_rand_index, nmi, paired_labels};
use gee_sparse::sparse::Dense;
use gee_sparse::util::rng::Rng;

/// Hide a fraction of labels (simulating the semi-supervised setting the
/// original GEE evaluates); returns (train-labeled graph, hidden truth).
fn hide_labels(g: &Graph, frac: f64, seed: u64) -> (Graph, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let mut train = g.clone();
    let mut hidden = Vec::new();
    for v in 0..g.n {
        if rng.f64() < frac {
            train.labels[v] = -1;
            hidden.push(v);
        }
    }
    (train, hidden)
}

fn rows(z: &Dense, idx: &[usize]) -> Dense {
    let mut out = Dense::zeros(idx.len(), z.ncols);
    for (r, &v) in idx.iter().enumerate() {
        out.row_mut(r).copy_from_slice(z.row(v));
    }
    out
}

fn main() -> anyhow::Result<()> {
    let n = 3_000;
    let g = generate_sbm(&SbmParams::paper(n), 99);
    println!(
        "SBM n={n}, edges={}, classes={} (priors [0.2, 0.3, 0.5])\n",
        g.num_edges(),
        g.k
    );

    // ---- 1. unsupervised: k-means on the embedding vs true communities
    println!("community detection (k-means on Z, all option combos, sparse engine):");
    println!("{:>28} {:>8} {:>8}", "options", "ARI", "NMI");
    for opts in GeeOptions::table_order() {
        let z = Engine::Sparse.embed(&g, &opts)?;
        let km = kmeans(&z, &KMeansConfig::new(g.k));
        let pred: Vec<i32> = km.assignments.iter().map(|&c| c as i32).collect();
        let (a, b) = paired_labels(&pred, &g.labels);
        println!(
            "{:>28} {:>8.4} {:>8.4}",
            opts.label(),
            adjusted_rand_index(&a, &b),
            nmi(&a, &b)
        );
    }

    // ---- 2. semi-supervised: hide 30% of labels, classify from embedding
    let (train, hidden) = hide_labels(&g, 0.3, 7);
    let z = Engine::Sparse.embed(&train, &GeeOptions::new(true, true, false))?;
    let labeled: Vec<usize> = (0..g.n).filter(|&v| train.labels[v] >= 0).collect();
    let train_x = rows(&z, &labeled);
    let train_y: Vec<i32> = labeled.iter().map(|&v| train.labels[v]).collect();
    let test_x = rows(&z, &hidden);
    let truth: Vec<i32> = hidden.iter().map(|&v| g.labels[v]).collect();

    println!("\nvertex classification with 30% of labels hidden ({} test vertices):", hidden.len());
    let pred_knn = knn_classify(&train_x, &train_y, &test_x, 5);
    println!("  5-NN accuracy: {:.4}", accuracy(&pred_knn, &truth));
    let lda = Lda::fit(&train_x, &train_y, g.k);
    let pred_lda = lda.predict(&test_x);
    println!("  LDA accuracy: {:.4}", accuracy(&pred_lda, &truth));

    // ---- 3. engines are interchangeable for the downstream task
    println!("\nsame task through each engine (must match — embeddings are identical):");
    for e in [Engine::EdgeList, Engine::Sparse, Engine::SparseFast] {
        let z2 = e.embed(&train, &GeeOptions::new(true, true, false))?;
        let diff = z.max_abs_diff(&z2);
        println!("  {:>12}: max |Δ| = {diff:.2e}", e.name());
    }
    Ok(())
}
