//! Fig. 3 reproduction driver: the SBM size sweep (100 … 10,000 nodes,
//! paper parameters, all options on) comparing original GEE with sparse
//! GEE — plus the dense-adjacency strawman on the sizes it can stomach,
//! showing the quadratic blow-up that motivates sparse storage.
//!
//! Run with: `cargo run --release --example sbm_sweep [--quick]`

use gee_sparse::gee::{Engine, GeeOptions};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::harness::{self, format_fig3, run_fig3};
use gee_sparse::util::timing::{bench_runs, secs, Stats};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick {
        &[100, 1_000, 3_000]
    } else {
        harness::FIG3_SIZES
    };
    let reps = if quick { 2 } else { 3 };

    println!("running Fig. 3 sweep (reps = {reps}; median reported)...\n");
    let points = run_fig3(sizes, reps, 7);
    println!("{}", format_fig3(&points));

    // The dense strawman, where it fits (quadratic memory!)
    println!("dense-adjacency baseline (same SBM, same options) — why sparse matters:");
    println!("{:>8} {:>12} {:>14}", "nodes", "dense (s)", "A bytes");
    let opts = GeeOptions::ALL;
    for &n in sizes.iter().filter(|&&n| n <= 5_000) {
        let g = generate_sbm(&SbmParams::paper(n), 7);
        let runs = bench_runs(0, reps.min(2), || {
            Engine::Dense.embed(&g, &opts).expect("within budget")
        });
        let st = Stats::from_runs(&runs);
        println!(
            "{:>8} {:>12} {:>13.1}M",
            n,
            secs(st.median),
            (n * n * 8) as f64 / 1e6
        );
    }
    println!("\n(the paper's 86x Python-level speedup becomes a smaller constant in\n compiled rust — see EXPERIMENTS.md for the shape comparison)");
}
