//! END-TO-END driver (DESIGN.md §Experiment index, EXPERIMENTS.md §E2E):
//! the full serving stack on real-scale workloads.
//!
//! 1. **Headline batch job** — embed the largest Table-2 twin
//!    (CL-100K-1d8-L5: 92,482 nodes / 10,000,000 edges) with all options
//!    on, the paper's flagship measurement (§4.2: 174.552 s in scipy on a
//!    laptop; "millions of edges within minutes").
//! 2. **Serving load** — start the coordinator (PJRT lane when artifacts
//!    are built, native lane otherwise), submit hundreds of mixed
//!    embedding requests, report throughput, latency percentiles and
//!    batch fill.
//! 3. **Quality gate** — k-means ARI on an SBM twin, proving the served
//!    embeddings are usable, not just fast.
//!
//! Run with: `cargo run --release --example serve_embeddings [--quick] [--pjrt]`

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use gee_sparse::coordinator::batcher::BatchCapacity;
use gee_sparse::coordinator::{EmbedRequest, EmbedService, Lane, ServiceConfig};
use gee_sparse::gee::{Engine, GeeOptions};
use gee_sparse::graph::datasets::{by_name, TABLE2};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::harness::edges_per_sec;
use gee_sparse::tasks::kmeans::{kmeans, KMeansConfig};
use gee_sparse::tasks::metrics::{adjusted_rand_index, paired_labels};
use gee_sparse::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    // ------------------------------------------------ 1. headline batch
    let spec = if quick {
        by_name("CL-100K-1d8-L9").unwrap()
    } else {
        by_name("CL-100K-1d8-L5").unwrap()
    };
    println!(
        "=== headline: {} ({} nodes / {} edges) ===",
        spec.name, spec.nodes, spec.edges
    );
    let t0 = Instant::now();
    let g_big = spec.generate();
    println!("twin generated in {:.1}s", t0.elapsed().as_secs_f64());

    for (engine, label) in [
        (Engine::EdgeList, "original GEE  (paper: 604.018 s)"),
        (Engine::Sparse, "sparse GEE    (paper: 174.552 s)"),
        (Engine::SparseFast, "sparse GEE, §Perf-tuned"),
    ] {
        let t = Instant::now();
        let z = engine.embed(&g_big, &GeeOptions::ALL)?;
        let dt = t.elapsed();
        println!(
            "  {label}: {:.3} s  ({:.1}M edges/s, Z is {}x{})",
            dt.as_secs_f64(),
            edges_per_sec(g_big.num_edges(), dt) / 1e6,
            z.nrows,
            z.ncols
        );
    }

    // ---------------------------------------------------- 2. serving load
    println!("\n=== serving load ===");
    let lane = if use_pjrt && artifact_dir.join("manifest.json").exists() {
        println!("lane: pjrt (compiled artifacts) + native fallback");
        Lane::Pjrt { artifact_dir, fallback: Engine::SparseFast }
    } else {
        println!("lane: native (sparse-fast)");
        Lane::Native(Engine::SparseFast)
    };
    let svc = EmbedService::start(ServiceConfig {
        lane,
        workers: 4,
        batching: true,
        batch_capacity: BatchCapacity::from_bucket(2_048, 16_384, 16),
        batch_linger: Duration::from_millis(2),
        queue_depth: 1024,
        // big solo graphs (the 90th-percentile tail below) use the
        // row-parallel engine instead of pinning one worker
        intra_op_threads: 4,
        intra_op_min_edges: 20_000,
        // past the u32 budget the sharded lane takes over (default)
        ..ServiceConfig::default()
    });

    let requests = if quick { 200 } else { 800 };
    let mut rng = Rng::new(2024);
    let combos = GeeOptions::table_order();
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(requests);
    for i in 0..requests {
        // a realistic mix: mostly small graphs, a long tail of medium ones
        let n = if rng.f64() < 0.9 {
            30 + rng.below(200)
        } else {
            1_000 + rng.below(2_000)
        };
        let g = generate_sbm(
            &SbmParams::fitted(n, 3, n * 4, 3.0, vec![0.2, 0.3, 0.5]),
            5_000 + i as u64,
        );
        let opts = combos[rng.below(8)];
        rxs.push(svc.submit(EmbedRequest { graph: g, options: opts }).expect("queue open"));
    }
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let m = svc.shutdown();
    println!("served {ok}/{requests} embedding requests in {:.2}s  ({:.0} req/s)", wall.as_secs_f64(), ok as f64 / wall.as_secs_f64());
    println!(
        "latency p50={:?} p95={:?} p99={:?}  batches={} (avg fill {:.2})",
        m.latency_quantile(0.50),
        m.latency_quantile(0.95),
        m.latency_quantile(0.99),
        m.batches.load(Ordering::Relaxed),
        m.avg_batch_fill()
    );
    println!(
        "volume: {} vertices, {} directed edges",
        m.vertices.load(Ordering::Relaxed),
        m.edges.load(Ordering::Relaxed)
    );

    // ---------------------------------------------------- 3. quality gate
    println!("\n=== quality gate ===");
    let g = generate_sbm(&SbmParams::paper(3_000), 99);
    let z = Engine::SparseFast.embed(&g, &GeeOptions::new(true, true, false))?;
    let km = kmeans(&z, &KMeansConfig::new(g.k));
    let pred: Vec<i32> = km.assignments.iter().map(|&c| c as i32).collect();
    let (a, b) = paired_labels(&pred, &g.labels);
    let ari = adjusted_rand_index(&a, &b);
    println!("k-means on served embedding: ARI = {ari:.4} (SBM n=3000)");
    anyhow::ensure!(ari > 0.5, "embedding quality gate failed (ARI {ari})");
    println!("quality gate passed ✓");

    // dataset inventory for the record
    println!("\ntwins available: {}", TABLE2.iter().map(|s| s.name).collect::<Vec<_>>().join(", "));
    Ok(())
}
