use gee_sparse::runtime::Runtime;
use std::time::Instant;
fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::new(&dir).unwrap();
    for b in ["s", "m", "l"] {
        let t0 = Instant::now();
        let n = rt.warmup(b).unwrap();
        println!("bucket {b}: {n} variants compiled in {:.2}s", t0.elapsed().as_secs_f64());
    }
}
