//! Bench: thread-sweep scaling of the row-parallel sparse GEE engine —
//! the intra-graph ablation of Edge-Parallel GEE (Lubonja, Priebe & Shen,
//! arXiv:2402.04403) on SBM and Chung-Lu graphs — plus the new
//! edge-parallel edge-list lane.
//!
//! Reports, per thread count: full embed (parallel prepare + parallel
//! accumulate), the amortized repeated-embed path (prepare once, embed
//! per option combo), the edge-parallel edge-list engine, and the
//! speedup over one thread. Also checks the determinism contracts: the
//! row-parallel output must be bitwise-identical to the serial fused
//! engine at every thread count; the edge-parallel engine must agree to
//! ≤1e-12.
//!
//! Results are appended to `BENCH_gee.json` (see `util::benchlog`).
//! `QUICK=1` (or the legacy `GEE_BENCH_QUICK`) trims sizes for CI smoke.

use gee_sparse::gee::edgelist_par::EdgeListParGee;
use gee_sparse::gee::parallel::{prepare_par, ParallelGee};
use gee_sparse::gee::sparse_gee::SparseGee;
use gee_sparse::gee::{EmbedWorkspace, GeeOptions};
use gee_sparse::graph::chung_lu::{generate_chung_lu, ChungLuParams};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::Graph;
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};
use gee_sparse::util::timing::{bench_runs, secs, Stats};

const THREADS: &[usize] = &[1, 2, 4, 8];

fn record(
    out: &mut Vec<BenchRecord>,
    engine: &str,
    g: &Graph,
    threads: usize,
    st: &Stats,
    base_ns: u128,
) {
    let ns = st.median.as_nanos();
    out.push(BenchRecord {
        bench: "thread_sweep".into(),
        engine: engine.into(),
        n: g.n,
        m: g.num_directed(),
        k: g.k,
        threads,
        median_ns: ns,
        speedup: base_ns as f64 / (ns.max(1) as f64),
        ..BenchRecord::default()
    });
}

fn sweep(name: &str, g: &Graph, reps: usize, records: &mut Vec<BenchRecord>) {
    let opts = GeeOptions::ALL;
    println!(
        "-- {name}: n={} edges={} ({} directed), k={}",
        g.n,
        g.num_edges(),
        g.num_directed(),
        g.k
    );

    // determinism gates
    let serial = SparseGee::fast().embed(g, &opts);
    for &t in THREADS {
        let z = ParallelGee::new(t).embed(g, &opts);
        assert_eq!(
            z.data, serial.data,
            "{name}: t={t} output not bitwise-identical to serial"
        );
        let ze = EdgeListParGee::new(t).embed(g, &opts);
        let d = serial.max_abs_diff(&ze);
        assert!(d <= 1e-12, "{name}: edge-par t={t} diff {d} vs serial");
    }
    println!("   row-par bitwise ✓, edge-par ≤1e-12 ✓ at all thread counts");

    println!(
        "   {:>8} {:>12} {:>9} {:>14} {:>9} {:>13} {:>9}",
        "threads", "embed (s)", "speedup", "amortized (s)", "speedup", "edge-par (s)", "speedup"
    );
    let mut base_embed = 0u128;
    let mut base_amort = 0u128;
    let mut base_epar = 0u128;
    // sweep only thread counts the machine actually has: the engines cap
    // at available parallelism, and an oversubscribed prepared-lane run
    // (which spawns exactly t) is not scaling data either
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    for &t in THREADS.iter().filter(|&&t| t <= avail.max(1)) {
        let engine = ParallelGee::new(t);
        let full = Stats::from_runs(&bench_runs(1, reps, || {
            std::hint::black_box(engine.embed(g, &opts));
        }));
        // amortized: prepare once, one embed pass per option combo,
        // pooled workspace (the serving hot path)
        let prepared = prepare_par(g, t);
        let combos = GeeOptions::table_order();
        let mut ws = EmbedWorkspace::new();
        let amort = Stats::from_runs(&bench_runs(1, reps, || {
            for o in &combos {
                prepared.embed_par_into(o, t, &mut ws);
                std::hint::black_box(ws.z.data.as_ptr());
            }
        }));
        // edge-parallel edge-list lane, pooled
        let epar_engine = EdgeListParGee::new(t);
        let mut ws2 = EmbedWorkspace::new();
        let epar = Stats::from_runs(&bench_runs(1, reps, || {
            epar_engine.embed_into(g, &opts, &mut ws2);
            std::hint::black_box(ws2.z.data.as_ptr());
        }));
        if t == 1 {
            base_embed = full.median.as_nanos();
            base_amort = amort.median.as_nanos();
            base_epar = epar.median.as_nanos();
        }
        // t <= avail by the sweep filter, so every lane really ran t-way
        record(records, "sparse-par", g, t, &full, base_embed);
        record(records, "sparse-par-prepared", g, t, &amort, base_amort);
        record(records, "edgelist-par", g, t, &epar, base_epar);
        println!(
            "   {:>8} {:>12} {:>8.2}x {:>14} {:>8.2}x {:>13} {:>8.2}x",
            t,
            secs(full.median),
            base_embed as f64 / full.median.as_nanos().max(1) as f64,
            secs(amort.median),
            base_amort as f64 / amort.median.as_nanos().max(1) as f64,
            secs(epar.median),
            base_epar as f64 / epar.median.as_nanos().max(1) as f64,
        );
    }
    println!();
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    println!(
        "== bench thread_sweep (reps={reps}, cores available: {}) ==\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let mut records = Vec::new();

    // SBM at the paper's parameters: n=10k gives ~5.6M undirected edges
    // (~11M directed), well past the 1M-directed-edge acceptance bar.
    let sbm_n = if quick { 2_000 } else { 10_000 };
    let sbm = generate_sbm(&SbmParams::paper(sbm_n), 7);
    sweep("SBM (paper params)", &sbm, reps, &mut records);

    // Chung-Lu power-law twin: skewed degrees stress the nnz-balanced row
    // partition (a hub row cannot be split, only isolated in a chunk).
    let cl_edges = if quick { 100_000 } else { 1_000_000 };
    let cl_n = if quick { 10_000 } else { 50_000 };
    let cl = generate_chung_lu(
        &ChungLuParams { n: cl_n, edges: cl_edges, gamma: 1.8, k: 5 },
        11,
    );
    sweep("Chung-Lu (gamma=1.8)", &cl, reps, &mut records);

    write_records("thread_sweep", &records);
}
