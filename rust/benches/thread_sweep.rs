//! Bench: thread-sweep scaling of the row-parallel sparse GEE engine —
//! the intra-graph ablation of Edge-Parallel GEE (Lubonja, Priebe & Shen,
//! arXiv:2402.04403) on SBM and Chung-Lu graphs.
//!
//! Reports, per thread count: full embed (parallel prepare + parallel
//! accumulate), the amortized repeated-embed path (prepare once, embed
//! per option combo), and the speedup over one thread. Also checks the
//! determinism contract: every thread count's output must be
//! bitwise-identical to the serial fused engine.
//!
//! The acceptance target for this PR: >1.5x at 4 threads on a
//! >= 1M-directed-edge SBM graph. `GEE_BENCH_QUICK=1` trims sizes.

use gee_sparse::gee::parallel::{prepare_par, ParallelGee};
use gee_sparse::gee::sparse_gee::SparseGee;
use gee_sparse::gee::GeeOptions;
use gee_sparse::graph::chung_lu::{generate_chung_lu, ChungLuParams};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::Graph;
use gee_sparse::util::timing::{bench_runs, secs, Stats};

const THREADS: &[usize] = &[1, 2, 4, 8];

fn sweep(name: &str, g: &Graph, reps: usize) {
    let opts = GeeOptions::ALL;
    println!(
        "-- {name}: n={} edges={} ({} directed), k={}",
        g.n,
        g.num_edges(),
        g.num_directed(),
        g.k
    );

    // determinism gate: parallel output must equal the serial fused engine
    let serial = SparseGee::fast().embed(g, &opts);
    for &t in THREADS {
        let z = ParallelGee::new(t).embed(g, &opts);
        assert_eq!(
            z.data, serial.data,
            "{name}: t={t} output not bitwise-identical to serial"
        );
    }
    println!("   bitwise-identical to serial fused engine at all thread counts ✓");

    println!(
        "   {:>8} {:>12} {:>9} {:>14} {:>9}",
        "threads", "embed (s)", "speedup", "amortized (s)", "speedup"
    );
    let mut base_embed = 0.0f64;
    let mut base_amort = 0.0f64;
    for &t in THREADS {
        let engine = ParallelGee::new(t);
        let full = Stats::from_runs(&bench_runs(1, reps, || {
            std::hint::black_box(engine.embed(g, &opts));
        }));
        // amortized: prepare once, one embed pass per option combo
        let prepared = prepare_par(g, t);
        let combos = GeeOptions::table_order();
        let amort = Stats::from_runs(&bench_runs(1, reps, || {
            for o in &combos {
                std::hint::black_box(prepared.embed_par(o, t));
            }
        }));
        let fs = full.median.as_secs_f64();
        let am = amort.median.as_secs_f64();
        if t == 1 {
            base_embed = fs;
            base_amort = am;
        }
        println!(
            "   {:>8} {:>12} {:>8.2}x {:>14} {:>8.2}x",
            t,
            secs(full.median),
            base_embed / fs.max(1e-12),
            secs(amort.median),
            base_amort / am.max(1e-12)
        );
    }
    println!();
}

fn main() {
    let quick = std::env::var("GEE_BENCH_QUICK").is_ok();
    let reps = if quick { 2 } else { 3 };
    println!(
        "== bench thread_sweep (reps={reps}, cores available: {}) ==\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    // SBM at the paper's parameters: n=10k gives ~5.6M undirected edges
    // (~11M directed), well past the 1M-directed-edge acceptance bar.
    let sbm_n = if quick { 3_000 } else { 10_000 };
    let sbm = generate_sbm(&SbmParams::paper(sbm_n), 7);
    sweep("SBM (paper params)", &sbm, reps);

    // Chung-Lu power-law twin: skewed degrees stress the nnz-balanced row
    // partition (a hub row cannot be split, only isolated in a chunk).
    let cl_edges = if quick { 300_000 } else { 1_000_000 };
    let cl = generate_chung_lu(
        &ChungLuParams { n: 50_000, edges: cl_edges, gamma: 1.8, k: 5 },
        11,
    );
    sweep("Chung-Lu (gamma=1.8)", &cl, reps);
}
