//! Bench: Fig. 3 — GEE vs sparse GEE runtime over the SBM size sweep
//! (100 … 10,000 nodes, paper parameters, Lap = Diag = Cor = T).
//!
//! Regenerates the paper's two series plus our engine variants. Custom
//! harness (the offline crate set has no criterion); medians over REPS
//! runs after one warmup. `GEE_BENCH_QUICK=1` trims the sweep.

use gee_sparse::gee::{Engine, GeeOptions};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::harness::{format_fig3, measure, run_fig3, FIG3_SIZES};
use gee_sparse::util::timing::secs;

fn main() {
    let quick = std::env::var("GEE_BENCH_QUICK").is_ok();
    let sizes: Vec<usize> = if quick {
        vec![100, 1_000, 3_000]
    } else {
        FIG3_SIZES.to_vec()
    };
    let reps = if quick { 2 } else { 5 };

    println!("== bench fig3_sbm (reps={reps}) ==");
    let points = run_fig3(&sizes, reps, 7);
    println!("{}", format_fig3(&points));

    // extended series: the §Perf-tuned sparse engine and the dense strawman
    println!("extended engines on the same graphs:");
    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "nodes", "sparse-fast", "dense", "paper GEE/sparse"
    );
    let paper: &[(usize, f64, f64)] = &[
        // (n, GEE s, sparse GEE s) read off the paper's Fig. 3 narrative:
        // largest point quoted exactly (52.4 vs 0.6); others approximate
        (10_000, 52.4, 0.6),
    ];
    let opts = GeeOptions::ALL;
    for &n in &sizes {
        let g = generate_sbm(&SbmParams::paper(n), 7);
        let fast = measure(Engine::SparseFast, &g, &opts, 1, reps);
        let dense = if n <= 5_000 {
            secs(measure(Engine::Dense, &g, &opts, 0, reps.min(2)).median)
        } else {
            "OOM-budget".to_string()
        };
        let paper_note = paper
            .iter()
            .find(|(pn, _, _)| *pn == n)
            .map(|(_, pg, ps)| format!("{pg}/{ps}s ({}x)", (pg / ps).round()))
            .unwrap_or_default();
        println!(
            "{:>8} {:>12} {:>12} {:>14}",
            n,
            secs(fast.median),
            dense,
            paper_note
        );
    }
}
