//! Bench: the serving data plane's client wire — v1 text vs v2 binary
//! vs v2 binary pipelined, against an in-process loopback server.
//!
//! Three lanes embed the same weighted SBM graph over one connection
//! each: `client-text` (lockstep v1 decimals), `client-binary`
//! (lockstep v2 frames), and `client-binary-pipelined` (the whole burst
//! in flight, replies collected out of order). Each row records req/s
//! (median over reps) and the wire bytes one full burst moves, measured
//! with the same [`ByteCounters`] the shard fleet uses. Two gates run
//! before timing: every lane's Z must be bitwise-identical to the text
//! lane's, and the binary wire must move strictly fewer bytes than
//! text.
//!
//! Results are appended to `BENCH_gee.json` (see `util::benchlog`).
//! `QUICK=1` (or the legacy `GEE_BENCH_QUICK`) trims sizes for CI smoke.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use gee_sparse::coordinator::server::TcpServer;
use gee_sparse::coordinator::{
    ClientConfig, ClientReply, EmbedClient, EmbedService, ServiceConfig,
};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::Graph;
use gee_sparse::shard::codec::ByteCounters;
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};
use gee_sparse::util::rng::Rng;
use gee_sparse::util::timing::{bench_runs, secs, Stats};

const CODE: &str = "ldc";

/// Real fleet graphs are weighted; an all-`1.0` generator graph would
/// let the text lane print each weight as one character and make the
/// byte comparison meaningless (same reasoning as shard_scale).
fn reweight(g: &mut Graph, seed: u64) {
    let mut rng = Rng::new(seed);
    for w in g.w.iter_mut() {
        *w = rng.f64() + 0.1;
    }
}

fn connect(addr: std::net::SocketAddr, force_text: bool) -> EmbedClient {
    connect_counted(addr, force_text, None)
}

fn connect_counted(
    addr: std::net::SocketAddr,
    force_text: bool,
    counters: Option<Arc<ByteCounters>>,
) -> EmbedClient {
    let cfg = ClientConfig { force_text, counters, ..ClientConfig::default() };
    let c = EmbedClient::connect(addr, &cfg).expect("connect");
    assert_eq!(c.is_binary(), !force_text, "negotiation mismatch");
    c
}

/// One pipelined burst: everything in flight, replies in completion
/// order. The generous server quota below keeps BUSY out of the lane —
/// this measures the wire, not admission.
fn run_pipelined(
    client: &mut EmbedClient,
    requests: usize,
    labels: &[i32],
    edges: &[(u32, u32, f64)],
    k: usize,
) {
    let mut pending = std::collections::HashSet::new();
    for _ in 0..requests {
        pending.insert(client.submit(CODE, labels, edges, k).expect("submit"));
    }
    for _ in 0..requests {
        let (id, reply) = client.recv_any().expect("recv");
        assert!(pending.remove(&id), "id {id} answered twice");
        match reply {
            ClientReply::Z(z) => {
                std::hint::black_box(z.data.as_ptr());
            }
            other => panic!("id {id}: unexpected {other:?}"),
        }
    }
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let requests = if quick { 16 } else { 64 };
    let n = if quick { 500 } else { 2_000 };
    println!("== bench client_wire (reps={reps}, {requests} requests per burst) ==\n");

    let mut g = generate_sbm(&SbmParams::paper(n), 7);
    reweight(&mut g, 1_013);
    let labels = g.labels.clone();
    let edges: Vec<(u32, u32, f64)> =
        (0..g.num_edges()).map(|i| (g.src[i], g.dst[i], g.w[i])).collect();
    println!("-- SBM (weighted): n={} edges={} k={}", g.n, g.num_edges(), g.k);

    // quota and queue sized so the pipelined burst is never refused
    let svc = Arc::new(EmbedService::start(ServiceConfig {
        tenant_tokens: 4 * requests,
        queue_depth: 4 * requests,
        ..ServiceConfig::default()
    }));
    let server = TcpServer::start("127.0.0.1:0", svc.clone()).expect("server");
    let addr = server.addr();

    // parity gate: both wires return the same bits
    let z_text = connect(addr, true).embed(CODE, &labels, &edges, g.k).expect("text embed");
    let z_bin = connect(addr, false).embed(CODE, &labels, &edges, g.k).expect("binary embed");
    assert_eq!(z_text.data.len(), z_bin.data.len());
    for (a, b) in z_text.data.iter().zip(&z_bin.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "wire lanes disagree");
    }
    println!("   binary Z bitwise vs text ✓");

    // byte gate: one full burst per lane, counted outside the timing
    // loops (deterministic per run)
    let mut lane_bytes = [(0u64, 0u64); 2]; // [(sent, received)] for [text, binary]
    for (i, force_text) in [true, false].into_iter().enumerate() {
        let counters = Arc::new(ByteCounters::default());
        let mut c = connect_counted(addr, force_text, Some(counters.clone()));
        for _ in 0..requests {
            std::hint::black_box(c.embed(CODE, &labels, &edges, g.k).expect("embed"));
        }
        lane_bytes[i] =
            (counters.sent.load(Ordering::Relaxed), counters.received.load(Ordering::Relaxed));
    }
    let text_total = lane_bytes[0].0 + lane_bytes[0].1;
    let bin_total = lane_bytes[1].0 + lane_bytes[1].1;
    assert!(
        bin_total < text_total,
        "binary wire must move strictly fewer bytes than text ({bin_total} vs {text_total})"
    );
    println!(
        "   binary wire moves {:.1}% of the text lane's bytes ✓",
        100.0 * bin_total as f64 / text_total as f64
    );

    let mut records = Vec::new();
    let mut results: Vec<(String, Stats, usize, (u64, u64))> = Vec::new();

    let mut text_client = connect(addr, true);
    let st = Stats::from_runs(&bench_runs(1, reps, || {
        for _ in 0..requests {
            std::hint::black_box(
                text_client.embed(CODE, &labels, &edges, g.k).expect("text embed"),
            );
        }
    }));
    results.push(("client-text".into(), st, 1, lane_bytes[0]));

    let mut bin_client = connect(addr, false);
    let st = Stats::from_runs(&bench_runs(1, reps, || {
        for _ in 0..requests {
            std::hint::black_box(
                bin_client.embed(CODE, &labels, &edges, g.k).expect("binary embed"),
            );
        }
    }));
    results.push(("client-binary".into(), st, 1, lane_bytes[1]));

    let mut pipe_client = connect(addr, false);
    let st = Stats::from_runs(&bench_runs(1, reps, || {
        run_pipelined(&mut pipe_client, requests, &labels, &edges, g.k);
    }));
    // pipelined traffic is byte-identical to lockstep binary — same
    // requests, same frames — so it reuses the binary lane's count
    results.push(("client-binary-pipelined".into(), st, requests, lane_bytes[1]));

    let base_ns = results[0].1.median.as_nanos();
    println!("   {:>24} {:>12} {:>10} {:>9}", "lane", "burst (s)", "req/s", "speedup");
    for (engine, st, depth, (sent, received)) in results {
        let ns = st.median.as_nanos();
        println!(
            "   {:>24} {:>12} {:>10.0} {:>8.2}x",
            engine,
            secs(st.median),
            requests as f64 / st.median.as_secs_f64().max(1e-9),
            base_ns as f64 / ns.max(1) as f64
        );
        records.push(BenchRecord {
            bench: "client_wire".into(),
            engine,
            n: g.n,
            m: g.num_directed(),
            k: g.k,
            threads: depth,
            median_ns: ns,
            speedup: base_ns as f64 / (ns.max(1) as f64),
            bytes_sent: sent,
            bytes_received: received,
            ..BenchRecord::default()
        });
    }

    server.stop();
    write_records("client_wire", &records);
}
