//! Bench: Table 4 — GEE vs Sparse GEE on the real-dataset twins, the
//! Laplacian-off half (Lap = F × {Diag, Cor}).
//!
//! The paper's finding for this half: without the Laplacian work, original
//! GEE can win on *small* graphs (construction overhead of the sparse
//! formats dominates) while sparse GEE still wins at scale — the
//! crossover this bench reproduces.

use gee_sparse::harness::{format_table, run_table};

fn main() {
    let quick = std::env::var("GEE_BENCH_QUICK").is_ok();
    let max_edges = if quick { 500_000 } else { usize::MAX };
    let reps = if quick { 2 } else { 3 };
    println!("== bench table4_real (reps={reps}, Lap=F) ==");
    let rows = run_table(false, reps, max_edges);
    println!("{}", format_table(&rows, 4));
    println!(
        "paper reference (scipy) for the largest twin, Lap=F Diag=F Cor=F:\n  \
         CL-100K-1d8-L5: GEE 171.714 s, Sparse GEE 106.264 s (1.6x)\n  \
         paper's small-graph crossover: GEE beats sparse on Citeseer/Cora when Lap=F"
    );
}
