//! Bench: cluster_loop — the iterative self-clustering lane (One-Hot
//! GEE: embed → k-means → relabel) locally and across a 2-daemon shard
//! fleet.
//!
//! A planted-partition SBM (the paper's 3-class shape, n=50k) is
//! clustered from deterministic seed labels. The local lane drives
//! [`IterativeJob`] over `sparse-fast`; the fleet lane drives the same
//! loop through a [`FleetSession`] against two in-process shard
//! daemons, where the graph ships once and rounds after the first
//! re-send only the label vector. Gates:
//!
//! * both lanes produce bitwise-identical per-round states and final Z;
//! * fleet traffic for rounds r>1 is O(W·n) label bytes — far below the
//!   round-1 cost of shipping edges (the RELABEL/RESHARD win);
//! * (full mode) the loop converges to ARI ≥ 0.9 vs the planted labels.
//!
//! One `BENCH_gee.json` row per round per lane: `median_ns` is that
//! round's wall time, `speedup` carries the round's ARI vs the previous
//! round's labels (the convergence trajectory), and the bytes columns
//! carry that round's fleet wire traffic. `QUICK=1` trims n for CI.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use gee_sparse::gee::iterate::{init_labels, IterativeJob, RoundState, INIT_SEED};
use gee_sparse::gee::sparse_gee::SparseGee;
use gee_sparse::gee::GeeOptions;
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::shard::codec::ByteCounters;
use gee_sparse::shard::spill::spill_from_graph;
use gee_sparse::shard::{DispatchConfig, FleetSession, ShardServer, SpillConfig};
use gee_sparse::tasks::metrics::{adjusted_rand_index, paired_labels};
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};

const ROUNDS: usize = 10;

struct LaneResult {
    z: Vec<f64>,
    labels: Vec<i32>,
    rounds: Vec<RoundState>,
    round_ns: Vec<u128>,
    /// Cumulative (sent, received) fleet bytes after each round; empty
    /// for the local lane.
    byte_marks: Vec<(u64, u64)>,
}

fn main() {
    let quick = quick_mode();
    let n = if quick { 5_000 } else { 50_000 };
    let seed = 42u64;
    let g = generate_sbm(&SbmParams::paper(n), seed);
    let k = g.k;
    let m = g.num_directed();
    let opts = GeeOptions::new(true, false, true);
    let truth = g.labels.clone();
    let init = init_labels(g.n, k, INIT_SEED);
    println!("== bench cluster_loop (n={n}, directed={m}, k={k}, rounds<={ROUNDS}) ==\n");

    // ---- local lane: IterativeJob over the in-process engine
    let mut wg = g.clone();
    wg.labels.copy_from_slice(&init);
    let driver = IterativeJob { rounds: ROUNDS, ..IterativeJob::new(g.n, k) };
    let engine = SparseGee::fast();
    let mut local = LaneResult {
        z: Vec::new(),
        labels: Vec::new(),
        rounds: Vec::new(),
        round_ns: Vec::new(),
        byte_marks: Vec::new(),
    };
    let mut last = Instant::now();
    let out = driver
        .run(
            Some(init.clone()),
            |lab| {
                wg.labels.copy_from_slice(lab);
                Ok(engine.embed(&wg, &opts))
            },
            |rs| {
                local.round_ns.push(last.elapsed().as_nanos().max(1));
                last = Instant::now();
                local.rounds.push(*rs);
            },
        )
        .expect("local cluster loop");
    local.z = out.z.data;
    local.labels = out.labels;

    // ---- fleet lane: same driver, rounds served by 2 shard daemons
    let s1 = ShardServer::start("127.0.0.1:0").expect("daemon 1");
    let s2 = ShardServer::start("127.0.0.1:0").expect("daemon 2");
    let spill_dir = std::env::temp_dir().join(format!("gee_cluster_bench_{}", std::process::id()));
    let mut fg = g.clone();
    fg.labels.copy_from_slice(&init);
    let sp = spill_from_graph(&fg, &SpillConfig { shards: 6, ..SpillConfig::new(spill_dir) })
        .expect("spill");
    let counters = Arc::new(ByteCounters::default());
    let dcfg = DispatchConfig {
        counters: Some(counters.clone()),
        ..DispatchConfig::new(vec![s1.addr().to_string(), s2.addr().to_string()])
    };
    let mut session = FleetSession::connect(&sp, &opts, &dcfg).expect("fleet session");
    let mut fleet = LaneResult {
        z: Vec::new(),
        labels: Vec::new(),
        rounds: Vec::new(),
        round_ns: Vec::new(),
        byte_marks: Vec::new(),
    };
    let mut last = Instant::now();
    let out = driver
        .run(
            Some(init.clone()),
            |lab| session.embed_round(lab),
            |rs| {
                fleet.round_ns.push(last.elapsed().as_nanos().max(1));
                last = Instant::now();
                fleet.rounds.push(*rs);
                fleet.byte_marks.push((
                    counters.sent.load(Ordering::Relaxed),
                    counters.received.load(Ordering::Relaxed),
                ));
            },
        )
        .expect("fleet cluster loop");
    session.close();
    s1.stop();
    s2.stop();
    fleet.z = out.z.data;
    fleet.labels = out.labels;

    // ---- gates: the lanes are the same computation
    assert_eq!(local.rounds, fleet.rounds, "per-round states must match");
    assert_eq!(local.labels, fleet.labels, "final labels must match");
    assert_eq!(local.z, fleet.z, "final Z must be bitwise identical across lanes");

    // rounds r>1 re-ship only the n-vector of labels (plus per-shard
    // headers): O(W·n) bytes against W=2 endpoints, far below round 1's
    // edge shipment
    let round1_sent = fleet.byte_marks[0].0;
    for (r, w) in fleet.byte_marks.windows(2).enumerate() {
        let sent = w[1].0 - w[0].0;
        assert!(
            sent <= 2 * (4 * n as u64) + 8_192,
            "round {} resent {} B — labels alone are {} B across 2 endpoints",
            r + 2,
            sent,
            2 * 4 * n as u64,
        );
        assert!(
            sent < round1_sent,
            "round {} sent {} B, not below round 1's {} B edge shipment",
            r + 2,
            sent,
            round1_sent,
        );
    }

    let pred = &local.labels;
    let (a, b) = paired_labels(pred, &truth);
    let ari = adjusted_rand_index(&a, &b);
    println!(
        "{:>6} {:>14} {:>14} {:>10} {:>12} {:>12}",
        "round", "local(ms)", "fleet(ms)", "changed", "ari_vs_prev", "fleet sent B"
    );
    let mut prev_sent = 0u64;
    for (i, rs) in local.rounds.iter().enumerate() {
        let sent = fleet.byte_marks[i].0 - prev_sent;
        prev_sent = fleet.byte_marks[i].0;
        println!(
            "{:>6} {:>14.3} {:>14.3} {:>10} {:>12.4} {:>12}",
            rs.round,
            local.round_ns[i] as f64 / 1e6,
            fleet.round_ns[i] as f64 / 1e6,
            rs.changed,
            rs.ari_vs_prev,
            sent,
        );
    }
    println!("\nfinal ARI vs planted labels: {ari:.4} ({} rounds)", local.rounds.len());
    if !quick {
        assert!(ari >= 0.9, "cluster loop must recover the planted partition, got ARI {ari:.4}");
    }

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut prev = (0u64, 0u64);
    for (i, rs) in local.rounds.iter().enumerate() {
        records.push(BenchRecord {
            bench: "cluster_loop".into(),
            engine: format!("cluster-local:r{}", rs.round),
            n,
            m,
            k,
            threads: 1,
            median_ns: local.round_ns[i],
            speedup: rs.ari_vs_prev,
            ..BenchRecord::default()
        });
        let (sent, received) = fleet.byte_marks[i];
        records.push(BenchRecord {
            bench: "cluster_loop".into(),
            engine: format!("cluster-fleet:r{}", rs.round),
            n,
            m,
            k,
            threads: 2,
            median_ns: fleet.round_ns[i],
            speedup: rs.ari_vs_prev,
            bytes_sent: sent - prev.0,
            bytes_received: received - prev.1,
            ..BenchRecord::default()
        });
        prev = (sent, received);
    }
    write_records("cluster_loop", &records);
}
