//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! A. W construction: DOK→CSR (published pipeline) vs direct CSR emission
//! B. SpMM engine: CSR×CSR (Gustavson, scipy's path) vs CSR×dense-K
//! A2. amortized repeated embedding (the Tables 3-4 workload)
//! A3. pooled u32 pipeline vs the PR-1 allocate-per-call fused engine —
//!     the zero-allocation acceptance comparison, recorded to
//!     `BENCH_gee.json` as engines "sparse-fast" vs "sparse-pooled" /
//!     "sparse-prepared-pooled"
//! C. COO→CSR build: general (counting sort + per-row sort) vs presorted
//! D. Storage: sparse pipeline bytes vs dense-Z (edge-list GEE) vs dense A
//! E. Service batching: solo vs disjoint-union packing (native lane)
//!
//! `QUICK=1` trims sizes for CI smoke runs.

use std::time::Duration;

use gee_sparse::coordinator::batcher::BatchCapacity;
use gee_sparse::coordinator::{EmbedRequest, EmbedService, Lane, ServiceConfig};
use gee_sparse::gee::sparse_gee::{embed_fused_into, Construction, SparseGee, SpmmEngine};
use gee_sparse::gee::edgelist_gee::EdgeListGee;
use gee_sparse::gee::{EmbedWorkspace, Engine, GeeOptions};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::sparse::Csr;
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};
use gee_sparse::util::rng::Rng;
use gee_sparse::util::timing::{bench_runs, secs, Stats};

fn stats(reps: usize, f: impl FnMut()) -> Stats {
    let mut f = f;
    Stats::from_runs(&bench_runs(1, reps, || f()))
}

fn main() {
    let quick = quick_mode();
    let n = if quick { 2_000 } else { 10_000 };
    let reps = if quick { 2 } else { 5 };
    let g = generate_sbm(&SbmParams::paper(n), 7);
    println!(
        "== bench ablation (SBM n={n}, edges={} / {} directed, reps={reps}) ==\n",
        g.num_edges(),
        g.num_directed()
    );
    let opts = GeeOptions::ALL;
    let mut records = Vec::new();
    let mut push = |engine: &str, threads: usize, st: &Stats, base_ns: u128| {
        records.push(BenchRecord {
            bench: "ablation".into(),
            engine: engine.into(),
            n: g.n,
            m: g.num_directed(),
            k: g.k,
            threads,
            median_ns: st.median.as_nanos(),
            speedup: base_ns as f64 / st.median.as_nanos().max(1) as f64,
            ..BenchRecord::default()
        });
    };

    // ---------------- A + B: construction × spmm grid
    println!("A/B. sparse-GEE engine grid (Lap=T Diag=T Cor=T, median s):");
    for construction in [Construction::DokThenCsr, Construction::DirectCsr] {
        for spmm in [SpmmEngine::CsrCsr, SpmmEngine::CsrDense, SpmmEngine::Fused] {
            let engine = SparseGee { construction, spmm };
            let st = stats(reps, || {
                std::hint::black_box(engine.embed(&g, &opts));
            });
            println!(
                "  {:>12?} + {:>9?}: {}",
                construction,
                spmm,
                secs(st.median)
            );
        }
    }

    // ---------------- A2: amortized repeated-embedding (the Tables 3-4
    // workload: 8 option combos on one graph)
    println!("\nA2. all 8 combos on one graph (total s):");
    let combos = GeeOptions::table_order();
    let st_solo = stats(reps.min(3), || {
        for o in &combos {
            std::hint::black_box(SparseGee::fast().embed(&g, o));
        }
    });
    let st_prepared = stats(reps.min(3), || {
        let p = SparseGee::prepare(&g);
        for o in &combos {
            std::hint::black_box(p.embed(o));
        }
    });
    let st_edgelist = stats(reps.min(3), || {
        for o in &combos {
            std::hint::black_box(EdgeListGee.embed(&g, o));
        }
    });
    println!("  fused, rebuild each time: {}", secs(st_solo.median));
    println!("  prepared once + 8 embeds: {}", secs(st_prepared.median));
    println!("  edge-list baseline (8x):  {}", secs(st_edgelist.median));

    // ---------------- A3: pooled u32 pipeline vs allocate-per-call (the
    // PR-1 engine). Same fused numerics; the pooled path reuses every
    // buffer from a warm workspace, the fresh path allocates all of them
    // per embed. Also the fully-amortized service path: prepared once,
    // pooled embed per request.
    println!("\nA3. pooled vs allocate-per-call (one ldc embed, median s):");
    let st_fresh = stats(reps, || {
        std::hint::black_box(SparseGee::fast().embed(&g, &opts));
    });
    let mut ws = EmbedWorkspace::new();
    embed_fused_into(&g, &opts, &mut ws); // warm the workspace
    let st_pooled = stats(reps, || {
        embed_fused_into(&g, &opts, &mut ws);
        std::hint::black_box(ws.z.data.as_ptr());
    });
    let prepared = SparseGee::prepare(&g);
    let mut ws2 = EmbedWorkspace::new();
    prepared.embed_into(&opts, &mut ws2);
    let st_prep_pooled = stats(reps, || {
        prepared.embed_into(&opts, &mut ws2);
        std::hint::black_box(ws2.z.data.as_ptr());
    });
    let base = st_fresh.median.as_nanos();
    push("sparse-fast", 1, &st_fresh, base);
    push("sparse-pooled", 1, &st_pooled, base);
    push("sparse-prepared-pooled", 1, &st_prep_pooled, base);
    println!(
        "  allocate-per-call (PR-1):   {}",
        secs(st_fresh.median)
    );
    println!(
        "  pooled fused (u32 + ws):    {}  ({:.2}x)",
        secs(st_pooled.median),
        base as f64 / st_pooled.median.as_nanos().max(1) as f64
    );
    println!(
        "  prepared + pooled embed:    {}  ({:.2}x)",
        secs(st_prep_pooled.median),
        base as f64 / st_prep_pooled.median.as_nanos().max(1) as f64
    );

    // ---------------- C: COO→CSR build paths
    println!("\nC. COO→CSR conversion (adjacency of the same graph):");
    let mut coo = g.adjacency();
    let st_general = stats(reps, || {
        std::hint::black_box(Csr::from_coo(&coo));
    });
    coo.sort_dedup();
    let st_sorted = stats(reps, || {
        std::hint::black_box(Csr::from_coo_sorted(&coo));
    });
    println!("  general (counting sort): {}", secs(st_general.median));
    println!("  presorted single pass:   {}", secs(st_sorted.median));

    // ---------------- D: storage accounting
    println!("\nD. storage (bytes) for the Laplacian pipeline:");
    let sparse_bytes = SparseGee::default().storage_bytes(&g, &opts);
    let edgelist_bytes = EdgeListGee.workspace_bytes(&g) + g.num_edges() * 3 * 8;
    let dense_bytes = g.n * g.n * 8;
    println!("  sparse GEE (A_s + W_s + Z_s): {:>14}", sparse_bytes);
    println!("  edge-list GEE (list + dense Z): {:>12}", edgelist_bytes);
    println!("  dense adjacency alone:        {:>14}", dense_bytes);

    // ---------------- E: batching on/off through the service
    println!("\nE. service throughput, batching off vs on (400 small requests):");
    for batching in [false, true] {
        let svc = EmbedService::start(ServiceConfig {
            lane: Lane::Native(Engine::SparseFast),
            workers: 2,
            batching,
            batch_capacity: BatchCapacity::from_bucket(2_048, 16_384, 16),
            batch_linger: Duration::from_millis(2),
            queue_depth: 1024,
            ..ServiceConfig::default()
        });
        let mut rng = Rng::new(99);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..400)
            .map(|i| {
                let gn = 30 + rng.below(120);
                let gg = generate_sbm(
                    &SbmParams::fitted(gn, 3, gn * 3, 3.0, vec![0.2, 0.3, 0.5]),
                    4_000 + i as u64,
                );
                svc.submit(EmbedRequest { graph: gg, options: GeeOptions::ALL }).unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let wall = t0.elapsed();
        let m = svc.shutdown();
        println!(
            "  batching={batching}: {:.2}s ({:.0} req/s, avg fill {:.2})",
            wall.as_secs_f64(),
            400.0 / wall.as_secs_f64(),
            m.avg_batch_fill()
        );
    }

    write_records("ablation", &records);
}
