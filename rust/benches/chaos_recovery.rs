//! Bench: recovery time under wire failures (ISSUE 10 satellite).
//!
//! Four rows, all against live loopback servers:
//!
//! * `clean` — fleet embed over two healthy daemons: the baseline.
//! * `daemon-kill` — one daemon accepts and immediately dies (fault plan
//!   `eof=1.0 grace=0`, the accept-then-die flap): time until the
//!   endpoint is condemned, its shards requeue onto the survivor, and
//!   the job completes — still bitwise-identical.
//! * `stall` — one daemon stalls every op past the hello budget: time
//!   for the deadline-driven condemnation path (each probe burns a
//!   `hello` timeout instead of an instant EOF).
//! * `slow-loris` — a coordinator connection that trickles a partial
//!   request line and stops: time until the header budget reaps it
//!   (measured via the `wire_loris_drops` counter).
//!
//! The fleet rows gate on bitwise equality with `SparseGee::fast()` —
//! recovery must never cost correctness. `speedup` records
//! clean-vs-row slowdown. Results append to `BENCH_gee.json`;
//! `QUICK=1` trims sizes for the CI smoke leg.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use gee_sparse::coordinator::server::TcpServer;
use gee_sparse::coordinator::{EmbedService, ServiceConfig};
use gee_sparse::gee::sparse_gee::SparseGee;
use gee_sparse::gee::GeeOptions;
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::shard::{
    embed_remote, spill::spill_from_graph, DaemonConfig, DispatchConfig,
    ShardServer, SpillConfig, SpilledShards,
};
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};
use gee_sparse::util::fault::FaultPlan;
use gee_sparse::util::retry::{BackoffPolicy, Deadlines};
use gee_sparse::util::timing::{bench_runs, secs, Stats};

fn faulty_daemon(spec: &str) -> ShardServer {
    let plan = Arc::new(FaultPlan::parse(spec).expect("fault plan"));
    ShardServer::start_with_config(
        "127.0.0.1:0",
        DaemonConfig {
            fault: Some(plan),
            idle_timeout: Some(Duration::from_secs(4)),
            io_timeout: Some(Duration::from_secs(2)),
            ..DaemonConfig::default()
        },
    )
    .expect("daemon")
}

fn fleet_config(endpoints: Vec<String>) -> DispatchConfig {
    DispatchConfig {
        deadlines: Deadlines::tight(),
        retry: BackoffPolicy {
            base: Duration::from_millis(5),
            cap: Duration::from_millis(40),
            attempts: 2,
            seed: 0xC4A05,
        },
        ..DispatchConfig::new(endpoints)
    }
}

/// One timed fleet embed, gated bitwise against the clean reference.
fn fleet_row(
    reps: usize,
    sp: &SpilledShards,
    opts: &GeeOptions,
    endpoints: Vec<String>,
    want: &[f64],
    row: &str,
) -> Stats {
    let cfg = fleet_config(endpoints);
    Stats::from_runs(&bench_runs(0, reps, || {
        let z = embed_remote(sp, opts, &cfg).expect("fleet embed");
        assert_eq!(&z.data[..], want, "{row}: recovery must stay bitwise");
    }))
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let n = if quick { 500 } else { 1_500 };
    println!("== bench chaos_recovery (reps={reps}) ==\n");

    let g = generate_sbm(&SbmParams::paper(n), 23);
    let opts = GeeOptions::ALL;
    let want = SparseGee::fast().embed(&g, &opts);
    let dir = std::env::temp_dir()
        .join(format!("gee_chaos_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("tmpdir");
    let sp = spill_from_graph(
        &g,
        &SpillConfig { shards: 4, ..SpillConfig::new(&dir) },
    )
    .expect("spill");
    println!("-- SBM: n={} edges={} k={}, 4 shards", g.n, g.num_edges(), g.k);

    let mut results: Vec<(String, Stats)> = Vec::new();

    // clean baseline: two healthy daemons
    {
        let a = ShardServer::start("127.0.0.1:0").expect("daemon");
        let b = ShardServer::start("127.0.0.1:0").expect("daemon");
        let st = fleet_row(
            reps,
            &sp,
            &opts,
            vec![a.addr().to_string(), b.addr().to_string()],
            &want.data,
            "clean",
        );
        results.push(("clean".into(), st));
        a.stop();
        b.stop();
    }

    // daemon-kill: one endpoint accepts, then every op is a hard EOF —
    // condemnation is instant (no timeout burned), shards requeue
    {
        let live = ShardServer::start("127.0.0.1:0").expect("daemon");
        let dead = faulty_daemon("seed=1 grace=0 eof=1.0");
        let st = fleet_row(
            reps,
            &sp,
            &opts,
            vec![live.addr().to_string(), dead.addr().to_string()],
            &want.data,
            "daemon-kill",
        );
        results.push(("daemon-kill".into(), st));
        live.stop();
        dead.stop();
    }

    // stall: the bad endpoint wedges every op for 3s, past the tight
    // hello budget — each probe costs a full deadline before condemnation
    {
        let live = ShardServer::start("127.0.0.1:0").expect("daemon");
        let wedged = faulty_daemon("seed=2 grace=0 stall=1.0:3000");
        let st = fleet_row(
            reps,
            &sp,
            &opts,
            vec![live.addr().to_string(), wedged.addr().to_string()],
            &want.data,
            "stall",
        );
        results.push(("stall".into(), st));
        live.stop();
        wedged.stop();
    }

    // slow-loris: partial request line against the coordinator; recovery
    // time is open-to-reap latency under a 300ms header budget
    {
        let svc = Arc::new(EmbedService::start(ServiceConfig {
            wire_deadlines: Deadlines {
                header: Some(Duration::from_millis(300)),
                ..Deadlines::tight()
            },
            ..ServiceConfig::default()
        }));
        let server = TcpServer::start("127.0.0.1:0", svc.clone()).expect("server");
        let st = Stats::from_runs(&bench_runs(0, reps, || {
            let before = svc.metrics().wire_loris_drops.load(Ordering::Relaxed);
            let mut s = TcpStream::connect(server.addr()).expect("connect");
            s.write_all(b"EMBED code=--- ").expect("partial header");
            s.flush().expect("flush");
            let t0 = Instant::now();
            while svc.metrics().wire_loris_drops.load(Ordering::Relaxed) == before {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "loris connection was never reaped"
                );
                std::thread::sleep(Duration::from_millis(5));
            }
        }));
        results.push(("slow-loris".into(), st));
        server.stop();
    }

    let base_ns = results[0].1.median.as_nanos();
    let mut records = Vec::new();
    println!("   {:>14} {:>12} {:>10}", "row", "median (s)", "slowdown");
    for (engine, st) in results {
        let ns = st.median.as_nanos();
        println!(
            "   {:>14} {:>12} {:>9.2}x",
            engine,
            secs(st.median),
            ns.max(1) as f64 / base_ns.max(1) as f64
        );
        records.push(BenchRecord {
            bench: "chaos_recovery".into(),
            engine,
            n: g.n,
            m: g.num_directed(),
            k: g.k,
            threads: 1,
            median_ns: ns,
            speedup: base_ns as f64 / (ns.max(1) as f64),
            ..BenchRecord::default()
        });
    }
    let _ = std::fs::remove_dir_all(&dir);
    write_records("chaos_recovery", &records);
}
