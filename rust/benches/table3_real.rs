//! Bench: Table 3 — GEE vs Sparse GEE on the real-dataset twins, the
//! Laplacian-on half of the option grid (Lap = T × {Diag, Cor}).
//!
//! `GEE_BENCH_QUICK=1` skips the 10M-edge CL-100K-1d8-L5 twin (its
//! generation alone is ~30 s).

use gee_sparse::harness::{format_table, run_table};

fn main() {
    let quick = std::env::var("GEE_BENCH_QUICK").is_ok();
    let max_edges = if quick { 500_000 } else { usize::MAX };
    let reps = if quick { 2 } else { 3 };
    println!("== bench table3_real (reps={reps}, Lap=T) ==");
    let rows = run_table(true, reps, max_edges);
    println!("{}", format_table(&rows, 3));
    println!(
        "paper reference (scipy, i5 laptop) for the largest twin, Lap=T Diag=T Cor=T:\n  \
         CL-100K-1d8-L5: GEE 604.018 s, Sparse GEE 174.552 s (3.5x)\n  \
         expectation here: same ordering (sparse wins), compiled-rust constants"
    );
}
