//! Bench: shard-count scaling of the vertex-range-sharded GEE engine on
//! SBM and Chung-Lu graphs — the sharded lane's perf trajectory next to
//! the in-core fused baseline, plus the out-of-core spill lane so the
//! disk-residency overhead is on the record too.
//!
//! Per shard count: the in-process sharded embed (phase 1 + bucket +
//! shard pass) and its speedup over the serial fused engine. One
//! out-of-core row per graph (spill + per-shard streaming embed from
//! disk), and two distributed rows (`sharded-remote`: the binary wire
//! with per-connection GLOBALS caching, and `sharded-remote-text`: the
//! same fleet forced onto the legacy v1 text wire) — two local
//! `gee shard-serve` daemons, shards dispatched over TCP; localhost
//! loopback, so the rows record protocol + placement overhead, the
//! floor of what a real fleet pays. Both remote rows carry their
//! `bytes_sent`/`bytes_received`, and the bench asserts the binary lane
//! moves strictly fewer bytes than the text lane on the same graph (the
//! GLOBALS cache amortizes labels+degrees across shards per
//! connection). Determinism gates first: every configuration must be
//! bitwise-identical to the serial fused engine.
//!
//! Results are appended to `BENCH_gee.json` (see `util::benchlog`).
//! `QUICK=1` (or the legacy `GEE_BENCH_QUICK`) trims sizes for CI smoke.

use std::io::BufRead;

use gee_sparse::gee::sparse_gee::SparseGee;
use gee_sparse::gee::GeeOptions;
use gee_sparse::graph::chung_lu::{generate_chung_lu, ChungLuParams};
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::Graph;
use gee_sparse::shard::{
    codec::ByteCounters, embed_out_of_core, embed_remote,
    spill::spill_from_graph, DispatchConfig, ShardedGee, SpillConfig,
};
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};
use gee_sparse::util::rng::Rng;
use gee_sparse::util::timing::{bench_runs, secs, Stats};

const SHARDS: &[usize] = &[1, 2, 4, 8];

/// Spawn a `gee shard-serve` daemon on an ephemeral port and return
/// (child, bound address) parsed from its announcement line.
fn spawn_daemon() -> (std::process::Child, String) {
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_gee"))
        .args(["shard-serve", "--listen", "127.0.0.1:0"])
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn gee shard-serve");
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    std::io::BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line.trim().rsplit(' ').next().unwrap().to_string();
    (child, addr)
}

fn record(
    out: &mut Vec<BenchRecord>,
    engine: &str,
    g: &Graph,
    shards: usize,
    st: &Stats,
    base_ns: u128,
) {
    record_bytes(out, engine, g, shards, st, base_ns, 0, 0);
}

#[allow(clippy::too_many_arguments)]
fn record_bytes(
    out: &mut Vec<BenchRecord>,
    engine: &str,
    g: &Graph,
    shards: usize,
    st: &Stats,
    base_ns: u128,
    bytes_sent: u64,
    bytes_received: u64,
) {
    let ns = st.median.as_nanos();
    out.push(BenchRecord {
        bench: "shard_scale".into(),
        engine: engine.into(),
        n: g.n,
        m: g.num_directed(),
        k: g.k,
        threads: shards,
        median_ns: ns,
        speedup: base_ns as f64 / (ns.max(1) as f64),
        bytes_sent,
        bytes_received,
        ..BenchRecord::default()
    });
}

fn sweep(name: &str, g: &Graph, reps: usize, records: &mut Vec<BenchRecord>) {
    let opts = GeeOptions::ALL;
    println!(
        "-- {name}: n={} edges={} ({} directed), k={}",
        g.n,
        g.num_edges(),
        g.num_directed(),
        g.k
    );

    // determinism gate: bitwise vs the serial fused engine at every count
    let serial = SparseGee::fast().embed(g, &opts);
    for &s in SHARDS {
        let z = ShardedGee::new(s).embed(g, &opts);
        assert_eq!(
            z.data, serial.data,
            "{name}: sharded s={s} not bitwise-identical to fused"
        );
    }
    println!("   sharded bitwise vs fused ✓ at all shard counts");

    // baseline row: the serial fused engine
    let fused_engine = SparseGee::fast();
    let fused = Stats::from_runs(&bench_runs(1, reps, || {
        std::hint::black_box(fused_engine.embed(g, &opts));
    }));
    let base_ns = fused.median.as_nanos();
    record(records, "sparse-fast", g, 1, &fused, base_ns);
    println!("   {:>10} {:>12} {:>9}", "config", "embed (s)", "speedup");
    println!("   {:>10} {:>12} {:>8.2}x", "fused", secs(fused.median), 1.0);

    for &s in SHARDS {
        let engine = ShardedGee::new(s);
        let st = Stats::from_runs(&bench_runs(1, reps, || {
            std::hint::black_box(engine.embed(g, &opts));
        }));
        record(records, "sharded", g, s, &st, base_ns);
        let label = format!("sharded:{s}");
        println!(
            "   {:>10} {:>12} {:>8.2}x",
            label,
            secs(st.median),
            base_ns as f64 / st.median.as_nanos().max(1) as f64
        );
    }

    // out-of-core: spill once, embed per rep from disk (4 shards)
    let dir = std::env::temp_dir().join(format!(
        "gee_shard_bench_{}_{}",
        std::process::id(),
        g.n
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let sp = spill_from_graph(g, &SpillConfig { shards: 4, ..SpillConfig::new(&dir) })
        .expect("spill");
    let st = Stats::from_runs(&bench_runs(1, reps, || {
        std::hint::black_box(embed_out_of_core(&sp, &opts).expect("ooc embed"));
    }));
    record(records, "sharded-ooc", g, 4, &st, base_ns);
    println!(
        "   {:>10} {:>12} {:>8.2}x   (spill + stream from disk)",
        "ooc:4",
        secs(st.median),
        base_ns as f64 / st.median.as_nanos().max(1) as f64
    );

    // distributed: the same spill dispatched to two local daemons over
    // TCP — the binary `sharded-remote` lane and the legacy text lane,
    // each with its wire bytes on the record
    let daemons: Vec<(std::process::Child, String)> =
        (0..2).map(|_| spawn_daemon()).collect();
    let endpoints: Vec<String> =
        daemons.iter().map(|(_, addr)| addr.clone()).collect();
    let mut lane_bytes = [0u64; 2]; // [binary, text] totals for the gate
    for (li, (engine_label, label, force_text)) in [
        ("sharded-remote", "remote:2", false),
        ("sharded-remote-text", "remote-txt", true),
    ]
    .into_iter()
    .enumerate()
    {
        let counters = std::sync::Arc::new(ByteCounters::default());
        let dcfg = DispatchConfig {
            force_text,
            counters: Some(counters.clone()),
            ..DispatchConfig::new(endpoints.clone())
        };
        let zr = embed_remote(&sp, &opts, &dcfg).expect("remote embed");
        assert_eq!(
            zr.data, serial.data,
            "{name}: {engine_label} not bitwise-identical to fused"
        );
        // bytes for exactly one embed (the determinism run above):
        // deterministic per run, so measured outside the timing loop
        let sent = counters.sent.load(std::sync::atomic::Ordering::Relaxed);
        let received =
            counters.received.load(std::sync::atomic::Ordering::Relaxed);
        lane_bytes[li] = sent + received;
        let dcfg_timed =
            DispatchConfig { counters: None, ..dcfg.clone() };
        let st = Stats::from_runs(&bench_runs(1, reps, || {
            std::hint::black_box(
                embed_remote(&sp, &opts, &dcfg_timed).expect("remote embed"),
            );
        }));
        record_bytes(records, engine_label, g, 2, &st, base_ns, sent, received);
        println!(
            "   {:>10} {:>12} {:>8.2}x   ({} MiB sent, {} MiB received; 2 daemons over loopback TCP)",
            label,
            secs(st.median),
            base_ns as f64 / st.median.as_nanos().max(1) as f64,
            sent >> 20,
            received >> 20,
        );
    }
    assert!(
        lane_bytes[0] < lane_bytes[1],
        "{name}: binary wire must move strictly fewer bytes than text \
         ({} vs {})",
        lane_bytes[0],
        lane_bytes[1]
    );
    println!(
        "   binary wire moves {:.1}% of the text lane's bytes ✓",
        100.0 * lane_bytes[0] as f64 / lane_bytes[1] as f64
    );
    for (mut child, _) in daemons {
        let _ = child.kill();
        let _ = child.wait();
    }

    drop(sp);
    let _ = std::fs::remove_dir_all(&dir);
    println!();
}

/// Give the bench graph representative f64 edge weights. Real fleet
/// graphs are weighted — that is why the spill/wire formats carry an
/// f64 per edge at all — and the byte-comparison gate in `sweep` is
/// only meaningful on that workload: an all-`1.0` generator graph lets
/// the text lane print each weight as one character, making decimal
/// text artificially denser than any fixed-width binary record.
fn reweight(g: &mut Graph, seed: u64) {
    let mut rng = Rng::new(seed);
    for w in g.w.iter_mut() {
        *w = rng.f64() + 0.1;
    }
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    println!(
        "== bench shard_scale (reps={reps}, cores available: {}) ==\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let mut records = Vec::new();

    let sbm_n = if quick { 2_000 } else { 10_000 };
    let mut sbm = generate_sbm(&SbmParams::paper(sbm_n), 7);
    reweight(&mut sbm, 1_007);
    sweep("SBM (paper params, weighted)", &sbm, reps, &mut records);

    let cl_edges = if quick { 100_000 } else { 1_000_000 };
    let cl_n = if quick { 10_000 } else { 50_000 };
    let mut cl = generate_chung_lu(
        &ChungLuParams { n: cl_n, edges: cl_edges, gamma: 1.8, k: 5 },
        11,
    );
    reweight(&mut cl, 1_009);
    sweep("Chung-Lu (gamma=1.8, weighted)", &cl, reps, &mut records);

    write_records("shard_scale", &records);
}
