//! Bench: delta_stream — the resident-session O(Δ) lane vs from-scratch
//! embedding under edge churn.
//!
//! Opens a [`GeeSession`] over a Chung-Lu graph (the paper's CL-100K
//! shape: n=100k, m=1M undirected, 1% churn), streams edge deltas
//! through `apply` + `refresh`, and compares the per-delta refresh cost
//! against the median from-scratch `sparse-fast` embed of the same
//! graph. A batched lane (apply 256 deltas, refresh once) shows the
//! coalescing win the serving fast-lane workers get.
//!
//! The session Z is gated bitwise against the from-scratch embed before
//! and after the churn stream — the lane must never trade exactness for
//! speed. Rows land in `BENCH_gee.json` (`median_ns` is per-delta for
//! the session lanes; `speedup` is full-embed-median / per-delta).
//! `QUICK=1` trims sizes for CI smoke.

use std::time::Instant;

use gee_sparse::coordinator::session::{Delta, GeeSession, SessionConfig};
use gee_sparse::gee::sparse_gee::SparseGee;
use gee_sparse::gee::GeeOptions;
use gee_sparse::graph::chung_lu::{generate_chung_lu, ChungLuParams};
use gee_sparse::graph::Graph;
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};
use gee_sparse::util::rng::Rng;
use gee_sparse::util::timing::{bench_runs, Stats};

/// Edge-churn stream: alternating deletes of live edges and inserts of
/// fresh random pairs, so the edge count stays roughly constant.
fn churn_stream(g: &Graph, count: usize, seed: u64) -> Vec<Delta> {
    let mut rng = Rng::new(seed);
    let mut live: Vec<(u32, u32)> =
        (0..g.num_edges()).map(|i| (g.src[i], g.dst[i])).collect();
    let n = g.n;
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        if i % 2 == 0 && !live.is_empty() {
            let (a, b) = live.swap_remove(rng.below(live.len()));
            out.push(Delta::Delete { a, b });
        } else {
            let (a, b) = (rng.below(n) as u32, rng.below(n) as u32);
            live.push((a, b));
            out.push(Delta::Insert { a, b, w: 1.0 + rng.f64() });
        }
    }
    out
}

fn parity_gate(s: &GeeSession, what: &str) {
    let fresh = SparseGee::fast().embed(&s.to_graph(), s.opts());
    assert_eq!(s.z().data, fresh.data, "{what}: session Z not bitwise");
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let (n, m) = if quick { (5_000, 50_000) } else { (100_000, 1_000_000) };
    let churn = m / 100; // 1% of the edge set
    let k = 10;
    println!("== bench delta_stream (n={n}, m={m} undirected, churn={churn}) ==\n");
    let g = generate_chung_lu(&ChungLuParams { n, edges: m, gamma: 1.8, k }, 42);
    let mut records: Vec<BenchRecord> = Vec::new();

    println!(
        "{:>6} {:>14} {:>14} {:>12} {:>10}",
        "opts", "full(ms)", "per-delta(us)", "deltas/sec", "speedup"
    );
    for opts in [GeeOptions::NONE, GeeOptions::ALL] {
        // ---- from-scratch baseline on the starting graph
        let engine = SparseGee::fast();
        let full = Stats::from_runs(&bench_runs(1, reps, || {
            std::hint::black_box(engine.embed(&g, &opts).data.as_ptr());
        }));
        let full_ns = full.median.as_nanos();

        // ---- per-delta lane: apply one delta, refresh immediately
        let cfg = SessionConfig { opts, rescale_threshold: 0.25 };
        let mut s = GeeSession::from_graph(&g, &cfg);
        parity_gate(&s, "pre-churn");
        let stream = churn_stream(&g, churn, 7 + opts.code().len() as u64);
        let t0 = Instant::now();
        for d in &stream {
            s.apply(d).expect("churn delta");
            s.refresh();
        }
        let per_delta_ns = (t0.elapsed().as_nanos() / stream.len() as u128).max(1);
        parity_gate(&s, "post-churn per-delta");

        // ---- batched lane: the fast-lane worker shape (coalesced dirty
        // rows, one refresh per batch of 256)
        let mut sb = GeeSession::from_graph(&g, &cfg);
        let stream_b = churn_stream(&g, churn, 11 + opts.code().len() as u64);
        let t0 = Instant::now();
        for chunk in stream_b.chunks(256) {
            let (applied, res) = sb.apply_all(chunk);
            assert_eq!((applied, res.is_ok()), (chunk.len(), true), "batched churn");
            sb.refresh();
        }
        let per_delta_batched_ns =
            (t0.elapsed().as_nanos() / stream_b.len() as u128).max(1);
        parity_gate(&sb, "post-churn batched");

        let speedup = full_ns as f64 / per_delta_ns as f64;
        let dps = 1e9 / per_delta_ns as f64;
        let dps_b = 1e9 / per_delta_batched_ns as f64;
        println!(
            "{:>6} {:>14.3} {:>14.3} {:>12.0} {:>9.1}x",
            opts.code(),
            full.median.as_secs_f64() * 1e3,
            per_delta_ns as f64 / 1e3,
            dps,
            speedup,
        );
        println!(
            "{:>6} {:>14} {:>14.3} {:>12.0} {:>9.1}x  (batch 256)",
            "",
            "",
            per_delta_batched_ns as f64 / 1e3,
            dps_b,
            full_ns as f64 / per_delta_batched_ns as f64,
        );
        if !quick {
            assert!(
                speedup >= 10.0,
                "per-delta refresh must beat a full embed 10x at 1% churn, got {speedup:.1}x"
            );
        }

        let dm = g.num_directed();
        records.push(BenchRecord {
            bench: "delta_stream".into(),
            engine: format!("full-embed-{}", opts.code()),
            n,
            m: dm,
            k,
            threads: 1,
            median_ns: full_ns,
            speedup: 1.0,
            ..BenchRecord::default()
        });
        records.push(BenchRecord {
            bench: "delta_stream".into(),
            engine: format!("session-delta-{}", opts.code()),
            n,
            m: dm,
            k,
            threads: 1,
            median_ns: per_delta_ns,
            speedup,
            ..BenchRecord::default()
        });
        records.push(BenchRecord {
            bench: "delta_stream".into(),
            engine: format!("session-batch256-{}", opts.code()),
            n,
            m: dm,
            k,
            threads: 1,
            median_ns: per_delta_batched_ns,
            speedup: full_ns as f64 / per_delta_batched_ns as f64,
            ..BenchRecord::default()
        });
    }

    write_records("delta_stream", &records);
}
