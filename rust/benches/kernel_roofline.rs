//! Bench: kernel roofline — how close each accumulation lane sits to the
//! machine's memory-bandwidth ceiling.
//!
//! Measures a stream baseline (memcpy + triad over kernel-sized f64
//! buffers), then times every dispatched small-K lane against the
//! generic reference on the same prepared graph, reporting estimated
//! bytes moved per nanosecond and that figure as a percentage of the
//! triad bandwidth. Also times the hub-splitting parallel plan on a
//! star graph whose center row exceeds the segmentation threshold.
//!
//! Each lane is gated bitwise against the generic kernel before timing —
//! dispatch must never change results, only speed.
//!
//! Rows land in `BENCH_gee.json` (`bytes_per_ns`, `pct_of_stream`,
//! speedup-vs-generic). `QUICK=1` trims sizes for CI smoke.

use gee_sparse::gee::kernel::{
    bytes_moved_estimate, counters_snapshot, force_kernel, reset_counters, KernelId,
};
use gee_sparse::gee::sparse_gee::SparseGee;
use gee_sparse::gee::{EmbedWorkspace, GeeOptions};
use gee_sparse::graph::Graph;
use gee_sparse::sparse::partition::HUB_SEGMENT_NNZ;
use gee_sparse::util::benchlog::{quick_mode, write_records, BenchRecord};
use gee_sparse::util::rng::Rng;
use gee_sparse::util::timing::{bench_runs, Stats};

/// Class counts swept: every fixed lane plus two chunked-lane points.
const KS: &[usize] = &[1, 2, 3, 4, 5, 6, 7, 8, 16, 32];

fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
    let mut rng = Rng::new(seed);
    let mut g = Graph::new(n, k);
    for l in g.labels.iter_mut() {
        *l = rng.below(k) as i32;
    }
    for _ in 0..m {
        g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
    }
    g
}

/// Measured stream bandwidth over `len` f64s: (copy bytes/ns, triad
/// bytes/ns). Copy counts read+write; triad counts two reads + a write —
/// the classic upper bounds the kernels are compared against.
fn stream_bw(len: usize, reps: usize) -> (f64, f64) {
    let src: Vec<f64> = (0..len).map(|i| i as f64 * 0.5).collect();
    let mut dst = vec![0.0f64; len];
    let copy = Stats::from_runs(&bench_runs(1, reps, || {
        dst.copy_from_slice(&src);
        std::hint::black_box(dst.as_ptr());
    }));
    let b: Vec<f64> = (0..len).map(|i| (i % 9) as f64).collect();
    let c: Vec<f64> = (0..len).map(|i| (i % 7) as f64).collect();
    let mut a = vec![0.0f64; len];
    let triad = Stats::from_runs(&bench_runs(1, reps, || {
        for i in 0..len {
            a[i] = b[i] + 2.5 * c[i];
        }
        std::hint::black_box(a.as_ptr());
    }));
    let copy_bpn = (2 * len * 8) as f64 / copy.median.as_nanos().max(1) as f64;
    let triad_bpn = (3 * len * 8) as f64 / triad.median.as_nanos().max(1) as f64;
    (copy_bpn, triad_bpn)
}

#[allow(clippy::too_many_arguments)]
fn push_row(
    records: &mut Vec<BenchRecord>,
    engine: String,
    g: &Graph,
    threads: usize,
    median_ns: u128,
    speedup: f64,
    bytes: u64,
    triad_bpn: f64,
) {
    let bpn = bytes as f64 / median_ns.max(1) as f64;
    records.push(BenchRecord {
        bench: "kernel_roofline".into(),
        engine,
        n: g.n,
        m: g.num_directed(),
        k: g.k,
        threads,
        median_ns,
        speedup,
        bytes_per_ns: bpn,
        pct_of_stream: 100.0 * bpn / triad_bpn.max(1e-12),
        ..BenchRecord::default()
    });
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 5 };
    let (n, m) = if quick { (2_000, 40_000) } else { (10_000, 1_000_000) };
    println!("== bench kernel_roofline (reps={reps}, n={n}, m={m} undirected) ==\n");
    reset_counters();
    let mut records: Vec<BenchRecord> = Vec::new();

    // ---- stream baseline over a buffer comparable to the edge arrays
    let stream_len = (2 * m).max(1 << 16);
    let (copy_bpn, triad_bpn) = stream_bw(stream_len, reps);
    println!(
        "stream baseline over {stream_len} f64s: copy {copy_bpn:.3} bytes/ns, triad {triad_bpn:.3} bytes/ns\n"
    );
    records.push(BenchRecord {
        bench: "kernel_roofline".into(),
        engine: "stream-copy".into(),
        n: stream_len,
        threads: 1,
        median_ns: ((2 * stream_len * 8) as f64 / copy_bpn.max(1e-12)) as u128,
        speedup: 1.0,
        bytes_per_ns: copy_bpn,
        pct_of_stream: 100.0 * copy_bpn / triad_bpn.max(1e-12),
        ..BenchRecord::default()
    });
    records.push(BenchRecord {
        bench: "kernel_roofline".into(),
        engine: "stream-triad".into(),
        n: stream_len,
        threads: 1,
        median_ns: ((3 * stream_len * 8) as f64 / triad_bpn.max(1e-12)) as u128,
        speedup: 1.0,
        bytes_per_ns: triad_bpn,
        pct_of_stream: 100.0,
        ..BenchRecord::default()
    });

    // ---- per-K lanes: dispatched vs forced-generic on the same graph.
    // GeeOptions::NONE isolates the accumulation loop itself — the part
    // the lanes specialize; options only add identical epilogue work.
    let opts = GeeOptions::NONE;
    println!(
        "{:>4} {:>8} {:>13} {:>13} {:>8} {:>10} {:>8}",
        "k", "lane", "dispatch(ms)", "generic(ms)", "speedup", "bytes/ns", "%stream"
    );
    for (ki, &k) in KS.iter().enumerate() {
        let g = random_graph(101 + ki as u64, n, m, k);
        let prepared = SparseGee::prepare(&g);
        let mut ws = EmbedWorkspace::new();
        let mut ws_gen = EmbedWorkspace::new();

        // bitwise gate before any timing
        prepared.embed_into(&opts, &mut ws);
        force_kernel(Some(KernelId::Generic));
        prepared.embed_into(&opts, &mut ws_gen);
        force_kernel(None);
        assert_eq!(
            ws.z.data, ws_gen.z.data,
            "k={k}: dispatched lane not bitwise-identical to generic"
        );

        let disp = Stats::from_runs(&bench_runs(1, reps, || {
            prepared.embed_into(&opts, &mut ws);
            std::hint::black_box(ws.z.data.as_ptr());
        }));
        force_kernel(Some(KernelId::Generic));
        let gene = Stats::from_runs(&bench_runs(1, reps, || {
            prepared.embed_into(&opts, &mut ws_gen);
            std::hint::black_box(ws_gen.z.data.as_ptr());
        }));
        force_kernel(None);

        let bytes = bytes_moved_estimate(g.n, g.num_directed(), k, &opts);
        let dns = disp.median.as_nanos();
        let gns = gene.median.as_nanos();
        let speedup = gns as f64 / dns.max(1) as f64;
        let lane = KernelId::for_k(k).name();
        let bpn = bytes as f64 / dns.max(1) as f64;
        let verdict = if k <= 8 && speedup < 1.3 { "  WARN <1.3x" } else { "" };
        println!(
            "{:>4} {:>8} {:>13.3} {:>13.3} {:>7.2}x {:>10.3} {:>7.1}%{verdict}",
            k,
            lane,
            disp.median.as_secs_f64() * 1e3,
            gene.median.as_secs_f64() * 1e3,
            speedup,
            bpn,
            100.0 * bpn / triad_bpn.max(1e-12),
        );
        push_row(
            &mut records,
            format!("kernel-{lane}-dispatch"),
            &g,
            1,
            dns,
            speedup,
            bytes,
            triad_bpn,
        );
        push_row(
            &mut records,
            format!("kernel-{lane}-generic"),
            &g,
            1,
            gns,
            1.0,
            bytes,
            triad_bpn,
        );
    }

    // ---- hub splitting: a star center far past the segmentation
    // threshold, parallel segment fan-out vs the serial segmented path
    let hub_n = if quick { 1_000 } else { 4_000 };
    let hub_edges = 3 * HUB_SEGMENT_NNZ + 500;
    let mut rng = Rng::new(909);
    let mut g = Graph::new(hub_n, 4);
    for l in g.labels.iter_mut() {
        *l = rng.below(4) as i32;
    }
    for i in 0..hub_edges {
        g.add_edge(0, (1 + (i % (hub_n - 1))) as u32, rng.f64() + 0.1);
    }
    for _ in 0..hub_n {
        g.add_edge(rng.below(hub_n) as u32, rng.below(hub_n) as u32, rng.f64() + 0.1);
    }
    let prepared = SparseGee::prepare(&g);
    let hopts = GeeOptions::ALL;
    let serial = prepared.embed(&hopts);
    let avail = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let t = avail.clamp(2, 8);
    let par = prepared.embed_par(&hopts, t);
    assert_eq!(par.data, serial.data, "hub split not bitwise at t={t}");
    let mut ws = EmbedWorkspace::new();
    let ser_st = Stats::from_runs(&bench_runs(1, reps, || {
        prepared.embed_into(&hopts, &mut ws);
        std::hint::black_box(ws.z.data.as_ptr());
    }));
    let mut wsp = EmbedWorkspace::new();
    let par_st = Stats::from_runs(&bench_runs(1, reps, || {
        prepared.embed_par_into(&hopts, t, &mut wsp);
        std::hint::black_box(wsp.z.data.as_ptr());
    }));
    let bytes = bytes_moved_estimate(g.n, g.num_directed(), g.k, &hopts);
    let sp = ser_st.median.as_nanos() as f64 / par_st.median.as_nanos().max(1) as f64;
    println!(
        "\nhub star (center nnz {hub_edges}): serial {:.3} ms, split t={t} {:.3} ms ({sp:.2}x), bitwise ✓",
        ser_st.median.as_secs_f64() * 1e3,
        par_st.median.as_secs_f64() * 1e3,
    );
    push_row(
        &mut records,
        "hub-split-serial".into(),
        &g,
        1,
        ser_st.median.as_nanos(),
        1.0,
        bytes,
        triad_bpn,
    );
    push_row(
        &mut records,
        "hub-split-par".into(),
        &g,
        t,
        par_st.median.as_nanos(),
        sp,
        bytes,
        triad_bpn,
    );

    println!("\nkernel dispatches this run: {}", counters_snapshot().nonzero_line());
    write_records("kernel_roofline", &records);
}
