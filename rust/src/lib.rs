//! # gee-sparse — Sparse Graph Encoder Embedding, three-layer edition
//!
//! Production-grade reproduction of **Qin & Shen, "Efficient Graph Encoder
//! Embedding for Large Sparse Graphs in Python" (2024)** as a rust
//! coordinator (L3) over JAX/Pallas AOT-compiled compute (L2/L1) executed
//! through PJRT, plus a full native sparse pipeline for the paper's
//! CPU-scale experiments.
//!
//! Layout (see DESIGN.md for the full inventory):
//!
//! * [`sparse`] — COO / DOK / CSR / dense substrate
//! * [`graph`] — graph type, SBM & Chung-Lu generators, dataset twins,
//!   stats (edge density Eq. 2, Fig 2 panels)
//! * [`gee`] — the three GEE implementations (dense, edge-list "original",
//!   sparse) and the lap/diag/cor options
//! * [`tasks`] — downstream validation: k-means, 1-NN, LDA, ARI/NMI
//! * [`runtime`] — PJRT client, artifact manifest, padded execution
//! * [`coordinator`] — embedding service: queue, batcher, streaming
//!   updates, metrics
//! * [`shard`] — vertex-range-sharded GEE: in-process, multi-process and
//!   out-of-core backends for graphs past one process's memory
//! * [`util`] — PRNG, JSON, property-test harness, timing

pub mod coordinator;
pub mod gee;
pub mod graph;
pub mod harness;
pub mod runtime;
pub mod shard;
pub mod sparse;
pub mod tasks;
pub mod util;
