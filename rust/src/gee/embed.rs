//! Unified embedding front-end: one enum over the GEE implementations
//! plus the PJRT-compiled path, so the coordinator, CLI and benches can
//! switch engines by name.
//!
//! This is also where the u32 index-compaction boundary check lives:
//! graphs whose directed-edge or vertex count exceeds `u32::MAX` are
//! rejected with a real error before any engine runs (the constructors
//! would otherwise panic with the same message).

use anyhow::Result;

use super::dense_gee::DenseGee;
use super::edgelist_gee::EdgeListGee;
use super::edgelist_par::EdgeListParGee;
use super::options::GeeOptions;
use super::parallel::ParallelGee;
use super::sparse_gee::SparseGee;
use super::workspace::EmbedWorkspace;
use crate::graph::Graph;
use crate::sparse::index::try_index;
use crate::sparse::Dense;

/// Which implementation computes the embedding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Dense-adjacency strawman (quadratic; node-budgeted).
    Dense,
    /// Original edge-list GEE (Shen & Priebe 2023).
    EdgeList,
    /// Edge-parallel edge-list GEE (per-thread Z partials, deterministic
    /// merge; 0 = auto threads). Bitwise-reproducible at a fixed thread
    /// count, ≤1e-12 vs the serial edge-list engine.
    EdgeListPar(usize),
    /// The paper's sparse GEE, published configuration (DOK + CSR×CSR).
    Sparse,
    /// Sparse GEE, §Perf-tuned configuration (direct CSR + fused SpMM).
    SparseFast,
    /// Row-parallel sparse GEE (std threads; 0 = auto). Bitwise-identical
    /// output to `SparseFast` for any thread count.
    SparsePar(usize),
    /// Vertex-range-sharded GEE (S shards; 0 = auto). Bitwise-identical
    /// to `SparseFast` for any shard count, and the only in-process lane
    /// that accepts graphs whose *global* directed-edge count overflows
    /// the u32 index space (each shard's structure is local, so only the
    /// per-shard slice must fit).
    Sharded(usize),
    /// Self-clustering GEE (One-Hot GEE, arXiv:2109.13098): alternate
    /// embed → k-means on Z → relabel for up to R rounds (0 = default
    /// cap), ignoring any input labels. The only lane whose output is a
    /// label *discovery*, not a supervised encoding — it is therefore
    /// excluded from [`Engine::ALL`] parity sweeps.
    Cluster(usize),
}

impl Engine {
    pub const ALL: &'static [Engine] = &[
        Engine::Dense,
        Engine::EdgeList,
        Engine::EdgeListPar(0),
        Engine::Sparse,
        Engine::SparseFast,
        Engine::SparsePar(0),
        Engine::Sharded(0),
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Dense => "dense",
            Engine::EdgeList => "edgelist",
            Engine::EdgeListPar(_) => "edgelist-par",
            Engine::Sparse => "sparse",
            Engine::SparseFast => "sparse-fast",
            Engine::SparsePar(_) => "sparse-par",
            Engine::Sharded(_) => "sharded",
            Engine::Cluster(_) => "cluster",
        }
    }

    pub fn from_name(s: &str) -> Option<Engine> {
        // "sparse-par:T" / "edgelist-par:T" / "sharded:S" pin the thread
        // or shard count; the bare names mean auto
        if let Some(t) = s.strip_prefix("sparse-par:") {
            return t.parse().ok().map(Engine::SparsePar);
        }
        if let Some(t) = s.strip_prefix("edgelist-par:") {
            return t.parse().ok().map(Engine::EdgeListPar);
        }
        if let Some(t) = s.strip_prefix("sharded:") {
            return t.parse().ok().map(Engine::Sharded);
        }
        if let Some(t) = s.strip_prefix("cluster:") {
            return t.parse().ok().map(Engine::Cluster);
        }
        match s {
            "dense" => Some(Engine::Dense),
            "edgelist" | "gee" | "original" => Some(Engine::EdgeList),
            "edgelist-par" | "epar" => Some(Engine::EdgeListPar(0)),
            "sparse" => Some(Engine::Sparse),
            "sparse-fast" | "fast" => Some(Engine::SparseFast),
            "sparse-par" | "par" => Some(Engine::SparsePar(0)),
            "sharded" | "shard" => Some(Engine::Sharded(0)),
            "cluster" => Some(Engine::Cluster(0)),
            _ => None,
        }
    }

    /// Reject graphs that overflow the u32 index space with a real error
    /// (engines past this point may assume 32-bit indexability). The
    /// common path is O(1): the directed expansion is at most 2·E, so the
    /// exact (O(E)) self-loop count is only taken when the cheap bound
    /// does not already prove fit. The sharded engine only needs the
    /// vertex check — its edge structures are per-shard, so the *global*
    /// directed-edge count may exceed the budget (that is the lane the
    /// coordinator routes such graphs to instead of erroring).
    fn check_index_width(g: &Graph) -> Result<()> {
        // anyhow::Error::new keeps IndexOverflow downcastable, so callers
        // can tell capacity rejection apart from other embed failures
        try_index(g.n, "vertices").map_err(anyhow::Error::new)?;
        if g.num_edges().saturating_mul(2) > crate::sparse::MAX_INDEX {
            try_index(g.num_directed(), "directed edges").map_err(anyhow::Error::new)?;
        }
        Ok(())
    }

    /// Run the embedding. All engines produce identical numerics (tested);
    /// they differ in data structures and therefore speed/space.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Result<Dense> {
        if let Engine::Sharded(s) = self {
            try_index(g.n, "vertices").map_err(anyhow::Error::new)?;
            return Ok(crate::shard::ShardedGee::new(*s).embed(g, opts));
        }
        Self::check_index_width(g)?;
        match self {
            Engine::Dense => DenseGee::default().embed(g, opts),
            Engine::EdgeList => Ok(EdgeListGee.embed(g, opts)),
            Engine::EdgeListPar(t) => Ok(EdgeListParGee::new(*t).embed(g, opts)),
            Engine::Sparse => Ok(SparseGee::default().embed(g, opts)),
            Engine::SparseFast => Ok(SparseGee::fast().embed(g, opts)),
            Engine::SparsePar(t) => Ok(ParallelGee::new(*t).embed(g, opts)),
            Engine::Cluster(iters) => cluster_local(g, opts, *iters),
            Engine::Sharded(_) => unreachable!("handled above"),
        }
    }

    /// Run the embedding with scratch borrowed from `ws` — the serving
    /// hot path. The engines with pooled lanes (edge-list, fused sparse,
    /// both parallel lanes) perform no per-request allocations beyond the
    /// returned Z buffer once the workspace is warm; the reference
    /// configurations (`Dense`, `Sparse`) keep their allocating paths —
    /// they exist for fidelity to the published pipeline, not throughput.
    pub fn embed_pooled(
        &self,
        g: &Graph,
        opts: &GeeOptions,
        ws: &mut EmbedWorkspace,
    ) -> Result<Dense> {
        if matches!(self, Engine::Sharded(_)) {
            // sharded accepts >u32 global directed edges; its embed path
            // applies the vertices-only check
            return self.embed(g, opts);
        }
        Self::check_index_width(g)?;
        match self {
            Engine::EdgeList => {
                EdgeListGee.embed_into(g, opts, ws);
                Ok(ws.take_z())
            }
            Engine::EdgeListPar(t) => {
                EdgeListParGee::new(*t).embed_into(g, opts, ws);
                Ok(ws.take_z())
            }
            Engine::SparseFast => {
                super::sparse_gee::embed_fused_into(g, opts, ws);
                Ok(ws.take_z())
            }
            Engine::SparsePar(t) => {
                ParallelGee::new(*t).embed_with(g, opts, ws);
                Ok(ws.take_z())
            }
            // the sharded engine pools one workspace per worker thread
            // internally; the cluster lane owns a workspace across its
            // rounds; the reference configurations keep their allocating
            // paths for fidelity to the published pipeline
            Engine::Dense | Engine::Sparse | Engine::Sharded(_) | Engine::Cluster(_) => {
                self.embed(g, opts)
            }
        }
    }
}

/// `Engine::Cluster` body: run the iterative self-clustering loop
/// in-process, riding `SparseFast`'s pooled lane with one workspace
/// reused across every round. Input labels are ignored (the loop
/// discovers its own from the deterministic init); `g.k` sets both the
/// cluster count and the embedding dimension.
fn cluster_local(g: &Graph, opts: &GeeOptions, iters: usize) -> Result<Dense> {
    let job = super::iterate::IterativeJob {
        rounds: iters,
        ..super::iterate::IterativeJob::new(g.n, g.k)
    };
    let mut gl = g.clone();
    let mut ws = EmbedWorkspace::new();
    let out = job.run(
        None,
        |labels: &[i32]| {
            gl.labels.copy_from_slice(labels);
            Engine::SparseFast.embed_pooled(&gl, opts, &mut ws)
        },
        |_| {},
    )?;
    Ok(out.z)
}

/// An embedding result with its provenance.
#[derive(Clone, Debug)]
pub struct Embedding {
    pub z: Dense,
    pub engine: Engine,
    pub options: GeeOptions,
}

impl Embedding {
    pub fn compute(engine: Engine, g: &Graph, opts: &GeeOptions) -> Result<Embedding> {
        Ok(Embedding { z: engine.embed(g, opts)?, engine, options: *opts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn names_roundtrip() {
        for e in Engine::ALL {
            assert_eq!(Engine::from_name(e.name()), Some(*e));
        }
        assert_eq!(Engine::from_name("original"), Some(Engine::EdgeList));
        assert_eq!(Engine::from_name("sparse-par"), Some(Engine::SparsePar(0)));
        assert_eq!(Engine::from_name("sparse-par:4"), Some(Engine::SparsePar(4)));
        assert_eq!(Engine::from_name("edgelist-par"), Some(Engine::EdgeListPar(0)));
        assert_eq!(
            Engine::from_name("edgelist-par:3"),
            Some(Engine::EdgeListPar(3))
        );
        assert_eq!(Engine::from_name("sharded"), Some(Engine::Sharded(0)));
        assert_eq!(Engine::from_name("sharded:5"), Some(Engine::Sharded(5)));
        assert_eq!(Engine::from_name("cluster"), Some(Engine::Cluster(0)));
        assert_eq!(Engine::from_name("cluster:7"), Some(Engine::Cluster(7)));
        assert_eq!(Engine::Cluster(3).name(), "cluster");
        assert_eq!(Engine::from_name("sparse-par:zap"), None);
        assert_eq!(Engine::from_name("sharded:x"), None);
        assert_eq!(Engine::from_name("cluster:x"), None);
        assert_eq!(Engine::from_name("bogus"), None);
    }

    #[test]
    fn engines_agree_via_front_end() {
        let mut rng = Rng::new(51);
        let mut g = Graph::new(25, 3);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        for _ in 0..70 {
            g.add_edge(rng.below(25) as u32, rng.below(25) as u32, 1.0);
        }
        let opts = GeeOptions::ALL;
        let base = Engine::Dense.embed(&g, &opts).unwrap();
        for e in Engine::ALL {
            let z = e.embed(&g, &opts).unwrap();
            assert!(base.max_abs_diff(&z) < 1e-10, "{} disagrees", e.name());
        }
    }

    #[test]
    fn pooled_front_end_matches_allocating_front_end() {
        let mut rng = Rng::new(52);
        let mut g = Graph::new(40, 4);
        for l in g.labels.iter_mut() {
            *l = rng.below(4) as i32;
        }
        for _ in 0..200 {
            g.add_edge(rng.below(40) as u32, rng.below(40) as u32, rng.f64() + 0.1);
        }
        let mut ws = EmbedWorkspace::new();
        for e in Engine::ALL {
            for opts in GeeOptions::table_order() {
                let fresh = e.embed(&g, &opts).unwrap();
                let pooled = e.embed_pooled(&g, &opts, &mut ws).unwrap();
                assert_eq!(
                    pooled.data,
                    fresh.data,
                    "pooled {} drifted at {opts:?}",
                    e.name()
                );
            }
        }
    }
}
