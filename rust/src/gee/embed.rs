//! Unified embedding front-end: one enum over the three GEE
//! implementations plus the PJRT-compiled path, so the coordinator, CLI
//! and benches can switch engines by name.

use anyhow::Result;

use super::dense_gee::DenseGee;
use super::edgelist_gee::EdgeListGee;
use super::options::GeeOptions;
use super::parallel::ParallelGee;
use super::sparse_gee::SparseGee;
use crate::graph::Graph;
use crate::sparse::Dense;

/// Which implementation computes the embedding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Dense-adjacency strawman (quadratic; node-budgeted).
    Dense,
    /// Original edge-list GEE (Shen & Priebe 2023).
    EdgeList,
    /// The paper's sparse GEE, published configuration (DOK + CSR×CSR).
    Sparse,
    /// Sparse GEE, §Perf-tuned configuration (direct CSR + CSR×dense).
    SparseFast,
    /// Row-parallel sparse GEE (std threads; 0 = auto). Bitwise-identical
    /// output to `SparseFast` for any thread count.
    SparsePar(usize),
}

impl Engine {
    pub const ALL: &'static [Engine] = &[
        Engine::Dense,
        Engine::EdgeList,
        Engine::Sparse,
        Engine::SparseFast,
        Engine::SparsePar(0),
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Dense => "dense",
            Engine::EdgeList => "edgelist",
            Engine::Sparse => "sparse",
            Engine::SparseFast => "sparse-fast",
            Engine::SparsePar(_) => "sparse-par",
        }
    }

    pub fn from_name(s: &str) -> Option<Engine> {
        // "sparse-par:T" pins the thread count; bare "sparse-par" = auto
        if let Some(t) = s.strip_prefix("sparse-par:") {
            return t.parse().ok().map(Engine::SparsePar);
        }
        match s {
            "dense" => Some(Engine::Dense),
            "edgelist" | "gee" | "original" => Some(Engine::EdgeList),
            "sparse" => Some(Engine::Sparse),
            "sparse-fast" | "fast" => Some(Engine::SparseFast),
            "sparse-par" | "par" => Some(Engine::SparsePar(0)),
            _ => None,
        }
    }

    /// Run the embedding. All engines produce identical numerics (tested);
    /// they differ in data structures and therefore speed/space.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Result<Dense> {
        match self {
            Engine::Dense => DenseGee::default().embed(g, opts),
            Engine::EdgeList => Ok(EdgeListGee.embed(g, opts)),
            Engine::Sparse => Ok(SparseGee::default().embed(g, opts)),
            Engine::SparseFast => Ok(SparseGee::fast().embed(g, opts)),
            Engine::SparsePar(t) => Ok(ParallelGee::new(*t).embed(g, opts)),
        }
    }
}

/// An embedding result with its provenance.
#[derive(Clone, Debug)]
pub struct Embedding {
    pub z: Dense,
    pub engine: Engine,
    pub options: GeeOptions,
}

impl Embedding {
    pub fn compute(engine: Engine, g: &Graph, opts: &GeeOptions) -> Result<Embedding> {
        Ok(Embedding { z: engine.embed(g, opts)?, engine, options: *opts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn names_roundtrip() {
        for e in Engine::ALL {
            assert_eq!(Engine::from_name(e.name()), Some(*e));
        }
        assert_eq!(Engine::from_name("original"), Some(Engine::EdgeList));
        assert_eq!(Engine::from_name("sparse-par"), Some(Engine::SparsePar(0)));
        assert_eq!(Engine::from_name("sparse-par:4"), Some(Engine::SparsePar(4)));
        assert_eq!(Engine::from_name("sparse-par:zap"), None);
        assert_eq!(Engine::from_name("bogus"), None);
    }

    #[test]
    fn engines_agree_via_front_end() {
        let mut rng = Rng::new(51);
        let mut g = Graph::new(25, 3);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        for _ in 0..70 {
            g.add_edge(rng.below(25) as u32, rng.below(25) as u32, 1.0);
        }
        let opts = GeeOptions::ALL;
        let base = Engine::Dense.embed(&g, &opts).unwrap();
        for e in Engine::ALL {
            let z = e.embed(&g, &opts).unwrap();
            assert!(base.max_abs_diff(&z) < 1e-10, "{} disagrees", e.name());
        }
    }
}
