//! Round-based iterative jobs: the embed→kmeans→relabel driver behind
//! `--engine cluster[:iters]` (One-Hot GEE's self-clustering loop,
//! arXiv:2109.13098) and every future iterative workload.
//!
//! The driver is transport-agnostic: it owns the loop — deterministic
//! label init, k-means on Z (the zero-allocation `kmeans_into` lane with
//! scratch reused across rounds), cluster-id alignment, convergence
//! bookkeeping — while the *embedding of the current labels* is a
//! closure supplied by the caller. The same driver therefore runs
//! against a local engine (`Engine::Cluster`), a pooled service worker,
//! or a persistent shard fleet where round r>1 re-ships only the label
//! vector against the cached `GLOBALS` hash.
//!
//! Determinism contract: given (n, k, seed) the initial labels are a
//! pure function of the config, k-means is bitwise-stable at any thread
//! count, and cluster-id alignment breaks ties by lowest index — so
//! every lane that embeds the same labels to the same Z walks the same
//! label trajectory and returns byte-identical output.

use anyhow::{Result, bail};

use crate::sparse::Dense;
use crate::tasks::kmeans::{KMeansConfig, KMeansScratch, kmeans_into};
use crate::tasks::metrics::{adjusted_rand_index, paired_labels};
use crate::util::rng::Rng;

/// Rounds cap when the caller asks for `cluster` without `:iters`.
pub const DEFAULT_ROUNDS: usize = 20;

/// Seed for the deterministic random label init. One constant shared by
/// every lane (CLI, service, wire client/server, fleet) — parity across
/// lanes starts from identical round-1 labels.
pub const INIT_SEED: u64 = 0x17E2_47E5;

/// Seed for the per-round k-means (fixed, not advanced round-to-round:
/// a round's output must be a pure function of its input Z).
const KMEANS_SEED: u64 = 0xC1_0551;

/// One embed→kmeans→relabel round, as reported to progress callbacks
/// and streamed back over the wire as a convergence summary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundState {
    /// 1-based round number.
    pub round: usize,
    /// Labels that differ from the previous round (after alignment).
    pub changed: usize,
    /// ARI between the previous and new labeling (1.0 = same partition).
    pub ari_vs_prev: f64,
    /// k-means inertia of this round's clustering.
    pub inertia: f64,
    /// Lloyd iterations the round's k-means took to converge.
    pub kmeans_iters: usize,
}

/// Outcome of an iterative job: the final embedding (always the embed of
/// `labels` — the driver re-embeds after the last relabel, so Z and
/// labels never disagree), the final labels, and the round trajectory.
#[derive(Clone, Debug)]
pub struct IterOutcome {
    pub z: Dense,
    pub labels: Vec<i32>,
    pub rounds: Vec<RoundState>,
}

/// Configuration for a round-based iterative job.
#[derive(Clone, Copy, Debug)]
pub struct IterativeJob {
    pub n: usize,
    /// Number of clusters (= embedding dimension).
    pub k: usize,
    /// Maximum rounds; 0 means [`DEFAULT_ROUNDS`].
    pub rounds: usize,
    /// Convergence tolerance: stop once `changed <= tol * n`. 0.0 means
    /// run to an exact label fixpoint (or the rounds cap).
    pub tol: f64,
    /// Seed for the deterministic label init.
    pub seed: u64,
    /// Thread budget for the k-means assignment step (0 = all cores);
    /// never changes results, only speed.
    pub kmeans_threads: usize,
}

impl IterativeJob {
    pub fn new(n: usize, k: usize) -> IterativeJob {
        IterativeJob { n, k, rounds: 0, tol: 0.0, seed: INIT_SEED, kmeans_threads: 0 }
    }

    /// The effective rounds cap (resolves the 0 = default sentinel).
    pub fn rounds_cap(&self) -> usize {
        if self.rounds == 0 { DEFAULT_ROUNDS } else { self.rounds }
    }

    /// Deterministic round-1 labels: a pure function of (n, k, seed).
    pub fn init_labels(&self) -> Vec<i32> {
        init_labels(self.n, self.k, self.seed)
    }

    /// Drive the loop. `embed` maps a label vector to its GEE embedding
    /// (local engine, pooled worker, or fleet round — the driver doesn't
    /// care); `on_round` observes each round as it completes (progress
    /// callbacks into metrics, wire `ROUND` lines). `labels0` overrides
    /// the deterministic init (a warm start from a previous job).
    pub fn run<E, C>(
        &self,
        labels0: Option<Vec<i32>>,
        mut embed: E,
        mut on_round: C,
    ) -> Result<IterOutcome>
    where
        E: FnMut(&[i32]) -> Result<Dense>,
        C: FnMut(&RoundState),
    {
        if self.n == 0 || self.k == 0 {
            bail!("iterative job needs n >= 1 and k >= 1 (got n={}, k={})", self.n, self.k);
        }
        let mut labels = match labels0 {
            Some(l) => {
                if l.len() != self.n {
                    bail!("warm-start labels have length {}, graph has {}", l.len(), self.n);
                }
                l
            }
            None => self.init_labels(),
        };
        let kcfg = KMeansConfig {
            k: self.k,
            seed: KMEANS_SEED,
            threads: self.kmeans_threads,
            ..KMeansConfig::new(self.k)
        };
        let mut scratch = KMeansScratch::new();
        let mut new_labels: Vec<i32> = Vec::with_capacity(self.n);
        let mut rounds_log = Vec::new();

        // Z always holds the embedding of `labels` at loop top.
        let mut z = embed(&labels)?;
        for round in 1..=self.rounds_cap() {
            let (inertia, kmeans_iters) = kmeans_into(&z, &kcfg, &mut scratch);
            new_labels.clear();
            new_labels.extend(scratch.assignments.iter().map(|&c| c as i32));
            // k-means is blind to cluster naming; align ids to the
            // previous round so the changed-count fixpoint is reachable
            align_to_previous(&labels, &mut new_labels, self.k);
            let changed = labels
                .iter()
                .zip(new_labels.iter())
                .filter(|(a, b)| a != b)
                .count();
            let ari_vs_prev = {
                let (a, b) = paired_labels(&labels, &new_labels);
                adjusted_rand_index(&a, &b)
            };
            let state = RoundState { round, changed, ari_vs_prev, inertia, kmeans_iters };
            on_round(&state);
            rounds_log.push(state);
            std::mem::swap(&mut labels, &mut new_labels);
            if changed == 0 {
                // exact fixpoint: Z is already the embedding of `labels`
                return Ok(IterOutcome { z, labels, rounds: rounds_log });
            }
            // keep the Z ↔ labels invariant: re-embed under the new
            // labels (also the final Z when this was the last round)
            z = embed(&labels)?;
            if (changed as f64) <= self.tol * self.n as f64 {
                break;
            }
        }
        Ok(IterOutcome { z, labels, rounds: rounds_log })
    }
}

/// Deterministic random label init shared by every lane.
pub fn init_labels(n: usize, k: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(k.max(1)) as i32).collect()
}

/// Rename the clusters in `new` (values in `0..k`) to maximally overlap
/// `old`: greedy largest-overlap assignment, ties broken by lowest new
/// id then lowest old id — deterministic, O(k³ + n). k-means output is
/// only a partition; without this, a converged partition whose ids
/// happen to permute between rounds would never reach `changed == 0`.
fn align_to_previous(old: &[i32], new: &mut [i32], k: usize) {
    if k == 0 {
        return;
    }
    let mut overlap = vec![0u64; k * k]; // overlap[new * k + old]
    for (&o, &nw) in old.iter().zip(new.iter()) {
        if o >= 0 && (o as usize) < k {
            overlap[nw as usize * k + o as usize] += 1;
        }
    }
    let mut perm = vec![usize::MAX; k]; // new id -> old id
    let mut used_old = vec![false; k];
    let mut used_new = vec![false; k];
    for _ in 0..k {
        let mut best: Option<(usize, usize, u64)> = None;
        for c in 0..k {
            if used_new[c] {
                continue;
            }
            for o in 0..k {
                if used_old[o] {
                    continue;
                }
                let v = overlap[c * k + o];
                // strict > keeps the first (lowest c, then lowest o) max
                let better = match best {
                    None => true,
                    Some((_, _, bv)) => v > bv,
                };
                if better {
                    best = Some((c, o, v));
                }
            }
        }
        let (c, o, _) = best.expect("k unused pairs remain by construction");
        perm[c] = o;
        used_new[c] = true;
        used_old[o] = true;
    }
    for l in new.iter_mut() {
        *l = perm[*l as usize] as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::{Engine, GeeOptions};
    use crate::graph::Graph;

    #[test]
    fn init_labels_deterministic_and_in_range() {
        let a = init_labels(100, 4, 7);
        let b = init_labels(100, 4, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&l| (0..4).contains(&l)));
        assert_ne!(a, init_labels(100, 4, 8), "different seed, different init");
    }

    #[test]
    fn align_maps_permuted_partition_onto_previous_ids() {
        let old = vec![0, 0, 1, 1, 2, 2];
        let mut new = vec![2, 2, 0, 0, 1, 1]; // same partition, renamed
        align_to_previous(&old, &mut new, 3);
        assert_eq!(new, old);
    }

    #[test]
    fn align_is_greedy_on_partial_overlap() {
        // new cluster 0 mostly covers old 1, new 1 mostly covers old 0
        let old = vec![1, 1, 1, 0, 0, 2];
        let mut new = vec![0, 0, 0, 1, 1, 2];
        align_to_previous(&old, &mut new, 3);
        assert_eq!(new, vec![1, 1, 1, 0, 0, 2]);
    }

    #[test]
    fn loop_reaches_fixpoint_on_label_independent_embedding() {
        // the embedding ignores the labels entirely (two fixed blobs),
        // so round 1 snaps the labels to the k-means partition and round
        // 2 must observe changed == 0 and stop — reusing round 2's Z
        // without a third embed call.
        let n = 12;
        let mut calls = 0usize;
        let embed = |_labels: &[i32]| {
            calls += 1;
            let mut z = Dense::zeros(n, 2);
            for i in 0..n {
                let hi = (i >= n / 2) as usize;
                *z.get_mut(i, hi) = 10.0;
            }
            Ok(z)
        };
        let mut seen = Vec::new();
        let job = IterativeJob { rounds: 10, ..IterativeJob::new(n, 2) };
        let out = job.run(None, embed, |r| seen.push(*r)).unwrap();
        assert!(out.rounds.len() <= 2, "rounds: {:?}", out.rounds);
        let last = out.rounds.last().unwrap();
        assert_eq!(last.changed, 0);
        assert!((last.ari_vs_prev - 1.0).abs() < 1e-12);
        assert_eq!(seen, out.rounds, "callback must see every round in order");
        // one embed per loop-top state; the fixpoint round reuses Z
        assert_eq!(calls, out.rounds.len());
        // labels must split exactly at n/2 (two coincident-point blobs)
        let a = out.labels[0];
        let b = out.labels[n / 2];
        assert_ne!(a, b);
        assert!(out.labels[..n / 2].iter().all(|&l| l == a));
        assert!(out.labels[n / 2..].iter().all(|&l| l == b));
    }

    #[test]
    fn rounds_cap_bounds_the_loop() {
        // an embedding of pure noise that reshuffles with the labels
        // never converges; the cap must stop it
        let n = 16;
        let embed = |labels: &[i32]| {
            let mut z = Dense::zeros(n, 2);
            let mut h = 0x9E37_79B9_u64;
            for (i, &l) in labels.iter().enumerate() {
                h = h.wrapping_mul(6364136223846793005).wrapping_add(l as u64 + i as u64);
                *z.get_mut(i, 0) = (h >> 11) as f64 / (1u64 << 53) as f64;
                *z.get_mut(i, 1) = (h >> 7) as f64 / (1u64 << 57) as f64;
            }
            Ok(z)
        };
        let job = IterativeJob { rounds: 3, ..IterativeJob::new(n, 2) };
        let out = job.run(None, embed, |_| {}).unwrap();
        assert!(out.rounds.len() <= 3);
    }

    #[test]
    fn recovers_planted_cliques_with_real_engine() {
        // two self-looped cliques (sizes 9 and 11): under any labeling,
        // every vertex of a clique sees the same neighbor multiset, so
        // clique rows coincide exactly and k-means++ must place its
        // second seed in the other clique (all distance mass is there).
        // The loop therefore snaps to the planted partition and stops.
        let sizes = [9usize, 11];
        let n = sizes.iter().sum::<usize>();
        let mut g = Graph::new(n, 2);
        let mut planted = vec![0i32; n];
        let mut base = 0usize;
        for (c, &sz) in sizes.iter().enumerate() {
            for i in base..base + sz {
                planted[i] = c as i32;
                for j in i..base + sz {
                    g.add_edge(i as u32, j as u32, 1.0);
                }
            }
            base += sz;
        }
        let opts = GeeOptions::NONE;
        let job = IterativeJob::new(n, 2);
        let out = job
            .run(
                None,
                |labels: &[i32]| {
                    let mut gl = g.clone();
                    gl.labels.copy_from_slice(labels);
                    Engine::SparseFast.embed(&gl, &opts)
                },
                |_| {},
            )
            .unwrap();
        let (a, b) = paired_labels(&planted, &out.labels);
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari > 0.99, "planted cliques not recovered: ARI {ari}");
        assert_eq!(out.rounds.last().unwrap().changed, 0, "{:?}", out.rounds);
        // the invariant: returned Z is the embedding of returned labels
        let mut gl = g.clone();
        gl.labels.copy_from_slice(&out.labels);
        let fresh = Engine::SparseFast.embed(&gl, &opts).unwrap();
        assert_eq!(out.z.data, fresh.data);
    }
}
