//! Construction of the paper's weight matrix W (§2): the normalized
//! one-hot encoding with `W[j, y_j] = 1/n_{y_j}`, in each storage format
//! the three GEE variants consume.

use crate::sparse::{Csr, Dense, Dok};

/// Per-class vertex counts as f64 (unlabeled vertices excluded).
pub fn class_counts(labels: &[i32], k: usize) -> Vec<f64> {
    let mut n_k = vec![0.0f64; k];
    for &l in labels {
        if l >= 0 {
            n_k[l as usize] += 1.0;
        }
    }
    n_k
}

/// Dense N×K weight matrix (baseline GEE variants).
pub fn weight_matrix_dense(labels: &[i32], k: usize) -> Dense {
    let n_k = class_counts(labels, k);
    let mut w = Dense::zeros(labels.len(), k);
    for (j, &l) in labels.iter().enumerate() {
        if l >= 0 && n_k[l as usize] > 0.0 {
            *w.get_mut(j, l as usize) = 1.0 / n_k[l as usize];
        }
    }
    w
}

/// The paper's construction path: build W in DOK (random-access inserts),
/// exactly as the scipy implementation does before converting to CSR.
pub fn weight_matrix_dok(labels: &[i32], k: usize) -> Dok {
    let n_k = class_counts(labels, k);
    let mut w = Dok::with_capacity(labels.len(), k, labels.len());
    for (j, &l) in labels.iter().enumerate() {
        if l >= 0 && n_k[l as usize] > 0.0 {
            w.set(j as u32, l as u32, 1.0 / n_k[l as usize]);
        }
    }
    w
}

/// Direct CSR construction — the §Perf fast path: W has exactly one entry
/// per labeled row, so CSR can be emitted in one pass with no hashing and
/// no sort. Ablation partner of [`weight_matrix_dok`].
pub fn weight_matrix_csr_direct(labels: &[i32], k: usize) -> Csr {
    let n_k = class_counts(labels, k);
    let n = labels.len();
    crate::sparse::index::to_index(n, "vertices");
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::with_capacity(n);
    let mut data = Vec::with_capacity(n);
    indptr.push(0u32);
    for &l in labels {
        if l >= 0 && n_k[l as usize] > 0.0 {
            indices.push(l as u32);
            data.push(1.0 / n_k[l as usize]);
        }
        indptr.push(indices.len() as u32);
    }
    Csr { nrows: n, ncols: k, indptr, indices, data }
}

/// Per-vertex weight value `1/n_{y_j}` (0 for unlabeled) — the edge-list
/// GEE variant consumes W in this collapsed form.
pub fn weight_values(labels: &[i32], k: usize) -> Vec<f64> {
    let mut n_k = Vec::new();
    let mut wv = Vec::new();
    weight_values_into(labels, k, &mut n_k, &mut wv);
    wv
}

/// Fill `n_k` with per-class counts, reusing its capacity — the pooled
/// twin of [`class_counts`] (zero allocations once the buffer is warm).
pub fn class_counts_into(labels: &[i32], k: usize, n_k: &mut Vec<f64>) {
    n_k.clear();
    n_k.resize(k, 0.0);
    for &l in labels {
        if l >= 0 {
            n_k[l as usize] += 1.0;
        }
    }
}

/// Fill `wv` with the per-vertex `1/n_{y_j}` weights, using `n_k` as
/// class-count scratch — the pooled twin of [`weight_values`]. Both
/// buffers reuse their capacity: zero allocations once warm.
pub fn weight_values_into(labels: &[i32], k: usize, n_k: &mut Vec<f64>, wv: &mut Vec<f64>) {
    class_counts_into(labels, k, n_k);
    weight_values_from_counts(labels, n_k, wv);
}

/// Fill `wv` from already-maintained class counts. Split out of
/// [`weight_values_into`] so incrementally-tracked `n_k` (the session /
/// streaming lanes) produces bit-identical weights to the batch path.
pub fn weight_values_from_counts(labels: &[i32], n_k: &[f64], wv: &mut Vec<f64>) {
    wv.clear();
    wv.extend(labels.iter().map(|&l| {
        if l >= 0 && n_k[l as usize] > 0.0 {
            1.0 / n_k[l as usize]
        } else {
            0.0
        }
    }));
}

#[cfg(test)]
mod tests {
    use super::*;

    const LABELS: &[i32] = &[0, 0, 1, 2, 2, 2, -1];

    #[test]
    fn counts_exclude_unlabeled() {
        assert_eq!(class_counts(LABELS, 3), vec![2.0, 1.0, 3.0]);
    }

    #[test]
    fn dense_columns_sum_to_one() {
        let w = weight_matrix_dense(LABELS, 3);
        for c in 0..3 {
            let sum: f64 = (0..7).map(|r| w.get(r, c)).sum();
            assert!((sum - 1.0).abs() < 1e-12, "col {c} sums to {sum}");
        }
        assert_eq!(w.row(6), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn all_formats_agree() {
        let dense = weight_matrix_dense(LABELS, 3);
        let dok = weight_matrix_dok(LABELS, 3).to_csr().to_dense();
        let direct = weight_matrix_csr_direct(LABELS, 3).to_dense();
        assert!(dense.max_abs_diff(&dok) < 1e-15);
        assert!(dense.max_abs_diff(&direct) < 1e-15);
    }

    #[test]
    fn direct_csr_has_one_entry_per_labeled_row() {
        let w = weight_matrix_csr_direct(LABELS, 3);
        assert_eq!(w.nnz(), 6);
        assert_eq!(w.indptr.len(), 8);
    }

    #[test]
    fn weight_values_match_dense_diagonal() {
        let vals = weight_values(LABELS, 3);
        let dense = weight_matrix_dense(LABELS, 3);
        for (j, &l) in LABELS.iter().enumerate() {
            if l >= 0 {
                assert_eq!(vals[j], dense.get(j, l as usize));
            } else {
                assert_eq!(vals[j], 0.0);
            }
        }
    }

    #[test]
    fn into_variants_match_and_reuse_capacity() {
        let mut n_k = Vec::new();
        let mut wv = Vec::new();
        weight_values_into(LABELS, 3, &mut n_k, &mut wv);
        assert_eq!(n_k, class_counts(LABELS, 3));
        assert_eq!(wv, weight_values(LABELS, 3));
        // second fill with the same shapes must not grow the buffers
        let (cap_nk, cap_wv) = (n_k.capacity(), wv.capacity());
        weight_values_into(LABELS, 3, &mut n_k, &mut wv);
        assert_eq!(n_k.capacity(), cap_nk);
        assert_eq!(wv.capacity(), cap_wv);
        assert_eq!(wv, weight_values(LABELS, 3));
    }

    #[test]
    fn empty_class_is_all_zero() {
        let labels = &[0, 0, 2]; // class 1 empty
        let w = weight_matrix_dense(labels, 3);
        for r in 0..3 {
            assert_eq!(w.get(r, 1), 0.0);
        }
    }
}
