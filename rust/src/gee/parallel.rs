//! Row-parallel sparse GEE — intra-graph parallelism over std threads.
//!
//! The serial fused engine ([`super::sparse_gee::SparseGee`] with
//! `SpmmEngine::Fused`, and its amortized twin [`PreparedGraph`]) is one
//! counting sort plus one row-major accumulation pass. Both passes
//! parallelize along the row dimension with no shared mutable state
//! (Edge-Parallel GEE, Lubonja, Priebe & Shen, arXiv:2402.04403, shows
//! the per-row accumulation scales near-linearly; One-Hot GEE,
//! arXiv:2109.13098, frames billions of edges as the target scale):
//!
//! * **prepare** — each thread counting-sorts a contiguous chunk of the
//!   edge list into a thread-local row-grouped buffer; local counts merge
//!   into the global `indptr` by a **parallel vertex-range merge** (each
//!   thread owns a contiguous vertex range, sums the per-vertex deltas
//!   across locals, prefix-sums within its range; range totals are
//!   prefix-summed serially and the offsets applied back in parallel —
//!   pure integer arithmetic, so the result is identical to the serial
//!   merge for any thread count). Threads then copy their row segments
//!   into disjoint ranges of the global `cols`/`vals` arrays.
//!   Concatenating per-thread segments in thread order reproduces global
//!   edge order within every row, so the arrays are **bitwise identical**
//!   to the serial [`PreparedGraph::new`] for any thread count.
//! * **degrees** — recovered per row as the ordered sum of that row's
//!   values. The serial constructor accumulates `deg[v]` in edge order,
//!   which is exactly the order the row's values land in, so this too is
//!   bitwise identical (and thread-count independent, unlike merging
//!   per-thread partial degree sums would be).
//! * **embed** — rows of Z are partitioned into contiguous chunks
//!   balanced by nonzero count ([`crate::sparse::partition::nnz_chunks`],
//!   shared with `Csr::spmm_dense_par`); each thread owns a disjoint
//!   `z.data` slice via [`std::thread::scope`] + `split_at_mut`, so there
//!   are no locks and no atomics. Every row is computed by exactly one
//!   thread with the same sequential accumulation the serial engine uses:
//!   the output is bitwise-deterministic regardless of thread count, and
//!   bitwise-equal to the serial fused engine. The lap/diag/cor options
//!   fold analytically exactly as the fused path does.
//! * **hub rows** — a row whose nnz exceeds
//!   [`HUB_SEGMENT_NNZ`](crate::sparse::partition::HUB_SEGMENT_NNZ) would
//!   serialize its chunk no matter how the boundaries fall, so
//!   [`accumulate_rows_par`] excises hub rows from the chunk plan and fans
//!   their fixed-order column segments across *all* threads, then merges
//!   the partials serially in segment order. The segment grid is the same
//!   one the serial kernel uses (a pure function of nnz — see
//!   [`super::kernel`]), so the result stays bitwise-identical to serial
//!   at any thread count.
//!
//! No dependencies beyond std. Exposed through
//! [`Engine::SparsePar`](super::embed::Engine) and the coordinator's
//! `ServiceConfig::intra_op_threads` knob (large solo graphs from the
//! batcher's oversize lane route here instead of pinning one worker).

use std::thread;

use super::kernel::{
    accumulate_rows, accumulate_segment, note_split_rows, row_epilogue, AccumCtx,
};
use super::options::GeeOptions;
use super::sparse_gee::PreparedGraph;
use super::weights::weight_values;
use super::workspace::{reset_f64, EmbedWorkspace};
use crate::graph::Graph;
use crate::sparse::index::to_index;
use crate::sparse::ops::safe_recip_sqrt;
use crate::sparse::partition::{
    even_chunks, hub_segments, nnz_chunks, segment_range, HUB_SEGMENT_NNZ,
};
use crate::sparse::Dense;

/// Below this many undirected edges `ParallelGee::embed` stays serial —
/// thread spawn/merge overhead dominates tiny graphs.
pub const PAR_MIN_EDGES: usize = 2_048;

/// Row-parallel sparse GEE engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelGee {
    /// Worker thread count; 0 = use `std::thread::available_parallelism`.
    pub threads: usize,
}

impl ParallelGee {
    pub fn new(threads: usize) -> Self {
        ParallelGee { threads }
    }

    /// The thread count a call will actually use — the shared policy in
    /// [`crate::sparse::partition::resolve_threads`] (0 = auto, explicit
    /// requests capped at available parallelism).
    pub fn resolved_threads(&self) -> usize {
        crate::sparse::partition::resolve_threads(self.threads)
    }

    /// Embed the graph. Output is bitwise-identical to the serial fused
    /// engine (`SparseGee::fast()`) for every option combination and any
    /// thread count.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Dense {
        let mut ws = EmbedWorkspace::new();
        self.embed_with(g, opts, &mut ws);
        ws.take_z()
    }

    /// Embed into `ws.z`. The output buffer and (on the serial fallback)
    /// all scratch come from `ws`; the genuinely parallel path still
    /// allocates its thread-local sort buffers, which is why the serving
    /// layer's zero-allocation contract covers the serial prepared path.
    pub fn embed_with(&self, g: &Graph, opts: &GeeOptions, ws: &mut EmbedWorkspace) {
        let t = self.resolved_threads();
        if t <= 1 || g.num_edges() < PAR_MIN_EDGES {
            super::sparse_gee::embed_fused_into(g, opts, ws);
            return;
        }
        prepare_par(g, t).embed_par_into(opts, t, ws);
    }
}

/// One thread's counting-sorted slice of the edge list.
struct LocalSort {
    /// Row pointers (length n+1, u32-compacted) into `cols`/`vals`.
    indptr: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f64>,
}

/// Serial reference merge of per-thread counts: per-vertex deltas summed
/// across locals, then prefix-summed. O(t·n). Kept as the oracle the
/// parallel merge must reproduce exactly (pure integer arithmetic).
fn merge_counts_serial(locals: &[LocalSort], n: usize) -> Vec<u32> {
    let mut indptr = vec![0u32; n + 1];
    for l in locals {
        for v in 0..n {
            indptr[v + 1] += l.indptr[v + 1] - l.indptr[v];
        }
    }
    for v in 0..n {
        indptr[v + 1] += indptr[v];
    }
    indptr
}

/// Parallel count-merge by vertex-range split (the ROADMAP open item):
/// each thread sums the per-vertex count deltas across all locals for a
/// contiguous vertex range and prefix-sums within the range (O(t·n/T)
/// per thread); the T range totals are prefix-summed serially and the
/// offsets applied back in parallel. Output is **identical** to
/// [`merge_counts_serial`] for any thread count — integer arithmetic has
/// no reassociation error — and the equality is asserted in debug builds.
fn merge_counts_par(locals: &[LocalSort], n: usize, threads: usize) -> Vec<u32> {
    let mut indptr = vec![0u32; n + 1];
    let vbounds = even_chunks(n, threads);
    let totals: Vec<u32> = thread::scope(|s| {
        let mut rest: &mut [u32] = &mut indptr[1..];
        let mut handles = Vec::with_capacity(vbounds.len() - 1);
        for w in vbounds.windows(2) {
            let (v0, v1) = (w[0], w[1]);
            let (here, next) = std::mem::take(&mut rest).split_at_mut(v1 - v0);
            rest = next;
            handles.push(s.spawn(move || {
                let mut run = 0u32;
                for (i, v) in (v0..v1).enumerate() {
                    for l in locals {
                        run += l.indptr[v + 1] - l.indptr[v];
                    }
                    here[i] = run;
                }
                run
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("count-merge worker panicked"))
            .collect()
    });
    thread::scope(|s| {
        let mut rest: &mut [u32] = &mut indptr[1..];
        let mut off = 0u32;
        for (w, &total) in vbounds.windows(2).zip(totals.iter()) {
            let (here, next) = std::mem::take(&mut rest).split_at_mut(w[1] - w[0]);
            rest = next;
            if off != 0 && !here.is_empty() {
                let o = off;
                s.spawn(move || {
                    for x in here.iter_mut() {
                        *x += o;
                    }
                });
            }
            off += total;
        }
    });
    indptr
}

/// Build a [`PreparedGraph`] with `threads` workers: per-thread local
/// counting sorts over contiguous edge chunks, merged by the parallel
/// vertex-range merge above.
/// The result is bitwise-identical to the serial [`PreparedGraph::new`].
pub fn prepare_par(g: &Graph, threads: usize) -> PreparedGraph {
    let n = g.n;
    let ne = g.num_edges();
    let m = g.num_directed();
    to_index(m, "directed edges");
    let t = threads.max(1).min(ne.max(1));
    if t <= 1 || n == 0 {
        return PreparedGraph::new(g);
    }
    let chunk = (ne + t - 1) / t;

    // ---- phase 1 (parallel): counting-sort each edge chunk locally
    let locals: Vec<LocalSort> = thread::scope(|s| {
        let handles: Vec<_> = (0..t)
            .map(|ti| {
                let lo = (ti * chunk).min(ne);
                let hi = ((ti + 1) * chunk).min(ne);
                s.spawn(move || {
                    let mut counts = vec![0u32; n + 1];
                    for i in lo..hi {
                        let (a, b) = (g.src[i] as usize, g.dst[i] as usize);
                        counts[a + 1] += 1;
                        if a != b {
                            counts[b + 1] += 1;
                        }
                    }
                    for v in 0..n {
                        counts[v + 1] += counts[v];
                    }
                    let local_m = counts[n] as usize;
                    let mut cols = vec![0u32; local_m];
                    let mut vals = vec![0.0f64; local_m];
                    let mut next = counts.clone();
                    for i in lo..hi {
                        let (a, b, w) = (g.src[i] as usize, g.dst[i] as usize, g.w[i]);
                        cols[next[a] as usize] = g.dst[i];
                        vals[next[a] as usize] = w;
                        next[a] += 1;
                        if a != b {
                            cols[next[b] as usize] = g.src[i];
                            vals[next[b] as usize] = w;
                            next[b] += 1;
                        }
                    }
                    LocalSort { indptr: counts, cols, vals }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("prepare_par sort worker panicked"))
            .collect()
    });

    // ---- phase 2 (parallel): vertex-range count-merge + two-level scan
    let indptr = merge_counts_par(&locals, n, t);
    debug_assert_eq!(indptr, merge_counts_serial(&locals, n));
    debug_assert_eq!(indptr[n] as usize, m);

    // ---- phase 3 (parallel): copy each thread's row segments into the
    // global arrays. Row ranges are disjoint contiguous slices, handed out
    // via split_at_mut — no locks. Concatenating thread segments in thread
    // order restores global edge order within each row, and the per-row
    // ordered value sum reproduces the serial degree accumulation exactly.
    let mut cols = vec![0u32; m];
    let mut vals = vec![0.0f64; m];
    let mut deg = vec![0.0f64; n];
    let bounds = nnz_chunks(&indptr, t);
    thread::scope(|s| {
        let mut cols_rest: &mut [u32] = &mut cols;
        let mut vals_rest: &mut [f64] = &mut vals;
        let mut deg_rest: &mut [f64] = &mut deg;
        for w in bounds.windows(2) {
            let (r0, r1) = (w[0], w[1]);
            let len = (indptr[r1] - indptr[r0]) as usize;
            let (c_here, c_next) = std::mem::take(&mut cols_rest).split_at_mut(len);
            let (v_here, v_next) = std::mem::take(&mut vals_rest).split_at_mut(len);
            let (d_here, d_next) = std::mem::take(&mut deg_rest).split_at_mut(r1 - r0);
            cols_rest = c_next;
            vals_rest = v_next;
            deg_rest = d_next;
            if r0 == r1 {
                continue;
            }
            let locals = &locals;
            s.spawn(move || {
                let mut write = 0usize;
                for r in r0..r1 {
                    let row_start = write;
                    for l in locals {
                        let (lo, hi) = (l.indptr[r] as usize, l.indptr[r + 1] as usize);
                        c_here[write..write + (hi - lo)].copy_from_slice(&l.cols[lo..hi]);
                        v_here[write..write + (hi - lo)].copy_from_slice(&l.vals[lo..hi]);
                        write += hi - lo;
                    }
                    d_here[r - r0] = v_here[row_start..write].iter().sum::<f64>();
                }
            });
        }
    });

    PreparedGraph {
        n,
        k: g.k,
        indptr,
        cols,
        vals,
        deg,
        wv: weight_values(&g.labels, g.k),
        labels: g.labels.clone(),
    }
}

/// Row-parallel accumulation over any prepared row-grouped structure —
/// the one parallel work plan shared by the row-parallel engine
/// ([`PreparedGraph::embed_par_into`]) and the sharded engine's hub
/// shards ([`crate::shard::local::embed_shard_par`]).
///
/// Non-hub rows run in nnz-balanced contiguous chunks, one thread per
/// chunk, exactly as before. Rows whose nnz exceeds
/// [`HUB_SEGMENT_NNZ`] are *excised* from the chunks and computed as
/// their fixed-order column segments fanned across all threads (phase
/// B), each segment accumulating into its own zeroed k-vector in
/// `seg_scratch`; the partials then merge into Z serially in segment
/// order (phase C) followed by the shared per-row epilogue. Because the
/// serial kernel computes hub rows as the *same* ordered segment
/// partials ([`super::kernel`]'s `segmented_row`), the result is
/// bitwise-identical to [`accumulate_rows`] for any thread count.
///
/// `out` must hold `(indptr.len() - 1) * k` zeros for the structure's
/// rows; `seg_scratch` is caller-pooled scratch (sized here, zeroed per
/// call) so steady-state embeds allocate nothing once warm.
pub(crate) fn accumulate_rows_par(
    ctx: &AccumCtx<'_>,
    opts: &GeeOptions,
    scale: Option<&[f64]>,
    out: &mut [f64],
    threads: usize,
    seg_scratch: &mut Vec<f64>,
) {
    let rows = ctx.indptr.len() - 1;
    let r0 = ctx.row_base;
    let k = ctx.k;
    debug_assert_eq!(out.len(), rows * k);
    let t = threads.max(1).min(rows.max(1));
    if t <= 1 {
        accumulate_rows(ctx, opts, r0, r0 + rows, scale, out);
        return;
    }
    // local (0-based) indices of rows that must be split
    let hubs: Vec<usize> = (0..rows)
        .filter(|&r| (ctx.indptr[r + 1] - ctx.indptr[r]) as usize > HUB_SEGMENT_NNZ)
        .collect();
    let bounds = nnz_chunks(ctx.indptr, t);

    if hubs.is_empty() {
        thread::scope(|s| {
            let mut rest: &mut [f64] = out;
            for w in bounds.windows(2) {
                let (a, b) = (w[0], w[1]);
                let (chunk, next) = std::mem::take(&mut rest).split_at_mut((b - a) * k);
                rest = next;
                if a == b {
                    continue;
                }
                s.spawn(move || accumulate_rows(ctx, opts, r0 + a, r0 + b, scale, chunk));
            }
        });
        return;
    }

    // ---- phase A (parallel): non-hub rows in nnz-balanced chunks, hub
    // rows skipped (their Z slots stay zero until phase C merges into them)
    thread::scope(|s| {
        let mut rest: &mut [f64] = &mut *out;
        let hubs = &hubs;
        for w in bounds.windows(2) {
            let (a, b) = (w[0], w[1]);
            let (chunk, next) = std::mem::take(&mut rest).split_at_mut((b - a) * k);
            rest = next;
            if a == b {
                continue;
            }
            s.spawn(move || {
                let mut start = a;
                let mut i = hubs.partition_point(|&h| h < a);
                while i < hubs.len() && hubs[i] < b {
                    let h = hubs[i];
                    if h > start {
                        accumulate_rows(
                            ctx,
                            opts,
                            r0 + start,
                            r0 + h,
                            scale,
                            &mut chunk[(start - a) * k..(h - a) * k],
                        );
                    }
                    start = h + 1;
                    i += 1;
                }
                if start < b {
                    accumulate_rows(
                        ctx,
                        opts,
                        r0 + start,
                        r0 + b,
                        scale,
                        &mut chunk[(start - a) * k..(b - a) * k],
                    );
                }
            });
        }
    });

    // ---- phase B (parallel): every hub segment, fanned across all
    // threads regardless of which row it belongs to. seg_offsets[i] is
    // the first global segment index of hub i.
    let mut seg_offsets: Vec<usize> = Vec::with_capacity(hubs.len() + 1);
    seg_offsets.push(0);
    for &h in &hubs {
        let nnz = (ctx.indptr[h + 1] - ctx.indptr[h]) as usize;
        let last = *seg_offsets.last().unwrap();
        seg_offsets.push(last + hub_segments(nnz));
    }
    let total_segs = *seg_offsets.last().unwrap();
    reset_f64(seg_scratch, total_segs * k);
    let sbounds = even_chunks(total_segs, t);
    thread::scope(|s| {
        let mut rest: &mut [f64] = &mut seg_scratch[..];
        let hubs = &hubs;
        let seg_offsets = &seg_offsets;
        for w in sbounds.windows(2) {
            let (s0, s1) = (w[0], w[1]);
            let (here, next) = std::mem::take(&mut rest).split_at_mut((s1 - s0) * k);
            rest = next;
            if s0 == s1 {
                continue;
            }
            s.spawn(move || {
                for gs in s0..s1 {
                    let hi_idx = seg_offsets.partition_point(|&o| o <= gs) - 1;
                    let h = hubs[hi_idx];
                    let lo = ctx.indptr[h] as usize;
                    let hi = ctx.indptr[h + 1] as usize;
                    let nnz = hi - lo;
                    let segs = hub_segments(nnz);
                    let si = gs - seg_offsets[hi_idx];
                    let (e0, e1) = segment_range(nnz, segs, si);
                    accumulate_segment(
                        ctx,
                        r0 + h,
                        lo + e0,
                        lo + e1,
                        scale,
                        &mut here[(gs - s0) * k..(gs - s0 + 1) * k],
                    );
                }
            });
        }
    });

    // ---- phase C (serial): merge each hub's partials in segment order —
    // the exact op sequence the serial segmented path performs — then the
    // shared diag/cor epilogue.
    note_split_rows(hubs.len() as u64);
    for (hi_idx, &h) in hubs.iter().enumerate() {
        let zrow = &mut out[h * k..(h + 1) * k];
        for gs in seg_offsets[hi_idx]..seg_offsets[hi_idx + 1] {
            let part = &seg_scratch[gs * k..(gs + 1) * k];
            for (z, &p) in zrow.iter_mut().zip(part.iter()) {
                *z += p;
            }
        }
        row_epilogue(ctx, opts, r0 + h, scale, zrow);
    }
}

impl PreparedGraph {
    /// Row-parallel embed: identical numerics to [`PreparedGraph::embed`]
    /// (bitwise — each row is one thread's sequential accumulation in the
    /// same order), `threads`-way parallel over row chunks balanced by
    /// nonzero count.
    pub fn embed_par(&self, opts: &GeeOptions, threads: usize) -> Dense {
        let mut ws = EmbedWorkspace::new();
        self.embed_par_into(opts, threads, &mut ws);
        ws.take_z()
    }

    /// Row-parallel embed into `ws.z` — the pooled twin of
    /// [`embed_par`](Self::embed_par); Z and the scale vector borrow from
    /// the workspace.
    pub fn embed_par_into(&self, opts: &GeeOptions, threads: usize, ws: &mut EmbedWorkspace) {
        let (n, k) = (self.n, self.k);
        let t = threads.max(1).min(n.max(1));
        if t <= 1 {
            self.embed_into(opts, ws);
            return;
        }
        let use_scale = opts.laplacian;
        if use_scale {
            let bump = if opts.diagonal { 1.0 } else { 0.0 };
            ws.scale.clear();
            ws.scale
                .extend(self.deg.iter().map(|&d| safe_recip_sqrt(d + bump)));
        }
        ws.reset_z(n, k);
        let EmbedWorkspace { z, scale, seg_partials, .. } = ws;
        let sc_opt: Option<&[f64]> = if use_scale { Some(&scale[..]) } else { None };
        accumulate_rows_par(&self.ctx(), opts, sc_opt, &mut z.data, t, seg_partials);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::embed::Engine;
    use crate::gee::sparse_gee::SparseGee;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            // ~8% unlabeled
            *l = if rng.f64() < 0.08 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        // guaranteed self loops
        g.add_edge(1, 1, 2.5);
        g.add_edge((n - 1) as u32, (n - 1) as u32, 0.7);
        g
    }

    #[test]
    fn prepare_par_bitwise_matches_serial() {
        let g = random_graph(61, 300, 2_000, 4);
        let serial = PreparedGraph::new(&g);
        for t in [1usize, 2, 3, 8] {
            let par = prepare_par(&g, t);
            assert_eq!(par.indptr, serial.indptr, "indptr differs at t={t}");
            assert_eq!(par.cols, serial.cols, "cols differ at t={t}");
            assert_eq!(par.vals, serial.vals, "vals differ at t={t}");
            assert_eq!(par.deg, serial.deg, "deg differs at t={t}");
        }
    }

    #[test]
    fn parallel_count_merge_identical_to_serial() {
        // direct oracle check on synthetic locals with skewed counts
        let mut rng = Rng::new(71);
        let n = 537; // deliberately not a multiple of any thread count
        let locals: Vec<LocalSort> = (0..5)
            .map(|_| {
                let mut counts = vec![0u32; n + 1];
                for v in 0..n {
                    // hub-skew: a few vertices carry most of the mass
                    let c = if rng.f64() < 0.02 { rng.below(200) } else { rng.below(4) };
                    counts[v + 1] = counts[v] + c as u32;
                }
                LocalSort { indptr: counts, cols: vec![], vals: vec![] }
            })
            .collect();
        let serial = merge_counts_serial(&locals, n);
        for t in [1usize, 2, 3, 4, 7, 16, 64] {
            assert_eq!(
                merge_counts_par(&locals, n, t),
                serial,
                "parallel merge differs at t={t}"
            );
        }
    }

    #[test]
    fn embed_par_bitwise_matches_serial_all_combos() {
        let g = random_graph(62, 250, 1_500, 5);
        let prepared = prepare_par(&g, 4);
        for opts in GeeOptions::table_order() {
            let serial = prepared.embed(&opts);
            for t in [1usize, 2, 8] {
                let par = prepared.embed_par(&opts, t);
                assert_eq!(
                    par.data, serial.data,
                    "embed_par not bitwise at {opts:?}, t={t}"
                );
            }
        }
    }

    #[test]
    fn embed_par_into_reuses_workspace_and_matches() {
        let g = random_graph(67, 300, 6_000, 4);
        let prepared = prepare_par(&g, 4);
        let mut ws = EmbedWorkspace::new();
        prepared.embed_par_into(&GeeOptions::ALL, 4, &mut ws); // warm
        let cap = ws.z.data.capacity();
        for opts in GeeOptions::table_order() {
            let expect = prepared.embed(&opts);
            prepared.embed_par_into(&opts, 4, &mut ws);
            assert_eq!(ws.z.data, expect.data, "pooled par embed at {opts:?}");
        }
        assert_eq!(ws.z.data.capacity(), cap, "workspace grew in steady state");
    }

    #[test]
    fn parallel_engine_matches_sparse_engine_selfloops_unlabeled() {
        // equivalence vs the published sparse pipeline across the full
        // option grid, on a graph with self loops and -1 labels
        let g = random_graph(63, 200, 1_200, 3);
        for opts in GeeOptions::table_order() {
            let sparse = Engine::Sparse.embed(&g, &opts).unwrap();
            for t in [1usize, 2, 8] {
                let par = prepare_par(&g, t).embed_par(&opts, t);
                assert!(
                    sparse.max_abs_diff(&par) < 1e-10,
                    "parallel vs sparse mismatch at {opts:?}, t={t}"
                );
            }
        }
    }

    #[test]
    fn parallel_engine_bitwise_matches_fused_engine() {
        // a graph large enough to take the genuinely parallel path in
        // ParallelGee::embed (>= PAR_MIN_EDGES undirected edges)
        let g = random_graph(64, 1_500, 3 * PAR_MIN_EDGES, 4);
        assert!(g.num_edges() >= PAR_MIN_EDGES);
        for opts in GeeOptions::table_order() {
            let fused = SparseGee::fast().embed(&g, &opts);
            let z1 = ParallelGee::new(1).embed(&g, &opts);
            let z2 = ParallelGee::new(2).embed(&g, &opts);
            let z8 = ParallelGee::new(8).embed(&g, &opts);
            assert_eq!(z1.data, fused.data, "t=1 not bitwise at {opts:?}");
            assert_eq!(z2.data, fused.data, "t=2 not bitwise at {opts:?}");
            assert_eq!(z8.data, fused.data, "t=8 not bitwise at {opts:?}");
        }
    }

    #[test]
    fn degenerate_shapes() {
        // empty graph
        let g0 = Graph::new(5, 2);
        let z = prepare_par(&g0, 4).embed_par(&GeeOptions::ALL, 4);
        assert_eq!(z.nrows, 5);
        assert!(z.data.iter().all(|&x| x == 0.0));
        // single vertex with a self loop
        let mut g1 = Graph::new(1, 1);
        g1.labels[0] = 0;
        g1.add_edge(0, 0, 2.0);
        let expect = SparseGee::fast().embed(&g1, &GeeOptions::ALL);
        let got = prepare_par(&g1, 8).embed_par(&GeeOptions::ALL, 8);
        assert_eq!(got.data, expect.data);
        // more threads than rows/edges
        let g2 = random_graph(65, 3, 4, 2);
        let expect = SparseGee::fast().embed(&g2, &GeeOptions::NONE);
        let got = prepare_par(&g2, 64).embed_par(&GeeOptions::NONE, 64);
        assert_eq!(got.data, expect.data);
    }

    #[test]
    fn nnz_chunks_cover_and_balance_on_prepared_graph() {
        let g = random_graph(66, 400, 3_000, 3);
        let p = PreparedGraph::new(&g);
        let bounds = nnz_chunks(&p.indptr, 4);
        assert_eq!(bounds.first(), Some(&0));
        assert_eq!(bounds.last(), Some(&400));
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]));
        // every chunk holds at most ~2x the fair nnz share
        let total = p.indptr[400] as usize;
        for w in bounds.windows(2) {
            let nnz = (p.indptr[w[1]] - p.indptr[w[0]]) as usize;
            assert!(nnz <= total / 2 + total / 4, "chunk nnz {nnz} of {total}");
        }
    }

    #[test]
    fn resolved_threads_auto_and_capped() {
        assert!(ParallelGee::new(0).resolved_threads() >= 1);
        // explicit counts are honored up to the core count, never beyond
        let r = ParallelGee::new(3).resolved_threads();
        assert!((1..=3).contains(&r), "resolved {r}");
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(ParallelGee::new(usize::MAX).resolved_threads() <= avail);
    }
}
