//! Pooled embedding workspaces — the allocation-free hot path.
//!
//! Every engine's `*_into` entry point borrows an [`EmbedWorkspace`]
//! instead of allocating its accumulator (Z), degree/scale vectors,
//! weight vectors, prepared-graph buffers and per-thread partials from
//! scratch. Buffers are recycled with `clear()` + `resize()` so capacity
//! is kept between calls: after one warm-up embed at a given shape, a
//! steady stream of same-shape requests performs **zero heap
//! allocations** (pinned by the counting-allocator test in
//! `rust/tests/alloc_zero.rs`).
//!
//! [`WorkspacePool`] shares warmed workspaces between the coordinator's
//! worker threads: each worker checks one out for its lifetime and the
//! buffers return to the pool on drop, so steady-state serving reuses
//! capacity across the whole service instead of re-warming per thread
//! restart.

use std::sync::{Arc, Mutex};

use crate::sparse::Dense;

/// Reusable buffers for one embedding computation. All fields keep their
/// capacity across calls; engines only ever `clear`/`resize` them.
#[derive(Debug)]
pub struct EmbedWorkspace {
    /// Output embedding of the most recent `*_into` call (N×K).
    pub z: Dense,
    /// Laplacian scale `d^-1/2` (length n when laplacian is on).
    pub(crate) scale: Vec<f64>,
    /// Weighted degrees (length n).
    pub(crate) deg: Vec<f64>,
    /// Per-vertex `1/n_{y_j}` weights (length n).
    pub(crate) wv: Vec<f64>,
    /// Per-class counts scratch (length k).
    pub(crate) nk: Vec<f64>,
    /// Prepared-structure row pointers (length n+1, u32-compacted).
    pub(crate) indptr: Vec<u32>,
    /// Counting-sort write cursors (length n+1).
    pub(crate) next: Vec<u32>,
    /// Prepared-structure column ids (length m directed).
    pub(crate) cols: Vec<u32>,
    /// Prepared-structure edge weights (length m directed).
    pub(crate) vals: Vec<f64>,
    /// Per-thread partial Z buffers for the edge-parallel engine.
    pub(crate) partials: Vec<Vec<f64>>,
    /// Hub-segment partial rows (total_segments × k) for the parallel
    /// hub plan in `gee::parallel::accumulate_rows_par`.
    pub(crate) seg_partials: Vec<f64>,
}

impl EmbedWorkspace {
    /// A fresh workspace holding no capacity. The first embed at a given
    /// shape warms it; subsequent same-shape embeds are allocation-free.
    pub fn new() -> Self {
        EmbedWorkspace {
            z: Dense::zeros(0, 0),
            scale: Vec::new(),
            deg: Vec::new(),
            wv: Vec::new(),
            nk: Vec::new(),
            indptr: Vec::new(),
            next: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
            partials: Vec::new(),
            seg_partials: Vec::new(),
        }
    }

    /// Shape `z` to n×k and zero it, reusing capacity.
    pub(crate) fn reset_z(&mut self, n: usize, k: usize) {
        self.z.nrows = n;
        self.z.ncols = k;
        reset_f64(&mut self.z.data, n * k);
    }

    /// Move the result out, leaving an empty (capacity-free) Z behind.
    /// The scratch buffers stay warm; only the Z allocation is given up —
    /// it becomes the caller's response buffer, which has to be an owned
    /// allocation anyway.
    pub fn take_z(&mut self) -> Dense {
        std::mem::replace(&mut self.z, Dense::zeros(0, 0))
    }

    /// Bytes of capacity currently held across all buffers (observability
    /// for pool sizing).
    pub fn capacity_bytes(&self) -> usize {
        self.z.data.capacity() * 8
            + (self.scale.capacity() + self.deg.capacity() + self.wv.capacity()) * 8
            + (self.nk.capacity() + self.vals.capacity() + self.seg_partials.capacity()) * 8
            + (self.indptr.capacity() + self.next.capacity() + self.cols.capacity()) * 4
            + self.partials.iter().map(|p| p.capacity() * 8).sum::<usize>()
    }
}

impl Default for EmbedWorkspace {
    fn default() -> Self {
        EmbedWorkspace::new()
    }
}

/// Zero-fill `buf` to `len`, reusing capacity (allocates only on growth).
#[inline]
pub(crate) fn reset_f64(buf: &mut Vec<f64>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}

/// Zero-fill `buf` to `len`, reusing capacity (allocates only on growth).
#[inline]
pub(crate) fn reset_u32(buf: &mut Vec<u32>, len: usize) {
    buf.clear();
    buf.resize(len, 0);
}

/// A shared pool of warmed [`EmbedWorkspace`]s. Checkout pops a warmed
/// workspace (or builds a cold one); the guard returns it on drop.
#[derive(Debug, Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<EmbedWorkspace>>,
}

impl WorkspacePool {
    pub fn new() -> Arc<WorkspacePool> {
        Arc::new(WorkspacePool::default())
    }

    /// Borrow a workspace; it returns to the pool when the guard drops.
    pub fn checkout(self: &Arc<Self>) -> PooledWorkspace {
        let ws = self
            .free
            .lock()
            .expect("workspace pool lock poisoned")
            .pop()
            .unwrap_or_default();
        PooledWorkspace { ws: Some(ws), pool: Arc::clone(self) }
    }

    /// Number of idle workspaces currently pooled.
    pub fn idle(&self) -> usize {
        self.free.lock().expect("workspace pool lock poisoned").len()
    }
}

/// RAII guard over a checked-out workspace.
#[derive(Debug)]
pub struct PooledWorkspace {
    ws: Option<EmbedWorkspace>,
    pool: Arc<WorkspacePool>,
}

impl std::ops::Deref for PooledWorkspace {
    type Target = EmbedWorkspace;
    fn deref(&self) -> &EmbedWorkspace {
        self.ws.as_ref().expect("workspace present until drop")
    }
}

impl std::ops::DerefMut for PooledWorkspace {
    fn deref_mut(&mut self) -> &mut EmbedWorkspace {
        self.ws.as_mut().expect("workspace present until drop")
    }
}

impl Drop for PooledWorkspace {
    fn drop(&mut self) {
        if let Some(ws) = self.ws.take() {
            self.pool
                .free
                .lock()
                .expect("workspace pool lock poisoned")
                .push(ws);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_reuses_capacity() {
        let mut ws = EmbedWorkspace::new();
        ws.reset_z(10, 4);
        assert_eq!(ws.z.data.len(), 40);
        assert_eq!((ws.z.nrows, ws.z.ncols), (10, 4));
        let cap = ws.z.data.capacity();
        ws.z.data[0] = 5.0;
        ws.reset_z(10, 4);
        assert_eq!(ws.z.data[0], 0.0, "reset must zero the buffer");
        assert_eq!(ws.z.data.capacity(), cap, "same shape must not realloc");
        // shrinking keeps capacity too
        ws.reset_z(2, 2);
        assert_eq!(ws.z.data.len(), 4);
        assert_eq!(ws.z.data.capacity(), cap);
    }

    #[test]
    fn take_z_leaves_workspace_usable() {
        let mut ws = EmbedWorkspace::new();
        ws.reset_z(3, 2);
        ws.z.data[5] = 1.5;
        let z = ws.take_z();
        assert_eq!((z.nrows, z.ncols), (3, 2));
        assert_eq!(z.data[5], 1.5);
        assert_eq!(ws.z.data.len(), 0);
        ws.reset_z(4, 1);
        assert_eq!(ws.z.data.len(), 4);
    }

    #[test]
    fn pool_roundtrip_keeps_warm_buffers() {
        let pool = WorkspacePool::new();
        assert_eq!(pool.idle(), 0);
        let cap = {
            let mut ws = pool.checkout();
            ws.reset_z(100, 8);
            ws.z.data.capacity()
        };
        assert_eq!(pool.idle(), 1, "drop must return the workspace");
        let ws2 = pool.checkout();
        assert_eq!(pool.idle(), 0);
        assert!(ws2.z.data.capacity() >= cap, "warm capacity must survive");
        drop(ws2);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn pool_grows_under_concurrent_checkout() {
        let pool = WorkspacePool::new();
        let a = pool.checkout();
        let b = pool.checkout();
        drop(a);
        drop(b);
        assert_eq!(pool.idle(), 2);
    }
}
