//! Synergistic graph fusion via encoder embedding (Shen et al. 2023,
//! ref [13] of the paper): multiple graphs over the **same vertex set**
//! (multi-modal networks, multiple edge types) are embedded jointly by
//! concatenating per-graph GEE embeddings — `Z_fused = [Z_1 | … | Z_M]`,
//! an N × (M·K) matrix. Downstream tasks (classification, clustering)
//! then see every modality at once; the reference shows this is
//! synergistic (fused accuracy ≥ best single graph).
//!
//! Each member graph embeds through the pooled fused engine, i.e.
//! through [`super::kernel`]'s runtime-dispatched accumulation lanes —
//! fusion jobs (typically small K per modality) hit the unrolled
//! small-K kernels with no code here knowing about them.

use anyhow::{bail, Result};

use super::options::GeeOptions;
use super::sparse_gee::embed_fused_into;
use super::workspace::EmbedWorkspace;
use crate::graph::Graph;
use crate::sparse::Dense;

/// Fuse M graphs over a shared labeled vertex set.
///
/// All graphs must agree on `n`, `k`, and labels (the label vector of the
/// first graph is authoritative; others must match or be unlabeled-only
/// divergent). Returns N × (M·K).
pub fn gee_fuse(graphs: &[&Graph], opts: &GeeOptions) -> Result<Dense> {
    let mut ws = EmbedWorkspace::new();
    gee_fuse_with(graphs, opts, &mut ws)
}

/// [`gee_fuse`] with the per-graph embedding scratch borrowed from `ws`:
/// each member graph is embedded through the pooled fused engine into the
/// same reused buffers, so fusing M graphs performs one fused-output
/// allocation instead of M+1. Numerics identical to [`gee_fuse`].
pub fn gee_fuse_with(
    graphs: &[&Graph],
    opts: &GeeOptions,
    ws: &mut EmbedWorkspace,
) -> Result<Dense> {
    if graphs.is_empty() {
        bail!("fusion needs at least one graph");
    }
    let n = graphs[0].n;
    let k = graphs[0].k;
    for (i, g) in graphs.iter().enumerate() {
        if g.n != n || g.k != k {
            bail!("graph {i} shape mismatch: ({}, {}) vs ({n}, {k})", g.n, g.k);
        }
        if g.labels != graphs[0].labels {
            bail!("graph {i} labels differ from graph 0 (fusion requires a shared vertex set)");
        }
    }
    let m = graphs.len();
    let mut fused = Dense::zeros(n, m * k);
    for (gi, g) in graphs.iter().enumerate() {
        embed_fused_into(g, opts, ws);
        for r in 0..n {
            fused.row_mut(r)[gi * k..(gi + 1) * k].copy_from_slice(&ws.z.data[r * k..(r + 1) * k]);
        }
    }
    Ok(fused)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::Engine;
    use crate::tasks::knn::loo_1nn_accuracy;
    use crate::util::rng::Rng;

    /// Two noisy views of the same 2-block structure; each view alone is
    /// weak, together they separate.
    fn two_views(seed: u64) -> (Graph, Graph) {
        let n = 120;
        let k = 2;
        let mut rng = Rng::new(seed);
        let mut labels = vec![0i32; n];
        for (i, l) in labels.iter_mut().enumerate() {
            *l = (i % 2) as i32;
        }
        let mut mk = |within_axis: bool| {
            let mut g = Graph::new(n, k);
            g.labels = labels.clone();
            for _ in 0..n * 6 {
                let a = rng.below(n);
                let b = rng.below(n);
                if a == b {
                    continue;
                }
                let same = labels[a] == labels[b];
                // view 1 is informative about same-block pairs, view 2
                // about different-block pairs (complementary signal)
                let p = if same == within_axis { 0.8 } else { 0.2 };
                if rng.f64() < p {
                    g.add_edge(a as u32, b as u32, 1.0);
                }
            }
            g
        };
        (mk(true), mk(false))
    }

    #[test]
    fn fused_shape_is_concatenation() {
        let (g1, g2) = two_views(11);
        let f = gee_fuse(&[&g1, &g2], &GeeOptions::NONE).unwrap();
        assert_eq!(f.nrows, 120);
        assert_eq!(f.ncols, 4);
        // block 0 equals embedding of g1
        let z1 = Engine::SparseFast.embed(&g1, &GeeOptions::NONE).unwrap();
        for r in 0..f.nrows {
            assert_eq!(&f.row(r)[..2], z1.row(r));
        }
    }

    #[test]
    fn fusion_is_synergistic() {
        let (g1, g2) = two_views(12);
        let opts = GeeOptions::new(true, true, false);
        let z1 = Engine::SparseFast.embed(&g1, &opts).unwrap();
        let z2 = Engine::SparseFast.embed(&g2, &opts).unwrap();
        let zf = gee_fuse(&[&g1, &g2], &opts).unwrap();
        let a1 = loo_1nn_accuracy(&z1, &g1.labels);
        let a2 = loo_1nn_accuracy(&z2, &g2.labels);
        let af = loo_1nn_accuracy(&zf, &g1.labels);
        assert!(
            af >= a1.max(a2) - 0.02,
            "fused {af} worse than best single ({a1}, {a2})"
        );
    }

    #[test]
    fn pooled_fusion_bitwise_matches() {
        let (g1, g2) = two_views(14);
        let mut ws = EmbedWorkspace::new();
        for opts in GeeOptions::table_order() {
            let fresh = gee_fuse(&[&g1, &g2], &opts).unwrap();
            let pooled = gee_fuse_with(&[&g1, &g2], &opts, &mut ws).unwrap();
            assert_eq!(pooled.data, fresh.data, "pooled fusion at {opts:?}");
        }
    }

    #[test]
    fn fusion_rides_the_kernel_dispatch() {
        use crate::gee::kernel::{counters_snapshot, KernelId};
        let (g1, g2) = two_views(15);
        let before = counters_snapshot().count(KernelId::K2);
        gee_fuse(&[&g1, &g2], &GeeOptions::ALL).unwrap();
        let after = counters_snapshot().count(KernelId::K2);
        assert!(after > before, "fusion (k=2) must dispatch the k2 lane");
    }

    #[test]
    fn rejects_mismatched_vertex_sets() {
        let (g1, _) = two_views(13);
        let g_small = Graph::new(10, 2);
        assert!(gee_fuse(&[&g1, &g_small], &GeeOptions::NONE).is_err());
        let mut g_other = g1.clone();
        g_other.labels[0] = 1 - g_other.labels[0];
        assert!(gee_fuse(&[&g1, &g_other], &GeeOptions::NONE).is_err());
        assert!(gee_fuse(&[], &GeeOptions::NONE).is_err());
    }
}
