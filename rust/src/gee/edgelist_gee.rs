//! Edge-list GEE — the **original GEE** algorithm (Shen & Priebe 2023)
//! that the paper benchmarks against: one pass over the edge list with a
//! dense N×K accumulator, never materializing the adjacency matrix, but
//! also never storing W / D / Z sparsely.
//!
//! This is the faithful port of the reference Python `GraphEncoder`
//! (linear time, edge-list driven); the paper's contribution
//! ([`super::sparse_gee::SparseGee`]) differs by keeping *every*
//! intermediate in sparse form.

use super::options::GeeOptions;
use super::weights::weight_values_into;
use super::workspace::{reset_f64, EmbedWorkspace};
use crate::graph::Graph;
use crate::sparse::ops::{normalize_rows, safe_recip, safe_recip_sqrt};
use crate::sparse::Dense;

/// Original (edge-list) GEE.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeListGee;

impl EdgeListGee {
    /// Embed the graph: O(E + N·K) time, dense N×K output.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Dense {
        let mut ws = EmbedWorkspace::new();
        self.embed_into(g, opts, &mut ws);
        ws.take_z()
    }

    /// Embed into `ws.z`, borrowing the degree/scale/weight scratch from
    /// `ws` — zero heap allocations once the workspace is warm at this
    /// graph shape. Numerics identical to [`embed`](Self::embed).
    pub fn embed_into(&self, g: &Graph, opts: &GeeOptions, ws: &mut EmbedWorkspace) {
        let n = g.n;
        let k = g.k;
        let EmbedWorkspace { z, scale, deg, wv, nk, .. } = ws;
        // per-vertex 1/n_{y_j}
        weight_values_into(&g.labels, k, nk, wv);
        let use_scale = degree_scale_into(g, opts, deg, scale);
        let sc: Option<&[f64]> = if use_scale { Some(&scale[..]) } else { None };

        // pass 2: accumulate Z over the edge list (both directions)
        z.nrows = n;
        z.ncols = k;
        reset_f64(&mut z.data, n * k);
        for i in 0..g.num_edges() {
            let (a, b, w) = (g.src[i] as usize, g.dst[i] as usize, g.w[i]);
            let (la, lb) = (g.labels[a], g.labels[b]);
            let s = match sc {
                Some(sc) => sc[a] * sc[b],
                None => 1.0,
            };
            if lb >= 0 {
                *z.get_mut(a, lb as usize) += w * s * wv[b];
            }
            if a != b && la >= 0 {
                *z.get_mut(b, la as usize) += w * s * wv[a];
            }
        }

        diag_cor_epilogue(&g.labels, opts, sc, &wv[..], z);
    }

    /// Peak auxiliary memory in bytes (the dense Z + degree vector) —
    /// reported by the space benches.
    pub fn workspace_bytes(&self, g: &Graph) -> usize {
        g.n * g.k * 8 + g.n * 8
    }
}

/// Pass 1 of both edge-list lanes (lap only): weighted degrees (self
/// loops counted once) and the `d^-1/2` scale with the diag bump folded
/// in, written into the workspace buffers. Returns whether the scale is
/// active. Shared by the serial and edge-parallel lanes so their
/// numerics cannot drift.
pub(crate) fn degree_scale_into(
    g: &Graph,
    opts: &GeeOptions,
    deg: &mut Vec<f64>,
    scale: &mut Vec<f64>,
) -> bool {
    if !opts.laplacian {
        return false;
    }
    reset_f64(deg, g.n);
    for i in 0..g.num_edges() {
        let (a, b, w) = (g.src[i] as usize, g.dst[i] as usize, g.w[i]);
        deg[a] += w;
        if a != b {
            deg[b] += w;
        }
    }
    let bump = if opts.diagonal { 1.0 } else { 0.0 };
    scale.clear();
    scale.extend(deg.iter().map(|&d| safe_recip_sqrt(d + bump)));
    true
}

/// Shared epilogue of both edge-list lanes: diagonal augmentation (a
/// weight-1 self loop on every labeled vertex, scaled by `s_v²` under
/// lap) and row correlation.
pub(crate) fn diag_cor_epilogue(
    labels: &[i32],
    opts: &GeeOptions,
    sc: Option<&[f64]>,
    wv: &[f64],
    z: &mut crate::sparse::Dense,
) {
    let k = z.ncols;
    if opts.diagonal {
        for (v, &l) in labels.iter().enumerate() {
            if l >= 0 {
                let s = match sc {
                    Some(sc) => sc[v] * sc[v],
                    None => 1.0,
                };
                z.data[v * k + l as usize] += s * wv[v];
            }
        }
    }
    if opts.correlation {
        normalize_rows(z);
    }
}

/// Safe reciprocal is re-exported through ops; silence unused import when
/// laplacian is off in doctests.
#[allow(dead_code)]
fn _keep(x: f64) -> f64 {
    safe_recip(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::dense_gee::DenseGee;
    use crate::graph::sbm::{generate_sbm, SbmParams};
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = rng.below(k) as i32;
        }
        for _ in 0..m {
            let a = rng.below(n) as u32;
            let b = rng.below(n) as u32;
            g.add_edge(a, b, rng.f64() + 0.1);
        }
        g
    }

    #[test]
    fn matches_dense_gee_all_combos() {
        let g = random_graph(31, 60, 200, 4);
        let dense = DenseGee::default();
        for opts in GeeOptions::table_order() {
            let zd = dense.embed(&g, &opts).unwrap();
            let ze = EdgeListGee.embed(&g, &opts);
            assert!(
                zd.max_abs_diff(&ze) < 1e-10,
                "mismatch at {:?}: {}",
                opts,
                zd.max_abs_diff(&ze)
            );
        }
    }

    #[test]
    fn matches_dense_gee_with_self_loops_and_unlabeled() {
        let mut g = random_graph(32, 40, 120, 3);
        g.add_edge(5, 5, 2.0);
        g.add_edge(7, 7, 1.0);
        g.labels[3] = -1;
        g.labels[11] = -1;
        let dense = DenseGee::default();
        for opts in GeeOptions::table_order() {
            let zd = dense.embed(&g, &opts).unwrap();
            let ze = EdgeListGee.embed(&g, &opts);
            assert!(zd.max_abs_diff(&ze) < 1e-10, "mismatch at {opts:?}");
        }
    }

    #[test]
    fn sbm_communities_separate_in_embedding() {
        // On a well-separated SBM the mean embedding of each class should
        // put the most mass on its own coordinate... with within > between
        // this means diagonal dominance of the class-mean matrix.
        let mut params = SbmParams::paper(600);
        // exaggerate separation for a deterministic test
        for i in 0..3 {
            params.block_probs[i * 3 + i] = 0.30;
        }
        let g = generate_sbm(&params, 77);
        let z = EdgeListGee.embed(&g, &GeeOptions::NONE);
        let mut means = vec![vec![0.0f64; 3]; 3];
        let counts = g.class_counts();
        for v in 0..g.n {
            let l = g.labels[v] as usize;
            for c in 0..3 {
                means[l][c] += z.get(v, c) / counts[l] as f64;
            }
        }
        for l in 0..3 {
            for c in 0..3 {
                if c != l {
                    assert!(
                        means[l][l] > means[l][c],
                        "class {l} mean not diagonal-dominant: {means:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn embed_into_bitwise_matches_embed_and_reuses_buffers() {
        let mut g = random_graph(34, 50, 160, 4);
        g.add_edge(6, 6, 1.2);
        g.labels[2] = -1;
        let mut ws = EmbedWorkspace::new();
        EdgeListGee.embed_into(&g, &GeeOptions::ALL, &mut ws); // warm
        let cap = ws.z.data.capacity();
        for opts in GeeOptions::table_order() {
            let fresh = EdgeListGee.embed(&g, &opts);
            EdgeListGee.embed_into(&g, &opts, &mut ws);
            assert_eq!(ws.z.data, fresh.data, "pooled edge-list at {opts:?}");
        }
        assert_eq!(ws.z.data.capacity(), cap);
    }

    #[test]
    fn workspace_linear_in_nk() {
        let g = random_graph(33, 100, 50, 5);
        assert_eq!(EdgeListGee.workspace_bytes(&g), 100 * 5 * 8 + 100 * 8);
    }
}
