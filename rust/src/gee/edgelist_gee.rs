//! Edge-list GEE — the **original GEE** algorithm (Shen & Priebe 2023)
//! that the paper benchmarks against: one pass over the edge list with a
//! dense N×K accumulator, never materializing the adjacency matrix, but
//! also never storing W / D / Z sparsely.
//!
//! This is the faithful port of the reference Python `GraphEncoder`
//! (linear time, edge-list driven); the paper's contribution
//! ([`super::sparse_gee::SparseGee`]) differs by keeping *every*
//! intermediate in sparse form.

use super::options::GeeOptions;
use super::weights::weight_values;
use crate::graph::Graph;
use crate::sparse::ops::{normalize_rows, safe_recip, safe_recip_sqrt};
use crate::sparse::Dense;

/// Original (edge-list) GEE.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeListGee;

impl EdgeListGee {
    /// Embed the graph: O(E + N·K) time, dense N×K output.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Dense {
        let n = g.n;
        let k = g.k;
        // per-vertex 1/n_{y_j} and class id
        let wv = weight_values(&g.labels, k);

        // pass 1 (lap only): weighted degrees, self loops counted once,
        // +1 for diagonal augmentation
        let scale: Option<Vec<f64>> = if opts.laplacian {
            let mut deg = g.degrees();
            if opts.diagonal {
                for d in deg.iter_mut() {
                    *d += 1.0;
                }
            }
            Some(deg.iter().map(|&d| safe_recip_sqrt(d)).collect())
        } else {
            None
        };

        // pass 2: accumulate Z over the edge list (both directions)
        let mut z = Dense::zeros(n, k);
        for i in 0..g.num_edges() {
            let (a, b, w) = (g.src[i] as usize, g.dst[i] as usize, g.w[i]);
            let (la, lb) = (g.labels[a], g.labels[b]);
            let s = match &scale {
                Some(sc) => sc[a] * sc[b],
                None => 1.0,
            };
            if lb >= 0 {
                *z.get_mut(a, lb as usize) += w * s * wv[b];
            }
            if a != b {
                if la >= 0 {
                    *z.get_mut(b, la as usize) += w * s * wv[a];
                }
            }
        }

        // diagonal augmentation: self loop of weight 1 on every vertex
        if opts.diagonal {
            for v in 0..n {
                let l = g.labels[v];
                if l >= 0 {
                    let s = match &scale {
                        // self loop scaled by 1/d_v (s_v * s_v)
                        Some(sc) => sc[v] * sc[v],
                        None => 1.0,
                    };
                    *z.get_mut(v, l as usize) += s * wv[v];
                }
            }
        }

        if opts.correlation {
            normalize_rows(&mut z);
        }
        z
    }

    /// Peak auxiliary memory in bytes (the dense Z + degree vector) —
    /// reported by the space benches.
    pub fn workspace_bytes(&self, g: &Graph) -> usize {
        g.n * g.k * 8 + g.n * 8
    }
}

/// Safe reciprocal is re-exported through ops; silence unused import when
/// laplacian is off in doctests.
#[allow(dead_code)]
fn _keep(x: f64) -> f64 {
    safe_recip(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::dense_gee::DenseGee;
    use crate::graph::sbm::{generate_sbm, SbmParams};
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = rng.below(k) as i32;
        }
        for _ in 0..m {
            let a = rng.below(n) as u32;
            let b = rng.below(n) as u32;
            g.add_edge(a, b, rng.f64() + 0.1);
        }
        g
    }

    #[test]
    fn matches_dense_gee_all_combos() {
        let g = random_graph(31, 60, 200, 4);
        let dense = DenseGee::default();
        for opts in GeeOptions::table_order() {
            let zd = dense.embed(&g, &opts).unwrap();
            let ze = EdgeListGee.embed(&g, &opts);
            assert!(
                zd.max_abs_diff(&ze) < 1e-10,
                "mismatch at {:?}: {}",
                opts,
                zd.max_abs_diff(&ze)
            );
        }
    }

    #[test]
    fn matches_dense_gee_with_self_loops_and_unlabeled() {
        let mut g = random_graph(32, 40, 120, 3);
        g.add_edge(5, 5, 2.0);
        g.add_edge(7, 7, 1.0);
        g.labels[3] = -1;
        g.labels[11] = -1;
        let dense = DenseGee::default();
        for opts in GeeOptions::table_order() {
            let zd = dense.embed(&g, &opts).unwrap();
            let ze = EdgeListGee.embed(&g, &opts);
            assert!(zd.max_abs_diff(&ze) < 1e-10, "mismatch at {opts:?}");
        }
    }

    #[test]
    fn sbm_communities_separate_in_embedding() {
        // On a well-separated SBM the mean embedding of each class should
        // put the most mass on its own coordinate... with within > between
        // this means diagonal dominance of the class-mean matrix.
        let mut params = SbmParams::paper(600);
        // exaggerate separation for a deterministic test
        for i in 0..3 {
            params.block_probs[i * 3 + i] = 0.30;
        }
        let g = generate_sbm(&params, 77);
        let z = EdgeListGee.embed(&g, &GeeOptions::NONE);
        let mut means = vec![vec![0.0f64; 3]; 3];
        let counts = g.class_counts();
        for v in 0..g.n {
            let l = g.labels[v] as usize;
            for c in 0..3 {
                means[l][c] += z.get(v, c) / counts[l] as f64;
            }
        }
        for l in 0..3 {
            for c in 0..3 {
                if c != l {
                    assert!(
                        means[l][l] > means[l][c],
                        "class {l} mean not diagonal-dominant: {means:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn workspace_linear_in_nk() {
        let g = random_graph(33, 100, 50, 5);
        assert_eq!(EdgeListGee.workspace_bytes(&g), 100 * 5 * 8 + 100 * 8);
    }
}
