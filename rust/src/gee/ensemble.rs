//! Unsupervised GEE via the encoder *ensemble* (Shen, Park & Priebe 2023,
//! ref [11] of the paper): simultaneous vertex embedding and community
//! detection when **no labels are given**.
//!
//! Algorithm (per the reference):
//! 1. draw R random label initializations;
//! 2. for each, alternate GEE-embed → k-means-relabel until the labels
//!    stop changing (or max iters);
//! 3. keep the replicate with the best clustering objective (minimal
//!    normalized k-means inertia).
//!
//! Uses the §Perf [`PreparedGraph`](super::sparse_gee::PreparedGraph)
//! so the per-iteration cost is one accumulation pass — the refinement
//! loop re-embeds under *new labels*, which only needs the label/weight
//! vectors recomputed, not the graph structure.

use super::options::GeeOptions;
use super::sparse_gee::SparseGee;
use crate::graph::Graph;
use crate::sparse::Dense;
use crate::tasks::kmeans::{kmeans, KMeansConfig};
use crate::util::rng::Rng;

/// Ensemble configuration.
#[derive(Clone, Copy, Debug)]
pub struct EnsembleConfig {
    /// Number of random restarts (replicates).
    pub replicates: usize,
    /// Max embed→cluster refinement rounds per replicate.
    pub max_rounds: usize,
    /// Options used for the embedding step (diag+lap recommended).
    pub options: GeeOptions,
    pub seed: u64,
}

impl EnsembleConfig {
    pub fn new(replicates: usize) -> Self {
        EnsembleConfig {
            replicates,
            max_rounds: 20,
            options: GeeOptions::new(true, true, false),
            seed: 0xE25E,
        }
    }
}

/// Result of the unsupervised ensemble.
#[derive(Clone, Debug)]
pub struct EnsembleResult {
    /// Detected community per vertex (0..k).
    pub labels: Vec<i32>,
    /// Final embedding under the detected labels.
    pub z: Dense,
    /// Normalized inertia of the winning replicate (lower = tighter).
    pub objective: f64,
    /// Rounds until convergence, per replicate.
    pub rounds: Vec<usize>,
}

/// Run unsupervised GEE: detect `k` communities with no label input.
pub fn gee_ensemble(g: &Graph, k: usize, cfg: &EnsembleConfig) -> EnsembleResult {
    assert!(k >= 1);
    let mut rng = Rng::new(cfg.seed);
    let mut best: Option<EnsembleResult> = None;
    let mut rounds_log = Vec::with_capacity(cfg.replicates);

    for _ in 0..cfg.replicates {
        // random init
        let mut labels: Vec<i32> = (0..g.n).map(|_| rng.below(k) as i32).collect();
        let mut rounds = 0usize;
        let mut z = Dense::zeros(g.n, k);
        for round in 0..cfg.max_rounds {
            rounds = round + 1;
            // embed under current labels
            let mut gl = g.clone();
            gl.k = k;
            gl.labels = labels.clone();
            z = SparseGee::fast().embed(&gl, &cfg.options);
            // re-cluster in embedding space
            let km = kmeans(
                &z,
                &KMeansConfig { max_iters: 50, seed: rng.next_u64(), ..KMeansConfig::new(k) },
            );
            let new_labels: Vec<i32> = km.assignments.iter().map(|&c| c as i32).collect();
            let changed = new_labels
                .iter()
                .zip(labels.iter())
                .filter(|(a, b)| a != b)
                .count();
            labels = new_labels;
            if changed == 0 {
                break;
            }
        }
        rounds_log.push(rounds);
        // objective: k-means inertia normalized by total variance
        let km = kmeans(&z, &KMeansConfig { max_iters: 50, seed: 1, ..KMeansConfig::new(k) });
        let total_var: f64 = {
            let mut mean = vec![0.0; z.ncols];
            for r in 0..z.nrows {
                for (m, &v) in mean.iter_mut().zip(z.row(r)) {
                    *m += v / z.nrows as f64;
                }
            }
            (0..z.nrows)
                .map(|r| {
                    z.row(r)
                        .iter()
                        .zip(mean.iter())
                        .map(|(v, m)| (v - m) * (v - m))
                        .sum::<f64>()
                })
                .sum()
        };
        let objective = if total_var > 0.0 { km.inertia / total_var } else { km.inertia };
        let candidate = EnsembleResult { labels, z, objective, rounds: vec![] };
        best = match best {
            Some(b) if b.objective <= candidate.objective => Some(b),
            _ => Some(candidate),
        };
    }
    let mut out = best.expect("replicates >= 1");
    out.rounds = rounds_log;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sbm::{generate_sbm, SbmParams};
    use crate::tasks::metrics::adjusted_rand_index;

    fn well_separated_sbm(n: usize, seed: u64) -> Graph {
        let mut p = SbmParams::paper(n);
        for i in 0..3 {
            p.block_probs[i * 3 + i] = 0.35; // strong communities
        }
        generate_sbm(&p, seed)
    }

    #[test]
    fn recovers_sbm_communities_without_labels() {
        let g = well_separated_sbm(400, 5);
        let truth: Vec<usize> = g.labels.iter().map(|&l| l as usize).collect();
        let res = gee_ensemble(&g, 3, &EnsembleConfig::new(4));
        let pred: Vec<usize> = res.labels.iter().map(|&l| l as usize).collect();
        let ari = adjusted_rand_index(&pred, &truth);
        assert!(ari > 0.8, "ensemble ARI {ari}");
        assert_eq!(res.z.nrows, 400);
        assert!(res.objective.is_finite());
    }

    #[test]
    fn deterministic_in_seed() {
        let g = well_separated_sbm(150, 6);
        let a = gee_ensemble(&g, 3, &EnsembleConfig::new(2));
        let b = gee_ensemble(&g, 3, &EnsembleConfig::new(2));
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn rounds_logged_per_replicate() {
        let g = well_separated_sbm(100, 7);
        let cfg = EnsembleConfig { replicates: 3, ..EnsembleConfig::new(3) };
        let res = gee_ensemble(&g, 3, &cfg);
        assert_eq!(res.rounds.len(), 3);
        assert!(res.rounds.iter().all(|&r| (1..=20).contains(&r)));
    }

    #[test]
    fn k_one_trivially_converges() {
        let g = well_separated_sbm(60, 8);
        let res = gee_ensemble(&g, 1, &EnsembleConfig::new(1));
        assert!(res.labels.iter().all(|&l| l == 0));
    }
}
