//! Dense GEE — the adjacency-matrix strawman baseline.
//!
//! Materializes the full N×N dense adjacency and follows Table 1
//! literally: `(A + I)`, `D^-1/2 A D^-1/2`, `Z = A·W`, row-normalize.
//! Quadratic in N for both space and time, so it carries a hard node
//! budget; the benches use it to show the blow-up the paper's Fig. 3
//! left y-axis implies for non-sparse representations.

use anyhow::{bail, Result};

use super::options::GeeOptions;
use super::weights::weight_matrix_dense;
use crate::graph::Graph;
use crate::sparse::ops::{inv_sqrt_vec, normalize_rows};
use crate::sparse::Dense;

/// Largest N the dense baseline will accept by default (an N×N f64 matrix
/// at this size is ~3.2 GB — past what a 16 GB laptop can double-buffer).
pub const DEFAULT_MAX_NODES: usize = 20_000;

/// Dense-adjacency GEE baseline.
#[derive(Clone, Debug)]
pub struct DenseGee {
    pub max_nodes: usize,
}

impl Default for DenseGee {
    fn default() -> Self {
        DenseGee { max_nodes: DEFAULT_MAX_NODES }
    }
}

impl DenseGee {
    /// Embed; errors when the graph exceeds the node budget.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Result<Dense> {
        if g.n > self.max_nodes {
            bail!(
                "dense GEE baseline refuses n={} > max_nodes={} (needs {:.1} GB)",
                g.n,
                self.max_nodes,
                (g.n * g.n * 8) as f64 / 1e9
            );
        }
        let mut a = g.adjacency().to_dense();
        if opts.diagonal {
            a.add_eye();
        }
        if opts.laplacian {
            let s = inv_sqrt_vec(&a.row_sums());
            a.scale_sym(&s);
        }
        let w = weight_matrix_dense(&g.labels, g.k);
        let mut z = a.matmul(&w);
        if opts.correlation {
            normalize_rows(&mut z);
        }
        Ok(z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph() -> Graph {
        // 0-1-2 path, labels [0, 1, 0]
        let mut g = Graph::new(3, 2);
        g.labels = vec![0, 1, 0];
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 1.0);
        g
    }

    #[test]
    fn plain_embedding_by_hand() {
        // W = [[1/2,0],[0,1],[1/2,0]]; A path.
        // Z0 = A0·W = row of vertex 0 = neighbor 1 -> [0, 1]
        // Z1 = neighbors 0,2 -> [1/2+1/2, 0] = [1, 0]
        let g = path_graph();
        let z = DenseGee::default().embed(&g, &GeeOptions::NONE).unwrap();
        assert_eq!(z.row(0), &[0.0, 1.0]);
        assert_eq!(z.row(1), &[1.0, 0.0]);
        assert_eq!(z.row(2), &[0.0, 1.0]);
    }

    #[test]
    fn diagonal_adds_self_weight() {
        let g = path_graph();
        let z = DenseGee::default()
            .embed(&g, &GeeOptions::new(false, true, false))
            .unwrap();
        // vertex 0: neighbor 1 (class 1) + self (class 0, 1/n0 = 1/2)
        assert_eq!(z.row(0), &[0.5, 1.0]);
    }

    #[test]
    fn correlation_unit_rows() {
        let g = path_graph();
        let z = DenseGee::default()
            .embed(&g, &GeeOptions::new(false, false, true))
            .unwrap();
        for r in 0..3 {
            let norm: f64 = z.row(r).iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn laplacian_by_hand() {
        // degrees [1, 2, 1]; scaled edge (0,1): 1/sqrt(1*2)
        let g = path_graph();
        let z = DenseGee::default()
            .embed(&g, &GeeOptions::new(true, false, false))
            .unwrap();
        let s = 1.0 / 2.0f64.sqrt();
        assert!((z.get(0, 1) - s).abs() < 1e-12);
        assert!((z.get(1, 0) - (s * 0.5 + s * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn node_budget_enforced() {
        let g = Graph::new(100, 2);
        let gee = DenseGee { max_nodes: 50 };
        assert!(gee.embed(&g, &GeeOptions::NONE).is_err());
    }
}
