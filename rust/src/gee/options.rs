//! The three GEE options (paper §2): Laplacian normalization, diagonal
//! augmentation, correlation — and the 8-combination grid Tables 3-4
//! sweep.

/// Option flags for a GEE run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct GeeOptions {
    /// Replace A with D^-1/2 A D^-1/2 (Laplacian normalization).
    pub laplacian: bool,
    /// Replace A with A + I (diagonal augmentation).
    pub diagonal: bool,
    /// Row-normalize Z to unit 2-norm (correlation).
    pub correlation: bool,
}

impl GeeOptions {
    pub const NONE: GeeOptions =
        GeeOptions { laplacian: false, diagonal: false, correlation: false };
    pub const ALL: GeeOptions =
        GeeOptions { laplacian: true, diagonal: true, correlation: true };

    pub fn new(laplacian: bool, diagonal: bool, correlation: bool) -> Self {
        GeeOptions { laplacian, diagonal, correlation }
    }

    /// All 8 combinations, in the paper's table order: Lap=T half first
    /// (Table 3), then Lap=F (Table 4); within a half, Diag=T before
    /// Diag=F, Cor=T before Cor=F.
    pub fn table_order() -> Vec<GeeOptions> {
        let mut out = Vec::with_capacity(8);
        for &lap in &[true, false] {
            for &diag in &[true, false] {
                for &cor in &[true, false] {
                    out.push(GeeOptions::new(lap, diag, cor));
                }
            }
        }
        out
    }

    /// Header label as printed in Tables 3-4.
    pub fn label(&self) -> String {
        fn tf(b: bool) -> char {
            if b {
                'T'
            } else {
                'F'
            }
        }
        format!(
            "Lap = {}, Diag = {}, Cor = {}",
            tf(self.laplacian),
            tf(self.diagonal),
            tf(self.correlation)
        )
    }

    /// Compact code matching artifact names: e.g. "l-c", "---", "ldc".
    pub fn code(&self) -> String {
        format!(
            "{}{}{}",
            if self.laplacian { 'l' } else { '-' },
            if self.diagonal { 'd' } else { '-' },
            if self.correlation { 'c' } else { '-' },
        )
    }

    /// Parse a compact code (inverse of [`code`](Self::code)).
    pub fn from_code(code: &str) -> Option<GeeOptions> {
        let b: Vec<char> = code.chars().collect();
        if b.len() != 3 {
            return None;
        }
        let pick = |c: char, on: char| -> Option<bool> {
            if c == on {
                Some(true)
            } else if c == '-' {
                Some(false)
            } else {
                None
            }
        };
        Some(GeeOptions {
            laplacian: pick(b[0], 'l')?,
            diagonal: pick(b[1], 'd')?,
            correlation: pick(b[2], 'c')?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_unique_combos() {
        let combos = GeeOptions::table_order();
        assert_eq!(combos.len(), 8);
        let set: std::collections::HashSet<_> = combos.iter().collect();
        assert_eq!(set.len(), 8);
        // table order: first four have laplacian on
        assert!(combos[..4].iter().all(|o| o.laplacian));
        assert!(combos[4..].iter().all(|o| !o.laplacian));
    }

    #[test]
    fn label_matches_paper_format() {
        let o = GeeOptions::new(true, false, true);
        assert_eq!(o.label(), "Lap = T, Diag = F, Cor = T");
    }

    #[test]
    fn code_roundtrip() {
        for o in GeeOptions::table_order() {
            assert_eq!(GeeOptions::from_code(&o.code()), Some(o));
        }
        assert_eq!(GeeOptions::from_code("xyz"), None);
        assert_eq!(GeeOptions::from_code("ld"), None);
    }
}
