//! Edge-parallel GEE — the parallel lane for the **original** edge-list
//! algorithm (Shen & Priebe 2023), closing the ROADMAP's "edge-list GEE
//! with per-thread Z partials" item.
//!
//! The edge list is split into contiguous chunks of equal edge count
//! (every edge costs the same — two scaled scatter-adds); each thread
//! accumulates its chunk into a private N×K partial of Z (per-thread
//! partials per Edge-Parallel GEE, arXiv:2402.04403 — no atomics, no
//! locks), and the partials are summed in thread order afterwards.
//!
//! Determinism contract (weaker than the row-parallel engine's, by the
//! nature of edge partitioning):
//! * for a **fixed thread count** the output is bitwise-reproducible —
//!   chunk boundaries and the merge order are functions of (E, T) only;
//! * across thread counts (and vs the serial [`EdgeListGee`]) results
//!   agree to floating-point reassociation error (≤1e-12 in the parity
//!   suite): summing a vertex's contributions per-chunk-then-merge
//!   regroups the additions.
//!
//! Memory: T−1 extra N×K partials (borrowed from the workspace and
//! reused across calls). For very large N prefer the row-parallel
//! engine, whose footprint is independent of thread count.
//!
//! Kernel note: this lane scatters in *edge order* into whole-Z
//! partials, so there is no per-row accumulator for
//! [`super::kernel`]'s register lanes to specialize — it deliberately
//! stays off the dispatch layer. The roofline bench uses it as the
//! scatter-bound contrast to the row-grouped kernels; its counter
//! surface is the absence of kernel dispatches for edge-list jobs.

use std::thread;

use super::edgelist_gee::{degree_scale_into, diag_cor_epilogue, EdgeListGee};
use super::options::GeeOptions;
use super::parallel::PAR_MIN_EDGES;
use super::weights::weight_values_into;
use super::workspace::{reset_f64, EmbedWorkspace};
use crate::graph::Graph;
use crate::sparse::partition::even_chunks;
use crate::sparse::Dense;

/// Edge-parallel edge-list GEE engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EdgeListParGee {
    /// Worker thread count; 0 = use `std::thread::available_parallelism`.
    pub threads: usize,
}

impl EdgeListParGee {
    pub fn new(threads: usize) -> Self {
        EdgeListParGee { threads }
    }

    /// The thread count a call will actually use — the shared policy in
    /// [`crate::sparse::partition::resolve_threads`] (0 = auto, explicit
    /// requests capped at available parallelism).
    pub fn resolved_threads(&self) -> usize {
        crate::sparse::partition::resolve_threads(self.threads)
    }

    /// Embed the graph. Falls back to the serial edge-list engine below
    /// [`PAR_MIN_EDGES`] undirected edges.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Dense {
        let mut ws = EmbedWorkspace::new();
        self.embed_into(g, opts, &mut ws);
        ws.take_z()
    }

    /// Embed into `ws.z`; Z, the per-thread partials and all scalar
    /// scratch borrow from the workspace and stay warm across calls.
    pub fn embed_into(&self, g: &Graph, opts: &GeeOptions, ws: &mut EmbedWorkspace) {
        let t = self.resolved_threads();
        let ne = g.num_edges();
        if t <= 1 || ne < PAR_MIN_EDGES {
            EdgeListGee.embed_into(g, opts, ws);
            return;
        }
        let (n, k) = (g.n, g.k);
        let EmbedWorkspace { z, scale, deg, wv, nk, partials, .. } = ws;
        weight_values_into(&g.labels, k, nk, wv);
        // pass 1 is the serial lane's, verbatim (shared helper)
        let use_scale = degree_scale_into(g, opts, deg, scale);
        let sc: Option<&[f64]> = if use_scale { Some(&scale[..]) } else { None };
        let wv_s: &[f64] = &wv[..];
        let labels: &[i32] = &g.labels[..];

        // pass 2 (parallel): thread 0 accumulates straight into Z, the
        // rest into private partials; every buffer is zeroed first
        z.nrows = n;
        z.ncols = k;
        reset_f64(&mut z.data, n * k);
        if partials.len() < t - 1 {
            partials.resize_with(t - 1, Vec::new);
        }
        for p in partials[..t - 1].iter_mut() {
            reset_f64(p, n * k);
        }
        let ebounds = even_chunks(ne, t);
        thread::scope(|s| {
            let mut bufs: Vec<&mut [f64]> = Vec::with_capacity(t);
            bufs.push(&mut z.data[..]);
            for p in partials[..t - 1].iter_mut() {
                bufs.push(&mut p[..]);
            }
            for (w, buf) in ebounds.windows(2).zip(bufs) {
                let (lo, hi) = (w[0], w[1]);
                if lo == hi {
                    continue;
                }
                s.spawn(move || {
                    for i in lo..hi {
                        let (a, b, wgt) = (g.src[i] as usize, g.dst[i] as usize, g.w[i]);
                        let (la, lb) = (labels[a], labels[b]);
                        let s = match sc {
                            Some(sc) => sc[a] * sc[b],
                            None => 1.0,
                        };
                        if lb >= 0 {
                            buf[a * k + lb as usize] += wgt * s * wv_s[b];
                        }
                        if a != b && la >= 0 {
                            buf[b * k + la as usize] += wgt * s * wv_s[a];
                        }
                    }
                });
            }
        });

        // deterministic merge: partials summed in thread order
        for p in partials[..t - 1].iter() {
            for (zi, &pi) in z.data.iter_mut().zip(p.iter()) {
                *zi += pi;
            }
        }

        // diag augmentation + correlation: the serial lane's epilogue
        diag_cor_epilogue(labels, opts, sc, wv_s, z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.08 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(1, 1, 2.5);
        g.add_edge((n - 1) as u32, (n - 1) as u32, 0.7);
        g
    }

    #[test]
    fn matches_serial_edgelist_within_tolerance() {
        // large enough to take the genuinely parallel path
        let g = random_graph(81, 600, 3 * PAR_MIN_EDGES, 4);
        assert!(g.num_edges() >= PAR_MIN_EDGES);
        for opts in GeeOptions::table_order() {
            let serial = EdgeListGee.embed(&g, &opts);
            for t in [2usize, 3, 8] {
                let par = EdgeListParGee::new(t).embed(&g, &opts);
                let d = serial.max_abs_diff(&par);
                assert!(d <= 1e-12, "edge-par vs serial {d} at {opts:?}, t={t}");
            }
        }
    }

    #[test]
    fn bitwise_reproducible_at_fixed_thread_count() {
        let g = random_graph(82, 400, 2 * PAR_MIN_EDGES, 3);
        for opts in [GeeOptions::NONE, GeeOptions::ALL] {
            let a = EdgeListParGee::new(3).embed(&g, &opts);
            let b = EdgeListParGee::new(3).embed(&g, &opts);
            assert_eq!(a.data, b.data, "not reproducible at {opts:?}");
        }
    }

    #[test]
    fn small_graphs_fall_back_to_serial_bitwise() {
        let g = random_graph(83, 40, 100, 3);
        assert!(g.num_edges() < PAR_MIN_EDGES);
        for opts in GeeOptions::table_order() {
            let serial = EdgeListGee.embed(&g, &opts);
            let par = EdgeListParGee::new(8).embed(&g, &opts);
            assert_eq!(par.data, serial.data, "fallback not bitwise at {opts:?}");
        }
    }

    #[test]
    fn workspace_partials_reused_across_calls() {
        let g = random_graph(84, 300, 2 * PAR_MIN_EDGES, 3);
        let engine = EdgeListParGee::new(2);
        if engine.resolved_threads() < 2 {
            return; // single-core runner: nothing to assert about partials
        }
        let mut ws = EmbedWorkspace::new();
        engine.embed_into(&g, &GeeOptions::ALL, &mut ws); // warm
        assert!(!ws.partials.is_empty());
        let caps: Vec<usize> = ws.partials.iter().map(|p| p.capacity()).collect();
        let zcap = ws.z.data.capacity();
        for opts in GeeOptions::table_order() {
            engine.embed_into(&g, &opts, &mut ws);
        }
        assert_eq!(
            ws.partials.iter().map(|p| p.capacity()).collect::<Vec<_>>(),
            caps
        );
        assert_eq!(ws.z.data.capacity(), zcap);
    }
}
