//! GEE core: the paper's method and its baselines.
//!
//! * [`options::GeeOptions`] — lap / diag / cor flags (Tables 3-4 grid)
//! * [`weights`] — W construction in every storage format
//! * [`dense_gee::DenseGee`] — dense-adjacency strawman
//! * [`edgelist_gee::EdgeListGee`] — the original GEE (linear, edge list)
//! * [`edgelist_par::EdgeListParGee`] — edge-parallel edge-list GEE
//!   (per-thread Z partials, deterministic merge)
//! * [`sparse_gee::SparseGee`] — the paper's sparse pipeline (DOK→CSR)
//! * [`kernel`] — runtime-dispatched accumulation lanes (unrolled
//!   K∈{1..8} register kernels, chunked K>8, generic reference) shared
//!   by every sparse-family engine; dispatch/split-row counters
//! * [`parallel::ParallelGee`] — row-parallel sparse GEE (std threads,
//!   bitwise-deterministic for any thread count)
//! * [`workspace::EmbedWorkspace`] — pooled scratch buffers; every engine
//!   has an `*_into` lane that allocates nothing once the workspace is
//!   warm ([`workspace::WorkspacePool`] shares them between workers)
//! * [`embed::Engine`] — unified front-end over all implementations
//! * [`iterate::IterativeJob`] — round-based embed→kmeans→relabel driver
//!   (the `cluster[:iters]` engine and the fleet/service cluster lanes)
//! * [`globals::Globals`] / [`globals::DirtySet`] — incrementally
//!   maintained `n_k`/degree vectors + coalescing dirty-row set shared
//!   by the resident session and streaming lanes

pub mod dense_gee;
pub mod ensemble;
pub mod edgelist_gee;
pub mod edgelist_par;
pub mod embed;
pub mod fusion;
pub mod globals;
pub mod iterate;
pub mod kernel;
pub mod options;
pub mod parallel;
pub mod sparse_gee;
pub mod weights;
pub mod workspace;

pub use edgelist_par::EdgeListParGee;
pub use embed::{Embedding, Engine};
pub use options::GeeOptions;
pub use parallel::ParallelGee;
pub use workspace::{EmbedWorkspace, WorkspacePool};
