//! GEE core: the paper's method and its baselines.
//!
//! * [`options::GeeOptions`] — lap / diag / cor flags (Tables 3-4 grid)
//! * [`weights`] — W construction in every storage format
//! * [`dense_gee::DenseGee`] — dense-adjacency strawman
//! * [`edgelist_gee::EdgeListGee`] — the original GEE (linear, edge list)
//! * [`sparse_gee::SparseGee`] — the paper's sparse pipeline (DOK→CSR)
//! * [`parallel::ParallelGee`] — row-parallel sparse GEE (std threads,
//!   bitwise-deterministic for any thread count)
//! * [`embed::Engine`] — unified front-end over all implementations

pub mod dense_gee;
pub mod ensemble;
pub mod edgelist_gee;
pub mod embed;
pub mod fusion;
pub mod options;
pub mod parallel;
pub mod sparse_gee;
pub mod weights;

pub use embed::{Embedding, Engine};
pub use options::GeeOptions;
pub use parallel::ParallelGee;
