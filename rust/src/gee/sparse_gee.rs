//! Sparse GEE — the paper's contribution: every matrix in the pipeline is
//! held sparse (DOK while constructing, CSR for compute), so zero entries
//! are never stored or touched.
//!
//! Pipeline per Table 1:
//! ```text
//! A_s  = CSR(adjacency from edge list)
//! A_s += I_s                       (diag option, CSR diagonal add)
//! A_s  = D_s^-1/2 A_s D_s^-1/2     (lap option, symmetric scaling)
//! W_s  = DOK(labels) -> CSR        (paper path)  |  direct CSR (fast path)
//! Z_s  = A_s · W_s                 (CSR×CSR Gustavson | CSR×dense)
//! Z'   = rownormalize(Z_s)         (cor option)
//! ```
//!
//! Two engine knobs exist *only* to reproduce the paper's ablations:
//! `construction` (DOK→CSR, as published, vs direct CSR) and `spmm`
//! (CSR×CSR, as published, vs CSR×dense which exploits K ≪ N). Defaults
//! match the published pipeline; the §Perf pass benchmarks the knobs.

use super::kernel::{accumulate_rows, AccumCtx};
use super::options::GeeOptions;
use super::weights::{weight_matrix_csr_direct, weight_matrix_dok, weight_values_into};
use super::workspace::{reset_f64, reset_u32, EmbedWorkspace};
use crate::graph::Graph;
use crate::sparse::index::to_index;
use crate::sparse::ops::{inv_sqrt_vec, normalize_rows, safe_recip_sqrt};
use crate::sparse::{Csr, Dense};

/// How W_s is constructed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Construction {
    /// DOK then convert — the published pipeline.
    DokThenCsr,
    /// Single-pass CSR emission (no hashing, no sort) — §Perf fast path.
    DirectCsr,
}

/// Which SpMM engine computes `A_s · W_s`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpmmEngine {
    /// CSR × CSR (Gustavson) — scipy's `A_s @ W_s`, the published path.
    CsrCsr,
    /// CSR × dense-K — exploits K ≪ N; output rows are dense anyway.
    CsrDense,
    /// §Perf fused path: CSR built straight from the edge arrays with a
    /// single counting sort (no column sort — SpMM never needs it), the
    /// Laplacian scale and diagonal term folded analytically into the
    /// accumulation pass (no `A+I` copy, no `D^-1/2 A D^-1/2` rewrite),
    /// and W collapsed to the per-vertex `1/n_k` vector. Same numerics
    /// (tested); ~40% less work per embed. See EXPERIMENTS.md §Perf.
    Fused,
}

/// The paper's sparse GEE.
#[derive(Clone, Copy, Debug)]
pub struct SparseGee {
    pub construction: Construction,
    pub spmm: SpmmEngine,
}

impl Default for SparseGee {
    /// Published configuration: DOK construction + CSR×CSR product.
    fn default() -> Self {
        SparseGee { construction: Construction::DokThenCsr, spmm: SpmmEngine::CsrCsr }
    }
}

impl SparseGee {
    /// The §Perf-tuned configuration (same numerics, faster construction
    /// and product).
    pub fn fast() -> Self {
        SparseGee { construction: Construction::DirectCsr, spmm: SpmmEngine::Fused }
    }

    /// Build the (optionally augmented/normalized) adjacency in CSR.
    pub fn build_adjacency(&self, g: &Graph, opts: &GeeOptions) -> Csr {
        let mut a = Csr::from_coo(&g.adjacency());
        if opts.diagonal {
            a = a.add_diag(&vec![1.0; g.n]);
        }
        if opts.laplacian {
            let s = inv_sqrt_vec(&a.row_sums());
            a.scale_sym(&s);
        }
        a
    }

    /// Embed the graph. Output is dense N×K: K is the class count, so the
    /// embedding rows are (near-)dense by construction; callers needing
    /// the sparse Z_s can use [`embed_sparse`](Self::embed_sparse).
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Dense {
        if self.spmm == SpmmEngine::Fused {
            return self.embed_fused(g, opts);
        }
        let a = self.build_adjacency(g, opts);
        let mut z = match self.spmm {
            SpmmEngine::CsrCsr => {
                let w = match self.construction {
                    Construction::DokThenCsr => weight_matrix_dok(&g.labels, g.k).to_csr(),
                    Construction::DirectCsr => weight_matrix_csr_direct(&g.labels, g.k),
                };
                a.spmm_csr(&w).to_dense()
            }
            SpmmEngine::CsrDense => {
                let w = match self.construction {
                    Construction::DokThenCsr => {
                        weight_matrix_dok(&g.labels, g.k).to_csr().to_dense()
                    }
                    Construction::DirectCsr => {
                        weight_matrix_csr_direct(&g.labels, g.k).to_dense()
                    }
                };
                a.spmm_dense(&w)
            }
            SpmmEngine::Fused => unreachable!("handled above"),
        };
        if opts.correlation {
            normalize_rows(&mut z);
        }
        z
    }

    /// The §Perf fused pipeline (see [`SpmmEngine::Fused`]).
    ///
    /// One counting sort builds the row-grouped directed edge structure
    /// (a CSR without sorted columns — SpMM is column-order-invariant);
    /// degrees fall out of the same pass; the Laplacian scale, diagonal
    /// self-term and `1/n_k` weights are applied analytically during the
    /// row-major accumulation, so no intermediate matrix is ever copied.
    /// Row-major accumulation is also the cache story: each Z row stays
    /// hot while its neighbors stream, unlike the edge-order scatter of
    /// the edge-list baseline.
    ///
    /// Both passes are exactly [`PreparedGraph::new`] + [`PreparedGraph::
    /// embed`] (which in turn shares its accumulation with the
    /// row-parallel engine) — one implementation, used un-amortized here.
    fn embed_fused(&self, g: &Graph, opts: &GeeOptions) -> Dense {
        let mut ws = EmbedWorkspace::new();
        embed_fused_into(g, opts, &mut ws);
        ws.take_z()
    }

    /// Prepare a graph once for repeated embedding (see [`PreparedGraph`]).
    pub fn prepare(g: &Graph) -> PreparedGraph {
        PreparedGraph::new(g)
    }

    /// Embed keeping Z in CSR (the paper's storage argument: Z_s stays
    /// sparse when classes are missing from a neighborhood). Correlation
    /// is applied by scaling each CSR row.
    pub fn embed_sparse(&self, g: &Graph, opts: &GeeOptions) -> Csr {
        let a = self.build_adjacency(g, opts);
        let w = match self.construction {
            Construction::DokThenCsr => weight_matrix_dok(&g.labels, g.k).to_csr(),
            Construction::DirectCsr => weight_matrix_csr_direct(&g.labels, g.k),
        };
        let mut z = a.spmm_csr(&w);
        if opts.correlation {
            for r in 0..z.nrows {
                let (lo, hi) = (z.indptr[r] as usize, z.indptr[r + 1] as usize);
                let norm: f64 =
                    z.data[lo..hi].iter().map(|x| x * x).sum::<f64>().sqrt();
                if norm > 0.0 {
                    for v in &mut z.data[lo..hi] {
                        *v /= norm;
                    }
                }
            }
        }
        z
    }

    /// Bytes held by the sparse pipeline's intermediates for this graph —
    /// the space half of the paper's claim (compare with the dense
    /// baseline's `n*n*8` and edge-list GEE's dense Z).
    pub fn storage_bytes(&self, g: &Graph, opts: &GeeOptions) -> usize {
        let a = self.build_adjacency(g, opts);
        let w = weight_matrix_csr_direct(&g.labels, g.k);
        let z = a.spmm_csr(&w);
        a.storage_bytes() + w.storage_bytes() + z.storage_bytes()
    }
}

/// A graph pre-processed for repeated embedding — the §Perf amortization
/// for the "many option combos / repeated queries on one graph" workload
/// (exactly what Tables 3-4 measure: 8 combos per dataset, and what the
/// serving layer sees for popular graphs).
///
/// Holds the row-grouped directed edge structure (counting-sorted CSR,
/// columns unsorted), base degrees, and the `1/n_k` weight vector; each
/// [`embed`](Self::embed) is then a single accumulation pass with the
/// options folded analytically — no per-call construction at all.
#[derive(Clone, Debug)]
pub struct PreparedGraph {
    // crate-visible so gee::parallel can build the identical structure
    // with per-thread counting sorts and read it for row-parallel embeds
    pub(crate) n: usize,
    pub(crate) k: usize,
    pub(crate) indptr: Vec<u32>,
    pub(crate) cols: Vec<u32>,
    pub(crate) vals: Vec<f64>,
    pub(crate) deg: Vec<f64>,
    pub(crate) wv: Vec<f64>,
    pub(crate) labels: Vec<i32>,
}

/// Counting-sort the graph's directed edges into row-grouped arrays,
/// writing into caller-provided buffers (capacity-reusing, u32 row
/// pointers). One implementation serves [`PreparedGraph::new`] and the
/// pooled fused path ([`embed_fused_into`]), so the two stay
/// bitwise-identical.
pub(crate) fn prepare_into(
    g: &Graph,
    indptr: &mut Vec<u32>,
    next: &mut Vec<u32>,
    cols: &mut Vec<u32>,
    vals: &mut Vec<f64>,
    deg: &mut Vec<f64>,
) {
    let n = g.n;
    let m = g.num_directed();
    to_index(m, "directed edges");
    reset_u32(indptr, n + 1);
    reset_f64(deg, n);
    for i in 0..g.num_edges() {
        let (a, b, w) = (g.src[i] as usize, g.dst[i] as usize, g.w[i]);
        indptr[a + 1] += 1;
        deg[a] += w;
        if a != b {
            indptr[b + 1] += 1;
            deg[b] += w;
        }
    }
    for i in 0..n {
        indptr[i + 1] += indptr[i];
    }
    reset_u32(cols, m);
    reset_f64(vals, m);
    next.clear();
    next.extend_from_slice(indptr);
    for i in 0..g.num_edges() {
        let (a, b, w) = (g.src[i] as usize, g.dst[i] as usize, g.w[i]);
        cols[next[a] as usize] = g.dst[i];
        vals[next[a] as usize] = w;
        next[a] += 1;
        if a != b {
            cols[next[b] as usize] = g.src[i];
            vals[next[b] as usize] = w;
            next[b] += 1;
        }
    }
}

/// The §Perf fused pipeline with every buffer borrowed from `ws`: one
/// counting sort into the workspace's prepared-structure buffers, then
/// one accumulation pass into `ws.z`. **Zero heap allocations** once the
/// workspace is warm at this graph shape (pinned by the counting-
/// allocator test). Numerically bitwise-identical to
/// `SparseGee::fast().embed`.
pub fn embed_fused_into(g: &Graph, opts: &GeeOptions, ws: &mut EmbedWorkspace) {
    let EmbedWorkspace {
        z,
        scale,
        deg,
        wv,
        nk,
        indptr,
        next,
        cols,
        vals,
        ..
    } = ws;
    prepare_into(g, indptr, next, cols, vals, deg);
    weight_values_into(&g.labels, g.k, nk, wv);
    z.nrows = g.n;
    z.ncols = g.k;
    reset_f64(&mut z.data, g.n * g.k);
    let use_scale = opts.laplacian;
    if use_scale {
        let bump = if opts.diagonal { 1.0 } else { 0.0 };
        scale.clear();
        scale.extend(deg.iter().map(|&d| safe_recip_sqrt(d + bump)));
    }
    let ctx = AccumCtx {
        indptr: &indptr[..],
        row_base: 0,
        cols: &cols[..],
        vals: &vals[..],
        labels: &g.labels[..],
        wv: &wv[..],
        k: g.k,
    };
    accumulate_rows(
        &ctx,
        opts,
        0,
        g.n,
        if use_scale { Some(&scale[..]) } else { None },
        &mut z.data,
    );
}

impl PreparedGraph {
    /// Build the reusable structure: O(N + E), done once.
    pub fn new(g: &Graph) -> PreparedGraph {
        let mut indptr = Vec::new();
        let mut next = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let mut deg = Vec::new();
        prepare_into(g, &mut indptr, &mut next, &mut cols, &mut vals, &mut deg);
        PreparedGraph {
            n: g.n,
            k: g.k,
            indptr,
            cols,
            vals,
            deg,
            wv: super::weights::weight_values(&g.labels, g.k),
            labels: g.labels.clone(),
        }
    }

    /// Embed under any option combo: one pass over the prepared structure.
    /// Delegates to [`embed_into`](Self::embed_into) with a fresh
    /// workspace; repeated-embed callers should hold their own
    /// [`EmbedWorkspace`] and call `embed_into` directly for the
    /// allocation-free path.
    pub fn embed(&self, opts: &GeeOptions) -> Dense {
        let mut ws = EmbedWorkspace::new();
        self.embed_into(opts, &mut ws);
        ws.take_z()
    }

    /// Embed into `ws.z`, borrowing every scratch buffer from `ws`.
    /// **Zero heap allocations** once `ws` is warm at this shape — the
    /// steady-state serving path.
    pub fn embed_into(&self, opts: &GeeOptions, ws: &mut EmbedWorkspace) {
        ws.reset_z(self.n, self.k);
        let use_scale = opts.laplacian;
        if use_scale {
            let bump = if opts.diagonal { 1.0 } else { 0.0 };
            ws.scale.clear();
            ws.scale
                .extend(self.deg.iter().map(|&d| safe_recip_sqrt(d + bump)));
        }
        let EmbedWorkspace { z, scale, .. } = ws;
        self.embed_rows(
            opts,
            0,
            self.n,
            if use_scale { Some(&scale[..]) } else { None },
            &mut z.data,
        );
    }

    /// Accumulate rows `r0..r1` of Z into `out` — thin wrapper over
    /// [`accumulate_rows`] viewing this prepared structure. The
    /// row-parallel engine calls this per chunk.
    pub(crate) fn embed_rows(
        &self,
        opts: &GeeOptions,
        r0: usize,
        r1: usize,
        scale: Option<&[f64]>,
        out: &mut [f64],
    ) {
        accumulate_rows(&self.ctx(), opts, r0, r1, scale, out);
    }

    /// Kernel view of the prepared structure (whole-graph: `row_base` 0).
    pub(crate) fn ctx(&self) -> AccumCtx<'_> {
        AccumCtx {
            indptr: &self.indptr[..],
            row_base: 0,
            cols: &self.cols[..],
            vals: &self.vals[..],
            labels: &self.labels[..],
            wv: &self.wv[..],
            k: self.k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::dense_gee::DenseGee;
    use crate::gee::edgelist_gee::EdgeListGee;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = rng.below(k) as i32;
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g
    }

    #[test]
    fn all_engines_match_dense_baseline() {
        let g = random_graph(41, 50, 180, 5);
        let dense = DenseGee::default();
        let engines = [
            SparseGee::default(),
            SparseGee::fast(),
            SparseGee { construction: Construction::DokThenCsr, spmm: SpmmEngine::CsrDense },
            SparseGee { construction: Construction::DirectCsr, spmm: SpmmEngine::CsrCsr },
        ];
        for opts in GeeOptions::table_order() {
            let zd = dense.embed(&g, &opts).unwrap();
            for engine in &engines {
                let zs = engine.embed(&g, &opts);
                assert!(
                    zd.max_abs_diff(&zs) < 1e-10,
                    "engine {engine:?} mismatch at {opts:?}"
                );
            }
        }
    }

    #[test]
    fn sparse_and_dense_z_agree() {
        let g = random_graph(42, 40, 120, 4);
        for opts in GeeOptions::table_order() {
            let zd = SparseGee::default().embed(&g, &opts);
            let zs = SparseGee::default().embed_sparse(&g, &opts).to_dense();
            assert!(zd.max_abs_diff(&zs) < 1e-10, "mismatch at {opts:?}");
        }
    }

    #[test]
    fn three_implementations_agree_on_self_loops_unlabeled() {
        let mut g = random_graph(43, 35, 100, 3);
        g.add_edge(4, 4, 3.0);
        g.labels[9] = -1;
        for opts in GeeOptions::table_order() {
            let zd = DenseGee::default().embed(&g, &opts).unwrap();
            let ze = EdgeListGee.embed(&g, &opts);
            let zs = SparseGee::default().embed(&g, &opts);
            assert!(zd.max_abs_diff(&ze) < 1e-10);
            assert!(zd.max_abs_diff(&zs) < 1e-10);
        }
    }

    #[test]
    fn storage_beats_dense_for_sparse_graph() {
        let g = random_graph(44, 500, 1000, 4);
        let sparse_bytes = SparseGee::default().storage_bytes(&g, &GeeOptions::NONE);
        let dense_bytes = 500 * 500 * 8;
        assert!(
            sparse_bytes < dense_bytes / 4,
            "sparse {sparse_bytes} not ≪ dense {dense_bytes}"
        );
    }

    #[test]
    fn prepared_graph_matches_all_engines() {
        let mut g = random_graph(46, 45, 150, 4);
        g.add_edge(7, 7, 2.0);
        g.labels[3] = -1;
        let prepared = SparseGee::prepare(&g);
        for opts in GeeOptions::table_order() {
            let expect = DenseGee::default().embed(&g, &opts).unwrap();
            let got = prepared.embed(&opts);
            assert!(
                expect.max_abs_diff(&got) < 1e-10,
                "prepared mismatch at {opts:?}"
            );
        }
    }

    #[test]
    fn pooled_paths_bitwise_match_allocating_paths() {
        let mut g = random_graph(47, 60, 250, 4);
        g.add_edge(9, 9, 1.5);
        g.labels[5] = -1;
        let prepared = SparseGee::prepare(&g);
        let mut ws = EmbedWorkspace::new();
        for opts in GeeOptions::table_order() {
            let fresh = prepared.embed(&opts);
            prepared.embed_into(&opts, &mut ws);
            assert_eq!(ws.z.data, fresh.data, "embed_into drifted at {opts:?}");
            embed_fused_into(&g, &opts, &mut ws);
            assert_eq!(ws.z.data, fresh.data, "fused_into drifted at {opts:?}");
        }
    }

    #[test]
    fn warm_workspace_keeps_capacity_across_embeds() {
        let g = random_graph(48, 80, 400, 3);
        let prepared = SparseGee::prepare(&g);
        let mut ws = EmbedWorkspace::new();
        // warm both pooled paths once
        prepared.embed_into(&GeeOptions::ALL, &mut ws);
        embed_fused_into(&g, &GeeOptions::ALL, &mut ws);
        let caps = (
            ws.z.data.capacity(),
            ws.scale.capacity(),
            ws.cols.capacity(),
            ws.vals.capacity(),
        );
        for opts in GeeOptions::table_order() {
            prepared.embed_into(&opts, &mut ws);
            embed_fused_into(&g, &opts, &mut ws);
        }
        assert_eq!(
            (
                ws.z.data.capacity(),
                ws.scale.capacity(),
                ws.cols.capacity(),
                ws.vals.capacity(),
            ),
            caps,
            "steady-state embeds must not grow any buffer"
        );
    }

    #[test]
    fn embedding_shape() {
        let g = random_graph(45, 30, 60, 7);
        let z = SparseGee::default().embed(&g, &GeeOptions::ALL);
        assert_eq!(z.nrows, 30);
        assert_eq!(z.ncols, 7);
    }
}
