//! Incrementally-maintained global vectors shared by the batch and
//! resident lanes.
//!
//! A GEE row depends on exactly two global vectors: the per-class vertex
//! counts `n_k` (through the weight values `1/n_k[y_c]`) and, under the
//! laplacian option, the per-vertex degrees (through the scale
//! `1/sqrt(deg + bump)`). [`Globals`] owns both and keeps them current
//! under edge and label deltas, so the session / streaming lanes never
//! re-derive them from scratch — and because class counts move by exact
//! whole numbers (±1.0, exact in f64) the maintained `n_k` is *bitwise*
//! what `class_counts_into` would recount, which is what lets incremental
//! refresh stay bit-identical to a from-scratch `sparse-fast` embed.
//!
//! [`DirtySet`] is the companion coalescing structure: an O(1) "mark row
//! dirty" set with a dense membership flag, drained by the refresh pass.

use crate::gee::weights::{class_counts_into, weight_values_from_counts};
use crate::gee::GeeOptions;
use crate::sparse::ops::safe_recip_sqrt;

/// The global `n_k` / degree vectors a GEE row reads besides its own
/// adjacency.
#[derive(Clone, Debug, Default)]
pub struct Globals {
    /// Per-class labeled-vertex counts (exact whole numbers).
    pub n_k: Vec<f64>,
    /// Per-vertex degrees (sum of incident weights; self-loops once).
    pub deg: Vec<f64>,
}

impl Globals {
    /// Zeroed globals for `n` vertices and `k` classes.
    pub fn new(n: usize, k: usize) -> Self {
        Globals { n_k: vec![0.0; k], deg: vec![0.0; n] }
    }

    /// Recount `n_k` from a label vector (the batch-path recount; the
    /// incremental updates below stay bitwise equal to this).
    pub fn recount_labels(&mut self, labels: &[i32], k: usize) {
        class_counts_into(labels, k, &mut self.n_k);
    }

    /// Number of classes.
    pub fn k(&self) -> usize {
        self.n_k.len()
    }

    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.deg.len()
    }

    /// Register one more vertex carrying `label` (-1 = unlabeled).
    pub fn count_label(&mut self, label: i32) {
        if label >= 0 {
            self.n_k[label as usize] += 1.0;
        }
    }

    /// Unregister one vertex carrying `label` (-1 = unlabeled).
    pub fn uncount_label(&mut self, label: i32) {
        if label >= 0 {
            self.n_k[label as usize] -= 1.0;
        }
    }

    /// Move one vertex from class `old` to class `new`.
    pub fn relabel(&mut self, old: i32, new: i32) {
        self.uncount_label(old);
        self.count_label(new);
    }

    /// Grow by one vertex with the given label; returns its id.
    pub fn add_vertex(&mut self, label: i32) -> u32 {
        let v = self.deg.len() as u32;
        self.deg.push(0.0);
        self.count_label(label);
        v
    }

    /// Fill `wv` with per-vertex `1/n_k[y_j]` weights from the maintained
    /// counts — bitwise the batch `weight_values_into` result.
    pub fn weight_values_into(&self, labels: &[i32], wv: &mut Vec<f64>) {
        weight_values_from_counts(labels, &self.n_k, wv);
    }

    /// The laplacian scale value for vertex `v` under `opts` — the same
    /// `safe_recip_sqrt(deg + bump)` the fused batch path computes, so a
    /// point lookup is bitwise the batch vector entry.
    pub fn scale_at(&self, v: usize, opts: &GeeOptions) -> f64 {
        safe_recip_sqrt(self.deg[v] + diag_bump(opts))
    }

    /// Fill `scale` with the full laplacian scale vector under `opts`.
    pub fn scale_into(&self, opts: &GeeOptions, scale: &mut Vec<f64>) {
        let bump = diag_bump(opts);
        scale.clear();
        scale.extend(self.deg.iter().map(|&d| safe_recip_sqrt(d + bump)));
    }
}

/// The +1 the diagonal option adds to every degree before the laplacian
/// scale (the augmented self-loop), 0 otherwise.
pub fn diag_bump(opts: &GeeOptions) -> f64 {
    if opts.diagonal {
        1.0
    } else {
        0.0
    }
}

/// Coalescing dirty-row set: O(1) mark with a dense membership flag, so
/// a row touched by many deltas between refreshes is refreshed once.
#[derive(Clone, Debug, Default)]
pub struct DirtySet {
    flag: Vec<bool>,
    rows: Vec<u32>,
    all: bool,
}

impl DirtySet {
    /// Empty set over `n` rows.
    pub fn new(n: usize) -> Self {
        DirtySet { flag: vec![false; n], rows: Vec::new(), all: false }
    }

    /// Mark row `v` dirty (no-op if already dirty or everything is).
    pub fn mark(&mut self, v: u32) {
        if !self.all && !self.flag[v as usize] {
            self.flag[v as usize] = true;
            self.rows.push(v);
        }
    }

    /// Escalate to "every row is dirty" (relabel storms, shape changes).
    pub fn mark_all(&mut self) {
        self.all = true;
    }

    /// Grow the flag vector to cover `n` rows (vertex growth).
    pub fn grow(&mut self, n: usize) {
        if n > self.flag.len() {
            self.flag.resize(n, false);
        }
    }

    /// Is everything dirty?
    pub fn is_all(&self) -> bool {
        self.all
    }

    /// Number of individually-marked rows (meaningless when `is_all`).
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Nothing to refresh?
    pub fn is_empty(&self) -> bool {
        !self.all && self.rows.is_empty()
    }

    /// The individually-marked rows (unordered, duplicate-free).
    pub fn rows(&self) -> &[u32] {
        &self.rows
    }

    /// Reset to clean after a refresh pass.
    pub fn clear(&mut self) {
        if self.all {
            // flags for individually-marked rows may predate mark_all
            self.flag.iter_mut().for_each(|f| *f = false);
        } else {
            for &r in &self.rows {
                self.flag[r as usize] = false;
            }
        }
        self.rows.clear();
        self.all = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::weights::{class_counts, weight_values};

    #[test]
    fn incremental_counts_match_recount_bitwise() {
        let mut labels = vec![0, 1, 1, 2, -1, 0];
        let mut g = Globals::new(labels.len(), 3);
        g.recount_labels(&labels, 3);
        assert_eq!(g.n_k, class_counts(&labels, 3));

        // churn labels incrementally and compare against a fresh recount
        let moves = [(0usize, 2i32), (4, 1), (1, -1), (3, 0), (2, 2)];
        for &(v, new) in &moves {
            g.relabel(labels[v], new);
            labels[v] = new;
            assert_eq!(g.n_k, class_counts(&labels, 3), "after {v} -> {new}");
            let mut wv = Vec::new();
            g.weight_values_into(&labels, &mut wv);
            let batch = weight_values(&labels, 3);
            assert!(wv.iter().zip(&batch).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn scale_point_lookup_matches_vector() {
        let mut g = Globals::new(4, 2);
        g.deg = vec![0.0, 1.0, 3.5, 100.0];
        for opts in GeeOptions::table_order() {
            let mut s = Vec::new();
            g.scale_into(&opts, &mut s);
            for v in 0..4 {
                assert_eq!(g.scale_at(v, &opts).to_bits(), s[v].to_bits());
            }
        }
    }

    #[test]
    fn dirty_set_coalesces_and_clears() {
        let mut d = DirtySet::new(5);
        d.mark(3);
        d.mark(1);
        d.mark(3);
        assert_eq!(d.len(), 2);
        assert!(!d.is_empty());
        d.clear();
        assert!(d.is_empty());
        d.mark(2);
        d.mark_all();
        assert!(d.is_all());
        d.clear();
        assert!(d.is_empty());
        d.mark(2); // flag from before mark_all must have been reset
        assert_eq!(d.rows(), &[2]);
        d.grow(9);
        d.mark(8);
        assert_eq!(d.len(), 2);
    }
}
