//! Runtime-dispatched accumulation kernels — the roofline layer.
//!
//! GEE's entire compute is one memory-bound inner loop: a K-wide f64
//! multiply-add per directed edge into the vertex's Z row. K is small
//! and fixed per job (it is the class count), so the loop specializes:
//!
//! * **k1..k8** — unrolled small-K lanes. The Z row lives in named f64
//!   locals (registers), so the per-edge read-modify-write never
//!   round-trips through memory. This removes the store-to-load forward
//!   on `zrow[y]` that serializes consecutive same-class edges — on SBM
//!   graphs, most of a row's neighbors share one class, so the generic
//!   loop's critical path is store → load → add per edge while the
//!   register lane pays only the FP add.
//! * **chunked** — for K > 8 the row no longer fits registers; the lane
//!   processes edges four at a time, batching the column/label gathers
//!   so several loads are in flight per iteration (SIMD-friendly: the
//!   compiler may vectorize the gathers; the adds stay scalar and in
//!   edge order).
//! * **generic** — byte-for-byte the historical `accumulate_rows` inner
//!   loop, kept as the reference every other lane must match bitwise
//!   (pinned by `tests/kernel_parity.rs`, which forces it via
//!   [`force_kernel`] and compares).
//!
//! Dispatch happens once per [`accumulate_rows`] call from one
//! [`KernelPlan`], so every caller — serial prepared, row-parallel
//! chunks, fused pooled, and `shard/local.rs` — gets the specialized
//! lanes for free.
//!
//! **Bitwise contract.** Every lane performs the identical sequence of
//! floating-point operations per row: the same products in the same
//! association, added to the same accumulator in edge order. Register
//! accumulation and load batching reorder *loads*, never FP ops, so the
//! engine-identity contract (row-parallel ≡ sharded ≡ fused serial,
//! bitwise) is preserved — now also across kernels.
//!
//! **Hub rows.** A row with more than
//! [`HUB_SEGMENT_NNZ`](crate::sparse::partition::HUB_SEGMENT_NNZ) stored
//! entries is accumulated as fixed-order *segments*: each segment sums
//! into a zeroed k-vector, and the partials merge into the Z row in
//! segment order. The segment grid is a pure function of the row's nnz
//! (never the thread count), and the serial kernel applies it too — so a
//! parallel lane may compute the segments on different threads
//! ([`crate::gee::parallel::accumulate_rows_par`]) and merge in order,
//! bitwise-identical to serial. Per-kernel dispatch and split-row
//! counters ([`counters_snapshot`]) surface which lanes production
//! traffic hits in the serve summary.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::options::GeeOptions;
use crate::sparse::ops::safe_recip;
use crate::sparse::partition::{hub_segments, segment_range};

/// Borrowed view of a prepared row-grouped structure — the accumulation
/// kernels run over it whether the buffers live in a
/// [`PreparedGraph`](super::sparse_gee::PreparedGraph) or an
/// [`EmbedWorkspace`](super::workspace::EmbedWorkspace).
pub(crate) struct AccumCtx<'a> {
    pub indptr: &'a [u32],
    /// Global row id of `indptr[0]`: row `r` reads `indptr[r - row_base]`.
    /// 0 for whole-graph structures; the sharded engine passes its shard's
    /// first vertex so a shard-local indptr serves global row ids (labels,
    /// weights and scale stay globally indexed either way).
    pub row_base: usize,
    pub cols: &'a [u32],
    pub vals: &'a [f64],
    pub labels: &'a [i32],
    pub wv: &'a [f64],
    pub k: usize,
}

/// Identity of one accumulation lane. Ordered so `id as usize` indexes
/// the dispatch-counter array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelId {
    K1,
    K2,
    K3,
    K4,
    K5,
    K6,
    K7,
    K8,
    /// 4-wide load-batched lane for K > 8.
    Chunked,
    /// The historical loop — the bitwise reference.
    Generic,
}

/// Number of [`KernelId`] variants (dispatch-counter array length).
pub const N_KERNELS: usize = 10;

impl KernelId {
    /// The lane the dispatcher picks for a job with `k` classes.
    pub fn for_k(k: usize) -> KernelId {
        match k {
            1 => KernelId::K1,
            2 => KernelId::K2,
            3 => KernelId::K3,
            4 => KernelId::K4,
            5 => KernelId::K5,
            6 => KernelId::K6,
            7 => KernelId::K7,
            8 => KernelId::K8,
            _ => KernelId::Chunked,
        }
    }

    /// Whether this lane can run a job with `k` classes (the fixed lanes
    /// are exact-K; chunked and generic take any K).
    pub fn supports(self, k: usize) -> bool {
        match self {
            KernelId::K1 => k == 1,
            KernelId::K2 => k == 2,
            KernelId::K3 => k == 3,
            KernelId::K4 => k == 4,
            KernelId::K5 => k == 5,
            KernelId::K6 => k == 6,
            KernelId::K7 => k == 7,
            KernelId::K8 => k == 8,
            KernelId::Chunked | KernelId::Generic => true,
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelId::K1 => "k1",
            KernelId::K2 => "k2",
            KernelId::K3 => "k3",
            KernelId::K4 => "k4",
            KernelId::K5 => "k5",
            KernelId::K6 => "k6",
            KernelId::K7 => "k7",
            KernelId::K8 => "k8",
            KernelId::Chunked => "chunked",
            KernelId::Generic => "generic",
        }
    }

    /// All lanes, in counter order.
    pub fn all() -> [KernelId; N_KERNELS] {
        [
            KernelId::K1,
            KernelId::K2,
            KernelId::K3,
            KernelId::K4,
            KernelId::K5,
            KernelId::K6,
            KernelId::K7,
            KernelId::K8,
            KernelId::Chunked,
            KernelId::Generic,
        ]
    }
}

/// The per-job dispatch decision: which lane runs a job with `k`
/// classes, resolved once per `accumulate_rows` call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelPlan {
    pub id: KernelId,
    pub k: usize,
    /// True when a [`force_kernel`] override (parity tests, the roofline
    /// bench) picked the lane instead of the K heuristic.
    pub forced: bool,
}

impl KernelPlan {
    pub fn for_job(k: usize) -> KernelPlan {
        if let Some(id) = forced_kernel() {
            if id.supports(k) {
                return KernelPlan { id, k, forced: true };
            }
        }
        KernelPlan { id: KernelId::for_k(k), k, forced: false }
    }
}

/// Forced-lane override: `usize::MAX` = none, else the lane's index.
static FORCED: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Force every subsequent dispatch onto one lane (`None` restores the K
/// heuristic). Process-global — used by the parity test (compare a lane
/// against the generic reference through identical call paths) and the
/// roofline bench (time generic vs dispatched). A forced lane that does
/// not support a job's K is ignored for that job.
pub fn force_kernel(id: Option<KernelId>) {
    FORCED.store(id.map(KernelId::index).unwrap_or(usize::MAX), Ordering::SeqCst);
}

/// The currently forced lane, if any.
pub fn forced_kernel() -> Option<KernelId> {
    match FORCED.load(Ordering::SeqCst) {
        usize::MAX => None,
        i => Some(KernelId::all()[i]),
    }
}

struct KernelCounters {
    dispatches: [AtomicU64; N_KERNELS],
    split_rows: AtomicU64,
}

static COUNTERS: KernelCounters = KernelCounters {
    dispatches: [
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
    ],
    split_rows: AtomicU64::new(0),
};

/// Point-in-time copy of the process-global kernel counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KernelSnapshot {
    /// `(lane name, dispatch count)` for every lane, in counter order.
    pub dispatches: Vec<(&'static str, u64)>,
    /// Hub rows computed as split segments (serial or parallel).
    pub split_rows: u64,
}

impl KernelSnapshot {
    /// Dispatch count for one lane.
    pub fn count(&self, id: KernelId) -> u64 {
        self.dispatches[id.index()].1
    }

    /// `"k3=12 chunked=4 split_rows=2"` — nonzero entries only; empty
    /// when nothing has dispatched yet. This is the serve-summary line.
    pub fn nonzero_line(&self) -> String {
        let mut parts: Vec<String> = self
            .dispatches
            .iter()
            .filter(|(_, c)| *c > 0)
            .map(|(name, c)| format!("{name}={c}"))
            .collect();
        if self.split_rows > 0 {
            parts.push(format!("split_rows={}", self.split_rows));
        }
        parts.join(" ")
    }
}

/// Snapshot the process-global dispatch / split-row counters.
pub fn counters_snapshot() -> KernelSnapshot {
    KernelSnapshot {
        dispatches: KernelId::all()
            .iter()
            .map(|&id| (id.name(), COUNTERS.dispatches[id.index()].load(Ordering::Relaxed)))
            .collect(),
        split_rows: COUNTERS.split_rows.load(Ordering::Relaxed),
    }
}

/// Zero all counters (bench isolation; tests prefer before/after deltas
/// since the counters are process-global).
pub fn reset_counters() {
    for c in &COUNTERS.dispatches {
        c.store(0, Ordering::Relaxed);
    }
    COUNTERS.split_rows.store(0, Ordering::Relaxed);
}

/// Record `count` hub rows computed as split segments. Crate-internal:
/// the serial segmented path and the parallel hub plan both report here.
pub(crate) fn note_split_rows(count: u64) {
    COUNTERS.split_rows.fetch_add(count, Ordering::Relaxed);
}

/// Estimated bytes one accumulation pass moves for a job of `n` rows,
/// `m` directed edges and `k` classes: per edge one u32 column id, one
/// f64 value, one i32 label gather and one f64 weight gather (plus one
/// f64 scale gather under laplacian); per row a k-wide f64 write of the
/// Z row plus its read-modify cycle (doubled again when correlation
/// re-reads the row to normalize). Compulsory traffic only — the
/// roofline bench divides it by measured ns for a bytes/ns figure
/// comparable against the stream baseline.
pub fn bytes_moved_estimate(n: usize, m: usize, k: usize, opts: &GeeOptions) -> u64 {
    let per_edge: u64 = 4 + 8 + 4 + 8 + if opts.laplacian { 8 } else { 0 };
    let mut per_row: u64 = 2 * 8 * k as u64;
    if opts.correlation {
        per_row += 2 * 8 * k as u64;
    }
    m as u64 * per_edge + n as u64 * per_row
}

/// Accumulate rows `r0..r1` of Z into `out` (their contiguous slice of
/// the output buffer), with the lap/diag/cor options folded analytically.
/// This is the single source of truth for the per-row accumulation: the
/// serial prepared path runs it over `0..n`, the row-parallel engine per
/// chunk, the pooled fused path over workspace buffers, and the sharded
/// engine per shard — so the bitwise-identity contract between them
/// cannot drift. Dispatches once per call to the lane
/// [`KernelPlan::for_job`] picks for `ctx.k`.
pub(crate) fn accumulate_rows(
    ctx: &AccumCtx<'_>,
    opts: &GeeOptions,
    r0: usize,
    r1: usize,
    scale: Option<&[f64]>,
    out: &mut [f64],
) {
    let plan = KernelPlan::for_job(ctx.k);
    COUNTERS.dispatches[plan.id.index()].fetch_add(1, Ordering::Relaxed);
    match plan.id {
        KernelId::K1 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k1),
        KernelId::K2 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k2),
        KernelId::K3 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k3),
        KernelId::K4 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k4),
        KernelId::K5 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k5),
        KernelId::K6 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k6),
        KernelId::K7 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k7),
        KernelId::K8 => rows_loop(ctx, opts, r0, r1, scale, out, seg_k8),
        KernelId::Chunked => rows_loop(ctx, opts, r0, r1, scale, out, seg_chunked),
        KernelId::Generic => rows_loop(ctx, opts, r0, r1, scale, out, seg_generic),
    }
}

/// Accumulate one *segment* of row `r` — the edge contributions of
/// `cols[lo..hi]` — into `out` (a zeroed k-vector), through the same
/// dispatched lane `accumulate_rows` would use. No diag/cor epilogue and
/// no segmentation: this is the parallel hub plan's phase-B primitive;
/// the caller merges partials in segment order and runs
/// [`row_epilogue`] itself.
pub(crate) fn accumulate_segment(
    ctx: &AccumCtx<'_>,
    r: usize,
    lo: usize,
    hi: usize,
    scale: Option<&[f64]>,
    out: &mut [f64],
) {
    match KernelPlan::for_job(ctx.k).id {
        KernelId::K1 => seg_k1(ctx, lo, hi, scale, r, out),
        KernelId::K2 => seg_k2(ctx, lo, hi, scale, r, out),
        KernelId::K3 => seg_k3(ctx, lo, hi, scale, r, out),
        KernelId::K4 => seg_k4(ctx, lo, hi, scale, r, out),
        KernelId::K5 => seg_k5(ctx, lo, hi, scale, r, out),
        KernelId::K6 => seg_k6(ctx, lo, hi, scale, r, out),
        KernelId::K7 => seg_k7(ctx, lo, hi, scale, r, out),
        KernelId::K8 => seg_k8(ctx, lo, hi, scale, r, out),
        KernelId::Chunked => seg_chunked(ctx, lo, hi, scale, r, out),
        KernelId::Generic => seg_generic(ctx, lo, hi, scale, r, out),
    }
}

/// The per-row diag/cor epilogue, shared by the straight path, the
/// serial segmented path, and the parallel hub plan's merge — one
/// implementation so the op order cannot drift between them.
pub(crate) fn row_epilogue(
    ctx: &AccumCtx<'_>,
    opts: &GeeOptions,
    r: usize,
    scale: Option<&[f64]>,
    zrow: &mut [f64],
) {
    if opts.diagonal {
        let y = ctx.labels[r];
        if y >= 0 {
            let s2 = scale.map(|s| s[r] * s[r]).unwrap_or(1.0);
            zrow[y as usize] += s2 * ctx.wv[r];
        }
    }
    if opts.correlation {
        // row-local, same op order as ops::normalize_rows
        let norm: f64 = zrow.iter().map(|x| x * x).sum::<f64>().sqrt();
        let s = safe_recip(norm);
        if s != 0.0 {
            for x in zrow.iter_mut() {
                *x *= s;
            }
        }
    }
}

/// Row loop shared by every lane: straight accumulation for normal rows,
/// fixed-order segmentation for hub rows, then the diag/cor epilogue.
/// Monomorphized per lane (`seg` is a function item), so the inner loop
/// inlines with no per-edge dispatch.
fn rows_loop<F>(
    ctx: &AccumCtx<'_>,
    opts: &GeeOptions,
    r0: usize,
    r1: usize,
    scale: Option<&[f64]>,
    out: &mut [f64],
    seg: F,
) where
    F: Fn(&AccumCtx<'_>, usize, usize, Option<&[f64]>, usize, &mut [f64]),
{
    let k = ctx.k;
    debug_assert_eq!(out.len(), (r1 - r0) * k);
    for r in r0..r1 {
        let lo = ctx.indptr[r - ctx.row_base] as usize;
        let hi = ctx.indptr[r - ctx.row_base + 1] as usize;
        let zrow = &mut out[(r - r0) * k..(r - r0 + 1) * k];
        let segs = hub_segments(hi - lo);
        if segs == 1 {
            seg(ctx, lo, hi, scale, r, zrow);
        } else {
            note_split_rows(1);
            segmented_row(ctx, lo, hi, segs, scale, r, zrow, &seg);
        }
        row_epilogue(ctx, opts, r, scale, zrow);
    }
}

/// Hub-row k-vectors up to this K live on the stack; larger K falls back
/// to a per-row heap temp (hub rows are rare and huge, so the allocation
/// amortizes; the zero-alloc serving contract covers k ≤ 64 regardless).
const SEG_STACK_K: usize = 64;

/// Serial hub row: each fixed-order segment sums into a zeroed k-vector,
/// partials merge into the Z row lane-wise in segment order. Exactly the
/// op sequence the parallel hub plan produces when its threads compute
/// the same segments — bitwise-identical by construction.
#[allow(clippy::too_many_arguments)]
fn segmented_row<F>(
    ctx: &AccumCtx<'_>,
    lo: usize,
    hi: usize,
    segs: usize,
    scale: Option<&[f64]>,
    r: usize,
    zrow: &mut [f64],
    seg: &F,
) where
    F: Fn(&AccumCtx<'_>, usize, usize, Option<&[f64]>, usize, &mut [f64]),
{
    let k = ctx.k;
    let nnz = hi - lo;
    let mut stack = [0.0f64; SEG_STACK_K];
    let mut heap: Vec<f64> = Vec::new();
    let tmp: &mut [f64] = if k <= SEG_STACK_K {
        &mut stack[..k]
    } else {
        heap.resize(k, 0.0);
        &mut heap[..]
    };
    for si in 0..segs {
        let (e0, e1) = segment_range(nnz, segs, si);
        for x in tmp.iter_mut() {
            *x = 0.0;
        }
        seg(ctx, lo + e0, lo + e1, scale, r, &mut tmp[..]);
        for (z, &p) in zrow.iter_mut().zip(tmp.iter()) {
            *z += p;
        }
    }
}

#[cold]
#[inline(never)]
fn bad_label(y: i32, k: usize) -> ! {
    panic!("label {y} out of range for k={k} classes");
}

/// The reference lane — byte-for-byte the historical `accumulate_rows`
/// inner loop. Every other lane must match it bitwise.
fn seg_generic(
    ctx: &AccumCtx<'_>,
    lo: usize,
    hi: usize,
    scale: Option<&[f64]>,
    r: usize,
    zrow: &mut [f64],
) {
    match scale {
        Some(s) => {
            let sr = s[r];
            for (&c, &v) in ctx.cols[lo..hi].iter().zip(&ctx.vals[lo..hi]) {
                let c = c as usize;
                let y = ctx.labels[c];
                if y >= 0 {
                    zrow[y as usize] += v * sr * s[c] * ctx.wv[c];
                }
            }
        }
        None => {
            for (&c, &v) in ctx.cols[lo..hi].iter().zip(&ctx.vals[lo..hi]) {
                let c = c as usize;
                let y = ctx.labels[c];
                if y >= 0 {
                    zrow[y as usize] += v * ctx.wv[c];
                }
            }
        }
    }
}

/// K > 8 lane: edges four at a time, column ids and label gathers
/// batched per group so several loads are in flight; each edge's
/// product and add stay in edge order (same FP sequence as generic).
fn seg_chunked(
    ctx: &AccumCtx<'_>,
    lo: usize,
    hi: usize,
    scale: Option<&[f64]>,
    r: usize,
    zrow: &mut [f64],
) {
    let cols = &ctx.cols[lo..hi];
    let vals = &ctx.vals[lo..hi];
    let labels = ctx.labels;
    let wv = ctx.wv;
    let mut c4 = cols.chunks_exact(4);
    let mut v4 = vals.chunks_exact(4);
    match scale {
        Some(s) => {
            let sr = s[r];
            for (cc, vv) in (&mut c4).zip(&mut v4) {
                let (c0, c1, c2, c3) =
                    (cc[0] as usize, cc[1] as usize, cc[2] as usize, cc[3] as usize);
                let (y0, y1, y2, y3) = (labels[c0], labels[c1], labels[c2], labels[c3]);
                if y0 >= 0 {
                    zrow[y0 as usize] += vv[0] * sr * s[c0] * wv[c0];
                }
                if y1 >= 0 {
                    zrow[y1 as usize] += vv[1] * sr * s[c1] * wv[c1];
                }
                if y2 >= 0 {
                    zrow[y2 as usize] += vv[2] * sr * s[c2] * wv[c2];
                }
                if y3 >= 0 {
                    zrow[y3 as usize] += vv[3] * sr * s[c3] * wv[c3];
                }
            }
            for (&c, &v) in c4.remainder().iter().zip(v4.remainder()) {
                let c = c as usize;
                let y = labels[c];
                if y >= 0 {
                    zrow[y as usize] += v * sr * s[c] * wv[c];
                }
            }
        }
        None => {
            for (cc, vv) in (&mut c4).zip(&mut v4) {
                let (c0, c1, c2, c3) =
                    (cc[0] as usize, cc[1] as usize, cc[2] as usize, cc[3] as usize);
                let (y0, y1, y2, y3) = (labels[c0], labels[c1], labels[c2], labels[c3]);
                if y0 >= 0 {
                    zrow[y0 as usize] += vv[0] * wv[c0];
                }
                if y1 >= 0 {
                    zrow[y1 as usize] += vv[1] * wv[c1];
                }
                if y2 >= 0 {
                    zrow[y2 as usize] += vv[2] * wv[c2];
                }
                if y3 >= 0 {
                    zrow[y3 as usize] += vv[3] * wv[c3];
                }
            }
            for (&c, &v) in c4.remainder().iter().zip(v4.remainder()) {
                let c = c as usize;
                let y = labels[c];
                if y >= 0 {
                    zrow[y as usize] += v * wv[c];
                }
            }
        }
    }
}

/// Generates one unrolled fixed-K lane: the Z row is held in named f64
/// locals for the whole segment, loaded once on entry and stored once on
/// exit, with a K-arm match steering each edge's add. Same products,
/// same association, same add order as `seg_generic` — only the *memory
/// traffic* changes, so the lanes are bitwise-identical.
macro_rules! fixed_kernel {
    ($fname:ident, $K:literal, [$(($acc:ident, $lane:literal)),+]) => {
        fn $fname(
            ctx: &AccumCtx<'_>,
            lo: usize,
            hi: usize,
            scale: Option<&[f64]>,
            r: usize,
            zrow: &mut [f64],
        ) {
            debug_assert_eq!(zrow.len(), $K);
            let cols = &ctx.cols[lo..hi];
            let vals = &ctx.vals[lo..hi];
            let labels = ctx.labels;
            let wv = ctx.wv;
            $(let mut $acc = zrow[$lane];)+
            match scale {
                Some(s) => {
                    let sr = s[r];
                    for (&c, &v) in cols.iter().zip(vals) {
                        let c = c as usize;
                        let y = labels[c];
                        if y >= 0 {
                            let t = v * sr * s[c] * wv[c];
                            match y {
                                $($lane => $acc += t,)+
                                _ => bad_label(y, $K),
                            }
                        }
                    }
                }
                None => {
                    for (&c, &v) in cols.iter().zip(vals) {
                        let c = c as usize;
                        let y = labels[c];
                        if y >= 0 {
                            let t = v * wv[c];
                            match y {
                                $($lane => $acc += t,)+
                                _ => bad_label(y, $K),
                            }
                        }
                    }
                }
            }
            $(zrow[$lane] = $acc;)+
        }
    };
}

fixed_kernel!(seg_k1, 1, [(a0, 0)]);
fixed_kernel!(seg_k2, 2, [(a0, 0), (a1, 1)]);
fixed_kernel!(seg_k3, 3, [(a0, 0), (a1, 1), (a2, 2)]);
fixed_kernel!(seg_k4, 4, [(a0, 0), (a1, 1), (a2, 2), (a3, 3)]);
fixed_kernel!(seg_k5, 5, [(a0, 0), (a1, 1), (a2, 2), (a3, 3), (a4, 4)]);
fixed_kernel!(seg_k6, 6, [(a0, 0), (a1, 1), (a2, 2), (a3, 3), (a4, 4), (a5, 5)]);
fixed_kernel!(
    seg_k7,
    7,
    [(a0, 0), (a1, 1), (a2, 2), (a3, 3), (a4, 4), (a5, 5), (a6, 6)]
);
fixed_kernel!(
    seg_k8,
    8,
    [(a0, 0), (a1, 1), (a2, 2), (a3, 3), (a4, 4), (a5, 5), (a6, 6), (a7, 7)]
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_picks_fixed_lanes_then_chunked() {
        assert_eq!(KernelId::for_k(1), KernelId::K1);
        assert_eq!(KernelId::for_k(8), KernelId::K8);
        assert_eq!(KernelId::for_k(9), KernelId::Chunked);
        assert_eq!(KernelId::for_k(0), KernelId::Chunked);
        assert_eq!(KernelId::for_k(100), KernelId::Chunked);
        for (i, id) in KernelId::all().iter().enumerate() {
            assert_eq!(id.index(), i, "counter order must match enum order");
        }
    }

    #[test]
    fn supports_gates_forced_lanes() {
        assert!(KernelId::K3.supports(3));
        assert!(!KernelId::K3.supports(4));
        assert!(KernelId::Chunked.supports(3));
        assert!(KernelId::Generic.supports(100));
        // an incompatible forced lane is ignored for that job
        force_kernel(Some(KernelId::K2));
        let plan = KernelPlan::for_job(5);
        assert_eq!(plan.id, KernelId::K5);
        assert!(!plan.forced);
        let plan2 = KernelPlan::for_job(2);
        assert_eq!(plan2.id, KernelId::K2);
        assert!(plan2.forced);
        force_kernel(None);
        assert_eq!(forced_kernel(), None);
    }

    #[test]
    fn snapshot_line_formats_nonzero_lanes() {
        let snap = KernelSnapshot {
            dispatches: KernelId::all().iter().map(|&id| (id.name(), 0)).collect(),
            split_rows: 0,
        };
        assert_eq!(snap.nonzero_line(), "");
        let mut snap2 = snap.clone();
        snap2.dispatches[KernelId::K3.index()].1 = 12;
        snap2.dispatches[KernelId::Chunked.index()].1 = 4;
        snap2.split_rows = 2;
        assert_eq!(snap2.nonzero_line(), "k3=12 chunked=4 split_rows=2");
        assert_eq!(snap2.count(KernelId::K3), 12);
    }

    #[test]
    fn bytes_estimate_scales_with_options() {
        let none = bytes_moved_estimate(100, 1000, 4, &GeeOptions::NONE);
        let lap = bytes_moved_estimate(100, 1000, 4, &GeeOptions::new(true, false, false));
        let all = bytes_moved_estimate(100, 1000, 4, &GeeOptions::ALL);
        assert_eq!(none, 1000 * 24 + 100 * 64);
        assert_eq!(lap, none + 1000 * 8);
        assert_eq!(all, lap + 100 * 64);
    }
}
