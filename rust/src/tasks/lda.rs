//! Linear Discriminant Analysis classifier (diagonal-covariance variant)
//! — the second classifier the original GEE paper pairs with the
//! embedding. Gaussian class-conditional model with shared diagonal
//! covariance: robust, closed-form, and O(N·K·D).

use crate::sparse::Dense;

/// Fitted LDA model.
#[derive(Clone, Debug)]
pub struct Lda {
    /// Class means, K×D.
    pub means: Dense,
    /// Shared diagonal variance, length D.
    pub var: Vec<f64>,
    /// Log class priors, length K.
    pub log_priors: Vec<f64>,
    pub k: usize,
}

impl Lda {
    /// Fit on labeled rows (label < 0 rows are ignored).
    pub fn fit(x: &Dense, labels: &[i32], k: usize) -> Lda {
        assert_eq!(x.nrows, labels.len());
        let d = x.ncols;
        let mut counts = vec![0usize; k];
        let mut means = Dense::zeros(k, d);
        for (i, &l) in labels.iter().enumerate() {
            if l < 0 {
                continue;
            }
            counts[l as usize] += 1;
            for (m, &v) in means.row_mut(l as usize).iter_mut().zip(x.row(i)) {
                *m += v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for m in means.row_mut(c) {
                    *m /= counts[c] as f64;
                }
            }
        }
        // pooled diagonal variance
        let mut var = vec![0.0f64; d];
        let mut total = 0usize;
        for (i, &l) in labels.iter().enumerate() {
            if l < 0 {
                continue;
            }
            total += 1;
            for (j, (&v, &m)) in x.row(i).iter().zip(means.row(l as usize)).enumerate() {
                var[j] += (v - m) * (v - m);
            }
        }
        let denom = total.saturating_sub(k).max(1) as f64;
        for v in var.iter_mut() {
            *v = (*v / denom).max(1e-12); // regularize
        }
        let total_f = total.max(1) as f64;
        let log_priors = counts
            .iter()
            .map(|&c| ((c.max(1)) as f64 / total_f).ln())
            .collect();
        Lda { means, var, log_priors, k }
    }

    /// Per-class discriminant scores for one row.
    pub fn scores(&self, row: &[f64]) -> Vec<f64> {
        (0..self.k)
            .map(|c| {
                let mut s = self.log_priors[c];
                for (j, (&v, &m)) in row.iter().zip(self.means.row(c)).enumerate() {
                    s -= (v - m) * (v - m) / (2.0 * self.var[j]);
                }
                s
            })
            .collect()
    }

    /// Predict the class of each row.
    pub fn predict(&self, x: &Dense) -> Vec<i32> {
        (0..x.nrows)
            .map(|i| {
                let s = self.scores(x.row(i));
                s.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(c, _)| c as i32)
                    .unwrap_or(-1)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_separated_gaussians() {
        let mut rng = Rng::new(61);
        let n_per = 100;
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for c in 0..3 {
            let cx = c as f64 * 5.0;
            for _ in 0..n_per {
                data.push(cx + 0.3 * rng.normal());
                data.push(-cx + 0.3 * rng.normal());
                labels.push(c as i32);
            }
        }
        let x = Dense::from_vec(3 * n_per, 2, data);
        let lda = Lda::fit(&x, &labels, 3);
        let pred = lda.predict(&x);
        let correct = pred
            .iter()
            .zip(labels.iter())
            .filter(|(p, l)| p == l)
            .count();
        assert!(correct as f64 / labels.len() as f64 > 0.99);
    }

    #[test]
    fn ignores_unlabeled_rows() {
        let x = Dense::from_vec(4, 1, vec![0.0, 0.2, 10.0, 500.0]);
        let labels = vec![0, 0, 1, -1];
        let lda = Lda::fit(&x, &labels, 2);
        // the outlier 500.0 must not have influenced class means
        assert!(lda.means.get(0, 0) < 1.0);
        assert!((lda.means.get(1, 0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn priors_reflect_imbalance() {
        let x = Dense::from_vec(4, 1, vec![0.0, 0.1, 0.2, 10.0]);
        let labels = vec![0, 0, 0, 1];
        let lda = Lda::fit(&x, &labels, 2);
        assert!(lda.log_priors[0] > lda.log_priors[1]);
    }

    #[test]
    fn empty_class_does_not_panic() {
        let x = Dense::from_vec(2, 1, vec![0.0, 1.0]);
        let labels = vec![0, 0];
        let lda = Lda::fit(&x, &labels, 3);
        let pred = lda.predict(&x);
        assert_eq!(pred.len(), 2);
    }
}
