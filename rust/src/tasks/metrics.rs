//! Clustering/classification quality metrics: accuracy, Adjusted Rand
//! Index, Normalized Mutual Information — used to validate that every
//! engine's embedding supports the downstream tasks equally well.

use std::collections::HashMap;

/// Fraction of agreeing positions (ignores pairs where truth < 0).
pub fn accuracy(pred: &[i32], truth: &[i32]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut n = 0usize;
    let mut ok = 0usize;
    for (&p, &t) in pred.iter().zip(truth.iter()) {
        if t < 0 {
            continue;
        }
        n += 1;
        if p == t {
            ok += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        ok as f64 / n as f64
    }
}

/// Contingency table between two labelings (ignoring truth < 0 pairs).
fn contingency(a: &[usize], b: &[usize]) -> (HashMap<(usize, usize), f64>, HashMap<usize, f64>, HashMap<usize, f64>, f64) {
    let mut joint: HashMap<(usize, usize), f64> = HashMap::new();
    let mut ma: HashMap<usize, f64> = HashMap::new();
    let mut mb: HashMap<usize, f64> = HashMap::new();
    for (&x, &y) in a.iter().zip(b.iter()) {
        *joint.entry((x, y)).or_insert(0.0) += 1.0;
        *ma.entry(x).or_insert(0.0) += 1.0;
        *mb.entry(y).or_insert(0.0) += 1.0;
    }
    let n = a.len() as f64;
    (joint, ma, mb, n)
}

fn choose2(x: f64) -> f64 {
    x * (x - 1.0) / 2.0
}

/// Adjusted Rand Index between two clusterings (label values arbitrary).
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let (joint, ma, mb, n) = contingency(a, b);
    let sum_ij: f64 = joint.values().map(|&c| choose2(c)).sum();
    let sum_a: f64 = ma.values().map(|&c| choose2(c)).sum();
    let sum_b: f64 = mb.values().map(|&c| choose2(c)).sum();
    let expected = sum_a * sum_b / choose2(n).max(1.0);
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return if (sum_ij - expected).abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Normalized Mutual Information (arithmetic normalization).
pub fn nmi(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let (joint, ma, mb, n) = contingency(a, b);
    let mut mi = 0.0;
    for (&(x, y), &c) in &joint {
        let pxy = c / n;
        let px = ma[&x] / n;
        let py = mb[&y] / n;
        if pxy > 0.0 {
            mi += pxy * (pxy / (px * py)).ln();
        }
    }
    let ha: f64 = -ma.values().map(|&c| (c / n) * (c / n).ln()).sum::<f64>();
    let hb: f64 = -mb.values().map(|&c| (c / n) * (c / n).ln()).sum::<f64>();
    let denom = 0.5 * (ha + hb);
    if denom <= 0.0 {
        // both partitions trivial (single cluster): identical -> 1
        return 1.0;
    }
    (mi / denom).clamp(0.0, 1.0)
}

/// Convert i32 labels (with possible -1) into usize labels, filtering
/// pairs where either side is negative. Returns (a, b) filtered together.
pub fn paired_labels(a: &[i32], b: &[i32]) -> (Vec<usize>, Vec<usize>) {
    let mut xa = Vec::new();
    let mut xb = Vec::new();
    for (&x, &y) in a.iter().zip(b.iter()) {
        if x >= 0 && y >= 0 {
            xa.push(x as usize);
            xb.push(y as usize);
        }
    }
    (xa, xb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[0, 1, 1], &[0, 1, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[0, 9], &[-1, -1]), 0.0);
    }

    #[test]
    fn ari_identical_is_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_permutation_invariant() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![2, 2, 0, 0, 1, 1]; // same partition, relabeled
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_random_near_zero() {
        // checkerboard against halves: ARI should be low/negative-ish
        let a: Vec<usize> = (0..40).map(|i| i % 2).collect();
        let b: Vec<usize> = (0..40).map(|i| i / 20).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.2, "ari {ari}");
    }

    #[test]
    fn nmi_bounds_and_identity() {
        let a = vec![0, 0, 1, 1];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        let b = vec![0, 1, 0, 1];
        let v = nmi(&a, &b);
        assert!((0.0..=1.0).contains(&v));
        assert!(v < 0.1);
    }

    #[test]
    fn paired_filters_negatives() {
        let (a, b) = paired_labels(&[0, -1, 2], &[1, 1, -1]);
        assert_eq!(a, vec![0]);
        assert_eq!(b, vec![1]);
    }

    #[test]
    fn ari_symmetric_under_argument_swap() {
        let a: Vec<usize> = (0..30).map(|i| i % 3).collect();
        let b: Vec<usize> = (0..30).map(|i| i / 10).collect();
        let ab = adjusted_rand_index(&a, &b);
        let ba = adjusted_rand_index(&b, &a);
        assert!((ab - ba).abs() < 1e-12, "{ab} vs {ba}");
    }

    #[test]
    fn ari_compares_i32_labels_against_usize_assignments() {
        // the cluster loop's exact shape: planted i32 labels (with a -1
        // unknown) vs k-means usize assignments cast to i32, joined
        // through paired_labels
        let truth: Vec<i32> = vec![0, 0, 0, -1, 1, 1, 1, 2, 2, 2];
        let assignments: Vec<usize> = vec![2, 2, 2, 0, 0, 0, 0, 1, 1, 1];
        let pred: Vec<i32> = assignments.iter().map(|&c| c as i32).collect();
        let (a, b) = paired_labels(&truth, &pred);
        assert_eq!(a.len(), 9, "the -1 pair must be dropped");
        // perfect partition match up to label names -> ARI exactly 1
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
        // flipping one prediction must strictly lower it
        let mut worse = b.clone();
        worse[0] = 1;
        assert!(adjusted_rand_index(&a, &worse) < 1.0);
    }

    #[test]
    fn ari_degenerate_single_cluster_both_sides() {
        // one cluster on both sides: max_index == expected, identical
        // partitions -> 1 by convention
        let a = vec![0usize; 8];
        let b = vec![3usize; 8];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_degenerate_all_singletons_both_sides() {
        // every point its own cluster on both sides: again a degenerate
        // agreement (sum_ij == expected == 0) -> 1
        let a: Vec<usize> = (0..8).collect();
        let b: Vec<usize> = (0..8).rev().collect();
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_single_cluster_vs_all_singletons_is_zero() {
        // maximally uninformative pair: one side lumps, the other
        // splits; the adjusted index's degenerate branch returns 0
        let a = vec![0usize; 8];
        let b: Vec<usize> = (0..8).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 1e-12, "ari {ari}");
        assert!(adjusted_rand_index(&b, &a).abs() < 1e-12);
    }

    #[test]
    fn ari_empty_input_is_zero() {
        assert_eq!(adjusted_rand_index(&[], &[]), 0.0);
    }
}
