//! Lloyd's k-means with k-means++ initialization — vertex clustering on
//! GEE embeddings (the paper's cited downstream task; GEE+k-means is the
//! community-detection recipe of Shen et al.).
//!
//! Two entry points: [`kmeans`] allocates its result, [`kmeans_into`]
//! reuses a caller-held [`KMeansScratch`] so the iterative cluster loop
//! (`gee::iterate`) performs no per-round allocation once the scratch is
//! warm — the same contract the embed engines give via `EmbedWorkspace`.
//!
//! Determinism contract (the cluster lane's fleet parity rests on it):
//! * assignment ties break to the **lowest centroid index** (strict `<`
//!   scan in index order), so equidistant points land identically on
//!   every run;
//! * the assignment step may fan rows across threads
//!   ([`KMeansConfig::threads`]) — each row's scan is independent, so
//!   assignments, centroids, and inertia are **bitwise-identical at any
//!   thread count** (inertia is re-summed serially from the per-point
//!   distances, never from per-thread partials);
//! * an emptied centroid is re-seeded from the farthest point under the
//!   *pre-update* assignment distances, first-maximum wins, and the
//!   chosen point is poisoned so a second empty centroid in the same
//!   iteration picks a different point.

use crate::sparse::Dense;
use crate::sparse::partition::{even_chunks, resolve_threads};
use crate::util::rng::Rng;

/// k-means configuration.
#[derive(Clone, Copy, Debug)]
pub struct KMeansConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Relative change of total inertia that counts as converged.
    pub tol: f64,
    pub seed: u64,
    /// Worker threads for the assignment step (0 = all cores). Results
    /// are bitwise-identical at any thread count; this only buys speed.
    pub threads: usize,
}

impl KMeansConfig {
    pub fn new(k: usize) -> Self {
        KMeansConfig { k, max_iters: 100, tol: 1e-6, seed: 0xC1_0551, threads: 1 }
    }
}

/// k-means result.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub assignments: Vec<usize>,
    pub centroids: Dense,
    pub inertia: f64,
    pub iterations: usize,
}

/// Reusable buffers for [`kmeans_into`]: every field keeps its capacity
/// across calls, so a loop clustering same-shape embeddings settles into
/// zero steady-state allocation.
#[derive(Debug)]
pub struct KMeansScratch {
    /// Cluster id per row of the most recent `kmeans_into` call.
    pub assignments: Vec<usize>,
    /// Centroids (k × d) of the most recent call.
    pub centroids: Dense,
    /// Per-point squared distance to its assigned centroid.
    dist2: Vec<f64>,
    counts: Vec<usize>,
    sums: Dense,
}

impl KMeansScratch {
    pub fn new() -> KMeansScratch {
        KMeansScratch {
            assignments: Vec::new(),
            centroids: Dense::zeros(0, 0),
            dist2: Vec::new(),
            counts: Vec::new(),
            sums: Dense::zeros(0, 0),
        }
    }
}

impl Default for KMeansScratch {
    fn default() -> KMeansScratch {
        KMeansScratch::new()
    }
}

/// Shape a Dense to `r × c` and zero it, reusing capacity.
fn reset_dense(d: &mut Dense, r: usize, c: usize) {
    d.nrows = r;
    d.ncols = c;
    d.data.clear();
    d.data.resize(r * c, 0.0);
}

#[inline]
fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Nearest-centroid scan for rows `[i0, i0 + len)`, writing into the
/// caller's disjoint `assignments`/`dist2` windows. Strict `<` keeps the
/// lowest-index centroid on ties; each row is independent, which is the
/// whole bitwise-at-any-thread-count argument.
fn assign_rows(
    x: &Dense,
    centroids: &Dense,
    k: usize,
    i0: usize,
    assignments: &mut [usize],
    dist2: &mut [f64],
) {
    for (j, (a, d2)) in assignments.iter_mut().zip(dist2.iter_mut()).enumerate() {
        let row = x.row(i0 + j);
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for c in 0..k {
            let d = sq_dist(row, centroids.row(c));
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        *a = best;
        *d2 = best_d;
    }
}

/// The assignment step, fanned over near-equal row chunks when the
/// config asks for threads and the input is big enough to pay for the
/// spawns. Serial and parallel paths produce identical bytes.
fn assign_step(
    x: &Dense,
    centroids: &Dense,
    k: usize,
    cfg: &KMeansConfig,
    assignments: &mut [usize],
    dist2: &mut [f64],
) {
    let n = x.nrows;
    let threads = resolve_threads(cfg.threads).min(n.max(1));
    if threads <= 1 || n < 2 * PAR_MIN_ROWS {
        assign_rows(x, centroids, k, 0, assignments, dist2);
        return;
    }
    let bounds = even_chunks(n, threads);
    std::thread::scope(|sc| {
        let mut arest: &mut [usize] = assignments;
        let mut drest: &mut [f64] = dist2;
        for w in bounds.windows(2) {
            let (i0, i1) = (w[0], w[1]);
            let (a, ar) = arest.split_at_mut(i1 - i0);
            let (d, dr) = drest.split_at_mut(i1 - i0);
            arest = ar;
            drest = dr;
            sc.spawn(move || assign_rows(x, centroids, k, i0, a, d));
        }
    });
}

/// Rows below which the assignment step stays serial regardless of the
/// thread budget — thread spawns cost more than the scan they'd split.
const PAR_MIN_ROWS: usize = 1 << 10;

/// Lloyd iterations over pre-seeded `s.centroids`. Returns
/// `(inertia, iterations)`; assignments/centroids are left in `s`.
fn lloyd(x: &Dense, cfg: &KMeansConfig, k: usize, s: &mut KMeansScratch) -> (f64, usize) {
    let n = x.nrows;
    let d = x.ncols;
    s.assignments.clear();
    s.assignments.resize(n, 0);
    s.dist2.clear();
    s.dist2.resize(n, 0.0);
    s.counts.clear();
    s.counts.resize(k, 0);
    reset_dense(&mut s.sums, k, d);

    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..cfg.max_iters {
        iterations = it + 1;
        // assign (possibly parallel; bitwise-stable either way), then sum
        // inertia serially from the per-point distances so the total is a
        // pure function of the assignment, not of the chunking
        assign_step(x, &s.centroids, k, cfg, &mut s.assignments, &mut s.dist2);
        let new_inertia: f64 = s.dist2.iter().sum();
        // update
        s.counts.fill(0);
        s.sums.data.fill(0.0);
        for i in 0..n {
            let c = s.assignments[i];
            s.counts[c] += 1;
            for (acc, &v) in s.sums.row_mut(c).iter_mut().zip(x.row(i)) {
                *acc += v;
            }
        }
        let mut reseeded = false;
        for c in 0..k {
            if s.counts[c] > 0 {
                let inv = 1.0 / s.counts[c] as f64;
                for (dst, &v) in s.centroids.row_mut(c).iter_mut().zip(s.sums.row(c)) {
                    *dst = v * inv;
                }
            } else {
                // re-seed the emptied centroid from the farthest point
                // under the assignment distances just computed (a
                // deterministic pre-update baseline): first maximum wins,
                // and the chosen point is poisoned so a second empty
                // centroid this iteration picks a different point
                let mut far = 0usize;
                let mut far_d = f64::NEG_INFINITY;
                for (i, &d2) in s.dist2.iter().enumerate() {
                    if d2 > far_d {
                        far_d = d2;
                        far = i;
                    }
                }
                s.centroids.row_mut(c).copy_from_slice(x.row(far));
                s.dist2[far] = f64::NEG_INFINITY;
                reseeded = true;
            }
        }
        // converged? (never while a reseed is pending: the fresh centroid
        // must get at least one assignment pass)
        if !reseeded
            && inertia.is_finite()
            && (inertia - new_inertia).abs() <= cfg.tol * inertia.max(1e-12)
        {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    (inertia, iterations)
}

/// Run k-means on the rows of `x` with scratch borrowed from `s` — the
/// allocation-free lane. Returns `(inertia, iterations)`; assignments
/// and centroids are left in the scratch.
pub fn kmeans_into(x: &Dense, cfg: &KMeansConfig, s: &mut KMeansScratch) -> (f64, usize) {
    let n = x.nrows;
    let d = x.ncols;
    let k = cfg.k.min(n.max(1));
    let mut rng = Rng::new(cfg.seed);

    // --- k-means++ seeding
    reset_dense(&mut s.centroids, k, d);
    let first = rng.below(n);
    s.centroids.row_mut(0).copy_from_slice(x.row(first));
    s.dist2.clear();
    s.dist2.extend((0..n).map(|i| sq_dist(x.row(i), s.centroids.row(0))));
    for c in 1..k {
        let total: f64 = s.dist2.iter().sum();
        let pick = if total > 0.0 {
            let mut t = rng.f64() * total;
            let mut chosen = n - 1;
            for (i, &d2) in s.dist2.iter().enumerate() {
                t -= d2;
                if t <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        } else {
            rng.below(n)
        };
        s.centroids.row_mut(c).copy_from_slice(x.row(pick));
        for i in 0..n {
            let nd = sq_dist(x.row(i), s.centroids.row(c));
            if nd < s.dist2[i] {
                s.dist2[i] = nd;
            }
        }
    }

    // --- Lloyd iterations
    lloyd(x, cfg, k, s)
}

/// Run k-means on the rows of `x` (allocating convenience front-end over
/// [`kmeans_into`]).
pub fn kmeans(x: &Dense, cfg: &KMeansConfig) -> KMeansResult {
    let mut s = KMeansScratch::new();
    let (inertia, iterations) = kmeans_into(x, cfg, &mut s);
    KMeansResult {
        assignments: s.assignments,
        centroids: s.centroids,
        inertia,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs() -> Dense {
        // two tight blobs around (0,0) and (10,10)
        let mut pts = Vec::new();
        for i in 0..20 {
            let j = (i % 5) as f64 * 0.01;
            pts.extend_from_slice(&[j, -j]);
        }
        for i in 0..20 {
            let j = (i % 5) as f64 * 0.01;
            pts.extend_from_slice(&[10.0 + j, 10.0 - j]);
        }
        Dense::from_vec(40, 2, pts)
    }

    #[test]
    fn separates_two_blobs() {
        let x = blobs();
        let res = kmeans(&x, &KMeansConfig::new(2));
        // all of first 20 in one cluster, all of last 20 in the other
        let a = res.assignments[0];
        assert!(res.assignments[..20].iter().all(|&c| c == a));
        let b = res.assignments[20];
        assert_ne!(a, b);
        assert!(res.assignments[20..].iter().all(|&c| c == b));
        assert!(res.inertia < 1.0);
    }

    #[test]
    fn deterministic_in_seed() {
        let x = blobs();
        let r1 = kmeans(&x, &KMeansConfig::new(2));
        let r2 = kmeans(&x, &KMeansConfig::new(2));
        assert_eq!(r1.assignments, r2.assignments);
    }

    #[test]
    fn k_larger_than_n_clamped() {
        let x = Dense::from_vec(3, 1, vec![0.0, 1.0, 2.0]);
        let res = kmeans(&x, &KMeansConfig::new(10));
        assert_eq!(res.assignments.len(), 3);
    }

    #[test]
    fn inertia_zero_for_k_equals_n() {
        let x = Dense::from_vec(4, 1, vec![0.0, 5.0, 10.0, 15.0]);
        let res = kmeans(&x, &KMeansConfig::new(4));
        assert!(res.inertia < 1e-9, "inertia {}", res.inertia);
    }

    #[test]
    fn scratch_lane_matches_allocating_lane_and_reuses_buffers() {
        let x = blobs();
        let cfg = KMeansConfig::new(2);
        let base = kmeans(&x, &cfg);
        let mut s = KMeansScratch::new();
        for _ in 0..3 {
            let (inertia, iterations) = kmeans_into(&x, &cfg, &mut s);
            assert_eq!(s.assignments, base.assignments);
            assert_eq!(s.centroids.data, base.centroids.data);
            assert!((inertia - base.inertia).abs() == 0.0);
            assert_eq!(iterations, base.iterations);
        }
    }

    #[test]
    fn parallel_assignment_is_bitwise_at_any_thread_count() {
        // a big-enough random cloud that the parallel path actually runs
        // (n >= 2 * PAR_MIN_ROWS), checked against the serial path
        let n = 2 * PAR_MIN_ROWS + 57;
        let mut rng = Rng::new(991);
        let data: Vec<f64> = (0..n * 3).map(|_| rng.f64() * 4.0).collect();
        let x = Dense::from_vec(n, 3, data);
        let serial = kmeans(&x, &KMeansConfig { threads: 1, ..KMeansConfig::new(5) });
        for threads in [2, 3, 8] {
            let par = kmeans(&x, &KMeansConfig { threads, ..KMeansConfig::new(5) });
            assert_eq!(par.assignments, serial.assignments, "threads={threads}");
            assert_eq!(par.centroids.data, serial.centroids.data, "threads={threads}");
            assert_eq!(
                par.inertia.to_bits(),
                serial.inertia.to_bits(),
                "threads={threads}"
            );
            assert_eq!(par.iterations, serial.iterations, "threads={threads}");
        }
    }

    #[test]
    fn ties_assign_to_lowest_centroid_index() {
        // two identical centroids: every point is equidistant, so all
        // assignments must land on index 0 (then centroid 1 empties and
        // the reseed path takes over — covered below)
        let x = Dense::from_vec(4, 1, vec![1.0, 1.0, 1.0, 9.0]);
        let centroids = Dense::from_vec(2, 1, vec![1.0, 1.0]);
        let mut assignments = vec![0usize; 4];
        let mut dist2 = vec![0.0f64; 4];
        assign_rows(&x, &centroids, 2, 0, &mut assignments, &mut dist2);
        assert_eq!(assignments, vec![0, 0, 0, 0]);
    }

    #[test]
    fn emptied_centroid_reseeds_from_farthest_point() {
        // regression for the empty-cluster path: centroids [0, 0, 10]
        // tie points 0.0/0.2 onto centroid 0 (lowest index wins), so
        // centroid 1 is emptied and must be re-seeded at the farthest
        // point (100.0) — deterministically, from the pre-update
        // assignment distances. The loop then converges with every
        // cluster populated.
        let x = Dense::from_vec(5, 1, vec![0.0, 0.2, 10.0, 10.2, 100.0]);
        let mut s = KMeansScratch::new();
        s.centroids = Dense::from_vec(3, 1, vec![0.0, 0.0, 10.0]);
        let cfg = KMeansConfig::new(3);
        let (inertia, _) = lloyd(&x, &cfg, 3, &mut s);
        assert_eq!(s.assignments, vec![0, 0, 2, 2, 1]);
        assert_eq!(s.centroids.get(1, 0), 100.0, "reseed must land on the outlier");
        let mut counts = [0usize; 3];
        s.assignments.iter().for_each(|&c| counts[c] += 1);
        assert!(counts.iter().all(|&c| c > 0), "no cluster may stay empty: {counts:?}");
        assert!(inertia < 0.1, "inertia {inertia}");
    }

    #[test]
    fn two_emptied_centroids_reseed_from_distinct_points() {
        // all three centroids identical: clusters 1 and 2 are both
        // emptied in the same iteration. Poisoning the first reseed's
        // point forces the second onto a *different* point — without it
        // both would grab the same outlier and one stayed empty.
        let x = Dense::from_vec(5, 1, vec![0.0, 0.2, 10.0, 10.2, 100.0]);
        let mut s = KMeansScratch::new();
        s.centroids = Dense::from_vec(3, 1, vec![0.0, 0.0, 0.0]);
        let cfg = KMeansConfig::new(3);
        lloyd(&x, &cfg, 3, &mut s);
        let mut counts = [0usize; 3];
        s.assignments.iter().for_each(|&c| counts[c] += 1);
        assert!(counts.iter().all(|&c| c > 0), "no cluster may stay empty: {counts:?}");
        // the partition must be the natural one: {0,.2} {10,10.2} {100}
        assert_eq!(s.assignments[0], s.assignments[1]);
        assert_eq!(s.assignments[2], s.assignments[3]);
        assert_ne!(s.assignments[0], s.assignments[2]);
        assert_ne!(s.assignments[0], s.assignments[4]);
        assert_ne!(s.assignments[2], s.assignments[4]);
    }

    #[test]
    fn reseed_is_deterministic_across_runs() {
        let x = Dense::from_vec(5, 1, vec![0.0, 0.2, 10.0, 10.2, 100.0]);
        let run = || {
            let mut s = KMeansScratch::new();
            s.centroids = Dense::from_vec(3, 1, vec![0.0, 0.0, 10.0]);
            lloyd(&x, &KMeansConfig::new(3), 3, &mut s);
            (s.assignments.clone(), s.centroids.data.clone())
        };
        assert_eq!(run(), run());
    }
}
