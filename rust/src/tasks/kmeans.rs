//! Lloyd's k-means with k-means++ initialization — vertex clustering on
//! GEE embeddings (the paper's cited downstream task; GEE+k-means is the
//! community-detection recipe of Shen et al.).

use crate::sparse::Dense;
use crate::util::rng::Rng;

/// k-means configuration.
#[derive(Clone, Copy, Debug)]
pub struct KMeansConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Relative change of total inertia that counts as converged.
    pub tol: f64,
    pub seed: u64,
}

impl KMeansConfig {
    pub fn new(k: usize) -> Self {
        KMeansConfig { k, max_iters: 100, tol: 1e-6, seed: 0xC1_0551 }
    }
}

/// k-means result.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub assignments: Vec<usize>,
    pub centroids: Dense,
    pub inertia: f64,
    pub iterations: usize,
}

#[inline]
fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Run k-means on the rows of `x`.
pub fn kmeans(x: &Dense, cfg: &KMeansConfig) -> KMeansResult {
    let n = x.nrows;
    let d = x.ncols;
    let k = cfg.k.min(n.max(1));
    let mut rng = Rng::new(cfg.seed);

    // --- k-means++ seeding
    let mut centroids = Dense::zeros(k, d);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(x.row(first));
    let mut dist2: Vec<f64> = (0..n).map(|i| sq_dist(x.row(i), centroids.row(0))).collect();
    for c in 1..k {
        let total: f64 = dist2.iter().sum();
        let pick = if total > 0.0 {
            let mut t = rng.f64() * total;
            let mut chosen = n - 1;
            for (i, &d2) in dist2.iter().enumerate() {
                t -= d2;
                if t <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        } else {
            rng.below(n)
        };
        centroids.row_mut(c).copy_from_slice(x.row(pick));
        for i in 0..n {
            let nd = sq_dist(x.row(i), centroids.row(c));
            if nd < dist2[i] {
                dist2[i] = nd;
            }
        }
    }

    // --- Lloyd iterations
    let mut assignments = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..cfg.max_iters {
        iterations = it + 1;
        // assign
        let mut new_inertia = 0.0;
        for i in 0..n {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d2 = sq_dist(x.row(i), centroids.row(c));
                if d2 < best_d {
                    best_d = d2;
                    best = c;
                }
            }
            assignments[i] = best;
            new_inertia += best_d;
        }
        // update
        let mut counts = vec![0usize; k];
        let mut sums = Dense::zeros(k, d);
        for i in 0..n {
            let c = assignments[i];
            counts[c] += 1;
            for (s, &v) in sums.row_mut(c).iter_mut().zip(x.row(i)) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for s in sums.row_mut(c) {
                    *s /= counts[c] as f64;
                }
                centroids.row_mut(c).copy_from_slice(sums.row(c));
            } else {
                // re-seed empty cluster at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        sq_dist(x.row(a), centroids.row(assignments[a]))
                            .partial_cmp(&sq_dist(x.row(b), centroids.row(assignments[b])))
                            .unwrap()
                    })
                    .unwrap_or(0);
                centroids.row_mut(c).copy_from_slice(x.row(far));
            }
        }
        // converged?
        if inertia.is_finite() && (inertia - new_inertia).abs() <= cfg.tol * inertia.max(1e-12) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }

    KMeansResult { assignments, centroids, inertia, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs() -> Dense {
        // two tight blobs around (0,0) and (10,10)
        let mut pts = Vec::new();
        for i in 0..20 {
            let j = (i % 5) as f64 * 0.01;
            pts.extend_from_slice(&[j, -j]);
        }
        for i in 0..20 {
            let j = (i % 5) as f64 * 0.01;
            pts.extend_from_slice(&[10.0 + j, 10.0 - j]);
        }
        Dense::from_vec(40, 2, pts)
    }

    #[test]
    fn separates_two_blobs() {
        let x = blobs();
        let res = kmeans(&x, &KMeansConfig::new(2));
        // all of first 20 in one cluster, all of last 20 in the other
        let a = res.assignments[0];
        assert!(res.assignments[..20].iter().all(|&c| c == a));
        let b = res.assignments[20];
        assert_ne!(a, b);
        assert!(res.assignments[20..].iter().all(|&c| c == b));
        assert!(res.inertia < 1.0);
    }

    #[test]
    fn deterministic_in_seed() {
        let x = blobs();
        let r1 = kmeans(&x, &KMeansConfig::new(2));
        let r2 = kmeans(&x, &KMeansConfig::new(2));
        assert_eq!(r1.assignments, r2.assignments);
    }

    #[test]
    fn k_larger_than_n_clamped() {
        let x = Dense::from_vec(3, 1, vec![0.0, 1.0, 2.0]);
        let res = kmeans(&x, &KMeansConfig::new(10));
        assert_eq!(res.assignments.len(), 3);
    }

    #[test]
    fn inertia_zero_for_k_equals_n() {
        let x = Dense::from_vec(4, 1, vec![0.0, 5.0, 10.0, 15.0]);
        let res = kmeans(&x, &KMeansConfig::new(4));
        assert!(res.inertia < 1e-9, "inertia {}", res.inertia);
    }
}
