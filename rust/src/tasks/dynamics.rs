//! Vertex dynamics over graph time series (Shen, Larson, Trinh, Qin,
//! Park & Priebe 2023, ref [12] of the paper): embed each time window
//! with GEE and measure per-vertex movement between consecutive
//! embeddings. Vertices whose communication pattern shifts show large
//! dynamics; stable vertices stay near zero — the reference uses this to
//! discover pattern shifts in large-scale networks.

use crate::gee::options::GeeOptions;
use crate::gee::sparse_gee::SparseGee;
use crate::graph::Graph;
use crate::sparse::Dense;

/// Per-window embedding plus per-vertex movement vs the previous window.
#[derive(Clone, Debug)]
pub struct DynamicsResult {
    /// One embedding per window, each N × K.
    pub embeddings: Vec<Dense>,
    /// Per-window per-vertex Euclidean displacement from the previous
    /// window (first window is all zeros). `dynamics[t][v]`.
    pub dynamics: Vec<Vec<f64>>,
}

/// Embed a time series of graphs (same vertex set / labels per window)
/// and compute vertex dynamics. The correlation option is recommended so
/// displacement measures direction change, not degree drift.
pub fn vertex_dynamics(windows: &[&Graph], opts: &GeeOptions) -> DynamicsResult {
    let engine = SparseGee::fast();
    let embeddings: Vec<Dense> = windows.iter().map(|g| engine.embed(g, opts)).collect();
    let mut dynamics = Vec::with_capacity(windows.len());
    for t in 0..embeddings.len() {
        if t == 0 {
            dynamics.push(vec![0.0; embeddings[0].nrows]);
            continue;
        }
        let (prev, cur) = (&embeddings[t - 1], &embeddings[t]);
        let n = prev.nrows.min(cur.nrows);
        let mut d = vec![0.0; cur.nrows];
        for v in 0..n {
            d[v] = prev
                .row(v)
                .iter()
                .zip(cur.row(v))
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
        }
        dynamics.push(d);
    }
    DynamicsResult { embeddings, dynamics }
}

/// Vertices whose max displacement over the series exceeds `threshold`,
/// sorted by descending peak movement — the "shift detector" output.
pub fn shifted_vertices(res: &DynamicsResult, threshold: f64) -> Vec<(usize, f64)> {
    let n = res.dynamics.iter().map(|d| d.len()).max().unwrap_or(0);
    let mut peaks = vec![0.0f64; n];
    for d in &res.dynamics {
        for (v, &x) in d.iter().enumerate() {
            if x > peaks[v] {
                peaks[v] = x;
            }
        }
    }
    let mut out: Vec<(usize, f64)> = peaks
        .into_iter()
        .enumerate()
        .filter(|&(_, p)| p > threshold)
        .collect();
    out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Three windows: stable 2-community graph, then vertices 0..5 switch
    /// their connectivity to the other community in window 2.
    fn series(seed: u64) -> Vec<Graph> {
        let n = 60;
        let mut rng = Rng::new(seed);
        let labels: Vec<i32> = (0..n).map(|i| (i % 2) as i32).collect();
        let mut make = |movers_flipped: bool| {
            let mut g = Graph::new(n, 2);
            g.labels = labels.clone();
            for _ in 0..n * 8 {
                let a = rng.below(n);
                let b = rng.below(n);
                if a == b {
                    continue;
                }
                let eff = |v: usize| -> i32 {
                    if movers_flipped && v < 5 {
                        1 - labels[v]
                    } else {
                        labels[v]
                    }
                };
                let p = if eff(a) == eff(b) { 0.7 } else { 0.1 };
                if rng.f64() < p {
                    g.add_edge(a as u32, b as u32, 1.0);
                }
            }
            g
        };
        vec![make(false), make(false), make(true)]
    }

    #[test]
    fn movers_have_largest_dynamics() {
        let windows = series(21);
        let refs: Vec<&Graph> = windows.iter().collect();
        let res = vertex_dynamics(&refs, &GeeOptions::new(false, true, true));
        assert_eq!(res.dynamics.len(), 3);
        assert!(res.dynamics[0].iter().all(|&d| d == 0.0));
        // window 2: movers (0..5) should out-move the stable majority
        let d2 = &res.dynamics[2];
        let mover_mean: f64 = d2[..5].iter().sum::<f64>() / 5.0;
        let stable_mean: f64 = d2[5..].iter().sum::<f64>() / (d2.len() - 5) as f64;
        assert!(
            mover_mean > 2.0 * stable_mean,
            "movers {mover_mean} vs stable {stable_mean}"
        );
    }

    #[test]
    fn shift_detector_ranks_movers_first() {
        let windows = series(22);
        let refs: Vec<&Graph> = windows.iter().collect();
        let res = vertex_dynamics(&refs, &GeeOptions::new(false, true, true));
        let shifts = shifted_vertices(&res, 0.0);
        // at least 3 of the 5 movers in the top 8
        let top: Vec<usize> = shifts.iter().take(8).map(|&(v, _)| v).collect();
        let movers_in_top = top.iter().filter(|&&v| v < 5).count();
        assert!(movers_in_top >= 3, "top8 {top:?}");
    }

    #[test]
    fn stable_series_has_small_dynamics() {
        let windows = series(23);
        let refs: Vec<&Graph> = windows[..2].iter().collect(); // two stable windows
        let res = vertex_dynamics(&refs, &GeeOptions::new(false, true, true));
        let mean: f64 =
            res.dynamics[1].iter().sum::<f64>() / res.dynamics[1].len() as f64;
        assert!(mean < 0.5, "stable mean movement {mean}");
    }

    #[test]
    fn single_window_is_trivial() {
        let windows = series(24);
        let refs: Vec<&Graph> = windows[..1].iter().collect();
        let res = vertex_dynamics(&refs, &GeeOptions::NONE);
        assert_eq!(res.embeddings.len(), 1);
        assert!(shifted_vertices(&res, 0.0).is_empty());
    }
}
