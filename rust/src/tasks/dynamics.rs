//! Vertex dynamics over graph time series (Shen, Larson, Trinh, Qin,
//! Park & Priebe 2023, ref [12] of the paper): embed each time window
//! with GEE and measure per-vertex movement between consecutive
//! embeddings. Vertices whose communication pattern shifts show large
//! dynamics; stable vertices stay near zero — the reference uses this to
//! discover pattern shifts in large-scale networks.
//!
//! The per-window embeddings ride the resident-session layer
//! ([`GeeSession`]): the first window opens a session, and every later
//! window is diffed against its predecessor and applied as a batch of
//! edge/label deltas, so each step costs O(Δ) row refreshes instead of a
//! from-scratch embed. Consecutive communication windows overlap heavily
//! in practice, which is exactly the regime the delta lane is built for.
//! The old rebuild-every-window path survives as
//! [`vertex_dynamics_batch`], the parity oracle: the session path must
//! agree with it to ~1e-9 (not bitwise — replaying a window as
//! deletes+inserts reorders the stored edge list, which reorders the FP
//! accumulation).

use std::collections::BTreeMap;

use crate::coordinator::session::{Delta, GeeSession, SessionConfig};
use crate::gee::options::GeeOptions;
use crate::gee::sparse_gee::SparseGee;
use crate::graph::Graph;
use crate::sparse::Dense;

/// Per-window embedding plus per-vertex movement vs the previous window.
#[derive(Clone, Debug)]
pub struct DynamicsResult {
    /// One embedding per window, each N × K.
    pub embeddings: Vec<Dense>,
    /// Per-window per-vertex Euclidean displacement from the previous
    /// window (first window is all zeros). `dynamics[t][v]`.
    pub dynamics: Vec<Vec<f64>>,
}

/// Embed a time series of graphs (same vertex set / labels per window)
/// and compute vertex dynamics. The correlation option is recommended so
/// displacement measures direction change, not degree drift.
///
/// Windows are embedded through a resident [`GeeSession`]: consecutive
/// windows with the same shape are applied as deltas (O(Δ) refresh); a
/// shape change (different `n` or `k`) or a rejected delta reopens the
/// session from that window.
pub fn vertex_dynamics(windows: &[&Graph], opts: &GeeOptions) -> DynamicsResult {
    let cfg = SessionConfig { opts: *opts, rescale_threshold: 0.25 };
    let mut embeddings: Vec<Dense> = Vec::with_capacity(windows.len());
    let mut session: Option<GeeSession> = None;
    for (t, g) in windows.iter().enumerate() {
        let same_shape =
            t > 0 && windows[t - 1].n == g.n && windows[t - 1].k == g.k;
        let mut advanced = false;
        if same_shape {
            let s = session.as_mut().expect("t > 0 implies an open session");
            let deltas = window_deltas(windows[t - 1], g);
            let (_, res) = s.apply_all(&deltas);
            if res.is_ok() {
                s.refresh();
                advanced = true;
            }
            // a rejected delta (shouldn't happen for valid windows) falls
            // through to a clean reopen below
        }
        if !advanced {
            session = Some(GeeSession::from_graph(g, &cfg));
        }
        embeddings.push(session.as_ref().expect("session opened above").z().clone());
    }
    dynamics_from(embeddings)
}

/// From-scratch per-window embedding — the batch oracle the session path
/// is tested against.
pub fn vertex_dynamics_batch(windows: &[&Graph], opts: &GeeOptions) -> DynamicsResult {
    let engine = SparseGee::fast();
    let embeddings: Vec<Dense> = windows.iter().map(|g| engine.embed(g, opts)).collect();
    dynamics_from(embeddings)
}

/// Diff two same-shape windows into session deltas: label changes become
/// `Relabel`; for every endpoint pair whose weight multiset changed, the
/// stored copies are deleted and the new window's copies inserted.
/// Identical pairs (the common case for overlapping windows) cost nothing.
fn window_deltas(prev: &Graph, cur: &Graph) -> Vec<Delta> {
    debug_assert_eq!(prev.n, cur.n);
    let mut out = Vec::new();
    for v in 0..cur.n {
        if prev.labels[v] != cur.labels[v] {
            out.push(Delta::Relabel { v: v as u32, label: cur.labels[v] });
        }
    }
    // BTreeMap keeps the delta order deterministic across runs
    let mut pairs: BTreeMap<(u32, u32), (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    let key = |a: u32, b: u32| if a <= b { (a, b) } else { (b, a) };
    for i in 0..prev.num_edges() {
        pairs.entry(key(prev.src[i], prev.dst[i])).or_default().0.push(prev.w[i]);
    }
    for i in 0..cur.num_edges() {
        pairs.entry(key(cur.src[i], cur.dst[i])).or_default().1.push(cur.w[i]);
    }
    for (&(a, b), (pw, cw)) in pairs.iter() {
        if pw.len() == cw.len() {
            let mut ps: Vec<u64> = pw.iter().map(|w| w.to_bits()).collect();
            let mut cs: Vec<u64> = cw.iter().map(|w| w.to_bits()).collect();
            ps.sort_unstable();
            cs.sort_unstable();
            if ps == cs {
                continue;
            }
        }
        // Delete removes the oldest stored copy regardless of weight, so a
        // changed multiset clears the pair and re-inserts the new copies.
        for _ in 0..pw.len() {
            out.push(Delta::Delete { a, b });
        }
        for &w in cw.iter() {
            out.push(Delta::Insert { a, b, w });
        }
    }
    out
}

/// Displacement bookkeeping shared by the session and batch paths.
fn dynamics_from(embeddings: Vec<Dense>) -> DynamicsResult {
    let mut dynamics = Vec::with_capacity(embeddings.len());
    for t in 0..embeddings.len() {
        if t == 0 {
            dynamics.push(vec![0.0; embeddings[0].nrows]);
            continue;
        }
        let (prev, cur) = (&embeddings[t - 1], &embeddings[t]);
        let n = prev.nrows.min(cur.nrows);
        let mut d = vec![0.0; cur.nrows];
        for v in 0..n {
            d[v] = prev
                .row(v)
                .iter()
                .zip(cur.row(v))
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
        }
        dynamics.push(d);
    }
    DynamicsResult { embeddings, dynamics }
}

/// Vertices whose max displacement over the series exceeds `threshold`,
/// sorted by descending peak movement — the "shift detector" output.
pub fn shifted_vertices(res: &DynamicsResult, threshold: f64) -> Vec<(usize, f64)> {
    let n = res.dynamics.iter().map(|d| d.len()).max().unwrap_or(0);
    let mut peaks = vec![0.0f64; n];
    for d in &res.dynamics {
        for (v, &x) in d.iter().enumerate() {
            if x > peaks[v] {
                peaks[v] = x;
            }
        }
    }
    let mut out: Vec<(usize, f64)> = peaks
        .into_iter()
        .enumerate()
        .filter(|&(_, p)| p > threshold)
        .collect();
    out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Three windows: stable 2-community graph, then vertices 0..5 switch
    /// their connectivity to the other community in window 2.
    fn series(seed: u64) -> Vec<Graph> {
        let n = 60;
        let mut rng = Rng::new(seed);
        let labels: Vec<i32> = (0..n).map(|i| (i % 2) as i32).collect();
        let mut make = |movers_flipped: bool| {
            let mut g = Graph::new(n, 2);
            g.labels = labels.clone();
            for _ in 0..n * 8 {
                let a = rng.below(n);
                let b = rng.below(n);
                if a == b {
                    continue;
                }
                let eff = |v: usize| -> i32 {
                    if movers_flipped && v < 5 {
                        1 - labels[v]
                    } else {
                        labels[v]
                    }
                };
                let p = if eff(a) == eff(b) { 0.7 } else { 0.1 };
                if rng.f64() < p {
                    g.add_edge(a as u32, b as u32, 1.0);
                }
            }
            g
        };
        vec![make(false), make(false), make(true)]
    }

    #[test]
    fn movers_have_largest_dynamics() {
        let windows = series(21);
        let refs: Vec<&Graph> = windows.iter().collect();
        let res = vertex_dynamics(&refs, &GeeOptions::new(false, true, true));
        assert_eq!(res.dynamics.len(), 3);
        assert!(res.dynamics[0].iter().all(|&d| d == 0.0));
        // window 2: movers (0..5) should out-move the stable majority
        let d2 = &res.dynamics[2];
        let mover_mean: f64 = d2[..5].iter().sum::<f64>() / 5.0;
        let stable_mean: f64 = d2[5..].iter().sum::<f64>() / (d2.len() - 5) as f64;
        assert!(
            mover_mean > 2.0 * stable_mean,
            "movers {mover_mean} vs stable {stable_mean}"
        );
    }

    #[test]
    fn shift_detector_ranks_movers_first() {
        let windows = series(22);
        let refs: Vec<&Graph> = windows.iter().collect();
        let res = vertex_dynamics(&refs, &GeeOptions::new(false, true, true));
        let shifts = shifted_vertices(&res, 0.0);
        // at least 3 of the 5 movers in the top 8
        let top: Vec<usize> = shifts.iter().take(8).map(|&(v, _)| v).collect();
        let movers_in_top = top.iter().filter(|&&v| v < 5).count();
        assert!(movers_in_top >= 3, "top8 {top:?}");
    }

    #[test]
    fn session_path_matches_batch_oracle() {
        let windows = series(26);
        let refs: Vec<&Graph> = windows.iter().collect();
        for opts in GeeOptions::table_order() {
            let sess = vertex_dynamics(&refs, &opts);
            let batch = vertex_dynamics_batch(&refs, &opts);
            for t in 0..refs.len() {
                let d = sess.embeddings[t].max_abs_diff(&batch.embeddings[t]);
                assert!(d < 1e-9, "{opts:?} window {t}: embed diff {d}");
                for (v, (a, b)) in
                    sess.dynamics[t].iter().zip(&batch.dynamics[t]).enumerate()
                {
                    assert!(
                        (a - b).abs() < 1e-9,
                        "{opts:?} window {t} vertex {v}: dynamics {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn label_churn_rides_relabel_deltas() {
        // same edges, drifting labels: the diff is pure Relabel deltas
        let mut rng = Rng::new(27);
        let n = 40;
        let mut base = Graph::new(n, 3);
        for l in base.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        for _ in 0..160 {
            base.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        let mut windows = vec![base.clone()];
        for _ in 0..3 {
            let mut g = windows.last().unwrap().clone();
            for _ in 0..6 {
                let v = rng.below(n);
                g.labels[v] = (rng.below(4) as i32) - 1; // includes -1
            }
            windows.push(g);
        }
        let refs: Vec<&Graph> = windows.iter().collect();
        let opts = GeeOptions::ALL;
        let sess = vertex_dynamics(&refs, &opts);
        let batch = vertex_dynamics_batch(&refs, &opts);
        for t in 0..refs.len() {
            let d = sess.embeddings[t].max_abs_diff(&batch.embeddings[t]);
            assert!(d < 1e-9, "window {t}: embed diff {d}");
        }
    }

    #[test]
    fn shape_change_reopens_session() {
        // windows of different vertex counts can't share a session; the
        // fallback must still match the batch oracle
        let mut rng = Rng::new(28);
        let mut small = Graph::new(20, 2);
        for l in small.labels.iter_mut() {
            *l = rng.below(2) as i32;
        }
        for _ in 0..60 {
            small.add_edge(rng.below(20) as u32, rng.below(20) as u32, 1.0);
        }
        let mut big = Graph::new(25, 2);
        for l in big.labels.iter_mut() {
            *l = rng.below(2) as i32;
        }
        for _ in 0..80 {
            big.add_edge(rng.below(25) as u32, rng.below(25) as u32, 1.0);
        }
        let windows = [&small, &big, &small];
        let opts = GeeOptions::new(true, false, true);
        let sess = vertex_dynamics(&windows, &opts);
        let batch = vertex_dynamics_batch(&windows, &opts);
        assert_eq!(sess.embeddings.len(), 3);
        for t in 0..3 {
            let d = sess.embeddings[t].max_abs_diff(&batch.embeddings[t]);
            assert!(d < 1e-9, "window {t}: embed diff {d}");
        }
        // dynamics across the size boundary only covers the shared prefix
        assert_eq!(sess.dynamics[1].len(), 25);
    }

    #[test]
    fn stable_series_has_small_dynamics() {
        let windows = series(23);
        let refs: Vec<&Graph> = windows[..2].iter().collect(); // two stable windows
        let res = vertex_dynamics(&refs, &GeeOptions::new(false, true, true));
        let mean: f64 =
            res.dynamics[1].iter().sum::<f64>() / res.dynamics[1].len() as f64;
        assert!(mean < 0.5, "stable mean movement {mean}");
    }

    #[test]
    fn single_window_is_trivial() {
        let windows = series(24);
        let refs: Vec<&Graph> = windows[..1].iter().collect();
        let res = vertex_dynamics(&refs, &GeeOptions::NONE);
        assert_eq!(res.embeddings.len(), 1);
        assert!(shifted_vertices(&res, 0.0).is_empty());
    }
}
