//! k-nearest-neighbor vertex classification on embeddings — the
//! vertex-classification downstream task GEE was designed for
//! (original GEE pairs the embedding with 5-NN / LDA).

use crate::sparse::Dense;

/// Classify each query row by majority vote among its k nearest train
/// rows (Euclidean). Ties break toward the nearest contributing class.
pub fn knn_classify(
    train: &Dense,
    train_labels: &[i32],
    queries: &Dense,
    k: usize,
) -> Vec<i32> {
    assert_eq!(train.nrows, train_labels.len());
    assert_eq!(train.ncols, queries.ncols);
    let k = k.max(1).min(train.nrows);
    let mut out = Vec::with_capacity(queries.nrows);
    // reusable scratch of (dist, idx)
    let mut dists: Vec<(f64, usize)> = Vec::with_capacity(train.nrows);
    for q in 0..queries.nrows {
        dists.clear();
        let qrow = queries.row(q);
        for t in 0..train.nrows {
            let d: f64 = qrow
                .iter()
                .zip(train.row(t))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            dists.push((d, t));
        }
        // partial select of the k smallest
        dists.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        let neighbors = &mut dists[..k];
        neighbors.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // majority vote
        let mut votes: std::collections::HashMap<i32, (usize, f64)> =
            std::collections::HashMap::new();
        for &(d, t) in neighbors.iter() {
            let e = votes.entry(train_labels[t]).or_insert((0, f64::INFINITY));
            e.0 += 1;
            e.1 = e.1.min(d);
        }
        let best = votes
            .into_iter()
            .max_by(|a, b| {
                (a.1 .0, std::cmp::Reverse(ordered(a.1 .1)))
                    .cmp(&(b.1 .0, std::cmp::Reverse(ordered(b.1 .1))))
            })
            .map(|(l, _)| l)
            .unwrap_or(-1);
        out.push(best);
    }
    out
}

/// Total-order wrapper for f64 (NaN-free by construction here).
fn ordered(x: f64) -> u64 {
    x.to_bits() ^ (((x.to_bits() as i64) >> 63) as u64 >> 1)
}

/// Leave-one-out 1-NN training accuracy — a quick embedding-quality
/// metric used by the examples.
pub fn loo_1nn_accuracy(x: &Dense, labels: &[i32]) -> f64 {
    let n = x.nrows;
    if n < 2 {
        return 0.0;
    }
    let mut correct = 0usize;
    let mut counted = 0usize;
    for i in 0..n {
        if labels[i] < 0 {
            continue;
        }
        let mut best = usize::MAX;
        let mut best_d = f64::INFINITY;
        for j in 0..n {
            if j == i || labels[j] < 0 {
                continue;
            }
            let d: f64 = x
                .row(i)
                .iter()
                .zip(x.row(j))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            if d < best_d {
                best_d = d;
                best = j;
            }
        }
        if best != usize::MAX {
            counted += 1;
            if labels[best] == labels[i] {
                correct += 1;
            }
        }
    }
    if counted == 0 {
        0.0
    } else {
        correct as f64 / counted as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn train_set() -> (Dense, Vec<i32>) {
        let x = Dense::from_vec(
            6,
            1,
            vec![0.0, 0.1, 0.2, 10.0, 10.1, 10.2],
        );
        (x, vec![0, 0, 0, 1, 1, 1])
    }

    #[test]
    fn classifies_obvious_queries() {
        let (x, y) = train_set();
        let q = Dense::from_vec(2, 1, vec![0.05, 9.9]);
        assert_eq!(knn_classify(&x, &y, &q, 3), vec![0, 1]);
    }

    #[test]
    fn k_one_nearest() {
        let (x, y) = train_set();
        let q = Dense::from_vec(1, 1, vec![5.2]);
        // nearest single point is 10.0 (class 1)? |5.2-0.2|=5.0, |5.2-10|=4.8
        assert_eq!(knn_classify(&x, &y, &q, 1), vec![1]);
    }

    #[test]
    fn k_clamped_to_train_size() {
        let (x, y) = train_set();
        let q = Dense::from_vec(1, 1, vec![0.0]);
        // k=100 -> all 6 vote, tie 3-3 broken by nearest distance (class 0)
        assert_eq!(knn_classify(&x, &y, &q, 100), vec![0]);
    }

    #[test]
    fn loo_accuracy_perfect_on_separated() {
        let (x, y) = train_set();
        assert_eq!(loo_1nn_accuracy(&x, &y), 1.0);
    }

    #[test]
    fn loo_skips_unlabeled() {
        let x = Dense::from_vec(3, 1, vec![0.0, 0.1, 100.0]);
        let y = vec![0, 0, -1];
        assert_eq!(loo_1nn_accuracy(&x, &y), 1.0);
    }
}
