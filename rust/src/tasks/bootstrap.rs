//! Graph bootstrap on GEE embeddings — one of the applications the paper
//! lists in §1 (via the original GEE work): resample the edge list with
//! replacement, re-embed each replicate, and report per-vertex embedding
//! variability (standard errors / percentile intervals). Vertices whose
//! embedding is unstable under resampling sit near community boundaries.

use crate::gee::options::GeeOptions;
use crate::gee::sparse_gee::SparseGee;
use crate::graph::Graph;
use crate::sparse::Dense;
use crate::util::rng::Rng;

/// Bootstrap output.
#[derive(Clone, Debug)]
pub struct BootstrapResult {
    /// Point estimate: embedding of the original graph.
    pub z: Dense,
    /// Per-vertex, per-dimension standard error across replicates (N×K).
    pub stderr: Dense,
    /// Per-vertex total instability: mean stderr across dimensions.
    pub instability: Vec<f64>,
    pub replicates: usize,
}

/// Edge-resampling bootstrap: each replicate draws |E| edges with
/// replacement from the original edge list (weights carried along).
pub fn bootstrap_embedding(
    g: &Graph,
    opts: &GeeOptions,
    replicates: usize,
    seed: u64,
) -> BootstrapResult {
    assert!(replicates >= 2);
    let engine = SparseGee::fast();
    let z = engine.embed(g, opts);
    let n = g.n;
    let k = g.k;
    let m = g.num_edges();

    let mut rng = Rng::new(seed);
    let mut sum = Dense::zeros(n, k);
    let mut sumsq = Dense::zeros(n, k);
    for _ in 0..replicates {
        let mut gb = Graph::new(n, k);
        gb.labels = g.labels.clone();
        for _ in 0..m {
            let e = rng.below(m);
            gb.add_edge(g.src[e], g.dst[e], g.w[e]);
        }
        let zb = engine.embed(&gb, opts);
        for i in 0..n * k {
            sum.data[i] += zb.data[i];
            sumsq.data[i] += zb.data[i] * zb.data[i];
        }
    }

    let r = replicates as f64;
    let mut stderr = Dense::zeros(n, k);
    for i in 0..n * k {
        let mean = sum.data[i] / r;
        let var = (sumsq.data[i] / r - mean * mean).max(0.0) * r / (r - 1.0);
        stderr.data[i] = var.sqrt();
    }
    let instability: Vec<f64> = (0..n)
        .map(|v| stderr.row(v).iter().sum::<f64>() / k as f64)
        .collect();
    BootstrapResult { z, stderr, instability, replicates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sbm::{generate_sbm, SbmParams};

    #[test]
    fn boundary_vertices_are_less_stable() {
        // strong 2-block graph plus one vertex (0) wired half-half
        let mut p = SbmParams::fitted(120, 2, 1200, 6.0, vec![0.5, 0.5]);
        p.class_probs = vec![0.5, 0.5];
        let mut g = generate_sbm(&p, 9);
        // rewire vertex 0: give it equal ties to both blocks
        let keep: Vec<usize> = (0..g.num_edges())
            .filter(|&i| g.src[i] != 0 && g.dst[i] != 0)
            .collect();
        let (src, dst, w): (Vec<u32>, Vec<u32>, Vec<f64>) = (
            keep.iter().map(|&i| g.src[i]).collect(),
            keep.iter().map(|&i| g.dst[i]).collect(),
            keep.iter().map(|&i| g.w[i]).collect(),
        );
        g.src = src;
        g.dst = dst;
        g.w = w;
        for v in 1..5u32 {
            g.add_edge(0, v, 1.0);
        }
        let other: Vec<u32> = (1..g.n as u32)
            .filter(|&v| g.labels[v as usize] != g.labels[0])
            .take(4)
            .collect();
        for v in other {
            g.add_edge(0, v, 1.0);
        }

        let res = bootstrap_embedding(&g, &GeeOptions::new(false, true, true), 12, 3);
        assert_eq!(res.replicates, 12);
        // vertex 0 (boundary, low degree) should be among the least stable
        let mut order: Vec<usize> = (0..g.n).collect();
        order.sort_by(|&a, &b| {
            res.instability[b].partial_cmp(&res.instability[a]).unwrap()
        });
        let rank0 = order.iter().position(|&v| v == 0).unwrap();
        assert!(rank0 < g.n / 3, "vertex 0 stability rank {rank0}");
    }

    #[test]
    fn stderr_nonnegative_and_shaped() {
        let g = generate_sbm(&SbmParams::paper(80), 4);
        let res = bootstrap_embedding(&g, &GeeOptions::NONE, 5, 1);
        assert_eq!(res.stderr.nrows, 80);
        assert!(res.stderr.data.iter().all(|&x| x >= 0.0));
        assert_eq!(res.instability.len(), 80);
    }

    #[test]
    fn deterministic_in_seed() {
        let g = generate_sbm(&SbmParams::paper(60), 5);
        let a = bootstrap_embedding(&g, &GeeOptions::NONE, 4, 7);
        let b = bootstrap_embedding(&g, &GeeOptions::NONE, 4, 7);
        assert_eq!(a.instability, b.instability);
    }
}
