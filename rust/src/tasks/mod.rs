//! Downstream tasks that validate embedding quality: vertex clustering
//! (k-means → ARI/NMI against SBM ground truth), vertex classification
//! (k-NN, LDA → accuracy). These are the applications the GEE line of
//! work targets; the examples use them as end-to-end sanity checks.

pub mod bootstrap;
pub mod dynamics;
pub mod kmeans;
pub mod knn;
pub mod lda;
pub mod metrics;
