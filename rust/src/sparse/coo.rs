//! COO (coordinate / triplet) sparse matrix — the edge-list format.
//!
//! This is the paper's on-wire representation: each entry is a
//! `(row, col, value)` triplet, exactly one per edge, with zeros never
//! stored. COO is the natural construction format (streaming edges in) and
//! converts to [`Csr`](super::csr::Csr) for compute.

use super::csr::Csr;
use super::dense::Dense;

/// Coordinate-format sparse matrix with f64 values and u32 indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Coo {
    /// Empty matrix of the given shape.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo { nrows, ncols, rows: vec![], cols: vec![], vals: vec![] }
    }

    /// With pre-reserved capacity for `nnz` entries.
    pub fn with_capacity(nrows: usize, ncols: usize, nnz: usize) -> Self {
        Coo {
            nrows,
            ncols,
            rows: Vec::with_capacity(nnz),
            cols: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    /// Build from triplet slices (lengths must match; indices in range).
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        rows: &[u32],
        cols: &[u32],
        vals: &[f64],
    ) -> Self {
        assert_eq!(rows.len(), cols.len());
        assert_eq!(rows.len(), vals.len());
        debug_assert!(rows.iter().all(|&r| (r as usize) < nrows));
        debug_assert!(cols.iter().all(|&c| (c as usize) < ncols));
        Coo {
            nrows,
            ncols,
            rows: rows.to_vec(),
            cols: cols.to_vec(),
            vals: vals.to_vec(),
        }
    }

    /// Number of stored (not necessarily distinct) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one entry.
    #[inline]
    pub fn push(&mut self, row: u32, col: u32, val: f64) {
        debug_assert!((row as usize) < self.nrows && (col as usize) < self.ncols);
        self.rows.push(row);
        self.cols.push(col);
        self.vals.push(val);
    }

    /// Sort entries by (row, col) and merge duplicates by summation.
    /// Drops exact-zero merged entries (mirrors `scipy.sparse.coo.sum_duplicates`
    /// followed by `eliminate_zeros`).
    pub fn sort_dedup(&mut self) {
        let mut order: Vec<u32> = (0..self.nnz() as u32).collect();
        order.sort_unstable_by_key(|&i| {
            (self.rows[i as usize], self.cols[i as usize])
        });
        let mut rows = Vec::with_capacity(self.nnz());
        let mut cols = Vec::with_capacity(self.nnz());
        let mut vals = Vec::with_capacity(self.nnz());
        for &i in &order {
            let (r, c, v) = (
                self.rows[i as usize],
                self.cols[i as usize],
                self.vals[i as usize],
            );
            if let (Some(&lr), Some(&lc)) = (rows.last(), cols.last()) {
                if lr == r && lc == c {
                    *vals.last_mut().unwrap() += v;
                    continue;
                }
            }
            rows.push(r);
            cols.push(c);
            vals.push(v);
        }
        // eliminate zeros created by cancellation
        let mut w = 0;
        for i in 0..vals.len() {
            if vals[i] != 0.0 {
                rows[w] = rows[i];
                cols[w] = cols[i];
                vals[w] = vals[i];
                w += 1;
            }
        }
        rows.truncate(w);
        cols.truncate(w);
        vals.truncate(w);
        self.rows = rows;
        self.cols = cols;
        self.vals = vals;
    }

    /// Transpose (swap row/col indices; O(nnz)).
    pub fn transpose(&self) -> Coo {
        Coo {
            nrows: self.ncols,
            ncols: self.nrows,
            rows: self.cols.clone(),
            cols: self.rows.clone(),
            vals: self.vals.clone(),
        }
    }

    /// Convert to CSR (sorts + dedups internally; see [`Csr::from_coo`]).
    pub fn to_csr(&self) -> Csr {
        Csr::from_coo(self)
    }

    /// Materialize as a dense matrix (tests/small baselines only).
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.nrows, self.ncols);
        for i in 0..self.nnz() {
            *d.get_mut(self.rows[i] as usize, self.cols[i] as usize) += self.vals[i];
        }
        d
    }

    /// Row sums (out-degrees when this is an adjacency matrix).
    pub fn row_sums(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows];
        for i in 0..self.nnz() {
            d[self.rows[i] as usize] += self.vals[i];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        // 3x4:  [ . 1 . 2 ]
        //       [ . . . . ]
        //       [ 3 . 4 . ]
        Coo::from_triplets(3, 4, &[0, 0, 2, 2], &[1, 3, 0, 2], &[1.0, 2.0, 3.0, 4.0])
    }

    #[test]
    fn push_and_nnz() {
        let mut m = Coo::new(2, 2);
        assert_eq!(m.nnz(), 0);
        m.push(0, 1, 5.0);
        m.push(1, 0, -1.0);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn sort_dedup_sums_duplicates() {
        let mut m = Coo::from_triplets(
            2,
            2,
            &[1, 0, 1, 1],
            &[1, 0, 1, 0],
            &[1.0, 2.0, 3.0, 4.0],
        );
        m.sort_dedup();
        assert_eq!(m.rows, vec![0, 1, 1]);
        assert_eq!(m.cols, vec![0, 0, 1]);
        assert_eq!(m.vals, vec![2.0, 4.0, 4.0]);
    }

    #[test]
    fn sort_dedup_drops_cancelled_zeros() {
        let mut m = Coo::from_triplets(1, 2, &[0, 0], &[1, 1], &[2.5, -2.5]);
        m.sort_dedup();
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose().transpose();
        assert_eq!(m, t);
    }

    #[test]
    fn to_dense_matches_entries() {
        let d = sample().to_dense();
        assert_eq!(d.get(0, 1), 1.0);
        assert_eq!(d.get(0, 3), 2.0);
        assert_eq!(d.get(2, 0), 3.0);
        assert_eq!(d.get(2, 2), 4.0);
        assert_eq!(d.get(1, 2), 0.0);
    }

    #[test]
    fn row_sums_are_degrees() {
        assert_eq!(sample().row_sums(), vec![3.0, 0.0, 7.0]);
    }
}
