//! Shared vector/row kernels used by every GEE variant: safe reciprocals,
//! row norms, row normalization (the paper's "correlation" option), axpy.

use super::dense::Dense;

/// 1/sqrt(x) with 0 → 0 (zero-degree vertices stay zero everywhere).
#[inline]
pub fn safe_recip_sqrt(x: f64) -> f64 {
    if x > 0.0 {
        1.0 / x.sqrt()
    } else {
        0.0
    }
}

/// 1/x with 0 → 0.
#[inline]
pub fn safe_recip(x: f64) -> f64 {
    if x > 0.0 {
        1.0 / x
    } else {
        0.0
    }
}

/// Elementwise safe inverse sqrt of a degree vector.
pub fn inv_sqrt_vec(d: &[f64]) -> Vec<f64> {
    d.iter().map(|&x| safe_recip_sqrt(x)).collect()
}

/// Euclidean norm of each row of a dense matrix.
pub fn row_norms(m: &Dense) -> Vec<f64> {
    (0..m.nrows)
        .map(|r| m.row(r).iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect()
}

/// In-place row 2-norm normalization — the paper's correlation option.
/// All-zero rows are left untouched (safe division).
pub fn normalize_rows(m: &mut Dense) {
    for r in 0..m.nrows {
        let norm: f64 = m.row(r).iter().map(|x| x * x).sum::<f64>().sqrt();
        let s = safe_recip(norm);
        if s != 0.0 {
            for x in m.row_mut(r) {
                *x *= s;
            }
        }
    }
}

/// y += a * x.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safe_funcs_zero() {
        assert_eq!(safe_recip(0.0), 0.0);
        assert_eq!(safe_recip_sqrt(0.0), 0.0);
        assert_eq!(safe_recip(4.0), 0.25);
        assert_eq!(safe_recip_sqrt(4.0), 0.5);
    }

    #[test]
    fn row_norms_known() {
        let m = Dense::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        assert_eq!(row_norms(&m), vec![5.0, 0.0]);
    }

    #[test]
    fn normalize_rows_unit_or_zero() {
        let mut m = Dense::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        normalize_rows(&mut m);
        assert!((m.get(0, 0) - 0.6).abs() < 1e-12);
        assert!((m.get(0, 1) - 0.8).abs() < 1e-12);
        assert_eq!(m.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn axpy_accumulates() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, vec![10.5, 21.0]);
    }
}
