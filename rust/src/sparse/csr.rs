//! CSR (compressed sparse row) matrix — the paper's compute format.
//!
//! Layout exactly as in the paper's Fig. 1: `indptr` (length nrows+1),
//! `indices` (column ids per entry), `data` (values), entries of row `r`
//! living in `indptr[r]..indptr[r+1]`, sorted by column within each row.
//!
//! Compute kernels implemented here:
//! * `spmm_dense`  — CSR × dense (the `A_s · W` product with W as N×K
//!   dense; the hot path when K is small),
//! * `spmm_csr`    — CSR × CSR via Gustavson's algorithm (the literal
//!   `A_s · W_s` of the paper where W is also sparse),
//! * `spmv`, `row_sums`, `scale_sym`, `add_diag` — the Laplacian /
//!   diagonal-augmentation building blocks.

use super::coo::Coo;
use super::dense::Dense;

/// Compressed-sparse-row matrix, f64 values, u32 column indices.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Empty matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Csr {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: vec![],
            data: vec![],
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        Csr {
            nrows: n,
            ncols: n,
            indptr: (0..=n).collect(),
            indices: (0..n as u32).collect(),
            data: vec![1.0; n],
        }
    }

    /// Diagonal matrix from a vector (zeros skipped).
    pub fn from_diag(diag: &[f64]) -> Self {
        let n = diag.len();
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for (i, &v) in diag.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        Csr { nrows: n, ncols: n, indptr, indices, data }
    }

    /// Build from COO, summing duplicates. Counting sort on rows — O(nnz),
    /// no comparison sort on the full triplet set (the §Perf fast path; see
    /// `from_coo_sorted` for the ablation baseline that assumes presorted
    /// input).
    pub fn from_coo(coo: &Coo) -> Self {
        let nnz = coo.nnz();
        // counting sort by row
        let mut counts = vec![0usize; coo.nrows + 1];
        for &r in &coo.rows {
            counts[r as usize + 1] += 1;
        }
        for i in 0..coo.nrows {
            counts[i + 1] += counts[i];
        }
        let mut col_tmp = vec![0u32; nnz];
        let mut val_tmp = vec![0.0f64; nnz];
        {
            let mut next = counts.clone();
            for i in 0..nnz {
                let r = coo.rows[i] as usize;
                let slot = next[r];
                next[r] += 1;
                col_tmp[slot] = coo.cols[i];
                val_tmp[slot] = coo.vals[i];
            }
        }
        // per-row: sort by column, merge duplicates
        let mut indptr = Vec::with_capacity(coo.nrows + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut data = Vec::with_capacity(nnz);
        indptr.push(0);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for r in 0..coo.nrows {
            let (lo, hi) = (counts[r], counts[r + 1]);
            scratch.clear();
            scratch.extend(
                col_tmp[lo..hi].iter().copied().zip(val_tmp[lo..hi].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in scratch.iter() {
                if let Some(last) = indices.last() {
                    if *last == c && data.len() > indptr[r] {
                        *data.last_mut().unwrap() += v;
                        continue;
                    }
                }
                indices.push(c);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        Csr { nrows: coo.nrows, ncols: coo.ncols, indptr, indices, data }
    }

    /// Build from a COO already sorted by (row, col) with no duplicates —
    /// single O(nnz) pass, zero scratch. Ablation partner of `from_coo`.
    pub fn from_coo_sorted(coo: &Coo) -> Self {
        let mut indptr = Vec::with_capacity(coo.nrows + 1);
        indptr.push(0);
        let mut r = 0usize;
        for (i, &row) in coo.rows.iter().enumerate() {
            debug_assert!(row as usize >= r, "input not row-sorted");
            while r < row as usize {
                indptr.push(i);
                r += 1;
            }
        }
        while r < coo.nrows {
            indptr.push(coo.nnz());
            r += 1;
        }
        Csr {
            nrows: coo.nrows,
            ncols: coo.ncols,
            indptr,
            indices: coo.cols.clone(),
            data: coo.vals.clone(),
        }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Entries of row `r` as (columns, values) slices.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// Random-access read: binary search within the row.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&(c as u32)) {
            Ok(i) => vals[i],
            Err(_) => 0.0,
        }
    }

    /// Row sums (the degree vector when `self` is an adjacency matrix).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.nrows)
            .map(|r| self.row(r).1.iter().sum())
            .collect()
    }

    /// Sparse matrix × dense vector.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        (0..self.nrows)
            .map(|r| {
                let (cols, vals) = self.row(r);
                cols.iter()
                    .zip(vals.iter())
                    .map(|(&c, &v)| v * x[c as usize])
                    .sum()
            })
            .collect()
    }

    /// CSR × dense: (m×n) · (n×k) → dense (m×k). The GEE hot path —
    /// each nonzero touches one k-wide dense row; k is the class count.
    pub fn spmm_dense(&self, b: &Dense) -> Dense {
        assert_eq!(self.ncols, b.nrows);
        let k = b.ncols;
        let mut out = Dense::zeros(self.nrows, k);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let orow = &mut out.data[r * k..(r + 1) * k];
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                let brow = &b.data[c as usize * k..(c as usize + 1) * k];
                for (o, &bb) in orow.iter_mut().zip(brow.iter()) {
                    *o += v * bb;
                }
            }
        }
        out
    }

    /// CSR × CSR via Gustavson: for each row of A, scatter-accumulate the
    /// scaled rows of B into a dense workspace, then gather the nonzeros.
    /// This is what `scipy.sparse.csr_matmat` does under `A_s @ W_s`.
    ///
    /// First-touch detection uses an SMMP-style marker array (`mark[c]`
    /// holds the last row that touched column c) so each nonzero costs
    /// O(1) — a `touched.contains` linear scan here would degrade the
    /// whole product from O(flops) to O(flops · row_nnz) on dense-ish
    /// output rows (see the regression test below).
    pub fn spmm_csr(&self, b: &Csr) -> Csr {
        assert_eq!(self.ncols, b.nrows);
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices: Vec<u32> = Vec::new();
        let mut data: Vec<f64> = Vec::new();
        indptr.push(0);
        let mut acc = vec![0.0f64; b.ncols];
        // usize::MAX: no row has touched this column yet (rows are < nrows)
        let mut mark = vec![usize::MAX; b.ncols];
        let mut touched: Vec<u32> = Vec::new();
        for r in 0..self.nrows {
            let (acols, avals) = self.row(r);
            for (&ac, &av) in acols.iter().zip(avals.iter()) {
                let (bcols, bvals) = b.row(ac as usize);
                for (&bc, &bv) in bcols.iter().zip(bvals.iter()) {
                    if mark[bc as usize] != r {
                        mark[bc as usize] = r;
                        touched.push(bc);
                    }
                    acc[bc as usize] += av * bv;
                }
            }
            touched.sort_unstable();
            for &c in &touched {
                let v = acc[c as usize];
                if v != 0.0 {
                    indices.push(c);
                    data.push(v);
                }
                acc[c as usize] = 0.0;
            }
            touched.clear();
            indptr.push(indices.len());
        }
        Csr { nrows: self.nrows, ncols: b.ncols, indptr, indices, data }
    }

    /// `self + diag(d)` — diagonal augmentation with d=1 everywhere gives
    /// the paper's `A_s + I_s`. Preserves sortedness; O(nnz + n).
    pub fn add_diag(&self, d: &[f64]) -> Csr {
        assert_eq!(self.nrows, self.ncols);
        assert_eq!(d.len(), self.nrows);
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices = Vec::with_capacity(self.nnz() + self.nrows);
        let mut data = Vec::with_capacity(self.nnz() + self.nrows);
        indptr.push(0);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let mut placed = d[r] == 0.0; // nothing to place if zero
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                if !placed && (c as usize) >= r {
                    if c as usize == r {
                        indices.push(c);
                        data.push(v + d[r]);
                        placed = true;
                        continue;
                    } else {
                        indices.push(r as u32);
                        data.push(d[r]);
                        placed = true;
                    }
                }
                indices.push(c);
                data.push(v);
            }
            if !placed {
                indices.push(r as u32);
                data.push(d[r]);
            }
            indptr.push(indices.len());
        }
        Csr { nrows: self.nrows, ncols: self.ncols, indptr, indices, data }
    }

    /// Symmetric diagonal scaling `diag(s) · A · diag(s)` in place —
    /// the Laplacian normalization with `s = d^-1/2`.
    pub fn scale_sym(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.nrows);
        assert_eq!(s.len(), self.ncols);
        for r in 0..self.nrows {
            let sr = s[r];
            let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
            for i in lo..hi {
                self.data[i] *= sr * s[self.indices[i] as usize];
            }
        }
    }

    /// Transpose via counting sort on columns — O(nnz + ncols).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.ncols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0f64; self.nnz()];
        let mut next = counts;
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                let slot = next[c as usize];
                next[c as usize] += 1;
                indices[slot] = r as u32;
                data[slot] = v;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr,
            indices,
            data,
        }
    }

    /// Back to COO (row-sorted).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                coo.push(r as u32, c, v);
            }
        }
        coo
    }

    /// Materialize dense (tests / small baselines).
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.nrows, self.ncols);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                *d.get_mut(r, c as usize) += v;
            }
        }
        d
    }

    /// Bytes of storage held (the paper's CSR-vs-edge-list space argument:
    /// 3E for triplets vs E·(4+8) + (R+1)·8 here).
    pub fn storage_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.data.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The worked example from the paper's Fig. 1.
    /// row_2 has value 2 at col_1 and 3 at col_5.
    fn fig1_matrix() -> Csr {
        let coo = Coo::from_triplets(
            4,
            6,
            &[0, 0, 1, 2, 2, 3],
            &[0, 3, 2, 1, 5, 4],
            &[5.0, 1.0, 4.0, 2.0, 3.0, 6.0],
        );
        Csr::from_coo(&coo)
    }

    #[test]
    fn fig1_row_pointers() {
        let m = fig1_matrix();
        // index_pointers length = R + 1
        assert_eq!(m.indptr.len(), 5);
        // row_2's start/end pointers are 3 and 5 (paper's worked example)
        assert_eq!(m.indptr[2], 3);
        assert_eq!(m.indptr[3], 5);
        let (cols, vals) = m.row(2);
        assert_eq!(cols, &[1, 5]);
        assert_eq!(vals, &[2.0, 3.0]);
    }

    #[test]
    fn from_coo_sums_duplicates() {
        let coo = Coo::from_triplets(2, 2, &[0, 0, 1], &[1, 1, 0], &[2.0, 3.0, 1.0]);
        let m = Csr::from_coo(&coo);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 5.0);
    }

    #[test]
    fn from_coo_sorted_matches_general() {
        let mut rng = Rng::new(5);
        let mut coo = Coo::new(20, 20);
        for _ in 0..100 {
            coo.push(rng.below(20) as u32, rng.below(20) as u32, rng.f64() + 0.1);
        }
        coo.sort_dedup();
        assert_eq!(Csr::from_coo(&coo), Csr::from_coo_sorted(&coo));
    }

    #[test]
    fn spmv_matches_dense() {
        let m = fig1_matrix();
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let y = m.spmv(&x);
        let d = m.to_dense();
        for r in 0..4 {
            let expect: f64 = (0..6).map(|c| d.get(r, c) * x[c]).sum();
            assert!((y[r] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn spmm_dense_matches_dense_matmul() {
        let mut rng = Rng::new(6);
        let mut coo = Coo::new(15, 10);
        for _ in 0..40 {
            coo.push(rng.below(15) as u32, rng.below(10) as u32, rng.f64());
        }
        let a = Csr::from_coo(&coo);
        let b = Dense::from_vec(10, 3, (0..30).map(|i| i as f64 * 0.5).collect());
        let got = a.spmm_dense(&b);
        let expect = a.to_dense().matmul(&b);
        assert!(got.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn spmm_csr_matches_dense_matmul() {
        let mut rng = Rng::new(7);
        let mut ca = Coo::new(12, 9);
        let mut cb = Coo::new(9, 7);
        for _ in 0..30 {
            ca.push(rng.below(12) as u32, rng.below(9) as u32, rng.f64() - 0.5);
            cb.push(rng.below(9) as u32, rng.below(7) as u32, rng.f64() - 0.5);
        }
        let a = Csr::from_coo(&ca);
        let b = Csr::from_coo(&cb);
        let got = a.spmm_csr(&b).to_dense();
        let expect = a.to_dense().matmul(&b.to_dense());
        assert!(got.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn spmm_csr_dense_rows_regression() {
        // A dense row in A times a B with wide rows used to trigger the
        // O(row_nnz) `touched.contains` scan per nonzero; the marker array
        // keeps it O(1). Verify correctness on exactly that shape: row 0
        // of A is fully dense, B has dense-ish rows, so the output row
        // touches every column many times over.
        let n = 64;
        let mut rng = Rng::new(8);
        let mut ca = Coo::new(4, n);
        for c in 0..n {
            ca.push(0, c as u32, rng.f64() + 0.5); // dense row
        }
        ca.push(1, 3, 2.0);
        ca.push(2, 3, -1.0);
        let mut cb = Coo::new(n, 48);
        for r in 0..n {
            for _ in 0..24 {
                cb.push(r as u32, rng.below(48) as u32, rng.f64() - 0.5);
            }
        }
        let a = Csr::from_coo(&ca);
        let b = Csr::from_coo(&cb);
        let got = a.spmm_csr(&b);
        let expect = a.to_dense().matmul(&b.to_dense());
        assert!(got.to_dense().max_abs_diff(&expect) < 1e-9);
        // output columns stay sorted within each row
        for r in 0..got.nrows {
            let (cols, _) = got.row(r);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn spmm_csr_repeated_touches_of_same_column() {
        // many B-rows all hitting the same output column — the marker must
        // record the column exactly once per output row
        let a = Csr::from_coo(&Coo::from_triplets(
            1,
            3,
            &[0, 0, 0],
            &[0, 1, 2],
            &[1.0, 1.0, 1.0],
        ));
        let b = Csr::from_coo(&Coo::from_triplets(
            3,
            2,
            &[0, 1, 2],
            &[1, 1, 1],
            &[2.0, 3.0, 4.0],
        ));
        let z = a.spmm_csr(&b);
        assert_eq!(z.nnz(), 1);
        assert_eq!(z.get(0, 1), 9.0);
    }

    #[test]
    fn add_diag_all_positions() {
        // diagonal before / inside / after existing entries
        let coo = Coo::from_triplets(3, 3, &[0, 1, 2], &[2, 1, 0], &[1.0, 5.0, 2.0]);
        let m = Csr::from_coo(&coo).add_diag(&[1.0, 1.0, 1.0]);
        let d = m.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(0, 2), 1.0);
        assert_eq!(d.get(1, 1), 6.0);
        assert_eq!(d.get(2, 2), 1.0);
        assert_eq!(d.get(2, 0), 2.0);
        // columns stay sorted
        for r in 0..3 {
            let (cols, _) = m.row(r);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn scale_sym_matches_dense() {
        // scale_sym needs a square matrix; build one directly
        let coo = Coo::from_triplets(3, 3, &[0, 1, 2, 2], &[1, 0, 2, 1], &[2.0, 3.0, 4.0, 5.0]);
        let mut m = Csr::from_coo(&coo);
        let s = vec![0.5, 2.0, 1.5];
        let mut dd = m.to_dense();
        m.scale_sym(&s);
        dd.scale_sym(&s);
        assert!(m.to_dense().max_abs_diff(&dd) < 1e-12);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = fig1_matrix();
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn eye_is_identity_under_spmm() {
        let m = fig1_matrix();
        let i6 = Csr::eye(6);
        let prod = m.spmm_csr(&i6);
        assert_eq!(prod.to_dense().data, m.to_dense().data);
    }

    #[test]
    fn storage_bytes_counts() {
        let m = fig1_matrix();
        assert_eq!(m.storage_bytes(), 5 * 8 + 6 * 4 + 6 * 8);
    }
}
