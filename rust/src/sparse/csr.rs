//! CSR (compressed sparse row) matrix — the paper's compute format.
//!
//! Layout exactly as in the paper's Fig. 1: `indptr` (length nrows+1),
//! `indices` (column ids per entry), `data` (values), entries of row `r`
//! living in `indptr[r]..indptr[r+1]`, sorted by column within each row.
//!
//! Both `indptr` and `indices` are **u32** (see [`super::index`]): the
//! compute loops are memory-bandwidth bound, and 32-bit indices halve the
//! index bytes streamed per nonzero. Constructors check the `u32::MAX`
//! entry cap instead of silently truncating.
//!
//! Compute kernels implemented here:
//! * `spmm_dense`  — CSR × dense (the `A_s · W` product with W as N×K
//!   dense; the hot path when K is small),
//! * `spmm_dense_par` — the same product, row-parallel over nnz-balanced
//!   chunks (bitwise-identical to `spmm_dense` for any thread count),
//! * `spmm_csr`    — CSR × CSR via Gustavson's algorithm (the literal
//!   `A_s · W_s` of the paper where W is also sparse),
//! * `spmv`, `row_sums`, `scale_sym`, `add_diag` — the Laplacian /
//!   diagonal-augmentation building blocks.

use std::thread;

use super::coo::Coo;
use super::dense::Dense;
use super::index::to_index;
use super::partition::nnz_chunks;

/// Compressed-sparse-row matrix, f64 values, u32 row pointers and column
/// indices.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Empty matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Csr {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: vec![],
            data: vec![],
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let nu = to_index(n, "rows");
        Csr {
            nrows: n,
            ncols: n,
            indptr: (0..=nu).collect(),
            indices: (0..nu).collect(),
            data: vec![1.0; n],
        }
    }

    /// Diagonal matrix from a vector (zeros skipped).
    pub fn from_diag(diag: &[f64]) -> Self {
        let n = diag.len();
        to_index(n, "rows");
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0u32);
        for (i, &v) in diag.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                data.push(v);
            }
            indptr.push(indices.len() as u32);
        }
        Csr { nrows: n, ncols: n, indptr, indices, data }
    }

    /// Build from COO, summing duplicates. Counting sort on rows — O(nnz),
    /// no comparison sort on the full triplet set (the §Perf fast path; see
    /// `from_coo_sorted` for the ablation baseline that assumes presorted
    /// input).
    pub fn from_coo(coo: &Coo) -> Self {
        let nnz = coo.nnz();
        // fail fast (with context) before any allocation if the entry
        // count cannot be indexed in 32 bits
        to_index(nnz, "stored entries");
        // counting sort by row — u32 counters, the same width the final
        // indptr uses, so the sort streams half the index bytes
        let mut counts = vec![0u32; coo.nrows + 1];
        for &r in &coo.rows {
            counts[r as usize + 1] += 1;
        }
        for i in 0..coo.nrows {
            counts[i + 1] += counts[i];
        }
        let mut col_tmp = vec![0u32; nnz];
        let mut val_tmp = vec![0.0f64; nnz];
        {
            let mut next = counts.clone();
            for i in 0..nnz {
                let r = coo.rows[i] as usize;
                let slot = next[r] as usize;
                next[r] += 1;
                col_tmp[slot] = coo.cols[i];
                val_tmp[slot] = coo.vals[i];
            }
        }
        // per-row: sort by column, merge duplicates
        let mut indptr = Vec::with_capacity(coo.nrows + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut data = Vec::with_capacity(nnz);
        indptr.push(0u32);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for r in 0..coo.nrows {
            let (lo, hi) = (counts[r] as usize, counts[r + 1] as usize);
            scratch.clear();
            scratch.extend(
                col_tmp[lo..hi].iter().copied().zip(val_tmp[lo..hi].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in scratch.iter() {
                if let Some(last) = indices.last() {
                    if *last == c && data.len() > indptr[r] as usize {
                        *data.last_mut().unwrap() += v;
                        continue;
                    }
                }
                indices.push(c);
                data.push(v);
            }
            indptr.push(indices.len() as u32);
        }
        Csr { nrows: coo.nrows, ncols: coo.ncols, indptr, indices, data }
    }

    /// Build from a COO already sorted by (row, col) with no duplicates —
    /// single O(nnz) pass, zero scratch. Ablation partner of `from_coo`.
    pub fn from_coo_sorted(coo: &Coo) -> Self {
        to_index(coo.nnz(), "stored entries");
        let mut indptr = Vec::with_capacity(coo.nrows + 1);
        indptr.push(0u32);
        let mut r = 0usize;
        for (i, &row) in coo.rows.iter().enumerate() {
            debug_assert!(row as usize >= r, "input not row-sorted");
            while r < row as usize {
                indptr.push(i as u32);
                r += 1;
            }
        }
        while r < coo.nrows {
            indptr.push(coo.nnz() as u32);
            r += 1;
        }
        Csr {
            nrows: coo.nrows,
            ncols: coo.ncols,
            indptr,
            indices: coo.cols.clone(),
            data: coo.vals.clone(),
        }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Entries of row `r` as (columns, values) slices.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// Random-access read: binary search within the row.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&(c as u32)) {
            Ok(i) => vals[i],
            Err(_) => 0.0,
        }
    }

    /// Row sums (the degree vector when `self` is an adjacency matrix).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.nrows)
            .map(|r| self.row(r).1.iter().sum())
            .collect()
    }

    /// Sparse matrix × dense vector.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        (0..self.nrows)
            .map(|r| {
                let (cols, vals) = self.row(r);
                cols.iter()
                    .zip(vals.iter())
                    .map(|(&c, &v)| v * x[c as usize])
                    .sum()
            })
            .collect()
    }

    /// CSR × dense: (m×n) · (n×k) → dense (m×k). The GEE hot path —
    /// each nonzero touches one k-wide dense row; k is the class count.
    pub fn spmm_dense(&self, b: &Dense) -> Dense {
        assert_eq!(self.ncols, b.nrows);
        let k = b.ncols;
        let mut out = Dense::zeros(self.nrows, k);
        self.spmm_dense_rows(b, 0, self.nrows, &mut out.data);
        out
    }

    /// Accumulate rows `r0..r1` of the product into `out` (their
    /// contiguous slice of the output buffer). Shared by the serial and
    /// row-parallel SpMM so the two cannot drift.
    fn spmm_dense_rows(&self, b: &Dense, r0: usize, r1: usize, out: &mut [f64]) {
        let k = b.ncols;
        debug_assert_eq!(out.len(), (r1 - r0) * k);
        for r in r0..r1 {
            let (cols, vals) = self.row(r);
            let orow = &mut out[(r - r0) * k..(r - r0 + 1) * k];
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                let brow = &b.data[c as usize * k..(c as usize + 1) * k];
                for (o, &bb) in orow.iter_mut().zip(brow.iter()) {
                    *o += v * bb;
                }
            }
        }
    }

    /// Row-parallel CSR × dense over nnz-balanced row chunks. Each thread
    /// owns a disjoint slice of the output via `split_at_mut` (no locks,
    /// no atomics) and runs the same sequential per-row accumulation as
    /// [`spmm_dense`](Self::spmm_dense), so the result is
    /// **bitwise-identical** to the serial product for any thread count.
    /// `threads == 0` uses the machine's available parallelism.
    pub fn spmm_dense_par(&self, b: &Dense, threads: usize) -> Dense {
        assert_eq!(self.ncols, b.nrows);
        let t = super::partition::resolve_threads(threads).min(self.nrows.max(1));
        if t <= 1 {
            return self.spmm_dense(b);
        }
        let k = b.ncols;
        let mut out = Dense::zeros(self.nrows, k);
        let bounds = nnz_chunks(&self.indptr, t);
        thread::scope(|s| {
            let mut rest: &mut [f64] = &mut out.data;
            for w in bounds.windows(2) {
                let (r0, r1) = (w[0], w[1]);
                let (chunk, next) =
                    std::mem::take(&mut rest).split_at_mut((r1 - r0) * k);
                rest = next;
                if r0 == r1 {
                    continue;
                }
                s.spawn(move || self.spmm_dense_rows(b, r0, r1, chunk));
            }
        });
        out
    }

    /// CSR × CSR via Gustavson: for each row of A, scatter-accumulate the
    /// scaled rows of B into a dense workspace, then gather the nonzeros.
    /// This is what `scipy.sparse.csr_matmat` does under `A_s @ W_s`.
    ///
    /// First-touch detection uses an SMMP-style marker array (`mark[c]`
    /// holds the last row that touched column c) so each nonzero costs
    /// O(1) — a `touched.contains` linear scan here would degrade the
    /// whole product from O(flops) to O(flops · row_nnz) on dense-ish
    /// output rows (see the regression test below).
    pub fn spmm_csr(&self, b: &Csr) -> Csr {
        assert_eq!(self.ncols, b.nrows);
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices: Vec<u32> = Vec::new();
        let mut data: Vec<f64> = Vec::new();
        indptr.push(0u32);
        let mut acc = vec![0.0f64; b.ncols];
        // usize::MAX: no row has touched this column yet (rows are < nrows)
        let mut mark = vec![usize::MAX; b.ncols];
        let mut touched: Vec<u32> = Vec::new();
        for r in 0..self.nrows {
            let (acols, avals) = self.row(r);
            for (&ac, &av) in acols.iter().zip(avals.iter()) {
                let (bcols, bvals) = b.row(ac as usize);
                for (&bc, &bv) in bcols.iter().zip(bvals.iter()) {
                    if mark[bc as usize] != r {
                        mark[bc as usize] = r;
                        touched.push(bc);
                    }
                    acc[bc as usize] += av * bv;
                }
            }
            touched.sort_unstable();
            for &c in &touched {
                let v = acc[c as usize];
                if v != 0.0 {
                    indices.push(c);
                    data.push(v);
                }
                acc[c as usize] = 0.0;
            }
            touched.clear();
            indptr.push(to_index(indices.len(), "stored entries"));
        }
        Csr { nrows: self.nrows, ncols: b.ncols, indptr, indices, data }
    }

    /// `self + diag(d)` — diagonal augmentation with d=1 everywhere gives
    /// the paper's `A_s + I_s`. Preserves sortedness; O(nnz + n).
    pub fn add_diag(&self, d: &[f64]) -> Csr {
        assert_eq!(self.nrows, self.ncols);
        assert_eq!(d.len(), self.nrows);
        to_index(self.nnz() + self.nrows, "stored entries");
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices = Vec::with_capacity(self.nnz() + self.nrows);
        let mut data = Vec::with_capacity(self.nnz() + self.nrows);
        indptr.push(0u32);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let mut placed = d[r] == 0.0; // nothing to place if zero
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                if !placed && (c as usize) >= r {
                    if c as usize == r {
                        indices.push(c);
                        data.push(v + d[r]);
                        placed = true;
                        continue;
                    } else {
                        indices.push(r as u32);
                        data.push(d[r]);
                        placed = true;
                    }
                }
                indices.push(c);
                data.push(v);
            }
            if !placed {
                indices.push(r as u32);
                data.push(d[r]);
            }
            indptr.push(indices.len() as u32);
        }
        Csr { nrows: self.nrows, ncols: self.ncols, indptr, indices, data }
    }

    /// Symmetric diagonal scaling `diag(s) · A · diag(s)` in place —
    /// the Laplacian normalization with `s = d^-1/2`.
    pub fn scale_sym(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.nrows);
        assert_eq!(s.len(), self.ncols);
        for r in 0..self.nrows {
            let sr = s[r];
            let (lo, hi) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            for i in lo..hi {
                self.data[i] *= sr * s[self.indices[i] as usize];
            }
        }
    }

    /// Transpose via counting sort on columns — O(nnz + ncols).
    pub fn transpose(&self) -> Csr {
        to_index(self.nnz(), "stored entries");
        let mut counts = vec![0u32; self.ncols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0f64; self.nnz()];
        let mut next = counts;
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                let slot = next[c as usize] as usize;
                next[c as usize] += 1;
                indices[slot] = r as u32;
                data[slot] = v;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr,
            indices,
            data,
        }
    }

    /// Back to COO (row-sorted).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                coo.push(r as u32, c, v);
            }
        }
        coo
    }

    /// Materialize dense (tests / small baselines).
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.nrows, self.ncols);
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                *d.get_mut(r, c as usize) += v;
            }
        }
        d
    }

    /// Bytes of storage held (the paper's CSR-vs-edge-list space argument,
    /// sharpened by u32 compaction: E·(4+8) + (R+1)·4 here vs 3E·8 for
    /// triplets).
    pub fn storage_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<u32>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.data.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The worked example from the paper's Fig. 1.
    /// row_2 has value 2 at col_1 and 3 at col_5.
    fn fig1_matrix() -> Csr {
        let coo = Coo::from_triplets(
            4,
            6,
            &[0, 0, 1, 2, 2, 3],
            &[0, 3, 2, 1, 5, 4],
            &[5.0, 1.0, 4.0, 2.0, 3.0, 6.0],
        );
        Csr::from_coo(&coo)
    }

    #[test]
    fn fig1_row_pointers() {
        let m = fig1_matrix();
        // index_pointers length = R + 1
        assert_eq!(m.indptr.len(), 5);
        // row_2's start/end pointers are 3 and 5 (paper's worked example)
        assert_eq!(m.indptr[2], 3);
        assert_eq!(m.indptr[3], 5);
        let (cols, vals) = m.row(2);
        assert_eq!(cols, &[1, 5]);
        assert_eq!(vals, &[2.0, 3.0]);
    }

    #[test]
    fn from_coo_sums_duplicates() {
        let coo = Coo::from_triplets(2, 2, &[0, 0, 1], &[1, 1, 0], &[2.0, 3.0, 1.0]);
        let m = Csr::from_coo(&coo);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 5.0);
    }

    #[test]
    fn from_coo_sorted_matches_general() {
        let mut rng = Rng::new(5);
        let mut coo = Coo::new(20, 20);
        for _ in 0..100 {
            coo.push(rng.below(20) as u32, rng.below(20) as u32, rng.f64() + 0.1);
        }
        coo.sort_dedup();
        assert_eq!(Csr::from_coo(&coo), Csr::from_coo_sorted(&coo));
    }

    #[test]
    fn spmv_matches_dense() {
        let m = fig1_matrix();
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let y = m.spmv(&x);
        let d = m.to_dense();
        for r in 0..4 {
            let expect: f64 = (0..6).map(|c| d.get(r, c) * x[c]).sum();
            assert!((y[r] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn spmm_dense_matches_dense_matmul() {
        let mut rng = Rng::new(6);
        let mut coo = Coo::new(15, 10);
        for _ in 0..40 {
            coo.push(rng.below(15) as u32, rng.below(10) as u32, rng.f64());
        }
        let a = Csr::from_coo(&coo);
        let b = Dense::from_vec(10, 3, (0..30).map(|i| i as f64 * 0.5).collect());
        let got = a.spmm_dense(&b);
        let expect = a.to_dense().matmul(&b);
        assert!(got.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn spmm_dense_par_bitwise_matches_serial() {
        let mut rng = Rng::new(9);
        let mut coo = Coo::new(200, 150);
        for _ in 0..3_000 {
            coo.push(rng.below(200) as u32, rng.below(150) as u32, rng.f64() - 0.5);
        }
        let a = Csr::from_coo(&coo);
        let b = Dense::from_vec(
            150,
            4,
            (0..600).map(|i| (i as f64).sin()).collect(),
        );
        let serial = a.spmm_dense(&b);
        for t in [0usize, 1, 2, 3, 8, 64] {
            let par = a.spmm_dense_par(&b, t);
            assert_eq!(par.data, serial.data, "t={t} not bitwise-identical");
        }
    }

    #[test]
    fn spmm_dense_par_degenerate_shapes() {
        // empty matrix
        let a = Csr::zeros(3, 3);
        let b = Dense::zeros(3, 2);
        let z = a.spmm_dense_par(&b, 4);
        assert!(z.data.iter().all(|&x| x == 0.0));
        // single row
        let coo = Coo::from_triplets(1, 2, &[0, 0], &[0, 1], &[1.0, 2.0]);
        let a = Csr::from_coo(&coo);
        let b = Dense::from_vec(2, 1, vec![3.0, 4.0]);
        let z = a.spmm_dense_par(&b, 8);
        assert_eq!(z.data, vec![11.0]);
    }

    #[test]
    fn spmm_csr_matches_dense_matmul() {
        let mut rng = Rng::new(7);
        let mut ca = Coo::new(12, 9);
        let mut cb = Coo::new(9, 7);
        for _ in 0..30 {
            ca.push(rng.below(12) as u32, rng.below(9) as u32, rng.f64() - 0.5);
            cb.push(rng.below(9) as u32, rng.below(7) as u32, rng.f64() - 0.5);
        }
        let a = Csr::from_coo(&ca);
        let b = Csr::from_coo(&cb);
        let got = a.spmm_csr(&b).to_dense();
        let expect = a.to_dense().matmul(&b.to_dense());
        assert!(got.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn spmm_csr_dense_rows_regression() {
        // A dense row in A times a B with wide rows used to trigger the
        // O(row_nnz) `touched.contains` scan per nonzero; the marker array
        // keeps it O(1). Verify correctness on exactly that shape: row 0
        // of A is fully dense, B has dense-ish rows, so the output row
        // touches every column many times over.
        let n = 64;
        let mut rng = Rng::new(8);
        let mut ca = Coo::new(4, n);
        for c in 0..n {
            ca.push(0, c as u32, rng.f64() + 0.5); // dense row
        }
        ca.push(1, 3, 2.0);
        ca.push(2, 3, -1.0);
        let mut cb = Coo::new(n, 48);
        for r in 0..n {
            for _ in 0..24 {
                cb.push(r as u32, rng.below(48) as u32, rng.f64() - 0.5);
            }
        }
        let a = Csr::from_coo(&ca);
        let b = Csr::from_coo(&cb);
        let got = a.spmm_csr(&b);
        let expect = a.to_dense().matmul(&b.to_dense());
        assert!(got.to_dense().max_abs_diff(&expect) < 1e-9);
        // output columns stay sorted within each row
        for r in 0..got.nrows {
            let (cols, _) = got.row(r);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn spmm_csr_repeated_touches_of_same_column() {
        // many B-rows all hitting the same output column — the marker must
        // record the column exactly once per output row
        let a = Csr::from_coo(&Coo::from_triplets(
            1,
            3,
            &[0, 0, 0],
            &[0, 1, 2],
            &[1.0, 1.0, 1.0],
        ));
        let b = Csr::from_coo(&Coo::from_triplets(
            3,
            2,
            &[0, 1, 2],
            &[1, 1, 1],
            &[2.0, 3.0, 4.0],
        ));
        let z = a.spmm_csr(&b);
        assert_eq!(z.nnz(), 1);
        assert_eq!(z.get(0, 1), 9.0);
    }

    #[test]
    fn add_diag_all_positions() {
        // diagonal before / inside / after existing entries
        let coo = Coo::from_triplets(3, 3, &[0, 1, 2], &[2, 1, 0], &[1.0, 5.0, 2.0]);
        let m = Csr::from_coo(&coo).add_diag(&[1.0, 1.0, 1.0]);
        let d = m.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(0, 2), 1.0);
        assert_eq!(d.get(1, 1), 6.0);
        assert_eq!(d.get(2, 2), 1.0);
        assert_eq!(d.get(2, 0), 2.0);
        // columns stay sorted
        for r in 0..3 {
            let (cols, _) = m.row(r);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn scale_sym_matches_dense() {
        // scale_sym needs a square matrix; build one directly
        let coo = Coo::from_triplets(3, 3, &[0, 1, 2, 2], &[1, 0, 2, 1], &[2.0, 3.0, 4.0, 5.0]);
        let mut m = Csr::from_coo(&coo);
        let s = vec![0.5, 2.0, 1.5];
        let mut dd = m.to_dense();
        m.scale_sym(&s);
        dd.scale_sym(&s);
        assert!(m.to_dense().max_abs_diff(&dd) < 1e-12);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = fig1_matrix();
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn eye_is_identity_under_spmm() {
        let m = fig1_matrix();
        let i6 = Csr::eye(6);
        let prod = m.spmm_csr(&i6);
        assert_eq!(prod.to_dense().data, m.to_dense().data);
    }

    #[test]
    fn storage_bytes_counts() {
        // u32 row pointers: (R+1)·4 + E·4 + E·8
        let m = fig1_matrix();
        assert_eq!(m.storage_bytes(), 5 * 4 + 6 * 4 + 6 * 8);
    }
}
