//! u32 index compaction — the §Perf memory-traffic half of the sparse
//! story.
//!
//! Every CSR row pointer and column index in the crate is stored as
//! `u32`, not `usize`: the embed and SpMM loops are memory-bandwidth
//! bound (Edge-Parallel GEE, arXiv:2402.04403), so halving index width
//! halves the index bytes streamed per nonzero. The trade is a hard cap
//! of `u32::MAX` vertices / stored entries per matrix — far beyond any
//! target graph (the paper's largest real dataset is ~5M edges) but
//! checked, never assumed:
//!
//! * [`try_index`] is the fallible conversion for API boundaries (the
//!   engine front-end rejects oversize graphs with a real error);
//! * [`to_index`] is the infallible-by-contract conversion used inside
//!   constructors that run after the boundary check — it still panics
//!   with a descriptive message rather than silently truncating.

use std::fmt;

/// Largest vertex count / entry count a u32-indexed structure can hold.
pub const MAX_INDEX: usize = u32::MAX as usize;

/// A graph or matrix dimension exceeded the u32 index space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexOverflow {
    /// What overflowed ("vertices", "stored entries", ...).
    pub what: &'static str,
    /// The offending value.
    pub value: usize,
}

impl fmt::Display for IndexOverflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} count {} exceeds the u32 index space ({}); \
             this build compacts all sparse indices to 32 bits",
            self.what, self.value, MAX_INDEX
        )
    }
}

impl std::error::Error for IndexOverflow {}

/// Checked `usize -> u32` for index values. Errors instead of truncating.
#[inline]
pub fn try_index(value: usize, what: &'static str) -> Result<u32, IndexOverflow> {
    u32::try_from(value).map_err(|_| IndexOverflow { what, value })
}

/// `usize -> u32` that panics with a descriptive message on overflow.
/// Used inside constructors; API boundaries use [`try_index`] first.
#[inline]
pub fn to_index(value: usize, what: &'static str) -> u32 {
    match try_index(value, what) {
        Ok(v) => v,
        Err(e) => panic!("{e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_convert() {
        assert_eq!(try_index(0, "x"), Ok(0));
        assert_eq!(try_index(MAX_INDEX, "x"), Ok(u32::MAX));
        assert_eq!(to_index(7, "x"), 7);
    }

    #[test]
    fn overflow_is_an_error_with_context() {
        let e = try_index(MAX_INDEX + 1, "vertices").unwrap_err();
        assert_eq!(e.what, "vertices");
        assert_eq!(e.value, MAX_INDEX + 1);
        assert!(e.to_string().contains("vertices"));
        assert!(e.to_string().contains("u32"));
    }

    #[test]
    #[should_panic(expected = "stored entries")]
    fn to_index_panics_with_message() {
        to_index(MAX_INDEX + 1, "stored entries");
    }
}
