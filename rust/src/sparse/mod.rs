//! Sparse-matrix substrate: the data structures the paper's method is
//! built from, implemented from scratch (no scipy on this side of the
//! fence).
//!
//! * [`coo::Coo`] — triplet / edge-list format (construction, I/O)
//! * [`dok::Dok`] — dictionary-of-keys (random-access construction; the
//!   paper builds W and the diagonal matrices in DOK, then converts)
//! * [`csr::Csr`] — compressed sparse row (all compute: SpMV, SpMM,
//!   diagonal add, symmetric scaling, transpose), u32-compacted indices
//! * [`dense::Dense`] — dense baseline substrate + embedding container
//! * [`ops`] — shared row/vector kernels (norms, safe division, axpy)
//! * [`index`] — checked usize→u32 index conversion (the compaction cap)
//! * [`partition`] — nnz-balanced row chunking for row-parallel kernels

pub mod coo;
pub mod csr;
pub mod dense;
pub mod dok;
pub mod index;
pub mod ops;
pub mod partition;

pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
pub use dok::Dok;
pub use index::{IndexOverflow, MAX_INDEX};
