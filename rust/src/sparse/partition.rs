//! Row-range partitioning shared by every row-parallel kernel: the
//! nnz-balanced contiguous chunking introduced for the row-parallel GEE
//! engine (`gee::parallel`), reused by `Csr::spmm_dense_par` and the
//! parallel count-merge. Balancing by nonzero count (not row count)
//! keeps skewed-degree graphs (Chung-Lu hubs) from serializing on one
//! thread; a hub row cannot be split, only isolated in its own chunk.

/// Resolve a requested worker-thread count against the machine: `0`
/// means "use all available parallelism", explicit requests are capped
/// at the core count (more threads never help these memory-bound
/// kernels, and the cap bounds oversubscription when several service
/// workers run intra-op embeds concurrently). One policy, shared by
/// every parallel lane.
pub fn resolve_threads(requested: usize) -> usize {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if requested > 0 {
        requested.min(avail)
    } else {
        avail
    }
}

/// Pick `chunks` contiguous row ranges with roughly equal nonzero counts.
/// Returns `chunks + 1` non-decreasing boundaries from 0 to n.
/// `indptr` is a CSR row-pointer array (length n+1, u32-compacted).
pub fn nnz_chunks(indptr: &[u32], chunks: usize) -> Vec<usize> {
    let n = indptr.len() - 1;
    let total = indptr[n] as usize;
    let chunks = chunks.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(chunks + 1);
    bounds.push(0usize);
    for i in 1..chunks {
        let target = (total as u128 * i as u128 / chunks as u128) as usize;
        let mut r = *bounds.last().unwrap();
        while r < n && (indptr[r] as usize) < target {
            r += 1;
        }
        bounds.push(r);
    }
    bounds.push(n);
    bounds
}

/// u64 twin of [`nnz_chunks`] for cost prefixes that may exceed the u32
/// index space — the sharded engine plans vertex-range shards over the
/// *global* directed-edge counts, which are allowed to overflow u32 (the
/// whole point of sharding is that only each shard's slice must fit).
pub fn nnz_chunks_u64(prefix: &[u64], chunks: usize) -> Vec<usize> {
    let n = prefix.len() - 1;
    let total = prefix[n];
    let chunks = chunks.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(chunks + 1);
    bounds.push(0usize);
    for i in 1..chunks {
        let target = (total as u128 * i as u128 / chunks as u128) as u64;
        let mut r = *bounds.last().unwrap();
        while r < n && prefix[r] < target {
            r += 1;
        }
        bounds.push(r);
    }
    bounds.push(n);
    bounds
}

/// Split `0..n` into `chunks` contiguous ranges of near-equal length.
/// Returns `chunks + 1` boundaries (used for vertex-range splits where
/// every element costs the same, e.g. the parallel count-merge).
pub fn even_chunks(n: usize, chunks: usize) -> Vec<usize> {
    let chunks = chunks.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(chunks + 1);
    for i in 0..=chunks {
        bounds.push(n * i / chunks);
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_threads_policy() {
        assert!(resolve_threads(0) >= 1);
        assert!((1..=3).contains(&resolve_threads(3)));
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(resolve_threads(usize::MAX) <= avail);
        assert_eq!(resolve_threads(1), 1);
    }

    #[test]
    fn nnz_chunks_cover_range() {
        // 6 rows with nnz 0,10,0,1,1,0 -> indptr
        let indptr: Vec<u32> = vec![0, 0, 10, 10, 11, 12, 12];
        let b = nnz_chunks(&indptr, 3);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&6));
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn nnz_chunks_more_chunks_than_rows() {
        let indptr: Vec<u32> = vec![0, 1, 2];
        let b = nnz_chunks(&indptr, 16);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&2));
    }

    #[test]
    fn nnz_chunks_empty_matrix() {
        let indptr: Vec<u32> = vec![0];
        assert_eq!(nnz_chunks(&indptr, 4), vec![0, 0]);
    }

    #[test]
    fn nnz_chunks_u64_matches_u32_twin_and_handles_big_totals() {
        let indptr32: Vec<u32> = vec![0, 0, 10, 10, 11, 12, 12];
        let prefix: Vec<u64> = indptr32.iter().map(|&x| x as u64).collect();
        assert_eq!(nnz_chunks_u64(&prefix, 3), nnz_chunks(&indptr32, 3));
        // totals beyond u32: two vertices each carrying 3B directed edges
        let big: Vec<u64> = vec![0, 3_000_000_000, 6_000_000_000];
        let b = nnz_chunks_u64(&big, 2);
        assert_eq!(b, vec![0, 1, 2]);
        assert_eq!(nnz_chunks_u64(&[0], 4), vec![0, 0]);
    }

    #[test]
    fn even_chunks_cover_and_balance() {
        let b = even_chunks(10, 3);
        assert_eq!(b, vec![0, 3, 6, 10]);
        assert_eq!(even_chunks(2, 8), vec![0, 1, 2]);
        assert_eq!(even_chunks(0, 4), vec![0, 0]);
    }
}
