//! Row-range partitioning shared by every row-parallel kernel: the
//! nnz-balanced contiguous chunking introduced for the row-parallel GEE
//! engine (`gee::parallel`), reused by `Csr::spmm_dense_par` and the
//! parallel count-merge. Balancing by nonzero count (not row count)
//! keeps skewed-degree graphs (Chung-Lu hubs) from serializing on one
//! thread.
//!
//! Hub rows get a second mechanism: a row whose nnz exceeds
//! [`HUB_SEGMENT_NNZ`] is *split* into fixed-order column segments
//! ([`hub_segments`]/[`segment_range`]). The segment grid depends only on
//! the row's nnz — never on the thread count — so every engine (serial
//! included) computes a hub row as the same ordered sequence of segment
//! partials, and a parallel lane may fan the segments across threads
//! while staying bitwise-identical to the serial kernel (Edge-Parallel
//! GEE, arXiv:2402.04403, is the motivating workload: one mega-vertex
//! must not serialize a chunk or a shard).

/// Resolve a requested worker-thread count against the machine: `0`
/// means "use all available parallelism", explicit requests are capped
/// at the core count (more threads never help these memory-bound
/// kernels, and the cap bounds oversubscription when several service
/// workers run intra-op embeds concurrently). One policy, shared by
/// every parallel lane.
pub fn resolve_threads(requested: usize) -> usize {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if requested > 0 {
        requested.min(avail)
    } else {
        avail
    }
}

/// Pick `chunks` contiguous row ranges with roughly equal nonzero counts.
/// Returns `chunks + 1` strictly increasing boundaries from 0 to n (no
/// chunk is ever empty once `chunks <= n`): when one hub row's nnz spans
/// several balance targets, the scan used to park consecutive boundaries
/// on the same row — one chunk held nearly all work while its neighbors
/// held none. Each boundary now advances at least one row past the
/// previous one and leaves at least one row for every remaining chunk.
/// `indptr` is a CSR row-pointer array (length n+1, u32-compacted).
pub fn nnz_chunks(indptr: &[u32], chunks: usize) -> Vec<usize> {
    let n = indptr.len() - 1;
    let total = indptr[n] as usize;
    let chunks = chunks.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(chunks + 1);
    bounds.push(0usize);
    for i in 1..chunks {
        let target = (total as u128 * i as u128 / chunks as u128) as usize;
        let mut r = *bounds.last().unwrap() + 1;
        while r < n && (indptr[r] as usize) < target {
            r += 1;
        }
        bounds.push(r.min(n - (chunks - i)));
    }
    bounds.push(n);
    bounds
}

/// u64 twin of [`nnz_chunks`] for cost prefixes that may exceed the u32
/// index space — the sharded engine plans vertex-range shards over the
/// *global* directed-edge counts, which are allowed to overflow u32 (the
/// whole point of sharding is that only each shard's slice must fit).
pub fn nnz_chunks_u64(prefix: &[u64], chunks: usize) -> Vec<usize> {
    let n = prefix.len() - 1;
    let total = prefix[n];
    let chunks = chunks.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(chunks + 1);
    bounds.push(0usize);
    for i in 1..chunks {
        let target = (total as u128 * i as u128 / chunks as u128) as u64;
        let mut r = *bounds.last().unwrap() + 1;
        while r < n && prefix[r] < target {
            r += 1;
        }
        bounds.push(r.min(n - (chunks - i)));
    }
    bounds.push(n);
    bounds
}

/// Nonzeros per hub-row segment. A row with more than this many stored
/// entries is accumulated as a fixed sequence of segment partials merged
/// in order (see the module docs); rows at or under it take the straight
/// single-pass path. The value is a *numerics contract*, not a tuning
/// knob: changing it changes which rows are segmented and therefore the
/// exact floating-point sums every engine produces.
pub const HUB_SEGMENT_NNZ: usize = 8_192;

/// Number of fixed-order segments a row of `nnz` stored entries is
/// computed in: 1 below the hub threshold, `ceil(nnz / HUB_SEGMENT_NNZ)`
/// above it. A pure function of nnz so serial and parallel lanes agree.
pub fn hub_segments(nnz: usize) -> usize {
    if nnz <= HUB_SEGMENT_NNZ {
        1
    } else {
        (nnz + HUB_SEGMENT_NNZ - 1) / HUB_SEGMENT_NNZ
    }
}

/// Half-open sub-range (relative to the row's nonzero slice) covered by
/// segment `i` of `segs` — near-equal sizes, deterministic in
/// `(nnz, segs)` alone.
pub fn segment_range(nnz: usize, segs: usize, i: usize) -> (usize, usize) {
    (nnz * i / segs, nnz * (i + 1) / segs)
}

/// Split `0..n` into `chunks` contiguous ranges of near-equal length.
/// Returns `chunks + 1` boundaries (used for vertex-range splits where
/// every element costs the same, e.g. the parallel count-merge).
pub fn even_chunks(n: usize, chunks: usize) -> Vec<usize> {
    let chunks = chunks.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(chunks + 1);
    for i in 0..=chunks {
        bounds.push(n * i / chunks);
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_threads_policy() {
        assert!(resolve_threads(0) >= 1);
        assert!((1..=3).contains(&resolve_threads(3)));
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(resolve_threads(usize::MAX) <= avail);
        assert_eq!(resolve_threads(1), 1);
    }

    #[test]
    fn nnz_chunks_cover_range() {
        // 6 rows with nnz 0,10,0,1,1,0 -> indptr
        let indptr: Vec<u32> = vec![0, 0, 10, 10, 11, 12, 12];
        let b = nnz_chunks(&indptr, 3);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&6));
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn nnz_chunks_more_chunks_than_rows() {
        let indptr: Vec<u32> = vec![0, 1, 2];
        let b = nnz_chunks(&indptr, 16);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&2));
    }

    #[test]
    fn nnz_chunks_empty_matrix() {
        let indptr: Vec<u32> = vec![0];
        assert_eq!(nnz_chunks(&indptr, 4), vec![0, 0]);
    }

    #[test]
    fn nnz_chunks_u64_matches_u32_twin_and_handles_big_totals() {
        let indptr32: Vec<u32> = vec![0, 0, 10, 10, 11, 12, 12];
        let prefix: Vec<u64> = indptr32.iter().map(|&x| x as u64).collect();
        assert_eq!(nnz_chunks_u64(&prefix, 3), nnz_chunks(&indptr32, 3));
        // totals beyond u32: two vertices each carrying 3B directed edges
        let big: Vec<u64> = vec![0, 3_000_000_000, 6_000_000_000];
        let b = nnz_chunks_u64(&big, 2);
        assert_eq!(b, vec![0, 1, 2]);
        assert_eq!(nnz_chunks_u64(&[0], 4), vec![0, 0]);
    }

    #[test]
    fn nnz_chunks_skewed_hub_prefix_has_no_empty_chunks() {
        // 5 rows, one hub carrying ~92% of the nnz. The old scan parked
        // boundaries 2 and 3 on the hub's end row, leaving empty chunks
        // ([0, 2, 2, 2, 5]); boundaries must now be strictly increasing.
        let indptr: Vec<u32> = vec![0, 1, 101, 104, 107, 110];
        let b = nnz_chunks(&indptr, 4);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&5));
        assert!(b.windows(2).all(|w| w[0] < w[1]), "empty chunk in {b:?}");
        let prefix: Vec<u64> = indptr.iter().map(|&x| x as u64).collect();
        assert_eq!(nnz_chunks_u64(&prefix, 4), b, "u64 twin drifted");
        // a hub that spans every balance target, at each chunk count
        let hubby: Vec<u32> = vec![0, 0, 1000, 1000, 1001, 1002, 1002];
        for chunks in 2..=6 {
            let b = nnz_chunks(&hubby, chunks);
            assert_eq!(b.len(), chunks + 1, "chunks={chunks}: {b:?}");
            assert!(
                b.windows(2).all(|w| w[0] < w[1]),
                "chunks={chunks}: empty chunk in {b:?}"
            );
            assert_eq!(b.last(), Some(&6));
        }
    }

    #[test]
    fn hub_segments_and_ranges_cover_exactly() {
        assert_eq!(hub_segments(0), 1);
        assert_eq!(hub_segments(HUB_SEGMENT_NNZ), 1);
        assert_eq!(hub_segments(HUB_SEGMENT_NNZ + 1), 2);
        assert_eq!(hub_segments(3 * HUB_SEGMENT_NNZ), 3);
        for nnz in [
            HUB_SEGMENT_NNZ + 1,
            2 * HUB_SEGMENT_NNZ + 77,
            5 * HUB_SEGMENT_NNZ,
        ] {
            let segs = hub_segments(nnz);
            let mut prev = 0usize;
            for i in 0..segs {
                let (a, b) = segment_range(nnz, segs, i);
                assert_eq!(a, prev, "gap at segment {i} of {segs} (nnz={nnz})");
                assert!(b > a, "empty segment {i} of {segs} (nnz={nnz})");
                assert!(b - a <= HUB_SEGMENT_NNZ + segs, "oversized segment");
                prev = b;
            }
            assert_eq!(prev, nnz, "segments must cover the row");
        }
    }

    #[test]
    fn even_chunks_cover_and_balance() {
        let b = even_chunks(10, 3);
        assert_eq!(b, vec![0, 3, 6, 10]);
        assert_eq!(even_chunks(2, 8), vec![0, 1, 2]);
        assert_eq!(even_chunks(0, 4), vec![0, 0]);
    }
}
