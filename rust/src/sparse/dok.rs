//! DOK (dictionary of keys) sparse matrix — the paper's construction
//! format: O(1) random insert/accumulate while building intermediate
//! matrices (W, the degree diagonal), then converted to CSR for compute.
//! Mirrors `scipy.sparse.dok_matrix` usage in the reference implementation.

use std::collections::HashMap;

use super::coo::Coo;
use super::csr::Csr;

/// Dictionary-of-keys sparse matrix.
#[derive(Clone, Debug, Default)]
pub struct Dok {
    pub nrows: usize,
    pub ncols: usize,
    map: HashMap<(u32, u32), f64>,
}

impl Dok {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Dok { nrows, ncols, map: HashMap::new() }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, nnz: usize) -> Self {
        Dok { nrows, ncols, map: HashMap::with_capacity(nnz) }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.map.len()
    }

    /// Set entry (r, c) to `val` (overwrites).
    #[inline]
    pub fn set(&mut self, r: u32, c: u32, val: f64) {
        debug_assert!((r as usize) < self.nrows && (c as usize) < self.ncols);
        if val == 0.0 {
            self.map.remove(&(r, c));
        } else {
            self.map.insert((r, c), val);
        }
    }

    /// Accumulate into entry (r, c).
    #[inline]
    pub fn add(&mut self, r: u32, c: u32, val: f64) {
        debug_assert!((r as usize) < self.nrows && (c as usize) < self.ncols);
        *self.map.entry((r, c)).or_insert(0.0) += val;
    }

    /// Read entry (zero when absent).
    #[inline]
    pub fn get(&self, r: u32, c: u32) -> f64 {
        self.map.get(&(r, c)).copied().unwrap_or(0.0)
    }

    /// Convert to COO (entry order unspecified).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for (&(r, c), &v) in &self.map {
            if v != 0.0 {
                coo.push(r, c, v);
            }
        }
        coo
    }

    /// Convert to CSR — the DOK→CSR step the paper's pipeline performs
    /// before every compute phase.
    pub fn to_csr(&self) -> Csr {
        Csr::from_coo(&self.to_coo())
    }

    /// Build a diagonal DOK from a vector (degree / identity matrices).
    pub fn from_diag(diag: &[f64]) -> Self {
        let n = diag.len();
        let mut d = Dok::with_capacity(n, n, n);
        for (i, &v) in diag.iter().enumerate() {
            if v != 0.0 {
                d.set(i as u32, i as u32, v);
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_add() {
        let mut d = Dok::new(3, 3);
        d.set(0, 1, 2.0);
        d.add(0, 1, 3.0);
        assert_eq!(d.get(0, 1), 5.0);
        assert_eq!(d.get(2, 2), 0.0);
        assert_eq!(d.nnz(), 1);
    }

    #[test]
    fn set_zero_removes() {
        let mut d = Dok::new(2, 2);
        d.set(1, 1, 4.0);
        d.set(1, 1, 0.0);
        assert_eq!(d.nnz(), 0);
    }

    #[test]
    fn to_csr_roundtrip_values() {
        let mut d = Dok::new(3, 4);
        d.set(2, 0, 3.0);
        d.set(0, 3, 2.0);
        d.set(0, 1, 1.0);
        let csr = d.to_csr();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.get(2, 0), 3.0);
        assert_eq!(csr.get(0, 3), 2.0);
        assert_eq!(csr.get(0, 1), 1.0);
        assert_eq!(csr.get(1, 1), 0.0);
    }

    #[test]
    fn from_diag_skips_zeros() {
        let d = Dok::from_diag(&[1.0, 0.0, 3.0]);
        assert_eq!(d.nnz(), 2);
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(1, 1), 0.0);
        assert_eq!(d.get(2, 2), 3.0);
    }
}
