//! Dense row-major f64 matrix — the baseline substrate the paper's
//! "original GEE" comparisons run on, plus the output container for
//! embeddings (Z is N×K with small K, effectively dense).

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<f64>,
}

impl Dense {
    /// All-zero matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Dense { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Dense::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// From a row-major data vec.
    pub fn from_vec(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols);
        Dense { nrows, ncols, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.ncols + c]
    }

    #[inline]
    pub fn get_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.ncols + c]
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.ncols..(r + 1) * self.ncols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.ncols..(r + 1) * self.ncols]
    }

    /// Dense matmul: self (m×n) · other (n×p) → (m×p). ikj loop order for
    /// cache-friendly access to both operands.
    pub fn matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.ncols, other.nrows);
        let (m, n, p) = (self.nrows, self.ncols, other.ncols);
        let mut out = Dense::zeros(m, p);
        for i in 0..m {
            for kk in 0..n {
                let a = self.data[i * n + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * p..(kk + 1) * p];
                let orow = &mut out.data[i * p..(i + 1) * p];
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// self += other (elementwise).
    pub fn add_assign(&mut self, other: &Dense) {
        assert_eq!(self.nrows, other.nrows);
        assert_eq!(self.ncols, other.ncols);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// Add the identity in place (square only) — diagonal augmentation.
    pub fn add_eye(&mut self) {
        assert_eq!(self.nrows, self.ncols);
        for i in 0..self.nrows {
            self.data[i * self.ncols + i] += 1.0;
        }
    }

    /// Row sums (degrees for adjacency).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.nrows)
            .map(|r| self.row(r).iter().sum())
            .collect()
    }

    /// Scale row r by s[r] and column c by s[c]: `diag(s) · A · diag(s)`.
    pub fn scale_sym(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.nrows);
        assert_eq!(s.len(), self.ncols);
        for r in 0..self.nrows {
            let sr = s[r];
            for c in 0..self.ncols {
                self.data[r * self.ncols + c] *= sr * s[c];
            }
        }
    }

    /// Max |a - b| over all entries.
    pub fn max_abs_diff(&self, other: &Dense) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_matmul_is_identity_op() {
        let a = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Dense::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known_product() {
        let a = Dense::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Dense::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn add_eye_and_row_sums() {
        let mut a = Dense::zeros(3, 3);
        a.add_eye();
        assert_eq!(a.row_sums(), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn scale_sym_matches_diag_products() {
        let mut a = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        a.scale_sym(&[2.0, 0.5]);
        assert_eq!(a.data, vec![4.0, 2.0, 3.0, 1.0]);
    }

    #[test]
    fn max_abs_diff() {
        let a = Dense::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Dense::from_vec(1, 2, vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
