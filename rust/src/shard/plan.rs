//! Phase 1 of the sharded pipeline: one streaming pass over the edge
//! list computes every *global* quantity a shard needs — weighted
//! degrees, per-vertex directed-slot counts, and (from the labels) the
//! `1/n_k` weight vector — then vertices are partitioned into contiguous
//! nnz-balanced ranges. This is what makes sharding **exact**: a GEE row
//! depends only on these globals plus the row's incident edges, so shard
//! outputs concatenate into the whole-graph answer with no correction
//! pass (cf. One-Hot GEE, arXiv:2109.13098, whose billions-of-edges
//! claim rests on the same per-row independence).
//!
//! The accumulator is streaming on purpose: [`GlobalPass::observe`] holds
//! O(vertices) state, never the edges, so the same phase 1 serves the
//! in-memory engine and the out-of-core lane reading a file larger than
//! RAM. The distributed fleet rests on the same split: the driver runs
//! phase 1 once and ships `deg` (shortest-roundtrip text) with each
//! shard, and a remote worker re-derives the scale through
//! [`scale_from_deg`] — one formula, one implementation, whichever
//! machine runs it.

use crate::gee::options::GeeOptions;
use crate::gee::weights::weight_values;
use crate::graph::Graph;
use crate::sparse::ops::safe_recip_sqrt;
use crate::sparse::partition::{nnz_chunks_u64, resolve_threads, HUB_SEGMENT_NNZ};
use crate::sparse::MAX_INDEX;

/// Everything phase 2 needs, computed once in phase 1.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub n: usize,
    pub k: usize,
    /// Shard vertex boundaries (length shards + 1, non-decreasing,
    /// `bounds[0] == 0`, `bounds[last] == n`), balanced by directed
    /// incident-slot count so hub-heavy ranges stay narrow.
    pub bounds: Vec<usize>,
    /// Global weighted degrees (length n), accumulated in edge order —
    /// bitwise-identical to `Graph::degrees` / `prepare_into`.
    pub deg: Vec<f64>,
    /// Global per-vertex `1/n_{y_j}` weights (length n).
    pub wv: Vec<f64>,
    /// Total directed slots (2·proper + self loops) as u64 — allowed to
    /// exceed the u32 index space; only per-shard slices must fit.
    pub directed: u64,
    /// Shards (ascending indices) containing at least one hub vertex —
    /// one whose directed-slot count exceeds
    /// [`HUB_SEGMENT_NNZ`]. `nnz_chunks_u64` can only *isolate* such a
    /// vertex, never split it, so these shards are the ones whose wall
    /// clock one mega-vertex dominates; the in-process engine runs them
    /// thread-parallel through `local::embed_shard_par` instead of
    /// packing them into the round-robin shard assignment.
    pub hub_shards: Vec<usize>,
}

impl ShardPlan {
    /// Phase 1 over an in-memory graph.
    pub fn from_graph(g: &Graph, shards: usize) -> ShardPlan {
        let mut pass = GlobalPass::new(g.n);
        for i in 0..g.num_edges() {
            pass.observe(g.src[i], g.dst[i], g.w[i]);
        }
        pass.finish(&g.labels, g.k, shards)
    }

    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Vertex range `[v0, v1)` of shard `s`.
    pub fn shard_range(&self, s: usize) -> (usize, usize) {
        (self.bounds[s], self.bounds[s + 1])
    }

    /// Which shard owns vertex `v` (binary search over the boundaries;
    /// empty shards are skipped by construction).
    pub fn shard_of(&self, v: usize) -> usize {
        self.bounds.partition_point(|&b| b <= v) - 1
    }

    /// The Laplacian scale vector `(deg + diag)^-1/2` for these options,
    /// or `None` when laplacian is off. Element-wise over the global
    /// degrees, so bitwise-identical to the fused engine's scale.
    pub fn scale_for(&self, opts: &GeeOptions) -> Option<Vec<f64>> {
        scale_from_deg(&self.deg, opts)
    }
}

/// The Laplacian scale formula, standalone: the shard-worker process
/// recomputes the scale from the shipped degree file through this same
/// function, so the cross-process bitwise contract rests on exactly one
/// implementation.
pub fn scale_from_deg(deg: &[f64], opts: &GeeOptions) -> Option<Vec<f64>> {
    if !opts.laplacian {
        return None;
    }
    let bump = if opts.diagonal { 1.0 } else { 0.0 };
    Some(deg.iter().map(|&d| safe_recip_sqrt(d + bump)).collect())
}

/// Resolve a requested shard count: `0` means one per available core
/// (the in-process sweet spot); any request is raised to keep every
/// shard's directed-slot count safely inside the u32 index space (the
/// *reason* oversize graphs route here), and capped at one shard per
/// vertex.
pub fn resolve_shards(requested: usize, n: usize, directed: u64) -> usize {
    let base = if requested == 0 { resolve_threads(0) } else { requested };
    // headroom factor 4 over perfect balance: nnz_chunks cannot split a
    // single vertex's slots, so a hub can push one shard past the ideal
    // share — target MAX_INDEX/4 per shard so even a shard that doubles
    // its share stays within the exact u32 check in `local::embed_shard`
    let quarter = (MAX_INDEX / 4).max(1) as u64;
    let min_for_u32 = ((directed + quarter - 1) / quarter) as usize;
    base.max(min_for_u32).max(1).min(n.max(1))
}

/// Streaming phase-1 accumulator: O(n) state, one `observe` per stored
/// (undirected) edge, in storage order.
#[derive(Clone, Debug)]
pub struct GlobalPass {
    deg: Vec<f64>,
    /// Directed incident slots per vertex (self loops count once).
    counts: Vec<u64>,
    directed: u64,
    edges: u64,
}

impl GlobalPass {
    pub fn new(n: usize) -> GlobalPass {
        GlobalPass { deg: vec![0.0; n], counts: vec![0; n], directed: 0, edges: 0 }
    }

    /// Account one stored (undirected) edge. Must be called in storage
    /// order for the degree accumulation to stay bitwise-identical to
    /// the in-core engines.
    #[inline]
    pub fn observe(&mut self, a: u32, b: u32, w: f64) {
        let (ai, bi) = (a as usize, b as usize);
        self.deg[ai] += w;
        self.counts[ai] += 1;
        self.directed += 1;
        if ai != bi {
            self.deg[bi] += w;
            self.counts[bi] += 1;
            self.directed += 1;
        }
        self.edges += 1;
    }

    /// Stored (undirected) edges observed so far.
    pub fn edges_seen(&self) -> u64 {
        self.edges
    }

    /// Directed slots observed so far.
    pub fn directed(&self) -> u64 {
        self.directed
    }

    /// Close the pass: balance the shard boundaries over the observed
    /// slot counts and derive the weight vector from the labels.
    pub fn finish(self, labels: &[i32], k: usize, shards: usize) -> ShardPlan {
        let n = self.deg.len();
        assert_eq!(labels.len(), n, "labels length must match vertex count");
        let shards = resolve_shards(shards, n, self.directed);
        let mut prefix = Vec::with_capacity(n + 1);
        prefix.push(0u64);
        let mut run = 0u64;
        for &c in &self.counts {
            run += c;
            prefix.push(run);
        }
        let bounds = nnz_chunks_u64(&prefix, shards);
        let hub_shards: Vec<usize> = bounds
            .windows(2)
            .enumerate()
            .filter(|(_, w)| {
                self.counts[w[0]..w[1]]
                    .iter()
                    .any(|&c| c > HUB_SEGMENT_NNZ as u64)
            })
            .map(|(s, _)| s)
            .collect();
        ShardPlan {
            n,
            k,
            bounds,
            deg: self.deg,
            wv: weight_values(labels, k),
            directed: self.directed,
            hub_shards,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = rng.below(k) as i32;
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(3, 3, 2.0);
        g
    }

    #[test]
    fn plan_globals_match_graph_accessors() {
        let g = random_graph(501, 120, 700, 4);
        let plan = ShardPlan::from_graph(&g, 4);
        assert_eq!(plan.deg, g.degrees(), "degrees must be bitwise identical");
        assert_eq!(plan.wv, weight_values(&g.labels, g.k));
        assert_eq!(plan.directed as usize, g.num_directed());
        assert_eq!(plan.bounds.first(), Some(&0));
        assert_eq!(plan.bounds.last(), Some(&g.n));
        assert!(plan.bounds.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn shard_of_inverts_ranges() {
        let g = random_graph(502, 200, 1_000, 3);
        let plan = ShardPlan::from_graph(&g, 5);
        for v in 0..g.n {
            let s = plan.shard_of(v);
            let (v0, v1) = plan.shard_range(s);
            assert!(v0 <= v && v < v1, "vertex {v} outside shard {s} [{v0},{v1})");
        }
    }

    #[test]
    fn resolve_shards_policy() {
        assert!(resolve_shards(0, 100, 1_000) >= 1);
        assert_eq!(resolve_shards(3, 100, 1_000), 3);
        // capped at vertex count
        assert_eq!(resolve_shards(64, 5, 100), 5);
        assert_eq!(resolve_shards(4, 0, 0), 1);
        // raised so each shard's slice fits u32 (with 4x headroom)
        let huge = 3 * (MAX_INDEX as u64); // ~12.9B directed slots
        assert!(resolve_shards(1, usize::MAX >> 8, huge) >= 12);
    }

    #[test]
    fn hub_shards_flag_mega_vertices() {
        let n = 50usize;
        let mut g = Graph::new(n, 2);
        for l in g.labels.iter_mut() {
            *l = 0;
        }
        // center 0 accumulates > HUB_SEGMENT_NNZ directed slots
        for i in 0..(HUB_SEGMENT_NNZ + 10) {
            g.add_edge(0, (1 + (i % (n - 1))) as u32, 1.0);
        }
        let plan = ShardPlan::from_graph(&g, 4);
        assert_eq!(plan.hub_shards, vec![plan.shard_of(0)]);
        // hub-free graphs flag nothing
        let g2 = random_graph(504, 100, 400, 3);
        assert!(ShardPlan::from_graph(&g2, 4).hub_shards.is_empty());
    }

    #[test]
    fn scale_matches_fused_formula() {
        let g = random_graph(503, 60, 300, 3);
        let plan = ShardPlan::from_graph(&g, 2);
        assert!(plan.scale_for(&GeeOptions::NONE).is_none());
        let s = plan
            .scale_for(&GeeOptions::new(true, true, false))
            .unwrap();
        for (v, &d) in plan.deg.iter().enumerate() {
            assert_eq!(s[v].to_bits(), safe_recip_sqrt(d + 1.0).to_bits());
        }
    }
}
