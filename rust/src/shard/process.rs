//! Multi-process backend: each spilled shard is embedded by a separate
//! worker process running this binary's `shard-worker` subcommand, so
//! the shard pass scales past one process's memory and (on a fleet
//! launcher) one machine.
//!
//! The exchange is entirely through the [`super::codec`] binary record
//! formats — binary shard edge files from the spill, a shared raw-i32
//! labels file, a shared raw-f64 degree file (exact bit patterns, so the
//! worker's Laplacian scale is bitwise-identical to the in-process one),
//! and one raw-f64 Z-rows file back per shard whose byte count the
//! parent validates exactly (a torn write cannot pass silently). The
//! worker binary still accepts the legacy text formats, so old drivers
//! can spawn it — but this driver ships `.bin` everywhere. Scheduling is
//! a rolling slot pool: up to `workers` children run at once and a new
//! shard launches the moment any slot frees, so one slow shard delays
//! only its own slot, never a whole wave. A failure stops new launches,
//! but every already-running child is reaped (no zombies, no orphaned
//! output files) before the error propagates.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::codec;
use super::spill::SpilledShards;
use crate::gee::options::GeeOptions;
use crate::sparse::Dense;

/// Multi-process execution settings.
#[derive(Clone, Debug)]
pub struct ProcessConfig {
    /// Concurrent worker-process slots (1–4 is the tested range; the
    /// rolling pool keeps this many children running until every shard
    /// is done).
    pub workers: usize,
    /// Binary exposing the `shard-worker` subcommand — the `gee` CLI
    /// itself in production; tests pass `env!("CARGO_BIN_EXE_gee")`.
    pub worker_bin: PathBuf,
}

impl ProcessConfig {
    pub fn new(worker_bin: impl Into<PathBuf>) -> ProcessConfig {
        ProcessConfig { workers: 2, worker_bin: worker_bin.into() }
    }
}

/// One in-flight worker child and where its rows go. `stderr_drain`
/// reads the child's stderr pipe concurrently — without it a child that
/// fills the pipe (long panic backtrace) would block on write(2) and
/// never exit, and the try_wait poll would spin forever.
struct Slot {
    shard: usize,
    v0: usize,
    v1: usize,
    out_path: PathBuf,
    child: Child,
    stderr_drain: std::thread::JoinHandle<String>,
}

/// Embed a spilled graph with worker processes, one shard per worker
/// invocation. Output is bitwise-identical to the in-process lanes.
pub fn embed_multiprocess(
    sp: &SpilledShards,
    opts: &GeeOptions,
    cfg: &ProcessConfig,
) -> Result<Dense> {
    let plan = &sp.plan;
    // ship the phase-1 globals once, as raw binary records
    let labels_path = sp.dir.join("global.labels.bin");
    codec::write_i32s_file(&labels_path, &sp.labels)?;
    let deg_path = sp.dir.join("global.deg.bin");
    codec::write_f64s_file(&deg_path, &plan.deg)?;

    let mut z = Dense::zeros(plan.n, plan.k);
    let slots = cfg.workers.max(1);
    let mut running: Vec<Slot> = Vec::with_capacity(slots);
    let mut next_shard = 0usize;
    let mut first_err: Option<anyhow::Error> = None;

    // rolling slot pool: refill free slots, reap whichever child exits
    // first, repeat. Once a failure is recorded nothing new launches, but
    // the loop keeps draining `running` — the reap-everything-before-
    // propagating-failure invariant the old wave scheduler had.
    while !running.is_empty() || (first_err.is_none() && next_shard < plan.shards()) {
        while first_err.is_none()
            && next_shard < plan.shards()
            && running.len() < slots
        {
            let s = next_shard;
            next_shard += 1;
            match spawn_worker(sp, opts, cfg, &labels_path, &deg_path, s) {
                Ok(slot) => running.push(slot),
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        if running.is_empty() {
            break;
        }
        // reap any exited child; poll with a short sleep (std has no
        // portable wait-for-any)
        let mut reaped = false;
        let mut i = 0;
        while i < running.len() {
            match running[i].child.try_wait() {
                Ok(Some(_)) => {
                    let slot = running.swap_remove(i);
                    reaped = true;
                    if let Err(e) = finish_slot(slot, plan.k, &mut z) {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
                Ok(None) => i += 1,
                Err(e) => {
                    let mut slot = running.swap_remove(i);
                    reaped = true;
                    let _ = slot.child.kill();
                    let _ = slot.child.wait();
                    let _ = fs::remove_file(&slot.out_path);
                    if first_err.is_none() {
                        first_err = Some(
                            anyhow::Error::new(e)
                                .context(format!("poll shard-worker {}", slot.shard)),
                        );
                    }
                }
            }
        }
        if !reaped && !running.is_empty() {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(z)
}

/// Launch one shard's worker child.
fn spawn_worker(
    sp: &SpilledShards,
    opts: &GeeOptions,
    cfg: &ProcessConfig,
    labels_path: &Path,
    deg_path: &Path,
    s: usize,
) -> Result<Slot> {
    let plan = &sp.plan;
    let (v0, v1) = plan.shard_range(s);
    let out_path = sp.dir.join(format!("z_{s}.bin"));
    let mut cmd = Command::new(&cfg.worker_bin);
    cmd.arg("shard-worker")
        .arg("--edges")
        .arg(&sp.files[s])
        .arg("--labels")
        .arg(labels_path)
        .arg("--deg")
        .arg(deg_path)
        .arg("--n")
        .arg(plan.n.to_string())
        .arg("--k")
        .arg(plan.k.to_string())
        .arg("--row0")
        .arg(v0.to_string())
        .arg("--row1")
        .arg(v1.to_string());
    // real boolean flags (presence = on). Note the compatibility
    // direction: the *worker* still accepts the legacy `--lap 1` 0/1
    // form, so old drivers can spawn this binary — but this driver's
    // bare flags require a worker from this revision (in practice the
    // two are always the same binary: current_exe / CARGO_BIN_EXE).
    if opts.laplacian {
        cmd.arg("--lap");
    }
    if opts.diagonal {
        cmd.arg("--diag");
    }
    if opts.correlation {
        cmd.arg("--cor");
    }
    let mut child = cmd
        .arg("--out")
        .arg(&out_path)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .with_context(|| {
            format!("spawn shard-worker via {}", cfg.worker_bin.display())
        })?;
    let stderr = child.stderr.take();
    let stderr_drain = std::thread::spawn(move || {
        let mut buf = String::new();
        if let Some(mut pipe) = stderr {
            use std::io::Read;
            let _ = pipe.read_to_string(&mut buf);
        }
        buf
    });
    Ok(Slot { shard: s, v0, v1, out_path, child, stderr_drain })
}

/// Collect one exited child: check status, load its binary Z records
/// into place (byte count validated exactly), remove its output file.
fn finish_slot(slot: Slot, k: usize, z: &mut Dense) -> Result<()> {
    let Slot { shard: s, v0, v1, out_path, mut child, stderr_drain } = slot;
    let step = (|| -> Result<()> {
        let status = child
            .wait()
            .with_context(|| format!("wait for shard-worker {s}"))?;
        let stderr = stderr_drain.join().unwrap_or_default();
        if !status.success() {
            bail!("shard-worker {s} failed ({status}): {}", stderr.trim());
        }
        let cells = codec::read_f64s_file(&out_path)?;
        let expect = (v1 - v0) * k;
        if cells.len() != expect {
            bail!(
                "shard-worker {s} wrote {} Z cells, expected {expect}",
                cells.len()
            );
        }
        z.data[v0 * k..v1 * k].copy_from_slice(&cells);
        Ok(())
    })();
    let _ = fs::remove_file(&out_path);
    step
}
