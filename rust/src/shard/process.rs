//! Multi-process backend: each spilled shard is embedded by a separate
//! worker process running this binary's `shard-worker` subcommand, so
//! the shard pass scales past one process's memory and (on a fleet
//! launcher) one machine.
//!
//! The exchange is entirely through the `graph::io` text formats — shard
//! edge files from the spill, a shared labels file, a shared degree file
//! (shortest-roundtrip f64, so the worker's Laplacian scale is
//! bitwise-identical to the in-process one), and one Z-rows file back per
//! shard. Workers run in waves of `workers` concurrent processes; a
//! failed worker surfaces its stderr.

use std::fs::{self, File};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};

use anyhow::{bail, Context, Result};

use super::spill::SpilledShards;
use crate::gee::options::GeeOptions;
use crate::graph::io::write_f64_vec;
use crate::sparse::Dense;

/// Multi-process execution settings.
#[derive(Clone, Debug)]
pub struct ProcessConfig {
    /// Concurrent worker processes (1–4 is the tested range; waves of
    /// this size run until every shard is done).
    pub workers: usize,
    /// Binary exposing the `shard-worker` subcommand — the `gee` CLI
    /// itself in production; tests pass `env!("CARGO_BIN_EXE_gee")`.
    pub worker_bin: PathBuf,
}

impl ProcessConfig {
    pub fn new(worker_bin: impl Into<PathBuf>) -> ProcessConfig {
        ProcessConfig { workers: 2, worker_bin: worker_bin.into() }
    }
}

/// Embed a spilled graph with worker processes, one shard per worker
/// invocation. Output is bitwise-identical to the in-process lanes.
pub fn embed_multiprocess(
    sp: &SpilledShards,
    opts: &GeeOptions,
    cfg: &ProcessConfig,
) -> Result<Dense> {
    let plan = &sp.plan;
    // ship the phase-1 globals once
    let labels_path = sp.dir.join("global.labels");
    {
        let mut f = BufWriter::new(
            File::create(&labels_path)
                .with_context(|| format!("create {}", labels_path.display()))?,
        );
        for &l in &sp.labels {
            writeln!(f, "{l}")?;
        }
        f.flush()?;
    }
    let deg_path = sp.dir.join("global.deg");
    write_f64_vec(&deg_path, &plan.deg)?;

    let mut z = Dense::zeros(plan.n, plan.k);
    let wave = cfg.workers.max(1);
    let mut next_shard = 0usize;
    while next_shard < plan.shards() {
        let hi = (next_shard + wave).min(plan.shards());
        let mut children = Vec::with_capacity(hi - next_shard);
        for s in next_shard..hi {
            let (v0, v1) = plan.shard_range(s);
            let out_path = sp.dir.join(format!("z_{s}.tsv"));
            let child = Command::new(&cfg.worker_bin)
                .arg("shard-worker")
                .arg("--edges")
                .arg(&sp.files[s])
                .arg("--labels")
                .arg(&labels_path)
                .arg("--deg")
                .arg(&deg_path)
                .arg("--n")
                .arg(plan.n.to_string())
                .arg("--k")
                .arg(plan.k.to_string())
                .arg("--row0")
                .arg(v0.to_string())
                .arg("--row1")
                .arg(v1.to_string())
                // lap/diag/cor as 0/1 values (the compact "--c"-style
                // code would be eaten as a flag by the CLI arg parser)
                .arg("--lap")
                .arg(if opts.laplacian { "1" } else { "0" })
                .arg("--diag")
                .arg(if opts.diagonal { "1" } else { "0" })
                .arg("--cor")
                .arg(if opts.correlation { "1" } else { "0" })
                .arg("--out")
                .arg(&out_path)
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::piped())
                .spawn()
                .with_context(|| {
                    format!("spawn shard-worker via {}", cfg.worker_bin.display())
                })?;
            children.push((s, v0, v1, out_path, child));
        }
        // wait the whole wave before acting on any failure: an early bail
        // must not leave running children (or zombies) and their output
        // files behind
        let mut outputs = Vec::with_capacity(children.len());
        for (s, v0, v1, out_path, child) in children {
            let res = child
                .wait_with_output()
                .with_context(|| format!("wait for shard-worker {s}"));
            outputs.push((s, v0, v1, out_path, res));
        }
        let mut first_err: Option<anyhow::Error> = None;
        for (s, v0, v1, out_path, res) in outputs {
            let step = (|| -> Result<()> {
                let out = res?;
                if !out.status.success() {
                    bail!(
                        "shard-worker {s} failed ({}): {}",
                        out.status,
                        String::from_utf8_lossy(&out.stderr).trim()
                    );
                }
                let rows = read_z_rows(
                    &out_path,
                    plan.k,
                    &mut z.data[v0 * plan.k..v1 * plan.k],
                )?;
                if rows != v1 - v0 {
                    bail!(
                        "shard-worker {s} wrote {rows} rows, expected {}",
                        v1 - v0
                    );
                }
                Ok(())
            })();
            let _ = fs::remove_file(&out_path);
            if let Err(e) = step {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            if !sp.keep {
                let _ = fs::remove_file(&labels_path);
                let _ = fs::remove_file(&deg_path);
            }
            return Err(e);
        }
        next_shard = hi;
    }
    if !sp.keep {
        let _ = fs::remove_file(&labels_path);
        let _ = fs::remove_file(&deg_path);
    }
    Ok(z)
}

/// Parse a worker's Z-rows file (one whitespace-separated row per line)
/// into `out`; returns the row count.
fn read_z_rows(path: &Path, k: usize, out: &mut [f64]) -> Result<usize> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut row = 0usize;
    for line in BufReader::new(f).lines() {
        let line = line?;
        if k > 0 && row * k >= out.len() {
            bail!("{}: more rows than the shard range", path.display());
        }
        let mut col = 0usize;
        for tok in line.split_whitespace() {
            if col >= k {
                bail!("{}:{}: more than {k} columns", path.display(), row + 1);
            }
            out[row * k + col] = tok.parse::<f64>().with_context(|| {
                format!("{}:{}: bad value", path.display(), row + 1)
            })?;
            col += 1;
        }
        if col != k {
            bail!(
                "{}:{}: {col} columns, expected {k}",
                path.display(),
                row + 1
            );
        }
        row += 1;
    }
    Ok(row)
}
