//! Phase 2 of the sharded pipeline: embed one shard's rows from its
//! incident edge arrays plus the phase-1 globals.
//!
//! The shard builds the same row-grouped structure the fused engine
//! builds globally (`prepare_into`), just restricted to its vertex range
//! — and because the incident edges arrive in global storage order, each
//! row's entries land in exactly the order the whole-graph counting sort
//! would produce. The accumulation then *is*
//! [`accumulate_rows`](crate::gee::kernel::accumulate_rows) — the
//! crate's single per-row kernel (runtime-dispatched small-K lanes and
//! all) — viewing the shard-local `indptr` through its `row_base`
//! offset. Net effect: shard outputs are **bitwise-identical** to
//! `SparseGee::fast()`, not merely close. Hub shards (flagged by the
//! planner) additionally get [`embed_shard_par`], which fans hub-row
//! segments across threads through the same fixed-order plan the serial
//! kernel uses — still bitwise-identical.

use crate::gee::kernel::{accumulate_rows, AccumCtx};
use crate::gee::options::GeeOptions;
use crate::gee::parallel::accumulate_rows_par;
use crate::gee::workspace::{reset_f64, reset_u32, EmbedWorkspace};
use crate::sparse::index::to_index;

/// Embed rows `[v0, v1)` into `out` (length `(v1 - v0) * k`).
///
/// * `src`/`dst`/`w` — the shard's incident stored edges, global vertex
///   ids, global storage order. Every stored edge with an endpoint in
///   range must appear exactly once (an edge with *both* endpoints in
///   range still appears once — both rows are recovered from the one
///   copy, mirroring the undirected storage convention).
/// * `labels`/`wv`/`scale` — the global (length-n) vectors from the
///   [`ShardPlan`](super::plan::ShardPlan).
/// * `ws` — scratch; the prepared-structure buffers are borrowed from it,
///   so a warm workspace makes repeated shard embeds allocation-free.
#[allow(clippy::too_many_arguments)]
pub(crate) fn embed_shard(
    src: &[u32],
    dst: &[u32],
    w: &[f64],
    v0: usize,
    v1: usize,
    labels: &[i32],
    wv: &[f64],
    scale: Option<&[f64]>,
    k: usize,
    opts: &GeeOptions,
    ws: &mut EmbedWorkspace,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), (v1 - v0) * k);
    let EmbedWorkspace { indptr, next, cols, vals, .. } = ws;
    build_local_structure(src, dst, w, v0, v1, indptr, next, cols, vals);
    let ctx = AccumCtx {
        indptr: &indptr[..],
        row_base: v0,
        cols: &cols[..],
        vals: &vals[..],
        labels,
        wv,
        k,
    };
    accumulate_rows(&ctx, opts, v0, v1, scale, out);
}

/// Thread-parallel twin of [`embed_shard`] for hub shards: same local
/// structure build, then [`accumulate_rows_par`] — non-hub rows in
/// nnz-balanced chunks, hub rows split into fixed-order segments fanned
/// across `threads`. Bitwise-identical to `embed_shard` (the serial
/// kernel computes hub rows through the same segment grid).
#[allow(clippy::too_many_arguments)]
pub(crate) fn embed_shard_par(
    src: &[u32],
    dst: &[u32],
    w: &[f64],
    v0: usize,
    v1: usize,
    labels: &[i32],
    wv: &[f64],
    scale: Option<&[f64]>,
    k: usize,
    opts: &GeeOptions,
    threads: usize,
    ws: &mut EmbedWorkspace,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), (v1 - v0) * k);
    let EmbedWorkspace { indptr, next, cols, vals, seg_partials, .. } = ws;
    build_local_structure(src, dst, w, v0, v1, indptr, next, cols, vals);
    let ctx = AccumCtx {
        indptr: &indptr[..],
        row_base: v0,
        cols: &cols[..],
        vals: &vals[..],
        labels,
        wv,
        k,
    };
    accumulate_rows_par(&ctx, opts, scale, out, threads, seg_partials);
}

/// Counting-sort the shard's incident edges into the row-grouped local
/// structure (`indptr` row pointers over `[v0, v1)`, `cols`/`vals` in
/// global storage order per row) — shared by the serial and parallel
/// shard embeds so the structure cannot drift between them.
#[allow(clippy::too_many_arguments)]
fn build_local_structure(
    src: &[u32],
    dst: &[u32],
    w: &[f64],
    v0: usize,
    v1: usize,
    indptr: &mut Vec<u32>,
    next: &mut Vec<u32>,
    cols: &mut Vec<u32>,
    vals: &mut Vec<f64>,
) {
    let rows = v1 - v0;
    debug_assert_eq!(src.len(), dst.len());
    debug_assert_eq!(src.len(), w.len());

    // counting pass over the shard's incident edges. `slots` tracks the
    // exact in-range directed-slot total in u64 so the u32 fit check
    // below is exact, not a 2x-conservative bound: the plan's headroom
    // (resolve_shards) keeps this far under u32::MAX, and the check only
    // fires for a genuinely unshardable range (a single vertex whose
    // incident slots alone approach u32::MAX).
    let range = v0..v1;
    reset_u32(indptr, rows + 1);
    let mut slots = 0u64;
    for i in 0..src.len() {
        let (a, b) = (src[i] as usize, dst[i] as usize);
        if range.contains(&a) {
            indptr[a - v0 + 1] = indptr[a - v0 + 1].wrapping_add(1);
            slots += 1;
        }
        if a != b && range.contains(&b) {
            indptr[b - v0 + 1] = indptr[b - v0 + 1].wrapping_add(1);
            slots += 1;
        }
    }
    // must precede any use of the (possibly wrapped) counts
    to_index(usize::try_from(slots).unwrap_or(usize::MAX), "shard directed slots");
    for r in 0..rows {
        indptr[r + 1] += indptr[r];
    }
    let local_m = indptr[rows] as usize;

    // fill pass, in the same order the global counting sort would
    reset_u32(cols, local_m);
    reset_f64(vals, local_m);
    next.clear();
    next.extend_from_slice(indptr);
    for i in 0..src.len() {
        let (a, b) = (src[i] as usize, dst[i] as usize);
        if range.contains(&a) {
            let p = next[a - v0] as usize;
            cols[p] = dst[i];
            vals[p] = w[i];
            next[a - v0] += 1;
        }
        if a != b && range.contains(&b) {
            let p = next[b - v0] as usize;
            cols[p] = src[i];
            vals[p] = w[i];
            next[b - v0] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::gee::GeeOptions;
    use crate::graph::Graph;
    use crate::shard::plan::ShardPlan;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(0, 0, 1.5);
        g.add_edge((n - 1) as u32, (n - 1) as u32, 0.25);
        g
    }

    /// Gather the incident stored edges of `[v0, v1)` in storage order —
    /// the reference gather the engine and spill lanes must both match.
    fn gather(g: &Graph, v0: usize, v1: usize) -> (Vec<u32>, Vec<u32>, Vec<f64>) {
        let (mut s, mut d, mut w) = (Vec::new(), Vec::new(), Vec::new());
        let range = v0..v1;
        for i in 0..g.num_edges() {
            let (a, b) = (g.src[i] as usize, g.dst[i] as usize);
            if range.contains(&a) || range.contains(&b) {
                s.push(g.src[i]);
                d.push(g.dst[i]);
                w.push(g.w[i]);
            }
        }
        (s, d, w)
    }

    #[test]
    fn shard_rows_bitwise_match_fused_engine() {
        let g = random_graph(511, 90, 500, 4);
        let plan = ShardPlan::from_graph(&g, 4);
        let mut ws = EmbedWorkspace::new();
        for opts in GeeOptions::table_order() {
            let whole = SparseGee::fast().embed(&g, &opts);
            let scale = plan.scale_for(&opts);
            for s in 0..plan.shards() {
                let (v0, v1) = plan.shard_range(s);
                let (src, dst, w) = gather(&g, v0, v1);
                let mut out = vec![0.0; (v1 - v0) * g.k];
                embed_shard(
                    &src,
                    &dst,
                    &w,
                    v0,
                    v1,
                    &g.labels,
                    &plan.wv,
                    scale.as_deref(),
                    g.k,
                    &opts,
                    &mut ws,
                    &mut out,
                );
                assert_eq!(
                    out,
                    whole.data[v0 * g.k..v1 * g.k],
                    "shard {s} rows drifted at {opts:?}"
                );
            }
        }
    }

    #[test]
    fn embed_shard_par_bitwise_matches_serial() {
        let g = random_graph(513, 80, 600, 3);
        let plan = ShardPlan::from_graph(&g, 3);
        let mut ws = EmbedWorkspace::new();
        let mut ws_par = EmbedWorkspace::new();
        for opts in GeeOptions::table_order() {
            let scale = plan.scale_for(&opts);
            for s in 0..plan.shards() {
                let (v0, v1) = plan.shard_range(s);
                let (src, dst, w) = gather(&g, v0, v1);
                let mut serial = vec![0.0; (v1 - v0) * g.k];
                embed_shard(
                    &src,
                    &dst,
                    &w,
                    v0,
                    v1,
                    &g.labels,
                    &plan.wv,
                    scale.as_deref(),
                    g.k,
                    &opts,
                    &mut ws,
                    &mut serial,
                );
                for t in [1usize, 2, 4] {
                    let mut par = vec![0.0; (v1 - v0) * g.k];
                    embed_shard_par(
                        &src,
                        &dst,
                        &w,
                        v0,
                        v1,
                        &g.labels,
                        &plan.wv,
                        scale.as_deref(),
                        g.k,
                        &opts,
                        t,
                        &mut ws_par,
                        &mut par,
                    );
                    assert_eq!(par, serial, "shard {s} par t={t} drifted at {opts:?}");
                }
            }
        }
    }

    #[test]
    fn empty_shard_and_empty_range() {
        let g = random_graph(512, 10, 0, 2);
        let wv = vec![0.0; g.n];
        let mut ws = EmbedWorkspace::new();
        let mut out = vec![0.0; 5 * g.k];
        embed_shard(
            &[],
            &[],
            &[],
            0,
            5,
            &g.labels,
            &wv,
            None,
            g.k,
            &GeeOptions::ALL,
            &mut ws,
            &mut out,
        );
        assert!(out.iter().all(|&x| x == 0.0));
        let mut none: Vec<f64> = Vec::new();
        embed_shard(
            &[],
            &[],
            &[],
            3,
            3,
            &g.labels,
            &wv,
            None,
            g.k,
            &GeeOptions::NONE,
            &mut ws,
            &mut none,
        );
    }
}
