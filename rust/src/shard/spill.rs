//! On-disk shard exchange — the out-of-core backend.
//!
//! Spilling uses the [`super::codec`] binary edge-record format (16
//! fixed-width bytes per record: `u32 src | u32 dst | f64 weight`, raw
//! little-endian bit patterns, so weights round-trip bitwise with no
//! decimal formatting on any path): one streaming pass writes every
//! stored edge into the spill file of each endpoint's shard (once, when
//! both endpoints share a shard). Each shard file therefore holds
//! exactly the shard's incident edges in global storage order — the
//! invariant [`local::embed_shard`](super::local::embed_shard) needs for
//! bitwise-identical rows — and its byte length is exactly
//! `records × 16`, headerless, which is what lets the TCP dispatcher
//! stream a spill file to a remote worker as one raw frame with zero
//! re-parse. Legacy text spill files (any extension but `.bin`) still
//! load through the same entry points.
//!
//! [`embed_out_of_core`] then loads one shard at a time, so peak edge
//! residency is a single shard's slice (bounded by
//! [`SpillConfig::mem_budget_edges`], which raises the shard count until
//! the ideal per-shard share fits) plus the O(n) global vectors — a graph
//! whose edge list dwarfs RAM still embeds.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::codec::{for_each_edge_auto, try_for_each_edge_auto, write_edge_record};
use super::local::embed_shard;
use super::plan::{GlobalPass, ShardPlan};
use crate::gee::options::GeeOptions;
use crate::gee::workspace::EmbedWorkspace;
use crate::graph::io::read_label_vec;
use crate::graph::Graph;
use crate::sparse::Dense;

/// How to spill.
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Requested shard count; 0 = auto. Raised by the memory budget and
    /// the u32-per-shard rule regardless.
    pub shards: usize,
    /// Target cap on stored-edge copies resident per shard load; 0 = no
    /// budget. The shard count is raised to `ceil(directed / budget)`,
    /// so the cap is exact under perfect balance and approximate when a
    /// hub vertex makes one range heavy (a single vertex's edges cannot
    /// be split across shards).
    pub mem_budget_edges: usize,
    /// Parent directory for spill files (created if absent). Each spill
    /// writes into its own unique subdirectory of this path — two
    /// concurrent spills sharing one config never see each other's
    /// `shard_N.bin` (they used to clobber silently).
    pub dir: PathBuf,
    /// Keep spill files on drop (debugging / inspection).
    pub keep: bool,
}

impl SpillConfig {
    pub fn new(dir: impl Into<PathBuf>) -> SpillConfig {
        SpillConfig { shards: 0, mem_budget_edges: 0, dir: dir.into(), keep: false }
    }
}

/// A spilled graph: the phase-1 plan, the global labels, and one
/// incident-edge file per shard. `dir` is this spill's own unique
/// subdirectory (under [`SpillConfig::dir`]); the whole subdirectory —
/// shard files plus anything a backend staged next to them — is removed
/// on drop unless the config said `keep`.
#[derive(Debug)]
pub struct SpilledShards {
    pub plan: ShardPlan,
    pub labels: Vec<i32>,
    pub files: Vec<PathBuf>,
    pub dir: PathBuf,
    pub keep: bool,
}

impl Drop for SpilledShards {
    fn drop(&mut self) {
        if !self.keep {
            let _ = fs::remove_dir_all(&self.dir);
        }
    }
}

/// Distinguishes concurrent spills within one process; the pid in the
/// directory name distinguishes processes.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

fn unique_spill_dir(parent: &Path) -> PathBuf {
    parent.join(format!(
        "spill_{}_{}",
        std::process::id(),
        SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Shard count request after applying the memory budget.
fn requested_shards(cfg: &SpillConfig, directed: u64) -> usize {
    let mut req = cfg.shards;
    if cfg.mem_budget_edges > 0 {
        let b = cfg.mem_budget_edges as u64;
        let need = ((directed + b - 1) / b) as usize;
        req = req.max(need);
    }
    req
}

fn open_writers(
    parent: &Path,
    shards: usize,
) -> Result<(PathBuf, Vec<PathBuf>, Vec<BufWriter<File>>)> {
    let dir = unique_spill_dir(parent);
    fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;
    let mut files = Vec::with_capacity(shards);
    let mut writers = Vec::with_capacity(shards);
    for s in 0..shards {
        let path = dir.join(format!("shard_{s}.bin"));
        let f = File::create(&path).with_context(|| format!("create {}", path.display()))?;
        files.push(path);
        writers.push(BufWriter::new(f));
    }
    Ok((dir, files, writers))
}

/// Spill an in-memory graph (the multi-process lane's entry point when
/// the graph is already resident).
pub fn spill_from_graph(g: &Graph, cfg: &SpillConfig) -> Result<SpilledShards> {
    let mut pass = GlobalPass::new(g.n);
    for i in 0..g.num_edges() {
        pass.observe(g.src[i], g.dst[i], g.w[i]);
    }
    let req = requested_shards(cfg, pass.directed());
    let plan = pass.finish(&g.labels, g.k, req);
    let (dir, files, mut writers) = open_writers(&cfg.dir, plan.shards())?;
    for i in 0..g.num_edges() {
        let (a, b, w) = (g.src[i], g.dst[i], g.w[i]);
        let sa = plan.shard_of(a as usize);
        let sb = plan.shard_of(b as usize);
        write_edge_record(&mut writers[sa], a, b, w)
            .with_context(|| format!("write {}", files[sa].display()))?;
        if sb != sa {
            write_edge_record(&mut writers[sb], a, b, w)
                .with_context(|| format!("write {}", files[sb].display()))?;
        }
    }
    for (s, wtr) in writers.iter_mut().enumerate() {
        wtr.flush().with_context(|| format!("flush {}", files[s].display()))?;
    }
    Ok(SpilledShards {
        plan,
        labels: g.labels.clone(),
        files,
        dir,
        keep: cfg.keep,
    })
}

/// Spill straight from on-disk `.edges` + `.labels` files without ever
/// materializing the graph: pass 1 streams the globals, pass 2 streams
/// again routing each line to its shard file(s). O(n) memory.
/// `k` is `max label + 1` from the labels file.
pub fn spill_from_files(
    edges: &Path,
    labels_path: &Path,
    cfg: &SpillConfig,
) -> Result<SpilledShards> {
    let labels = read_label_vec(labels_path)?;
    let n = labels.len();
    let k = (labels.iter().copied().max().unwrap_or(-1).max(-1) + 1) as usize;

    let mut pass = GlobalPass::new(n);
    let mut oob: Option<(u32, u32)> = None;
    try_for_each_edge_auto(edges, |a, b, w| {
        if (a as usize) < n && (b as usize) < n {
            pass.observe(a, b, w);
            std::ops::ControlFlow::Continue(())
        } else {
            // stop the stream: validating the rest of a file that may be
            // larger than RAM buys nothing once one edge is fatal
            oob = Some((a, b));
            std::ops::ControlFlow::Break(())
        }
    })?;
    if let Some((a, b)) = oob {
        bail!(
            "edge ({a}, {b}) out of range: {} declares {n} vertices",
            labels_path.display()
        );
    }

    let req = requested_shards(cfg, pass.directed());
    let plan = pass.finish(&labels, k, req);
    let (dir, files, mut writers) = open_writers(&cfg.dir, plan.shards())?;
    // a mid-spill IO failure (disk full, quota, yanked mount) must name
    // the shard file it hit, not just "write spill files"
    let mut io_err: Option<(std::io::Error, usize)> = None;
    for_each_edge_auto(edges, |a, b, w| {
        if io_err.is_some() {
            return;
        }
        let sa = plan.shard_of(a as usize);
        let sb = plan.shard_of(b as usize);
        if let Err(e) = write_edge_record(&mut writers[sa], a, b, w) {
            io_err = Some((e, sa));
            return;
        }
        if sb != sa {
            if let Err(e) = write_edge_record(&mut writers[sb], a, b, w) {
                io_err = Some((e, sb));
            }
        }
    })?;
    if let Some((e, s)) = io_err {
        return Err(anyhow::Error::new(e)
            .context(format!("write spill shard file {}", files[s].display())));
    }
    for (s, wtr) in writers.iter_mut().enumerate() {
        wtr.flush().with_context(|| format!("flush {}", files[s].display()))?;
    }
    Ok(SpilledShards { plan, labels, files, dir, keep: cfg.keep })
}

/// Embed a spilled graph shard-by-shard, in-process: only one shard's
/// edges are resident at a time (buffers reused across shards), so a
/// graph whose edge list exceeds RAM embeds within the spill budget.
/// Bitwise-identical to the in-core engines.
pub fn embed_out_of_core(sp: &SpilledShards, opts: &GeeOptions) -> Result<Dense> {
    let plan = &sp.plan;
    let scale = plan.scale_for(opts);
    let mut z = Dense::zeros(plan.n, plan.k);
    let (mut src, mut dst, mut w) = (Vec::new(), Vec::new(), Vec::new());
    let mut ws = EmbedWorkspace::new();
    for s in 0..plan.shards() {
        let (v0, v1) = plan.shard_range(s);
        src.clear();
        dst.clear();
        w.clear();
        for_each_edge_auto(&sp.files[s], |a, b, ww| {
            src.push(a);
            dst.push(b);
            w.push(ww);
        })?;
        embed_shard(
            &src,
            &dst,
            &w,
            v0,
            v1,
            &sp.labels,
            &plan.wv,
            scale.as_deref(),
            plan.k,
            opts,
            &mut ws,
            &mut z.data[v0 * plan.k..v1 * plan.k],
        );
    }
    Ok(z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::gee::GeeOptions;
    use crate::graph::io::write_graph;
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("gee_spill_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    /// Edge records in a binary spill file, via its exact byte length.
    fn spill_records(f: &Path) -> usize {
        let bytes = fs::metadata(f).unwrap().len();
        assert_eq!(
            bytes % super::super::codec::EDGE_RECORD_BYTES as u64,
            0,
            "{}: spill files must be whole records",
            f.display()
        );
        (bytes / super::super::codec::EDGE_RECORD_BYTES as u64) as usize
    }

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for c in 0..k {
            g.labels[c] = c as i32; // every class occupied: file-derived
                                    // k (max label + 1) matches declared k
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(1, 1, 2.0);
        g
    }

    #[test]
    fn spilled_graph_embeds_bitwise_from_disk() {
        let d = tmpdir("mem");
        let g = random_graph(531, 80, 450, 3);
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 4, ..SpillConfig::new(&d) },
        )
        .unwrap();
        assert_eq!(sp.files.len(), sp.plan.shards());
        for opts in GeeOptions::table_order() {
            let expect = SparseGee::fast().embed(&g, &opts);
            let z = embed_out_of_core(&sp, &opts).unwrap();
            assert_eq!(z.data, expect.data, "ooc drifted at {opts:?}");
        }
    }

    #[test]
    fn memory_budget_bounds_resident_edges() {
        let d = tmpdir("budget");
        let g = random_graph(532, 120, 800, 4);
        let total = g.num_edges();
        let budget = total / 5;
        let stem = d.join("big");
        write_graph(&stem, &g).unwrap();
        let sp = spill_from_files(
            &stem.with_extension("edges"),
            &stem.with_extension("labels"),
            &SpillConfig {
                mem_budget_edges: budget,
                keep: true,
                ..SpillConfig::new(&d)
            },
        )
        .unwrap();
        assert!(
            sp.plan.shards() >= 5,
            "budget {budget} of {total} edges must raise the shard count"
        );
        // the resident set per shard load is that shard's record count:
        // within 2x of the budget even with hubs (the balance headroom)
        for f in &sp.files {
            let records = spill_records(f);
            assert!(
                records <= 2 * budget,
                "shard file {} holds {records} edges, budget {budget}",
                f.display()
            );
        }
        // and the embed is still exact — while every shard's slice was
        // smaller than the whole edge list
        let expect = SparseGee::fast().embed(&g, &GeeOptions::ALL);
        let z = embed_out_of_core(&sp, &GeeOptions::ALL).unwrap();
        assert_eq!(z.data, expect.data);
    }

    #[test]
    fn spill_from_files_matches_spill_from_graph() {
        let d1 = tmpdir("files");
        let d2 = tmpdir("graph");
        let g = random_graph(533, 60, 300, 3);
        let stem = d1.join("g");
        write_graph(&stem, &g).unwrap();
        let spf = spill_from_files(
            &stem.with_extension("edges"),
            &stem.with_extension("labels"),
            &SpillConfig { shards: 3, ..SpillConfig::new(&d1) },
        )
        .unwrap();
        let spg = spill_from_graph(
            &g,
            &SpillConfig { shards: 3, ..SpillConfig::new(&d2) },
        )
        .unwrap();
        assert_eq!(spf.plan.k, spg.plan.k);
        assert_eq!(spf.plan.bounds, spg.plan.bounds);
        assert_eq!(spf.labels, spg.labels);
        let opts = GeeOptions::new(true, false, true);
        let zf = embed_out_of_core(&spf, &opts).unwrap();
        let zg = embed_out_of_core(&spg, &opts).unwrap();
        assert_eq!(zf.data, zg.data);
    }

    #[test]
    fn concurrent_spills_into_one_config_dir_never_collide() {
        // regression: two spills sharing one SpillConfig::dir used to
        // write the same shard_N.edges paths and silently clobber each
        // other — each spill now gets its own subdirectory
        let d = tmpdir("collide");
        let cfg = SpillConfig { shards: 3, ..SpillConfig::new(&d) };
        let g1 = random_graph(536, 70, 400, 3);
        let g2 = random_graph(537, 90, 500, 3);
        let sp1 = spill_from_graph(&g1, &cfg).unwrap();
        let sp2 = spill_from_graph(&g2, &cfg).unwrap();
        assert_ne!(sp1.dir, sp2.dir, "each spill must own a unique directory");
        for (f1, f2) in sp1.files.iter().zip(&sp2.files) {
            assert_ne!(f1, f2);
        }
        // both embed bitwise even though they coexisted
        for (g, sp) in [(&g1, &sp1), (&g2, &sp2)] {
            let expect = SparseGee::fast().embed(g, &GeeOptions::ALL);
            let z = embed_out_of_core(sp, &GeeOptions::ALL).unwrap();
            assert_eq!(z.data, expect.data);
        }
        // drop removes each spill's whole subdirectory, not the parent
        let (d1, d2) = (sp1.dir.clone(), sp2.dir.clone());
        drop(sp1);
        drop(sp2);
        assert!(!d1.exists() && !d2.exists());
        assert!(d.exists(), "the shared parent dir must survive");
    }

    #[test]
    fn spill_file_size_is_exactly_records_times_record_size() {
        // regression guard for the binary data plane: a spill writer that
        // silently falls back to text (or grows any per-record framing)
        // changes the file length, and the remote dispatcher streams
        // spill files as raw frames whose length must be the byte count
        // of `records x 16` — so the size is pinned exactly, per shard
        let d = tmpdir("exact");
        let g = random_graph(535, 90, 520, 3);
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 4, keep: true, ..SpillConfig::new(&d) },
        )
        .unwrap();
        // independently count each shard's expected record copies from
        // the plan (an edge lands in both endpoints' shards when they
        // differ, once when they share one)
        let mut expect = vec![0u64; sp.plan.shards()];
        for i in 0..g.num_edges() {
            let sa = sp.plan.shard_of(g.src[i] as usize);
            let sb = sp.plan.shard_of(g.dst[i] as usize);
            expect[sa] += 1;
            if sb != sa {
                expect[sb] += 1;
            }
        }
        for (s, f) in sp.files.iter().enumerate() {
            let bytes = fs::metadata(f).unwrap().len();
            assert_eq!(
                bytes,
                expect[s] * super::super::codec::EDGE_RECORD_BYTES as u64,
                "{}: spill bytes must be exactly records x record_size",
                f.display()
            );
        }
        // and the binary records decode back to the graph's exact edges
        let mut total = 0usize;
        for f in &sp.files {
            total += spill_records(f);
        }
        assert_eq!(total as u64, expect.iter().sum::<u64>());
    }

    #[test]
    fn out_of_range_edge_is_rejected() {
        let d = tmpdir("oob");
        fs::write(d.join("bad.edges"), "0 9\n").unwrap();
        fs::write(d.join("bad.labels"), "0\n1\n").unwrap();
        let err = spill_from_files(
            &d.join("bad.edges"),
            &d.join("bad.labels"),
            &SpillConfig::new(&d),
        )
        .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn spill_files_removed_on_drop_unless_kept() {
        let d = tmpdir("drop");
        let g = random_graph(534, 20, 60, 2);
        let files = {
            let sp =
                spill_from_graph(&g, &SpillConfig { shards: 2, ..SpillConfig::new(&d) })
                    .unwrap();
            sp.files.clone()
        };
        for f in &files {
            assert!(!f.exists(), "{} must be cleaned up", f.display());
        }
        let kept = {
            let sp = spill_from_graph(
                &g,
                &SpillConfig { shards: 2, keep: true, ..SpillConfig::new(&d) },
            )
            .unwrap();
            sp.files.clone()
        };
        for f in &kept {
            assert!(f.exists(), "{} must be kept", f.display());
        }
    }
}
