//! Vertex-range-sharded GEE — scale-out past one process's memory and
//! threads (ROADMAP "sharding / multi-process" item; the scale framing of
//! One-Hot GEE, arXiv:2109.13098, and the row-independence observation of
//! Edge-Parallel GEE, arXiv:2402.04403, made concrete).
//!
//! Two phases, exact by construction:
//!
//! 1. **Global pass** ([`plan::GlobalPass`]) — one streaming sweep over
//!    the edge list computes class counts (via labels → `1/n_k` weights),
//!    weighted degrees, and per-vertex directed-slot counts; vertices are
//!    then split into contiguous nnz-balanced shards
//!    ([`crate::sparse::partition::nnz_chunks_u64`]).
//! 2. **Shard pass** ([`local`]) — each shard embeds its own rows from
//!    its incident edges plus the phase-1 globals, through the crate's
//!    single per-row accumulation kernel. Rows are disjoint, so outputs
//!    concatenate with no merge; every row is produced in the same op
//!    order as the fused serial engine, so the result is
//!    **bitwise-identical** to `SparseGee::fast()`.
//!
//! Three execution backends:
//! * **in-process** ([`ShardedGee`], `Engine::Sharded`) — shards run on
//!   scoped threads, each worker thread holding one pooled
//!   [`EmbedWorkspace`] reused across its shards. Because each shard's
//!   index structure is local, graphs whose *global* directed-edge count
//!   overflows the u32 index space embed here instead of erroring.
//! * **out-of-core** ([`spill::embed_out_of_core`]) — edges stream from
//!   disk: one pass spills each shard's incident edges to its own file,
//!   then shards load one at a time, so peak residency is one shard's
//!   slice (+ O(n) vectors) no matter how large the edge list is.
//! * **multi-process** ([`process::embed_multiprocess`]) — worker
//!   processes (`gee shard-worker`) each embed one spilled shard,
//!   exchanging data via the [`codec`] binary record files (raw LE bit
//!   patterns — exact by construction; the worker still reads the
//!   legacy text formats for old drivers), scheduled by a rolling slot
//!   pool.
//! * **distributed** ([`dispatch::embed_remote`]) — shard workers are
//!   `gee shard-serve` daemons on other machines; the driver streams
//!   each shard's spill file over TCP as one raw binary frame and ships
//!   the global vectors once per connection under a content hash
//!   ([`remote`]'s wire v2; legacy daemons get the v1 text protocol via
//!   per-connection negotiation), and a placement layer with rolling
//!   slots health-probes endpoints and requeues a dead worker's shards
//!   onto survivors.

pub mod codec;
pub mod dispatch;
pub mod local;
pub mod plan;
pub mod process;
pub mod remote;
pub mod spill;
pub mod worker;

pub use dispatch::{embed_remote, DispatchConfig, FleetSession};
pub use plan::{resolve_shards, GlobalPass, ShardPlan};
pub use process::{embed_multiprocess, ProcessConfig};
pub use remote::{DaemonConfig, ShardServer};
pub use spill::{embed_out_of_core, SpillConfig, SpilledShards};
pub use worker::{run_worker, WorkerArgs};

use crate::gee::options::GeeOptions;
use crate::gee::workspace::EmbedWorkspace;
use crate::graph::Graph;
use crate::sparse::partition::resolve_threads;
use crate::sparse::Dense;

/// In-process sharded engine: phase 1, bucket incident edges per shard,
/// embed shards on scoped threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardedGee {
    /// Shard count; 0 = auto (one per core, raised for u32 safety).
    pub shards: usize,
    /// Worker threads; 0 = auto (capped at the shard count).
    pub threads: usize,
}

impl ShardedGee {
    pub fn new(shards: usize) -> ShardedGee {
        ShardedGee { shards, threads: 0 }
    }

    pub fn with_threads(shards: usize, threads: usize) -> ShardedGee {
        ShardedGee { shards, threads }
    }

    /// Embed the graph. Bitwise-identical to `SparseGee::fast()` for any
    /// shard count and thread count.
    ///
    /// Memory note: the in-process lane stages a second copy of the edge
    /// list in per-shard buckets (~16 bytes per stored edge, plus one
    /// duplicate per shard-crossing edge) — the price of embedding a
    /// graph whose *index structures* overflow u32 without touching
    /// disk. When the edge list itself is the memory constraint, use the
    /// spill lanes ([`spill::embed_out_of_core`] /
    /// [`process::embed_multiprocess`]), which keep one shard resident
    /// at a time.
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Dense {
        let plan = ShardPlan::from_graph(g, self.shards);
        let s_count = plan.shards();
        let (k, n) = (g.k, g.n);

        // bucket incident stored edges per shard (counted first so each
        // bucket is one exact allocation); an edge crossing two shards is
        // copied into both, mirroring the on-disk spill format
        let mut copies = vec![0usize; s_count];
        for i in 0..g.num_edges() {
            let sa = plan.shard_of(g.src[i] as usize);
            let sb = plan.shard_of(g.dst[i] as usize);
            copies[sa] += 1;
            if sb != sa {
                copies[sb] += 1;
            }
        }
        let mut shard_src: Vec<Vec<u32>> =
            copies.iter().map(|&c| Vec::with_capacity(c)).collect();
        let mut shard_dst: Vec<Vec<u32>> =
            copies.iter().map(|&c| Vec::with_capacity(c)).collect();
        let mut shard_w: Vec<Vec<f64>> =
            copies.iter().map(|&c| Vec::with_capacity(c)).collect();
        for i in 0..g.num_edges() {
            let (a, b, w) = (g.src[i], g.dst[i], g.w[i]);
            let sa = plan.shard_of(a as usize);
            let sb = plan.shard_of(b as usize);
            shard_src[sa].push(a);
            shard_dst[sa].push(b);
            shard_w[sa].push(w);
            if sb != sa {
                shard_src[sb].push(a);
                shard_dst[sb].push(b);
                shard_w[sb].push(w);
            }
        }

        let scale = plan.scale_for(opts);
        let mut z = Dense::zeros(n, k);

        // hand each worker thread its shards' disjoint Z row blocks.
        // Hub shards (one mega-vertex dominates the shard's work —
        // see ShardPlan::hub_shards) are held back and run one at a
        // time with *all* threads fanning the hub's fixed-order
        // segments, instead of serializing one round-robin worker.
        let t = resolve_threads(self.threads).min(s_count.max(1));
        let mut assignments: Vec<Vec<(usize, &mut [f64])>> =
            (0..t).map(|_| Vec::new()).collect();
        let mut hub_work: Vec<(usize, &mut [f64])> = Vec::new();
        {
            let mut rest: &mut [f64] = &mut z.data;
            for s in 0..s_count {
                let (v0, v1) = plan.shard_range(s);
                let (here, next) =
                    std::mem::take(&mut rest).split_at_mut((v1 - v0) * k);
                rest = next;
                if t > 1 && plan.hub_shards.binary_search(&s).is_ok() {
                    hub_work.push((s, here));
                } else {
                    assignments[s % t].push((s, here));
                }
            }
        }

        let plan_ref = &plan;
        let scale_ref = scale.as_deref();
        let (src_ref, dst_ref, w_ref) = (&shard_src, &shard_dst, &shard_w);
        let labels_ref = &g.labels;
        std::thread::scope(|sc| {
            for work in assignments {
                sc.spawn(move || {
                    // one pooled workspace per worker thread, reused
                    // across all of its shards
                    let mut ws = EmbedWorkspace::new();
                    for (s, out) in work {
                        let (v0, v1) = plan_ref.shard_range(s);
                        local::embed_shard(
                            &src_ref[s],
                            &dst_ref[s],
                            &w_ref[s],
                            v0,
                            v1,
                            labels_ref,
                            &plan_ref.wv,
                            scale_ref,
                            k,
                            opts,
                            &mut ws,
                            out,
                        );
                    }
                });
            }
        });

        // hub shards, one at a time, all threads on each
        if !hub_work.is_empty() {
            let mut ws = EmbedWorkspace::new();
            for (s, out) in hub_work {
                let (v0, v1) = plan.shard_range(s);
                local::embed_shard_par(
                    &shard_src[s],
                    &shard_dst[s],
                    &shard_w[s],
                    v0,
                    v1,
                    &g.labels,
                    &plan.wv,
                    scale.as_deref(),
                    k,
                    opts,
                    t,
                    &mut ws,
                    out,
                );
            }
        }
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::gee::Engine;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.08 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(2, 2, 2.5);
        g
    }

    #[test]
    fn sharded_bitwise_matches_fused_any_shard_count() {
        let g = random_graph(521, 150, 900, 4);
        for opts in GeeOptions::table_order() {
            let fused = SparseGee::fast().embed(&g, &opts);
            for s in [1usize, 2, 3, 7, 16] {
                let z = ShardedGee::new(s).embed(&g, &opts);
                assert_eq!(
                    z.data, fused.data,
                    "sharded s={s} not bitwise vs fused at {opts:?}"
                );
            }
        }
    }

    #[test]
    fn sharded_matches_published_sparse_engine() {
        let g = random_graph(522, 100, 600, 3);
        for opts in GeeOptions::table_order() {
            let reference = Engine::Sparse.embed(&g, &opts).unwrap();
            let z = ShardedGee::with_threads(4, 2).embed(&g, &opts);
            assert!(
                reference.max_abs_diff(&z) <= 1e-12,
                "sharded vs sparse at {opts:?}"
            );
        }
    }

    #[test]
    fn thread_count_never_changes_output() {
        let g = random_graph(523, 80, 400, 3);
        let opts = GeeOptions::ALL;
        let base = ShardedGee::with_threads(5, 1).embed(&g, &opts);
        for t in [2usize, 3, 8] {
            let z = ShardedGee::with_threads(5, t).embed(&g, &opts);
            assert_eq!(z.data, base.data, "t={t} changed sharded output");
        }
    }

    #[test]
    fn hub_shard_splitting_stays_bitwise() {
        use crate::sparse::partition::HUB_SEGMENT_NNZ;
        let n = 64usize;
        let mut rng = Rng::new(525);
        let mut g = Graph::new(n, 3);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        // hub vertex 0: well past the segmentation threshold
        for i in 0..(HUB_SEGMENT_NNZ + 500) {
            g.add_edge(0, (1 + (i % (n - 1))) as u32, rng.f64() + 0.1);
        }
        for _ in 0..300 {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        let plan = ShardPlan::from_graph(&g, 4);
        assert!(!plan.hub_shards.is_empty(), "hub vertex must be flagged");
        for opts in GeeOptions::table_order() {
            let fused = SparseGee::fast().embed(&g, &opts);
            for t in [1usize, 2, 4] {
                let z = ShardedGee::with_threads(4, t).embed(&g, &opts);
                assert_eq!(z.data, fused.data, "hub shard t={t} at {opts:?}");
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        // empty graph
        let g0 = Graph::new(4, 2);
        let z = ShardedGee::new(3).embed(&g0, &GeeOptions::ALL);
        assert_eq!((z.nrows, z.ncols), (4, 2));
        assert!(z.data.iter().all(|&x| x == 0.0));
        // zero vertices
        let ge = Graph::new(0, 0);
        let z = ShardedGee::new(2).embed(&ge, &GeeOptions::NONE);
        assert_eq!(z.data.len(), 0);
        // single vertex, self loop
        let mut g1 = Graph::new(1, 1);
        g1.labels[0] = 0;
        g1.add_edge(0, 0, 2.0);
        let expect = SparseGee::fast().embed(&g1, &GeeOptions::ALL);
        let z = ShardedGee::new(8).embed(&g1, &GeeOptions::ALL);
        assert_eq!(z.data, expect.data);
        // more shards than vertices
        let g2 = random_graph(524, 3, 5, 2);
        let expect = SparseGee::fast().embed(&g2, &GeeOptions::NONE);
        let z = ShardedGee::new(64).embed(&g2, &GeeOptions::NONE);
        assert_eq!(z.data, expect.data);
    }
}
