//! The shard-worker process body — what runs behind the CLI's
//! `shard-worker` subcommand. Kept in the library so the multi-process
//! protocol (read globals → embed shard rows → write Z rows) is unit- and
//! integration-testable without spawning, and so the CLI stays a thin
//! argument shim.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

/// Parse one Z row (whitespace-separated, exactly `k` values) into
/// `out_row`. Shared by every consumer of worker output — the
/// multi-process file exchange and the TCP fleet client — so the row
/// grammar has exactly one implementation.
pub(crate) fn parse_z_row(line: &str, k: usize, out_row: &mut [f64]) -> Result<()> {
    debug_assert_eq!(out_row.len(), k);
    let mut col = 0usize;
    for tok in line.split_whitespace() {
        if col >= k {
            bail!("more than {k} columns");
        }
        out_row[col] = tok.parse::<f64>().context("bad value")?;
        col += 1;
    }
    if col != k {
        bail!("{col} columns, expected {k}");
    }
    Ok(())
}

/// Write Z rows (`rows × k`, row-major) as tab-separated
/// shortest-roundtrip text, one row per line — the inverse of
/// [`parse_z_row`], bitwise under re-parse.
pub(crate) fn write_z_rows(
    f: &mut impl Write,
    out: &[f64],
    rows: usize,
    k: usize,
) -> std::io::Result<()> {
    for r in 0..rows {
        for (i, v) in out[r * k..(r + 1) * k].iter().enumerate() {
            if i > 0 {
                f.write_all(b"\t")?;
            }
            write!(f, "{v}")?;
        }
        f.write_all(b"\n")?;
    }
    Ok(())
}

use super::codec;
use super::local::embed_shard;
use crate::gee::options::GeeOptions;
use crate::gee::weights::weight_values;
use crate::gee::workspace::EmbedWorkspace;
use crate::graph::io::{read_f64_vec, read_label_vec};

/// One worker invocation: embed rows `[row0, row1)` of an `n × k`
/// embedding from a shard's incident-edge file plus the shared globals.
#[derive(Clone, Debug)]
pub struct WorkerArgs {
    /// The shard's incident edges (spill format, global ids).
    pub edges: PathBuf,
    /// Shared global labels (one per vertex line).
    pub labels: PathBuf,
    /// Shared global weighted degrees (one f64 per line).
    pub deg: PathBuf,
    pub n: usize,
    pub k: usize,
    pub row0: usize,
    pub row1: usize,
    pub options: GeeOptions,
    /// Where to write the shard's Z rows (one row per line).
    pub out: PathBuf,
}

/// Run the worker: everything global is *re-derived from the shipped
/// files* with the same formulas the in-process engine uses, and every
/// f64 crossed the process boundary either as a raw little-endian bit
/// pattern (`.bin` files, the [`codec`] record formats the current
/// driver ships) or as shortest-roundtrip text (the legacy formats, so
/// old drivers can still spawn this binary) — both exact, so the rows
/// written here are bitwise-identical to the in-process shard pass.
pub fn run_worker(args: &WorkerArgs) -> Result<()> {
    if args.row0 > args.row1 || args.row1 > args.n {
        bail!("bad row range [{}, {}) for n={}", args.row0, args.row1, args.n);
    }
    let labels = if codec::is_binary_path(&args.labels) {
        codec::read_i32s_file(&args.labels)?
    } else {
        read_label_vec(&args.labels)?
    };
    if labels.len() != args.n {
        bail!("labels file has {} entries, expected n={}", labels.len(), args.n);
    }
    // one label contract for both file formats (the text reader already
    // rejects < -1 at parse time; re-checking is harmless)
    for &l in &labels {
        codec::validate_label(l, args.k)?;
    }
    let deg = if codec::is_binary_path(&args.deg) {
        codec::read_f64s_file(&args.deg)?
    } else {
        read_f64_vec(&args.deg)?
    };
    if deg.len() != args.n {
        bail!("degree file has {} entries, expected n={}", deg.len(), args.n);
    }

    let wv = weight_values(&labels, args.k);
    let scale = super::plan::scale_from_deg(&deg, &args.options);

    let (mut src, mut dst, mut w) = (Vec::new(), Vec::new(), Vec::new());
    codec::for_each_edge_auto(&args.edges, |a, b, ww| {
        src.push(a);
        dst.push(b);
        w.push(ww);
    })?;
    if let Some(&v) = src.iter().chain(dst.iter()).find(|&&v| v as usize >= args.n) {
        bail!("shard edge endpoint {v} out of range for n={}", args.n);
    }

    let rows = args.row1 - args.row0;
    let mut out = vec![0.0f64; rows * args.k];
    let mut ws = EmbedWorkspace::new();
    embed_shard(
        &src,
        &dst,
        &w,
        args.row0,
        args.row1,
        &labels,
        &wv,
        scale.as_deref(),
        args.k,
        &args.options,
        &mut ws,
        &mut out,
    );

    if codec::is_binary_path(&args.out) {
        // raw f64 records, rows*k of them — the parent validates the
        // exact byte count, so a torn write cannot pass silently
        codec::write_f64s_file(&args.out, &out)?;
    } else {
        let mut f = BufWriter::new(
            File::create(&args.out)
                .with_context(|| format!("create {}", args.out.display()))?,
        );
        write_z_rows(&mut f, &out, rows, args.k)
            .with_context(|| format!("write {}", args.out.display()))?;
        f.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::graph::io::write_f64_vec;
    use crate::graph::Graph;
    use crate::shard::plan::ShardPlan;
    use crate::shard::spill::{spill_from_graph, SpillConfig};
    use crate::util::rng::Rng;

    #[test]
    fn worker_rows_roundtrip_bitwise_through_files() {
        // drive run_worker in-process over real spill files and parse its
        // output exactly as the parent does
        let dir = std::env::temp_dir()
            .join(format!("gee_worker_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        let mut rng = Rng::new(541);
        let (n, k) = (70, 3);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for c in 0..k {
            g.labels[c] = c as i32;
        }
        for _ in 0..350 {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(5, 5, 1.25);

        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 3, keep: true, ..SpillConfig::new(&dir) },
        )
        .unwrap();
        let plan: &ShardPlan = &sp.plan;
        let labels_path = dir.join("w.labels");
        std::fs::write(
            &labels_path,
            g.labels
                .iter()
                .map(|l| l.to_string())
                .collect::<Vec<_>>()
                .join("\n"),
        )
        .unwrap();
        let deg_path = dir.join("w.deg");
        write_f64_vec(&deg_path, &plan.deg).unwrap();

        for opts in crate::gee::GeeOptions::table_order() {
            let whole = SparseGee::fast().embed(&g, &opts);
            for s in 0..plan.shards() {
                let (v0, v1) = plan.shard_range(s);
                let out_path = dir.join(format!("w_z_{s}.tsv"));
                run_worker(&WorkerArgs {
                    edges: sp.files[s].clone(),
                    labels: labels_path.clone(),
                    deg: deg_path.clone(),
                    n,
                    k,
                    row0: v0,
                    row1: v1,
                    options: opts,
                    out: out_path.clone(),
                })
                .unwrap();
                let text = std::fs::read_to_string(&out_path).unwrap();
                let got: Vec<f64> = text
                    .lines()
                    .flat_map(|l| l.split_whitespace())
                    .map(|t| t.parse().unwrap())
                    .collect();
                assert_eq!(
                    got,
                    whole.data[v0 * k..v1 * k].to_vec(),
                    "worker shard {s} rows drifted at {opts:?}"
                );
            }
        }
    }

    #[test]
    fn worker_binary_exchange_is_bitwise() {
        // the current driver's exchange: binary spill edges, binary
        // labels/degree files, binary Z output — raw bit patterns end to
        // end, asserted bitwise against the in-core engine
        let dir = std::env::temp_dir()
            .join(format!("gee_worker_bin_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        let mut rng = Rng::new(543);
        let (n, k) = (60, 3);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for c in 0..k {
            g.labels[c] = c as i32;
        }
        for _ in 0..300 {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 2, keep: true, ..SpillConfig::new(&dir) },
        )
        .unwrap();
        let labels_path = dir.join("g.labels.bin");
        crate::shard::codec::write_i32s_file(&labels_path, &g.labels).unwrap();
        let deg_path = dir.join("g.deg.bin");
        crate::shard::codec::write_f64s_file(&deg_path, &sp.plan.deg).unwrap();

        let opts = crate::gee::GeeOptions::ALL;
        let whole = SparseGee::fast().embed(&g, &opts);
        for s in 0..sp.plan.shards() {
            let (v0, v1) = sp.plan.shard_range(s);
            let out_path = dir.join(format!("z_{s}.bin"));
            run_worker(&WorkerArgs {
                edges: sp.files[s].clone(),
                labels: labels_path.clone(),
                deg: deg_path.clone(),
                n,
                k,
                row0: v0,
                row1: v1,
                options: opts,
                out: out_path.clone(),
            })
            .unwrap();
            let got = crate::shard::codec::read_f64s_file(&out_path).unwrap();
            assert_eq!(
                got,
                whole.data[v0 * k..v1 * k].to_vec(),
                "binary worker shard {s} rows drifted"
            );
        }
        // binary labels must obey the same sentinel contract as text
        let bad = dir.join("bad.labels.bin");
        let bad_labels = vec![-5i32; n];
        crate::shard::codec::write_i32s_file(&bad, &bad_labels).unwrap();
        let err = run_worker(&WorkerArgs {
            edges: sp.files[0].clone(),
            labels: bad,
            deg: deg_path.clone(),
            n,
            k,
            row0: 0,
            row1: 1,
            options: opts,
            out: dir.join("z_bad.bin"),
        })
        .unwrap_err();
        assert!(err.to_string().contains("< -1"), "{err}");
    }

    #[test]
    fn worker_rejects_inconsistent_inputs() {
        let dir = std::env::temp_dir()
            .join(format!("gee_worker_bad_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("e.edges"), "0 1\n").unwrap();
        std::fs::write(dir.join("l.labels"), "0\n1\n").unwrap();
        write_f64_vec(&dir.join("d.deg"), &[1.0, 1.0]).unwrap();
        let base = WorkerArgs {
            edges: dir.join("e.edges"),
            labels: dir.join("l.labels"),
            deg: dir.join("d.deg"),
            n: 2,
            k: 2,
            row0: 0,
            row1: 2,
            options: crate::gee::GeeOptions::NONE,
            out: dir.join("z.tsv"),
        };
        assert!(run_worker(&base).is_ok());
        assert!(run_worker(&WorkerArgs { n: 3, ..base.clone() }).is_err());
        assert!(run_worker(&WorkerArgs { k: 1, ..base.clone() }).is_err());
        assert!(run_worker(&WorkerArgs { row1: 5, ..base.clone() }).is_err());
    }
}
