//! TCP transport for the sharded engine — shard workers on other
//! machines, no shared filesystem (the ROADMAP "distribute the sharded
//! lane" item: the worker protocol was already file/process-based; this
//! is the transport half, [`super::dispatch`] is the placement half).
//!
//! Style follows `coordinator/server.rs`: verb lines over stdlib
//! `TcpListener`, one thread per connection, no new dependencies. The
//! worker re-derives the weight vector and Laplacian scale from the
//! shipped globals through the same single implementations the
//! in-process engines use ([`weight_values`],
//! [`scale_from_deg`](super::plan::scale_from_deg)) — so remote rows are
//! **bitwise-identical** to `SparseGee::fast()`, the same contract
//! `shard/worker.rs` gives the multi-process lane.
//!
//! ## Protocol v2 (binary) — the default
//!
//! Verb lines stay text; bodies are [`super::codec`] binary frames
//! (`u64` LE length prefix + fixed-width LE records), so every f64
//! crosses the wire as its raw bit pattern — parity is bitwise **by
//! construction**, no shortest-roundtrip dance. A driver negotiates
//! once per connection, ships the global vectors once per connection
//! under a content hash, then references them per shard:
//!
//! ```text
//! -> HELLO2
//! <- HELLO2                          (a legacy daemon answers ERR and
//!                                     closes; the driver reconnects in
//!                                     text mode — see the README matrix)
//! -> GLOBALS g=<fnv64 hex> n=<n> k=<k>
//! -> <labels frame: n i32 records>
//! -> <degrees frame: n f64 records>
//! <- OK
//! -> SHARD2 g=<hash> n= k= row0= row1= lap= diag= cor=
//! -> <edges frame: 16-byte edge records — a spill file streamed raw>
//! <- OK rows=<v1 - v0>
//! <- <Z frame: rows*k f64 records>
//! -> SHARD2 ... (same hash, no globals resent)   ...
//! ```
//!
//! The daemon caches the `GLOBALS` vectors (and the derived weight
//! vector) per connection under the declared hash, re-hashes the bytes
//! it actually received and rejects a mismatch, so per-job fleet traffic
//! is O(W·n + E) instead of O(S·n + E). A `GLOBALS` with a new hash
//! simply replaces the cached entry (one per connection — a connection
//! serves one job at a time, and the hash pins the job epoch).
//!
//! ## Protocol v1 (text) — kept for mixed fleets
//!
//! The original line exchange (`SHARD` header → n label lines → n
//! degree lines → edge lines → `END`, answered by `OK rows=` + text Z
//! rows + `DONE`), every f64 in shortest-roundtrip form. Old drivers
//! against this daemon, and new drivers against old daemons, both keep
//! working; `ShardServer::start_text_only` serves only v1, emulating a
//! legacy daemon for negotiation tests.
//!
//! Either way: `ERR <message>` (after which the daemon closes the
//! connection — a half-consumed body has no well-defined resync point),
//! `PING` → `PONG` for health checks and placement probes, `QUIT`
//! closes. Admission is bounded: headers and frame length prefixes are
//! rejected against the `MAX_FRAME_*` caps *before* anything is
//! allocated from them, bodies are consumed in bounded chunks
//! ([`codec::FRAME_CHUNK_BYTES`]) with buffers growing only as data
//! actually arrives, and the one header-driven allocation — the
//! `rows × k` output block — is capped at [`MAX_FRAME_CELLS`] (2 GiB),
//! the same worst-case the coordinator wire protocol admits.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::codec;
use super::local::embed_shard;
use super::plan::scale_from_deg;
use crate::gee::options::GeeOptions;
use crate::gee::weights::weight_values;
use crate::gee::workspace::EmbedWorkspace;
use crate::graph::io::parse_edge_fields;
use crate::util::fault::{FaultPlan, FaultyStream};
use crate::util::retry;

/// Vertex ids travel as u32, so no header may claim more vertices.
pub const MAX_FRAME_VERTICES: usize = u32::MAX as usize;
/// Class-count sanity bound (the weight pass allocates O(k)).
pub const MAX_FRAME_CLASSES: usize = 1 << 24;
/// Cap on `rows * k` output cells per request — the one allocation
/// driven by header values alone rather than by received data (2 GiB of
/// f64 at the cap, the same worst-case the coordinator's
/// `MAX_WIRE_CELLS` admits). A legitimate fleet driver that trips this
/// has very wide embeddings on very large shards: raise the shard count
/// so each shard's row block shrinks.
pub const MAX_FRAME_CELLS: usize = 1 << 28;
/// Cap on edge lines accepted per request, enforced as the stream
/// arrives. A legitimate shard is far below this (`resolve_shards`
/// targets ≤ `MAX_INDEX/4` directed slots per shard); without the cap a
/// driver that never sends `END` grows the daemon's edge buffers until
/// it OOMs — the same exhaustion `coordinator/server.rs` guards with
/// `MAX_WIRE_EDGES`.
pub const MAX_FRAME_EDGES: usize = 1 << 31;

/// A `SHARD` request header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    pub n: usize,
    pub k: usize,
    pub row0: usize,
    pub row1: usize,
    pub options: GeeOptions,
}

impl ShardHeader {
    /// Parse the key=val fields after the `SHARD` verb.
    pub fn parse(header: &str) -> Result<ShardHeader> {
        Ok(parse_shard_header(header, "SHARD")?.0)
    }

    /// Parse a `SHARD2` header: same fields plus the required `g=`
    /// GLOBALS content hash this shard references and the optional
    /// `keep=` flag asking the daemon to retain the edge payload for
    /// later `RESHARD` rounds.
    pub fn parse_v2(header: &str) -> Result<(ShardHeader, u64, bool)> {
        let (h, hash, keep) = parse_shard_header(header, "SHARD2")?;
        Ok((h, hash.context("SHARD2 requires g= (the GLOBALS content hash)")?, keep))
    }

    /// Parse a `RESHARD` header: the `SHARD2` grammar with no edge frame
    /// to follow — the daemon re-embeds the edges cached by an earlier
    /// `SHARD2 keep=1` for the same row range.
    pub fn parse_reshard(header: &str) -> Result<(ShardHeader, u64)> {
        let (h, hash, _) = parse_shard_header(header, "RESHARD")?;
        Ok((h, hash.context("RESHARD requires g= (the GLOBALS content hash)")?))
    }

    /// Bounds gate, applied before anything is allocated from the header.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 {
            bail!("SHARD requires n >= 1");
        }
        if self.n > MAX_FRAME_VERTICES {
            bail!("n={} exceeds the wire limit {MAX_FRAME_VERTICES}", self.n);
        }
        if self.k > MAX_FRAME_CLASSES {
            bail!("k={} exceeds the wire limit {MAX_FRAME_CLASSES}", self.k);
        }
        if self.row0 > self.row1 || self.row1 > self.n {
            bail!("bad row range [{}, {}) for n={}", self.row0, self.row1, self.n);
        }
        let rows = self.row1 - self.row0;
        match rows.checked_mul(self.k) {
            Some(cells) if cells <= MAX_FRAME_CELLS => Ok(()),
            _ => bail!(
                "rows*k = {rows}*{} exceeds the wire limit {MAX_FRAME_CELLS}",
                self.k
            ),
        }
    }
}

/// The shared `SHARD`/`SHARD2`/`RESHARD` key=val grammar. The `g=` hash
/// and `keep=` retention keys are v2-only (an unknown-arg error for v1,
/// so old daemons keep rejecting headers they cannot honor).
fn parse_shard_header(
    header: &str,
    verb: &str,
) -> Result<(ShardHeader, Option<u64>, bool)> {
    let mut parts = header.split_whitespace();
    if parts.next() != Some(verb) {
        bail!("expected {verb}, got '{header}'");
    }
    let (mut n, mut k, mut row0, mut row1) = (None, None, None, None);
    let (mut lap, mut diag, mut cor) = (false, false, false);
    let mut hash = None;
    let mut keep = false;
    let mut parse_bool = |val: &str, key: &str| -> Result<bool> {
        match val {
            "0" => Ok(false),
            "1" => Ok(true),
            other => bail!("bad {key}={other} (use 0 or 1)"),
        }
    };
    for p in parts {
        let (key, val) = p.split_once('=').with_context(|| format!("{verb} args are key=val"))?;
        match key {
            "n" => n = Some(val.parse::<usize>().context("bad n")?),
            "k" => k = Some(val.parse::<usize>().context("bad k")?),
            "row0" => row0 = Some(val.parse::<usize>().context("bad row0")?),
            "row1" => row1 = Some(val.parse::<usize>().context("bad row1")?),
            "lap" => lap = parse_bool(val, "lap")?,
            "diag" => diag = parse_bool(val, "diag")?,
            "cor" => cor = parse_bool(val, "cor")?,
            "g" if verb != "SHARD" => {
                hash = Some(parse_hash(val)?);
            }
            "keep" if verb == "SHARD2" => keep = parse_bool(val, "keep")?,
            other => bail!("unknown {verb} arg '{other}'"),
        }
    }
    let h = ShardHeader {
        n: n.with_context(|| format!("{verb} requires n="))?,
        k: k.with_context(|| format!("{verb} requires k="))?,
        row0: row0.with_context(|| format!("{verb} requires row0="))?,
        row1: row1.with_context(|| format!("{verb} requires row1="))?,
        options: GeeOptions::new(lap, diag, cor),
    };
    h.validate()?;
    Ok((h, hash, keep))
}

fn parse_hash(val: &str) -> Result<u64> {
    u64::from_str_radix(val, 16).with_context(|| format!("bad content hash '{val}'"))
}

/// A `GLOBALS` header: declared content hash + vector dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GlobalsHeader {
    pub hash: u64,
    pub n: usize,
    pub k: usize,
}

impl GlobalsHeader {
    /// Parse and bounds-gate a `GLOBALS g=<hex> n=<n> k=<k>` line —
    /// nothing is allocated from the header before this passes.
    pub fn parse(header: &str) -> Result<GlobalsHeader> {
        Self::parse_verb(header, "GLOBALS")
    }

    /// Parse a `RELABEL` header — the `GLOBALS` grammar under a
    /// different verb: only the label frame follows (the cached degrees
    /// are round-invariant), and `g=` declares the hash of the *new*
    /// labels against the cached degrees.
    pub fn parse_relabel(header: &str) -> Result<GlobalsHeader> {
        Self::parse_verb(header, "RELABEL")
    }

    fn parse_verb(header: &str, verb: &str) -> Result<GlobalsHeader> {
        let mut parts = header.split_whitespace();
        if parts.next() != Some(verb) {
            bail!("expected {verb}, got '{header}'");
        }
        let (mut hash, mut n, mut k) = (None, None, None);
        for p in parts {
            let (key, val) = p
                .split_once('=')
                .with_context(|| format!("{verb} args are key=val"))?;
            match key {
                "g" => hash = Some(parse_hash(val)?),
                "n" => n = Some(val.parse::<usize>().context("bad n")?),
                "k" => k = Some(val.parse::<usize>().context("bad k")?),
                other => bail!("unknown {verb} arg '{other}'"),
            }
        }
        let h = GlobalsHeader {
            hash: hash.with_context(|| format!("{verb} requires g="))?,
            n: n.with_context(|| format!("{verb} requires n="))?,
            k: k.with_context(|| format!("{verb} requires k="))?,
        };
        if h.n == 0 {
            bail!("{verb} requires n >= 1");
        }
        if h.n > MAX_FRAME_VERTICES {
            bail!("n={} exceeds the wire limit {MAX_FRAME_VERTICES}", h.n);
        }
        if h.k > MAX_FRAME_CLASSES {
            bail!("k={} exceeds the wire limit {MAX_FRAME_CLASSES}", h.k);
        }
        Ok(h)
    }
}

/// Connections dropped because no header arrived within `idle_timeout`.
static REAPED_IDLE: AtomicU64 = AtomicU64::new(0);
/// `keep=1` payloads dropped because they outlived `keep_ttl`.
static EXPIRED_KEEPS: AtomicU64 = AtomicU64::new(0);
/// Live `keep=1` payloads across every connection in this process —
/// the leak gauge the chaos soak drives back to zero.
static CACHED_PAYLOADS: AtomicI64 = AtomicI64::new(0);

/// Process-wide daemon lifecycle counters:
/// `(idle connections reaped, keep=1 payloads expired, payloads live now)`.
/// Also served over the wire as the `STATS` verb.
pub fn reap_stats() -> (u64, u64, i64) {
    (
        REAPED_IDLE.load(Ordering::Relaxed),
        EXPIRED_KEEPS.load(Ordering::Relaxed),
        CACHED_PAYLOADS.load(Ordering::Relaxed),
    )
}

/// Daemon lifecycle and robustness knobs (CLI: `gee shard-serve`).
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Serve only the v1 text protocol (legacy-daemon emulation).
    pub text_only: bool,
    /// Reap a connection when no request header arrives within this
    /// budget — a dead driver cannot pin a thread (or its `keep=1`
    /// payloads) forever.
    pub idle_timeout: Option<Duration>,
    /// Per-read/write progress budget once a request has started.
    pub io_timeout: Option<Duration>,
    /// Drop `keep=1` edge payloads not re-embedded within this window;
    /// an expired range fails `RESHARD` with the usual typed error.
    pub keep_ttl: Option<Duration>,
    /// Deterministic fault plan armed on accepted connections (chaos
    /// testing; see [`crate::util::fault`]).
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            text_only: false,
            idle_timeout: Some(Duration::from_secs(300)),
            io_timeout: Some(Duration::from_secs(60)),
            keep_ttl: Some(Duration::from_secs(600)),
            fault: None,
        }
    }
}

/// Per-connection scratch: every buffer is reused across the pipelined
/// requests of one connection, so a fleet daemon serving a long driver
/// session settles into zero steady-state allocation growth. The same
/// label/degree buffers double as the wire-v2 GLOBALS cache: when
/// `g_hash` is set they hold the vectors (and derived weights) shipped
/// once by `GLOBALS`, and `SHARD2` requests reference them by hash. A
/// v1 `SHARD` request overwrites the buffers, so it invalidates the
/// cache.
struct ConnState {
    labels: Vec<i32>,
    deg: Vec<f64>,
    src: Vec<u32>,
    dst: Vec<u32>,
    w: Vec<f64>,
    out: Vec<f64>,
    ws: EmbedWorkspace,
    line: String,
    /// Cached GLOBALS fingerprint (with its dimensions and the derived
    /// weight vector) — `None` until a GLOBALS lands, and after any v1
    /// request clobbers the buffers.
    g_hash: Option<u64>,
    g_n: usize,
    g_k: usize,
    wv: Vec<f64>,
    /// Frame chunk scratch (bounded by [`codec::FRAME_CHUNK_BYTES`]).
    chunk: Vec<u8>,
    /// Edge payloads retained by `SHARD2 keep=1`, keyed by row range —
    /// round r>1 of an iterative job re-embeds them via `RESHARD`
    /// without the edges ever crossing the wire again. Structural
    /// validity only depends on `n`, so the cache survives `RELABEL`
    /// (the whole point) and is dropped when a `GLOBALS` re-dimensions
    /// the connection or a v1 request clobbers the buffers.
    cache: std::collections::HashMap<(usize, usize), CachedShard>,
}

/// One retained `SHARD2 keep=1` edge payload, stamped for TTL expiry.
struct CachedShard {
    src: Vec<u32>,
    dst: Vec<u32>,
    w: Vec<f64>,
    kept_at: Instant,
}

impl ConnState {
    fn new() -> ConnState {
        ConnState {
            labels: Vec::new(),
            deg: Vec::new(),
            src: Vec::new(),
            dst: Vec::new(),
            w: Vec::new(),
            out: Vec::new(),
            ws: EmbedWorkspace::new(),
            line: String::new(),
            g_hash: None,
            g_n: 0,
            g_k: 0,
            wv: Vec::new(),
            chunk: Vec::new(),
            cache: std::collections::HashMap::new(),
        }
    }

    /// Retain a payload, keeping the process-wide gauge in step
    /// (replacement of the same row range is not a net gain).
    fn cache_insert(&mut self, key: (usize, usize), val: CachedShard) {
        if self.cache.insert(key, val).is_none() {
            CACHED_PAYLOADS.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drop every retained payload (v1 clobber / GLOBALS re-dimension).
    fn cache_clear(&mut self) {
        let n = self.cache.len() as i64;
        if n > 0 {
            CACHED_PAYLOADS.fetch_sub(n, Ordering::Relaxed);
        }
        self.cache.clear();
    }

    /// Expire payloads older than `ttl`; counted so an operator can see
    /// dead drivers' memory being reclaimed.
    fn cache_purge_expired(&mut self, ttl: Option<Duration>) {
        let Some(ttl) = ttl else { return };
        if self.cache.is_empty() {
            return;
        }
        let before = self.cache.len();
        let now = Instant::now();
        self.cache
            .retain(|_, c| now.duration_since(c.kept_at) <= ttl);
        let dropped = (before - self.cache.len()) as i64;
        if dropped > 0 {
            EXPIRED_KEEPS.fetch_add(dropped as u64, Ordering::Relaxed);
            CACHED_PAYLOADS.fetch_sub(dropped, Ordering::Relaxed);
        }
    }
}

impl Drop for ConnState {
    fn drop(&mut self) {
        // a closing connection releases its retained payloads
        let n = self.cache.len() as i64;
        if n > 0 {
            CACHED_PAYLOADS.fetch_sub(n, Ordering::Relaxed);
        }
    }
}

/// A running shard-worker daemon bound to `addr()`.
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ShardServer {
    /// Bind (port 0 for ephemeral) and serve shard requests — wire v2
    /// plus the v1 text fallback. One thread per connection; a driver
    /// keeps one connection per dispatch slot, so connection count
    /// equals fleet slot count.
    pub fn start(bind: &str) -> Result<ShardServer> {
        Self::start_with_config(bind, DaemonConfig::default())
    }

    /// Serve only the v1 text protocol — `HELLO2`/`GLOBALS`/`SHARD2`
    /// draw the same `ERR` + close a pre-v2 daemon gives, so this is the
    /// stand-in for a legacy daemon in negotiation tests and the CI
    /// mixed-fleet smoke (CLI: `gee shard-serve --text-only`).
    pub fn start_text_only(bind: &str) -> Result<ShardServer> {
        Self::start_with_config(
            bind,
            DaemonConfig { text_only: true, ..DaemonConfig::default() },
        )
    }

    /// Bind and serve under explicit lifecycle/chaos configuration.
    pub fn start_with_config(bind: &str, cfg: DaemonConfig) -> Result<ShardServer> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let cfg = Arc::new(cfg);
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let cfg = Arc::clone(&cfg);
                        std::thread::spawn(move || {
                            let stream = FaultPlan::wrap(&cfg.fault, stream);
                            let _ = handle_connection(stream, &cfg);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ShardServer { addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting; in-flight connections finish on their own threads.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_connection(stream: FaultyStream, cfg: &DaemonConfig) -> Result<()> {
    let text_only = cfg.text_only;
    stream.set_nodelay(true).ok();
    // write progress budget: a peer that stops draining replies cannot
    // pin this thread forever
    stream.set_write_timeout(cfg.io_timeout).ok();
    // `try_clone` dups the fd but socket options live on the shared file
    // description, so this control handle flips the read budget between
    // the idle (header) phase and the in-request phase for both halves
    let ctl = stream.try_clone()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut st = ConnState::new();
    loop {
        st.line.clear();
        ctl.set_read_timeout(cfg.idle_timeout).ok();
        match reader.read_line(&mut st.line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e) if retry::is_timeout(&e) => {
                // no (complete) header within the idle budget: reap the
                // connection — and with it any retained keep=1 payloads
                REAPED_IDLE.fetch_add(1, Ordering::Relaxed);
                let _ = writeln!(writer, "ERR idle connection reaped (header deadline exceeded)");
                let _ = writer.flush();
                bail!("idle connection reaped (header deadline exceeded)");
            }
            Err(e) => return Err(e.into()),
        }
        ctl.set_read_timeout(cfg.io_timeout).ok();
        st.cache_purge_expired(cfg.keep_ttl);
        let line = st.line.trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line == "PING" {
            writeln!(writer, "PONG")?;
            writer.flush()?;
            continue;
        }
        if line == "STATS" {
            let (reaped, expired, cached) = reap_stats();
            writeln!(writer, "STATS cached={cached} reaped={reaped} expired={expired}")?;
            writer.flush()?;
            continue;
        }
        if line == "QUIT" {
            return Ok(());
        }
        if !text_only && line == "HELLO2" {
            // version negotiation: echoing the verb advertises wire v2
            writeln!(writer, "HELLO2")?;
            writer.flush()?;
            continue;
        }
        let served = if !text_only && line.starts_with("GLOBALS") {
            serve_globals(&line, &mut reader, &mut writer, &mut st)
        } else if !text_only && line.starts_with("SHARD2") {
            serve_shard2(&line, &mut reader, &mut writer, &mut st)
        } else if !text_only && line.starts_with("RELABEL") {
            serve_relabel(&line, &mut reader, &mut writer, &mut st)
        } else if !text_only && line.starts_with("RESHARD") {
            serve_reshard(&line, &mut writer, &mut st)
        } else {
            // v1 text request — or, in text-only mode, *any* v2 verb,
            // which fails here exactly as a pre-v2 daemon fails it
            // ("expected SHARD, got 'HELLO2'"), driving the driver's
            // reconnect-as-text fallback
            serve_shard(&line, &mut reader, &mut writer, &mut st)
        };
        match served {
            Ok(()) => writer.flush()?,
            Err(e) => {
                // after a failed request the body position is undefined —
                // report and drop the connection rather than resync-guess
                writeln!(writer, "ERR {e:#}")?;
                writer.flush()?;
                return Err(e);
            }
        }
    }
}

/// Serve one `SHARD` request: header → globals → edges → embed → rows.
fn serve_shard(
    header: &str,
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    st: &mut ConnState,
) -> Result<()> {
    let h = ShardHeader::parse(header)?;
    let (n, k) = (h.n, h.k);

    // a v1 request refills the label/degree buffers, clobbering any
    // cached GLOBALS — drop the fingerprint (and the retained edge
    // payloads that referenced its dimensions) so a later SHARD2 or
    // RESHARD cannot reference vectors that are no longer there
    st.g_hash = None;
    st.cache_clear();

    // globals: n labels, then n degrees — allocation tracks received data
    st.labels.clear();
    for i in 0..n {
        let t = read_trimmed(reader, &mut st.line)
            .with_context(|| format!("label line {}", i + 1))?;
        let l: i32 = t.parse().with_context(|| format!("bad label '{t}'"))?;
        codec::validate_label(l, k)?;
        st.labels.push(l);
    }
    st.deg.clear();
    for i in 0..n {
        let t = read_trimmed(reader, &mut st.line)
            .with_context(|| format!("degree line {}", i + 1))?;
        st.deg
            .push(t.parse::<f64>().with_context(|| format!("bad degree '{t}'"))?);
    }

    // the shard's incident edges, until END
    st.src.clear();
    st.dst.clear();
    st.w.clear();
    loop {
        let t = read_trimmed(reader, &mut st.line).context("edge line")?;
        if t == "END" {
            break;
        }
        let Some((a, b, w)) = parse_edge_fields(t)? else {
            continue;
        };
        if a as usize >= n || b as usize >= n {
            bail!("shard edge endpoint {} out of range for n={n}", a.max(b));
        }
        if st.src.len() >= MAX_FRAME_EDGES {
            bail!("request exceeds the wire limit of {MAX_FRAME_EDGES} edges");
        }
        st.src.push(a);
        st.dst.push(b);
        st.w.push(w);
    }

    // re-derive the globals' derived vectors through the shared formulas
    let wv = weight_values(&st.labels, k);
    let scale = scale_from_deg(&st.deg, &h.options);

    let rows = h.row1 - h.row0;
    st.out.clear();
    st.out.resize(rows * k, 0.0);
    embed_shard(
        &st.src,
        &st.dst,
        &st.w,
        h.row0,
        h.row1,
        &st.labels,
        &wv,
        scale.as_deref(),
        k,
        &h.options,
        &mut st.ws,
        &mut st.out,
    );

    writeln!(writer, "OK rows={rows}")?;
    super::worker::write_z_rows(writer, &st.out, rows, k)?;
    writeln!(writer, "DONE")?;
    Ok(())
}

/// Serve a `GLOBALS` upload: validate the header, stream the label and
/// degree frames into the connection cache in bounded chunks (hashing
/// the bytes as they arrive), and refuse a content-hash mismatch.
fn serve_globals(
    header: &str,
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    st: &mut ConnState,
) -> Result<()> {
    let h = GlobalsHeader::parse(header)?;
    // invalidate while loading: a failure mid-upload must not leave a
    // stale fingerprint over half-replaced buffers
    st.g_hash = None;
    if h.n != st.g_n {
        // retained edge payloads were validated against the old n; a
        // re-dimensioned connection must not serve them
        st.cache_clear();
    }
    let mut hasher = codec::Fnv64::new();

    let len = codec::read_frame_len(reader, "GLOBALS labels frame")?;
    codec::check_frame_len(
        len,
        codec::LABEL_RECORD_BYTES,
        (MAX_FRAME_VERTICES * codec::LABEL_RECORD_BYTES) as u64,
        Some((h.n * codec::LABEL_RECORD_BYTES) as u64),
        "GLOBALS labels frame",
    )?;
    st.labels.clear();
    let (labels, chunk) = (&mut st.labels, &mut st.chunk);
    let k = h.k;
    codec::read_frame_body(reader, len, chunk, "GLOBALS labels frame", |bytes| {
        hasher.update(bytes);
        for rec in bytes.chunks_exact(codec::LABEL_RECORD_BYTES) {
            let l = i32::from_le_bytes(rec.try_into().unwrap());
            codec::validate_label(l, k)?;
            labels.push(l);
        }
        Ok(())
    })?;

    let len = codec::read_frame_len(reader, "GLOBALS degrees frame")?;
    codec::check_frame_len(
        len,
        codec::F64_RECORD_BYTES,
        (MAX_FRAME_VERTICES * codec::F64_RECORD_BYTES) as u64,
        Some((h.n * codec::F64_RECORD_BYTES) as u64),
        "GLOBALS degrees frame",
    )?;
    st.deg.clear();
    let (deg, chunk) = (&mut st.deg, &mut st.chunk);
    codec::read_frame_body(reader, len, chunk, "GLOBALS degrees frame", |bytes| {
        hasher.update(bytes);
        for rec in bytes.chunks_exact(codec::F64_RECORD_BYTES) {
            deg.push(f64::from_le_bytes(rec.try_into().unwrap()));
        }
        Ok(())
    })?;

    let got = hasher.finish();
    if got != h.hash {
        bail!(
            "GLOBALS hash mismatch: header declared {:016x} but the received \
             vectors hash to {got:016x}",
            h.hash
        );
    }
    // derive + cache the weight vector once per upload, not per shard
    st.wv = weight_values(&st.labels, h.k);
    st.g_hash = Some(h.hash);
    st.g_n = h.n;
    st.g_k = h.k;
    writeln!(writer, "OK")?;
    Ok(())
}

/// Check a shard-family header's declared hash and dimensions against
/// the connection's cached GLOBALS.
fn check_cached_globals(verb: &str, h: &ShardHeader, hash: u64, st: &ConnState) -> Result<()> {
    match st.g_hash {
        Some(g) if g == hash => {}
        Some(g) => bail!(
            "{verb} references GLOBALS {hash:016x} but this connection cached \
             {g:016x} — resend GLOBALS"
        ),
        None => bail!(
            "{verb} before GLOBALS: no global vectors cached on this connection"
        ),
    }
    if h.n != st.g_n || h.k != st.g_k {
        bail!(
            "{verb} n={} k={} disagrees with cached GLOBALS n={} k={}",
            h.n,
            h.k,
            st.g_n,
            st.g_k
        );
    }
    Ok(())
}

/// Serve one `SHARD2` request against the connection's cached GLOBALS:
/// header → edge frame → embed → `OK rows=` + Z frame. With `keep=1`
/// the decoded edge payload is retained for later `RESHARD` rounds.
fn serve_shard2(
    header: &str,
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    st: &mut ConnState,
) -> Result<()> {
    let (h, hash, keep) = ShardHeader::parse_v2(header)?;
    check_cached_globals("SHARD2", &h, hash, st)?;
    let (n, k) = (h.n, h.k);

    let len = codec::read_frame_len(reader, "SHARD2 edge frame")?;
    codec::check_frame_len(
        len,
        codec::EDGE_RECORD_BYTES,
        (MAX_FRAME_EDGES * codec::EDGE_RECORD_BYTES) as u64,
        None,
        "SHARD2 edge frame",
    )?;
    st.src.clear();
    st.dst.clear();
    st.w.clear();
    let (src, dst, w, chunk) = (&mut st.src, &mut st.dst, &mut st.w, &mut st.chunk);
    codec::read_frame_body(reader, len, chunk, "SHARD2 edge frame", |bytes| {
        for rec in bytes.chunks_exact(codec::EDGE_RECORD_BYTES) {
            let (a, b, wt) = codec::decode_edge(rec);
            if a as usize >= n || b as usize >= n {
                bail!("shard edge endpoint {} out of range for n={n}", a.max(b));
            }
            src.push(a);
            dst.push(b);
            w.push(wt);
        }
        Ok(())
    })?;

    // the weight vector is cached with the globals; the Laplacian scale
    // depends on the per-request options, so it is derived here — same
    // single implementation as every other lane
    let scale = scale_from_deg(&st.deg, &h.options);

    let rows = h.row1 - h.row0;
    st.out.clear();
    st.out.resize(rows * k, 0.0);
    embed_shard(
        &st.src,
        &st.dst,
        &st.w,
        h.row0,
        h.row1,
        &st.labels,
        &st.wv,
        scale.as_deref(),
        k,
        &h.options,
        &mut st.ws,
        &mut st.out,
    );

    writeln!(writer, "OK rows={rows}")?;
    codec::write_frame_f64s(writer, &st.out)?;

    if keep {
        // retain the decoded payload for RESHARD rounds (replacing any
        // earlier payload kept for the same row range)
        st.cache_insert(
            (h.row0, h.row1),
            CachedShard {
                src: st.src.clone(),
                dst: st.dst.clone(),
                w: st.w.clone(),
                kept_at: Instant::now(),
            },
        );
    }
    Ok(())
}

/// Serve a `RELABEL`: swap in a new label vector against the cached
/// degrees — the round r>1 path of an iterative job, where only the
/// n-vector of labels crosses the wire. The declared `g=` must equal
/// the content hash of (new labels, cached degrees); on success the
/// cached weight vector is re-derived and the connection's GLOBALS
/// epoch moves to the new hash.
fn serve_relabel(
    header: &str,
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    st: &mut ConnState,
) -> Result<()> {
    let h = GlobalsHeader::parse_relabel(header)?;
    if st.g_hash.is_none() {
        bail!("RELABEL before GLOBALS: no global vectors cached on this connection");
    }
    if h.n != st.g_n || h.k != st.g_k {
        bail!(
            "RELABEL n={} k={} disagrees with cached GLOBALS n={} k={}",
            h.n,
            h.k,
            st.g_n,
            st.g_k
        );
    }
    // invalidate while loading — a mid-frame failure closes the
    // connection, but it must not close it with a stale fingerprint
    st.g_hash = None;
    let mut hasher = codec::Fnv64::new();

    let len = codec::read_frame_len(reader, "RELABEL labels frame")?;
    codec::check_frame_len(
        len,
        codec::LABEL_RECORD_BYTES,
        (MAX_FRAME_VERTICES * codec::LABEL_RECORD_BYTES) as u64,
        Some((h.n * codec::LABEL_RECORD_BYTES) as u64),
        "RELABEL labels frame",
    )?;
    st.labels.clear();
    let (labels, chunk) = (&mut st.labels, &mut st.chunk);
    let k = h.k;
    codec::read_frame_body(reader, len, chunk, "RELABEL labels frame", |bytes| {
        hasher.update(bytes);
        for rec in bytes.chunks_exact(codec::LABEL_RECORD_BYTES) {
            let l = i32::from_le_bytes(rec.try_into().unwrap());
            codec::validate_label(l, k)?;
            labels.push(l);
        }
        Ok(())
    })?;
    // fold the round-invariant cached degrees into the hash — the
    // declared fingerprint is over (labels, degrees), exactly what a
    // full GLOBALS upload of the same vectors would hash
    for &d in &st.deg {
        hasher.update(&d.to_le_bytes());
    }
    let got = hasher.finish();
    if got != h.hash {
        bail!(
            "RELABEL hash mismatch: header declared {:016x} but the new labels \
             with the cached degrees hash to {got:016x}",
            h.hash
        );
    }
    st.wv = weight_values(&st.labels, h.k);
    st.g_hash = Some(h.hash);
    writeln!(writer, "OK")?;
    Ok(())
}

/// Serve a `RESHARD`: embed a row range from the edge payload retained
/// by an earlier `SHARD2 keep=1`, under the connection's *current*
/// globals — no body follows the header, so an iterative round's
/// per-shard cost is one header line down and one Z frame back.
fn serve_reshard(header: &str, writer: &mut impl Write, st: &mut ConnState) -> Result<()> {
    let (h, hash) = ShardHeader::parse_reshard(header)?;
    check_cached_globals("RESHARD", &h, hash, st)?;
    let k = h.k;
    let Some(cached) = st.cache.get(&(h.row0, h.row1)) else {
        bail!(
            "RESHARD for rows [{}, {}) but no SHARD2 keep=1 payload is retained \
             for that range on this connection",
            h.row0,
            h.row1
        );
    };

    let scale = scale_from_deg(&st.deg, &h.options);
    let rows = h.row1 - h.row0;
    st.out.clear();
    st.out.resize(rows * k, 0.0);
    embed_shard(
        &cached.src,
        &cached.dst,
        &cached.w,
        h.row0,
        h.row1,
        &st.labels,
        &st.wv,
        scale.as_deref(),
        k,
        &h.options,
        &mut st.ws,
        &mut st.out,
    );

    writeln!(writer, "OK rows={rows}")?;
    codec::write_frame_f64s(writer, &st.out)?;
    Ok(())
}

/// Read one line into `buf`, returning its trimmed contents; EOF is an
/// error (a framed body must be complete).
fn read_trimmed<'a>(reader: &mut impl BufRead, buf: &'a mut String) -> Result<&'a str> {
    buf.clear();
    if reader.read_line(buf)? == 0 {
        bail!("connection closed mid-request");
    }
    Ok(buf.trim())
}

/// Client side of one v1 `SHARD` round trip: stream shard `s` of `sp`
/// to an open daemon connection and return its `(row1-row0) * k` Z
/// cells. This is the **fallback lane** for legacy daemons: the binary
/// spill records are formatted as shortest-roundtrip text (exact under
/// re-parse) and the reply is parsed with the shared row grammar, so
/// the result is still byte-for-byte what the in-process shard pass
/// produces — it just pays the decimal formatting the v2 lane deleted.
pub(crate) fn request_shard(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    sp: &super::spill::SpilledShards,
    opts: &GeeOptions,
    s: usize,
) -> Result<Vec<f64>> {
    let plan = &sp.plan;
    let (v0, v1) = plan.shard_range(s);
    let b = |v: bool| if v { "1" } else { "0" };
    writeln!(
        writer,
        "SHARD n={} k={} row0={v0} row1={v1} lap={} diag={} cor={}",
        plan.n,
        plan.k,
        b(opts.laplacian),
        b(opts.diagonal),
        b(opts.correlation)
    )?;
    for &l in &sp.labels {
        writeln!(writer, "{l}")?;
    }
    for &d in &plan.deg {
        writeln!(writer, "{d}")?;
    }
    // stop decoding the spill the moment the socket dies: a dead daemon
    // must fail the slot (and requeue the shard) without a full wasted
    // scan of a potentially huge spill file
    let mut io_err: Option<std::io::Error> = None;
    codec::try_for_each_edge_auto(&sp.files[s], |a, b, w| {
        if let Err(e) = writeln!(writer, "{a} {b} {w}") {
            io_err = Some(e);
            return std::ops::ControlFlow::Break(());
        }
        std::ops::ControlFlow::Continue(())
    })?;
    if let Some(e) = io_err {
        return Err(anyhow::Error::new(e).context("stream shard edges"));
    }
    writeln!(writer, "END")?;
    writer.flush()?;

    let mut line = String::new();
    let t = read_trimmed(reader, &mut line).context("shard reply header")?;
    let rows_claim: usize = t
        .strip_prefix("OK rows=")
        .with_context(|| format!("worker said: {t}"))?
        .parse()
        .context("bad rows count")?;
    let rows = v1 - v0;
    if rows_claim != rows {
        bail!("worker replied {rows_claim} rows, expected {rows}");
    }
    let k = plan.k;
    let mut out = vec![0.0f64; rows * k];
    for r in 0..rows {
        let t = read_trimmed(reader, &mut line)
            .with_context(|| format!("Z row {}", r + 1))?;
        super::worker::parse_z_row(t, k, &mut out[r * k..(r + 1) * k])
            .with_context(|| format!("Z row {}", r + 1))?;
    }
    let t = read_trimmed(reader, &mut line)?;
    if t != "DONE" {
        bail!("missing DONE trailer, got '{t}'");
    }
    Ok(out)
}

/// Ship a job's global vectors to a v2 daemon under their content hash
/// — once per connection; every subsequent [`request_shard_v2`] on the
/// connection references them by `hash`.
pub(crate) fn send_globals(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    sp: &super::spill::SpilledShards,
    hash: u64,
) -> Result<()> {
    let plan = &sp.plan;
    writeln!(writer, "GLOBALS g={hash:016x} n={} k={}", plan.n, plan.k)?;
    codec::write_frame_i32s(writer, &sp.labels)?;
    codec::write_frame_f64s(writer, &plan.deg)?;
    writer.flush()?;
    let mut line = String::new();
    let t = read_trimmed(reader, &mut line).context("GLOBALS reply")?;
    if t != "OK" {
        bail!("worker rejected GLOBALS: {t}");
    }
    Ok(())
}

/// Client side of one `SHARD2` round trip: the spill file is streamed to
/// the daemon as one raw edge frame (the file *is* the frame body —
/// zero re-parse, zero formatting) and the Z rows come back as raw f64
/// bit patterns. Requires [`send_globals`] to have shipped `hash` on
/// this connection already. `scratch` is the caller's reused frame-chunk
/// buffer (a slot holds one for its lifetime, so per-shard calls do not
/// re-allocate it). With `keep` the daemon retains the edge payload so
/// later rounds can [`request_reshard`] the same row range.
#[allow(clippy::too_many_arguments)]
pub(crate) fn request_shard_v2(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    sp: &super::spill::SpilledShards,
    opts: &GeeOptions,
    s: usize,
    hash: u64,
    scratch: &mut Vec<u8>,
    keep: bool,
) -> Result<Vec<f64>> {
    let plan = &sp.plan;
    let (v0, v1) = plan.shard_range(s);

    // open + size the spill file *before* the header line goes out: a
    // local file problem must not leave the connection mid-request
    let path = &sp.files[s];
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let flen = f.metadata()?.len();
    if flen % codec::EDGE_RECORD_BYTES as u64 != 0 {
        bail!(
            "{}: {flen} bytes is not a whole number of edge records (truncated?)",
            path.display()
        );
    }

    let b = |v: bool| if v { "1" } else { "0" };
    // keep= only goes out when asked for: the plain dispatch path keeps
    // emitting byte-identical headers that pre-RESHARD daemons accept
    let keep_arg = if keep { " keep=1" } else { "" };
    writeln!(
        writer,
        "SHARD2 g={hash:016x} n={} k={} row0={v0} row1={v1} lap={} diag={} cor={}{keep_arg}",
        plan.n,
        plan.k,
        b(opts.laplacian),
        b(opts.diagonal),
        b(opts.correlation)
    )?;
    codec::write_frame_len(writer, flen)?;
    // take() pins the copy to the declared frame length: a file that
    // grows mid-stream cannot push stray bytes past the frame boundary
    // (desyncing the protocol), and one that shrinks under-fills the
    // frame and fails the length check below immediately
    let copied = std::io::copy(&mut f.take(flen), writer)
        .with_context(|| format!("stream {}", path.display()))?;
    if copied != flen {
        bail!(
            "{}: streamed {copied} of {flen} bytes (file changed mid-stream?)",
            path.display()
        );
    }
    writer.flush()?;

    read_z_reply(reader, v1 - v0, plan.k, scratch)
}

/// Ship a new label vector for an iterative round — the `RELABEL` round
/// trip. `hash` must be `codec::globals_hash(labels, deg)` over the
/// *cached* (round-invariant) degrees; after `OK` every subsequent
/// `SHARD2`/`RESHARD` on the connection references the new hash.
pub(crate) fn send_relabel(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    labels: &[i32],
    n: usize,
    k: usize,
    hash: u64,
) -> Result<()> {
    writeln!(writer, "RELABEL g={hash:016x} n={n} k={k}")?;
    codec::write_frame_i32s(writer, labels)?;
    writer.flush()?;
    let mut line = String::new();
    let t = read_trimmed(reader, &mut line).context("RELABEL reply")?;
    if t != "OK" {
        bail!("worker rejected RELABEL: {t}");
    }
    Ok(())
}

/// Client side of one `RESHARD` round trip: one header line out (no
/// edges — the daemon re-embeds the payload it retained from `SHARD2
/// keep=1`), `OK rows=` + Z frame back.
pub(crate) fn request_reshard(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    plan: &super::plan::ShardPlan,
    opts: &GeeOptions,
    s: usize,
    hash: u64,
    scratch: &mut Vec<u8>,
) -> Result<Vec<f64>> {
    let (v0, v1) = plan.shard_range(s);
    let b = |v: bool| if v { "1" } else { "0" };
    writeln!(
        writer,
        "RESHARD g={hash:016x} n={} k={} row0={v0} row1={v1} lap={} diag={} cor={}",
        plan.n,
        plan.k,
        b(opts.laplacian),
        b(opts.diagonal),
        b(opts.correlation)
    )?;
    writer.flush()?;
    read_z_reply(reader, v1 - v0, plan.k, scratch)
}

/// Parse the `OK rows=` + Z-frame reply shared by `SHARD2`/`RESHARD`.
fn read_z_reply(
    reader: &mut impl BufRead,
    rows: usize,
    k: usize,
    scratch: &mut Vec<u8>,
) -> Result<Vec<f64>> {
    let mut line = String::new();
    let t = read_trimmed(reader, &mut line).context("shard reply header")?;
    let rows_claim: usize = t
        .strip_prefix("OK rows=")
        .with_context(|| format!("worker said: {t}"))?
        .parse()
        .context("bad rows count")?;
    if rows_claim != rows {
        bail!("worker replied {rows_claim} rows, expected {rows}");
    }
    let expect = (rows * k * codec::F64_RECORD_BYTES) as u64;
    let len = codec::read_frame_len(reader, "Z frame")?;
    codec::check_frame_len(len, codec::F64_RECORD_BYTES, expect, Some(expect), "Z frame")?;
    let mut out = Vec::with_capacity(rows * k);
    codec::read_frame_body(reader, len, scratch, "Z frame", |bytes| {
        for rec in bytes.chunks_exact(codec::F64_RECORD_BYTES) {
            out.push(f64::from_le_bytes(rec.try_into().unwrap()));
        }
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::graph::Graph;
    use crate::shard::spill::{spill_from_graph, SpillConfig};
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(4, 4, 1.75);
        g
    }

    #[test]
    fn header_parse_and_bounds() {
        let h = ShardHeader::parse("SHARD n=10 k=3 row0=2 row1=7 lap=1 diag=0 cor=1")
            .unwrap();
        assert_eq!((h.n, h.k, h.row0, h.row1), (10, 3, 2, 7));
        assert_eq!(h.options, GeeOptions::new(true, false, true));

        // oversized / inconsistent headers are rejected before allocation
        assert!(ShardHeader::parse("SHARD n=0 k=1 row0=0 row1=0").is_err());
        assert!(ShardHeader::parse(&format!(
            "SHARD n={} k=1 row0=0 row1=1",
            MAX_FRAME_VERTICES + 1
        ))
        .is_err());
        assert!(ShardHeader::parse(&format!(
            "SHARD n=10 k={} row0=0 row1=1",
            MAX_FRAME_CLASSES + 1
        ))
        .is_err());
        // rows*k product overflow / cap
        assert!(ShardHeader::parse(&format!(
            "SHARD n={0} k=16777216 row0=0 row1={0}",
            u32::MAX
        ))
        .is_err());
        assert!(ShardHeader::parse("SHARD n=5 k=2 row0=4 row1=2").is_err());
        assert!(ShardHeader::parse("SHARD n=5 k=2 row0=0 row1=9").is_err());
        assert!(ShardHeader::parse("SHARD n=5 k=2 row0=0 row1=5 lap=x").is_err());
        assert!(ShardHeader::parse("SHARD n=5 row0=0 row1=5").is_err());
        assert!(ShardHeader::parse("PING").is_err());
    }

    #[test]
    fn round_trip_over_localhost_is_bitwise() {
        let dir = std::env::temp_dir()
            .join(format!("gee_remote_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let g = random_graph(551, 80, 450, 3);
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 3, ..SpillConfig::new(&dir) },
        )
        .unwrap();

        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);

        for opts in GeeOptions::table_order() {
            let whole = SparseGee::fast().embed(&g, &opts);
            for s in 0..sp.plan.shards() {
                let (v0, v1) = sp.plan.shard_range(s);
                let rows =
                    request_shard(&mut reader, &mut writer, &sp, &opts, s).unwrap();
                assert_eq!(
                    rows,
                    whole.data[v0 * g.k..v1 * g.k].to_vec(),
                    "remote shard {s} drifted at {opts:?}"
                );
            }
        }
        server.stop();
    }

    #[test]
    fn v2_round_trip_over_localhost_is_bitwise() {
        // the binary wire end to end: HELLO2, GLOBALS once, SHARD2 per
        // shard — rows bitwise vs the fused engine for the whole grid
        let dir = std::env::temp_dir()
            .join(format!("gee_remote_v2_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let g = random_graph(552, 90, 500, 3);
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 3, ..SpillConfig::new(&dir) },
        )
        .unwrap();

        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);

        // negotiate
        writeln!(writer, "HELLO2").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "HELLO2");

        // one GLOBALS for the whole connection, every shard x option
        // served against the cache
        let hash = codec::globals_hash(&sp.labels, &sp.plan.deg);
        send_globals(&mut reader, &mut writer, &sp, hash).unwrap();
        let mut scratch = Vec::new();
        for opts in GeeOptions::table_order() {
            let whole = SparseGee::fast().embed(&g, &opts);
            for s in 0..sp.plan.shards() {
                let (v0, v1) = sp.plan.shard_range(s);
                let rows = request_shard_v2(
                    &mut reader,
                    &mut writer,
                    &sp,
                    &opts,
                    s,
                    hash,
                    &mut scratch,
                    false,
                )
                .unwrap();
                assert_eq!(
                    rows,
                    whole.data[v0 * g.k..v1 * g.k].to_vec(),
                    "v2 shard {s} drifted at {opts:?}"
                );
            }
        }
        server.stop();
    }

    /// Open a raw client connection to a fresh v2 daemon.
    fn raw_conn(
        server: &ShardServer,
    ) -> (BufReader<TcpStream>, BufWriter<TcpStream>) {
        let stream = TcpStream::connect(server.addr()).unwrap();
        (
            BufReader::new(stream.try_clone().unwrap()),
            BufWriter::new(stream),
        )
    }

    fn read_reply(reader: &mut BufReader<TcpStream>) -> String {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    }

    #[test]
    fn hostile_v2_bodies_get_bounded_typed_errors() {
        let server = ShardServer::start("127.0.0.1:0").unwrap();

        // oversized GLOBALS frame length prefix: rejected from the
        // prefix alone (n*4 expected), before any body bytes exist
        {
            let (mut reader, mut writer) = raw_conn(&server);
            writeln!(writer, "GLOBALS g=00000000deadbeef n=10 k=2").unwrap();
            codec::write_frame_len(&mut writer, 1 << 40).unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("labels frame"), "{t}");
        }

        // GLOBALS content-hash mismatch: vectors arrive intact but under
        // the wrong fingerprint — typed rejection, nothing cached
        {
            let (mut reader, mut writer) = raw_conn(&server);
            writeln!(writer, "GLOBALS g=0123456789abcdef n=3 k=2").unwrap();
            codec::write_frame_i32s(&mut writer, &[0, 1, -1]).unwrap();
            codec::write_frame_f64s(&mut writer, &[1.0, 2.0, 0.5]).unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("hash mismatch"), "{t}");
        }

        // SHARD2 with no GLOBALS cached on the connection
        {
            let (mut reader, mut writer) = raw_conn(&server);
            writeln!(
                writer,
                "SHARD2 g=0123456789abcdef n=3 k=2 row0=0 row1=1"
            )
            .unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("before GLOBALS"), "{t}");
        }

        // SHARD2 referencing a different hash than the cached one
        {
            let (mut reader, mut writer) = raw_conn(&server);
            let (labels, deg) = (vec![0, 1, -1], vec![1.0, 2.0, 0.5]);
            let hash = codec::globals_hash(&labels, &deg);
            writeln!(writer, "GLOBALS g={hash:016x} n=3 k=2").unwrap();
            codec::write_frame_i32s(&mut writer, &labels).unwrap();
            codec::write_frame_f64s(&mut writer, &deg).unwrap();
            writer.flush().unwrap();
            assert_eq!(read_reply(&mut reader), "OK");
            writeln!(
                writer,
                "SHARD2 g={:016x} n=3 k=2 row0=0 row1=1",
                hash ^ 1
            )
            .unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("resend GLOBALS"), "{t}");
        }

        // misaligned SHARD2 edge frame (not a whole number of records)
        {
            let (mut reader, mut writer) = raw_conn(&server);
            let (labels, deg) = (vec![0, 1, -1], vec![1.0, 2.0, 0.5]);
            let hash = codec::globals_hash(&labels, &deg);
            writeln!(writer, "GLOBALS g={hash:016x} n=3 k=2").unwrap();
            codec::write_frame_i32s(&mut writer, &labels).unwrap();
            codec::write_frame_f64s(&mut writer, &deg).unwrap();
            writer.flush().unwrap();
            assert_eq!(read_reply(&mut reader), "OK");
            writeln!(writer, "SHARD2 g={hash:016x} n=3 k=2 row0=0 row1=1")
                .unwrap();
            codec::write_frame_len(&mut writer, 15).unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
        }

        // mid-frame EOF: a client that declares a body then hangs up must
        // not wedge or crash the daemon — a fresh connection still works
        {
            let (_reader, mut writer) = raw_conn(&server);
            writeln!(writer, "GLOBALS g=0000000000000001 n=10 k=2").unwrap();
            codec::write_frame_len(&mut writer, 40).unwrap();
            writer.write_all(&[0u8; 8]).unwrap(); // 8 of 40 bytes, then gone
            writer.flush().unwrap();
        }
        {
            let (mut reader, mut writer) = raw_conn(&server);
            writeln!(writer, "PING").unwrap();
            writer.flush().unwrap();
            assert_eq!(read_reply(&mut reader), "PONG");
        }
        server.stop();
    }

    #[test]
    fn text_only_server_rejects_v2_verbs_like_a_legacy_daemon() {
        let server = ShardServer::start_text_only("127.0.0.1:0").unwrap();
        // HELLO2 draws ERR + close — exactly what a pre-v2 daemon does —
        // so driver negotiation falls back to text against it
        {
            let (mut reader, mut writer) = raw_conn(&server);
            writeln!(writer, "HELLO2").unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("expected SHARD"), "{t}");
            let mut rest = String::new();
            assert_eq!(
                reader.read_line(&mut rest).unwrap(),
                0,
                "legacy-emulating daemon must close after ERR"
            );
        }
        // and it still serves the v1 text protocol
        {
            let dir = std::env::temp_dir()
                .join(format!("gee_remote_textonly_{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            std::fs::create_dir_all(&dir).unwrap();
            let g = random_graph(553, 50, 250, 3);
            let sp = spill_from_graph(
                &g,
                &SpillConfig { shards: 2, ..SpillConfig::new(&dir) },
            )
            .unwrap();
            let (mut reader, mut writer) = raw_conn(&server);
            let opts = crate::gee::GeeOptions::ALL;
            let whole = SparseGee::fast().embed(&g, &opts);
            for s in 0..sp.plan.shards() {
                let (v0, v1) = sp.plan.shard_range(s);
                let rows =
                    request_shard(&mut reader, &mut writer, &sp, &opts, s).unwrap();
                assert_eq!(rows, whole.data[v0 * g.k..v1 * g.k].to_vec());
            }
        }
        server.stop();
    }

    #[test]
    fn globals_header_parse_and_bounds() {
        let h = GlobalsHeader::parse("GLOBALS g=00ff00ff00ff00ff n=10 k=3").unwrap();
        assert_eq!(h.hash, 0x00ff_00ff_00ff_00ff);
        assert_eq!((h.n, h.k), (10, 3));
        assert!(GlobalsHeader::parse("GLOBALS n=10 k=3").is_err());
        assert!(GlobalsHeader::parse("GLOBALS g=zz n=10 k=3").is_err());
        assert!(GlobalsHeader::parse("GLOBALS g=1 n=0 k=3").is_err());
        assert!(GlobalsHeader::parse(&format!(
            "GLOBALS g=1 n={} k=3",
            MAX_FRAME_VERTICES + 1
        ))
        .is_err());
        assert!(GlobalsHeader::parse(&format!(
            "GLOBALS g=1 n=10 k={}",
            MAX_FRAME_CLASSES + 1
        ))
        .is_err());
        // v1 SHARD headers must keep rejecting the v2-only g= key
        assert!(ShardHeader::parse("SHARD g=1 n=5 k=2 row0=0 row1=5").is_err());
        // and SHARD2 requires it
        assert!(ShardHeader::parse_v2("SHARD2 n=5 k=2 row0=0 row1=5").is_err());
        let (h2, hash, keep) =
            ShardHeader::parse_v2("SHARD2 g=ab n=5 k=2 row0=0 row1=5 lap=1").unwrap();
        assert_eq!(hash, 0xab);
        assert_eq!((h2.n, h2.k, h2.row0, h2.row1), (5, 2, 0, 5));
        assert!(h2.options.laplacian);
        assert!(!keep, "keep defaults to off");
        let (_, _, keep) =
            ShardHeader::parse_v2("SHARD2 g=ab n=5 k=2 row0=0 row1=5 keep=1").unwrap();
        assert!(keep);
        // keep= is v2-only grammar, and RESHARD shares the SHARD2 shape
        assert!(ShardHeader::parse("SHARD n=5 k=2 row0=0 row1=5 keep=1").is_err());
        let (h3, hash3) =
            ShardHeader::parse_reshard("RESHARD g=cd n=5 k=2 row0=2 row1=5").unwrap();
        assert_eq!(hash3, 0xcd);
        assert_eq!((h3.row0, h3.row1), (2, 5));
        assert!(ShardHeader::parse_reshard("RESHARD n=5 k=2 row0=0 row1=5").is_err());
        // RELABEL shares the GLOBALS grammar under its own verb
        let r = GlobalsHeader::parse_relabel("RELABEL g=0f n=7 k=3").unwrap();
        assert_eq!((r.hash, r.n, r.k), (0x0f, 7, 3));
        assert!(GlobalsHeader::parse_relabel("GLOBALS g=0f n=7 k=3").is_err());
    }

    #[test]
    fn relabel_reshard_rounds_are_bitwise_with_edges_shipped_once() {
        // the iterative-job wire pattern end to end: GLOBALS + SHARD2
        // keep=1 once, then per round RELABEL (labels only) + RESHARD
        // per shard — every round's rows bitwise vs a from-scratch
        // fused embed under that round's labels
        let dir = std::env::temp_dir()
            .join(format!("gee_remote_reshard_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let g = random_graph(554, 70, 400, 3);
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 2, ..SpillConfig::new(&dir) },
        )
        .unwrap();

        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let opts = GeeOptions::new(true, false, true);
        let mut scratch = Vec::new();

        // round 1: globals + edges, retained
        let hash = codec::globals_hash(&sp.labels, &sp.plan.deg);
        send_globals(&mut reader, &mut writer, &sp, hash).unwrap();
        let whole = SparseGee::fast().embed(&g, &opts);
        for s in 0..sp.plan.shards() {
            let (v0, v1) = sp.plan.shard_range(s);
            let rows = request_shard_v2(
                &mut reader, &mut writer, &sp, &opts, s, hash, &mut scratch, true,
            )
            .unwrap();
            assert_eq!(rows, whole.data[v0 * g.k..v1 * g.k].to_vec());
        }

        // rounds 2..: rotate every label, ship only the label vector
        let mut labels = sp.labels.clone();
        for round in 0..3 {
            for l in labels.iter_mut() {
                if *l >= 0 {
                    *l = (*l + 1) % g.k as i32;
                }
            }
            let rhash = codec::globals_hash(&labels, &sp.plan.deg);
            send_relabel(&mut reader, &mut writer, &labels, g.n, g.k, rhash).unwrap();
            let mut gl = g.clone();
            gl.labels.copy_from_slice(&labels);
            let whole = SparseGee::fast().embed(&gl, &opts);
            for s in 0..sp.plan.shards() {
                let (v0, v1) = sp.plan.shard_range(s);
                let rows = request_reshard(
                    &mut reader, &mut writer, &sp.plan, &opts, s, rhash, &mut scratch,
                )
                .unwrap();
                assert_eq!(
                    rows,
                    whole.data[v0 * g.k..v1 * g.k].to_vec(),
                    "round {round} shard {s} drifted"
                );
            }
        }
        server.stop();
    }

    #[test]
    fn reshard_without_retained_payload_is_a_typed_error() {
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let (mut reader, mut writer) = raw_conn(&server);
        let (labels, deg) = (vec![0, 1, -1], vec![1.0, 2.0, 0.5]);
        let hash = codec::globals_hash(&labels, &deg);
        writeln!(writer, "GLOBALS g={hash:016x} n=3 k=2").unwrap();
        codec::write_frame_i32s(&mut writer, &labels).unwrap();
        codec::write_frame_f64s(&mut writer, &deg).unwrap();
        writer.flush().unwrap();
        assert_eq!(read_reply(&mut reader), "OK");
        // nothing was kept for [0,2): RESHARD must fail with a pointer
        // at the SHARD2 keep=1 contract
        writeln!(writer, "RESHARD g={hash:016x} n=3 k=2 row0=0 row1=2").unwrap();
        writer.flush().unwrap();
        let t = read_reply(&mut reader);
        assert!(t.starts_with("ERR"), "{t}");
        assert!(t.contains("keep=1"), "{t}");
        server.stop();
    }

    #[test]
    fn relabel_guards_hash_epoch_and_ordering() {
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        // RELABEL before any GLOBALS: typed rejection
        {
            let (mut reader, mut writer) = raw_conn(&server);
            writeln!(writer, "RELABEL g=01 n=3 k=2").unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("before GLOBALS"), "{t}");
        }
        // RELABEL whose declared hash disagrees with (labels, cached deg)
        {
            let (mut reader, mut writer) = raw_conn(&server);
            let (labels, deg) = (vec![0, 1, -1], vec![1.0, 2.0, 0.5]);
            let hash = codec::globals_hash(&labels, &deg);
            writeln!(writer, "GLOBALS g={hash:016x} n=3 k=2").unwrap();
            codec::write_frame_i32s(&mut writer, &labels).unwrap();
            codec::write_frame_f64s(&mut writer, &deg).unwrap();
            writer.flush().unwrap();
            assert_eq!(read_reply(&mut reader), "OK");
            let new_labels = vec![1, 0, -1];
            writeln!(writer, "RELABEL g={:016x} n=3 k=2", hash ^ 5).unwrap();
            codec::write_frame_i32s(&mut writer, &new_labels).unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("hash mismatch"), "{t}");
        }
        // dimension drift is rejected before any frame is read
        {
            let (mut reader, mut writer) = raw_conn(&server);
            let (labels, deg) = (vec![0, 1, -1], vec![1.0, 2.0, 0.5]);
            let hash = codec::globals_hash(&labels, &deg);
            writeln!(writer, "GLOBALS g={hash:016x} n=3 k=2").unwrap();
            codec::write_frame_i32s(&mut writer, &labels).unwrap();
            codec::write_frame_f64s(&mut writer, &deg).unwrap();
            writer.flush().unwrap();
            assert_eq!(read_reply(&mut reader), "OK");
            writeln!(writer, "RELABEL g={hash:016x} n=4 k=2").unwrap();
            writer.flush().unwrap();
            let t = read_reply(&mut reader);
            assert!(t.starts_with("ERR"), "{t}");
            assert!(t.contains("disagrees"), "{t}");
        }
        server.stop();
    }

    #[test]
    fn ping_and_error_paths() {
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writeln!(writer, "PING").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        // hostile header: huge n rejected with a bounded error, instantly
        writeln!(writer, "SHARD n=99999999999999 k=2 row0=0 row1=1").unwrap();
        writer.flush().unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");
        server.stop();
    }

    #[test]
    fn idle_connection_is_reaped_with_named_error() {
        let server = ShardServer::start_with_config(
            "127.0.0.1:0",
            DaemonConfig {
                idle_timeout: Some(Duration::from_millis(100)),
                ..DaemonConfig::default()
            },
        )
        .unwrap();
        let (reaped_before, _, _) = reap_stats();
        let (mut reader, mut writer) = raw_conn(&server);
        // healthy request first: the idle budget only bites between verbs
        writeln!(writer, "PING").unwrap();
        writer.flush().unwrap();
        assert_eq!(read_reply(&mut reader), "PONG");
        // then go silent; the daemon must reap us with a named error
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(
            line.contains("idle connection reaped"),
            "expected reap notice, got {line:?}"
        );
        let (reaped_after, _, _) = reap_stats();
        assert!(reaped_after > reaped_before, "reap counter must advance");
        server.stop();
    }

    #[test]
    fn keep_payloads_expire_after_ttl() {
        let server = ShardServer::start_with_config(
            "127.0.0.1:0",
            DaemonConfig {
                keep_ttl: Some(Duration::from_millis(500)),
                ..DaemonConfig::default()
            },
        )
        .unwrap();
        let dir = std::env::temp_dir()
            .join(format!("gee_remote_ttl_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let g = random_graph(555, 40, 200, 3);
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 1, ..SpillConfig::new(&dir) },
        )
        .unwrap();
        let (mut reader, mut writer) = raw_conn(&server);
        let hash = codec::globals_hash(&sp.labels, &sp.plan.deg);
        send_globals(&mut reader, &mut writer, &sp, hash).unwrap();
        let mut scratch = Vec::new();
        let opts = GeeOptions::ALL;
        request_shard_v2(
            &mut reader, &mut writer, &sp, &opts, 0, hash, &mut scratch, true,
        )
        .unwrap();
        let (_, expired_before, _) = reap_stats();
        // immediate RESHARD works: the payload is fresh
        request_reshard(
            &mut reader, &mut writer, &sp.plan, &opts, 0, hash, &mut scratch,
        )
        .unwrap();
        // after the TTL the payload is purged and RESHARD gets the typed
        // "nothing retained" error
        std::thread::sleep(Duration::from_millis(700));
        let err = request_reshard(
            &mut reader, &mut writer, &sp.plan, &opts, 0, hash, &mut scratch,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("keep=1"), "{err:#}");
        let (_, expired_after, _) = reap_stats();
        assert!(expired_after > expired_before, "expiry counter must advance");
        server.stop();
    }

    #[test]
    fn stats_verb_reports_counters() {
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let (mut reader, mut writer) = raw_conn(&server);
        writeln!(writer, "STATS").unwrap();
        writer.flush().unwrap();
        let t = read_reply(&mut reader);
        assert!(t.starts_with("STATS cached="), "{t}");
        assert!(t.contains(" reaped="), "{t}");
        assert!(t.contains(" expired="), "{t}");
        server.stop();
    }
}
