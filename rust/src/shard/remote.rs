//! TCP transport for the sharded engine — shard workers on other
//! machines, no shared filesystem (the ROADMAP "distribute the sharded
//! lane" item: the worker protocol was already file/process-based; this
//! is the transport half, [`super::dispatch`] is the placement half).
//!
//! Style follows `coordinator/server.rs`: a minimal line-oriented text
//! exchange over stdlib `TcpListener`, one thread per connection, no new
//! dependencies. Every f64 crosses the wire in shortest-roundtrip form
//! (Rust's `Display` re-parses bitwise), and the worker re-derives the
//! weight vector and Laplacian scale from the shipped globals through
//! the same single implementations the in-process engines use
//! ([`weight_values`], [`scale_from_deg`](super::plan::scale_from_deg)) —
//! so remote rows are **bitwise-identical** to `SparseGee::fast()`, the
//! same contract `shard/worker.rs` gives the multi-process lane.
//!
//! ## Protocol
//!
//! One request (pipelined sequentially per connection):
//!
//! ```text
//! -> SHARD n=<n> k=<k> row0=<v0> row1=<v1> lap=<0|1> diag=<0|1> cor=<0|1>
//! -> <n lines: one global label each>
//! -> <n lines: one global weighted degree each (shortest-roundtrip f64)>
//! -> <the shard's incident edges, one "src dst weight" line each>
//! -> END
//! <- OK rows=<v1 - v0>
//! <- <v1 - v0 lines: k tab-separated shortest-roundtrip f64 each>
//! <- DONE
//! ```
//!
//! or `ERR <message>` (after which the daemon closes the connection — a
//! half-consumed body has no well-defined resync point). `PING` → `PONG`
//! for health checks and placement probes; `QUIT` closes. Admission is
//! bounded: headers are rejected against the `MAX_FRAME_*` caps before
//! anything is allocated from them, the label / degree / edge vectors
//! grow only as data actually arrives (edge lines additionally capped),
//! and the one header-driven allocation — the `rows × k` output block,
//! sized after the body is fully read — is capped at [`MAX_FRAME_CELLS`]
//! (2 GiB), the same worst-case the coordinator wire protocol admits.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use super::local::embed_shard;
use super::plan::scale_from_deg;
use crate::gee::options::GeeOptions;
use crate::gee::weights::weight_values;
use crate::gee::workspace::EmbedWorkspace;
use crate::graph::io::parse_edge_fields;

/// Vertex ids travel as u32, so no header may claim more vertices.
pub const MAX_FRAME_VERTICES: usize = u32::MAX as usize;
/// Class-count sanity bound (the weight pass allocates O(k)).
pub const MAX_FRAME_CLASSES: usize = 1 << 24;
/// Cap on `rows * k` output cells per request — the one allocation
/// driven by header values alone rather than by received data (2 GiB of
/// f64 at the cap, the same worst-case the coordinator's
/// `MAX_WIRE_CELLS` admits). A legitimate fleet driver that trips this
/// has very wide embeddings on very large shards: raise the shard count
/// so each shard's row block shrinks.
pub const MAX_FRAME_CELLS: usize = 1 << 28;
/// Cap on edge lines accepted per request, enforced as the stream
/// arrives. A legitimate shard is far below this (`resolve_shards`
/// targets ≤ `MAX_INDEX/4` directed slots per shard); without the cap a
/// driver that never sends `END` grows the daemon's edge buffers until
/// it OOMs — the same exhaustion `coordinator/server.rs` guards with
/// `MAX_WIRE_EDGES`.
pub const MAX_FRAME_EDGES: usize = 1 << 31;

/// A `SHARD` request header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    pub n: usize,
    pub k: usize,
    pub row0: usize,
    pub row1: usize,
    pub options: GeeOptions,
}

impl ShardHeader {
    /// Parse the key=val fields after the `SHARD` verb.
    pub fn parse(header: &str) -> Result<ShardHeader> {
        let mut parts = header.split_whitespace();
        if parts.next() != Some("SHARD") {
            bail!("expected SHARD, got '{header}'");
        }
        let (mut n, mut k, mut row0, mut row1) = (None, None, None, None);
        let (mut lap, mut diag, mut cor) = (false, false, false);
        let mut parse_bool = |val: &str, key: &str| -> Result<bool> {
            match val {
                "0" => Ok(false),
                "1" => Ok(true),
                other => bail!("bad {key}={other} (use 0 or 1)"),
            }
        };
        for p in parts {
            let (key, val) = p.split_once('=').context("SHARD args are key=val")?;
            match key {
                "n" => n = Some(val.parse::<usize>().context("bad n")?),
                "k" => k = Some(val.parse::<usize>().context("bad k")?),
                "row0" => row0 = Some(val.parse::<usize>().context("bad row0")?),
                "row1" => row1 = Some(val.parse::<usize>().context("bad row1")?),
                "lap" => lap = parse_bool(val, "lap")?,
                "diag" => diag = parse_bool(val, "diag")?,
                "cor" => cor = parse_bool(val, "cor")?,
                other => bail!("unknown SHARD arg '{other}'"),
            }
        }
        let h = ShardHeader {
            n: n.context("SHARD requires n=")?,
            k: k.context("SHARD requires k=")?,
            row0: row0.context("SHARD requires row0=")?,
            row1: row1.context("SHARD requires row1=")?,
            options: GeeOptions::new(lap, diag, cor),
        };
        h.validate()?;
        Ok(h)
    }

    /// Bounds gate, applied before anything is allocated from the header.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 {
            bail!("SHARD requires n >= 1");
        }
        if self.n > MAX_FRAME_VERTICES {
            bail!("n={} exceeds the wire limit {MAX_FRAME_VERTICES}", self.n);
        }
        if self.k > MAX_FRAME_CLASSES {
            bail!("k={} exceeds the wire limit {MAX_FRAME_CLASSES}", self.k);
        }
        if self.row0 > self.row1 || self.row1 > self.n {
            bail!("bad row range [{}, {}) for n={}", self.row0, self.row1, self.n);
        }
        let rows = self.row1 - self.row0;
        match rows.checked_mul(self.k) {
            Some(cells) if cells <= MAX_FRAME_CELLS => Ok(()),
            _ => bail!(
                "rows*k = {rows}*{} exceeds the wire limit {MAX_FRAME_CELLS}",
                self.k
            ),
        }
    }
}

/// Per-connection scratch: every buffer is reused across the pipelined
/// requests of one connection, so a fleet daemon serving a long driver
/// session settles into zero steady-state allocation growth.
struct ConnState {
    labels: Vec<i32>,
    deg: Vec<f64>,
    src: Vec<u32>,
    dst: Vec<u32>,
    w: Vec<f64>,
    out: Vec<f64>,
    ws: EmbedWorkspace,
    line: String,
}

impl ConnState {
    fn new() -> ConnState {
        ConnState {
            labels: Vec::new(),
            deg: Vec::new(),
            src: Vec::new(),
            dst: Vec::new(),
            w: Vec::new(),
            out: Vec::new(),
            ws: EmbedWorkspace::new(),
            line: String::new(),
        }
    }
}

/// A running shard-worker daemon bound to `addr()`.
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ShardServer {
    /// Bind (port 0 for ephemeral) and serve shard requests. One thread
    /// per connection; a driver keeps one connection per dispatch slot,
    /// so connection count equals fleet slot count.
    pub fn start(bind: &str) -> Result<ShardServer> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ShardServer { addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting; in-flight connections finish on their own threads.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_connection(stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut st = ConnState::new();
    loop {
        st.line.clear();
        if reader.read_line(&mut st.line)? == 0 {
            return Ok(()); // client closed
        }
        let line = st.line.trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line == "PING" {
            writeln!(writer, "PONG")?;
            writer.flush()?;
            continue;
        }
        if line == "QUIT" {
            return Ok(());
        }
        match serve_shard(&line, &mut reader, &mut writer, &mut st) {
            Ok(()) => writer.flush()?,
            Err(e) => {
                // after a failed request the body position is undefined —
                // report and drop the connection rather than resync-guess
                writeln!(writer, "ERR {e:#}")?;
                writer.flush()?;
                return Err(e);
            }
        }
    }
}

/// Serve one `SHARD` request: header → globals → edges → embed → rows.
fn serve_shard(
    header: &str,
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    st: &mut ConnState,
) -> Result<()> {
    let h = ShardHeader::parse(header)?;
    let (n, k) = (h.n, h.k);

    // globals: n labels, then n degrees — allocation tracks received data
    st.labels.clear();
    for i in 0..n {
        let t = read_trimmed(reader, &mut st.line)
            .with_context(|| format!("label line {}", i + 1))?;
        let l: i32 = t.parse().with_context(|| format!("bad label '{t}'"))?;
        if l < -1 {
            bail!("label {l} < -1 (use -1 for unlabeled)");
        }
        if l >= k as i32 {
            bail!("label {l} >= k {k}");
        }
        st.labels.push(l);
    }
    st.deg.clear();
    for i in 0..n {
        let t = read_trimmed(reader, &mut st.line)
            .with_context(|| format!("degree line {}", i + 1))?;
        st.deg
            .push(t.parse::<f64>().with_context(|| format!("bad degree '{t}'"))?);
    }

    // the shard's incident edges, until END
    st.src.clear();
    st.dst.clear();
    st.w.clear();
    loop {
        let t = read_trimmed(reader, &mut st.line).context("edge line")?;
        if t == "END" {
            break;
        }
        let Some((a, b, w)) = parse_edge_fields(t)? else {
            continue;
        };
        if a as usize >= n || b as usize >= n {
            bail!("shard edge endpoint {} out of range for n={n}", a.max(b));
        }
        if st.src.len() >= MAX_FRAME_EDGES {
            bail!("request exceeds the wire limit of {MAX_FRAME_EDGES} edges");
        }
        st.src.push(a);
        st.dst.push(b);
        st.w.push(w);
    }

    // re-derive the globals' derived vectors through the shared formulas
    let wv = weight_values(&st.labels, k);
    let scale = scale_from_deg(&st.deg, &h.options);

    let rows = h.row1 - h.row0;
    st.out.clear();
    st.out.resize(rows * k, 0.0);
    embed_shard(
        &st.src,
        &st.dst,
        &st.w,
        h.row0,
        h.row1,
        &st.labels,
        &wv,
        scale.as_deref(),
        k,
        &h.options,
        &mut st.ws,
        &mut st.out,
    );

    writeln!(writer, "OK rows={rows}")?;
    super::worker::write_z_rows(writer, &st.out, rows, k)?;
    writeln!(writer, "DONE")?;
    Ok(())
}

/// Read one line into `buf`, returning its trimmed contents; EOF is an
/// error (a framed body must be complete).
fn read_trimmed<'a>(reader: &mut impl BufRead, buf: &'a mut String) -> Result<&'a str> {
    buf.clear();
    if reader.read_line(buf)? == 0 {
        bail!("connection closed mid-request");
    }
    Ok(buf.trim())
}

/// Client side of one `SHARD` round trip: stream shard `s` of `sp` to an
/// open daemon connection and return its `(row1-row0) * k` Z cells.
/// Bitwise contract: the spill file's weight text is forwarded verbatim
/// and the reply is parsed with the shared row grammar, so the result is
/// byte-for-byte what the in-process shard pass produces.
pub(crate) fn request_shard(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    sp: &super::spill::SpilledShards,
    opts: &GeeOptions,
    s: usize,
) -> Result<Vec<f64>> {
    let plan = &sp.plan;
    let (v0, v1) = plan.shard_range(s);
    let b = |v: bool| if v { "1" } else { "0" };
    writeln!(
        writer,
        "SHARD n={} k={} row0={v0} row1={v1} lap={} diag={} cor={}",
        plan.n,
        plan.k,
        b(opts.laplacian),
        b(opts.diagonal),
        b(opts.correlation)
    )?;
    for &l in &sp.labels {
        writeln!(writer, "{l}")?;
    }
    for &d in &plan.deg {
        writeln!(writer, "{d}")?;
    }
    // forward the spill file's lines untouched (already shortest-roundtrip)
    let f = std::fs::File::open(&sp.files[s])
        .with_context(|| format!("open {}", sp.files[s].display()))?;
    let mut file_line = String::new();
    let mut fr = BufReader::new(f);
    loop {
        file_line.clear();
        if fr.read_line(&mut file_line)? == 0 {
            break;
        }
        let t = file_line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        writer.write_all(t.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    writeln!(writer, "END")?;
    writer.flush()?;

    let mut line = String::new();
    let t = read_trimmed(reader, &mut line).context("shard reply header")?;
    let rows_claim: usize = t
        .strip_prefix("OK rows=")
        .with_context(|| format!("worker said: {t}"))?
        .parse()
        .context("bad rows count")?;
    let rows = v1 - v0;
    if rows_claim != rows {
        bail!("worker replied {rows_claim} rows, expected {rows}");
    }
    let k = plan.k;
    let mut out = vec![0.0f64; rows * k];
    for r in 0..rows {
        let t = read_trimmed(reader, &mut line)
            .with_context(|| format!("Z row {}", r + 1))?;
        super::worker::parse_z_row(t, k, &mut out[r * k..(r + 1) * k])
            .with_context(|| format!("Z row {}", r + 1))?;
    }
    let t = read_trimmed(reader, &mut line)?;
    if t != "DONE" {
        bail!("missing DONE trailer, got '{t}'");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::graph::Graph;
    use crate::shard::spill::{spill_from_graph, SpillConfig};
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(4, 4, 1.75);
        g
    }

    #[test]
    fn header_parse_and_bounds() {
        let h = ShardHeader::parse("SHARD n=10 k=3 row0=2 row1=7 lap=1 diag=0 cor=1")
            .unwrap();
        assert_eq!((h.n, h.k, h.row0, h.row1), (10, 3, 2, 7));
        assert_eq!(h.options, GeeOptions::new(true, false, true));

        // oversized / inconsistent headers are rejected before allocation
        assert!(ShardHeader::parse("SHARD n=0 k=1 row0=0 row1=0").is_err());
        assert!(ShardHeader::parse(&format!(
            "SHARD n={} k=1 row0=0 row1=1",
            MAX_FRAME_VERTICES + 1
        ))
        .is_err());
        assert!(ShardHeader::parse(&format!(
            "SHARD n=10 k={} row0=0 row1=1",
            MAX_FRAME_CLASSES + 1
        ))
        .is_err());
        // rows*k product overflow / cap
        assert!(ShardHeader::parse(&format!(
            "SHARD n={0} k=16777216 row0=0 row1={0}",
            u32::MAX
        ))
        .is_err());
        assert!(ShardHeader::parse("SHARD n=5 k=2 row0=4 row1=2").is_err());
        assert!(ShardHeader::parse("SHARD n=5 k=2 row0=0 row1=9").is_err());
        assert!(ShardHeader::parse("SHARD n=5 k=2 row0=0 row1=5 lap=x").is_err());
        assert!(ShardHeader::parse("SHARD n=5 row0=0 row1=5").is_err());
        assert!(ShardHeader::parse("PING").is_err());
    }

    #[test]
    fn round_trip_over_localhost_is_bitwise() {
        let dir = std::env::temp_dir()
            .join(format!("gee_remote_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let g = random_graph(551, 80, 450, 3);
        let sp = spill_from_graph(
            &g,
            &SpillConfig { shards: 3, ..SpillConfig::new(&dir) },
        )
        .unwrap();

        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);

        for opts in GeeOptions::table_order() {
            let whole = SparseGee::fast().embed(&g, &opts);
            for s in 0..sp.plan.shards() {
                let (v0, v1) = sp.plan.shard_range(s);
                let rows =
                    request_shard(&mut reader, &mut writer, &sp, &opts, s).unwrap();
                assert_eq!(
                    rows,
                    whole.data[v0 * g.k..v1 * g.k].to_vec(),
                    "remote shard {s} drifted at {opts:?}"
                );
            }
        }
        server.stop();
    }

    #[test]
    fn ping_and_error_paths() {
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writeln!(writer, "PING").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        // hostile header: huge n rejected with a bounded error, instantly
        writeln!(writer, "SHARD n=99999999999999 k=2 row0=0 row1=1").unwrap();
        writer.flush().unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");
        server.stop();
    }
}
