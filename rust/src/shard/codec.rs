//! The shard fleet's binary data plane — one codec for spill files,
//! worker pipes, and the TCP wire.
//!
//! Everything the distributed lanes exchange reduces to three record
//! shapes, all little-endian, all fixed width:
//!
//! * **edge record** — `u32 src | u32 dst | f64 weight` (16 bytes);
//! * **label record** — one `i32` (4 bytes);
//! * **value record** — one `f64` raw bit pattern (8 bytes).
//!
//! f64s travel as raw bit patterns, so parity with `sparse-fast` is
//! bitwise *by construction* — no shortest-roundtrip format/re-parse
//! dance, no decimal grammar on any hot path. On the wire, records are
//! grouped into **frames**: a `u64` little-endian byte-length prefix
//! followed by exactly that many payload bytes. A reader validates the
//! prefix (record alignment, a hard byte cap, and — when the protocol
//! fixes the size — the exact expected length) *before* allocating
//! anything from it, then consumes the body in bounded chunks, so a
//! hostile or truncated peer costs at most one chunk of memory and a
//! typed error, never a panic or an unbounded allocation (the same
//! admission discipline as the `MAX_FRAME_*` header caps in
//! [`super::remote`]).
//!
//! Spill files are headerless runs of edge records (`len % 16 == 0`
//! always), which is what lets [`super::dispatch`] stream a shard's
//! spill file to a remote worker as raw bytes with zero re-parse: the
//! file *is* the frame body, the frame length *is* the file length.
//!
//! [`globals_hash`] fingerprints a job's global label + degree vectors
//! (FNV-1a 64 over their serialized bytes); the wire-v2 `GLOBALS` verb
//! ships the vectors once per connection under that key and every
//! subsequent `SHARD2` request references them by hash — per-job fleet
//! traffic drops from O(S·n + E) to O(W·n + E).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// Bytes per wire/spill edge record: `u32 src | u32 dst | f64 weight`.
pub const EDGE_RECORD_BYTES: usize = 16;
/// Bytes per label record (`i32`).
pub const LABEL_RECORD_BYTES: usize = 4;
/// Bytes per f64 value record (degrees, Z cells).
pub const F64_RECORD_BYTES: usize = 8;
/// Frame bodies are consumed in chunks of at most this many bytes, so a
/// declared-huge frame never translates into one huge allocation. A
/// multiple of every record size, so chunk boundaries never split a
/// record.
pub const FRAME_CHUNK_BYTES: usize = 1 << 20;

const _: () = assert!(FRAME_CHUNK_BYTES % EDGE_RECORD_BYTES == 0);
const _: () = assert!(EDGE_RECORD_BYTES % F64_RECORD_BYTES == 0);
const _: () = assert!(F64_RECORD_BYTES % LABEL_RECORD_BYTES == 0);
const _: () = assert!(FRAME_CHUNK_BYTES % DELTA_RECORD_BYTES == 0);

/// Extension marking a file as binary records; everything else is the
/// legacy text format. Explicit-by-name beats content sniffing: a spill
/// file has no magic header (its byte length must be exactly
/// `records × 16`), so the name is the only place the format can live.
pub const BINARY_EXT: &str = "bin";

/// Does `path` name a binary-record file?
pub fn is_binary_path(path: &Path) -> bool {
    path.extension().and_then(|e| e.to_str()) == Some(BINARY_EXT)
}

// ---------------------------------------------------------------- records

/// Encode one edge record.
#[inline]
pub fn encode_edge(a: u32, b: u32, w: f64) -> [u8; EDGE_RECORD_BYTES] {
    let mut rec = [0u8; EDGE_RECORD_BYTES];
    rec[0..4].copy_from_slice(&a.to_le_bytes());
    rec[4..8].copy_from_slice(&b.to_le_bytes());
    rec[8..16].copy_from_slice(&w.to_le_bytes());
    rec
}

/// Decode one edge record (inverse of [`encode_edge`], bitwise).
#[inline]
pub fn decode_edge(rec: &[u8]) -> (u32, u32, f64) {
    debug_assert_eq!(rec.len(), EDGE_RECORD_BYTES);
    let a = u32::from_le_bytes(rec[0..4].try_into().unwrap());
    let b = u32::from_le_bytes(rec[4..8].try_into().unwrap());
    let w = f64::from_le_bytes(rec[8..16].try_into().unwrap());
    (a, b, w)
}

/// Append one edge record to a writer (spill writers' per-edge call).
#[inline]
pub fn write_edge_record(w: &mut impl Write, a: u32, b: u32, wt: f64) -> std::io::Result<()> {
    w.write_all(&encode_edge(a, b, wt))
}

/// Bytes per session delta record (`DELTA2` frame bodies):
/// `u32 op | u32 a | u32 b | u32 pad | f64 weight | f64 reserved`.
/// 32 bytes keeps [`FRAME_CHUNK_BYTES`] a whole number of records, so
/// chunked frame reads never split one.
pub const DELTA_RECORD_BYTES: usize = 32;

/// Delta op codes. For [`DELTA_OP_RELABEL`], `a` is the vertex and `b`
/// carries the new label's i32 bit pattern (`-1` = unlabeled); the
/// weight field is ignored.
pub const DELTA_OP_INSERT: u32 = 0;
pub const DELTA_OP_DELETE: u32 = 1;
pub const DELTA_OP_RELABEL: u32 = 2;

/// Encode one session delta record.
#[inline]
pub fn encode_delta(op: u32, a: u32, b: u32, w: f64) -> [u8; DELTA_RECORD_BYTES] {
    let mut rec = [0u8; DELTA_RECORD_BYTES];
    rec[0..4].copy_from_slice(&op.to_le_bytes());
    rec[4..8].copy_from_slice(&a.to_le_bytes());
    rec[8..12].copy_from_slice(&b.to_le_bytes());
    rec[16..24].copy_from_slice(&w.to_le_bytes());
    rec
}

/// Decode one session delta record (inverse of [`encode_delta`],
/// bitwise on the weight).
#[inline]
pub fn decode_delta(rec: &[u8]) -> (u32, u32, u32, f64) {
    debug_assert_eq!(rec.len(), DELTA_RECORD_BYTES);
    let op = u32::from_le_bytes(rec[0..4].try_into().unwrap());
    let a = u32::from_le_bytes(rec[4..8].try_into().unwrap());
    let b = u32::from_le_bytes(rec[8..12].try_into().unwrap());
    let w = f64::from_le_bytes(rec[16..24].try_into().unwrap());
    (op, a, b, w)
}

/// Append one session delta record to a writer.
#[inline]
pub fn write_delta_record(
    w: &mut impl Write,
    op: u32,
    a: u32,
    b: u32,
    wt: f64,
) -> std::io::Result<()> {
    w.write_all(&encode_delta(op, a, b, wt))
}

// ------------------------------------------------------------ record files

/// Stream a binary edge-record file in file order. The file length must
/// be an exact multiple of the record size — anything else means
/// truncation (or a text file got in), and half a record silently
/// dropped would corrupt an embed, so it is a hard error.
pub fn for_each_edge_binary(path: &Path, mut f: impl FnMut(u32, u32, f64)) -> Result<usize> {
    try_for_each_edge_binary(path, |a, b, w| {
        f(a, b, w);
        std::ops::ControlFlow::Continue(())
    })
}

/// [`for_each_edge_binary`] with early exit (the binary twin of
/// `graph::io::try_for_each_edge`): the callback returns
/// `ControlFlow::Break(())` to stop the stream; the visit count so far
/// is still returned.
pub fn try_for_each_edge_binary(
    path: &Path,
    mut f: impl FnMut(u32, u32, f64) -> std::ops::ControlFlow<()>,
) -> Result<usize> {
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let len = file.metadata()?.len();
    if len % EDGE_RECORD_BYTES as u64 != 0 {
        bail!(
            "{}: {len} bytes is not a whole number of {EDGE_RECORD_BYTES}-byte edge records (truncated?)",
            path.display()
        );
    }
    let mut reader = BufReader::new(file);
    let mut rec = [0u8; EDGE_RECORD_BYTES];
    let total = (len / EDGE_RECORD_BYTES as u64) as usize;
    for i in 0..total {
        reader
            .read_exact(&mut rec)
            .with_context(|| format!("{}: edge record {}", path.display(), i + 1))?;
        let (a, b, w) = decode_edge(&rec);
        if f(a, b, w).is_break() {
            return Ok(i + 1);
        }
    }
    Ok(total)
}

/// Stream an edge file of either format: binary records when the path
/// says [`BINARY_EXT`], the `graph::io` text grammar otherwise — so the
/// shard lanes read old text spills and new binary spills through one
/// call.
pub fn for_each_edge_auto(path: &Path, f: impl FnMut(u32, u32, f64)) -> Result<usize> {
    if is_binary_path(path) {
        for_each_edge_binary(path, f)
    } else {
        crate::graph::io::for_each_edge(path, f)
    }
}

/// Format-dispatching twin of [`try_for_each_edge_binary`] /
/// `graph::io::try_for_each_edge`.
pub fn try_for_each_edge_auto(
    path: &Path,
    f: impl FnMut(u32, u32, f64) -> std::ops::ControlFlow<()>,
) -> Result<usize> {
    if is_binary_path(path) {
        try_for_each_edge_binary(path, f)
    } else {
        crate::graph::io::try_for_each_edge(path, f)
    }
}

/// Write a headerless run of `i32` records (the binary labels file).
pub fn write_i32s_file(path: &Path, vals: &[i32]) -> Result<()> {
    let mut f = BufWriter::new(
        File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    for v in vals {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush().with_context(|| format!("flush {}", path.display()))?;
    Ok(())
}

/// Read a headerless run of `i32` records; byte length must be exact.
pub fn read_i32s_file(path: &Path) -> Result<Vec<i32>> {
    let bytes = record_file_bytes(path, LABEL_RECORD_BYTES)?;
    Ok(bytes
        .chunks_exact(LABEL_RECORD_BYTES)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Write a headerless run of raw-bit f64 records (degrees, Z rows).
pub fn write_f64s_file(path: &Path, vals: &[f64]) -> Result<()> {
    let mut f = BufWriter::new(
        File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    for v in vals {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush().with_context(|| format!("flush {}", path.display()))?;
    Ok(())
}

/// Read a headerless run of raw-bit f64 records; byte length must be
/// exact (bitwise inverse of [`write_f64s_file`]).
pub fn read_f64s_file(path: &Path) -> Result<Vec<f64>> {
    let bytes = record_file_bytes(path, F64_RECORD_BYTES)?;
    Ok(bytes
        .chunks_exact(F64_RECORD_BYTES)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn record_file_bytes(path: &Path, record: usize) -> Result<Vec<u8>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    if bytes.len() % record != 0 {
        bail!(
            "{}: {} bytes is not a whole number of {record}-byte records (truncated?)",
            path.display(),
            bytes.len()
        );
    }
    Ok(bytes)
}

// --------------------------------------------------------------- wire frames

/// Write a frame's length prefix.
pub fn write_frame_len(w: &mut impl Write, len: u64) -> std::io::Result<()> {
    w.write_all(&len.to_le_bytes())
}

/// Read a frame's length prefix. EOF here is a typed error naming the
/// frame — a framed body must be complete.
pub fn read_frame_len(r: &mut impl Read, what: &str) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)
        .with_context(|| format!("{what}: connection closed before frame length"))?;
    Ok(u64::from_le_bytes(buf))
}

/// Validate a frame length prefix *before* anything is allocated from
/// it: record alignment, the hard byte cap, and (when the protocol fixes
/// the size) the exact expected length.
pub fn check_frame_len(
    len: u64,
    record: usize,
    max_bytes: u64,
    expected: Option<u64>,
    what: &str,
) -> Result<()> {
    if len > max_bytes {
        bail!("{what}: frame of {len} bytes exceeds the wire limit {max_bytes}");
    }
    if len % record as u64 != 0 {
        bail!("{what}: frame of {len} bytes is not a whole number of {record}-byte records");
    }
    if let Some(exp) = expected {
        if len != exp {
            bail!("{what}: frame of {len} bytes, expected exactly {exp}");
        }
    }
    Ok(())
}

/// Consume a frame body of `len` bytes in bounded chunks, invoking
/// `sink` per chunk. `scratch` is the reused chunk buffer (grows to at
/// most [`FRAME_CHUNK_BYTES`]); every chunk's length is a multiple of
/// every record size, so sinks can `chunks_exact` without carry-over.
/// Mid-frame EOF is a typed error naming the frame.
pub fn read_frame_body(
    r: &mut impl Read,
    len: u64,
    scratch: &mut Vec<u8>,
    what: &str,
    mut sink: impl FnMut(&[u8]) -> Result<()>,
) -> Result<()> {
    let mut remaining = len;
    while remaining > 0 {
        let take = remaining.min(FRAME_CHUNK_BYTES as u64) as usize;
        scratch.resize(take, 0);
        r.read_exact(&mut scratch[..take]).with_context(|| {
            format!("{what}: connection closed mid-frame ({remaining} of {len} bytes unread)")
        })?;
        sink(&scratch[..take])?;
        remaining -= take as u64;
    }
    Ok(())
}

/// Write one frame of `i32` records.
pub fn write_frame_i32s(w: &mut impl Write, vals: &[i32]) -> std::io::Result<()> {
    write_frame_len(w, (vals.len() * LABEL_RECORD_BYTES) as u64)?;
    for v in vals {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Write one frame of raw-bit f64 records.
pub fn write_frame_f64s(w: &mut impl Write, vals: &[f64]) -> std::io::Result<()> {
    write_frame_len(w, (vals.len() * F64_RECORD_BYTES) as u64)?;
    for v in vals {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// The label contract every transport enforces on ingest, in one place:
/// `-1` is the only negative (the unlabeled sentinel the engines'
/// `l >= 0` checks understand), and labels must stay below `k`. Shared
/// by the v1 text wire, the v2 `GLOBALS` decode, and the worker's
/// binary label files, so the lanes cannot drift apart on what a valid
/// label is.
#[inline]
pub fn validate_label(l: i32, k: usize) -> Result<()> {
    if l < -1 {
        bail!("label {l} < -1 (use -1 for unlabeled)");
    }
    if l >= k as i32 {
        bail!("label {l} >= k {k}");
    }
    Ok(())
}

// ------------------------------------------------------------- content hash

/// Incremental FNV-1a (64-bit) — the GLOBALS content fingerprint. Not
/// cryptographic (the fleet is a trusted tier; see the README's TLS/auth
/// note): it exists to catch mismatched or re-ordered global vectors,
/// not adversarial collisions.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::default()
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint a job's global vectors: FNV-1a over the labels' LE bytes
/// then the degrees' LE bytes — exactly the byte stream the `GLOBALS`
/// frames carry, so the daemon can re-hash what it receives and reject a
/// mismatch.
pub fn globals_hash(labels: &[i32], deg: &[f64]) -> u64 {
    let mut h = Fnv64::new();
    for l in labels {
        h.update(&l.to_le_bytes());
    }
    for d in deg {
        h.update(&d.to_le_bytes());
    }
    h.finish()
}

// ---------------------------------------------------------- byte accounting

/// Shared per-lane byte counters — how the text→binary win is measured
/// instead of asserted ([`super::dispatch`] threads these through every
/// slot connection; `benches/shard_scale.rs` records them and
/// `Metrics::remote_bytes` aggregates them in the coordinator).
#[derive(Debug, Default)]
pub struct ByteCounters {
    pub sent: AtomicU64,
    pub received: AtomicU64,
}

impl ByteCounters {
    pub fn total(&self) -> u64 {
        self.sent.load(Ordering::Relaxed) + self.received.load(Ordering::Relaxed)
    }
}

/// A reader that counts bytes into [`ByteCounters::received`].
pub struct CountingReader<R> {
    inner: R,
    counters: Arc<ByteCounters>,
}

impl<R: Read> CountingReader<R> {
    pub fn new(inner: R, counters: Arc<ByteCounters>) -> Self {
        CountingReader { inner, counters }
    }
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.counters.received.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }
}

/// A writer that counts bytes into [`ByteCounters::sent`].
pub struct CountingWriter<W> {
    inner: W,
    counters: Arc<ByteCounters>,
}

impl<W: Write> CountingWriter<W> {
    pub fn new(inner: W, counters: Arc<ByteCounters>) -> Self {
        CountingWriter { inner, counters }
    }
}

impl<W: Write> Write for CountingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.counters.sent.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn edge_record_roundtrips_bitwise() {
        for (a, b, w) in [
            (0u32, 0u32, 0.0f64),
            (7, 3, 0.1 + 0.2),
            (u32::MAX, u32::MAX - 1, f64::MIN_POSITIVE),
            (1, 2, -0.0),
            (9, 9, f64::NAN),
        ] {
            let rec = encode_edge(a, b, w);
            let (a2, b2, w2) = decode_edge(&rec);
            assert_eq!((a, b), (a2, b2));
            assert_eq!(w.to_bits(), w2.to_bits(), "weight bits drifted");
        }
    }

    #[test]
    fn delta_record_roundtrips_bitwise() {
        for (op, a, b, w) in [
            (DELTA_OP_INSERT, 0u32, 1u32, 1.5f64),
            (DELTA_OP_DELETE, u32::MAX, 7, 0.0),
            (DELTA_OP_RELABEL, 3, (-1i32) as u32, f64::NAN),
            (DELTA_OP_RELABEL, 9, 4, 0.1 + 0.2),
        ] {
            let rec = encode_delta(op, a, b, w);
            assert_eq!(rec.len(), DELTA_RECORD_BYTES);
            let (op2, a2, b2, w2) = decode_delta(&rec);
            assert_eq!((op, a, b), (op2, a2, b2));
            assert_eq!(w.to_bits(), w2.to_bits(), "weight bits drifted");
        }
        // the relabel label round-trips through the u32 field
        let rec = encode_delta(DELTA_OP_RELABEL, 5, (-1i32) as u32, 0.0);
        let (_, _, label_bits, _) = decode_delta(&rec);
        assert_eq!(label_bits as i32, -1);
    }

    #[test]
    fn edge_file_roundtrips_and_pins_exact_size() {
        let d = std::env::temp_dir().join(format!("gee_codec_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("edges.bin");
        let edges = [(1u32, 2u32, 0.5f64), (3, 3, 2.0_f64.sqrt()), (0, 7, 1.0)];
        {
            let mut f = BufWriter::new(File::create(&p).unwrap());
            for &(a, b, w) in &edges {
                write_edge_record(&mut f, a, b, w).unwrap();
            }
            f.flush().unwrap();
        }
        assert_eq!(
            std::fs::metadata(&p).unwrap().len(),
            (edges.len() * EDGE_RECORD_BYTES) as u64,
            "binary edge files are exactly records x record_size"
        );
        let mut seen = Vec::new();
        let count = for_each_edge_binary(&p, |a, b, w| seen.push((a, b, w.to_bits()))).unwrap();
        assert_eq!(count, edges.len());
        let expect: Vec<_> = edges.iter().map(|&(a, b, w)| (a, b, w.to_bits())).collect();
        assert_eq!(seen, expect);
        // auto dispatch: same file through the extension router
        assert!(is_binary_path(&p));
        let n = for_each_edge_auto(&p, |_, _, _| {}).unwrap();
        assert_eq!(n, edges.len());
    }

    #[test]
    fn truncated_edge_file_is_a_typed_error() {
        let d = std::env::temp_dir().join(format!("gee_codec_tr_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("torn.bin");
        std::fs::write(&p, [0u8; EDGE_RECORD_BYTES + 5]).unwrap();
        let err = for_each_edge_binary(&p, |_, _, _| {}).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn i32_and_f64_files_roundtrip_bitwise() {
        let d = std::env::temp_dir().join(format!("gee_codec_v_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let lp = d.join("l.bin");
        let labels = vec![-1, 0, 3, i32::MAX, i32::MIN];
        write_i32s_file(&lp, &labels).unwrap();
        assert_eq!(read_i32s_file(&lp).unwrap(), labels);

        let vp = d.join("v.bin");
        let vals = vec![0.0, -0.0, 0.1 + 0.2, f64::INFINITY, 2.0_f64.sqrt()];
        write_f64s_file(&vp, &vals).unwrap();
        let back = read_f64s_file(&vp).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // ragged byte counts are rejected, not rounded down
        std::fs::write(&vp, [0u8; 13]).unwrap();
        assert!(read_f64s_file(&vp).is_err());
        std::fs::write(&lp, [0u8; 6]).unwrap();
        assert!(read_i32s_file(&lp).is_err());
    }

    #[test]
    fn frame_roundtrip_and_length_validation() {
        let vals = vec![1.5f64, -2.25, 0.1 + 0.2];
        let mut wire = Vec::new();
        write_frame_f64s(&mut wire, &vals).unwrap();
        let mut r = Cursor::new(&wire);
        let len = read_frame_len(&mut r, "test frame").unwrap();
        check_frame_len(len, F64_RECORD_BYTES, 1 << 20, Some(24), "test frame").unwrap();
        let mut scratch = Vec::new();
        let mut back = Vec::new();
        read_frame_body(&mut r, len, &mut scratch, "test frame", |chunk| {
            for c in chunk.chunks_exact(F64_RECORD_BYTES) {
                back.push(f64::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(())
        })
        .unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // oversized prefix: rejected before any body read or allocation
        assert!(check_frame_len(1 << 40, 8, 1 << 30, None, "x").is_err());
        // misaligned prefix
        assert!(check_frame_len(12, 8, 1 << 30, None, "x").is_err());
        // exact-size mismatch
        assert!(check_frame_len(16, 8, 1 << 30, Some(24), "x").is_err());
    }

    #[test]
    fn mid_frame_eof_is_a_typed_error_with_bounded_allocation() {
        // a peer declares 1 GiB then hangs up after 16 bytes: the reader
        // must fail with a typed error having allocated at most one chunk
        let mut wire = Vec::new();
        write_frame_len(&mut wire, 1 << 30).unwrap();
        wire.extend_from_slice(&[0u8; 16]);
        let mut r = Cursor::new(&wire);
        let len = read_frame_len(&mut r, "hostile frame").unwrap();
        check_frame_len(len, 8, 1 << 35, None, "hostile frame").unwrap();
        let mut scratch = Vec::new();
        let err = read_frame_body(&mut r, len, &mut scratch, "hostile frame", |_| Ok(()))
            .unwrap_err();
        assert!(err.to_string().contains("mid-frame"), "{err}");
        assert!(
            scratch.capacity() <= FRAME_CHUNK_BYTES,
            "allocation must be bounded by the chunk size, got {}",
            scratch.capacity()
        );
    }

    #[test]
    fn eof_before_frame_length_is_typed() {
        let mut r = Cursor::new(&[1u8, 2, 3][..]);
        let err = read_frame_len(&mut r, "short frame").unwrap_err();
        assert!(err.to_string().contains("frame length"), "{err}");
    }

    #[test]
    fn label_contract_is_shared_and_exact() {
        assert!(validate_label(-1, 2).is_ok());
        assert!(validate_label(0, 2).is_ok());
        assert!(validate_label(1, 2).is_ok());
        assert!(validate_label(-2, 2).is_err());
        assert!(validate_label(2, 2).is_err());
        assert!(validate_label(0, 0).is_err(), "k=0 admits only -1");
        assert!(validate_label(-1, 0).is_ok());
    }

    #[test]
    fn globals_hash_is_stable_and_order_sensitive() {
        let labels = vec![0, 1, -1, 2];
        let deg = vec![1.5, 0.0, 2.25];
        let h = globals_hash(&labels, &deg);
        assert_eq!(h, globals_hash(&labels, &deg), "hash must be deterministic");
        assert_ne!(h, globals_hash(&labels, &[2.25, 0.0, 1.5]));
        assert_ne!(h, globals_hash(&[1, 0, -1, 2], &deg));
        // matches an incremental hash over the same byte stream (what the
        // daemon computes while receiving the frames)
        let mut inc = Fnv64::new();
        for l in &labels {
            inc.update(&l.to_le_bytes());
        }
        for d in &deg {
            inc.update(&d.to_le_bytes());
        }
        assert_eq!(h, inc.finish());
    }

    #[test]
    fn counting_streams_count() {
        let counters = Arc::new(ByteCounters::default());
        let mut w = CountingWriter::new(Vec::new(), counters.clone());
        w.write_all(b"hello fleet").unwrap();
        let data = b"0123456789".to_vec();
        let mut r = CountingReader::new(Cursor::new(data), counters.clone());
        let mut buf = Vec::new();
        r.read_to_end(&mut buf).unwrap();
        assert_eq!(counters.sent.load(Ordering::Relaxed), 11);
        assert_eq!(counters.received.load(Ordering::Relaxed), 10);
        assert_eq!(counters.total(), 21);
    }
}
