//! Placement and dispatch for the TCP shard fleet — which daemon embeds
//! which shard, and what happens when one dies.
//!
//! The model is a work queue over a pool of **slots**: each configured
//! endpoint contributes `slots_per_worker` independent connections, and
//! every slot pulls the next pending shard the moment it finishes the
//! previous one (rolling — no waves, no head-of-line blocking; the same
//! scheduling fix [`super::process`] got for local children). Failure
//! semantics mirror the multi-process reaper:
//!
//! * a slot that fails (connect refused, connection dropped mid-stream,
//!   `ERR` reply) pushes its shard back onto the queue and retires — the
//!   failed endpoint is excluded from all further placement, exactly like
//!   a reaped dead child;
//! * surviving slots drain the requeued shards, so a daemon killed
//!   mid-run costs only the retries of its in-flight shard;
//! * the driver returns an error only when the *whole* fleet is dead with
//!   shards still pending, and the error names every endpoint failure.
//!
//! Because each shard's rows are recomputed from the same spill bytes by
//! whichever daemon ends up serving it, retries cannot change the result:
//! output stays bitwise-identical to `SparseGee::fast()` through any
//! sequence of worker deaths that leaves one worker alive.
//!
//! Each slot connection opens with a `PING` health probe (a dead worker
//! is condemned before any shard payload is streamed at it) and then
//! negotiates the wire version: v2 slots stream binary spill bytes
//! ([`super::codec`]) and ship the job's global vectors **once per
//! connection** under a content hash — O(W·n + E) fleet traffic instead
//! of O(S·n + E) — while legacy daemons are served the v1 text protocol
//! unchanged. Mixed fleets are fine: the version is per connection, and
//! both wires produce bit-identical rows.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

use super::codec::{globals_hash, ByteCounters, CountingReader, CountingWriter, Fnv64};
use super::remote::{
    request_reshard, request_shard, request_shard_v2, send_globals, send_relabel,
};
use super::spill::SpilledShards;
use crate::gee::options::GeeOptions;
use crate::sparse::Dense;
use crate::util::retry::{self, BackoffPolicy, Deadlines};

/// Fleet shape.
#[derive(Clone, Debug)]
pub struct DispatchConfig {
    /// Worker daemon endpoints (`host:port`). An endpoint may be listed
    /// more than once to weight placement toward a bigger machine.
    pub endpoints: Vec<String>,
    /// Concurrent in-flight shards per endpoint (each slot holds its own
    /// connection; a daemon embeds its slots on parallel threads).
    pub slots_per_worker: usize,
    /// Per-phase I/O budgets, replacing the old single `io_timeout`:
    /// `connect` bounds the TCP handshake, `hello` the PING/HELLO2
    /// negotiation, `frame` write progress while a spill payload
    /// streams out, and `compute` reads while a request is in flight
    /// (the reply wait — legitimately long on huge shards). A *hung*
    /// worker (silent network partition — no RST) would otherwise
    /// stall the whole dispatch with its in-flight shard never
    /// requeued; with budgets the slot fails like a dead one and
    /// survivors take over. Each is a per-syscall progress clock, not
    /// a whole-shard clock, so the defaults are safe for long embeds.
    pub deadlines: Deadlines,
    /// Bounded exponential backoff (deterministic jitter) for the
    /// connect/negotiate path: a flapping endpoint is condemned after
    /// `retry.attempts` connection attempts instead of being retried
    /// forever or condemned on one blip.
    pub retry: BackoffPolicy,
    /// Skip the `HELLO2` upgrade and speak the v1 text protocol even to
    /// daemons that could do better — the ops escape hatch (and what the
    /// bench uses to put the text lane's byte count on the record next
    /// to the binary lane's).
    pub force_text: bool,
    /// When set, every slot connection counts its wire bytes here
    /// (`benches/shard_scale.rs` records them; the coordinator feeds
    /// them into `Metrics::remote_bytes`).
    pub counters: Option<Arc<ByteCounters>>,
}

impl DispatchConfig {
    pub fn new(endpoints: Vec<String>) -> DispatchConfig {
        DispatchConfig {
            endpoints,
            slots_per_worker: 1,
            deadlines: Deadlines::default(),
            retry: BackoffPolicy::default(),
            force_text: false,
            counters: None,
        }
    }
}

/// Shared scheduler state. Invariant: `total == done + pending.len() +
/// in_flight` — which is what makes the wait condition below sound: a
/// slot waiting on an empty queue is always woken by either a completion
/// (possibly the last) or a requeue.
struct FleetState {
    pending: VecDeque<usize>,
    in_flight: usize,
    done: usize,
    total: usize,
    /// Endpoint indices excluded from further placement. One slot's
    /// failure condemns the whole endpoint: its sibling slots retire at
    /// their next queue visit instead of feeding more shards to a node
    /// already known bad.
    dead: std::collections::HashSet<usize>,
    failures: Vec<String>,
}

/// Embed a spilled graph over the fleet. Bitwise-identical to the
/// in-process lanes for any endpoint count, slot count, and placement
/// order (rows are disjoint; each is produced by the shared shard
/// kernel from the same spill bytes).
pub fn embed_remote(
    sp: &SpilledShards,
    opts: &GeeOptions,
    cfg: &DispatchConfig,
) -> Result<Dense> {
    if cfg.endpoints.is_empty() {
        bail!("remote dispatch needs at least one worker endpoint");
    }
    let plan = &sp.plan;
    let total = plan.shards();
    let slots = cfg.slots_per_worker.max(1);
    let state = Mutex::new(FleetState {
        pending: (0..total).collect(),
        in_flight: 0,
        done: 0,
        total,
        dead: std::collections::HashSet::new(),
        failures: Vec::new(),
    });
    let cond = Condvar::new();
    let mut z = Dense::zeros(plan.n, plan.k);
    // one fingerprint per job: v2 slots ship the global vectors once per
    // connection under this hash and reference them per shard
    let ghash = globals_hash(&sp.labels, &plan.deg);

    let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<f64>)>();
    std::thread::scope(|sc| {
        for (ep_idx, ep) in cfg.endpoints.iter().enumerate() {
            for slot in 0..slots {
                let tx = tx.clone();
                let (state, cond) = (&state, &cond);
                sc.spawn(move || {
                    slot_loop(ep, ep_idx, slot, sp, opts, cfg, ghash, state, cond, tx)
                });
            }
        }
        drop(tx);
        // the collector is this thread: place rows as slots finish; the
        // channel closes when every slot has retired or the work is done
        while let Ok((s, rows)) = rx.recv() {
            let (v0, v1) = plan.shard_range(s);
            z.data[v0 * plan.k..v1 * plan.k].copy_from_slice(&rows);
        }
    });

    let st = state.into_inner().unwrap();
    if st.done != total {
        bail!(
            "remote fleet incomplete: {}/{} shards embedded, all endpoints dead: {}",
            st.done,
            total,
            st.failures.join("; ")
        );
    }
    Ok(z)
}

/// One negotiated slot connection. `v2` is decided once per connection
/// (the `HELLO2` exchange); `globals_sent` tracks whether this
/// connection has shipped the job's global vectors yet — the per-
/// (connection, job) cache key is the content hash computed in
/// [`embed_remote`].
struct SlotConn {
    reader: BufReader<CountingReader<TcpStream>>,
    writer: BufWriter<CountingWriter<TcpStream>>,
    /// Frame-chunk scratch reused across every shard this slot serves
    /// (bounded by `codec::FRAME_CHUNK_BYTES`) — the driver-side twin of
    /// the daemon's `ConnState::chunk`.
    scratch: Vec<u8>,
    v2: bool,
    globals_sent: bool,
}

impl SlotConn {
    /// `ctl` is a dup of the connection's fd: socket timeouts live on
    /// the shared file description, so flipping them here reaches both
    /// the reader and writer halves. Negotiation ran under the `hello`
    /// budget; steady state is read=`compute` (the reply wait — the
    /// legitimately long pole) and write=`frame` (per-syscall progress
    /// while a spill payload streams out).
    fn new(
        reader: BufReader<CountingReader<TcpStream>>,
        writer: BufWriter<CountingWriter<TcpStream>>,
        ctl: &TcpStream,
        deadlines: &Deadlines,
        v2: bool,
    ) -> SlotConn {
        ctl.set_read_timeout(deadlines.compute).ok();
        ctl.set_write_timeout(deadlines.frame).ok();
        SlotConn { reader, writer, scratch: Vec::new(), v2, globals_sent: false }
    }

    /// Run one shard through whichever wire the connection negotiated.
    fn request(
        &mut self,
        sp: &SpilledShards,
        opts: &GeeOptions,
        s: usize,
        ghash: u64,
    ) -> Result<Vec<f64>> {
        let mut run = || -> Result<Vec<f64>> {
            if self.v2 {
                if !self.globals_sent {
                    send_globals(&mut self.reader, &mut self.writer, sp, ghash)
                        .context("send GLOBALS")?;
                    self.globals_sent = true;
                }
                request_shard_v2(
                    &mut self.reader,
                    &mut self.writer,
                    sp,
                    opts,
                    s,
                    ghash,
                    &mut self.scratch,
                    false,
                )
            } else {
                request_shard(&mut self.reader, &mut self.writer, sp, opts, s)
            }
        };
        run().map_err(|e| name_deadline(e, "frame/compute"))
    }
}

/// Rename a timeout-rooted error after the protocol phase whose budget
/// it blew — the bare `WouldBlock`/`TimedOut` a socket read surfaces
/// says nothing about *which* deadline fired.
fn name_deadline(e: anyhow::Error, phase: &str) -> anyhow::Error {
    let timed_out = e
        .root_cause()
        .downcast_ref::<std::io::Error>()
        .map(retry::is_timeout)
        .unwrap_or(false);
    if timed_out {
        e.context(format!("{phase} deadline exceeded"))
    } else {
        e
    }
}

/// One slot: connect + probe + negotiate, then pull shards until the
/// work is done or this endpoint fails. A failure (on this slot *or* a
/// sibling slot of the same endpoint) requeues the in-flight shard for
/// survivors, marks the endpoint dead, and retires the slot — the
/// endpoint-exclusion rule.
#[allow(clippy::too_many_arguments)]
fn slot_loop(
    endpoint: &str,
    ep_idx: usize,
    slot: usize,
    sp: &SpilledShards,
    opts: &GeeOptions,
    cfg: &DispatchConfig,
    ghash: u64,
    state: &Mutex<FleetState>,
    cond: &Condvar,
    tx: Sender<(usize, Vec<f64>)>,
) {
    let key = ((ep_idx as u64) << 32) | slot as u64;
    let mut conn = match connect_with_retry(endpoint, key, cfg) {
        Ok(c) => c,
        Err(e) => {
            let mut g = state.lock().unwrap();
            g.dead.insert(ep_idx);
            g.failures.push(format!("{endpoint}: {e:#}"));
            // no shard was held, so nothing to requeue; wake any waiter
            // in case this was the last live slot
            cond.notify_all();
            return;
        }
    };
    loop {
        let s = {
            let mut g = state.lock().unwrap();
            while g.pending.is_empty()
                && g.done < g.total
                && !g.dead.contains(&ep_idx)
            {
                g = cond.wait(g).unwrap();
            }
            if g.dead.contains(&ep_idx) {
                // a sibling slot condemned this endpoint: retire without
                // taking work (our connection is to the same bad node)
                return;
            }
            if g.done >= g.total {
                break;
            }
            let s = g.pending.pop_front().unwrap();
            g.in_flight += 1;
            s
        };
        match conn.request(sp, opts, s, ghash) {
            Ok(rows) => {
                // send before decrementing in_flight: the collector must
                // never observe "all done" with a row block still in a
                // slot's hands
                let _ = tx.send((s, rows));
                let mut g = state.lock().unwrap();
                g.in_flight -= 1;
                g.done += 1;
                cond.notify_all();
            }
            Err(e) => {
                let mut g = state.lock().unwrap();
                g.in_flight -= 1;
                g.pending.push_back(s);
                g.dead.insert(ep_idx);
                g.failures.push(format!("{endpoint}: shard {s}: {e:#}"));
                cond.notify_all();
                return;
            }
        }
    }
    let _ = writeln!(conn.writer, "QUIT");
    let _ = conn.writer.flush();
}

/// Raw TCP connect under the `connect` budget; byte-counted
/// reader/writer over one shared stream, plus a `ctl` dup for later
/// phase-timeout flips. The socket opens in the `hello` phase: reads
/// are budgeted for negotiation until [`SlotConn::new`] switches to
/// steady state.
fn tcp_connect(
    endpoint: &str,
    cfg: &DispatchConfig,
) -> Result<(
    BufReader<CountingReader<TcpStream>>,
    BufWriter<CountingWriter<TcpStream>>,
    TcpStream,
)> {
    let addr = endpoint
        .to_socket_addrs()
        .with_context(|| format!("resolve {endpoint}"))?
        .next()
        .with_context(|| format!("{endpoint} resolved to no address"))?;
    let stream = TcpStream::connect_timeout(&addr, cfg.deadlines.connect)
        .with_context(|| format!("connect {endpoint}"))?;
    stream.set_read_timeout(cfg.deadlines.hello)?;
    stream.set_write_timeout(cfg.deadlines.frame)?;
    stream.set_nodelay(true).ok();
    let counters = cfg
        .counters
        .clone()
        .unwrap_or_else(|| Arc::new(ByteCounters::default()));
    let ctl = stream.try_clone()?;
    let reader = BufReader::new(CountingReader::new(stream.try_clone()?, counters.clone()));
    Ok((reader, BufWriter::new(CountingWriter::new(stream, counters)), ctl))
}

fn read_reply_line(
    reader: &mut impl BufRead,
    line: &mut String,
) -> std::io::Result<Option<String>> {
    line.clear();
    if reader.read_line(line)? == 0 {
        return Ok(None); // peer closed
    }
    Ok(Some(line.trim().to_string()))
}

/// Consume one reply line that must be the `PONG` health-probe answer.
fn expect_pong(reader: &mut impl BufRead, line: &mut String, what: &str) -> Result<()> {
    match read_reply_line(reader, line).with_context(|| format!("{what}: read reply"))? {
        Some(t) if t == "PONG" => Ok(()),
        other => bail!("{what}: expected PONG, got {other:?}"),
    }
}

/// Connect, health-probe, and negotiate the wire version.
///
/// The slot always opens with a cheap `PING` — so a long-dead worker is
/// condemned right here, before a multi-MB shard payload is streamed at
/// it (the first evidence of death used to be a failed bulk write).
/// Unless `force_text`, a `HELLO2` is pipelined behind the `PING`: a v2
/// daemon answers `PONG` + `HELLO2`; a legacy daemon answers `PONG`,
/// then `ERR` for the unknown verb and closes — in which case the slot
/// reconnects (the endpoint is known alive from the `PONG`) and speaks
/// v1 text. One extra round trip per connection, only against legacy
/// daemons.
fn connect(endpoint: &str, cfg: &DispatchConfig) -> Result<SlotConn> {
    let (mut reader, mut writer, ctl) = tcp_connect(endpoint, cfg)?;
    let mut line = String::new();
    if cfg.force_text {
        writeln!(writer, "PING")?;
        writer.flush()?;
        expect_pong(&mut reader, &mut line, "health probe")
            .map_err(|e| name_deadline(e, "hello"))?;
        return Ok(SlotConn::new(reader, writer, &ctl, &cfg.deadlines, false));
    }
    writeln!(writer, "PING\nHELLO2")?;
    writer.flush()?;
    expect_pong(&mut reader, &mut line, "health probe")
        .map_err(|e| name_deadline(e, "hello"))?;
    match read_reply_line(&mut reader, &mut line) {
        Ok(Some(t)) if t == "HELLO2" => {
            return Ok(SlotConn::new(reader, writer, &ctl, &cfg.deadlines, true));
        }
        // an ERR line, a clean close, or a teardown-class error while the
        // legacy daemon drops the connection — "no v2 here", fall back
        Ok(_) => {}
        Err(e) if matches!(
            e.kind(),
            std::io::ErrorKind::ConnectionReset
                | std::io::ErrorKind::ConnectionAborted
                | std::io::ErrorKind::BrokenPipe
                | std::io::ErrorKind::UnexpectedEof
        ) => {}
        // a timeout or transient read fault on a PONG-answering daemon is
        // a sick endpoint, not a legacy one: fail the slot instead of
        // silently downgrading a healthy v2 fleet to the text wire
        Err(e) => {
            return Err(name_deadline(
                anyhow::Error::new(e)
                    .context("reading HELLO2 reply (endpoint answered PONG, then wedged)"),
                "hello",
            ));
        }
    }
    let (mut reader, mut writer, ctl) = tcp_connect(endpoint, cfg)?;
    writeln!(writer, "PING")?;
    writer.flush()?;
    expect_pong(&mut reader, &mut line, "health probe (text fallback)")
        .map_err(|e| name_deadline(e, "hello"))?;
    Ok(SlotConn::new(reader, writer, &ctl, &cfg.deadlines, false))
}

/// [`connect`] under the configured backoff policy: transient failures
/// (refused, accept-then-die flapping, negotiation timeouts) are
/// retried with deterministically jittered exponential delays, and the
/// endpoint is condemned once the attempt budget is spent. The jitter
/// stream is keyed by endpoint name and slot so parallel slots don't
/// thunder in lockstep, yet every run with the same policy seed replays
/// the same schedule.
fn connect_with_retry(endpoint: &str, key: u64, cfg: &DispatchConfig) -> Result<SlotConn> {
    let mut fnv = Fnv64::new();
    fnv.update(endpoint.as_bytes());
    let mut backoff = cfg.retry.schedule(fnv.finish() ^ key);
    loop {
        match connect(endpoint, cfg) {
            Ok(c) => return Ok(c),
            Err(e) => match backoff.next_delay() {
                Some(d) => std::thread::sleep(d),
                None => {
                    return Err(e.context(format!(
                        "endpoint condemned after {} connection attempt(s)",
                        cfg.retry.attempts.max(1)
                    )))
                }
            },
        }
    }
}

/// Per-endpoint connection state a [`FleetSession`] holds across rounds.
struct EndpointState {
    conn: SlotConn,
    /// Hash of the global vectors this daemon currently holds (`None`
    /// until the first `GLOBALS` ships).
    ghash: Option<u64>,
    /// Shards whose spill payload this daemon retains (`SHARD2 keep=1`
    /// was served) — eligible for edge-free `RESHARD` in later rounds.
    kept: std::collections::HashSet<usize>,
}

/// A multi-round fleet conversation for the iterative cluster loop.
///
/// [`embed_remote`] is one-shot: connections are opened, the job runs,
/// everything is torn down. The cluster loop embeds the *same* spilled
/// graph many times under *changing labels*, so a session keeps one v2
/// connection per endpoint alive across rounds and exploits the daemon's
/// retained-payload cache:
///
/// * round 1 — `GLOBALS` once per endpoint, then `SHARD2 keep=1` per
///   owned shard (edges cross the wire exactly once);
/// * round r>1 — one `RELABEL` per endpoint (the n-vector of labels
///   against the cached globals hash) and one `RESHARD` header per
///   shard: per-round fleet traffic is O(W·n) label bytes, never O(E).
///
/// Shard ownership is deterministic (contiguous blocks over live
/// endpoints), which keeps the daemon-side caches hot. An endpoint that
/// dies mid-session is excluded and its shards are re-served on the
/// survivors via `SHARD2 keep=1` — the spill files back every retry, so
/// output stays bitwise-identical to the in-process lanes through any
/// failure sequence that leaves one endpoint alive.
pub struct FleetSession<'a> {
    sp: &'a SpilledShards,
    opts: GeeOptions,
    endpoints: Vec<String>,
    /// `None` marks a dead endpoint (connect failure, v1-only daemon,
    /// or a mid-round wire error).
    conns: Vec<Option<EndpointState>>,
    /// Hash of the labels the spill was taken under — what `GLOBALS`
    /// ships to a fresh connection before any `RELABEL`.
    sp_hash: u64,
    failures: Vec<String>,
}

impl<'a> FleetSession<'a> {
    /// Connect and negotiate v2 with every endpoint. Endpoints that are
    /// down or speak only the v1 text wire are recorded as dead (the
    /// session needs `RELABEL`/`RESHARD`, which v1 lacks); at least one
    /// live v2 endpoint is required.
    pub fn connect(
        sp: &'a SpilledShards,
        opts: &GeeOptions,
        cfg: &DispatchConfig,
    ) -> Result<FleetSession<'a>> {
        if cfg.endpoints.is_empty() {
            bail!("cluster fleet session needs at least one worker endpoint");
        }
        if cfg.force_text {
            bail!("cluster fleet session requires the binary v2 wire (force_text is set)");
        }
        let mut conns = Vec::with_capacity(cfg.endpoints.len());
        let mut failures = Vec::new();
        for (i, ep) in cfg.endpoints.iter().enumerate() {
            match connect_with_retry(ep, i as u64, cfg) {
                Ok(c) if c.v2 => conns.push(Some(EndpointState {
                    conn: c,
                    ghash: None,
                    kept: std::collections::HashSet::new(),
                })),
                Ok(_) => {
                    failures.push(format!("{ep}: speaks only the v1 text wire"));
                    conns.push(None);
                }
                Err(e) => {
                    failures.push(format!("{ep}: {e:#}"));
                    conns.push(None);
                }
            }
        }
        if conns.iter().all(|c| c.is_none()) {
            bail!(
                "no live v2 endpoint for cluster fleet session: {}",
                failures.join("; ")
            );
        }
        Ok(FleetSession {
            sp,
            opts: *opts,
            endpoints: cfg.endpoints.clone(),
            conns,
            sp_hash: globals_hash(&sp.labels, &sp.plan.deg),
            failures,
        })
    }

    /// Embed the spilled graph under `labels`, reusing kept payloads.
    /// Bitwise-identical to `SparseGee::fast()` on the same graph and
    /// labels, for any endpoint count and any death sequence that
    /// leaves one endpoint alive.
    pub fn embed_round(&mut self, labels: &[i32]) -> Result<Dense> {
        let plan = &self.sp.plan;
        if labels.len() != plan.n {
            bail!(
                "label vector has {} entries for a {}-vertex spill",
                labels.len(),
                plan.n
            );
        }
        let hash = globals_hash(labels, &plan.deg);
        let total = plan.shards();
        let mut z = Dense::zeros(plan.n, plan.k);
        let mut todo: Vec<usize> = (0..total).collect();
        let (sp, opts, sp_hash) = (self.sp, self.opts, self.sp_hash);
        while !todo.is_empty() {
            let live: Vec<usize> = self
                .conns
                .iter()
                .enumerate()
                .filter_map(|(i, c)| c.as_ref().map(|_| i))
                .collect();
            if live.is_empty() {
                bail!(
                    "cluster fleet dead with {} shards pending: {}",
                    todo.len(),
                    self.failures.join("; ")
                );
            }
            // deterministic contiguous blocks over live endpoints; with
            // a stable fleet the same endpoint serves the same shards
            // every round, so its retained payloads always hit
            let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); self.conns.len()];
            for (i, &s) in todo.iter().enumerate() {
                assigned[live[i * live.len() / todo.len()]].push(s);
            }
            let results = std::thread::scope(|sc| {
                let mut handles = Vec::new();
                for ((e, slot), shards) in
                    self.conns.iter_mut().enumerate().zip(assigned.iter())
                {
                    let Some(st) = slot.as_mut() else { continue };
                    if shards.is_empty() {
                        continue;
                    }
                    handles.push((e, sc.spawn(move || -> Result<Vec<(usize, Vec<f64>)>> {
                        ensure_globals(st, sp, labels, hash, sp_hash)?;
                        let mut out = Vec::with_capacity(shards.len());
                        for &s in shards {
                            let rows = if st.kept.contains(&s) {
                                request_reshard(
                                    &mut st.conn.reader,
                                    &mut st.conn.writer,
                                    &sp.plan,
                                    &opts,
                                    s,
                                    hash,
                                    &mut st.conn.scratch,
                                )
                                .with_context(|| format!("RESHARD shard {s}"))?
                            } else {
                                let r = request_shard_v2(
                                    &mut st.conn.reader,
                                    &mut st.conn.writer,
                                    sp,
                                    &opts,
                                    s,
                                    hash,
                                    &mut st.conn.scratch,
                                    true,
                                )
                                .with_context(|| format!("SHARD2 shard {s}"))?;
                                st.kept.insert(s);
                                r
                            };
                            out.push((s, rows));
                        }
                        Ok(out)
                    })));
                }
                handles
                    .into_iter()
                    .map(|(e, h)| (e, h.join().expect("session endpoint thread panicked")))
                    .collect::<Vec<_>>()
            });
            todo.clear();
            for (e, res) in results {
                match res {
                    Ok(rows) => {
                        for (s, r) in rows {
                            let (v0, v1) = plan.shard_range(s);
                            z.data[v0 * plan.k..v1 * plan.k].copy_from_slice(&r);
                        }
                    }
                    Err(err) => {
                        self.failures
                            .push(format!("{}: {err:#}", self.endpoints[e]));
                        self.conns[e] = None;
                        todo.extend(assigned[e].iter().copied());
                    }
                }
            }
            todo.sort_unstable();
        }
        Ok(z)
    }

    /// Politely end the session (`QUIT` on every live connection).
    pub fn close(mut self) {
        for slot in self.conns.iter_mut().filter_map(|c| c.as_mut()) {
            let _ = writeln!(slot.conn.writer, "QUIT");
            let _ = slot.conn.writer.flush();
        }
    }
}

/// Bring one daemon's global vectors up to `hash`: first contact ships
/// the spill-time `GLOBALS` (optionally followed by a `RELABEL` when the
/// round's labels already differ); later rounds ship only the `RELABEL`.
fn ensure_globals(
    st: &mut EndpointState,
    sp: &SpilledShards,
    labels: &[i32],
    hash: u64,
    sp_hash: u64,
) -> Result<()> {
    if st.ghash == Some(hash) {
        return Ok(());
    }
    if st.ghash.is_none() {
        send_globals(&mut st.conn.reader, &mut st.conn.writer, sp, sp_hash)
            .context("send GLOBALS")?;
        st.ghash = Some(sp_hash);
        if hash == sp_hash {
            return Ok(());
        }
    }
    send_relabel(
        &mut st.conn.reader,
        &mut st.conn.writer,
        labels,
        sp.plan.n,
        sp.plan.k,
        hash,
    )
    .context("send RELABEL")?;
    st.ghash = Some(hash);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::graph::Graph;
    use crate::shard::remote::ShardServer;
    use crate::shard::spill::{spill_from_graph, SpillConfig};
    use crate::util::rng::Rng;
    use std::time::Duration;

    /// A connect budget for tests that point at dead endpoints: fail
    /// fast, retry fast, keep the suite quick.
    fn fast_fail() -> (Deadlines, BackoffPolicy) {
        (
            Deadlines { connect: Duration::from_millis(300), ..Deadlines::default() },
            BackoffPolicy {
                base: Duration::from_millis(2),
                cap: Duration::from_millis(10),
                attempts: 2,
                seed: 7,
            },
        )
    }

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(3, 3, 2.0);
        g
    }

    fn spill(g: &Graph, tag: &str, shards: usize) -> SpilledShards {
        let dir = std::env::temp_dir()
            .join(format!("gee_dispatch_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        spill_from_graph(g, &SpillConfig { shards, ..SpillConfig::new(&dir) })
            .unwrap()
    }

    #[test]
    fn fleet_of_in_process_daemons_is_bitwise() {
        let g = random_graph(561, 120, 700, 4);
        let sp = spill(&g, "fleet", 5);
        let s1 = ShardServer::start("127.0.0.1:0").unwrap();
        let s2 = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig::new(vec![
            s1.addr().to_string(),
            s2.addr().to_string(),
        ]);
        for opts in crate::gee::GeeOptions::table_order() {
            let expect = SparseGee::fast().embed(&g, &opts);
            let z = embed_remote(&sp, &opts, &cfg).unwrap();
            assert_eq!(z.data, expect.data, "remote fleet drifted at {opts:?}");
        }
        s1.stop();
        s2.stop();
    }

    #[test]
    fn dead_endpoint_is_excluded_and_survivor_finishes() {
        let g = random_graph(562, 90, 500, 3);
        let sp = spill(&g, "dead", 6);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        // 127.0.0.1:1 — reserved port, nothing listens: connect fails,
        // every shard lands on the survivor
        let (deadlines, retry) = fast_fail();
        let cfg = DispatchConfig {
            deadlines,
            retry,
            ..DispatchConfig::new(vec![
                "127.0.0.1:1".to_string(),
                live.addr().to_string(),
            ])
        };
        let opts = crate::gee::GeeOptions::ALL;
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        live.stop();
    }

    #[test]
    fn err_replying_endpoint_is_condemned_with_all_its_slots() {
        // a server that accepts connections but answers every line with
        // ERR: the first slot to hit it condemns the endpoint, sibling
        // slots retire instead of feeding it more shards, and the real
        // daemon finishes everything — bitwise
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let bad_addr = listener.local_addr().unwrap().to_string();
        let bad_server = std::thread::spawn(move || {
            use std::io::{BufRead, BufReader, Write};
            // serve a handful of connections, then quit
            for stream in listener.incoming().take(4) {
                let Ok(stream) = stream else { break };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut line = String::new();
                if reader.read_line(&mut line).is_ok() {
                    let mut w = stream;
                    let _ = writeln!(w, "ERR boom");
                    let _ = w.flush();
                }
            }
        });
        let g = random_graph(566, 100, 600, 3);
        let sp = spill(&g, "errnode", 6);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig {
            slots_per_worker: 3,
            ..DispatchConfig::new(vec![bad_addr, live.addr().to_string()])
        };
        let opts = crate::gee::GeeOptions::ALL;
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        live.stop();
        drop(bad_server); // detach; it exits after its accept budget
    }

    #[test]
    fn mixed_fleet_v2_and_legacy_text_daemon_is_bitwise() {
        // one binary-capable daemon + one legacy text-only daemon: the
        // driver negotiates per connection (HELLO2 vs reconnect-as-text)
        // and both serve shards of the same job — rows must still be
        // bitwise-identical to the fused engine
        let g = random_graph(567, 130, 800, 4);
        let sp = spill(&g, "mixed", 6);
        let v2 = ShardServer::start("127.0.0.1:0").unwrap();
        let legacy = ShardServer::start_text_only("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig::new(vec![
            v2.addr().to_string(),
            legacy.addr().to_string(),
        ]);
        for opts in crate::gee::GeeOptions::table_order() {
            let expect = SparseGee::fast().embed(&g, &opts);
            let z = embed_remote(&sp, &opts, &cfg).unwrap();
            assert_eq!(z.data, expect.data, "mixed fleet drifted at {opts:?}");
        }
        v2.stop();
        legacy.stop();
    }

    #[test]
    fn forced_text_wire_is_bitwise_and_moves_more_bytes() {
        let g = random_graph(568, 110, 650, 3);
        let sp = spill(&g, "forcetext", 5);
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let opts = crate::gee::GeeOptions::ALL;
        let expect = SparseGee::fast().embed(&g, &opts);
        let mut totals = Vec::new();
        for force_text in [false, true] {
            let counters = Arc::new(super::ByteCounters::default());
            let cfg = DispatchConfig {
                force_text,
                counters: Some(counters.clone()),
                ..DispatchConfig::new(vec![server.addr().to_string()])
            };
            let z = embed_remote(&sp, &opts, &cfg).unwrap();
            assert_eq!(z.data, expect.data, "force_text={force_text} drifted");
            assert!(counters.total() > 0, "counters must observe traffic");
            totals.push(counters.total());
        }
        assert!(
            totals[0] < totals[1],
            "binary wire ({}) must move strictly fewer bytes than text ({})",
            totals[0],
            totals[1]
        );
        server.stop();
    }

    #[test]
    fn globals_ship_once_per_connection_not_per_shard() {
        // the GLOBALS-cache contract, measured: the same job over 1
        // connection with many shards must send far less than shards x
        // globals — the per-shard cost is the edge payload + a header,
        // not O(n)
        let g = random_graph(569, 400, 1_500, 3);
        let shards = 8;
        let sp = spill(&g, "amortize", shards);
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let counters = Arc::new(super::ByteCounters::default());
        let cfg = DispatchConfig {
            counters: Some(counters.clone()),
            ..DispatchConfig::new(vec![server.addr().to_string()])
        };
        let opts = crate::gee::GeeOptions::ALL;
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, SparseGee::fast().embed(&g, &opts).data);
        let globals_bytes = (g.n * (4 + 8)) as u64; // labels + degrees
        let spill_bytes: u64 = sp
            .files
            .iter()
            .map(|f| std::fs::metadata(f).unwrap().len())
            .sum();
        // one connection: globals once (+frames/headers/Z slack), never
        // once per shard
        let sent = counters.sent.load(std::sync::atomic::Ordering::Relaxed);
        assert!(
            sent < spill_bytes + 2 * globals_bytes + 1024 * shards as u64,
            "sent {sent} bytes — globals must not be resent per shard \
             (spill={spill_bytes}, globals={globals_bytes}, shards={shards})"
        );
        server.stop();
    }

    #[test]
    fn garbage_probe_reply_condemns_endpoint_before_any_shard_is_streamed() {
        // an endpoint that accepts but answers the PING probe with
        // garbage: the slot must condemn it at bind time — before a
        // multi-MB shard payload is streamed at it — and the survivor
        // must finish everything
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let bad_addr = listener.local_addr().unwrap().to_string();
        let received_payload = std::sync::Arc::new(
            std::sync::atomic::AtomicBool::new(false),
        );
        let received_clone = received_payload.clone();
        let bad_server = std::thread::spawn(move || {
            use std::io::{BufRead, BufReader, Write};
            for stream in listener.incoming().take(2) {
                let Ok(stream) = stream else { break };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut line = String::new();
                if reader.read_line(&mut line).is_ok() {
                    let mut w = stream;
                    let _ = writeln!(w, "WAT");
                    let _ = w.flush();
                    // if the driver streams anything beyond its probe
                    // verbs at us, the probe failed to protect it
                    let mut rest = String::new();
                    while reader.read_line(&mut rest).map(|n| n > 0).unwrap_or(false) {
                        let t = rest.trim();
                        if !t.is_empty() && t != "HELLO2" && t != "PING" && t != "QUIT" {
                            received_clone
                                .store(true, std::sync::atomic::Ordering::Relaxed);
                        }
                        rest.clear();
                    }
                }
            }
        });
        let g = random_graph(570, 80, 400, 3);
        let sp = spill(&g, "probe", 4);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg =
            DispatchConfig::new(vec![bad_addr, live.addr().to_string()]);
        let opts = crate::gee::GeeOptions::NONE;
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        assert!(
            !received_payload.load(std::sync::atomic::Ordering::Relaxed),
            "a shard payload reached an endpoint that failed its health probe"
        );
        live.stop();
        drop(bad_server);
    }

    fn rotate_labels(labels: &mut [i32], k: usize) {
        for l in labels.iter_mut().filter(|l| **l >= 0) {
            *l = (*l + 1) % k as i32;
        }
    }

    #[test]
    fn fleet_session_relabel_rounds_are_bitwise_and_edge_free() {
        let g = random_graph(571, 200, 1200, 4);
        let sp = spill(&g, "session", 6);
        let s1 = ShardServer::start("127.0.0.1:0").unwrap();
        let s2 = ShardServer::start("127.0.0.1:0").unwrap();
        let counters = Arc::new(super::ByteCounters::default());
        let cfg = DispatchConfig {
            counters: Some(counters.clone()),
            ..DispatchConfig::new(vec![
                s1.addr().to_string(),
                s2.addr().to_string(),
            ])
        };
        let opts = crate::gee::GeeOptions::new(true, false, true);
        let mut session = FleetSession::connect(&sp, &opts, &cfg).unwrap();
        let mut labels = sp.labels.clone();
        let mut gl = g.clone();
        let mut sent_after = Vec::new();
        for round in 0..3 {
            if round > 0 {
                rotate_labels(&mut labels, g.k);
            }
            let z = session.embed_round(&labels).unwrap();
            gl.labels.copy_from_slice(&labels);
            let expect = SparseGee::fast().embed(&gl, &opts);
            assert_eq!(z.data, expect.data, "session drifted at round {round}");
            sent_after.push(counters.sent.load(std::sync::atomic::Ordering::Relaxed));
        }
        // rounds after the first ship one RELABEL (label frame) per
        // endpoint plus per-shard RESHARD headers — O(W*n) bytes, never
        // the edge payload again
        let round1 = sent_after[0];
        let label_budget = 2 * (4 * g.n as u64) + 4096;
        for (r, w) in sent_after.windows(2).enumerate() {
            let delta = w[1] - w[0];
            assert!(
                delta <= label_budget,
                "round {} sent {delta} bytes, over the O(W*n) budget {label_budget}",
                r + 2
            );
            assert!(
                delta < round1 / 4,
                "round {} sent {delta} bytes — not clearly cheaper than the \
                 edge-shipping round 1 ({round1})",
                r + 2
            );
        }
        session.close();
        s1.stop();
        s2.stop();
    }

    #[test]
    fn fleet_session_survives_endpoint_death_between_rounds() {
        let g = random_graph(572, 120, 700, 3);
        let sp = spill(&g, "sessiondeath", 5);
        let s1 = ShardServer::start("127.0.0.1:0").unwrap();
        let s2 = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig::new(vec![
            s1.addr().to_string(),
            s2.addr().to_string(),
        ]);
        let opts = crate::gee::GeeOptions::ALL;
        let mut session = FleetSession::connect(&sp, &opts, &cfg).unwrap();
        let z1 = session.embed_round(&sp.labels).unwrap();
        assert_eq!(z1.data, SparseGee::fast().embed(&g, &opts).data);
        // endpoint 0 dies between rounds: its shards must be re-served
        // on the survivor via SHARD2 keep=1 (the spill files still back
        // every retry), and the round must stay bitwise
        session.conns[0] = None;
        let mut labels = sp.labels.clone();
        rotate_labels(&mut labels, g.k);
        let mut gl = g.clone();
        gl.labels.copy_from_slice(&labels);
        let z2 = session.embed_round(&labels).unwrap();
        assert_eq!(z2.data, SparseGee::fast().embed(&gl, &opts).data);
        session.close();
        s1.stop();
        s2.stop();
    }

    #[test]
    fn fleet_session_excludes_connect_dead_endpoint_and_rejects_text_fleet() {
        let g = random_graph(573, 80, 400, 3);
        let sp = spill(&g, "sessionconnect", 4);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        let (deadlines, retry) = fast_fail();
        let cfg = DispatchConfig {
            deadlines,
            retry,
            ..DispatchConfig::new(vec![
                "127.0.0.1:1".to_string(),
                live.addr().to_string(),
            ])
        };
        let opts = crate::gee::GeeOptions::NONE;
        let mut session = FleetSession::connect(&sp, &opts, &cfg).unwrap();
        let z = session.embed_round(&sp.labels).unwrap();
        assert_eq!(z.data, SparseGee::fast().embed(&g, &opts).data);
        session.close();
        live.stop();
        // a fleet with no v2 daemon cannot host a session: RELABEL and
        // RESHARD do not exist on the v1 text wire
        let legacy = ShardServer::start_text_only("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig::new(vec![legacy.addr().to_string()]);
        let err = FleetSession::connect(&sp, &opts, &cfg).unwrap_err();
        assert!(err.to_string().contains("v1 text wire"), "{err}");
        legacy.stop();
    }

    #[test]
    fn whole_fleet_dead_reports_every_endpoint() {
        let g = random_graph(563, 30, 90, 2);
        let sp = spill(&g, "allgone", 2);
        let (deadlines, retry) = fast_fail();
        let cfg = DispatchConfig {
            deadlines,
            retry,
            ..DispatchConfig::new(vec![
                "127.0.0.1:1".to_string(),
                "127.0.0.1:2".to_string(),
            ])
        };
        let err = embed_remote(&sp, &crate::gee::GeeOptions::NONE, &cfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("0/2 shards"), "{msg}");
        assert!(msg.contains("127.0.0.1:1") && msg.contains("127.0.0.1:2"), "{msg}");
    }

    #[test]
    fn flapping_endpoint_is_condemned_within_attempt_budget() {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        // an endpoint that accepts and immediately slams the door,
        // forever: every connect attempt sees EOF instead of PONG. The
        // retry loop must spend exactly `retry.attempts` connections on
        // it, then condemn — and the survivor finishes the job.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let flap_addr = listener.local_addr().unwrap().to_string();
        listener.set_nonblocking(true).unwrap();
        let accepts = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (accepts_t, stop_t) = (accepts.clone(), stop.clone());
        let flapper = std::thread::spawn(move || {
            while !stop_t.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        accepts_t.fetch_add(1, Ordering::Relaxed);
                        drop(stream);
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(2)),
                }
            }
        });
        let g = random_graph(574, 60, 300, 3);
        let sp = spill(&g, "flap", 4);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        let attempts = 3;
        let cfg = DispatchConfig {
            retry: BackoffPolicy {
                base: Duration::from_millis(2),
                cap: Duration::from_millis(10),
                attempts,
                seed: 42,
            },
            ..DispatchConfig::new(vec![flap_addr, live.addr().to_string()])
        };
        let opts = crate::gee::GeeOptions::NONE;
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        assert_eq!(
            accepts.load(Ordering::Relaxed),
            attempts as u64,
            "retry loop must spend exactly the attempt budget on a flapping endpoint"
        );
        stop.store(true, Ordering::Relaxed);
        flapper.join().unwrap();
        live.stop();
    }

    #[test]
    fn no_endpoints_is_an_error() {
        let g = random_graph(564, 10, 20, 2);
        let sp = spill(&g, "none", 2);
        assert!(embed_remote(
            &sp,
            &crate::gee::GeeOptions::NONE,
            &DispatchConfig::new(Vec::new())
        )
        .is_err());
    }

    #[test]
    fn multiple_slots_per_worker_stay_bitwise() {
        let g = random_graph(565, 150, 900, 4);
        let sp = spill(&g, "slots", 8);
        let s1 = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig {
            slots_per_worker: 3,
            ..DispatchConfig::new(vec![s1.addr().to_string()])
        };
        let opts = crate::gee::GeeOptions::new(true, false, true);
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        s1.stop();
    }
}
