//! Placement and dispatch for the TCP shard fleet — which daemon embeds
//! which shard, and what happens when one dies.
//!
//! The model is a work queue over a pool of **slots**: each configured
//! endpoint contributes `slots_per_worker` independent connections, and
//! every slot pulls the next pending shard the moment it finishes the
//! previous one (rolling — no waves, no head-of-line blocking; the same
//! scheduling fix [`super::process`] got for local children). Failure
//! semantics mirror the multi-process reaper:
//!
//! * a slot that fails (connect refused, connection dropped mid-stream,
//!   `ERR` reply) pushes its shard back onto the queue and retires — the
//!   failed endpoint is excluded from all further placement, exactly like
//!   a reaped dead child;
//! * surviving slots drain the requeued shards, so a daemon killed
//!   mid-run costs only the retries of its in-flight shard;
//! * the driver returns an error only when the *whole* fleet is dead with
//!   shards still pending, and the error names every endpoint failure.
//!
//! Because each shard's rows are recomputed from the same spill bytes by
//! whichever daemon ends up serving it, retries cannot change the result:
//! output stays bitwise-identical to `SparseGee::fast()` through any
//! sequence of worker deaths that leaves one worker alive.
//!
//! Each slot connection opens with a `PING` health probe (a dead worker
//! is condemned before any shard payload is streamed at it) and then
//! negotiates the wire version: v2 slots stream binary spill bytes
//! ([`super::codec`]) and ship the job's global vectors **once per
//! connection** under a content hash — O(W·n + E) fleet traffic instead
//! of O(S·n + E) — while legacy daemons are served the v1 text protocol
//! unchanged. Mixed fleets are fine: the version is per connection, and
//! both wires produce bit-identical rows.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::codec::{globals_hash, ByteCounters, CountingReader, CountingWriter};
use super::remote::{request_shard, request_shard_v2, send_globals};
use super::spill::SpilledShards;
use crate::gee::options::GeeOptions;
use crate::sparse::Dense;

/// Fleet shape.
#[derive(Clone, Debug)]
pub struct DispatchConfig {
    /// Worker daemon endpoints (`host:port`). An endpoint may be listed
    /// more than once to weight placement toward a bigger machine.
    pub endpoints: Vec<String>,
    /// Concurrent in-flight shards per endpoint (each slot holds its own
    /// connection; a daemon embeds its slots on parallel threads).
    pub slots_per_worker: usize,
    /// TCP connect timeout per endpoint.
    pub connect_timeout: Duration,
    /// Per-syscall read/write timeout on worker connections. A *hung*
    /// worker (silent network partition — no RST, so reads block
    /// forever) would otherwise stall the whole dispatch with its
    /// in-flight shard never requeued; with the timeout the slot fails
    /// like a dead one and survivors take over. The clock only runs
    /// while a single read/write makes no progress, not across a whole
    /// shard, so the default is safe for long embeds; `None` disables.
    pub io_timeout: Option<Duration>,
    /// Skip the `HELLO2` upgrade and speak the v1 text protocol even to
    /// daemons that could do better — the ops escape hatch (and what the
    /// bench uses to put the text lane's byte count on the record next
    /// to the binary lane's).
    pub force_text: bool,
    /// When set, every slot connection counts its wire bytes here
    /// (`benches/shard_scale.rs` records them; the coordinator feeds
    /// them into `Metrics::remote_bytes`).
    pub counters: Option<Arc<ByteCounters>>,
}

impl DispatchConfig {
    pub fn new(endpoints: Vec<String>) -> DispatchConfig {
        DispatchConfig {
            endpoints,
            slots_per_worker: 1,
            connect_timeout: Duration::from_secs(5),
            io_timeout: Some(Duration::from_secs(600)),
            force_text: false,
            counters: None,
        }
    }
}

/// Shared scheduler state. Invariant: `total == done + pending.len() +
/// in_flight` — which is what makes the wait condition below sound: a
/// slot waiting on an empty queue is always woken by either a completion
/// (possibly the last) or a requeue.
struct FleetState {
    pending: VecDeque<usize>,
    in_flight: usize,
    done: usize,
    total: usize,
    /// Endpoint indices excluded from further placement. One slot's
    /// failure condemns the whole endpoint: its sibling slots retire at
    /// their next queue visit instead of feeding more shards to a node
    /// already known bad.
    dead: std::collections::HashSet<usize>,
    failures: Vec<String>,
}

/// Embed a spilled graph over the fleet. Bitwise-identical to the
/// in-process lanes for any endpoint count, slot count, and placement
/// order (rows are disjoint; each is produced by the shared shard
/// kernel from the same spill bytes).
pub fn embed_remote(
    sp: &SpilledShards,
    opts: &GeeOptions,
    cfg: &DispatchConfig,
) -> Result<Dense> {
    if cfg.endpoints.is_empty() {
        bail!("remote dispatch needs at least one worker endpoint");
    }
    let plan = &sp.plan;
    let total = plan.shards();
    let slots = cfg.slots_per_worker.max(1);
    let state = Mutex::new(FleetState {
        pending: (0..total).collect(),
        in_flight: 0,
        done: 0,
        total,
        dead: std::collections::HashSet::new(),
        failures: Vec::new(),
    });
    let cond = Condvar::new();
    let mut z = Dense::zeros(plan.n, plan.k);
    // one fingerprint per job: v2 slots ship the global vectors once per
    // connection under this hash and reference them per shard
    let ghash = globals_hash(&sp.labels, &plan.deg);

    let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<f64>)>();
    std::thread::scope(|sc| {
        for (ep_idx, ep) in cfg.endpoints.iter().enumerate() {
            for _ in 0..slots {
                let tx = tx.clone();
                let (state, cond) = (&state, &cond);
                sc.spawn(move || {
                    slot_loop(ep, ep_idx, sp, opts, cfg, ghash, state, cond, tx)
                });
            }
        }
        drop(tx);
        // the collector is this thread: place rows as slots finish; the
        // channel closes when every slot has retired or the work is done
        while let Ok((s, rows)) = rx.recv() {
            let (v0, v1) = plan.shard_range(s);
            z.data[v0 * plan.k..v1 * plan.k].copy_from_slice(&rows);
        }
    });

    let st = state.into_inner().unwrap();
    if st.done != total {
        bail!(
            "remote fleet incomplete: {}/{} shards embedded, all endpoints dead: {}",
            st.done,
            total,
            st.failures.join("; ")
        );
    }
    Ok(z)
}

/// One negotiated slot connection. `v2` is decided once per connection
/// (the `HELLO2` exchange); `globals_sent` tracks whether this
/// connection has shipped the job's global vectors yet — the per-
/// (connection, job) cache key is the content hash computed in
/// [`embed_remote`].
struct SlotConn {
    reader: BufReader<CountingReader<TcpStream>>,
    writer: BufWriter<CountingWriter<TcpStream>>,
    /// Frame-chunk scratch reused across every shard this slot serves
    /// (bounded by `codec::FRAME_CHUNK_BYTES`) — the driver-side twin of
    /// the daemon's `ConnState::chunk`.
    scratch: Vec<u8>,
    v2: bool,
    globals_sent: bool,
}

impl SlotConn {
    fn new(
        reader: BufReader<CountingReader<TcpStream>>,
        writer: BufWriter<CountingWriter<TcpStream>>,
        v2: bool,
    ) -> SlotConn {
        SlotConn { reader, writer, scratch: Vec::new(), v2, globals_sent: false }
    }

    /// Run one shard through whichever wire the connection negotiated.
    fn request(
        &mut self,
        sp: &SpilledShards,
        opts: &GeeOptions,
        s: usize,
        ghash: u64,
    ) -> Result<Vec<f64>> {
        if self.v2 {
            if !self.globals_sent {
                send_globals(&mut self.reader, &mut self.writer, sp, ghash)
                    .context("send GLOBALS")?;
                self.globals_sent = true;
            }
            request_shard_v2(
                &mut self.reader,
                &mut self.writer,
                sp,
                opts,
                s,
                ghash,
                &mut self.scratch,
            )
        } else {
            request_shard(&mut self.reader, &mut self.writer, sp, opts, s)
        }
    }
}

/// One slot: connect + probe + negotiate, then pull shards until the
/// work is done or this endpoint fails. A failure (on this slot *or* a
/// sibling slot of the same endpoint) requeues the in-flight shard for
/// survivors, marks the endpoint dead, and retires the slot — the
/// endpoint-exclusion rule.
#[allow(clippy::too_many_arguments)]
fn slot_loop(
    endpoint: &str,
    ep_idx: usize,
    sp: &SpilledShards,
    opts: &GeeOptions,
    cfg: &DispatchConfig,
    ghash: u64,
    state: &Mutex<FleetState>,
    cond: &Condvar,
    tx: Sender<(usize, Vec<f64>)>,
) {
    let mut conn = match connect(endpoint, cfg) {
        Ok(c) => c,
        Err(e) => {
            let mut g = state.lock().unwrap();
            g.dead.insert(ep_idx);
            g.failures.push(format!("{endpoint}: {e:#}"));
            // no shard was held, so nothing to requeue; wake any waiter
            // in case this was the last live slot
            cond.notify_all();
            return;
        }
    };
    loop {
        let s = {
            let mut g = state.lock().unwrap();
            while g.pending.is_empty()
                && g.done < g.total
                && !g.dead.contains(&ep_idx)
            {
                g = cond.wait(g).unwrap();
            }
            if g.dead.contains(&ep_idx) {
                // a sibling slot condemned this endpoint: retire without
                // taking work (our connection is to the same bad node)
                return;
            }
            if g.done >= g.total {
                break;
            }
            let s = g.pending.pop_front().unwrap();
            g.in_flight += 1;
            s
        };
        match conn.request(sp, opts, s, ghash) {
            Ok(rows) => {
                // send before decrementing in_flight: the collector must
                // never observe "all done" with a row block still in a
                // slot's hands
                let _ = tx.send((s, rows));
                let mut g = state.lock().unwrap();
                g.in_flight -= 1;
                g.done += 1;
                cond.notify_all();
            }
            Err(e) => {
                let mut g = state.lock().unwrap();
                g.in_flight -= 1;
                g.pending.push_back(s);
                g.dead.insert(ep_idx);
                g.failures.push(format!("{endpoint}: shard {s}: {e:#}"));
                cond.notify_all();
                return;
            }
        }
    }
    let _ = writeln!(conn.writer, "QUIT");
    let _ = conn.writer.flush();
}

/// Raw TCP connect with timeouts; byte-counted reader/writer over one
/// shared stream.
fn tcp_connect(
    endpoint: &str,
    cfg: &DispatchConfig,
) -> Result<(BufReader<CountingReader<TcpStream>>, BufWriter<CountingWriter<TcpStream>>)> {
    let addr = endpoint
        .to_socket_addrs()
        .with_context(|| format!("resolve {endpoint}"))?
        .next()
        .with_context(|| format!("{endpoint} resolved to no address"))?;
    let stream = TcpStream::connect_timeout(&addr, cfg.connect_timeout)
        .with_context(|| format!("connect {endpoint}"))?;
    stream.set_read_timeout(cfg.io_timeout)?;
    stream.set_write_timeout(cfg.io_timeout)?;
    stream.set_nodelay(true).ok();
    let counters = cfg
        .counters
        .clone()
        .unwrap_or_else(|| Arc::new(ByteCounters::default()));
    let reader = BufReader::new(CountingReader::new(stream.try_clone()?, counters.clone()));
    Ok((reader, BufWriter::new(CountingWriter::new(stream, counters))))
}

fn read_reply_line(
    reader: &mut impl BufRead,
    line: &mut String,
) -> std::io::Result<Option<String>> {
    line.clear();
    if reader.read_line(line)? == 0 {
        return Ok(None); // peer closed
    }
    Ok(Some(line.trim().to_string()))
}

/// Consume one reply line that must be the `PONG` health-probe answer.
fn expect_pong(reader: &mut impl BufRead, line: &mut String, what: &str) -> Result<()> {
    match read_reply_line(reader, line).with_context(|| format!("{what}: read reply"))? {
        Some(t) if t == "PONG" => Ok(()),
        other => bail!("{what}: expected PONG, got {other:?}"),
    }
}

/// Connect, health-probe, and negotiate the wire version.
///
/// The slot always opens with a cheap `PING` — so a long-dead worker is
/// condemned right here, before a multi-MB shard payload is streamed at
/// it (the first evidence of death used to be a failed bulk write).
/// Unless `force_text`, a `HELLO2` is pipelined behind the `PING`: a v2
/// daemon answers `PONG` + `HELLO2`; a legacy daemon answers `PONG`,
/// then `ERR` for the unknown verb and closes — in which case the slot
/// reconnects (the endpoint is known alive from the `PONG`) and speaks
/// v1 text. One extra round trip per connection, only against legacy
/// daemons.
fn connect(endpoint: &str, cfg: &DispatchConfig) -> Result<SlotConn> {
    let (mut reader, mut writer) = tcp_connect(endpoint, cfg)?;
    let mut line = String::new();
    if cfg.force_text {
        writeln!(writer, "PING")?;
        writer.flush()?;
        expect_pong(&mut reader, &mut line, "health probe")?;
        return Ok(SlotConn::new(reader, writer, false));
    }
    writeln!(writer, "PING\nHELLO2")?;
    writer.flush()?;
    expect_pong(&mut reader, &mut line, "health probe")?;
    match read_reply_line(&mut reader, &mut line) {
        Ok(Some(t)) if t == "HELLO2" => {
            return Ok(SlotConn::new(reader, writer, true));
        }
        // an ERR line, a clean close, or a teardown-class error while the
        // legacy daemon drops the connection — "no v2 here", fall back
        Ok(_) => {}
        Err(e) if matches!(
            e.kind(),
            std::io::ErrorKind::ConnectionReset
                | std::io::ErrorKind::ConnectionAborted
                | std::io::ErrorKind::BrokenPipe
                | std::io::ErrorKind::UnexpectedEof
        ) => {}
        // a timeout or transient read fault on a PONG-answering daemon is
        // a sick endpoint, not a legacy one: fail the slot instead of
        // silently downgrading a healthy v2 fleet to the text wire
        Err(e) => {
            return Err(anyhow::Error::new(e)
                .context("reading HELLO2 reply (endpoint answered PONG, then wedged)"));
        }
    }
    let (mut reader, mut writer) = tcp_connect(endpoint, cfg)?;
    writeln!(writer, "PING")?;
    writer.flush()?;
    expect_pong(&mut reader, &mut line, "health probe (text fallback)")?;
    Ok(SlotConn::new(reader, writer, false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::graph::Graph;
    use crate::shard::remote::ShardServer;
    use crate::shard::spill::{spill_from_graph, SpillConfig};
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = if rng.f64() < 0.1 { -1 } else { rng.below(k) as i32 };
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g.add_edge(3, 3, 2.0);
        g
    }

    fn spill(g: &Graph, tag: &str, shards: usize) -> SpilledShards {
        let dir = std::env::temp_dir()
            .join(format!("gee_dispatch_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        spill_from_graph(g, &SpillConfig { shards, ..SpillConfig::new(&dir) })
            .unwrap()
    }

    #[test]
    fn fleet_of_in_process_daemons_is_bitwise() {
        let g = random_graph(561, 120, 700, 4);
        let sp = spill(&g, "fleet", 5);
        let s1 = ShardServer::start("127.0.0.1:0").unwrap();
        let s2 = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig::new(vec![
            s1.addr().to_string(),
            s2.addr().to_string(),
        ]);
        for opts in crate::gee::GeeOptions::table_order() {
            let expect = SparseGee::fast().embed(&g, &opts);
            let z = embed_remote(&sp, &opts, &cfg).unwrap();
            assert_eq!(z.data, expect.data, "remote fleet drifted at {opts:?}");
        }
        s1.stop();
        s2.stop();
    }

    #[test]
    fn dead_endpoint_is_excluded_and_survivor_finishes() {
        let g = random_graph(562, 90, 500, 3);
        let sp = spill(&g, "dead", 6);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        // 127.0.0.1:1 — reserved port, nothing listens: connect fails,
        // every shard lands on the survivor
        let cfg = DispatchConfig {
            connect_timeout: Duration::from_millis(500),
            ..DispatchConfig::new(vec![
                "127.0.0.1:1".to_string(),
                live.addr().to_string(),
            ])
        };
        let opts = crate::gee::GeeOptions::ALL;
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        live.stop();
    }

    #[test]
    fn err_replying_endpoint_is_condemned_with_all_its_slots() {
        // a server that accepts connections but answers every line with
        // ERR: the first slot to hit it condemns the endpoint, sibling
        // slots retire instead of feeding it more shards, and the real
        // daemon finishes everything — bitwise
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let bad_addr = listener.local_addr().unwrap().to_string();
        let bad_server = std::thread::spawn(move || {
            use std::io::{BufRead, BufReader, Write};
            // serve a handful of connections, then quit
            for stream in listener.incoming().take(4) {
                let Ok(stream) = stream else { break };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut line = String::new();
                if reader.read_line(&mut line).is_ok() {
                    let mut w = stream;
                    let _ = writeln!(w, "ERR boom");
                    let _ = w.flush();
                }
            }
        });
        let g = random_graph(566, 100, 600, 3);
        let sp = spill(&g, "errnode", 6);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig {
            slots_per_worker: 3,
            ..DispatchConfig::new(vec![bad_addr, live.addr().to_string()])
        };
        let opts = crate::gee::GeeOptions::ALL;
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        live.stop();
        drop(bad_server); // detach; it exits after its accept budget
    }

    #[test]
    fn mixed_fleet_v2_and_legacy_text_daemon_is_bitwise() {
        // one binary-capable daemon + one legacy text-only daemon: the
        // driver negotiates per connection (HELLO2 vs reconnect-as-text)
        // and both serve shards of the same job — rows must still be
        // bitwise-identical to the fused engine
        let g = random_graph(567, 130, 800, 4);
        let sp = spill(&g, "mixed", 6);
        let v2 = ShardServer::start("127.0.0.1:0").unwrap();
        let legacy = ShardServer::start_text_only("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig::new(vec![
            v2.addr().to_string(),
            legacy.addr().to_string(),
        ]);
        for opts in crate::gee::GeeOptions::table_order() {
            let expect = SparseGee::fast().embed(&g, &opts);
            let z = embed_remote(&sp, &opts, &cfg).unwrap();
            assert_eq!(z.data, expect.data, "mixed fleet drifted at {opts:?}");
        }
        v2.stop();
        legacy.stop();
    }

    #[test]
    fn forced_text_wire_is_bitwise_and_moves_more_bytes() {
        let g = random_graph(568, 110, 650, 3);
        let sp = spill(&g, "forcetext", 5);
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let opts = crate::gee::GeeOptions::ALL;
        let expect = SparseGee::fast().embed(&g, &opts);
        let mut totals = Vec::new();
        for force_text in [false, true] {
            let counters = Arc::new(super::ByteCounters::default());
            let cfg = DispatchConfig {
                force_text,
                counters: Some(counters.clone()),
                ..DispatchConfig::new(vec![server.addr().to_string()])
            };
            let z = embed_remote(&sp, &opts, &cfg).unwrap();
            assert_eq!(z.data, expect.data, "force_text={force_text} drifted");
            assert!(counters.total() > 0, "counters must observe traffic");
            totals.push(counters.total());
        }
        assert!(
            totals[0] < totals[1],
            "binary wire ({}) must move strictly fewer bytes than text ({})",
            totals[0],
            totals[1]
        );
        server.stop();
    }

    #[test]
    fn globals_ship_once_per_connection_not_per_shard() {
        // the GLOBALS-cache contract, measured: the same job over 1
        // connection with many shards must send far less than shards x
        // globals — the per-shard cost is the edge payload + a header,
        // not O(n)
        let g = random_graph(569, 400, 1_500, 3);
        let shards = 8;
        let sp = spill(&g, "amortize", shards);
        let server = ShardServer::start("127.0.0.1:0").unwrap();
        let counters = Arc::new(super::ByteCounters::default());
        let cfg = DispatchConfig {
            counters: Some(counters.clone()),
            ..DispatchConfig::new(vec![server.addr().to_string()])
        };
        let opts = crate::gee::GeeOptions::ALL;
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, SparseGee::fast().embed(&g, &opts).data);
        let globals_bytes = (g.n * (4 + 8)) as u64; // labels + degrees
        let spill_bytes: u64 = sp
            .files
            .iter()
            .map(|f| std::fs::metadata(f).unwrap().len())
            .sum();
        // one connection: globals once (+frames/headers/Z slack), never
        // once per shard
        let sent = counters.sent.load(std::sync::atomic::Ordering::Relaxed);
        assert!(
            sent < spill_bytes + 2 * globals_bytes + 1024 * shards as u64,
            "sent {sent} bytes — globals must not be resent per shard \
             (spill={spill_bytes}, globals={globals_bytes}, shards={shards})"
        );
        server.stop();
    }

    #[test]
    fn garbage_probe_reply_condemns_endpoint_before_any_shard_is_streamed() {
        // an endpoint that accepts but answers the PING probe with
        // garbage: the slot must condemn it at bind time — before a
        // multi-MB shard payload is streamed at it — and the survivor
        // must finish everything
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let bad_addr = listener.local_addr().unwrap().to_string();
        let received_payload = std::sync::Arc::new(
            std::sync::atomic::AtomicBool::new(false),
        );
        let received_clone = received_payload.clone();
        let bad_server = std::thread::spawn(move || {
            use std::io::{BufRead, BufReader, Write};
            for stream in listener.incoming().take(2) {
                let Ok(stream) = stream else { break };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut line = String::new();
                if reader.read_line(&mut line).is_ok() {
                    let mut w = stream;
                    let _ = writeln!(w, "WAT");
                    let _ = w.flush();
                    // if the driver streams anything beyond its probe
                    // verbs at us, the probe failed to protect it
                    let mut rest = String::new();
                    while reader.read_line(&mut rest).map(|n| n > 0).unwrap_or(false) {
                        let t = rest.trim();
                        if !t.is_empty() && t != "HELLO2" && t != "PING" && t != "QUIT" {
                            received_clone
                                .store(true, std::sync::atomic::Ordering::Relaxed);
                        }
                        rest.clear();
                    }
                }
            }
        });
        let g = random_graph(570, 80, 400, 3);
        let sp = spill(&g, "probe", 4);
        let live = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg =
            DispatchConfig::new(vec![bad_addr, live.addr().to_string()]);
        let opts = crate::gee::GeeOptions::NONE;
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        assert!(
            !received_payload.load(std::sync::atomic::Ordering::Relaxed),
            "a shard payload reached an endpoint that failed its health probe"
        );
        live.stop();
        drop(bad_server);
    }

    #[test]
    fn whole_fleet_dead_reports_every_endpoint() {
        let g = random_graph(563, 30, 90, 2);
        let sp = spill(&g, "allgone", 2);
        let cfg = DispatchConfig {
            connect_timeout: Duration::from_millis(300),
            ..DispatchConfig::new(vec![
                "127.0.0.1:1".to_string(),
                "127.0.0.1:2".to_string(),
            ])
        };
        let err = embed_remote(&sp, &crate::gee::GeeOptions::NONE, &cfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("0/2 shards"), "{msg}");
        assert!(msg.contains("127.0.0.1:1") && msg.contains("127.0.0.1:2"), "{msg}");
    }

    #[test]
    fn no_endpoints_is_an_error() {
        let g = random_graph(564, 10, 20, 2);
        let sp = spill(&g, "none", 2);
        assert!(embed_remote(
            &sp,
            &crate::gee::GeeOptions::NONE,
            &DispatchConfig::new(Vec::new())
        )
        .is_err());
    }

    #[test]
    fn multiple_slots_per_worker_stay_bitwise() {
        let g = random_graph(565, 150, 900, 4);
        let sp = spill(&g, "slots", 8);
        let s1 = ShardServer::start("127.0.0.1:0").unwrap();
        let cfg = DispatchConfig {
            slots_per_worker: 3,
            ..DispatchConfig::new(vec![s1.addr().to_string()])
        };
        let opts = crate::gee::GeeOptions::new(true, false, true);
        let expect = SparseGee::fast().embed(&g, &opts);
        let z = embed_remote(&sp, &opts, &cfg).unwrap();
        assert_eq!(z.data, expect.data);
        s1.stop();
    }
}
