//! `gee` — command-line front end for the sparse-GEE stack.
//!
//! Subcommands:
//! * `info`         — Table 2 twins + artifact manifest summary
//! * `generate`     — write a dataset twin / SBM graph to .edges/.labels
//! * `embed`        — embed a graph with any engine (native or PJRT)
//! * `shard-embed`  — out-of-core sharded embed straight from files,
//!                    optionally across worker processes
//! * `shard-worker` — one shard's worker process (spawned by
//!                    `shard-embed --workers P`; not for direct use)
//! * `bench-table`  — regenerate a paper table/figure (2, 3, 4, fig3)
//! * `serve`        — run the embedding service demo under synthetic load
//! * `client-embed` — embed a graph against a running `serve --listen`
//!                    daemon (binary v2 wire, `--text-wire` for v1)
//! * `client-stream` — open a resident session on a `serve --listen
//!                    --sessions` daemon, stream a held-back edge suffix
//!                    as `DELTA2` batches, and drain to a full read
//! * `cluster-embed` — unsupervised One-Hot GEE: embed → k-means →
//!                    relabel until labels stabilize, locally, against a
//!                    `serve` daemon (`ITER2`), or across a shard fleet
//!
//! Arg parsing is hand-rolled (`--key value` / `--key=value` /
//! `--flag`) because the offline crate set has no clap; see `Args`
//! below.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use gee_sparse::coordinator::batcher::BatchCapacity;
use gee_sparse::coordinator::{
    ClientConfig, Delta, EmbedClient, EmbedRequest, EmbedService, Lane, ServiceConfig,
};
use gee_sparse::gee::iterate;
use gee_sparse::gee::{Engine, GeeOptions};
use gee_sparse::graph::datasets::by_name;
use gee_sparse::graph::sbm::{generate_sbm, SbmParams};
use gee_sparse::graph::{io, Graph};
use gee_sparse::harness;
use gee_sparse::runtime::{Manifest, Runtime};
use gee_sparse::shard::{
    embed_multiprocess, embed_out_of_core, embed_remote, run_worker,
    spill::{spill_from_files, spill_from_graph},
    DaemonConfig, DispatchConfig, FleetSession, ProcessConfig, ShardServer, SpillConfig,
    WorkerArgs,
};
use gee_sparse::tasks::kmeans::{kmeans, KMeansConfig};
use gee_sparse::tasks::metrics::{adjusted_rand_index, paired_labels};
use gee_sparse::util::fault::FaultPlan;
use gee_sparse::util::retry::Deadlines;
use gee_sparse::util::rng::Rng;

/// Flags that take no value. Declaring them is what lets every *other*
/// `--key` consume its next token as a value unconditionally — including
/// values that begin with `-` or `--` (an options code like `--c`, a
/// negative number, a file named `-`). The old parser guessed by
/// sniffing the next token for a `--` prefix, which silently swallowed
/// such values as flags and forced workarounds like spelling booleans
/// `--lap 1`.
const BOOL_FLAGS: &[&str] = &[
    "pjrt",
    "cluster",
    "quick",
    "keep-spill",
    "no-batching",
    // shard-worker engine options (presence = on; `--lap 1` / `--lap 0`
    // still parse for back-compat with older drivers)
    "lap",
    "diag",
    "cor",
    // wire-protocol overrides: serve only the v1 text protocol
    // (shard-serve — emulates a legacy daemon), or force the v1 text
    // wire as a client (shard-embed / serve) instead of negotiating the
    // binary protocol
    "text-only",
    "text-wire",
];

/// Minimal `--key value` / `--key=value` / `--flag` parser.
struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((key, val)) = key.split_once('=') {
                    // --key=value always binds, boolean or not
                    values.insert(key.to_string(), val.to_string());
                    i += 1;
                } else if BOOL_FLAGS.contains(&key) {
                    // back-compat: the old 0/1 value form still parses
                    match argv.get(i + 1).map(|s| s.as_str()) {
                        Some(v @ ("0" | "1" | "true" | "false")) => {
                            values.insert(key.to_string(), v.to_string());
                            i += 2;
                        }
                        _ => {
                            flags.push(key.to_string());
                            i += 1;
                        }
                    }
                } else {
                    let val = argv
                        .get(i + 1)
                        .with_context(|| format!("--{key} requires a value"))?;
                    values.insert(key.to_string(), val.clone());
                    i += 2;
                }
            } else {
                flags.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { values, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
            || matches!(self.get(flag), Some("1") | Some("true"))
    }

    /// A millisecond timeout knob: `0` disables the budget entirely,
    /// absent keeps the built-in default.
    fn get_timeout_ms(&self, key: &str, default: Option<Duration>) -> Result<Option<Duration>> {
        match self.get(key) {
            Some("0") => Ok(None),
            Some(v) => {
                let ms: u64 = v
                    .parse()
                    .with_context(|| format!("--{key} takes milliseconds (0 disables)"))?;
                Ok(Some(Duration::from_millis(ms)))
            }
            None => Ok(default),
        }
    }
}

fn default_artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Resolve the graph a command operates on.
fn load_graph(args: &Args) -> Result<Graph> {
    if let Some(name) = args.get("dataset") {
        let spec = by_name(name)
            .with_context(|| format!("unknown dataset '{name}' (see `gee info`)"))?;
        eprintln!("generating twin '{}' ({} nodes)...", spec.name, spec.nodes);
        return Ok(spec.generate());
    }
    if let Some(n) = args.get("sbm") {
        let n: usize = n.parse().context("--sbm takes a node count")?;
        let seed = args.get_usize("seed", 7)? as u64;
        return Ok(generate_sbm(&SbmParams::paper(n), seed));
    }
    if let Some(stem) = args.get("input") {
        return io::read_graph(Path::new(stem));
    }
    bail!("specify a graph: --dataset NAME | --sbm N | --input STEM")
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("{}", harness::format_table2());
    let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(default_artifacts);
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} variants in {}", m.variants.len(), dir.display());
            for b in m.buckets() {
                let v = m.variants.iter().find(|v| v.bucket == b).unwrap();
                println!(
                    "  bucket {b}: n={} e={} k={} (block_n={} tile_e={} vmem={}K)",
                    v.n,
                    v.e,
                    v.k,
                    v.block_n,
                    v.tile_e,
                    v.vmem_bytes / 1024
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#})"),
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let out = args.get("out").context("--out STEM required")?;
    io::write_graph(Path::new(out), &g)?;
    println!(
        "wrote {}.edges / {}.labels  (n={}, edges={}, k={}, density={:.5})",
        out,
        out,
        g.n,
        g.num_edges(),
        g.k,
        g.density()
    );
    Ok(())
}

fn cmd_embed(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let opts = GeeOptions::from_code(args.get("options").unwrap_or("---"))
        .context("--options takes a 3-char code like ldc, l-c, ---")?;
    let t0 = Instant::now();
    let z = if args.has("pjrt") {
        let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(default_artifacts);
        let rt = Runtime::new(&dir)?;
        println!("pjrt platform: {}", rt.platform());
        rt.embed(&g, &opts)?
    } else {
        let engine = Engine::from_name(args.get("engine").unwrap_or("sparse"))
            .context(
                "--engine must be dense|edgelist|edgelist-par[:T]|sparse|sparse-fast|sparse-par[:T]|sharded[:S]|cluster[:R]",
            )?;
        engine.embed(&g, &opts)?
    };
    let dt = t0.elapsed();
    println!(
        "embedded n={} edges={} k={} with {} in {:.3}s ({:.0} edges/s)",
        g.n,
        g.num_edges(),
        g.k,
        opts.label(),
        dt.as_secs_f64(),
        harness::edges_per_sec(g.num_edges(), dt)
    );
    if args.has("cluster") {
        let res = kmeans(&z, &KMeansConfig::new(g.k));
        let pred: Vec<i32> = res.assignments.iter().map(|&c| c as i32).collect();
        let (a, b) = paired_labels(&pred, &g.labels);
        println!("k-means ARI vs labels: {:.4}", adjusted_rand_index(&a, &b));
    }
    if let Some(out) = args.get("out") {
        write_embedding(out, &z)?;
    }
    Ok(())
}

fn cmd_bench_table(args: &Args) -> Result<()> {
    let which = args.get("table").unwrap_or("fig3");
    let reps = args.get_usize("reps", 3)?;
    match which {
        "2" => println!("{}", harness::format_table2()),
        "3" | "4" => {
            let lap = which == "3";
            let max_edges = args.get_usize(
                "max-edges",
                if args.has("quick") { 500_000 } else { usize::MAX },
            )?;
            let rows = harness::run_table(lap, reps, max_edges);
            println!("{}", harness::format_table(&rows, if lap { 3 } else { 4 }));
        }
        "fig3" => {
            let sizes: Vec<usize> = match args.get("sizes") {
                Some(s) => s
                    .split(',')
                    .map(|x| x.parse().context("bad --sizes"))
                    .collect::<Result<_>>()?,
                None if args.has("quick") => vec![100, 1_000, 3_000],
                None => harness::FIG3_SIZES.to_vec(),
            };
            let points = harness::run_fig3(&sizes, reps, 7);
            println!("{}", harness::format_fig3(&points));
        }
        other => bail!("unknown table '{other}' (use 2, 3, 4 or fig3)"),
    }
    Ok(())
}

/// Write an embedding as one TSV row per vertex (shared by `embed` and
/// `shard-embed`).
fn write_embedding(path: &str, z: &gee_sparse::sparse::Dense) -> Result<()> {
    let mut text = String::new();
    for r in 0..z.nrows {
        let row: Vec<String> = z.row(r).iter().map(|v| format!("{v:.6}")).collect();
        text.push_str(&row.join("\t"));
        text.push('\n');
    }
    std::fs::write(path, text)?;
    println!("embedding written to {path}");
    Ok(())
}

fn cmd_shard_embed(args: &Args) -> Result<()> {
    let (edges, labels) = if let Some(stem) = args.get("input") {
        let stem = Path::new(stem);
        (stem.with_extension("edges"), stem.with_extension("labels"))
    } else {
        let e = args.get("edges").context(
            "specify a graph: --input STEM | --edges FILE --labels FILE",
        )?;
        let l = args.get("labels").context("--labels FILE required with --edges")?;
        (PathBuf::from(e), PathBuf::from(l))
    };
    let opts = GeeOptions::from_code(args.get("options").unwrap_or("---"))
        .context("--options takes a 3-char code like ldc, l-c, ---")?;
    let spill_dir = args
        .get("spill-dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("gee_shard_{}", std::process::id()))
        });
    let cfg = SpillConfig {
        shards: args.get_usize("shards", 0)?,
        mem_budget_edges: args.get_usize("mem-budget-edges", 0)?,
        dir: spill_dir,
        keep: args.has("keep-spill"),
    };
    // --workers N        -> N local worker processes
    // --workers h:p,h:p  -> remote fleet of `gee shard-serve` daemons
    enum Workers {
        Local(usize),
        Remote(Vec<String>),
    }
    let workers = match args.get("workers") {
        None => Workers::Local(1),
        Some(v) if v.contains(':') => {
            Workers::Remote(v.split(',').map(|s| s.trim().to_string()).collect())
        }
        Some(v) => Workers::Local(
            v.parse().context("--workers takes a count or host:port,...")?,
        ),
    };

    let t0 = Instant::now();
    let sp = spill_from_files(&edges, &labels, &cfg)?;
    let spill_dt = t0.elapsed();
    println!(
        "spilled n={} directed={} k={} into {} shards under {} ({:.3}s)",
        sp.plan.n,
        sp.plan.directed,
        sp.plan.k,
        sp.plan.shards(),
        sp.dir.display(),
        spill_dt.as_secs_f64()
    );
    let t1 = Instant::now();
    let (z, lane) = match &workers {
        Workers::Remote(endpoints) => {
            let mut dcfg = DispatchConfig::new(endpoints.clone());
            dcfg.slots_per_worker = args.get_usize("slots", 1)?;
            dcfg.force_text = args.has("text-wire");
            (
                embed_remote(&sp, &opts, &dcfg)?,
                if dcfg.force_text { "remote fleet (text wire)" } else { "remote fleet" },
            )
        }
        Workers::Local(w) if *w > 1 => {
            let worker_bin = std::env::current_exe().context("locate own binary")?;
            (
                embed_multiprocess(
                    &sp,
                    &opts,
                    &ProcessConfig { workers: *w, worker_bin },
                )?,
                "multi-process",
            )
        }
        Workers::Local(_) => (embed_out_of_core(&sp, &opts)?, "out-of-core"),
    };
    let dt = t1.elapsed();
    println!(
        "sharded embed ({lane}) of {} directed edges with {} in {:.3}s ({:.0} edges/s)",
        sp.plan.directed,
        opts.label(),
        dt.as_secs_f64(),
        sp.plan.directed as f64 / dt.as_secs_f64().max(1e-9)
    );
    if let Some(out) = args.get("out") {
        write_embedding(out, &z)?;
    }
    Ok(())
}

fn cmd_shard_worker(args: &Args) -> Result<()> {
    let get_path = |key: &str| -> Result<PathBuf> {
        Ok(PathBuf::from(
            args.get(key).with_context(|| format!("--{key} required"))?,
        ))
    };
    let wargs = WorkerArgs {
        edges: get_path("edges")?,
        labels: get_path("labels")?,
        deg: get_path("deg")?,
        n: args.get_usize("n", 0)?,
        k: args.get_usize("k", 0)?,
        row0: args.get_usize("row0", 0)?,
        row1: args.get_usize("row1", 0)?,
        // real boolean flags; `has` also honors the legacy 0/1 form
        options: GeeOptions::new(args.has("lap"), args.has("diag"), args.has("cor")),
        out: get_path("out")?,
    };
    run_worker(&wargs)
}

fn cmd_shard_serve(args: &Args) -> Result<()> {
    let bind = args.get("listen").unwrap_or("127.0.0.1:0");
    let defaults = DaemonConfig::default();
    let fault = FaultPlan::from_env().map_err(|e| anyhow::anyhow!(e))?;
    if fault.is_some() {
        eprintln!("shard-serve: GEE_FAULT_PLAN armed — injecting deterministic wire faults");
    }
    // --text-only serves just the v1 text protocol — a stand-in for a
    // legacy daemon when testing mixed-fleet negotiation
    let cfg = DaemonConfig {
        text_only: args.has("text-only"),
        idle_timeout: args.get_timeout_ms("idle-timeout", defaults.idle_timeout)?,
        io_timeout: args.get_timeout_ms("io-timeout", defaults.io_timeout)?,
        keep_ttl: args.get_timeout_ms("keep-ttl", defaults.keep_ttl)?,
        fault,
    };
    let server = ShardServer::start_with_config(bind, cfg)?;
    // the bound address is the contract with launchers: with port 0 this
    // line is how they learn the ephemeral port, so flush it eagerly
    // (stdout is block-buffered under a pipe)
    println!("shard-serve listening on {}", server.addr());
    std::io::Write::flush(&mut std::io::stdout())?;
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_client_embed(args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr = args
        .get("addr")
        .context("--addr HOST:PORT required (a running `gee serve --listen` daemon)")?
        .parse()
        .context("--addr must be HOST:PORT")?;
    let g = load_graph(args)?;
    let code = args.get("options").unwrap_or("---");
    GeeOptions::from_code(code).context("--options takes a 3-char code like ldc, l-c, ---")?;
    let edges: Vec<(u32, u32, f64)> =
        (0..g.num_edges()).map(|i| (g.src[i], g.dst[i], g.w[i])).collect();
    let counters = std::sync::Arc::new(gee_sparse::shard::codec::ByteCounters::default());
    let cfg = ClientConfig {
        tenant: args.get("tenant").map(|s| s.to_string()),
        force_text: args.has("text-wire"),
        counters: Some(counters.clone()),
        ..ClientConfig::default()
    };
    let t0 = Instant::now();
    let mut client = EmbedClient::connect(addr, &cfg)?;
    let wire = if client.is_binary() { "binary v2" } else { "text v1" };
    let z = client.embed(code, &g.labels, &edges, g.k)?;
    let dt = t0.elapsed();
    use std::sync::atomic::Ordering;
    println!(
        "embedded n={} edges={} k={} over the {wire} wire in {:.3}s ({} B sent, {} B received)",
        g.n,
        g.num_edges(),
        g.k,
        dt.as_secs_f64(),
        counters.sent.load(Ordering::Relaxed),
        counters.received.load(Ordering::Relaxed),
    );
    if let Some(out) = args.get("out") {
        // full-precision rows: CI compares the v1 and v2 lanes' outputs
        // byte for byte, and rounding would hide wire bugs
        let mut text = String::new();
        for r in 0..z.nrows {
            let row: Vec<String> = z.row(r).iter().map(|v| format!("{v}")).collect();
            text.push_str(&row.join("\t"));
            text.push('\n');
        }
        std::fs::write(out, text)?;
        println!("embedding written to {out}");
    }
    Ok(())
}

/// Open a resident session with part of the graph held back, stream the
/// holdback as `DELTA2` insert batches (interleaved with watermark'd
/// `ROWS2` probes), drain, and dump the full embedding. Because the
/// session replays inserts in the original edge order, the output is
/// bitwise identical to `client-embed` of the whole graph — CI `cmp`s
/// the two files.
fn cmd_client_stream(args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr = args
        .get("addr")
        .context("--addr HOST:PORT required (a running `gee serve --listen --sessions N` daemon)")?
        .parse()
        .context("--addr must be HOST:PORT")?;
    let g = load_graph(args)?;
    let code = args.get("options").unwrap_or("---");
    GeeOptions::from_code(code).context("--options takes a 3-char code like ldc, l-c, ---")?;
    let holdback = args.get_usize("deltas", 1_000)?.min(g.num_edges());
    let batch = args.get_usize("batch", 256)?.max(1);
    let thresh: Option<f64> = match args.get("thresh") {
        Some(v) => Some(v.parse().context("--thresh must be a fraction in 0..=1")?),
        None => None,
    };
    let split = g.num_edges() - holdback;
    let base: Vec<(u32, u32, f64)> =
        (0..split).map(|i| (g.src[i], g.dst[i], g.w[i])).collect();

    let counters = std::sync::Arc::new(gee_sparse::shard::codec::ByteCounters::default());
    let cfg = ClientConfig {
        tenant: args.get("tenant").map(|s| s.to_string()),
        force_text: false,
        counters: Some(counters.clone()),
        ..ClientConfig::default()
    };
    let mut client = EmbedClient::connect(addr, &cfg)?;
    if !client.is_binary() {
        bail!("sessions require the v2 binary wire (is the server --text-only?)");
    }
    let t0 = Instant::now();
    let sess = client.open_session(code, &g.labels, &base, g.k, thresh)?;
    println!("session {sess}: n={} k={} opened with {} base edges", g.n, g.k, split);

    // stream the holdback, probing a few rows each batch to show the
    // bounded-staleness watermark moving
    let probe: Vec<u32> = (0..g.n.min(4) as u32).collect();
    let mut max_stale = 0u64;
    let mut i = split;
    while i < g.num_edges() {
        let hi = (i + batch).min(g.num_edges());
        let ds: Vec<Delta> = (i..hi)
            .map(|j| Delta::Insert { a: g.src[j], b: g.dst[j], w: g.w[j] })
            .collect();
        let (_, stale) = client.send_deltas(sess, &ds)?;
        max_stale = max_stale.max(stale);
        if !probe.is_empty() {
            let (_, applied, clean) = client.fetch_rows(sess, &probe)?;
            max_stale = max_stale.max(applied - clean);
        }
        i = hi;
    }
    let applied = client.wait_clean(sess, Duration::from_secs(120))?;
    let stream_dt = t0.elapsed();

    // drain done: fetch every row, chunked to keep replies bounded
    let mut text = String::new();
    let ids: Vec<u32> = (0..g.n as u32).collect();
    for chunk in ids.chunks(16_384) {
        let (z, _, clean) = client.fetch_rows(sess, chunk)?;
        anyhow::ensure!(clean == applied, "read raced a refresh after drain");
        for r in 0..z.nrows {
            // full precision: CI compares against client-embed byte for byte
            let row: Vec<String> = z.row(r).iter().map(|v| format!("{v}")).collect();
            text.push_str(&row.join("\t"));
            text.push('\n');
        }
    }
    client.close_session(sess)?;
    use std::sync::atomic::Ordering;
    println!(
        "streamed {holdback} deltas in {:.3}s ({:.0} deltas/s), max staleness {max_stale}, \
         applied watermark {applied} ({} B sent, {} B received)",
        stream_dt.as_secs_f64(),
        holdback as f64 / stream_dt.as_secs_f64().max(1e-9),
        counters.sent.load(Ordering::Relaxed),
        counters.received.load(Ordering::Relaxed),
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, text)?;
        println!("embedding written to {out}");
    }
    Ok(())
}

/// Self-clustering embed (One-Hot GEE, arXiv:2109.13098): start from
/// deterministic seed labels, alternate embed → k-means → relabel until
/// labels stabilize. Three lanes share one driver and stay bitwise
/// identical: local (default), `--addr` (one `ITER2` job against a
/// `serve --listen` daemon; a text-only server runs the loop
/// client-side), and `--workers` (shard fleet — the graph spills once,
/// rounds after the first re-ship only the label vector).
fn cmd_cluster_embed(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let code = args.get("options").unwrap_or("---");
    let opts = GeeOptions::from_code(code)
        .context("--options takes a 3-char code like ldc, l-c, ---")?;
    let k = match args.get("k") {
        None | Some("auto") => g.k,
        Some(v) => v.parse().context("--k takes a class count or 'auto'")?,
    };
    anyhow::ensure!(k >= 2, "--k must be at least 2 (got {k})");
    let rounds = args.get_usize("iters", 0)?;
    let tol: f64 = match args.get("tol") {
        Some(v) => v.parse().context("--tol must be a fraction in 0..=1")?,
        None => 0.0,
    };
    let init = iterate::init_labels(g.n, k, iterate::INIT_SEED);
    let on_round = |rs: &iterate::RoundState| {
        println!(
            "round {}: changed={} ari_vs_prev={:.4} inertia={:.3} kmeans_iters={}",
            rs.round, rs.changed, rs.ari_vs_prev, rs.inertia, rs.kmeans_iters
        );
    };

    let t0 = Instant::now();
    let (z, states, lane) = if let Some(addr) = args.get("addr") {
        let addr: std::net::SocketAddr = addr.parse().context("--addr must be HOST:PORT")?;
        let edges: Vec<(u32, u32, f64)> =
            (0..g.num_edges()).map(|i| (g.src[i], g.dst[i], g.w[i])).collect();
        let cfg = ClientConfig {
            tenant: args.get("tenant").map(|s| s.to_string()),
            force_text: args.has("text-wire"),
            counters: None,
            ..ClientConfig::default()
        };
        let mut client = EmbedClient::connect(addr, &cfg)?;
        let lane =
            if client.is_binary() { "ITER2 wire" } else { "text v1 (client-side loop)" };
        let (z, states) = client.cluster_embed(code, &init, &edges, k, rounds, tol)?;
        for rs in &states {
            on_round(rs);
        }
        (z, states, lane)
    } else {
        // both in-process lanes: rebuild the graph with the requested k
        // and the deterministic seed labels, then drive the shared loop
        let mut wg = Graph::new(g.n, k);
        wg.labels = init.clone();
        for i in 0..g.num_edges() {
            wg.add_edge(g.src[i], g.dst[i], g.w[i]);
        }
        let driver =
            iterate::IterativeJob { rounds, tol, ..iterate::IterativeJob::new(g.n, k) };
        if let Some(w) = args.get("workers") {
            let endpoints: Vec<String> =
                w.split(',').map(|s| s.trim().to_string()).collect();
            let spill_dir = args.get("spill-dir").map(PathBuf::from).unwrap_or_else(|| {
                std::env::temp_dir().join(format!("gee_cluster_{}", std::process::id()))
            });
            let sp = spill_from_graph(
                &wg,
                &SpillConfig {
                    shards: args.get_usize("shards", 0)?,
                    ..SpillConfig::new(spill_dir)
                },
            )?;
            let mut dcfg = DispatchConfig::new(endpoints);
            dcfg.slots_per_worker = args.get_usize("slots", 1)?;
            dcfg.force_text = args.has("text-wire");
            let mut session = FleetSession::connect(&sp, &opts, &dcfg)?;
            let out =
                driver.run(Some(init.clone()), |lab| session.embed_round(lab), on_round)?;
            session.close();
            (out.z, out.rounds, "shard fleet")
        } else {
            let out = driver.run(
                Some(init.clone()),
                |lab| {
                    wg.labels.copy_from_slice(lab);
                    Engine::SparseFast.embed(&wg, &opts)
                },
                on_round,
            )?;
            (out.z, out.rounds, "local")
        }
    };
    let dt = t0.elapsed();
    println!(
        "cluster-embed ({lane}): n={} edges={} k={k} {} rounds with {} in {:.3}s",
        g.n,
        g.num_edges(),
        states.len(),
        opts.label(),
        dt.as_secs_f64(),
    );
    if g.labels.iter().any(|&l| l >= 0) {
        // lane-independent quality report: k-means on the final Z vs the
        // graph's own labels (planted classes for SBM / dataset twins)
        let res = kmeans(&z, &KMeansConfig::new(k));
        let pred: Vec<i32> = res.assignments.iter().map(|&c| c as i32).collect();
        let (a, b) = paired_labels(&pred, &g.labels);
        println!("final k-means ARI vs labels: {:.4}", adjusted_rand_index(&a, &b));
    }
    if let Some(out) = args.get("out") {
        // full-precision rows: CI compares the lanes' outputs byte for
        // byte, and rounding would hide wire or fleet bugs
        let mut text = String::new();
        for r in 0..z.nrows {
            let row: Vec<String> = z.row(r).iter().map(|v| format!("{v}")).collect();
            text.push_str(&row.join("\t"));
            text.push('\n');
        }
        std::fs::write(out, text)?;
        println!("embedding written to {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.get_usize("requests", 200)?;
    let workers = args.get_usize("workers", 2)?;
    // remote shard fleet for oversize jobs (gee shard-serve daemons)
    let shard_remote_workers: Vec<String> = args
        .get("shard-workers")
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_default();
    // network mode: expose the service over TCP and block
    if let Some(bind) = args.get("listen") {
        let wire_defaults = Deadlines::default();
        let wire_deadlines = Deadlines {
            header: args.get_timeout_ms("header-timeout", wire_defaults.header)?,
            frame: args.get_timeout_ms("frame-timeout", wire_defaults.frame)?,
            ..wire_defaults
        };
        let svc = std::sync::Arc::new(EmbedService::start(ServiceConfig {
            workers,
            intra_op_threads: args.get_usize("intra-op", 0)?,
            shard_remote_workers,
            shard_wire_text: args.has("text-wire"),
            tenant_tokens: args.get_usize("tenant-tokens", 64)?,
            session_workers: args.get_usize("sessions", 0)?,
            session_quota: args.get_usize("session-quota", 4)?,
            wire_deadlines,
            ..ServiceConfig::default()
        }));
        let fault = FaultPlan::from_env().map_err(|e| anyhow::anyhow!(e))?;
        // --text-only refuses the HELLO2 upgrade — emulates a pre-v2
        // daemon for mixed-version testing
        let server = if args.has("text-only") {
            if fault.is_some() {
                eprintln!("serve: GEE_FAULT_PLAN is ignored with --text-only");
            }
            gee_sparse::coordinator::TcpServer::start_text_only(bind, svc)?
        } else {
            if fault.is_some() {
                eprintln!("serve: GEE_FAULT_PLAN armed — injecting deterministic wire faults");
            }
            gee_sparse::coordinator::TcpServer::start_with_fault(bind, svc, fault)?
        };
        println!(
            "listening on {} (v1 text + v2 binary wire; PING/EMBED/HELLO2; ctrl-c to stop)",
            server.addr()
        );
        std::io::Write::flush(&mut std::io::stdout())?;
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    let lane = if args.has("pjrt") {
        let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(default_artifacts);
        Lane::Pjrt { artifact_dir: dir, fallback: Engine::SparseFast }
    } else {
        Lane::Native(Engine::SparseFast)
    };
    let svc = EmbedService::start(ServiceConfig {
        lane,
        workers,
        batching: !args.has("no-batching"),
        batch_capacity: BatchCapacity::from_bucket(2_048, 16_384, 16),
        batch_linger: Duration::from_millis(2),
        queue_depth: 512,
        intra_op_threads: args.get_usize("intra-op", 0)?,
        shard_remote_workers,
        shard_wire_text: args.has("text-wire"),
        ..ServiceConfig::default()
    });

    let mut rng = Rng::new(args.get_usize("seed", 11)? as u64);
    let combos = GeeOptions::table_order();
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..requests {
        let n = 30 + rng.below(200);
        let g = generate_sbm(
            &SbmParams::fitted(n, 3, n * 3, 3.0, vec![0.2, 0.3, 0.5]),
            1000 + i as u64,
        );
        let opts = combos[rng.below(8)];
        rxs.push(
            svc.submit(EmbedRequest { graph: g, options: opts })
                .map_err(|e| anyhow::anyhow!("submit failed: {e:?}"))?,
        );
    }
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let m = svc.shutdown();
    println!(
        "served {ok}/{requests} requests in {:.2}s ({:.0} req/s)",
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64()
    );
    println!("{}", m.summary());
    Ok(())
}

fn usage() -> &'static str {
    "usage: gee <command> [options]\n\
     commands:\n\
       info         [--artifacts DIR]\n\
       generate     --dataset NAME | --sbm N   --out STEM [--seed S]\n\
       embed        --dataset NAME | --sbm N | --input STEM\n\
                    [--engine dense|edgelist|edgelist-par[:T]|sparse|sparse-fast|sparse-par[:T]|sharded[:S]|cluster[:R]]\n\
                    [--options ldc] [--pjrt [--artifacts DIR]] [--cluster] [--out FILE]\n\
       shard-embed  --input STEM | --edges FILE --labels FILE\n\
                    [--shards S] [--mem-budget-edges B]\n\
                    [--workers P | --workers HOST:PORT,... [--slots N]]\n\
                    [--options ldc] [--spill-dir D] [--keep-spill] [--out FILE]\n\
                    [--text-wire]   (force the v1 text protocol instead of\n\
                    negotiating the binary wire per connection)\n\
                    (out-of-core: streams edges from disk per shard;\n\
                     --workers P > 1 embeds shards in P worker processes;\n\
                     --workers HOST:PORT,... dispatches shards to remote\n\
                     `gee shard-serve` daemons over TCP, N in-flight\n\
                     shards per daemon)\n\
       shard-serve  [--listen ADDR:PORT] [--text-only]   (shard-fleet worker\n\
                    daemon; port 0 = ephemeral, the bound address is printed;\n\
                    --text-only serves just the legacy v1 text protocol)\n\
                    [--idle-timeout MS] [--io-timeout MS] [--keep-ttl MS]\n\
                    (lifecycle budgets, 0 disables; defaults 300000 / 60000 /\n\
                    600000; GEE_FAULT_PLAN=... arms deterministic wire faults)\n\
       bench-table  --table 2|3|4|fig3 [--reps R] [--quick] [--sizes a,b,c]\n\
       serve        [--requests N] [--workers W] [--pjrt] [--no-batching]\n\
                    [--intra-op T]   (row-parallel threads for oversize graphs)\n\
                    [--shard-workers HOST:PORT,...]   (remote fleet for\n\
                    oversize jobs)  [--text-wire]\n\
                    [--listen ADDR:PORT]   (network mode: v1 text + v2\n\
                    binary client wire)  [--text-only]   (refuse the v2\n\
                    upgrade)  [--tenant-tokens N]   (per-tenant in-flight\n\
                    quota, default 64)  [--sessions W]   (enable the\n\
                    resident-session lane with W fast-lane refresh threads)\n\
                    [--session-quota N]   (open sessions per tenant, default 4)\n\
                    [--header-timeout MS] [--frame-timeout MS]   (per-phase\n\
                    wire budgets on accepted connections, 0 disables; defaults\n\
                    300000 / 60000; GEE_FAULT_PLAN=... arms wire faults)\n\
       client-embed --addr HOST:PORT   --dataset NAME | --sbm N | --input STEM\n\
                    [--options ldc] [--tenant NAME] [--text-wire] [--out FILE]\n\
                    (one embed against a running `serve --listen` daemon;\n\
                    negotiates the binary v2 wire, --text-wire forces v1)\n\
       client-stream --addr HOST:PORT  --dataset NAME | --sbm N | --input STEM\n\
                    [--options ldc] [--deltas D] [--batch B] [--thresh F]\n\
                    [--tenant NAME] [--out FILE]\n\
                    (open a session holding back the last D edges, stream\n\
                    them as DELTA2 batches, drain, and dump Z — bitwise\n\
                    identical to client-embed of the full graph)\n\
       cluster-embed --dataset NAME | --sbm N | --input STEM\n\
                    [--k K|auto] [--iters R] [--tol F] [--options ldc]\n\
                    [--addr HOST:PORT [--tenant NAME] [--text-wire]]\n\
                    [--workers HOST:PORT,... [--shards S] [--slots N]\n\
                     [--spill-dir D]] [--out FILE]\n\
                    (unsupervised One-Hot GEE: embed → k-means → relabel\n\
                    until labels stabilize; --addr runs one ITER2 job on a\n\
                    serve daemon, --workers drives a shard fleet re-shipping\n\
                    only labels after round 1 — all lanes bitwise identical)\n"
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{}", usage());
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "embed" => cmd_embed(&args),
        "shard-embed" => cmd_shard_embed(&args),
        "shard-serve" => cmd_shard_serve(&args),
        "shard-worker" => cmd_shard_worker(&args),
        "bench-table" => cmd_bench_table(&args),
        "serve" => cmd_serve(&args),
        "client-embed" => cmd_client_embed(&args),
        "client-stream" => cmd_client_stream(&args),
        "cluster-embed" => cmd_cluster_embed(&args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{}", usage()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        let v: Vec<String> = tokens.iter().map(|s| s.to_string()).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn values_starting_with_dashes_are_not_swallowed() {
        // regression: the old parser sniffed the next token for a `--`
        // prefix, so an options code like `--c` became a stray flag and
        // `--options` lost its value
        let a = parse(&["--options", "--c", "--out", "-"]);
        assert_eq!(a.get("options"), Some("--c"));
        assert_eq!(a.get("out"), Some("-"));
        assert!(!a.has("c"));
    }

    #[test]
    fn key_equals_value_form() {
        let a = parse(&["--options=ldc", "--workers=a:1,b:2", "--quick=1"]);
        assert_eq!(a.get("options"), Some("ldc"));
        assert_eq!(a.get("workers"), Some("a:1,b:2"));
        assert!(a.has("quick"));
    }

    #[test]
    fn boolean_flags_bare_and_legacy_forms() {
        // bare presence
        let a = parse(&["--lap", "--cor", "--n", "5"]);
        assert!(a.has("lap") && a.has("cor") && !a.has("diag"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        // legacy 0/1 values still parse (old drivers spawn workers so)
        let a = parse(&["--lap", "1", "--diag", "0", "--cor", "true"]);
        assert!(a.has("lap") && !a.has("diag") && a.has("cor"));
        // a boolean flag directly followed by another option
        let a = parse(&["--quick", "--reps", "3"]);
        assert!(a.has("quick"));
        assert_eq!(a.get_usize("reps", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_is_an_error_not_a_flag() {
        let v = vec!["--options".to_string()];
        let err = Args::parse(&v).unwrap_err();
        assert!(err.to_string().contains("--options requires a value"), "{err}");
    }

    #[test]
    fn positionals_and_unknown_numbers() {
        let a = parse(&["run-this", "--seed", "7"]);
        assert!(a.has("run-this"));
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
        assert!(a.get_usize("seed", 0).is_ok());
        assert!(parse(&["--seed", "x"]).get_usize("seed", 0).is_err());
    }
}
