//! Artifact manifest: the index of AOT-compiled GEE variants written by
//! `python/compile/aot.py`, and the bucket-selection + padding logic that
//! maps a concrete graph onto a shape-specialized PJRT executable.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::gee::GeeOptions;
use crate::util::json::Json;

/// One compiled (bucket × option-combo) variant.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    pub bucket: String,
    /// Padded vertex count.
    pub n: usize,
    /// Padded directed-edge count.
    pub e: usize,
    /// Padded class count.
    pub k: usize,
    pub options: GeeOptions,
    /// L1 kernel tile plan (recorded for §Perf accounting).
    pub block_n: usize,
    pub tile_e: usize,
    pub vmem_bytes: usize,
}

impl Variant {
    /// Does a graph with these dimensions fit this variant?
    pub fn fits(&self, n: usize, e: usize, k: usize) -> bool {
        n <= self.n && e <= self.e && k <= self.k
    }

    /// Absolute path of the HLO file.
    pub fn path(&self, dir: &Path) -> PathBuf {
        dir.join(&self.file)
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<Variant>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`?)", path.display()))?;
        let root = Json::parse(&text).context("parse manifest.json")?;
        if root.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("manifest format is not hlo-text");
        }
        let mut variants = Vec::new();
        for v in root
            .get("variants")
            .and_then(Json::as_arr)
            .context("manifest missing variants")?
        {
            let take_str = |k: &str| -> Result<String> {
                Ok(v.get(k)
                    .and_then(Json::as_str)
                    .with_context(|| format!("variant missing {k}"))?
                    .to_string())
            };
            let take_n = |k: &str| -> Result<usize> {
                v.get(k)
                    .and_then(Json::as_usize)
                    .with_context(|| format!("variant missing {k}"))
            };
            let take_b = |k: &str| -> Result<bool> {
                v.get(k)
                    .and_then(Json::as_bool)
                    .with_context(|| format!("variant missing {k}"))
            };
            variants.push(Variant {
                name: take_str("name")?,
                file: take_str("file")?,
                bucket: take_str("bucket")?,
                n: take_n("n")?,
                e: take_n("e")?,
                k: take_n("k")?,
                options: GeeOptions::new(take_b("lap")?, take_b("diag")?, take_b("cor")?),
                block_n: take_n("block_n")?,
                tile_e: take_n("tile_e")?,
                vmem_bytes: take_n("vmem_bytes")?,
            });
        }
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    /// Smallest variant (by padded element count) that fits the request
    /// and matches the option flags exactly.
    pub fn select(&self, n: usize, e: usize, k: usize, opts: &GeeOptions) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.options == *opts && v.fits(n, e, k))
            .min_by_key(|v| v.n * v.k + v.e)
    }

    /// All bucket names, deduped, in manifest order.
    pub fn buckets(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for v in &self.variants {
            if !seen.contains(&v.bucket) {
                seen.push(v.bucket.clone());
            }
        }
        seen
    }
}

/// Padded input arrays for one variant, ready to become literals.
#[derive(Clone, Debug)]
pub struct PaddedInputs {
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub w: Vec<f32>,
    pub labels: Vec<i32>,
    /// Real (unpadded) sizes, to slice the output back down.
    pub real_n: usize,
    pub real_k: usize,
}

/// Pad a directed edge list + labels to a variant's bucket shape, per the
/// contract in `python/compile/model.py`: zero-weight edges and -1 labels
/// are exact no-ops. Edges are sorted by src first — the kernel's
/// preferred input order (see gee_pallas.py).
pub fn pad_inputs(
    variant: &Variant,
    src: &[u32],
    dst: &[u32],
    w: &[f64],
    labels: &[i32],
) -> Result<PaddedInputs> {
    let (n, e) = (labels.len(), src.len());
    if !variant.fits(n, e, usize::MAX.min(variant.k)) {
        bail!(
            "graph (n={n}, e={e}) does not fit variant {} (n={}, e={})",
            variant.name,
            variant.n,
            variant.e
        );
    }
    // sort edges by src (stable counting-sort order via indices)
    let mut order: Vec<usize> = (0..e).collect();
    order.sort_unstable_by_key(|&i| src[i]);
    let mut ps = Vec::with_capacity(variant.e);
    let mut pd = Vec::with_capacity(variant.e);
    let mut pw = Vec::with_capacity(variant.e);
    for &i in &order {
        ps.push(src[i] as i32);
        pd.push(dst[i] as i32);
        pw.push(w[i] as f32);
    }
    ps.resize(variant.e, 0);
    pd.resize(variant.e, 0);
    pw.resize(variant.e, 0.0);
    let mut pl = labels.to_vec();
    pl.resize(variant.n, -1);
    Ok(PaddedInputs { src: ps, dst: pd, w: pw, labels: pl, real_n: n, real_k: variant.k })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> Manifest {
        let mk = |bucket: &str, n: usize, e: usize, k: usize, code: &str| Variant {
            name: format!("gee_{bucket}_{code}"),
            file: format!("gee_{bucket}_{code}.hlo.txt"),
            bucket: bucket.into(),
            n,
            e,
            k,
            options: GeeOptions::from_code(code).unwrap(),
            block_n: 128,
            tile_e: 64,
            vmem_bytes: 1 << 20,
        };
        Manifest {
            dir: PathBuf::from("/tmp"),
            variants: vec![
                mk("s", 256, 2048, 8, "---"),
                mk("s", 256, 2048, 8, "ldc"),
                mk("m", 2048, 16384, 8, "---"),
                mk("m", 2048, 16384, 8, "ldc"),
            ],
        }
    }

    #[test]
    fn select_prefers_smallest_fitting() {
        let m = fake_manifest();
        let v = m.select(100, 500, 4, &GeeOptions::NONE).unwrap();
        assert_eq!(v.bucket, "s");
        let v = m.select(1000, 500, 4, &GeeOptions::NONE).unwrap();
        assert_eq!(v.bucket, "m");
        assert!(m.select(10_000, 500, 4, &GeeOptions::NONE).is_none());
        assert!(m
            .select(100, 500, 4, &GeeOptions::new(true, false, false))
            .is_none());
    }

    #[test]
    fn pad_inputs_contract() {
        let m = fake_manifest();
        let v = m.select(3, 2, 2, &GeeOptions::NONE).unwrap();
        let p = pad_inputs(v, &[1, 0], &[2, 1], &[0.5, 1.5], &[0, 1, -1]).unwrap();
        assert_eq!(p.src.len(), 2048);
        assert_eq!(p.labels.len(), 256);
        // sorted by src: edge (0,1) first
        assert_eq!(p.src[0], 0);
        assert_eq!(p.dst[0], 1);
        assert_eq!(p.w[0], 1.5);
        assert_eq!(p.w[2], 0.0); // padding
        assert_eq!(p.labels[3], -1);
        assert_eq!(p.real_n, 3);
    }

    #[test]
    fn pad_rejects_oversize() {
        let m = fake_manifest();
        let v = m.select(3, 2, 2, &GeeOptions::NONE).unwrap().clone();
        let src: Vec<u32> = (0..3000).map(|i| i % 10).collect();
        let dst = src.clone();
        let w = vec![1.0; 3000];
        let labels = vec![0; 10];
        assert!(pad_inputs(&v, &src, &dst, &w, &labels).is_err());
    }

    #[test]
    fn buckets_deduped() {
        assert_eq!(fake_manifest().buckets(), vec!["s".to_string(), "m".to_string()]);
    }

    #[test]
    fn load_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variants.len(), 24);
        // every option combo must exist in every bucket
        for b in m.buckets() {
            for o in GeeOptions::table_order() {
                assert!(
                    m.variants.iter().any(|v| v.bucket == b && v.options == o),
                    "missing {b}/{}",
                    o.code()
                );
            }
        }
    }
}
