//! Runtime layer: load and execute the AOT-compiled GEE artifacts
//! (HLO text emitted by `python/compile/aot.py`) on the PJRT CPU client.
//!
//! * [`artifact`] — manifest parsing, bucket selection, padding contract
//! * [`pjrt`] — client + executable cache + the execute hot path

pub mod artifact;
pub mod pjrt;
#[cfg(not(feature = "xla"))]
#[allow(dead_code)]
pub(crate) mod xla_stub;

pub use artifact::Manifest;
pub use pjrt::Runtime;
