//! Build-time stand-in for the `xla` crate (PJRT C-API bindings).
//!
//! The real bindings are not in the offline crate registry, so the default
//! build compiles [`super::pjrt`] against this API-compatible stub instead
//! (see the `xla` cargo feature). Every entry point that would touch PJRT
//! returns an error, which the coordinator already handles: the PJRT lane
//! fails its jobs with a clear message and the native workers keep serving.
//!
//! The surface below mirrors exactly the subset of the real crate that
//! `pjrt.rs` consumes; swapping in the vendored crate requires no source
//! change beyond enabling the feature and adding the dependency.

use std::path::Path;

use anyhow::{bail, Result};

const UNAVAILABLE: &str = "PJRT backend unavailable: built without the `xla` \
     feature (no vendored xla crate in this environment); \
     native engines serve all requests";

pub struct PjRtClient;
pub struct PjRtLoadedExecutable;
pub struct PjRtBuffer;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        bail!(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        bail!(UNAVAILABLE)
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(UNAVAILABLE)
    }
}
