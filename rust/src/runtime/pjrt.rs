//! PJRT execution of the AOT-compiled GEE artifacts.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): load HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `compile` →
//! `execute`. Executables are compiled once per variant and cached; the
//! request path is pad → 4 literals → execute → slice — no Python
//! anywhere.
//!
//! Threading note: the underlying PJRT handles are raw pointers without
//! Send/Sync markers, so a [`Runtime`] is confined to the thread that
//! created it. The coordinator gives its PJRT lane a dedicated worker
//! thread (see `coordinator::service`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

// Without the `xla` feature the PJRT bindings resolve to the in-crate stub
// (same API surface, every call errors); with it, `xla::` resolves to the
// vendored crate via the extern prelude.
#[cfg(not(feature = "xla"))]
use super::xla_stub as xla;

use super::artifact::{pad_inputs, Manifest, Variant};
use crate::gee::GeeOptions;
use crate::graph::Graph;
use crate::sparse::Dense;

/// PJRT-backed GEE engine.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `artifact_dir`.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Can this runtime serve a graph of the given size at all?
    pub fn fits(&self, g: &Graph, opts: &GeeOptions) -> bool {
        self.manifest
            .select(g.n, g.num_directed(), g.k, opts)
            .is_some()
    }

    /// Compile (or fetch from cache) the executable for a variant.
    fn executable(&self, variant: &Variant) -> Result<()> {
        let mut cache = self.cache.borrow_mut();
        if cache.contains_key(&variant.name) {
            return Ok(());
        }
        let path = variant.path(&self.manifest.dir);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", variant.name))?;
        cache.insert(variant.name.clone(), exe);
        Ok(())
    }

    /// Eagerly compile every variant of a bucket (used at service start so
    /// first-request latency is flat).
    pub fn warmup(&self, bucket: &str) -> Result<usize> {
        let variants: Vec<Variant> = self
            .manifest
            .variants
            .iter()
            .filter(|v| v.bucket == bucket)
            .cloned()
            .collect();
        for v in &variants {
            self.executable(v)?;
        }
        Ok(variants.len())
    }

    /// Embed a graph through the compiled artifact for `opts`.
    ///
    /// Returns the N×K embedding (f64 for API uniformity with the native
    /// engines; the artifact computes in f32 — differences vs the native
    /// f64 pipeline are bounded by f32 epsilon · degree).
    pub fn embed(&self, g: &Graph, opts: &GeeOptions) -> Result<Dense> {
        let (src, dst, w) = g.directed_edges();
        let variant = self
            .manifest
            .select(g.n, src.len(), g.k, opts)
            .with_context(|| {
                format!(
                    "no artifact bucket fits n={} e={} k={} {}",
                    g.n,
                    src.len(),
                    g.k,
                    opts.label()
                )
            })?
            .clone();
        self.executable(&variant)?;
        let padded = pad_inputs(&variant, &src, &dst, &w, &g.labels)?;

        let lits = [
            xla::Literal::vec1(padded.src.as_slice()),
            xla::Literal::vec1(padded.dst.as_slice()),
            xla::Literal::vec1(padded.w.as_slice()),
            xla::Literal::vec1(padded.labels.as_slice()),
        ];
        let cache = self.cache.borrow();
        let exe = cache.get(&variant.name).expect("compiled above");
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("execute {}", variant.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?
            .to_tuple1()
            .context("unwrap 1-tuple")?;
        let flat: Vec<f32> = out.to_vec().context("read f32 output")?;
        // padded output is (variant.n, variant.k); slice to (g.n, g.k)
        let mut z = Dense::zeros(g.n, g.k);
        for r in 0..g.n {
            for c in 0..g.k {
                *z.get_mut(r, c) = flat[r * variant.k + c] as f64;
            }
        }
        Ok(z)
    }

    /// Number of compiled executables held.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

// Integration tests live in rust/tests/runtime_integration.rs (they need
// built artifacts); unit coverage for selection/padding is in artifact.rs.
