//! Benchmark harness — the code that regenerates every table and figure
//! of the paper's evaluation section. Shared by the `cargo bench` targets
//! (`rust/benches/*.rs`) and the `gee bench-table` CLI so the numbers in
//! EXPERIMENTS.md come from one implementation.

use std::time::Duration;

use crate::gee::{Engine, GeeOptions};
use crate::graph::datasets::{paper_density, DatasetSpec, TABLE2};
use crate::graph::sbm::{generate_sbm, SbmParams};
use crate::graph::Graph;
use crate::util::timing::{bench_runs, secs, Stats};

/// One measured cell: engine × (dataset, options).
#[derive(Clone, Debug)]
pub struct Cell {
    pub engine: Engine,
    pub options: GeeOptions,
    pub stats: Stats,
}

/// Measure one engine on one graph/options combo.
pub fn measure(engine: Engine, g: &Graph, opts: &GeeOptions, warmup: usize, reps: usize) -> Stats {
    let runs = bench_runs(warmup, reps, || {
        engine.embed(g, opts).expect("engine must handle this graph")
    });
    Stats::from_runs(&runs)
}

// ------------------------------------------------------------- Fig. 3

/// The paper's Fig. 3 node counts.
pub const FIG3_SIZES: &[usize] = &[100, 1_000, 3_000, 5_000, 10_000];

/// One Fig. 3 series point.
#[derive(Clone, Debug)]
pub struct Fig3Point {
    pub n: usize,
    pub edges: usize,
    pub gee: Stats,
    pub sparse: Stats,
}

/// Run the Fig. 3 sweep: SBM at the paper's parameters, all options on
/// (Lap = Diag = Cor = T), original GEE vs sparse GEE.
pub fn run_fig3(sizes: &[usize], reps: usize, seed: u64) -> Vec<Fig3Point> {
    let opts = GeeOptions::ALL;
    sizes
        .iter()
        .map(|&n| {
            let g = generate_sbm(&SbmParams::paper(n), seed);
            let gee = measure(Engine::EdgeList, &g, &opts, 1, reps);
            let sparse = measure(Engine::Sparse, &g, &opts, 1, reps);
            Fig3Point { n, edges: g.num_edges(), gee, sparse }
        })
        .collect()
}

/// Render Fig. 3 as the table of series the paper plots.
pub fn format_fig3(points: &[Fig3Point]) -> String {
    let mut out = String::new();
    out.push_str("Fig 3 — GEE vs sparse GEE on simulated SBM (Lap=T, Diag=T, Cor=T)\n");
    out.push_str(&format!(
        "{:>8} {:>10} {:>12} {:>12} {:>9}\n",
        "nodes", "edges", "GEE (s)", "sparse (s)", "speedup"
    ));
    for p in points {
        let s = p.gee.median.as_secs_f64() / p.sparse.median.as_secs_f64().max(1e-12);
        out.push_str(&format!(
            "{:>8} {:>10} {:>12} {:>12} {:>8.1}x\n",
            p.n,
            p.edges,
            secs(p.gee.median),
            secs(p.sparse.median),
            s
        ));
    }
    out
}

// -------------------------------------------------------- Tables 3-4

/// One row of Table 3 or 4: a dataset × 4 option combos × both engines.
#[derive(Clone, Debug)]
pub struct TableRow {
    pub dataset: &'static str,
    pub nodes: usize,
    pub edges: usize,
    /// (options, original GEE stats, sparse GEE stats), 4 combos.
    pub cells: Vec<(GeeOptions, Stats, Stats)>,
}

/// The paper's Table 3 (Lap = T) or Table 4 (Lap = F) option columns.
pub fn table_columns(lap: bool) -> Vec<GeeOptions> {
    let mut cols = Vec::new();
    for &diag in &[true, false] {
        for &cor in &[true, false] {
            cols.push(GeeOptions::new(lap, diag, cor));
        }
    }
    cols
}

/// Run one of the real-dataset tables over the Table-2 twins.
/// `max_edges` lets quick runs skip the 10M-edge twin.
pub fn run_table(lap: bool, reps: usize, max_edges: usize) -> Vec<TableRow> {
    let cols = table_columns(lap);
    TABLE2
        .iter()
        .filter(|spec| spec.edges <= max_edges)
        .map(|spec| run_table_row(spec, &cols, reps))
        .collect()
}

/// Run a single dataset row.
pub fn run_table_row(spec: &DatasetSpec, cols: &[GeeOptions], reps: usize) -> TableRow {
    let g = spec.generate();
    let cells = cols
        .iter()
        .map(|opts| {
            let gee = measure(Engine::EdgeList, &g, opts, 1, reps);
            let sparse = measure(Engine::Sparse, &g, opts, 1, reps);
            (*opts, gee, sparse)
        })
        .collect();
    TableRow { dataset: spec.name, nodes: g.n, edges: g.num_edges(), cells }
}

/// Render in the paper's layout: per combo, GEE column then Sparse GEE.
pub fn format_table(rows: &[TableRow], table_no: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Table {table_no} — GEE vs Sparse GEE on real-dataset twins (operation time, s)\n"
    ));
    if let Some(first) = rows.first() {
        out.push_str(&format!("{:>28}", "Data Set (node/edge)"));
        for (o, _, _) in &first.cells {
            out.push_str(&format!(" | {:^21}", o.label().replace("Lap = ", "L").replace("Diag = ", "D").replace("Cor = ", "C")));
        }
        out.push('\n');
        out.push_str(&format!("{:>28}", ""));
        for _ in &first.cells {
            out.push_str(&format!(" | {:>9} {:>11}", "GEE", "Sparse GEE"));
        }
        out.push('\n');
    }
    for r in rows {
        out.push_str(&format!(
            "{:>28}",
            format!("{} ({}/{})", r.dataset, r.nodes, r.edges)
        ));
        for (_, gee, sparse) in &r.cells {
            out.push_str(&format!(
                " | {:>9} {:>11}",
                secs(gee.median),
                secs(sparse.median)
            ));
        }
        out.push('\n');
    }
    out
}

// ------------------------------------------------------------ Table 2

/// Render Table 2 (dataset statistics) from the twin registry, with the
/// paper's published densities alongside for the fidelity check.
pub fn format_table2() -> String {
    let mut out = String::new();
    out.push_str("Table 2 — datasets (synthetic twins; density per Eq. 2)\n");
    out.push_str(&format!(
        "{:>16} {:>8} {:>11} {:>8} {:>12} {:>12}\n",
        "Dataset", "Nodes", "Edges", "Classes", "Density", "Paper d"
    ));
    for spec in TABLE2 {
        out.push_str(&format!(
            "{:>16} {:>8} {:>11} {:>8} {:>12.5} {:>12.5}\n",
            spec.name,
            spec.nodes,
            spec.edges,
            spec.classes,
            spec.density(),
            paper_density(spec.name).unwrap_or(f64::NAN)
        ));
    }
    out
}

// ----------------------------------------------------------- summary

/// Throughput in directed edges per second for a measured stat.
pub fn edges_per_sec(edges: usize, d: Duration) -> f64 {
    2.0 * edges as f64 / d.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick_run_produces_points() {
        let points = run_fig3(&[100, 300], 2, 1);
        assert_eq!(points.len(), 2);
        assert!(points[1].edges > points[0].edges);
        let text = format_fig3(&points);
        assert!(text.contains("nodes"));
        assert!(text.contains("300"));
    }

    #[test]
    fn table_columns_layout() {
        let t3 = table_columns(true);
        assert_eq!(t3.len(), 4);
        assert!(t3.iter().all(|o| o.laplacian));
        assert_eq!(t3[0], GeeOptions::new(true, true, true));
        assert_eq!(t3[3], GeeOptions::new(true, false, false));
        let t4 = table_columns(false);
        assert!(t4.iter().all(|o| !o.laplacian));
    }

    #[test]
    fn table_quick_row() {
        let cols = table_columns(false);
        let spec = &TABLE2[1]; // Cora twin
        let row = run_table_row(spec, &cols[..1], 1);
        assert_eq!(row.dataset, "Cora");
        assert_eq!(row.cells.len(), 1);
        let text = format_table(&[row], 4);
        assert!(text.contains("Cora"));
        assert!(text.contains("Sparse GEE"));
    }

    #[test]
    fn table2_includes_all_six() {
        let t = format_table2();
        for spec in TABLE2 {
            assert!(t.contains(spec.name));
        }
    }

    #[test]
    fn edges_per_sec_sane() {
        let e = edges_per_sec(1000, Duration::from_secs(1));
        assert!((e - 2000.0).abs() < 1e-9);
    }
}
