//! Streaming (incremental) GEE — the coordinator's dynamic-graph lane,
//! the setting of the GEE line's time-series work (Shen et al. 2023,
//! communication-pattern shifts): edges arrive as a stream and embeddings
//! must stay queryable without recomputing from scratch.
//!
//! Key design: the state is the *unnormalized* class-sum matrix
//! `counts[i][c] = Σ_{(i,j)∈E, y_j=c} w_ij` plus degrees and class sizes.
//! Because the `1/n_k` normalization is applied at snapshot time,
//! every mutation is O(1) or O(deg):
//!
//! * `add_edge`      O(1) bookkeeping (plus O(deg) dirty marks when a
//!                   Laplacian snapshot is cached — see below)
//! * `add_vertex`    O(K)
//! * `relabel`       O(deg(v))   (moves v's contribution column at its
//!                                neighbors)
//! * `snapshot`      O(Δ·K) between edits: the last snapshot is cached
//!                   together with a [`DirtySet`] of rows whose inputs
//!                   changed, and only those rows are recomputed (each in
//!                   O(deg·K)). Falls back to the full pass on an option
//!                   change, on global invalidation (label churn moves
//!                   `n_k`, which touches every row), or on first call.
//!
//! The full pass survives as [`snapshot_full`](StreamingGee::snapshot_full),
//! the parity oracle: the cached path is required to be **bitwise**
//! identical to it, which the tests enforce with `f64::to_bits`. That
//! works because the per-row recompute replays the exact same
//! floating-point sequence as the full pass (same accumulation order over
//! the adjacency list, same `safe_recip_sqrt` scale factors, same row
//! normalization as [`normalize_rows`]).
//!
//! Every snapshot is *exact*: equality with the batch `SparseGee` is
//! property-tested across all 8 option combos after random edit scripts.

use crate::gee::globals::DirtySet;
use crate::gee::options::GeeOptions;
use crate::gee::weights::class_counts;
use crate::graph::Graph;
use crate::sparse::ops::{normalize_rows, safe_recip, safe_recip_sqrt};
use crate::sparse::Dense;

/// Incremental GEE state.
#[derive(Clone, Debug)]
pub struct StreamingGee {
    k: usize,
    labels: Vec<i32>,
    /// Unnormalized class sums, row-major N×K.
    counts: Vec<f64>,
    /// Weighted degree (self loops once).
    degrees: Vec<f64>,
    /// Class sizes.
    n_k: Vec<f64>,
    /// Adjacency list (neighbor, weight); self loops stored once.
    adj: Vec<Vec<(u32, f64)>>,
    /// Rows whose cached embedding is stale. Only maintained while a
    /// snapshot is cached (`snap.is_some()`); before the first snapshot
    /// every mutation is absorbed for free.
    dirty: DirtySet,
    /// Last materialized snapshot and the options it was taken under.
    snap: Option<(GeeOptions, Dense)>,
    /// Edges processed (for metrics).
    pub edges_seen: usize,
}

impl StreamingGee {
    /// Start from an existing labeled graph (may have zero edges).
    pub fn new(g: &Graph) -> Self {
        let mut s = StreamingGee {
            k: g.k,
            labels: g.labels.clone(),
            counts: vec![0.0; g.n * g.k],
            degrees: vec![0.0; g.n],
            n_k: class_counts(&g.labels, g.k),
            adj: vec![Vec::new(); g.n],
            dirty: DirtySet::new(g.n),
            snap: None,
            edges_seen: 0,
        };
        for i in 0..g.num_edges() {
            s.add_edge(g.src[i], g.dst[i], g.w[i]);
        }
        s
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Insert an undirected edge. O(1). Panics on an out-of-range endpoint
    /// or a non-finite weight; see [`try_add_edge`](Self::try_add_edge)
    /// for the validating form.
    pub fn add_edge(&mut self, a: u32, b: u32, w: f64) {
        self.try_add_edge(a, b, w).expect("StreamingGee::add_edge");
    }

    /// Validating [`add_edge`](Self::add_edge): rejects out-of-range
    /// endpoints and non-finite weights, leaving the state untouched.
    pub fn try_add_edge(&mut self, a: u32, b: u32, w: f64) -> Result<(), String> {
        let (ai, bi) = (a as usize, b as usize);
        let n = self.n();
        if ai >= n || bi >= n {
            return Err(format!("edge ({a}, {b}) out of range (n={n})"));
        }
        if !w.is_finite() {
            return Err(format!("edge ({a}, {b}) has non-finite weight {w}"));
        }
        if self.snap.is_some() {
            // The endpoints' rows change under every option combo. Under
            // Laplacian scaling their degrees feed every incident row, so
            // current neighbors go stale too; edges inserted *later* mark
            // their own endpoints, so marking the pre-insert lists is
            // enough.
            self.dirty.mark(a);
            self.dirty.mark(b);
            let lap = self.snap.as_ref().is_some_and(|(o, _)| o.laplacian);
            if lap {
                for &(u, _) in &self.adj[ai] {
                    self.dirty.mark(u);
                }
                for &(u, _) in &self.adj[bi] {
                    self.dirty.mark(u);
                }
            }
        }
        let (la, lb) = (self.labels[ai], self.labels[bi]);
        if lb >= 0 {
            self.counts[ai * self.k + lb as usize] += w;
        }
        self.degrees[ai] += w;
        if ai != bi {
            if la >= 0 {
                self.counts[bi * self.k + la as usize] += w;
            }
            self.degrees[bi] += w;
        }
        self.adj[ai].push((b, w));
        if ai != bi {
            self.adj[bi].push((a, w));
        }
        self.edges_seen += 1;
        Ok(())
    }

    /// Append a vertex with the given label (or -1). O(K). Returns its id.
    /// Any negative label is normalized to the canonical `-1` sentinel:
    /// the engines' `l >= 0` checks would already *treat* a `-7` as
    /// unlabeled, but storing it verbatim would leak out of
    /// [`to_graph`](Self::to_graph) and desync snapshot/batch round-trips.
    /// Panics on `label >= k`; see [`try_add_vertex`](Self::try_add_vertex).
    pub fn add_vertex(&mut self, label: i32) -> u32 {
        self.try_add_vertex(label).expect("StreamingGee::add_vertex")
    }

    /// Validating [`add_vertex`](Self::add_vertex): rejects `label >= k`,
    /// leaving the state untouched.
    pub fn try_add_vertex(&mut self, label: i32) -> Result<u32, String> {
        let label = label.max(-1);
        if label >= self.k as i32 {
            return Err(format!("label {label} out of range (k={})", self.k));
        }
        let id = self.n() as u32;
        self.labels.push(label);
        self.counts.extend(std::iter::repeat(0.0).take(self.k));
        self.degrees.push(0.0);
        self.adj.push(Vec::new());
        self.dirty.grow(self.n());
        if label >= 0 {
            self.n_k[label as usize] += 1.0;
            // n_k moved: 1/n_k feeds every row of the cached snapshot.
            if self.snap.is_some() {
                self.dirty.mark_all();
            }
        } else if self.snap.is_some() {
            // Unlabeled vertex: n_k untouched, only the (all-zero) new row
            // needs materializing.
            self.dirty.mark(id);
        }
        Ok(id)
    }

    /// Change a vertex's label. O(deg(v)): moves v's contribution from the
    /// old class column to the new one at every neighbor. Negative labels
    /// normalize to `-1` (same rationale as [`add_vertex`](Self::add_vertex)).
    /// Panics on out-of-range input; see [`try_relabel`](Self::try_relabel).
    pub fn relabel(&mut self, v: u32, new_label: i32) {
        self.try_relabel(v, new_label).expect("StreamingGee::relabel");
    }

    /// Validating [`relabel`](Self::relabel): rejects an out-of-range
    /// vertex or `new_label >= k`, leaving the state untouched.
    pub fn try_relabel(&mut self, v: u32, new_label: i32) -> Result<(), String> {
        let new_label = new_label.max(-1);
        let vi = v as usize;
        if vi >= self.n() {
            return Err(format!("vertex {v} out of range (n={})", self.n()));
        }
        if new_label >= self.k as i32 {
            return Err(format!("label {new_label} out of range (k={})", self.k));
        }
        let old = self.labels[vi];
        if old == new_label {
            return Ok(());
        }
        if old >= 0 {
            self.n_k[old as usize] -= 1.0;
        }
        if new_label >= 0 {
            self.n_k[new_label as usize] += 1.0;
        }
        // move v's column contribution at each neighbor (self loops move
        // v's own row too, handled uniformly since adj stores (v, w))
        for &(u, w) in &self.adj[vi] {
            let ui = u as usize;
            if old >= 0 {
                self.counts[ui * self.k + old as usize] -= w;
            }
            if new_label >= 0 {
                self.counts[ui * self.k + new_label as usize] += w;
            }
        }
        self.labels[vi] = new_label;
        // A relabel moves n_k (and hence 1/n_k) whenever either side is a
        // real class, which is always the case past the old == new check:
        // every cached row goes stale.
        if self.snap.is_some() {
            self.dirty.mark_all();
        }
        Ok(())
    }

    /// Exact embedding snapshot under the given options. Served from the
    /// row cache in O(dirty·deg·K) when the previous snapshot used the
    /// same options; otherwise falls back to
    /// [`snapshot_full`](Self::snapshot_full). Either way the result is
    /// bitwise identical to the full pass.
    pub fn snapshot(&mut self, opts: &GeeOptions) -> Dense {
        self.refresh(opts);
        match &self.snap {
            Some((_, z)) => z.clone(),
            None => unreachable!("refresh always materializes a snapshot"),
        }
    }

    /// Bring the cached snapshot up to date under `opts`.
    fn refresh(&mut self, opts: &GeeOptions) {
        let n = self.n();
        let hit = matches!(&self.snap,
            Some((cached, _)) if cached == opts && !self.dirty.is_all());
        if !hit {
            let z = self.snapshot_full(opts);
            self.snap = Some((*opts, z));
            self.dirty.clear();
            return;
        }
        let (_, mut z) = self.snap.take().expect("hit implies a cached snapshot");
        if z.nrows < n {
            // vertices appended since the cache was taken; their rows are
            // in the dirty set
            z.data.resize(n * self.k, 0.0);
            z.nrows = n;
        }
        let inv_nk: Vec<f64> = self.n_k.iter().map(|&c| safe_recip(c)).collect();
        for &r in self.dirty.rows() {
            self.recompute_row(opts, &inv_nk, &mut z, r as usize);
        }
        self.dirty.clear();
        self.snap = Some((*opts, z));
    }

    /// Recompute one row of the embedding in place — the O(deg·K) unit of
    /// the incremental path. Must replay the exact floating-point sequence
    /// of [`snapshot_full`](Self::snapshot_full) for that row (accumulation
    /// order, scale factors, normalization) so the two stay bitwise equal.
    fn recompute_row(&self, opts: &GeeOptions, inv_nk: &[f64], z: &mut Dense, v: usize) {
        let row = z.row_mut(v);
        row.fill(0.0);
        if opts.laplacian {
            let dv = if opts.diagonal { self.degrees[v] + 1.0 } else { self.degrees[v] };
            let sv = safe_recip_sqrt(dv);
            for &(u, w) in &self.adj[v] {
                let ui = u as usize;
                let lu = self.labels[ui];
                if lu >= 0 {
                    let du = if opts.diagonal { self.degrees[ui] + 1.0 } else { self.degrees[ui] };
                    let su = safe_recip_sqrt(du);
                    row[lu as usize] += w * sv * su * inv_nk[lu as usize];
                }
            }
            if opts.diagonal {
                let l = self.labels[v];
                if l >= 0 {
                    row[l as usize] += sv * sv * inv_nk[l as usize];
                }
            }
        } else {
            let base = v * self.k;
            for (c, x) in row.iter_mut().enumerate() {
                *x = self.counts[base + c] * inv_nk[c];
            }
            if opts.diagonal {
                let l = self.labels[v];
                if l >= 0 {
                    row[l as usize] += inv_nk[l as usize];
                }
            }
        }
        if opts.correlation {
            // same per-row math as normalize_rows (bitwise)
            let norm: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
            let s = safe_recip(norm);
            if s != 0.0 {
                for x in row.iter_mut() {
                    *x *= s;
                }
            }
        }
    }

    /// Exact embedding snapshot computed from scratch — the parity oracle
    /// for the cached path. O(N·K) for plain/diag/cor; O(E + N·K) with
    /// Laplacian on.
    pub fn snapshot_full(&self, opts: &GeeOptions) -> Dense {
        let n = self.n();
        let k = self.k;
        let inv_nk: Vec<f64> = self.n_k.iter().map(|&c| safe_recip(c)).collect();
        let mut z = Dense::zeros(n, k);

        if opts.laplacian {
            // one pass over the adjacency list with degree scaling
            let mut deg = self.degrees.clone();
            if opts.diagonal {
                for d in deg.iter_mut() {
                    *d += 1.0;
                }
            }
            let s: Vec<f64> = deg.iter().map(|&d| safe_recip_sqrt(d)).collect();
            for v in 0..n {
                let row = z.row_mut(v);
                for &(u, w) in &self.adj[v] {
                    let ui = u as usize;
                    let lu = self.labels[ui];
                    if lu >= 0 {
                        row[lu as usize] += w * s[v] * s[ui] * inv_nk[lu as usize];
                    }
                }
                // adj double-stores proper edges but self loops only once,
                // which matches the degree convention already.
            }
            if opts.diagonal {
                for v in 0..n {
                    let l = self.labels[v];
                    if l >= 0 {
                        *z.get_mut(v, l as usize) += s[v] * s[v] * inv_nk[l as usize];
                    }
                }
            }
        } else {
            for v in 0..n {
                let row = z.row_mut(v);
                let base = v * k;
                for c in 0..k {
                    row[c] = self.counts[base + c] * inv_nk[c];
                }
            }
            if opts.diagonal {
                for v in 0..n {
                    let l = self.labels[v];
                    if l >= 0 {
                        *z.get_mut(v, l as usize) += inv_nk[l as usize];
                    }
                }
            }
        }

        if opts.correlation {
            normalize_rows(&mut z);
        }
        z
    }

    /// Export the current state as a plain graph (for checkpointing and
    /// the equality tests).
    pub fn to_graph(&self) -> Graph {
        let n = self.n();
        let mut g = Graph::new(n, self.k);
        g.labels = self.labels.clone();
        for v in 0..n {
            for &(u, w) in &self.adj[v] {
                // emit each proper edge once (from its lower endpoint's
                // list the first time we see it with u >= v)
                if u as usize >= v {
                    g.add_edge(v as u32, u, w);
                }
            }
        }
        // adj double-stores proper edges: (v,u) appears in v's list and u's
        // list; the filter above keeps exactly one copy. Self loops stored
        // once and kept once.
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::Engine;
    use crate::util::rng::Rng;

    fn assert_bitwise(a: &Dense, b: &Dense, ctx: &str) {
        assert_eq!((a.nrows, a.ncols), (b.nrows, b.ncols), "{ctx}: shape");
        for (i, (x, y)) in a.data.iter().zip(b.data.iter()).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "{ctx}: cell {i} differs: {x:e} vs {y:e}"
            );
        }
    }

    fn check_all_combos(s: &mut StreamingGee) {
        let g = s.to_graph();
        for opts in GeeOptions::table_order() {
            let batch = Engine::Sparse.embed(&g, &opts).unwrap();
            let stream = s.snapshot(&opts);
            assert!(
                batch.max_abs_diff(&stream) < 1e-10,
                "streaming != batch at {:?}: {}",
                opts,
                batch.max_abs_diff(&stream)
            );
            assert_bitwise(&stream, &s.snapshot_full(&opts), &format!("{opts:?}"));
        }
    }

    #[test]
    fn matches_batch_after_edge_stream() {
        let mut g = Graph::new(30, 3);
        let mut rng = Rng::new(301);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        let mut s = StreamingGee::new(&g);
        for _ in 0..150 {
            s.add_edge(rng.below(30) as u32, rng.below(30) as u32, rng.f64() + 0.1);
        }
        check_all_combos(&mut s);
    }

    #[test]
    fn matches_batch_after_vertex_growth() {
        let mut g = Graph::new(10, 3);
        let mut rng = Rng::new(302);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        let mut s = StreamingGee::new(&g);
        for i in 0..40 {
            if i % 3 == 0 {
                let lbl = if i % 6 == 0 { -1 } else { rng.below(3) as i32 };
                s.add_vertex(lbl);
            }
            let n = s.n();
            s.add_edge(rng.below(n) as u32, rng.below(n) as u32, 1.0);
        }
        check_all_combos(&mut s);
    }

    #[test]
    fn matches_batch_after_relabels() {
        let mut g = Graph::new(25, 4);
        let mut rng = Rng::new(303);
        for l in g.labels.iter_mut() {
            *l = rng.below(4) as i32;
        }
        for _ in 0..80 {
            g.add_edge(rng.below(25) as u32, rng.below(25) as u32, rng.f64() + 0.1);
        }
        let mut s = StreamingGee::new(&g);
        for _ in 0..30 {
            let v = rng.below(25) as u32;
            let new = (rng.below(5) as i32) - 1; // includes -1
            s.relabel(v, new);
        }
        check_all_combos(&mut s);
    }

    #[test]
    fn dirty_refresh_bitwise_matches_full() {
        // the cached O(Δ) path: prime the cache, mutate, snapshot again —
        // every snapshot must be bitwise equal to the from-scratch pass
        for (oi, opts) in GeeOptions::table_order().into_iter().enumerate() {
            let mut g = Graph::new(40, 4);
            let mut rng = Rng::new(0xD117 ^ oi as u64);
            for l in g.labels.iter_mut() {
                *l = rng.below(4) as i32;
            }
            for _ in 0..100 {
                g.add_edge(rng.below(40) as u32, rng.below(40) as u32, rng.f64() + 0.1);
            }
            let mut s = StreamingGee::new(&g);
            s.snapshot(&opts); // prime the cache
            for round in 0..12 {
                for _ in 0..10 {
                    let n = s.n();
                    s.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
                }
                if round % 4 == 1 {
                    s.add_vertex(-1); // cache grows in place
                }
                if round % 4 == 3 {
                    // forces mark_all and a full fallback next snapshot
                    let v = rng.below(s.n()) as u32;
                    s.relabel(v, (rng.below(5) as i32) - 1);
                }
                let cached = s.snapshot(&opts);
                let full = s.snapshot_full(&opts);
                assert_bitwise(&cached, &full, &format!("{opts:?} round {round}"));
            }
        }
    }

    #[test]
    fn option_switch_invalidates_cache() {
        let mut g = Graph::new(20, 3);
        let mut rng = Rng::new(305);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        let mut s = StreamingGee::new(&g);
        for _ in 0..60 {
            s.add_edge(rng.below(20) as u32, rng.below(20) as u32, 1.0);
        }
        // alternate between two option sets with edits in between; each
        // switch is a cache miss and must still be exact
        let a = GeeOptions { laplacian: true, diagonal: true, correlation: false };
        let b = GeeOptions { laplacian: false, diagonal: false, correlation: true };
        for i in 0..6 {
            s.add_edge(rng.below(20) as u32, rng.below(20) as u32, rng.f64() + 0.1);
            let opts = if i % 2 == 0 { a } else { b };
            assert_bitwise(&s.snapshot(&opts), &s.snapshot_full(&opts), "switch");
        }
    }

    #[test]
    fn try_apis_reject_and_leave_state_unchanged() {
        let mut g = Graph::new(6, 2);
        g.labels = vec![0, 1, 0, 1, 0, 1];
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 2.0);
        let mut s = StreamingGee::new(&g);
        let before = s.snapshot(&GeeOptions::ALL);
        let edges_before = s.edges_seen;

        assert!(s.try_add_edge(0, 6, 1.0).is_err(), "endpoint out of range");
        assert!(s.try_add_edge(9, 0, 1.0).is_err(), "endpoint out of range");
        assert!(s.try_add_edge(0, 1, f64::NAN).is_err(), "NaN weight");
        assert!(s.try_add_edge(0, 1, f64::INFINITY).is_err(), "inf weight");
        assert!(s.try_add_vertex(2).is_err(), "label >= k");
        assert!(s.try_relabel(6, 0).is_err(), "vertex out of range");
        assert!(s.try_relabel(0, 2).is_err(), "label >= k");

        assert_eq!(s.n(), 6, "rejected ops must not change n");
        assert_eq!(s.edges_seen, edges_before, "rejected ops must not count");
        let after = s.snapshot(&GeeOptions::ALL);
        assert_bitwise(&before, &after, "state after rejected ops");
        check_all_combos(&mut s);

        // the valid forms still work through the same entry points
        assert!(s.try_add_edge(0, 5, 0.5).is_ok());
        assert_eq!(s.try_add_vertex(-3), Ok(6), "negative labels normalize");
        assert!(s.try_relabel(0, -1).is_ok());
        check_all_combos(&mut s);
    }

    #[test]
    fn arbitrary_negative_labels_normalize_to_unlabeled() {
        // regression (ISSUE 3): `-7` used to be stored verbatim, leaking a
        // non-canonical unlabeled sentinel into to_graph()
        let mut g = Graph::new(4, 3);
        g.labels = vec![0, 1, 2, 0];
        g.add_edge(0, 1, 1.0);
        let mut s = StreamingGee::new(&g);
        let v = s.add_vertex(-7);
        s.add_edge(v, 0, 2.0);
        s.relabel(1, -9);
        let out = s.to_graph();
        assert_eq!(out.labels[v as usize], -1, "add_vertex(-7) must store -1");
        assert_eq!(out.labels[1], -1, "relabel(-9) must store -1");
        assert!(out.validate().is_ok());
        // n_k bookkeeping stayed consistent: snapshot == batch everywhere
        check_all_combos(&mut s);
        // and relabeling back from the normalized sentinel still works
        s.relabel(v, 2);
        assert_eq!(s.to_graph().labels[v as usize], 2);
        check_all_combos(&mut s);
    }

    #[test]
    fn self_loops_in_stream() {
        let mut g = Graph::new(8, 2);
        g.labels = vec![0, 0, 1, 1, 0, 1, 0, 1];
        let mut s = StreamingGee::new(&g);
        s.add_edge(3, 3, 2.5);
        s.add_edge(0, 3, 1.0);
        s.add_edge(3, 3, 0.5);
        check_all_combos(&mut s);
    }

    #[test]
    fn snapshot_is_pure() {
        let mut g = Graph::new(12, 2);
        g.labels = (0..12).map(|i| (i % 2) as i32).collect();
        let mut s = StreamingGee::new(&g);
        s.add_edge(0, 1, 1.0);
        let a = s.snapshot(&GeeOptions::ALL);
        let b = s.snapshot(&GeeOptions::ALL);
        assert!(a.max_abs_diff(&b) == 0.0);
    }

    #[test]
    fn edges_seen_counter() {
        let g = Graph::new(5, 2);
        let mut s = StreamingGee::new(&g);
        s.add_edge(0, 1, 1.0);
        s.add_edge(1, 2, 1.0);
        assert_eq!(s.edges_seen, 2);
    }
}
