//! Streaming (incremental) GEE — the coordinator's dynamic-graph lane,
//! the setting of the GEE line's time-series work (Shen et al. 2023,
//! communication-pattern shifts): edges arrive as a stream and embeddings
//! must stay queryable without recomputing from scratch.
//!
//! Key design: the state is the *unnormalized* class-sum matrix
//! `counts[i][c] = Σ_{(i,j)∈E, y_j=c} w_ij` plus degrees and class sizes.
//! Because the `1/n_k` normalization is applied at snapshot time,
//! every mutation is O(1) or O(deg):
//!
//! * `add_edge`      O(1)
//! * `add_vertex`    O(K)
//! * `relabel`       O(deg(v))   (moves v's contribution column at its
//!                                neighbors)
//! * `snapshot`      O(N·K) for plain/diag/cor — exact;
//!                   O(E + N·K) when Laplacian is on (degree-dependent
//!                   scaling breaks O(1) incrementality; recomputed from
//!                   the adjacency list, still one pass).
//!
//! Every snapshot is *exact*: equality with the batch `SparseGee` is
//! property-tested across all 8 option combos after random edit scripts.

use crate::gee::options::GeeOptions;
use crate::gee::weights::class_counts;
use crate::graph::Graph;
use crate::sparse::ops::{normalize_rows, safe_recip, safe_recip_sqrt};
use crate::sparse::Dense;

/// Incremental GEE state.
#[derive(Clone, Debug)]
pub struct StreamingGee {
    k: usize,
    labels: Vec<i32>,
    /// Unnormalized class sums, row-major N×K.
    counts: Vec<f64>,
    /// Weighted degree (self loops once).
    degrees: Vec<f64>,
    /// Class sizes.
    n_k: Vec<f64>,
    /// Adjacency list (neighbor, weight); self loops stored once.
    adj: Vec<Vec<(u32, f64)>>,
    /// Edges processed (for metrics).
    pub edges_seen: usize,
}

impl StreamingGee {
    /// Start from an existing labeled graph (may have zero edges).
    pub fn new(g: &Graph) -> Self {
        let mut s = StreamingGee {
            k: g.k,
            labels: g.labels.clone(),
            counts: vec![0.0; g.n * g.k],
            degrees: vec![0.0; g.n],
            n_k: class_counts(&g.labels, g.k),
            adj: vec![Vec::new(); g.n],
            edges_seen: 0,
        };
        for i in 0..g.num_edges() {
            s.add_edge(g.src[i], g.dst[i], g.w[i]);
        }
        s
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Insert an undirected edge. O(1).
    pub fn add_edge(&mut self, a: u32, b: u32, w: f64) {
        let (ai, bi) = (a as usize, b as usize);
        assert!(ai < self.n() && bi < self.n());
        let (la, lb) = (self.labels[ai], self.labels[bi]);
        if lb >= 0 {
            self.counts[ai * self.k + lb as usize] += w;
        }
        self.degrees[ai] += w;
        if ai != bi {
            if la >= 0 {
                self.counts[bi * self.k + la as usize] += w;
            }
            self.degrees[bi] += w;
        }
        self.adj[ai].push((b, w));
        if ai != bi {
            self.adj[bi].push((a, w));
        }
        self.edges_seen += 1;
    }

    /// Append a vertex with the given label (or -1). O(K). Returns its id.
    /// Any negative label is normalized to the canonical `-1` sentinel:
    /// the engines' `l >= 0` checks would already *treat* a `-7` as
    /// unlabeled, but storing it verbatim would leak out of
    /// [`to_graph`](Self::to_graph) and desync snapshot/batch round-trips.
    pub fn add_vertex(&mut self, label: i32) -> u32 {
        let label = label.max(-1);
        assert!(label < self.k as i32);
        let id = self.n() as u32;
        self.labels.push(label);
        self.counts.extend(std::iter::repeat(0.0).take(self.k));
        self.degrees.push(0.0);
        self.adj.push(Vec::new());
        if label >= 0 {
            self.n_k[label as usize] += 1.0;
        }
        id
    }

    /// Change a vertex's label. O(deg(v)): moves v's contribution from the
    /// old class column to the new one at every neighbor. Negative labels
    /// normalize to `-1` (same rationale as [`add_vertex`](Self::add_vertex)).
    pub fn relabel(&mut self, v: u32, new_label: i32) {
        let new_label = new_label.max(-1);
        let vi = v as usize;
        assert!(vi < self.n() && new_label < self.k as i32);
        let old = self.labels[vi];
        if old == new_label {
            return;
        }
        if old >= 0 {
            self.n_k[old as usize] -= 1.0;
        }
        if new_label >= 0 {
            self.n_k[new_label as usize] += 1.0;
        }
        // move v's column contribution at each neighbor (self loops move
        // v's own row too, handled uniformly since adj stores (v, w))
        for &(u, w) in &self.adj[vi] {
            let ui = u as usize;
            if old >= 0 {
                self.counts[ui * self.k + old as usize] -= w;
            }
            if new_label >= 0 {
                self.counts[ui * self.k + new_label as usize] += w;
            }
        }
        self.labels[vi] = new_label;
    }

    /// Exact embedding snapshot under the given options.
    pub fn snapshot(&self, opts: &GeeOptions) -> Dense {
        let n = self.n();
        let k = self.k;
        let inv_nk: Vec<f64> = self.n_k.iter().map(|&c| safe_recip(c)).collect();
        let mut z = Dense::zeros(n, k);

        if opts.laplacian {
            // one pass over the adjacency list with degree scaling
            let mut deg = self.degrees.clone();
            if opts.diagonal {
                for d in deg.iter_mut() {
                    *d += 1.0;
                }
            }
            let s: Vec<f64> = deg.iter().map(|&d| safe_recip_sqrt(d)).collect();
            for v in 0..n {
                let row = z.row_mut(v);
                for &(u, w) in &self.adj[v] {
                    let ui = u as usize;
                    let lu = self.labels[ui];
                    if lu >= 0 {
                        row[lu as usize] += w * s[v] * s[ui] * inv_nk[lu as usize];
                    }
                }
                // adj double-stores proper edges but self loops only once,
                // which matches the degree convention already.
            }
            if opts.diagonal {
                for v in 0..n {
                    let l = self.labels[v];
                    if l >= 0 {
                        *z.get_mut(v, l as usize) += s[v] * s[v] * inv_nk[l as usize];
                    }
                }
            }
        } else {
            for v in 0..n {
                let row = z.row_mut(v);
                let base = v * k;
                for c in 0..k {
                    row[c] = self.counts[base + c] * inv_nk[c];
                }
            }
            if opts.diagonal {
                for v in 0..n {
                    let l = self.labels[v];
                    if l >= 0 {
                        *z.get_mut(v, l as usize) += inv_nk[l as usize];
                    }
                }
            }
        }

        if opts.correlation {
            normalize_rows(&mut z);
        }
        z
    }

    /// Export the current state as a plain graph (for checkpointing and
    /// the equality tests).
    pub fn to_graph(&self) -> Graph {
        let n = self.n();
        let mut g = Graph::new(n, self.k);
        g.labels = self.labels.clone();
        for v in 0..n {
            for &(u, w) in &self.adj[v] {
                // emit each proper edge once (from its lower endpoint's
                // list the first time we see it with u >= v)
                if u as usize >= v {
                    g.add_edge(v as u32, u, w);
                }
            }
        }
        // adj double-stores proper edges: (v,u) appears in v's list and u's
        // list; the filter above keeps exactly one copy. Self loops stored
        // once and kept once.
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::Engine;
    use crate::util::rng::Rng;

    fn check_all_combos(s: &StreamingGee) {
        let g = s.to_graph();
        for opts in GeeOptions::table_order() {
            let batch = Engine::Sparse.embed(&g, &opts).unwrap();
            let stream = s.snapshot(&opts);
            assert!(
                batch.max_abs_diff(&stream) < 1e-10,
                "streaming != batch at {:?}: {}",
                opts,
                batch.max_abs_diff(&stream)
            );
        }
    }

    #[test]
    fn matches_batch_after_edge_stream() {
        let mut g = Graph::new(30, 3);
        let mut rng = Rng::new(301);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        let mut s = StreamingGee::new(&g);
        for _ in 0..150 {
            s.add_edge(rng.below(30) as u32, rng.below(30) as u32, rng.f64() + 0.1);
        }
        check_all_combos(&s);
    }

    #[test]
    fn matches_batch_after_vertex_growth() {
        let mut g = Graph::new(10, 3);
        let mut rng = Rng::new(302);
        for l in g.labels.iter_mut() {
            *l = rng.below(3) as i32;
        }
        let mut s = StreamingGee::new(&g);
        for i in 0..40 {
            if i % 3 == 0 {
                let lbl = if i % 6 == 0 { -1 } else { rng.below(3) as i32 };
                s.add_vertex(lbl);
            }
            let n = s.n();
            s.add_edge(rng.below(n) as u32, rng.below(n) as u32, 1.0);
        }
        check_all_combos(&s);
    }

    #[test]
    fn matches_batch_after_relabels() {
        let mut g = Graph::new(25, 4);
        let mut rng = Rng::new(303);
        for l in g.labels.iter_mut() {
            *l = rng.below(4) as i32;
        }
        for _ in 0..80 {
            g.add_edge(rng.below(25) as u32, rng.below(25) as u32, rng.f64() + 0.1);
        }
        let mut s = StreamingGee::new(&g);
        for _ in 0..30 {
            let v = rng.below(25) as u32;
            let new = (rng.below(5) as i32) - 1; // includes -1
            s.relabel(v, new);
        }
        check_all_combos(&s);
    }

    #[test]
    fn arbitrary_negative_labels_normalize_to_unlabeled() {
        // regression (ISSUE 3): `-7` used to be stored verbatim, leaking a
        // non-canonical unlabeled sentinel into to_graph()
        let mut g = Graph::new(4, 3);
        g.labels = vec![0, 1, 2, 0];
        g.add_edge(0, 1, 1.0);
        let mut s = StreamingGee::new(&g);
        let v = s.add_vertex(-7);
        s.add_edge(v, 0, 2.0);
        s.relabel(1, -9);
        let out = s.to_graph();
        assert_eq!(out.labels[v as usize], -1, "add_vertex(-7) must store -1");
        assert_eq!(out.labels[1], -1, "relabel(-9) must store -1");
        assert!(out.validate().is_ok());
        // n_k bookkeeping stayed consistent: snapshot == batch everywhere
        check_all_combos(&s);
        // and relabeling back from the normalized sentinel still works
        s.relabel(v, 2);
        assert_eq!(s.to_graph().labels[v as usize], 2);
        check_all_combos(&s);
    }

    #[test]
    fn self_loops_in_stream() {
        let mut g = Graph::new(8, 2);
        g.labels = vec![0, 0, 1, 1, 0, 1, 0, 1];
        let mut s = StreamingGee::new(&g);
        s.add_edge(3, 3, 2.5);
        s.add_edge(0, 3, 1.0);
        s.add_edge(3, 3, 0.5);
        check_all_combos(&s);
    }

    #[test]
    fn snapshot_is_pure() {
        let mut g = Graph::new(12, 2);
        g.labels = (0..12).map(|i| (i % 2) as i32).collect();
        let mut s = StreamingGee::new(&g);
        s.add_edge(0, 1, 1.0);
        let a = s.snapshot(&GeeOptions::ALL);
        let b = s.snapshot(&GeeOptions::ALL);
        assert!(a.max_abs_diff(&b) == 0.0);
    }

    #[test]
    fn edges_seen_counter() {
        let g = Graph::new(5, 2);
        let mut s = StreamingGee::new(&g);
        s.add_edge(0, 1, 1.0);
        s.add_edge(1, 2, 1.0);
        assert_eq!(s.edges_seen, 2);
    }
}
