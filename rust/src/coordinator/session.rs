//! Resident embedding sessions: O(Δ) incremental GEE.
//!
//! The batch lanes build a [`Graph`], embed once, and drop everything.
//! A [`GeeSession`] instead stays resident: it owns a mutable adjacency
//! ([`RowStore`]), incrementally-maintained globals
//! ([`Globals`]: `n_k` + degrees), the embedding matrix `Z`, and a
//! coalescing [`DirtySet`] of rows whose stored inputs changed. Applying
//! an edge insert/delete dirties exactly the two endpoint rows (plus
//! their neighbors under the laplacian option, whose scale entries
//! shifted); a relabel dirties the members of the two affected classes
//! and their neighbors — or escalates to a full rescale pass when the
//! affected fraction crosses the configurable threshold, because at that
//! point one sweep is cheaper than chasing per-row invalidation.
//!
//! [`GeeSession::refresh`] recomputes only the dirty rows, each through
//! the same [`AccumCtx`]/[`accumulate_rows`] kernel dispatch the batch
//! engines ride (hub rows still segment-split inside `rows_loop`), with
//! a one-row CSR window over the stored row. Because the row store
//! preserves the batch CSR accumulation order ([`RowStore`] docs), the
//! maintained class counts are exact whole numbers, and degrees are
//! re-summed in row order, a refreshed row is **bitwise identical** to
//! the same row of a from-scratch `sparse-fast` embed of the final graph
//! — pinned by the drift tests below and `tests/session_churn.rs`.
//!
//! [`SessionRegistry`] is the serving shell: sessions live under ids,
//! per-tenant session quotas ride a dedicated
//! [`TenantGovernor`], and a background fast-lane worker pool drains a
//! queue of dirty session ids so wire threads only apply deltas and
//! enqueue — reads see a bounded-staleness watermark instead of a stall.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::{AdmitError, BoundedQueue, TenantGovernor, TenantPermit};
use crate::gee::globals::{DirtySet, Globals};
use crate::gee::kernel::{accumulate_rows, AccumCtx};
use crate::gee::GeeOptions;
use crate::graph::rowstore::RowStore;
use crate::graph::Graph;
use crate::sparse::Dense;

/// How a session embeds and when it abandons per-row refresh.
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// Option grid point the resident `Z` is maintained under.
    pub opts: GeeOptions,
    /// When one delta's affected-row fraction exceeds this, the session
    /// escalates to a full rescale pass instead of per-row invalidation
    /// (relabel storms; large classes). 0.0 forces every relabel to a
    /// full pass, 1.0 never escalates.
    pub rescale_threshold: f64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { opts: GeeOptions::NONE, rescale_threshold: 0.25 }
    }
}

/// One incremental mutation of a session's graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Delta {
    /// Add an undirected edge (self-loops allowed).
    Insert { a: u32, b: u32, w: f64 },
    /// Remove the oldest stored edge between the endpoints.
    Delete { a: u32, b: u32 },
    /// Reassign vertex `v` to `label` (-1 = unlabeled).
    Relabel { v: u32, label: i32 },
}

/// What one [`GeeSession::refresh`] pass did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RefreshOutcome {
    /// Rows recomputed through the kernel.
    pub rows: usize,
    /// Whether this was a full rescale pass rather than per-row refresh.
    pub full: bool,
}

/// A resident embedding: mutable adjacency + incremental globals + `Z`.
#[derive(Debug)]
pub struct GeeSession {
    store: RowStore,
    labels: Vec<i32>,
    k: usize,
    globals: Globals,
    opts: GeeOptions,
    rescale_threshold: f64,
    /// Per-vertex weight values `1/n_k[y]`; rebuilt lazily after relabels.
    wv: Vec<f64>,
    wv_stale: bool,
    /// Laplacian scale vector, maintained eagerly (empty when !lap).
    scale: Vec<f64>,
    z: Dense,
    dirty: DirtySet,
    /// Deltas applied since open.
    applied: u64,
    /// Watermark: `applied` as of the last completed refresh.
    clean: u64,
    // refresh scratch (kept warm across refreshes)
    scratch_cols: Vec<u32>,
    scratch_vals: Vec<f64>,
    csr_indptr: Vec<u32>,
    csr_cols: Vec<u32>,
    csr_vals: Vec<f64>,
}

impl GeeSession {
    /// Open a session over `g` (the session replays `g`'s edge list, so
    /// its canonical order is the graph's) and compute the initial `Z`.
    pub fn from_graph(g: &Graph, cfg: &SessionConfig) -> Self {
        let store = RowStore::from_graph(g);
        let mut globals = Globals::new(g.n, g.k);
        globals.recount_labels(&g.labels, g.k);
        for (v, d) in globals.deg.iter_mut().enumerate() {
            *d = store.resum_degree(v);
        }
        let mut s = GeeSession {
            store,
            labels: g.labels.clone(),
            k: g.k,
            globals,
            opts: cfg.opts,
            rescale_threshold: cfg.rescale_threshold.clamp(0.0, 1.0),
            wv: Vec::new(),
            wv_stale: true,
            scale: Vec::new(),
            z: Dense::zeros(g.n, g.k),
            dirty: DirtySet::new(g.n),
            applied: 0,
            clean: 0,
            scratch_cols: Vec::new(),
            scratch_vals: Vec::new(),
            csr_indptr: Vec::new(),
            csr_cols: Vec::new(),
            csr_vals: Vec::new(),
        };
        s.dirty.mark_all();
        s.refresh();
        s
    }

    /// Vertex count.
    pub fn n(&self) -> usize {
        self.store.n()
    }

    /// Class count.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Undirected stored-edge count.
    pub fn num_edges(&self) -> usize {
        self.store.num_edges()
    }

    /// The option grid point this session maintains `Z` under.
    pub fn opts(&self) -> &GeeOptions {
        &self.opts
    }

    /// The resident embedding. Rows marked dirty since the last
    /// [`refresh`](Self::refresh) are stale; check [`stale`](Self::stale).
    pub fn z(&self) -> &Dense {
        &self.z
    }

    /// `(applied, clean)` delta watermarks: `clean` is the value of
    /// `applied` as of the last completed refresh.
    pub fn watermark(&self) -> (u64, u64) {
        (self.applied, self.clean)
    }

    /// Deltas applied but not yet reflected in `Z`.
    pub fn stale(&self) -> u64 {
        self.applied - self.clean
    }

    /// Individually-dirty row count (0 when a full pass is pending).
    pub fn dirty_rows(&self) -> usize {
        self.dirty.len()
    }

    /// Materialize the current graph — the parity-oracle bridge: a
    /// from-scratch `sparse-fast` embed of this graph is bitwise what
    /// [`refresh`](Self::refresh) maintains.
    pub fn to_graph(&self) -> Graph {
        self.store.to_graph(&self.labels, self.k)
    }

    /// Apply one delta. On error the session state is unchanged.
    pub fn apply(&mut self, d: &Delta) -> Result<(), String> {
        match *d {
            Delta::Insert { a, b, w } => {
                self.check_vertex(a)?;
                self.check_vertex(b)?;
                if !w.is_finite() {
                    return Err(format!("edge weight {w} is not finite"));
                }
                self.store.insert(a, b, w);
                self.touch_endpoint(a);
                self.touch_endpoint(b);
            }
            Delta::Delete { a, b } => {
                self.check_vertex(a)?;
                self.check_vertex(b)?;
                if self.store.remove(a, b).is_none() {
                    return Err(format!("no stored edge ({a}, {b})"));
                }
                self.touch_endpoint(a);
                self.touch_endpoint(b);
            }
            Delta::Relabel { v, label } => {
                self.check_vertex(v)?;
                if label < -1 || label >= self.k as i32 {
                    return Err(format!("label {label} out of range for k={}", self.k));
                }
                let old = self.labels[v as usize];
                if old != label {
                    self.globals.relabel(old, label);
                    self.labels[v as usize] = label;
                    self.wv_stale = true;
                    self.dirty_after_relabel(v, old, label);
                }
            }
        }
        self.applied += 1;
        Ok(())
    }

    /// Apply deltas in order, stopping at the first failure; returns how
    /// many applied either way (the prefix before the failure sticks).
    pub fn apply_all(&mut self, ds: &[Delta]) -> (usize, Result<(), String>) {
        for (i, d) in ds.iter().enumerate() {
            if let Err(e) = self.apply(d) {
                return (i, Err(format!("delta {i}: {e}")));
            }
        }
        (ds.len(), Ok(()))
    }

    fn check_vertex(&self, v: u32) -> Result<(), String> {
        if (v as usize) < self.store.n() {
            Ok(())
        } else {
            Err(format!("vertex {v} out of range (n={})", self.store.n()))
        }
    }

    /// Degree bookkeeping + dirty marks after an edge touched `v`. The
    /// degree is *re-summed* in row order, not adjusted: a mid-sequence
    /// removal changes the FP fold, so only a resum stays bitwise equal
    /// to a fresh prepare.
    fn touch_endpoint(&mut self, v: u32) {
        self.globals.deg[v as usize] = self.store.resum_degree(v as usize);
        if self.opts.laplacian {
            if !self.scale.is_empty() {
                self.scale[v as usize] = self.globals.scale_at(v as usize, &self.opts);
            }
            // neighbors read s[v] in their own rows
            self.mark_neighbors(v);
        }
        self.dirty.mark(v);
    }

    fn mark_neighbors(&mut self, v: u32) {
        for e in self.store.row(v as usize) {
            self.dirty.mark(e.nbr);
        }
    }

    /// Dirty propagation for a relabel: `wv` changed for every member of
    /// the two affected classes, so every row with such a member as a
    /// neighbor must refresh. Escalate to a full pass when the affected
    /// classes cover more than `rescale_threshold` of the graph.
    fn dirty_after_relabel(&mut self, v: u32, old: i32, new: i32) {
        let mut affected = 1.0;
        if old >= 0 {
            affected += self.globals.n_k[old as usize];
        }
        if new >= 0 {
            affected += self.globals.n_k[new as usize];
        }
        if affected > self.rescale_threshold * self.store.n() as f64 {
            self.dirty.mark_all();
            return;
        }
        self.dirty.mark(v);
        self.mark_neighbors(v);
        for u in 0..self.labels.len() {
            let l = self.labels[u];
            if (l == old || l == new) && l >= 0 {
                self.dirty.mark(u as u32);
                self.mark_neighbors(u as u32);
            }
        }
    }

    /// Recompute every stale row and advance the clean watermark. Falls
    /// back to one full rescale pass when a delta escalated (or when the
    /// dirty set alone crosses the threshold — at that point one sweep
    /// beats per-row bookkeeping).
    pub fn refresh(&mut self) -> RefreshOutcome {
        if self.dirty.is_empty() {
            self.clean = self.applied;
            return RefreshOutcome::default();
        }
        let n = self.store.n();
        let full =
            self.dirty.is_all() || self.dirty.len() as f64 > self.rescale_threshold * n as f64;
        let outcome = if full {
            self.refresh_full();
            RefreshOutcome { rows: n, full: true }
        } else {
            if self.wv_stale {
                self.globals.weight_values_into(&self.labels, &mut self.wv);
                self.wv_stale = false;
            }
            if self.opts.laplacian && self.scale.is_empty() {
                self.globals.scale_into(&self.opts, &mut self.scale);
            }
            let rows = self.dirty.len();
            for i in 0..rows {
                let r = self.dirty.rows()[i];
                self.refresh_row(r as usize);
            }
            RefreshOutcome { rows, full: false }
        };
        self.dirty.clear();
        self.clean = self.applied;
        outcome
    }

    /// One full rescale pass: export the CSR snapshot, rebuild weights
    /// and scale from the maintained globals, and run the whole-graph
    /// kernel — the exact `embed_fused_into` sequence, so the result is
    /// bitwise a from-scratch `sparse-fast` embed.
    fn refresh_full(&mut self) {
        let n = self.store.n();
        self.store.export_csr(&mut self.csr_indptr, &mut self.csr_cols, &mut self.csr_vals);
        for (v, d) in self.globals.deg.iter_mut().enumerate() {
            *d = self.store.resum_degree(v);
        }
        self.globals.weight_values_into(&self.labels, &mut self.wv);
        self.wv_stale = false;
        if self.opts.laplacian {
            self.globals.scale_into(&self.opts, &mut self.scale);
        }
        self.z.nrows = n;
        self.z.ncols = self.k;
        crate::gee::workspace::reset_f64(&mut self.z.data, n * self.k);
        let ctx = AccumCtx {
            indptr: &self.csr_indptr,
            row_base: 0,
            cols: &self.csr_cols,
            vals: &self.csr_vals,
            labels: &self.labels,
            wv: &self.wv,
            k: self.k,
        };
        let scale = if self.opts.laplacian { Some(self.scale.as_slice()) } else { None };
        accumulate_rows(&ctx, &self.opts, 0, n, scale, &mut self.z.data);
    }

    /// Recompute one row through the kernel dispatch with a one-row CSR
    /// window: `indptr = [0, len]`, `row_base = r`, cols/vals sliced to
    /// the stored row. Globals (labels, wv, scale) stay globally indexed,
    /// so the kernel runs the identical FP sequence the full pass would
    /// for this row — including hub segment-splitting and the
    /// diag/cor epilogue, which live inside `rows_loop`.
    fn refresh_row(&mut self, r: usize) {
        self.scratch_cols.clear();
        self.scratch_vals.clear();
        for e in self.store.row(r) {
            self.scratch_cols.push(e.nbr);
            self.scratch_vals.push(e.w);
        }
        let indptr = [0u32, self.scratch_cols.len() as u32];
        let ctx = AccumCtx {
            indptr: &indptr,
            row_base: r,
            cols: &self.scratch_cols,
            vals: &self.scratch_vals,
            labels: &self.labels,
            wv: &self.wv,
            k: self.k,
        };
        let scale = if self.opts.laplacian { Some(self.scale.as_slice()) } else { None };
        let zrow = &mut self.z.data[r * self.k..(r + 1) * self.k];
        zrow.fill(0.0);
        accumulate_rows(&ctx, &self.opts, r, r + 1, scale, zrow);
    }
}

// ------------------------------------------------------------- registry

/// Why a session could not be opened.
#[derive(Debug)]
pub enum OpenError {
    /// Per-tenant session quota or registry shutdown.
    Admission(AdmitError),
    /// The offered graph was invalid.
    Invalid(String),
}

/// One registered session: the lock-guarded state plus its queue flag
/// and the tenant quota permit held for the session's lifetime.
pub struct SessionEntry {
    /// Registry-unique session id (wire `sess=`).
    pub id: u64,
    /// Owning tenant.
    pub tenant: String,
    /// The resident session; wire threads apply deltas and read rows
    /// under this lock, the fast-lane workers refresh under it.
    pub session: Mutex<GeeSession>,
    queued: AtomicBool,
    _permit: TenantPermit,
}

/// Session registry + background fast-lane refresh workers.
///
/// Wire threads apply deltas under the session lock, then
/// [`enqueue_refresh`](Self::enqueue_refresh): the `queued` flag
/// coalesces enqueues, so a session appears in the drain queue at most
/// once no matter how many delta batches land before a worker gets to
/// it (the Mira pending-embeddings shape: pending work queued, batched
/// by a background worker, stored for query).
pub struct SessionRegistry {
    next_id: AtomicU64,
    sessions: Mutex<HashMap<u64, Arc<SessionEntry>>>,
    governor: Arc<TenantGovernor>,
    queue: Arc<BoundedQueue<u64>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    metrics: Arc<Metrics>,
}

impl SessionRegistry {
    /// Start the registry with `workers` fast-lane threads and a
    /// per-tenant open-session quota.
    pub fn start(workers: usize, session_quota: usize, metrics: Arc<Metrics>) -> Arc<Self> {
        let reg = Arc::new(SessionRegistry {
            next_id: AtomicU64::new(1),
            sessions: Mutex::new(HashMap::new()),
            governor: TenantGovernor::new(session_quota.max(1)),
            queue: Arc::new(BoundedQueue::new(4096)),
            workers: Mutex::new(Vec::new()),
            metrics,
        });
        let mut handles = reg.workers.lock().unwrap();
        for i in 0..workers.max(1) {
            let r = Arc::clone(&reg);
            handles.push(
                thread::Builder::new()
                    .name(format!("gee-session-{i}"))
                    .spawn(move || r.worker_loop())
                    .expect("spawn session worker"),
            );
        }
        drop(handles);
        reg
    }

    fn worker_loop(&self) {
        while let Some(sid) = self.queue.pop() {
            let entry = self.sessions.lock().unwrap().get(&sid).cloned();
            let Some(entry) = entry else { continue };
            // clear before refreshing: deltas landing mid-refresh re-enqueue
            entry.queued.store(false, Ordering::SeqCst);
            let outcome = entry.session.lock().unwrap().refresh();
            self.metrics.session_refreshes.fetch_add(1, Ordering::Relaxed);
            self.metrics.session_rows_refreshed.fetch_add(outcome.rows as u64, Ordering::Relaxed);
            if outcome.full {
                self.metrics.session_full_rescales.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Open a session for `tenant` over `g`, charging its session quota
    /// for the session's lifetime.
    pub fn open(
        &self,
        tenant: &str,
        g: &Graph,
        cfg: &SessionConfig,
    ) -> Result<Arc<SessionEntry>, OpenError> {
        g.validate().map_err(OpenError::Invalid)?;
        let permit = self.governor.try_admit(tenant).map_err(OpenError::Admission)?;
        let session = GeeSession::from_graph(g, cfg);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let entry = Arc::new(SessionEntry {
            id,
            tenant: tenant.to_string(),
            session: Mutex::new(session),
            queued: AtomicBool::new(false),
            _permit: permit,
        });
        self.sessions.lock().unwrap().insert(id, Arc::clone(&entry));
        self.metrics.sessions_opened.fetch_add(1, Ordering::Relaxed);
        Ok(entry)
    }

    /// Look up a live session.
    pub fn get(&self, id: u64) -> Option<Arc<SessionEntry>> {
        self.sessions.lock().unwrap().get(&id).cloned()
    }

    /// Close (unregister) a session; its quota permit releases once the
    /// last in-flight reference drops. Returns whether it existed.
    pub fn close(&self, id: u64) -> bool {
        let removed = self.sessions.lock().unwrap().remove(&id).is_some();
        if removed {
            self.metrics.sessions_closed.fetch_add(1, Ordering::Relaxed);
        }
        removed
    }

    /// Queue `entry` for a fast-lane refresh (coalesced: at most one
    /// pending drain per session).
    pub fn enqueue_refresh(&self, entry: &SessionEntry) {
        if !entry.queued.swap(true, Ordering::SeqCst)
            && self.queue.push(entry.id).is_err()
        {
            // registry shutting down; leave the session readable as-is
            entry.queued.store(false, Ordering::SeqCst);
        }
    }

    /// Count deltas toward the serve summary.
    pub fn note_deltas(&self, count: u64) {
        self.metrics.session_deltas.fetch_add(count, Ordering::Relaxed);
    }

    /// Live session count.
    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// No live sessions?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop the fast-lane workers (idempotent). Live sessions stay
    /// readable; pending refreshes after close are abandoned.
    pub fn shutdown(&self) {
        self.queue.close();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::sparse_gee::SparseGee;
    use crate::graph::sbm::{generate_sbm, SbmParams};
    use crate::util::rng::Rng;

    fn assert_bitwise(a: &Dense, b: &Dense, what: &str) {
        assert_eq!((a.nrows, a.ncols), (b.nrows, b.ncols), "{what}: shape");
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "{what}: cell {i} differs: {x:e} vs {y:e}"
            );
        }
    }

    fn oracle(s: &GeeSession) -> Dense {
        SparseGee::fast().embed(&s.to_graph(), s.opts())
    }

    fn random_delta(rng: &mut Rng, n: u32, k: usize, live: &mut Vec<(u32, u32)>) -> Delta {
        let roll = rng.f64();
        if roll < 0.45 || live.is_empty() {
            let (a, b) = (rng.below(n as usize) as u32, rng.below(n as usize) as u32);
            live.push((a, b));
            Delta::Insert { a, b, w: 1.0 + rng.f64() }
        } else if roll < 0.8 {
            let (a, b) = live.swap_remove(rng.below(live.len()));
            Delta::Delete { a, b }
        } else {
            Delta::Relabel {
                v: rng.below(n as usize) as u32,
                label: rng.below(k + 1) as i32 - 1,
            }
        }
    }

    #[test]
    fn drift_refresh_is_bitwise_across_option_grid() {
        let g = generate_sbm(&SbmParams::paper(220), 97);
        for opts in GeeOptions::table_order() {
            let cfg = SessionConfig { opts, rescale_threshold: 0.25 };
            let mut s = GeeSession::from_graph(&g, &cfg);
            assert_bitwise(s.z(), &SparseGee::fast().embed(&g, &opts), "initial");
            let mut rng = Rng::new(5 + opts.code().len() as u64);
            let mut live: Vec<(u32, u32)> =
                (0..g.src.len()).map(|i| (g.src[i], g.dst[i])).collect();
            for round in 0..12 {
                for _ in 0..20 {
                    let d = random_delta(&mut rng, g.n as u32, g.k, &mut live);
                    s.apply(&d).unwrap();
                }
                s.refresh();
                assert_eq!(s.stale(), 0);
                assert_bitwise(s.z(), &oracle(&s), &format!("{} round {round}", opts.code()));
            }
        }
    }

    #[test]
    fn rescale_threshold_governs_escalation_and_stays_bitwise() {
        let g = generate_sbm(&SbmParams::paper(150), 3);
        // threshold 0: every delta escalates to a full rescale pass
        let cfg = SessionConfig { opts: GeeOptions::ALL, rescale_threshold: 0.0 };
        let mut s = GeeSession::from_graph(&g, &cfg);
        s.apply(&Delta::Relabel { v: 3, label: 0 }).unwrap();
        let out = s.refresh();
        assert!(out.full, "threshold 0 must escalate to a full pass");
        assert_bitwise(s.z(), &oracle(&s), "post full rescale");
        // threshold 1: nothing escalates — even relabels refresh per-row
        let cfg = SessionConfig { opts: GeeOptions::ALL, rescale_threshold: 1.0 };
        let mut s = GeeSession::from_graph(&g, &cfg);
        s.apply(&Delta::Insert { a: 1, b: 2, w: 1.5 }).unwrap();
        let out = s.refresh();
        assert!(!out.full && out.rows >= 2, "edge delta must stay per-row: {out:?}");
        assert_bitwise(s.z(), &oracle(&s), "post per-row insert");
        s.apply(&Delta::Relabel { v: 3, label: 1 }).unwrap();
        let out = s.refresh();
        assert!(!out.full, "threshold 1 never escalates");
        assert_bitwise(s.z(), &oracle(&s), "post per-row relabel");
    }

    #[test]
    fn apply_errors_leave_state_unchanged() {
        let mut g = Graph::new(5, 2);
        g.labels = vec![0, 0, 1, 1, -1];
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(3, 3, 0.5);
        let mut s = GeeSession::from_graph(&g, &SessionConfig::default());
        let before = s.z().data.clone();
        let (applied, _) = s.watermark();
        let n = s.n() as u32;
        for bad in [
            Delta::Insert { a: n, b: 0, w: 1.0 },
            Delta::Insert { a: 0, b: n + 7, w: 1.0 },
            Delta::Insert { a: 0, b: 1, w: f64::NAN },
            Delta::Insert { a: 0, b: 1, w: f64::INFINITY },
            Delta::Delete { a: 0, b: n },
            Delta::Delete { a: 0, b: 3 }, // in range, but no such edge
            Delta::Relabel { v: n, label: 0 },
            Delta::Relabel { v: 0, label: g.k as i32 },
            Delta::Relabel { v: 0, label: -2 },
        ] {
            assert!(s.apply(&bad).is_err(), "{bad:?} must be rejected");
        }
        assert_eq!(s.watermark().0, applied, "failed deltas must not advance the watermark");
        assert_eq!(s.num_edges(), 3);
        s.refresh();
        assert!(s.z().data.iter().zip(&before).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn apply_all_keeps_prefix_and_reports_index() {
        let g = generate_sbm(&SbmParams::paper(50), 13);
        let mut s = GeeSession::from_graph(&g, &SessionConfig::default());
        let n = s.n() as u32;
        let ds = [
            Delta::Insert { a: 0, b: 1, w: 1.0 },
            Delta::Insert { a: n, b: 1, w: 1.0 },
            Delta::Insert { a: 2, b: 3, w: 1.0 },
        ];
        let (applied, res) = s.apply_all(&ds);
        assert_eq!(applied, 1);
        assert!(res.unwrap_err().starts_with("delta 1:"));
        assert_eq!(s.stale(), 1);
        s.refresh();
        assert_bitwise(s.z(), &oracle(&s), "after partial batch");
    }

    #[test]
    fn watermarks_track_refresh() {
        let g = generate_sbm(&SbmParams::paper(40), 17);
        let mut s = GeeSession::from_graph(&g, &SessionConfig::default());
        assert_eq!(s.watermark(), (0, 0));
        s.apply(&Delta::Insert { a: 0, b: 1, w: 1.0 }).unwrap();
        s.apply(&Delta::Delete { a: 0, b: 1 }).unwrap();
        assert_eq!(s.watermark(), (2, 0));
        assert_eq!(s.stale(), 2);
        s.refresh();
        assert_eq!(s.watermark(), (2, 2));
    }

    #[test]
    fn registry_fast_lane_drains_to_bitwise_clean() {
        let metrics = Arc::new(Metrics::default());
        let reg = SessionRegistry::start(2, 4, Arc::clone(&metrics));
        let g = generate_sbm(&SbmParams::paper(120), 29);
        let entry = reg
            .open("default", &g, &SessionConfig { opts: GeeOptions::ALL, rescale_threshold: 0.25 })
            .unwrap();
        let mut rng = Rng::new(31);
        let mut live: Vec<(u32, u32)> = (0..g.src.len()).map(|i| (g.src[i], g.dst[i])).collect();
        for _ in 0..40 {
            let d = random_delta(&mut rng, g.n as u32, g.k, &mut live);
            entry.session.lock().unwrap().apply(&d).unwrap();
            reg.enqueue_refresh(&entry);
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            let stale = entry.session.lock().unwrap().stale();
            if stale == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "fast lane never drained");
            thread::sleep(std::time::Duration::from_millis(5));
        }
        {
            let s = entry.session.lock().unwrap();
            assert_bitwise(s.z(), &oracle(&s), "registry drain");
        }
        assert!(metrics.session_refreshes.load(Ordering::Relaxed) > 0);
        assert_eq!(reg.len(), 1);
        assert!(reg.close(entry.id));
        assert!(!reg.close(entry.id));
        assert!(reg.get(entry.id).is_none());
        reg.shutdown();
    }

    #[test]
    fn session_quota_rides_the_governor() {
        let metrics = Arc::new(Metrics::default());
        let reg = SessionRegistry::start(1, 2, metrics);
        let g = generate_sbm(&SbmParams::paper(30), 41);
        let cfg = SessionConfig::default();
        let a = reg.open("t1", &g, &cfg).unwrap();
        let _b = reg.open("t1", &g, &cfg).unwrap();
        match reg.open("t1", &g, &cfg) {
            Err(OpenError::Admission(AdmitError::OverQuota)) => {}
            Err(e) => panic!("expected quota refusal, got {e:?}"),
            Ok(_) => panic!("expected quota refusal, got a session"),
        }
        // other tenants unaffected; closing frees the slot
        let _c = reg.open("t2", &g, &cfg).unwrap();
        let id = a.id;
        drop(a);
        assert!(reg.close(id));
        let _d = reg.open("t1", &g, &cfg).unwrap();
        reg.shutdown();
    }
}
