//! Service metrics: lock-free counters + log-bucketed latency histogram
//! with p50/p95/p99 extraction — what `serve_embeddings` reports and
//! EXPERIMENTS.md records.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::shard::codec::ByteCounters;

/// Number of log-spaced latency buckets: bucket i covers
/// [2^i, 2^(i+1)) microseconds. The top bucket (i = 39) additionally
/// absorbs everything ≥ 2^39 µs ≈ 6.4 days, so `latency_quantile` can
/// report at most its upper bound 2^40 µs ≈ 12.7 days — far beyond any
/// real request, which is the point: no observable latency overflows the
/// histogram.
const BUCKETS: usize = 40;

/// Thread-safe metrics sink.
#[derive(Debug)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Total vertices and directed edges processed (throughput numerators).
    pub vertices: AtomicU64,
    pub edges: AtomicU64,
    /// Oversize jobs meant for the remote shard fleet that fell back to
    /// the local sharded engine because the whole fleet was unreachable —
    /// a nonzero value is the "fleet is down" alarm.
    pub remote_fallbacks: AtomicU64,
    /// Total bytes moved over the shard-fleet wire (sent + received,
    /// across all fleet jobs). The binary wire's traffic win is a number
    /// here, not an anecdote — and a regression back toward text-sized
    /// volumes (or toward O(shards·n) global resends) shows up as this
    /// counter growing out of proportion to `edges`.
    pub remote_bytes: AtomicU64,
    /// Resident-session lane: sessions opened/closed over the process
    /// lifetime, deltas applied, fast-lane refresh passes and the rows
    /// they recomputed, and how many passes escalated to a full rescale
    /// (per-delta cost regressing toward full re-embeds shows up as
    /// `session_full_rescales` tracking `session_refreshes`).
    pub sessions_opened: AtomicU64,
    pub sessions_closed: AtomicU64,
    pub session_deltas: AtomicU64,
    pub session_refreshes: AtomicU64,
    pub session_rows_refreshed: AtomicU64,
    pub session_full_rescales: AtomicU64,
    /// Iterative lane: self-clustering jobs completed (`ITER2` /
    /// `submit_admitted_iter`) and total embed→kmeans→relabel rounds
    /// they ran — rounds far outpacing jobs means the loop is not
    /// converging within its caps.
    pub iter_jobs: AtomicU64,
    pub iter_rounds: AtomicU64,
    /// Accepted connections dropped because the header budget expired
    /// while the peer sat silent between requests (idle reap) or stalled
    /// partway through a request (slow-loris / mid-body stall). Steady
    /// growth under normal traffic means the `header`/`frame` budgets
    /// are too tight; growth during an incident is the wire defending
    /// itself.
    pub wire_idle_reaps: AtomicU64,
    pub wire_loris_drops: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
    /// Per-tenant admission counters, created lazily on first touch
    /// (tenants are declared on the wire in HELLO; v1 text clients land
    /// in the "default" bucket).
    tenants: Mutex<HashMap<String, Arc<TenantCounters>>>,
}

/// Admission-control counters for one tenant. `bytes` is shared with the
/// connection's [`CountingReader`](crate::shard::codec::CountingReader)/
/// [`CountingWriter`](crate::shard::codec::CountingWriter) wrappers, so
/// wire traffic is attributed per tenant without any per-write locking.
#[derive(Debug, Default)]
pub struct TenantCounters {
    pub admitted: AtomicU64,
    pub rejected_quota: AtomicU64,
    pub rejected_backpressure: AtomicU64,
    /// Cloned into each connection's counting stream wrappers.
    pub bytes: Arc<ByteCounters>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            vertices: AtomicU64::new(0),
            edges: AtomicU64::new(0),
            remote_fallbacks: AtomicU64::new(0),
            remote_bytes: AtomicU64::new(0),
            sessions_opened: AtomicU64::new(0),
            sessions_closed: AtomicU64::new(0),
            session_deltas: AtomicU64::new(0),
            session_refreshes: AtomicU64::new(0),
            session_rows_refreshed: AtomicU64::new(0),
            session_full_rescales: AtomicU64::new(0),
            iter_jobs: AtomicU64::new(0),
            iter_rounds: AtomicU64::new(0),
            wire_idle_reaps: AtomicU64::new(0),
            wire_loris_drops: AtomicU64::new(0),
            latency_us: std::array::from_fn(|_| AtomicU64::new(0)),
            latency_sum_us: AtomicU64::new(0),
            tenants: Mutex::new(HashMap::new()),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn bucket(us: u64) -> usize {
        (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1)
    }

    /// Record one completed request's latency.
    pub fn observe_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.latency_us[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Quantile over the histogram (0.0..=1.0), as an upper bucket bound.
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let counts: Vec<u64> = self
            .latency_us
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1u64 << BUCKETS)
    }

    /// Mean latency.
    pub fn latency_mean(&self) -> Duration {
        let n = self
            .latency_us
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum::<u64>();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.latency_sum_us.load(Ordering::Relaxed) / n)
    }

    /// One-line summary for logs. `remote_fallbacks` only appears when
    /// nonzero — it is the "shard fleet is down" alarm, so it must be
    /// visible in the log line operators actually read, not only in code.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "submitted={} completed={} failed={} rejected={} batches={} (avg fill {:.2}) p50={:?} p95={:?} p99={:?} mean={:?}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.avg_batch_fill(),
            self.latency_quantile(0.50),
            self.latency_quantile(0.95),
            self.latency_quantile(0.99),
            self.latency_mean(),
        );
        let fallbacks = self.remote_fallbacks.load(Ordering::Relaxed);
        if fallbacks > 0 {
            s.push_str(&format!(" remote_fallbacks={fallbacks} (shard fleet unreachable)"));
        }
        let remote_bytes = self.remote_bytes.load(Ordering::Relaxed);
        if remote_bytes > 0 {
            s.push_str(&format!(" remote_bytes={remote_bytes}"));
        }
        let opened = self.sessions_opened.load(Ordering::Relaxed);
        if opened > 0 {
            s.push_str(&format!(
                "\n  sessions: opened={opened} closed={} deltas={} refreshes={} rows_refreshed={} full_rescales={}",
                self.sessions_closed.load(Ordering::Relaxed),
                self.session_deltas.load(Ordering::Relaxed),
                self.session_refreshes.load(Ordering::Relaxed),
                self.session_rows_refreshed.load(Ordering::Relaxed),
                self.session_full_rescales.load(Ordering::Relaxed),
            ));
        }
        let iter_rounds = self.iter_rounds.load(Ordering::Relaxed);
        if iter_rounds > 0 {
            s.push_str(&format!(
                "\n  iter: jobs={} rounds={iter_rounds}",
                self.iter_jobs.load(Ordering::Relaxed),
            ));
        }
        let idle = self.wire_idle_reaps.load(Ordering::Relaxed);
        let loris = self.wire_loris_drops.load(Ordering::Relaxed);
        if idle > 0 || loris > 0 {
            s.push_str(&format!("\n  wire: idle_reaps={idle} loris_drops={loris}"));
        }
        for (name, tc) in self.tenant_snapshot() {
            s.push_str(&format!(
                "\n  tenant {name}: admitted={} rejected_quota={} rejected_backpressure={} bytes_in={} bytes_out={}",
                tc.admitted.load(Ordering::Relaxed),
                tc.rejected_quota.load(Ordering::Relaxed),
                tc.rejected_backpressure.load(Ordering::Relaxed),
                tc.bytes.received.load(Ordering::Relaxed),
                tc.bytes.sent.load(Ordering::Relaxed),
            ));
        }
        // which accumulation lanes this process's traffic actually hit
        // (process-global dispatch counters — `serve` and `shard-serve`
        // both report through here)
        let kernels = crate::gee::kernel::counters_snapshot().nonzero_line();
        if !kernels.is_empty() {
            s.push_str(&format!("\n  kernels: {kernels}"));
        }
        s
    }

    /// This tenant's counters, created on first touch. The returned Arc
    /// is stable for the tenant's lifetime, so connections hold it
    /// directly instead of re-locking the map per request.
    pub fn tenant(&self, name: &str) -> Arc<TenantCounters> {
        let mut map = self.tenants.lock().unwrap();
        if let Some(tc) = map.get(name) {
            return tc.clone();
        }
        let tc = Arc::new(TenantCounters::default());
        map.insert(name.to_string(), tc.clone());
        tc
    }

    /// Snapshot of all tenants seen so far, sorted by name (stable output
    /// for logs and tests).
    pub fn tenant_snapshot(&self) -> Vec<(String, Arc<TenantCounters>)> {
        let map = self.tenants.lock().unwrap();
        let mut rows: Vec<_> = map.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    pub fn avg_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(Metrics::bucket(1), 0);
        assert_eq!(Metrics::bucket(2), 1);
        assert_eq!(Metrics::bucket(3), 1);
        assert_eq!(Metrics::bucket(4), 2);
        assert_eq!(Metrics::bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_monotone() {
        let m = Metrics::new();
        for us in [10u64, 100, 1_000, 10_000, 100_000] {
            for _ in 0..20 {
                m.observe_latency(Duration::from_micros(us));
            }
        }
        let p50 = m.latency_quantile(0.5);
        let p95 = m.latency_quantile(0.95);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 >= Duration::from_micros(512)); // median bucket ≈ 1ms
        assert!(m.latency_mean() > Duration::ZERO);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.99), Duration::ZERO);
        assert_eq!(m.latency_mean(), Duration::ZERO);
    }

    #[test]
    fn overflow_bucket_absorbs_absurd_latencies() {
        // the top bucket starts at 2^39 µs ≈ 6.4 days; anything beyond
        // (here 20 days) must land there, and the quantile must report the
        // bucket's upper bound 2^40 µs ≈ 12.7 days rather than panic or
        // wrap (the old module comment claimed "≈ 18 minutes max")
        let m = Metrics::new();
        m.observe_latency(Duration::from_secs(20 * 86_400));
        m.observe_latency(Duration::from_micros(u64::MAX));
        assert_eq!(Metrics::bucket(20 * 86_400 * 1_000_000), BUCKETS - 1);
        let top = m.latency_quantile(0.99);
        assert_eq!(top, Duration::from_micros(1u64 << BUCKETS));
        assert!(top > Duration::from_secs(12 * 86_400));
        assert!(top < Duration::from_secs(13 * 86_400));
    }

    #[test]
    fn remote_bytes_surface_in_summary_only_when_nonzero() {
        let m = Metrics::new();
        assert!(!m.summary().contains("remote_bytes"));
        m.remote_bytes.fetch_add(12_345, Ordering::Relaxed);
        assert!(m.summary().contains("remote_bytes=12345"), "{}", m.summary());
    }

    #[test]
    fn session_counters_surface_in_summary_only_when_active() {
        let m = Metrics::new();
        assert!(!m.summary().contains("sessions:"));
        m.sessions_opened.fetch_add(2, Ordering::Relaxed);
        m.session_deltas.fetch_add(10, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("sessions: opened=2"), "{s}");
        assert!(s.contains("deltas=10"), "{s}");
    }

    #[test]
    fn iter_counters_surface_in_summary_only_when_active() {
        let m = Metrics::new();
        assert!(!m.summary().contains("iter:"));
        m.iter_jobs.fetch_add(1, Ordering::Relaxed);
        m.iter_rounds.fetch_add(5, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("iter: jobs=1 rounds=5"), "{s}");
    }

    #[test]
    fn tenant_counters_lazy_stable_and_in_summary() {
        let m = Metrics::new();
        assert!(!m.summary().contains("tenant "));
        let acme = m.tenant("acme");
        acme.admitted.fetch_add(3, Ordering::Relaxed);
        acme.rejected_quota.fetch_add(1, Ordering::Relaxed);
        acme.bytes.received.fetch_add(100, Ordering::Relaxed);
        acme.bytes.sent.fetch_add(250, Ordering::Relaxed);
        // second lookup returns the same counters, not a fresh bucket
        assert_eq!(m.tenant("acme").admitted.load(Ordering::Relaxed), 3);
        m.tenant("zeta").rejected_backpressure.fetch_add(2, Ordering::Relaxed);
        let s = m.summary();
        assert!(
            s.contains("tenant acme: admitted=3 rejected_quota=1 rejected_backpressure=0 bytes_in=100 bytes_out=250"),
            "{s}"
        );
        assert!(s.contains("tenant zeta:"), "{s}");
        // snapshot is name-sorted
        let names: Vec<String> = m.tenant_snapshot().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["acme".to_string(), "zeta".to_string()]);
    }

    #[test]
    fn kernel_lanes_surface_in_summary_after_dispatch() {
        // drive at least one dispatch through the kernel layer so the
        // process-global counters are nonzero regardless of test order
        let mut g = crate::graph::Graph::new(4, 2);
        g.labels[0] = 0;
        g.labels[1] = 1;
        g.labels[2] = 0;
        g.labels[3] = 1;
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        let _ = crate::gee::sparse_gee::SparseGee::fast()
            .embed(&g, &crate::gee::GeeOptions::ALL);
        let m = Metrics::new();
        let s = m.summary();
        assert!(s.contains("kernels: "), "{s}");
        assert!(s.contains("k2="), "{s}");
    }

    #[test]
    fn batch_fill_average() {
        let m = Metrics::new();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batched_requests.fetch_add(7, Ordering::Relaxed);
        assert!((m.avg_batch_fill() - 3.5).abs() < 1e-12);
        assert!(m.summary().contains("batches=2"));
    }
}
