//! Client for the embedding server — negotiates the v2 binary wire
//! ([`super::wire`]) and falls back to the v1 text protocol when the
//! server refuses the upgrade.
//!
//! Two usage shapes:
//!
//! * [`EmbedClient::embed`] — one lockstep round trip (both wires).
//! * [`EmbedClient::submit`] + [`EmbedClient::recv_any`] — pipelining:
//!   queue any number of requests, then collect replies in whatever
//!   order the server finishes them, matched by request id (v2 only).
//! * [`EmbedClient::cluster_embed`] — one `ITER2` self-clustering job:
//!   the graph ships once, per-round progress streams back, the final Z
//!   follows (text-only servers run the identical loop client-side).
//! * [`EmbedClient::open_session`] / [`send_deltas`](EmbedClient::send_deltas)
//!   / [`fetch_rows`](EmbedClient::fetch_rows) /
//!   [`close_session`](EmbedClient::close_session) — the resident-session
//!   delta lane (v2 only, lockstep; do not interleave with outstanding
//!   pipelined embeds).
//!
//! All connection bytes flow through [`ByteCounters`], so benches can
//! compare the two wires' traffic with the same instrument the shard
//! fleet uses.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::server::MAX_WIRE_CELLS;
use super::session::Delta;
use super::wire::{self, Reply, RequestHeader, SessionHeader, SessionOpHeader};
use crate::gee::GeeOptions;
use crate::shard::codec::{self, ByteCounters, CountingReader, CountingWriter, F64_RECORD_BYTES};
use crate::sparse::Dense;
use crate::util::retry::{BackoffPolicy, Deadlines};

/// Connection options for [`EmbedClient::connect`].
#[derive(Debug, Default, Clone)]
pub struct ClientConfig {
    /// Tenant declared in `HELLO2` (quota bucket + metrics key). `None`
    /// bills to `"default"`. Text connections cannot declare a tenant.
    pub tenant: Option<String>,
    /// Skip negotiation and speak v1 text — the escape hatch, and the
    /// reference lane the parity test compares against.
    pub force_text: bool,
    /// Share a caller-owned byte counter (benches aggregate across
    /// connections this way); a private one is created when `None`.
    pub counters: Option<Arc<ByteCounters>>,
    /// Per-phase wire budgets: `connect` bounds the TCP dial, `hello`
    /// the negotiation reply, `compute` the wait for a job's reply line,
    /// `frame` every read while a Z frame streams and every write.
    pub deadlines: Deadlines,
    /// Bounded, deterministically jittered backoff for
    /// [`EmbedClient::connect`] redials and
    /// [`EmbedClient::embed_with_retry`] `BUSY` retries.
    pub retry: BackoffPolicy,
}

/// One pipelined reply from [`EmbedClient::recv_any`].
#[derive(Debug)]
pub enum ClientReply {
    /// The embedding.
    Z(Dense),
    /// Admission refused the request; retry after roughly this long.
    Busy { retry_ms: u64 },
    /// This request failed server-side; the connection is still usable.
    Err(String),
}

pub struct EmbedClient {
    reader: BufReader<CountingReader<TcpStream>>,
    writer: BufWriter<CountingWriter<TcpStream>>,
    /// Retained clone of the connection: socket timeouts live on the
    /// shared file description, so this handle flips the read budget
    /// between the `compute` (reply wait) and `frame` (body streaming)
    /// phases without touching the reader/writer halves.
    ctl: TcpStream,
    deadlines: Deadlines,
    retry: BackoffPolicy,
    binary: bool,
    next_id: u64,
    scratch: Vec<u8>,
}

impl EmbedClient {
    /// Connect and negotiate, redialing under the config's bounded
    /// backoff when the dial or negotiation fails. Tries `HELLO2` first
    /// (unless `force_text`); any refusal — a text-only server, a pre-v2
    /// server that doesn't know the verb, a closed socket — reconnects
    /// fresh as v1 text rather than guessing at the old connection's
    /// state.
    pub fn connect(addr: SocketAddr, cfg: &ClientConfig) -> Result<EmbedClient> {
        let mut backoff = cfg.retry.schedule(u64::from(addr.port()) ^ 0xC11E_47);
        loop {
            match Self::connect_once(addr, cfg) {
                Ok(c) => return Ok(c),
                Err(e) => match backoff.next_delay() {
                    Some(d) => std::thread::sleep(d),
                    None => {
                        return Err(e.context(format!(
                            "giving up after {} connection attempt(s)",
                            cfg.retry.attempts.max(1)
                        )))
                    }
                },
            }
        }
    }

    fn connect_once(addr: SocketAddr, cfg: &ClientConfig) -> Result<EmbedClient> {
        let counters = cfg.counters.clone().unwrap_or_default();
        if !cfg.force_text {
            let (mut reader, mut writer, ctl) = open(addr, &counters, &cfg.deadlines)?;
            io_phase(
                writeln!(writer, "{}", wire::format_hello(cfg.tenant.as_deref())),
                "hello",
            )?;
            io_phase(writer.flush(), "hello")?;
            let mut line = String::new();
            if io_phase(reader.read_line(&mut line), "hello")? > 0 && line.trim() == "HELLO2" {
                // negotiated: replies now take as long as jobs compute
                ctl.set_read_timeout(cfg.deadlines.compute).ok();
                return Ok(EmbedClient {
                    reader,
                    writer,
                    ctl,
                    deadlines: cfg.deadlines.clone(),
                    retry: cfg.retry.clone(),
                    binary: true,
                    next_id: 1,
                    scratch: Vec::new(),
                });
            }
        }
        let (reader, writer, ctl) = open(addr, &counters, &cfg.deadlines)?;
        ctl.set_read_timeout(cfg.deadlines.compute).ok();
        Ok(EmbedClient {
            reader,
            writer,
            ctl,
            deadlines: cfg.deadlines.clone(),
            retry: cfg.retry.clone(),
            binary: false,
            next_id: 1,
            scratch: Vec::new(),
        })
    }

    /// True when the connection negotiated the v2 binary wire.
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// One embed round trip. On the binary wire a `BUSY` or `ERR id=`
    /// reply becomes this call's error; pipelined callers who want to
    /// retry use [`submit`](Self::submit)/[`recv_any`](Self::recv_any)
    /// and see [`ClientReply::Busy`] instead.
    pub fn embed(
        &mut self,
        code: &str,
        labels: &[i32],
        edges: &[(u32, u32, f64)],
        k: usize,
    ) -> Result<Dense> {
        if !self.binary {
            return self.embed_text(code, labels, edges, k);
        }
        let want = self.submit(code, labels, edges, k)?;
        loop {
            let (id, reply) = self.recv_any()?;
            if id != want {
                bail!("reply for unexpected id {id} (awaiting {want})");
            }
            match reply {
                ClientReply::Z(z) => return Ok(z),
                ClientReply::Busy { retry_ms } => {
                    bail!("server busy (retry after {retry_ms}ms)")
                }
                ClientReply::Err(msg) => bail!("server error: {msg}"),
            }
        }
    }

    /// [`embed`](Self::embed) with bounded, deterministically jittered
    /// retries on `BUSY` admission refusals. A retry re-submits the
    /// identical request, so the returned bits are unaffected; sleeps
    /// honour whichever is longer of the server's `retry=` hint and the
    /// backoff schedule. Any non-BUSY error returns immediately.
    pub fn embed_with_retry(
        &mut self,
        code: &str,
        labels: &[i32],
        edges: &[(u32, u32, f64)],
        k: usize,
    ) -> Result<Dense> {
        let mut backoff = self.retry.schedule(self.next_id ^ 0xB0_55);
        loop {
            match self.embed(code, labels, edges, k) {
                Ok(z) => return Ok(z),
                Err(e) => {
                    let Some(server_ms) = busy_retry_ms(&e) else { return Err(e) };
                    let Some(d) = backoff.next_delay() else {
                        return Err(e.context(format!(
                            "still busy after {} attempt(s)",
                            self.retry.attempts.max(1)
                        )));
                    };
                    std::thread::sleep(d.max(std::time::Duration::from_millis(server_ms)));
                }
            }
        }
    }

    /// Queue one request on the binary wire and return its id. Replies
    /// arrive via [`recv_any`](Self::recv_any), possibly out of order.
    pub fn submit(
        &mut self,
        code: &str,
        labels: &[i32],
        edges: &[(u32, u32, f64)],
        k: usize,
    ) -> Result<u64> {
        if !self.binary {
            bail!("pipelining requires the binary wire (server negotiated text)");
        }
        let options = GeeOptions::from_code(code).context("bad options code")?;
        let id = self.next_id;
        self.next_id += 1;
        let h = RequestHeader { id, options, n: labels.len(), k };
        io_phase(writeln!(self.writer, "{}", wire::format_request_header(&h)), "frame")?;
        io_phase(wire::write_request_body(&mut self.writer, labels, edges), "frame")?;
        io_phase(self.writer.flush(), "frame")?;
        Ok(id)
    }

    /// Block for the next reply on the binary wire, whichever request it
    /// answers. Fails on connection-fatal errors (bare `ERR`, EOF, a
    /// malformed frame) — per-request failures come back as
    /// [`ClientReply::Err`]/[`ClientReply::Busy`] with their id.
    pub fn recv_any(&mut self) -> Result<(u64, ClientReply)> {
        loop {
            let mut line = String::new();
            if io_phase(self.reader.read_line(&mut line), "compute")? == 0 {
                bail!("server closed the connection");
            }
            match wire::parse_reply(&line)? {
                Reply::Ok { id, rows, cols } => {
                    let z = self.read_z_frame(rows, cols)?;
                    return Ok((id, ClientReply::Z(z)));
                }
                Reply::Busy { id, retry_ms } => return Ok((id, ClientReply::Busy { retry_ms })),
                Reply::Err { id, msg } => return Ok((id, ClientReply::Err(msg))),
                Reply::Pong => continue,
                Reply::Fatal(msg) => bail!("server error: {msg}"),
            }
        }
    }

    /// One self-clustering job (`ITER2`): ship the graph once, let the
    /// server run the embed→kmeans→relabel loop, and stream per-round
    /// progress back ahead of the final Z. `labels` seed round 1 (use
    /// [`crate::gee::iterate::init_labels`] for the deterministic
    /// default); `rounds`/`tol` of 0 accept the driver defaults.
    ///
    /// On a text-only server the same loop runs client-side — one
    /// `EMBED` round trip per round, the kmeans/relabel steps local.
    /// Shortest-roundtrip decimals make the text lane recover exact
    /// bits, so both paths return the identical `(Z, rounds)`.
    pub fn cluster_embed(
        &mut self,
        code: &str,
        labels: &[i32],
        edges: &[(u32, u32, f64)],
        k: usize,
        rounds: usize,
        tol: f64,
    ) -> Result<(Dense, Vec<crate::gee::iterate::RoundState>)> {
        if !self.binary {
            return self.cluster_embed_text(code, labels, edges, k, rounds, tol);
        }
        let options = GeeOptions::from_code(code).context("bad options code")?;
        let id = self.next_id;
        self.next_id += 1;
        let h = wire::IterHeader { id, options, n: labels.len(), k, rounds, tol };
        io_phase(writeln!(self.writer, "{}", wire::format_iter_header(&h)), "frame")?;
        io_phase(wire::write_request_body(&mut self.writer, labels, edges), "frame")?;
        io_phase(self.writer.flush(), "frame")?;
        let mut states = Vec::new();
        loop {
            let mut line = String::new();
            if io_phase(self.reader.read_line(&mut line), "compute")? == 0 {
                bail!("server closed the connection");
            }
            if line.starts_with("ROUND ") {
                let (rid, rs) = wire::parse_round(&line)?;
                if rid != id {
                    bail!("ROUND line for unexpected id {rid} (awaiting {id})");
                }
                states.push(rs);
                continue;
            }
            match wire::parse_reply(&line)? {
                Reply::Ok { id: rid, rows, cols } => {
                    if rid != id {
                        bail!("reply for unexpected id {rid} (awaiting {id})");
                    }
                    let z = self.read_z_frame(rows, cols)?;
                    return Ok((z, states));
                }
                Reply::Busy { retry_ms, .. } => {
                    bail!("server busy (retry after {retry_ms}ms)")
                }
                Reply::Err { msg, .. } => bail!("server error: {msg}"),
                Reply::Pong => continue,
                Reply::Fatal(msg) => bail!("server error: {msg}"),
            }
        }
    }

    /// The client-side loop behind [`cluster_embed`](Self::cluster_embed)
    /// on the v1 text wire: same driver, same seeds, one `EMBED` round
    /// trip per round.
    fn cluster_embed_text(
        &mut self,
        code: &str,
        labels: &[i32],
        edges: &[(u32, u32, f64)],
        k: usize,
        rounds: usize,
        tol: f64,
    ) -> Result<(Dense, Vec<crate::gee::iterate::RoundState>)> {
        let driver = crate::gee::iterate::IterativeJob {
            rounds,
            tol,
            ..crate::gee::iterate::IterativeJob::new(labels.len(), k)
        };
        let mut states = Vec::new();
        let out = driver.run(
            Some(labels.to_vec()),
            |lab| self.embed_text(code, lab, edges, k),
            |rs| states.push(*rs),
        )?;
        Ok((out.z, states))
    }

    // ------------------------------------------------- session lane (v2)

    /// Open a resident session over the graph (`SESS2`; same body shape
    /// as an embed). Returns the server's session id. `rescale_threshold`
    /// `None` accepts the server default.
    pub fn open_session(
        &mut self,
        code: &str,
        labels: &[i32],
        edges: &[(u32, u32, f64)],
        k: usize,
        rescale_threshold: Option<f64>,
    ) -> Result<u64> {
        if !self.binary {
            bail!("sessions require the binary wire (server negotiated text)");
        }
        let options = GeeOptions::from_code(code).context("bad options code")?;
        let id = self.next_id;
        self.next_id += 1;
        let h = SessionHeader { id, options, n: labels.len(), k, rescale_threshold };
        io_phase(writeln!(self.writer, "{}", wire::format_session_header(&h)), "frame")?;
        io_phase(wire::write_request_body(&mut self.writer, labels, edges), "frame")?;
        io_phase(self.writer.flush(), "frame")?;
        let line = self.session_reply_line()?;
        match wire::parse_sess_ok(&line) {
            Ok((rid, sess, rows, cols)) => {
                if rid != id {
                    bail!("SESS reply for unexpected id {rid} (awaiting {id})");
                }
                if rows != labels.len() || cols != k {
                    bail!("SESS reply dims {rows}x{cols} do not match the request");
                }
                Ok(sess)
            }
            Err(_) => Err(session_err(&line)),
        }
    }

    /// Stream one delta batch (`DELTA2`) and return the session's
    /// `(applied, stale)` watermark from the `DACK`. An empty batch is a
    /// pure watermark poll.
    pub fn send_deltas(&mut self, sess: u64, deltas: &[Delta]) -> Result<(u64, u64)> {
        if !self.binary {
            bail!("sessions require the binary wire (server negotiated text)");
        }
        let id = self.next_id;
        self.next_id += 1;
        let h = SessionOpHeader { id, sess, count: deltas.len() as u64 };
        io_phase(writeln!(self.writer, "{}", wire::format_delta_header(&h)), "frame")?;
        io_phase(wire::write_delta_frame(&mut self.writer, deltas), "frame")?;
        io_phase(self.writer.flush(), "frame")?;
        let line = self.session_reply_line()?;
        match wire::parse_dack(&line) {
            Ok((rid, applied, stale)) => {
                if rid != id {
                    bail!("DACK reply for unexpected id {rid} (awaiting {id})");
                }
                Ok((applied, stale))
            }
            Err(_) => Err(session_err(&line)),
        }
    }

    /// Fetch chosen Z rows (`ROWS2`) and the `(applied, clean)`
    /// watermark they were read under. Row `r` of the returned matrix is
    /// session row `ids[r]`.
    pub fn fetch_rows(&mut self, sess: u64, ids: &[u32]) -> Result<(Dense, u64, u64)> {
        if !self.binary {
            bail!("sessions require the binary wire (server negotiated text)");
        }
        let id = self.next_id;
        self.next_id += 1;
        let h = SessionOpHeader { id, sess, count: ids.len() as u64 };
        io_phase(writeln!(self.writer, "{}", wire::format_rows_header(&h)), "frame")?;
        io_phase(wire::write_rows_frame(&mut self.writer, ids), "frame")?;
        io_phase(self.writer.flush(), "frame")?;
        let line = self.session_reply_line()?;
        match wire::parse_rows_ok(&line) {
            Ok((rid, rows, cols, applied, clean)) => {
                if rid != id {
                    bail!("ROWS reply for unexpected id {rid} (awaiting {id})");
                }
                if rows != ids.len() {
                    bail!("ROWS reply has {rows} rows, requested {}", ids.len());
                }
                let z = self.read_z_frame(rows, cols)?;
                Ok((z, applied, clean))
            }
            Err(_) => Err(session_err(&line)),
        }
    }

    /// Poll the staleness watermark (zero-delta `DELTA2` round trips)
    /// until the fast lane has drained; returns the applied watermark.
    pub fn wait_clean(&mut self, sess: u64, timeout: std::time::Duration) -> Result<u64> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let (applied, stale) = self.send_deltas(sess, &[])?;
            if stale == 0 {
                return Ok(applied);
            }
            if std::time::Instant::now() >= deadline {
                bail!("session {sess} still {stale} deltas stale after {timeout:?}");
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    /// Close a session (`CLOSE2`), releasing its tenant quota slot.
    pub fn close_session(&mut self, sess: u64) -> Result<()> {
        if !self.binary {
            bail!("sessions require the binary wire (server negotiated text)");
        }
        let id = self.next_id;
        self.next_id += 1;
        io_phase(writeln!(self.writer, "{}", wire::format_close_header(id, sess)), "frame")?;
        io_phase(self.writer.flush(), "frame")?;
        let line = self.session_reply_line()?;
        match wire::parse_closed(&line) {
            Ok(rid) => {
                if rid != id {
                    bail!("CLOSED reply for unexpected id {rid} (awaiting {id})");
                }
                Ok(())
            }
            Err(_) => Err(session_err(&line)),
        }
    }

    /// Next non-PONG reply line for the lockstep session exchanges.
    fn session_reply_line(&mut self) -> Result<String> {
        loop {
            let mut line = String::new();
            if io_phase(self.reader.read_line(&mut line), "compute")? == 0 {
                bail!("server closed the connection");
            }
            if line.trim() == "PONG" {
                continue;
            }
            return Ok(line);
        }
    }

    fn read_z_frame(&mut self, rows: usize, cols: usize) -> Result<Dense> {
        // while the frame streams, each read must make progress within
        // the frame budget; restore the (longer) compute budget for the
        // next reply wait afterwards
        self.ctl.set_read_timeout(self.deadlines.frame).ok();
        let out = self.read_z_frame_inner(rows, cols).map_err(|e| {
            let timed_out = e
                .root_cause()
                .downcast_ref::<std::io::Error>()
                .map(crate::util::retry::is_timeout)
                .unwrap_or(false);
            if timed_out { e.context("frame deadline exceeded") } else { e }
        });
        self.ctl.set_read_timeout(self.deadlines.compute).ok();
        out
    }

    fn read_z_frame_inner(&mut self, rows: usize, cols: usize) -> Result<Dense> {
        let cells = rows
            .checked_mul(cols)
            .filter(|&c| c <= MAX_WIRE_CELLS)
            .with_context(|| format!("Z frame {rows}x{cols} exceeds the wire limit"))?;
        let len = codec::read_frame_len(&mut self.reader, "Z frame")?;
        codec::check_frame_len(
            len,
            F64_RECORD_BYTES,
            (MAX_WIRE_CELLS * F64_RECORD_BYTES) as u64,
            Some((cells * F64_RECORD_BYTES) as u64),
            "Z frame",
        )?;
        let mut z = Dense::zeros(rows, cols);
        let data = &mut z.data;
        let mut pos = 0usize;
        codec::read_frame_body(&mut self.reader, len, &mut self.scratch, "Z frame", |chunk| {
            for rec in chunk.chunks_exact(F64_RECORD_BYTES) {
                // raw bits over the wire: bitwise-exact by construction
                data[pos] = f64::from_le_bytes(rec.try_into().unwrap());
                pos += 1;
            }
            Ok(())
        })?;
        Ok(z)
    }

    /// The v1 text exchange, kept verb-for-verb compatible with pre-v2
    /// servers. Weights and returned floats are shortest-roundtrip
    /// decimals, so the recovered Z matches the binary wire bit for bit.
    fn embed_text(
        &mut self,
        code: &str,
        labels: &[i32],
        edges: &[(u32, u32, f64)],
        k: usize,
    ) -> Result<Dense> {
        io_phase(
            writeln!(self.writer, "EMBED code={code} k={k} n={}", labels.len()),
            "frame",
        )?;
        let labs: Vec<String> = labels.iter().map(|l| l.to_string()).collect();
        io_phase(writeln!(self.writer, "LABELS {}", labs.join(" ")), "frame")?;
        for chunk in edges.chunks(512) {
            let toks: Vec<String> =
                chunk.iter().map(|(a, b, w)| format!("{a}:{b}:{w}")).collect();
            io_phase(writeln!(self.writer, "EDGES {}", toks.join(" ")), "frame")?;
        }
        io_phase(writeln!(self.writer, "END"), "frame")?;
        io_phase(self.writer.flush(), "frame")?;

        let mut line = String::new();
        io_phase(self.reader.read_line(&mut line), "compute")?;
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("BUSY ") {
            let retry_ms: u64 = rest.trim().parse().unwrap_or(wire::RETRY_AFTER_MS);
            bail!("server busy (retry after {retry_ms}ms)");
        }
        let dims = t.strip_prefix("OK ").with_context(|| format!("server error: {t}"))?;
        let mut it = dims.split_whitespace();
        let nrows: usize = it.next().context("bad OK line")?.parse()?;
        let ncols: usize = it.next().context("bad OK line")?.parse()?;
        let mut z = Dense::zeros(nrows, ncols);
        for r in 0..nrows {
            line.clear();
            io_phase(self.reader.read_line(&mut line), "compute")?;
            let row = z.row_mut(r);
            for (i, tok) in line.split_whitespace().enumerate() {
                if i >= ncols {
                    bail!("row {r} has more than {ncols} values");
                }
                row[i] = tok.parse()?;
            }
        }
        line.clear();
        io_phase(self.reader.read_line(&mut line), "compute")?;
        if line.trim() != "DONE" {
            bail!("expected DONE, got '{}'", line.trim());
        }
        Ok(z)
    }
}

/// Turn a non-matching session reply line into the call's error: the
/// server's request-scoped `ERR id=`/`BUSY` (or a bare fatal `ERR`)
/// with the connection left usable where the taxonomy says it is.
/// Extract the server's wait hint from a `BUSY` error (`None` for every
/// other failure — only admission refusals are retryable in place).
fn busy_retry_ms(e: &anyhow::Error) -> Option<u64> {
    let msg = format!("{e:#}");
    let rest = msg.split("retry after ").nth(1)?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Map a socket-timeout expiry onto its protocol phase so failures name
/// the budget that fired ("compute deadline exceeded") instead of
/// surfacing a bare os error.
fn io_phase<T>(r: std::io::Result<T>, phase: &str) -> Result<T> {
    r.map_err(|e| anyhow::Error::from(crate::util::retry::deadline_error(phase, e)))
}

fn session_err(line: &str) -> anyhow::Error {
    match wire::parse_reply(line) {
        Ok(Reply::Busy { retry_ms, .. }) => {
            anyhow::anyhow!("server busy (retry after {retry_ms}ms)")
        }
        Ok(Reply::Err { msg, .. }) | Ok(Reply::Fatal(msg)) => {
            anyhow::anyhow!("server error: {msg}")
        }
        _ => anyhow::anyhow!("unexpected reply '{}'", line.trim()),
    }
}

type OpenHalves =
    (BufReader<CountingReader<TcpStream>>, BufWriter<CountingWriter<TcpStream>>, TcpStream);

fn open(addr: SocketAddr, counters: &Arc<ByteCounters>, deadlines: &Deadlines) -> Result<OpenHalves> {
    let stream = TcpStream::connect_timeout(&addr, deadlines.connect)
        .with_context(|| format!("connect {addr} (connect deadline {:?})", deadlines.connect))?;
    stream.set_nodelay(true).ok();
    // negotiation budget until the HELLO2 reply lands; every write gets
    // the frame budget (the send-side stall bound)
    stream.set_read_timeout(deadlines.hello).ok();
    stream.set_write_timeout(deadlines.frame).ok();
    let ctl = stream.try_clone()?;
    let reader = BufReader::new(CountingReader::new(stream.try_clone()?, counters.clone()));
    let writer = BufWriter::new(CountingWriter::new(stream, counters.clone()));
    Ok((reader, writer, ctl))
}
