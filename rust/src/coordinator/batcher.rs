//! Dynamic batching by disjoint union — the coordinator's throughput
//! lever for the PJRT lane.
//!
//! PJRT executables are shape-specialized: a request for a 60-vertex graph
//! still pays for the full padded (N, E, K) bucket. The batcher packs many
//! small graphs into ONE padded execution as a disjoint union:
//!
//! * vertices of graph i are shifted by a node offset;
//! * labels of graph i are shifted by a class offset (classes of different
//!   graphs never share a column, so each graph keeps its own `n_k`
//!   normalization — this is what makes the union *exact*, not an
//!   approximation);
//! * no edges cross graphs, so degrees, Laplacian scaling, diagonal
//!   augmentation and row normalization all act per-graph.
//!
//! `split` slices each member's Z block back out. Equality with
//! per-graph embedding is tested for every option combo below and for the
//! PJRT path in `rust/tests/coordinator_integration.rs`.

use std::sync::{Arc, Mutex};

use crate::graph::Graph;
use crate::sparse::Dense;

/// Capacity of one packed execution (mirrors an artifact bucket).
#[derive(Clone, Copy, Debug)]
pub struct BatchCapacity {
    pub max_nodes: usize,
    pub max_directed_edges: usize,
    pub max_classes: usize,
    /// Cap on members per batch regardless of fit (latency control).
    pub max_requests: usize,
}

impl BatchCapacity {
    /// Capacity matching an artifact bucket (n, e, k).
    pub fn from_bucket(n: usize, e: usize, k: usize) -> Self {
        BatchCapacity { max_nodes: n, max_directed_edges: e, max_classes: k, max_requests: 64 }
    }

    /// Does a single graph fit at all?
    pub fn admits(&self, g: &Graph) -> bool {
        g.n <= self.max_nodes
            && g.num_directed() <= self.max_directed_edges
            && g.k <= self.max_classes
    }
}

/// Placement of one member inside a packed batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub node_offset: usize,
    pub class_offset: usize,
    pub n: usize,
    pub k: usize,
}

/// A packed batch: the union graph plus each member's placement.
#[derive(Clone, Debug)]
pub struct PackedBatch {
    pub union: Graph,
    pub placements: Vec<Placement>,
}

/// Plan batch membership under `cap` with bounded look-ahead (no unions
/// built — callers with a reusable [`PackedBatch`] buffer follow up with
/// [`build_union_into`] per plan). Each batch starts at the earliest
/// unplaced graph and scans subsequent unplaced graphs, examining at most
/// `max_requests` candidates (the look-ahead window that bounds both
/// batch size and reordering distance), adding every one that fits the
/// remaining capacity. This removes the old head-of-line blocking where a
/// single non-fitting arrival flushed a half-empty batch even though
/// later queued graphs would have filled it. Members keep arrival order
/// within a batch and batches are ordered by their first member, so
/// per-member result routing is unchanged. Graphs that individually
/// exceed `cap` land in `oversize` for the solo lane.
pub fn plan_batches(
    graphs: &[&Graph],
    cap: &BatchCapacity,
) -> (Vec<Vec<usize>>, Vec<usize>) {
    let mut oversize = Vec::new();
    let mut pending = Vec::new();
    // (nodes, directed edges, classes) per graph, computed once up front:
    // num_directed() is an O(E) scan, and the window below may examine a
    // graph once per batch attempt
    let mut needs = Vec::with_capacity(graphs.len());
    for (i, g) in graphs.iter().enumerate() {
        let need = (g.n, g.num_directed(), g.k);
        needs.push(need);
        let admitted = need.0 <= cap.max_nodes
            && need.1 <= cap.max_directed_edges
            && need.2 <= cap.max_classes;
        if admitted {
            pending.push(i);
        } else {
            oversize.push(i);
        }
    }
    let mut placed = vec![false; graphs.len()];
    let mut batches: Vec<Vec<usize>> = Vec::new();
    let mut start = 0usize; // position in `pending` of the first unplaced
    while start < pending.len() {
        let mut members = Vec::new();
        let mut used = (0usize, 0usize, 0usize); // nodes, edges, classes
        let mut examined = 0usize;
        for &idx in &pending[start..] {
            if placed[idx] {
                continue;
            }
            if examined >= cap.max_requests || members.len() >= cap.max_requests {
                break;
            }
            examined += 1;
            let need = needs[idx];
            if used.0 + need.0 <= cap.max_nodes
                && used.1 + need.1 <= cap.max_directed_edges
                && used.2 + need.2 <= cap.max_classes
            {
                members.push(idx);
                placed[idx] = true;
                used = (used.0 + need.0, used.1 + need.1, used.2 + need.2);
            }
        }
        if members.is_empty() {
            // degenerate `max_requests == 0` config: take one graph anyway
            // so every batch makes progress (matches the old packer, which
            // treated the cap as at-least-one)
            let idx = pending[start];
            members.push(idx);
            placed[idx] = true;
        }
        batches.push(members);
        while start < pending.len() && placed[pending[start]] {
            start += 1;
        }
    }
    (batches, oversize)
}

/// Plan + build in one call — the allocating convenience wrapper over
/// [`plan_batches`] + [`build_union_into`] (benches, tests, one-shot
/// callers; the service workers use the pooled pieces directly).
pub fn pack_graphs(
    graphs: &[&Graph],
    cap: &BatchCapacity,
) -> (Vec<(PackedBatch, Vec<usize>)>, Vec<usize>) {
    let (plans, oversize) = plan_batches(graphs, cap);
    let batches = plans
        .into_iter()
        .map(|members| {
            let refs: Vec<&Graph> = members.iter().map(|&i| graphs[i]).collect();
            (build_union(&refs), members)
        })
        .collect();
    (batches, oversize)
}

/// Build the disjoint union with node/class offsets into `out`, reusing
/// every buffer's capacity (edge arrays, labels, placements). After one
/// warm-up batch at a given shape, steady-state union construction
/// performs **zero heap allocations** (pinned in `tests/alloc_zero.rs`)
/// — the ROADMAP "pool build_union" item.
pub fn build_union_into(members: &[&Graph], out: &mut PackedBatch) {
    let total_n: usize = members.iter().map(|g| g.n).sum();
    let total_k: usize = members.iter().map(|g| g.k).sum();
    let union = &mut out.union;
    union.n = total_n;
    union.k = total_k;
    union.src.clear();
    union.dst.clear();
    union.w.clear();
    union.labels.clear();
    union.labels.resize(total_n, -1);
    out.placements.clear();
    let mut node_off = 0usize;
    let mut class_off = 0usize;
    for g in members {
        for v in 0..g.n {
            if g.labels[v] >= 0 {
                union.labels[node_off + v] = g.labels[v] + class_off as i32;
            }
        }
        for e in 0..g.num_edges() {
            union.add_edge(
                g.src[e] + node_off as u32,
                g.dst[e] + node_off as u32,
                g.w[e],
            );
        }
        out.placements.push(Placement {
            node_offset: node_off,
            class_offset: class_off,
            n: g.n,
            k: g.k,
        });
        node_off += g.n;
        class_off += g.k;
    }
}

/// Build the disjoint union with node/class offsets (fresh allocation;
/// see [`build_union_into`] for the pooled lane).
pub fn build_union(members: &[&Graph]) -> PackedBatch {
    let mut out = PackedBatch { union: Graph::new(0, 0), placements: Vec::new() };
    build_union_into(members, &mut out);
    out
}

/// A shared pool of warmed union buffers — the batching twin of the embed
/// path's `WorkspacePool`: each coordinator worker checks one out for its
/// lifetime, and the capacity returns to the pool on drop.
#[derive(Debug, Default)]
pub struct UnionPool {
    free: Mutex<Vec<PackedBatch>>,
}

impl UnionPool {
    pub fn new() -> Arc<UnionPool> {
        Arc::new(UnionPool::default())
    }

    /// Borrow a union buffer; it returns to the pool when the guard drops.
    pub fn checkout(self: &Arc<Self>) -> PooledUnion {
        let buf = self
            .free
            .lock()
            .expect("union pool lock poisoned")
            .pop()
            .unwrap_or_else(|| PackedBatch {
                union: Graph::new(0, 0),
                placements: Vec::new(),
            });
        PooledUnion { buf: Some(buf), pool: Arc::clone(self) }
    }

    /// Number of idle buffers currently pooled.
    pub fn idle(&self) -> usize {
        self.free.lock().expect("union pool lock poisoned").len()
    }
}

/// RAII guard over a checked-out union buffer.
#[derive(Debug)]
pub struct PooledUnion {
    buf: Option<PackedBatch>,
    pool: Arc<UnionPool>,
}

impl std::ops::Deref for PooledUnion {
    type Target = PackedBatch;
    fn deref(&self) -> &PackedBatch {
        self.buf.as_ref().expect("union buffer present until drop")
    }
}

impl std::ops::DerefMut for PooledUnion {
    fn deref_mut(&mut self) -> &mut PackedBatch {
        self.buf.as_mut().expect("union buffer present until drop")
    }
}

impl Drop for PooledUnion {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.pool
                .free
                .lock()
                .expect("union pool lock poisoned")
                .push(buf);
        }
    }
}

/// Slice one member's embedding block out of the union's Z.
pub fn split_member(z_union: &Dense, p: &Placement) -> Dense {
    let mut z = Dense::zeros(p.n, p.k);
    for r in 0..p.n {
        for c in 0..p.k {
            *z.get_mut(r, c) = z_union.get(p.node_offset + r, p.class_offset + c);
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::{Engine, GeeOptions};
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = rng.below(k) as i32;
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g
    }

    #[test]
    fn union_embedding_equals_individual_all_combos() {
        let g1 = random_graph(201, 30, 80, 3);
        let g2 = random_graph(202, 45, 120, 4);
        let g3 = random_graph(203, 20, 40, 2);
        let batch = build_union(&[&g1, &g2, &g3]);
        assert_eq!(batch.union.n, 95);
        assert_eq!(batch.union.k, 9);
        for opts in GeeOptions::table_order() {
            let zu = Engine::Sparse.embed(&batch.union, &opts).unwrap();
            for (g, p) in [&g1, &g2, &g3].iter().zip(&batch.placements) {
                let z_split = split_member(&zu, p);
                let z_solo = Engine::Sparse.embed(g, &opts).unwrap();
                assert!(
                    z_solo.max_abs_diff(&z_split) < 1e-10,
                    "union != solo at {:?}",
                    opts
                );
            }
        }
    }

    #[test]
    fn union_with_unlabeled_members() {
        let mut g1 = random_graph(204, 25, 60, 3);
        g1.labels[0] = -1;
        let g2 = random_graph(205, 25, 60, 3);
        let batch = build_union(&[&g1, &g2]);
        assert_eq!(batch.union.labels[0], -1);
        let opts = GeeOptions::ALL;
        let zu = Engine::Sparse.embed(&batch.union, &opts).unwrap();
        let z1 = split_member(&zu, &batch.placements[0]);
        let solo = Engine::Sparse.embed(&g1, &opts).unwrap();
        assert!(solo.max_abs_diff(&z1) < 1e-10);
    }

    #[test]
    fn pack_respects_capacity() {
        let graphs: Vec<Graph> = (0..6).map(|i| random_graph(210 + i, 40, 60, 3)).collect();
        let refs: Vec<&Graph> = graphs.iter().collect();
        let cap = BatchCapacity {
            max_nodes: 100,
            max_directed_edges: 1_000,
            max_classes: 16,
            max_requests: 64,
        };
        let (batches, oversize) = pack_graphs(&refs, &cap);
        assert!(oversize.is_empty());
        // 40 nodes each, 100 max -> 2 per batch -> 3 batches
        assert_eq!(batches.len(), 3);
        for (b, members) in &batches {
            assert!(b.union.n <= cap.max_nodes);
            assert!(b.union.k <= cap.max_classes);
            assert_eq!(members.len(), 2);
        }
        // all members covered exactly once, in order
        let all: Vec<usize> = batches.iter().flat_map(|(_, m)| m.clone()).collect();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn pack_routes_oversize_to_solo() {
        let small = random_graph(220, 10, 20, 2);
        let big = random_graph(221, 500, 100, 2);
        let refs: Vec<&Graph> = vec![&small, &big];
        let cap = BatchCapacity {
            max_nodes: 100,
            max_directed_edges: 10_000,
            max_classes: 16,
            max_requests: 64,
        };
        let (batches, oversize) = pack_graphs(&refs, &cap);
        assert_eq!(batches.len(), 1);
        assert_eq!(oversize, vec![1]);
    }

    #[test]
    fn scan_ahead_fixes_head_of_line_blocking() {
        // regression (ISSUE 3): arrival order 60, 60, 40, 40 under a
        // 100-node cap used to flush [60] half-empty when the second 60
        // arrived, producing 3 batches; scanning ahead packs 2 full ones
        let g60a = random_graph(240, 60, 30, 2);
        let g60b = random_graph(241, 60, 30, 2);
        let g40a = random_graph(242, 40, 20, 2);
        let g40b = random_graph(243, 40, 20, 2);
        let refs: Vec<&Graph> = vec![&g60a, &g60b, &g40a, &g40b];
        let cap = BatchCapacity {
            max_nodes: 100,
            max_directed_edges: 100_000,
            max_classes: 64,
            max_requests: 64,
        };
        let (plans, oversize) = plan_batches(&refs, &cap);
        assert!(oversize.is_empty());
        assert_eq!(plans.len(), 2, "scan-ahead must fill both batches");
        assert_eq!(plans[0], vec![0, 2], "members keep arrival order");
        assert_eq!(plans[1], vec![1, 3]);
        // fill rate: every batch at the node cap
        let (batches, _) = pack_graphs(&refs, &cap);
        for (b, _) in &batches {
            assert_eq!(b.union.n, 100);
        }
        // every member appears exactly once
        let mut all: Vec<usize> = plans.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
    }

    #[test]
    fn scan_ahead_window_is_bounded_by_max_requests() {
        // a non-fitting graph parked at the front must not let the scan
        // run arbitrarily far: with max_requests=2 the window examines at
        // most 2 candidates per batch, so the fitting graph 3 slots away
        // stays out of the first batch
        let big = random_graph(245, 90, 30, 2);
        let mid = random_graph(246, 60, 30, 2);
        let mid2 = random_graph(247, 60, 30, 2);
        let tiny = random_graph(248, 10, 5, 2);
        let refs: Vec<&Graph> = vec![&big, &mid, &mid2, &tiny];
        let cap = BatchCapacity {
            max_nodes: 100,
            max_directed_edges: 100_000,
            max_classes: 64,
            max_requests: 2,
        };
        let (plans, _) = plan_batches(&refs, &cap);
        // batch 0 examines big (fits) then mid (90+60 > 100, skip) and
        // stops at the window: tiny would fit but is outside it
        assert_eq!(plans[0], vec![0]);
        let mut all: Vec<usize> = plans.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3], "window skips must still be packed later");
    }

    #[test]
    fn union_buffer_reuses_capacity() {
        let g1 = random_graph(250, 30, 80, 3);
        let g2 = random_graph(251, 45, 120, 4);
        let pool = UnionPool::new();
        let mut buf = pool.checkout();
        build_union_into(&[&g1, &g2], &mut buf);
        let expect = build_union(&[&g1, &g2]);
        assert_eq!(buf.union.src, expect.union.src);
        assert_eq!(buf.union.labels, expect.union.labels);
        assert_eq!(buf.placements, expect.placements);
        let caps = (
            buf.union.src.capacity(),
            buf.union.labels.capacity(),
            buf.placements.capacity(),
        );
        for _ in 0..5 {
            build_union_into(&[&g1, &g2], &mut buf);
        }
        assert_eq!(
            (
                buf.union.src.capacity(),
                buf.union.labels.capacity(),
                buf.placements.capacity(),
            ),
            caps,
            "steady-state unions must not grow any buffer"
        );
        assert_eq!(buf.union.labels, expect.union.labels, "rebuild stays exact");
        drop(buf);
        assert_eq!(pool.idle(), 1, "drop must return the buffer");
        let warm = pool.checkout();
        assert!(warm.union.src.capacity() >= caps.0, "warm capacity survives");
    }

    #[test]
    fn max_requests_limits_fill() {
        let graphs: Vec<Graph> = (0..5).map(|i| random_graph(230 + i, 5, 5, 2)).collect();
        let refs: Vec<&Graph> = graphs.iter().collect();
        let cap = BatchCapacity {
            max_nodes: 1_000,
            max_directed_edges: 10_000,
            max_classes: 100,
            max_requests: 2,
        };
        let (batches, _) = pack_graphs(&refs, &cap);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].1.len(), 2);
        assert_eq!(batches[2].1.len(), 1);
    }
}
