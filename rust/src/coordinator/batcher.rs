//! Dynamic batching by disjoint union — the coordinator's throughput
//! lever for the PJRT lane.
//!
//! PJRT executables are shape-specialized: a request for a 60-vertex graph
//! still pays for the full padded (N, E, K) bucket. The batcher packs many
//! small graphs into ONE padded execution as a disjoint union:
//!
//! * vertices of graph i are shifted by a node offset;
//! * labels of graph i are shifted by a class offset (classes of different
//!   graphs never share a column, so each graph keeps its own `n_k`
//!   normalization — this is what makes the union *exact*, not an
//!   approximation);
//! * no edges cross graphs, so degrees, Laplacian scaling, diagonal
//!   augmentation and row normalization all act per-graph.
//!
//! `split` slices each member's Z block back out. Equality with
//! per-graph embedding is tested for every option combo below and for the
//! PJRT path in `rust/tests/coordinator_integration.rs`.

use crate::graph::Graph;
use crate::sparse::Dense;

/// Capacity of one packed execution (mirrors an artifact bucket).
#[derive(Clone, Copy, Debug)]
pub struct BatchCapacity {
    pub max_nodes: usize,
    pub max_directed_edges: usize,
    pub max_classes: usize,
    /// Cap on members per batch regardless of fit (latency control).
    pub max_requests: usize,
}

impl BatchCapacity {
    /// Capacity matching an artifact bucket (n, e, k).
    pub fn from_bucket(n: usize, e: usize, k: usize) -> Self {
        BatchCapacity { max_nodes: n, max_directed_edges: e, max_classes: k, max_requests: 64 }
    }

    /// Does a single graph fit at all?
    pub fn admits(&self, g: &Graph) -> bool {
        g.n <= self.max_nodes
            && g.num_directed() <= self.max_directed_edges
            && g.k <= self.max_classes
    }
}

/// Placement of one member inside a packed batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub node_offset: usize,
    pub class_offset: usize,
    pub n: usize,
    pub k: usize,
}

/// A packed batch: the union graph plus each member's placement.
#[derive(Clone, Debug)]
pub struct PackedBatch {
    pub union: Graph,
    pub placements: Vec<Placement>,
}

/// Greedily pack graphs (in arrival order, first-fit into the current
/// batch) under `cap`. Returns batches with the indices of the member
/// graphs. Graphs that individually exceed `cap` are returned in
/// `oversize` for the caller to route to a solo lane.
pub fn pack_graphs(
    graphs: &[&Graph],
    cap: &BatchCapacity,
) -> (Vec<(PackedBatch, Vec<usize>)>, Vec<usize>) {
    let mut batches: Vec<(PackedBatch, Vec<usize>)> = Vec::new();
    let mut oversize = Vec::new();
    let mut current: Vec<usize> = Vec::new();
    let mut used = (0usize, 0usize, 0usize); // nodes, edges, classes

    let flush = |current: &mut Vec<usize>,
                 batches: &mut Vec<(PackedBatch, Vec<usize>)>| {
        if !current.is_empty() {
            let members: Vec<&Graph> = current.iter().map(|&i| graphs[i]).collect();
            batches.push((build_union(&members), std::mem::take(current)));
        }
    };

    for (i, g) in graphs.iter().enumerate() {
        if !cap.admits(g) {
            oversize.push(i);
            continue;
        }
        let need = (g.n, g.num_directed(), g.k);
        let fits = current.len() < cap.max_requests
            && used.0 + need.0 <= cap.max_nodes
            && used.1 + need.1 <= cap.max_directed_edges
            && used.2 + need.2 <= cap.max_classes;
        if !fits {
            flush(&mut current, &mut batches);
            used = (0, 0, 0);
        }
        current.push(i);
        used = (used.0 + need.0, used.1 + need.1, used.2 + need.2);
    }
    flush(&mut current, &mut batches);
    (batches, oversize)
}

/// Build the disjoint union with node/class offsets.
pub fn build_union(members: &[&Graph]) -> PackedBatch {
    let total_n: usize = members.iter().map(|g| g.n).sum();
    let total_k: usize = members.iter().map(|g| g.k).sum();
    let mut union = Graph::new(total_n, total_k);
    let mut placements = Vec::with_capacity(members.len());
    let mut node_off = 0usize;
    let mut class_off = 0usize;
    for g in members {
        for v in 0..g.n {
            union.labels[node_off + v] = if g.labels[v] >= 0 {
                g.labels[v] + class_off as i32
            } else {
                -1
            };
        }
        for e in 0..g.num_edges() {
            union.add_edge(
                g.src[e] + node_off as u32,
                g.dst[e] + node_off as u32,
                g.w[e],
            );
        }
        placements.push(Placement { node_offset: node_off, class_offset: class_off, n: g.n, k: g.k });
        node_off += g.n;
        class_off += g.k;
    }
    PackedBatch { union, placements }
}

/// Slice one member's embedding block out of the union's Z.
pub fn split_member(z_union: &Dense, p: &Placement) -> Dense {
    let mut z = Dense::zeros(p.n, p.k);
    for r in 0..p.n {
        for c in 0..p.k {
            *z.get_mut(r, c) = z_union.get(p.node_offset + r, p.class_offset + c);
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gee::{Engine, GeeOptions};
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = rng.below(k) as i32;
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, rng.f64() + 0.1);
        }
        g
    }

    #[test]
    fn union_embedding_equals_individual_all_combos() {
        let g1 = random_graph(201, 30, 80, 3);
        let g2 = random_graph(202, 45, 120, 4);
        let g3 = random_graph(203, 20, 40, 2);
        let batch = build_union(&[&g1, &g2, &g3]);
        assert_eq!(batch.union.n, 95);
        assert_eq!(batch.union.k, 9);
        for opts in GeeOptions::table_order() {
            let zu = Engine::Sparse.embed(&batch.union, &opts).unwrap();
            for (g, p) in [&g1, &g2, &g3].iter().zip(&batch.placements) {
                let z_split = split_member(&zu, p);
                let z_solo = Engine::Sparse.embed(g, &opts).unwrap();
                assert!(
                    z_solo.max_abs_diff(&z_split) < 1e-10,
                    "union != solo at {:?}",
                    opts
                );
            }
        }
    }

    #[test]
    fn union_with_unlabeled_members() {
        let mut g1 = random_graph(204, 25, 60, 3);
        g1.labels[0] = -1;
        let g2 = random_graph(205, 25, 60, 3);
        let batch = build_union(&[&g1, &g2]);
        assert_eq!(batch.union.labels[0], -1);
        let opts = GeeOptions::ALL;
        let zu = Engine::Sparse.embed(&batch.union, &opts).unwrap();
        let z1 = split_member(&zu, &batch.placements[0]);
        let solo = Engine::Sparse.embed(&g1, &opts).unwrap();
        assert!(solo.max_abs_diff(&z1) < 1e-10);
    }

    #[test]
    fn pack_respects_capacity() {
        let graphs: Vec<Graph> = (0..6).map(|i| random_graph(210 + i, 40, 60, 3)).collect();
        let refs: Vec<&Graph> = graphs.iter().collect();
        let cap = BatchCapacity {
            max_nodes: 100,
            max_directed_edges: 1_000,
            max_classes: 16,
            max_requests: 64,
        };
        let (batches, oversize) = pack_graphs(&refs, &cap);
        assert!(oversize.is_empty());
        // 40 nodes each, 100 max -> 2 per batch -> 3 batches
        assert_eq!(batches.len(), 3);
        for (b, members) in &batches {
            assert!(b.union.n <= cap.max_nodes);
            assert!(b.union.k <= cap.max_classes);
            assert_eq!(members.len(), 2);
        }
        // all members covered exactly once, in order
        let all: Vec<usize> = batches.iter().flat_map(|(_, m)| m.clone()).collect();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn pack_routes_oversize_to_solo() {
        let small = random_graph(220, 10, 20, 2);
        let big = random_graph(221, 500, 100, 2);
        let refs: Vec<&Graph> = vec![&small, &big];
        let cap = BatchCapacity {
            max_nodes: 100,
            max_directed_edges: 10_000,
            max_classes: 16,
            max_requests: 64,
        };
        let (batches, oversize) = pack_graphs(&refs, &cap);
        assert_eq!(batches.len(), 1);
        assert_eq!(oversize, vec![1]);
    }

    #[test]
    fn max_requests_limits_fill() {
        let graphs: Vec<Graph> = (0..5).map(|i| random_graph(230 + i, 5, 5, 2)).collect();
        let refs: Vec<&Graph> = graphs.iter().collect();
        let cap = BatchCapacity {
            max_nodes: 1_000,
            max_directed_edges: 10_000,
            max_classes: 100,
            max_requests: 2,
        };
        let (batches, _) = pack_graphs(&refs, &cap);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].1.len(), 2);
        assert_eq!(batches[2].1.len(), 1);
    }
}
