//! Bounded MPMC job queue with blocking push/pop and explicit
//! backpressure — the admission-control stage of the embedding service.
//! (The offline crate set has no tokio/crossbeam-channel; Mutex+Condvar
//! is entirely adequate for graph-sized work items.)

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Why a push was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity (try_push only).
    Full,
    /// Queue closed for new work.
    Closed,
}

/// Bounded multi-producer multi-consumer FIFO.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking push; `Full` applies backpressure to the caller.
    pub fn try_push(&self, item: T) -> Result<(), (T, PushError)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((item, PushError::Closed));
        }
        if g.items.len() >= self.capacity {
            return Err((item, PushError::Full));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push — waits for space. Errors only when closed.
    pub fn push(&self, item: T) -> Result<(), (T, PushError)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err((item, PushError::Closed));
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking pop; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a deadline; `Ok(None)` = closed+drained, `Err(())` = timed
    /// out with the queue still open (the batcher's flush tick).
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, ()> {
        let mut g = self.inner.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                if g.closed {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    /// Close: producers fail fast, consumers drain then see `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_full_backpressure() {
        let q = BoundedQueue::new(1);
        q.try_push(1).unwrap();
        match q.try_push(2) {
            Err((2, PushError::Full)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8).unwrap_err().1, PushError::Closed);
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_timeout_ticks() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Err(()));
        q.try_push(1).unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Ok(Some(1)));
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Ok(None));
    }

    #[test]
    fn pop_timeout_delivers_item_arriving_during_wait() {
        // an item pushed while the consumer is parked inside the wait
        // must be delivered, not swallowed by the flush tick
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.try_push(42).unwrap();
        });
        assert_eq!(q.pop_timeout(Duration::from_secs(5)), Ok(Some(42)));
        producer.join().unwrap();
    }

    #[test]
    fn pop_timeout_err_only_after_full_deadline() {
        // Err(()) means "the deadline passed with nothing to hand out" —
        // it must never fire early (a short tick would make the batcher
        // flush before its linger window closed)
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let timeout = Duration::from_millis(40);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(timeout), Err(()));
        assert!(
            t0.elapsed() >= timeout,
            "timed out after {:?}, before the {timeout:?} deadline",
            t0.elapsed()
        );
    }

    #[test]
    fn pop_timeout_arrival_racing_deadline_never_loses_items() {
        // hammer the exact race the deadline logic guards: a producer
        // pushing right around the consumer's timeout instant. Every
        // push must end up either in a pop_timeout result or still
        // queued — Err(()) with an item silently dropped is the bug
        // class this pins down.
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(64));
        let rounds = 200u32;
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..rounds {
                // jitter around the consumer's 1ms deadline
                std::thread::sleep(Duration::from_micros((i % 7) as u64 * 300));
                q2.push(i).unwrap();
            }
        });
        let mut delivered = 0u32;
        while delivered < rounds {
            match q.pop_timeout(Duration::from_millis(1)) {
                Ok(Some(_)) => delivered += 1,
                Ok(None) => panic!("queue closed unexpectedly"),
                Err(()) => {} // timed out with the queue open: retry
            }
        }
        producer.join().unwrap();
        assert_eq!(delivered, rounds);
        assert!(q.is_empty(), "every push must be delivered exactly once");
    }

    #[test]
    fn blocking_push_wakes_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(2).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let q = Arc::new(BoundedQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let mut expect: Vec<i32> =
            (0..4).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }
}
