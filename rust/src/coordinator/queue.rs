//! Bounded MPMC job queue with blocking push/pop and explicit
//! backpressure — the admission-control stage of the embedding service.
//! (The offline crate set has no tokio/crossbeam-channel; Mutex+Condvar
//! is entirely adequate for graph-sized work items.)

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Why a push was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity (try_push only).
    Full,
    /// Queue closed for new work.
    Closed,
}

/// Bounded multi-producer multi-consumer FIFO.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    /// Slots promised to admitted-but-not-yet-pushed requests. The wire
    /// admission path reserves a slot from the request *header* alone so
    /// backpressure fires before any edge buffer is allocated; the slot
    /// is consumed by `push_reserved` or returned by `cancel_reservation`.
    reserved: usize,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), reserved: 0, closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking push; `Full` applies backpressure to the caller.
    pub fn try_push(&self, item: T) -> Result<(), (T, PushError)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((item, PushError::Closed));
        }
        if g.items.len() + g.reserved >= self.capacity {
            return Err((item, PushError::Full));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push — waits for space. Errors only when closed.
    pub fn push(&self, item: T) -> Result<(), (T, PushError)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err((item, PushError::Closed));
            }
            if g.items.len() + g.reserved < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Blocking pop; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a deadline; `Ok(None)` = closed+drained, `Err(())` = timed
    /// out with the queue still open (the batcher's flush tick).
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, ()> {
        let mut g = self.inner.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                if g.closed {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    /// Close: producers fail fast, consumers drain then see `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reserve one queue slot without an item. Returns `Err(Full)` when
    /// queued items plus outstanding reservations already fill the queue,
    /// `Err(Closed)` once closed. A successful reservation must be
    /// resolved by exactly one of [`push_reserved`](Self::push_reserved)
    /// or [`cancel_reservation`](Self::cancel_reservation).
    pub fn try_reserve(&self) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.items.len() + g.reserved >= self.capacity {
            return Err(PushError::Full);
        }
        g.reserved += 1;
        Ok(())
    }

    /// Consume a previously acquired reservation by pushing its item.
    /// Never reports `Full` (the slot was promised); errors only when
    /// the queue closed between reserve and push, in which case the
    /// reservation is released.
    pub fn push_reserved(&self, item: T) -> Result<(), (T, PushError)> {
        let mut g = self.inner.lock().unwrap();
        debug_assert!(g.reserved > 0, "push_reserved without a reservation");
        g.reserved = g.reserved.saturating_sub(1);
        if g.closed {
            drop(g);
            self.not_full.notify_one();
            return Err((item, PushError::Closed));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Return an unused reservation's slot to the queue.
    pub fn cancel_reservation(&self) {
        let mut g = self.inner.lock().unwrap();
        debug_assert!(g.reserved > 0, "cancel_reservation without a reservation");
        g.reserved = g.reserved.saturating_sub(1);
        drop(g);
        self.not_full.notify_one();
    }
}

/// Why a tenant's request was refused admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The tenant is at its in-flight token quota.
    OverQuota,
    /// The service queue (items + reservations) is full.
    Backpressure,
    /// The service is shutting down.
    Closed,
}

/// Per-tenant in-flight token quotas. Each admitted request holds one
/// token from HELLO-declared tenant's bucket until its reply is sent;
/// a tenant at quota is refused from the request *header* alone, before
/// any edge frame is read or allocated. Tenants are created lazily on
/// first admission; all buckets share `default_tokens` unless an
/// explicit override is set.
pub struct TenantGovernor {
    default_tokens: usize,
    state: Mutex<TenantState>,
}

#[derive(Default)]
struct TenantState {
    limits: HashMap<String, usize>,
    in_flight: HashMap<String, usize>,
}

/// RAII token held by one admitted request; dropping it returns the
/// token to the tenant's bucket.
pub struct TenantPermit {
    governor: Arc<TenantGovernor>,
    tenant: String,
}

impl TenantGovernor {
    pub fn new(default_tokens: usize) -> Arc<Self> {
        assert!(default_tokens > 0);
        Arc::new(TenantGovernor { default_tokens, state: Mutex::new(TenantState::default()) })
    }

    /// Override one tenant's token budget (0 bans the tenant outright).
    pub fn set_limit(&self, tenant: &str, tokens: usize) {
        self.state.lock().unwrap().limits.insert(tenant.to_string(), tokens);
    }

    /// Tokens the named tenant may hold concurrently.
    pub fn limit(&self, tenant: &str) -> usize {
        self.state
            .lock()
            .unwrap()
            .limits
            .get(tenant)
            .copied()
            .unwrap_or(self.default_tokens)
    }

    /// Admit one request for `tenant`, or refuse with `OverQuota`.
    pub fn try_admit(self: &Arc<Self>, tenant: &str) -> Result<TenantPermit, AdmitError> {
        let mut st = self.state.lock().unwrap();
        let limit = st.limits.get(tenant).copied().unwrap_or(self.default_tokens);
        let used = st.in_flight.get(tenant).copied().unwrap_or(0);
        if used >= limit {
            return Err(AdmitError::OverQuota);
        }
        *st.in_flight.entry(tenant.to_string()).or_insert(0) += 1;
        drop(st);
        Ok(TenantPermit { governor: self.clone(), tenant: tenant.to_string() })
    }

    /// Tokens currently held by `tenant` (observability / tests).
    pub fn in_flight(&self, tenant: &str) -> usize {
        self.state
            .lock()
            .unwrap()
            .in_flight
            .get(tenant)
            .copied()
            .unwrap_or(0)
    }
}

impl TenantPermit {
    pub fn tenant(&self) -> &str {
        &self.tenant
    }
}

impl Drop for TenantPermit {
    fn drop(&mut self) {
        let mut st = self.governor.state.lock().unwrap();
        if let Some(used) = st.in_flight.get_mut(&self.tenant) {
            *used = used.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_full_backpressure() {
        let q = BoundedQueue::new(1);
        q.try_push(1).unwrap();
        match q.try_push(2) {
            Err((2, PushError::Full)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8).unwrap_err().1, PushError::Closed);
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_timeout_ticks() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Err(()));
        q.try_push(1).unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Ok(Some(1)));
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Ok(None));
    }

    #[test]
    fn pop_timeout_delivers_item_arriving_during_wait() {
        // an item pushed while the consumer is parked inside the wait
        // must be delivered, not swallowed by the flush tick
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.try_push(42).unwrap();
        });
        assert_eq!(q.pop_timeout(Duration::from_secs(5)), Ok(Some(42)));
        producer.join().unwrap();
    }

    #[test]
    fn pop_timeout_err_only_after_full_deadline() {
        // Err(()) means "the deadline passed with nothing to hand out" —
        // it must never fire early (a short tick would make the batcher
        // flush before its linger window closed)
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let timeout = Duration::from_millis(40);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(timeout), Err(()));
        assert!(
            t0.elapsed() >= timeout,
            "timed out after {:?}, before the {timeout:?} deadline",
            t0.elapsed()
        );
    }

    #[test]
    fn pop_timeout_arrival_racing_deadline_never_loses_items() {
        // hammer the exact race the deadline logic guards: a producer
        // pushing right around the consumer's timeout instant. Every
        // push must end up either in a pop_timeout result or still
        // queued — Err(()) with an item silently dropped is the bug
        // class this pins down.
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(64));
        let rounds = 200u32;
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..rounds {
                // jitter around the consumer's 1ms deadline
                std::thread::sleep(Duration::from_micros((i % 7) as u64 * 300));
                q2.push(i).unwrap();
            }
        });
        let mut delivered = 0u32;
        while delivered < rounds {
            match q.pop_timeout(Duration::from_millis(1)) {
                Ok(Some(_)) => delivered += 1,
                Ok(None) => panic!("queue closed unexpectedly"),
                Err(()) => {} // timed out with the queue open: retry
            }
        }
        producer.join().unwrap();
        assert_eq!(delivered, rounds);
        assert!(q.is_empty(), "every push must be delivered exactly once");
    }

    #[test]
    fn blocking_push_wakes_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(2).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let q = Arc::new(BoundedQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let mut expect: Vec<i32> =
            (0..4).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }

    #[test]
    fn reservations_count_against_capacity() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        q.try_reserve().unwrap();
        q.try_push(1).unwrap();
        // 1 item + 1 reservation = capacity: both lanes must refuse
        assert_eq!(q.try_push(2).unwrap_err().1, PushError::Full);
        assert_eq!(q.try_reserve(), Err(PushError::Full));
        // consuming the reservation fills the promised slot
        q.push_reserved(3).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn cancel_reservation_releases_slot() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.try_reserve().unwrap();
        assert_eq!(q.try_push(1).unwrap_err().1, PushError::Full);
        q.cancel_reservation();
        q.try_push(1).unwrap();
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn cancel_reservation_wakes_blocked_push() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        q.try_reserve().unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(9).is_ok());
        std::thread::sleep(Duration::from_millis(20));
        q.cancel_reservation();
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(9));
    }

    #[test]
    fn push_reserved_after_close_reports_closed() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.try_reserve().unwrap();
        q.close();
        assert_eq!(q.push_reserved(1).unwrap_err().1, PushError::Closed);
        // the reservation was released — no slot leaks
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn governor_enforces_default_quota() {
        let gov = TenantGovernor::new(2);
        let a = gov.try_admit("acme").unwrap();
        let _b = gov.try_admit("acme").unwrap();
        match gov.try_admit("acme") {
            Err(AdmitError::OverQuota) => {}
            Err(other) => panic!("expected OverQuota, got {other:?}"),
            Ok(_) => panic!("expected OverQuota, got a permit"),
        }
        // another tenant has its own bucket
        let _c = gov.try_admit("umbrella").unwrap();
        assert_eq!(gov.in_flight("acme"), 2);
        drop(a);
        assert_eq!(gov.in_flight("acme"), 1);
        let _d = gov.try_admit("acme").unwrap();
    }

    #[test]
    fn governor_per_tenant_override_and_ban() {
        let gov = TenantGovernor::new(8);
        gov.set_limit("noisy", 1);
        gov.set_limit("banned", 0);
        assert_eq!(gov.limit("noisy"), 1);
        assert_eq!(gov.limit("anyone-else"), 8);
        let held = gov.try_admit("noisy").unwrap();
        assert!(gov.try_admit("noisy").is_err());
        assert!(gov.try_admit("banned").is_err());
        assert_eq!(held.tenant(), "noisy");
    }
}
