//! Client wire protocol v2 — the grammar shared by the server's
//! reader/writer threads, [`super::client::EmbedClient`], and the
//! hostile-input tests.
//!
//! Verb lines stay text (debuggable with netcat); request/response
//! bodies are [`crate::shard::codec`] binary frames, so f64 transport is
//! bitwise by construction:
//!
//! ```text
//! -> HELLO2 tenant=acme          (once per connection; server echoes HELLO2)
//! -> EMBED2 id=7 code=ldc n=5 k=3
//! -> <labels frame: n i32 records>
//! -> <edges frame: 16-byte edge records>
//! <- OK id=7 rows=5 cols=3
//! <- <Z frame: rows*cols raw-bit f64 records>
//! ```
//!
//! Requests are pipelined: any number of `EMBED2` exchanges may be in
//! flight per connection and responses stream back **out of order**,
//! matched by `id`. Per-request failures are `ERR id=<id> <msg>`;
//! admission refusals are `BUSY id=<id> retry=<ms>` and arrive from the
//! request *header* alone — the body frames are drained within the
//! codec caps but never decoded into a graph. A protocol violation
//! (unparseable verb, duplicate in-flight id, mid-frame EOF) is
//! connection-fatal: a bare `ERR <msg>` (no id) and close, the
//! ERR-then-close discipline of `shard::remote` — after a framing error
//! there is no resync point.

//! The session lane adds four more verbs over the same framing (see
//! [`SessionHeader`] and friends): `SESS2` opens a resident session from
//! an `EMBED2`-shaped body, `DELTA2` streams batched edge
//! insert/delete/relabel records, `ROWS2` fetches chosen Z rows plus the
//! `applied`/`clean` staleness watermark, `CLOSE2` unregisters.
//!
//! The iterative lane adds `ITER2` (see [`IterHeader`]): an
//! `EMBED2`-shaped body whose labels frame seeds a self-clustering
//! embed→kmeans→relabel loop. The reply streams one `ROUND` progress
//! line per round, then the usual `OK` + final Z frame. One `ITER2` is
//! one admission — rounds never re-enter the queue.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::server::{MAX_WIRE_EDGES, MAX_WIRE_VERTICES};
use super::session::Delta;
use crate::gee::GeeOptions;
use crate::graph::Graph;
use crate::shard::codec::{
    self, DELTA_OP_DELETE, DELTA_OP_INSERT, DELTA_OP_RELABEL, DELTA_RECORD_BYTES,
    EDGE_RECORD_BYTES, LABEL_RECORD_BYTES,
};

/// The tenant v1 text connections (and HELLO2 without `tenant=`) bill to.
pub const DEFAULT_TENANT: &str = "default";

/// What `BUSY` tells the client to wait before retrying.
pub const RETRY_AFTER_MS: u64 = 50;

/// Format the connection greeting.
pub fn format_hello(tenant: Option<&str>) -> String {
    match tenant {
        Some(t) => format!("HELLO2 tenant={t}"),
        None => "HELLO2".to_string(),
    }
}

/// Parse a `HELLO2 [tenant=<name>]` line into the declared tenant.
/// Tenant names are bare ASCII-ish tokens (no whitespace, no `=`); they
/// key quota buckets and metrics, so junk is refused rather than binned.
pub fn parse_hello(line: &str) -> Result<String> {
    let mut parts = line.split_whitespace();
    if parts.next() != Some("HELLO2") {
        bail!("expected HELLO2, got '{line}'");
    }
    let mut tenant = DEFAULT_TENANT.to_string();
    for p in parts {
        let (key, val) = p.split_once('=').context("HELLO2 args are key=val")?;
        match key {
            "tenant" => {
                if val.is_empty() || !val.chars().all(|c| c.is_ascii_graphic() && c != '=') {
                    bail!("bad tenant name '{val}'");
                }
                tenant = val.to_string();
            }
            other => bail!("unknown HELLO2 arg '{other}'"),
        }
    }
    Ok(tenant)
}

/// One `EMBED2` request header — everything admission needs, no body.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestHeader {
    pub id: u64,
    pub options: GeeOptions,
    pub n: usize,
    pub k: usize,
}

pub fn format_request_header(h: &RequestHeader) -> String {
    format!("EMBED2 id={} code={} n={} k={}", h.id, h.options.code(), h.n, h.k)
}

/// Parse an `EMBED2` header. Dimension *bounds* are the server's call
/// (`validate_wire_dims` in its read loop) — a parse failure here is
/// connection-fatal because the body frames can no longer be trusted,
/// while an out-of-bounds-but-parseable header earns a request-scoped
/// `ERR id=` with the body drained.
pub fn parse_request_header(line: &str) -> Result<RequestHeader> {
    let mut parts = line.split_whitespace();
    if parts.next() != Some("EMBED2") {
        bail!("expected EMBED2, got '{line}'");
    }
    let mut id: Option<u64> = None;
    let mut code = "---".to_string();
    let mut n = 0usize;
    let mut k = 0usize;
    for p in parts {
        let (key, val) = p.split_once('=').context("EMBED2 args are key=val")?;
        match key {
            "id" => id = Some(val.parse().context("bad id")?),
            "code" => code = val.to_string(),
            "n" => n = val.parse().context("bad n")?,
            "k" => k = val.parse().context("bad k")?,
            other => bail!("unknown EMBED2 arg '{other}'"),
        }
    }
    let id = id.context("EMBED2 requires id=<u64>")?;
    let options = GeeOptions::from_code(&code).context("bad options code")?;
    Ok(RequestHeader { id, options, n, k })
}

/// Byte caps for the two request body frames, derived from the same
/// admission constants the v1 header gate enforces.
pub fn max_labels_frame_bytes() -> u64 {
    (MAX_WIRE_VERTICES * LABEL_RECORD_BYTES) as u64
}

pub fn max_edges_frame_bytes() -> u64 {
    MAX_WIRE_EDGES as u64 * EDGE_RECORD_BYTES as u64
}

/// Client side: the two body frames that follow an `EMBED2` header.
pub fn write_request_body(
    w: &mut impl Write,
    labels: &[i32],
    edges: &[(u32, u32, f64)],
) -> std::io::Result<()> {
    codec::write_frame_i32s(w, labels)?;
    codec::write_frame_len(w, (edges.len() * EDGE_RECORD_BYTES) as u64)?;
    for &(a, b, wt) in edges {
        codec::write_edge_record(w, a, b, wt)?;
    }
    Ok(())
}

/// Reset `g` to an `n`-vertex, `k`-class graph with no edges, keeping
/// every buffer's capacity — the decode target is reusable, so a warm
/// graph costs the steady state nothing.
pub fn reset_graph(g: &mut Graph, n: usize, k: usize) {
    g.n = n;
    g.k = k;
    g.src.clear();
    g.dst.clear();
    g.w.clear();
    g.labels.clear();
}

/// Server side: decode the two body frames into `g` (reset first). The
/// labels frame must be exactly `n` records; every label is validated on
/// ingest ([`codec::validate_label`]) and every edge endpoint
/// range-checked, mirroring the v1 text lane's checks record for record.
pub fn read_request_body_into(
    r: &mut impl Read,
    h: &RequestHeader,
    g: &mut Graph,
    scratch: &mut Vec<u8>,
) -> Result<()> {
    reset_graph(g, h.n, h.k);
    let len = codec::read_frame_len(r, "labels frame")?;
    codec::check_frame_len(
        len,
        LABEL_RECORD_BYTES,
        max_labels_frame_bytes(),
        Some((h.n * LABEL_RECORD_BYTES) as u64),
        "labels frame",
    )?;
    let k = h.k;
    let labels = &mut g.labels;
    codec::read_frame_body(r, len, scratch, "labels frame", |chunk| {
        for rec in chunk.chunks_exact(LABEL_RECORD_BYTES) {
            let l = i32::from_le_bytes(rec.try_into().unwrap());
            codec::validate_label(l, k)?;
            labels.push(l);
        }
        Ok(())
    })?;

    let len = codec::read_frame_len(r, "edges frame")?;
    codec::check_frame_len(len, EDGE_RECORD_BYTES, max_edges_frame_bytes(), None, "edges frame")?;
    let n = h.n;
    let (src, dst, w) = (&mut g.src, &mut g.dst, &mut g.w);
    codec::read_frame_body(r, len, scratch, "edges frame", |chunk| {
        for rec in chunk.chunks_exact(EDGE_RECORD_BYTES) {
            let (a, b, wt) = codec::decode_edge(rec);
            if a as usize >= n || b as usize >= n {
                bail!("edge {a}:{b} out of range (n={n})");
            }
            src.push(a);
            dst.push(b);
            w.push(wt);
        }
        Ok(())
    })?;
    Ok(())
}

/// Reject path: consume a refused request's two body frames — length
/// prefixes still validated against the codec caps, bodies read through
/// the reused chunk scratch and discarded. Nothing proportional to the
/// request is allocated, which is exactly what the counting-allocator
/// test pins.
pub fn drain_request_body(r: &mut impl Read, scratch: &mut Vec<u8>) -> Result<()> {
    let len = codec::read_frame_len(r, "labels frame")?;
    codec::check_frame_len(len, LABEL_RECORD_BYTES, max_labels_frame_bytes(), None, "labels frame")?;
    codec::read_frame_body(r, len, scratch, "labels frame", |_| Ok(()))?;
    let len = codec::read_frame_len(r, "edges frame")?;
    codec::check_frame_len(len, EDGE_RECORD_BYTES, max_edges_frame_bytes(), None, "edges frame")?;
    codec::read_frame_body(r, len, scratch, "edges frame", |_| Ok(()))
}

/// One server→client line of the v2 protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// `OK id=<id> rows=<r> cols=<c>`, followed by the Z frame.
    Ok { id: u64, rows: usize, cols: usize },
    /// `ERR id=<id> <msg>` — this request failed; the connection lives.
    Err { id: u64, msg: String },
    /// `BUSY id=<id> retry=<ms>` — admission refused; retry later.
    Busy { id: u64, retry_ms: u64 },
    /// `PONG` (health check).
    Pong,
    /// `ERR <msg>` with no id — connection-fatal; the server closes.
    Fatal(String),
}

pub fn format_ok(id: u64, rows: usize, cols: usize) -> String {
    format!("OK id={id} rows={rows} cols={cols}")
}

pub fn format_err(id: u64, msg: &str) -> String {
    format!("ERR id={id} {}", sanitize(msg))
}

pub fn format_busy(id: u64, retry_ms: u64) -> String {
    format!("BUSY id={id} retry={retry_ms}")
}

pub fn format_fatal(msg: &str) -> String {
    format!("ERR {}", sanitize(msg))
}

/// Error messages travel on a protocol line; embedded newlines would
/// desynchronize the stream.
fn sanitize(msg: &str) -> String {
    msg.replace(['\n', '\r'], " ")
}

fn parse_kv<T: std::str::FromStr>(tok: Option<&str>, key: &str, line: &str) -> Result<T> {
    let tok = tok.with_context(|| format!("reply '{line}' missing {key}=<v>"))?;
    let (k, v) = tok.split_once('=').with_context(|| format!("reply '{line}': bad {key} token"))?;
    if k != key {
        bail!("reply '{line}': expected {key}=, got {k}=");
    }
    v.parse().map_err(|_| anyhow::anyhow!("reply '{line}': bad {key} value"))
}

/// Parse one server reply line.
pub fn parse_reply(line: &str) -> Result<Reply> {
    let line = line.trim();
    if line == "PONG" {
        return Ok(Reply::Pong);
    }
    if let Some(rest) = line.strip_prefix("OK ") {
        let mut it = rest.split_whitespace();
        let id = parse_kv(it.next(), "id", line)?;
        let rows = parse_kv(it.next(), "rows", line)?;
        let cols = parse_kv(it.next(), "cols", line)?;
        return Ok(Reply::Ok { id, rows, cols });
    }
    if let Some(rest) = line.strip_prefix("BUSY ") {
        let mut it = rest.split_whitespace();
        let id = parse_kv(it.next(), "id", line)?;
        let retry_ms = parse_kv(it.next(), "retry", line)?;
        return Ok(Reply::Busy { id, retry_ms });
    }
    if let Some(rest) = line.strip_prefix("ERR ") {
        if let Some(idpart) = rest.split_whitespace().next() {
            if let Some(v) = idpart.strip_prefix("id=") {
                if let Ok(id) = v.parse::<u64>() {
                    let msg = rest[idpart.len()..].trim_start().to_string();
                    return Ok(Reply::Err { id, msg });
                }
            }
        }
        return Ok(Reply::Fatal(rest.to_string()));
    }
    bail!("unparseable reply line '{line}'");
}

// ---------------------------------------------------------- session verbs

/// Row-id records in a `ROWS2` request body are bare `u32`s.
pub const ROW_ID_RECORD_BYTES: usize = 4;

/// Hard cap on deltas per `DELTA2` frame — far above any sane batch, it
/// exists so a hostile count can't translate into an unbounded decode.
pub const MAX_FRAME_DELTAS: u64 = 1 << 22;

/// `SESS2` header: an `EMBED2`-shaped open (same body frames follow)
/// plus the optional per-session rescale threshold.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionHeader {
    pub id: u64,
    pub options: GeeOptions,
    pub n: usize,
    pub k: usize,
    /// `thresh=` — affected-row fraction above which a delta escalates
    /// to a full rescale pass; server default when absent.
    pub rescale_threshold: Option<f64>,
}

pub fn format_session_header(h: &SessionHeader) -> String {
    let mut s = format!("SESS2 id={} code={} n={} k={}", h.id, h.options.code(), h.n, h.k);
    if let Some(t) = h.rescale_threshold {
        s.push_str(&format!(" thresh={t}"));
    }
    s
}

/// Parse a `SESS2` header (same fatality contract as
/// [`parse_request_header`]: a parse failure is connection-fatal,
/// out-of-bounds dims are the server's to refuse request-scoped).
pub fn parse_session_header(line: &str) -> Result<SessionHeader> {
    let mut parts = line.split_whitespace();
    if parts.next() != Some("SESS2") {
        bail!("expected SESS2, got '{line}'");
    }
    let mut id: Option<u64> = None;
    let mut code = "---".to_string();
    let mut n = 0usize;
    let mut k = 0usize;
    let mut thresh: Option<f64> = None;
    for p in parts {
        let (key, val) = p.split_once('=').context("SESS2 args are key=val")?;
        match key {
            "id" => id = Some(val.parse().context("bad id")?),
            "code" => code = val.to_string(),
            "n" => n = val.parse().context("bad n")?,
            "k" => k = val.parse().context("bad k")?,
            "thresh" => {
                let t: f64 = val.parse().context("bad thresh")?;
                if !(0.0..=1.0).contains(&t) {
                    bail!("thresh {t} outside 0..=1");
                }
                thresh = Some(t);
            }
            other => bail!("unknown SESS2 arg '{other}'"),
        }
    }
    let id = id.context("SESS2 requires id=<u64>")?;
    let options = GeeOptions::from_code(&code).context("bad options code")?;
    Ok(SessionHeader { id, options, n, k, rescale_threshold: thresh })
}

/// `DELTA2` / `ROWS2` / `CLOSE2` headers share one shape: request id,
/// target session, and a body record count (0 for `CLOSE2`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionOpHeader {
    pub id: u64,
    pub sess: u64,
    pub count: u64,
}

pub fn format_delta_header(h: &SessionOpHeader) -> String {
    format!("DELTA2 id={} sess={} count={}", h.id, h.sess, h.count)
}

pub fn format_rows_header(h: &SessionOpHeader) -> String {
    format!("ROWS2 id={} sess={} count={}", h.id, h.sess, h.count)
}

pub fn format_close_header(id: u64, sess: u64) -> String {
    format!("CLOSE2 id={id} sess={sess}")
}

/// Parse a `DELTA2`/`ROWS2`/`CLOSE2` line (pass the expected verb).
/// `CLOSE2` takes no `count=`.
pub fn parse_session_op(line: &str, verb: &str) -> Result<SessionOpHeader> {
    let mut parts = line.split_whitespace();
    if parts.next() != Some(verb) {
        bail!("expected {verb}, got '{line}'");
    }
    let mut id: Option<u64> = None;
    let mut sess: Option<u64> = None;
    let mut count = 0u64;
    for p in parts {
        let (key, val) = p.split_once('=').with_context(|| format!("{verb} args are key=val"))?;
        match key {
            "id" => id = Some(val.parse().context("bad id")?),
            "sess" => sess = Some(val.parse().context("bad sess")?),
            "count" if verb != "CLOSE2" => count = val.parse().context("bad count")?,
            other => bail!("unknown {verb} arg '{other}'"),
        }
    }
    Ok(SessionOpHeader {
        id: id.with_context(|| format!("{verb} requires id=<u64>"))?,
        sess: sess.with_context(|| format!("{verb} requires sess=<u64>"))?,
        count,
    })
}

/// The wire fields of one delta record (op code, endpoints/label, weight).
pub fn delta_fields(d: &Delta) -> (u32, u32, u32, f64) {
    match *d {
        Delta::Insert { a, b, w } => (DELTA_OP_INSERT, a, b, w),
        Delta::Delete { a, b } => (DELTA_OP_DELETE, a, b, 0.0),
        Delta::Relabel { v, label } => (DELTA_OP_RELABEL, v, label as u32, 0.0),
    }
}

/// Decode one delta record's fields; unknown op codes are refused here,
/// semantic validity (vertex range, label range) is the session's call.
pub fn delta_from_fields(op: u32, a: u32, b: u32, w: f64) -> Result<Delta> {
    match op {
        DELTA_OP_INSERT => Ok(Delta::Insert { a, b, w }),
        DELTA_OP_DELETE => Ok(Delta::Delete { a, b }),
        DELTA_OP_RELABEL => Ok(Delta::Relabel { v: a, label: b as i32 }),
        other => bail!("unknown delta op {other}"),
    }
}

/// Client side: one `DELTA2` body frame.
pub fn write_delta_frame(w: &mut impl Write, deltas: &[Delta]) -> std::io::Result<()> {
    codec::write_frame_len(w, (deltas.len() * DELTA_RECORD_BYTES) as u64)?;
    for d in deltas {
        let (op, a, b, wt) = delta_fields(d);
        codec::write_delta_record(w, op, a, b, wt)?;
    }
    Ok(())
}

/// Server side: decode a `DELTA2` body of exactly `count` records into
/// `out` (cleared first). Frame-length mismatches are framing errors
/// (connection-fatal at the call site); an unknown op code arrives
/// inside a well-formed frame, so it surfaces as a normal error after
/// the body is fully consumed.
pub fn read_delta_frame(
    r: &mut impl Read,
    count: u64,
    scratch: &mut Vec<u8>,
    out: &mut Vec<Delta>,
) -> Result<()> {
    if count > MAX_FRAME_DELTAS {
        bail!("delta frame of {count} records exceeds the cap {MAX_FRAME_DELTAS}");
    }
    out.clear();
    let len = codec::read_frame_len(r, "delta frame")?;
    codec::check_frame_len(
        len,
        DELTA_RECORD_BYTES,
        MAX_FRAME_DELTAS * DELTA_RECORD_BYTES as u64,
        Some(count * DELTA_RECORD_BYTES as u64),
        "delta frame",
    )?;
    let mut bad: Option<String> = None;
    codec::read_frame_body(r, len, scratch, "delta frame", |chunk| {
        for rec in chunk.chunks_exact(DELTA_RECORD_BYTES) {
            let (op, a, b, w) = codec::decode_delta(rec);
            match delta_from_fields(op, a, b, w) {
                Ok(d) => out.push(d),
                Err(e) => bad = bad.take().or(Some(e.to_string())),
            }
        }
        Ok(())
    })?;
    if let Some(msg) = bad {
        bail!("{msg}");
    }
    Ok(())
}

/// Client side: one `ROWS2` body frame of row ids.
pub fn write_rows_frame(w: &mut impl Write, ids: &[u32]) -> std::io::Result<()> {
    codec::write_frame_len(w, (ids.len() * ROW_ID_RECORD_BYTES) as u64)?;
    for v in ids {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Server side: decode a `ROWS2` body of exactly `count` row ids.
pub fn read_rows_frame(
    r: &mut impl Read,
    count: u64,
    scratch: &mut Vec<u8>,
    out: &mut Vec<u32>,
) -> Result<()> {
    out.clear();
    let len = codec::read_frame_len(r, "row-ids frame")?;
    codec::check_frame_len(
        len,
        ROW_ID_RECORD_BYTES,
        (MAX_WIRE_VERTICES * ROW_ID_RECORD_BYTES) as u64,
        Some(count * ROW_ID_RECORD_BYTES as u64),
        "row-ids frame",
    )?;
    codec::read_frame_body(r, len, scratch, "row-ids frame", |chunk| {
        for rec in chunk.chunks_exact(ROW_ID_RECORD_BYTES) {
            out.push(u32::from_le_bytes(rec.try_into().unwrap()));
        }
        Ok(())
    })
}

/// Session reply lines (the session twins of [`Reply`]'s `OK`):
/// `SESS id= sess= rows= cols=`, `DACK id= applied= stale=`,
/// `ROWS id= rows= cols= applied= clean=` (+ Z frame), `CLOSED id=`.
pub fn format_sess_ok(id: u64, sess: u64, rows: usize, cols: usize) -> String {
    format!("SESS id={id} sess={sess} rows={rows} cols={cols}")
}

pub fn parse_sess_ok(line: &str) -> Result<(u64, u64, usize, usize)> {
    let rest = line.trim().strip_prefix("SESS ").context("expected SESS reply")?;
    let mut it = rest.split_whitespace();
    let id = parse_kv(it.next(), "id", line)?;
    let sess = parse_kv(it.next(), "sess", line)?;
    let rows = parse_kv(it.next(), "rows", line)?;
    let cols = parse_kv(it.next(), "cols", line)?;
    Ok((id, sess, rows, cols))
}

pub fn format_dack(id: u64, applied: u64, stale: u64) -> String {
    format!("DACK id={id} applied={applied} stale={stale}")
}

pub fn parse_dack(line: &str) -> Result<(u64, u64, u64)> {
    let rest = line.trim().strip_prefix("DACK ").context("expected DACK reply")?;
    let mut it = rest.split_whitespace();
    let id = parse_kv(it.next(), "id", line)?;
    let applied = parse_kv(it.next(), "applied", line)?;
    let stale = parse_kv(it.next(), "stale", line)?;
    Ok((id, applied, stale))
}

pub fn format_rows_ok(id: u64, rows: usize, cols: usize, applied: u64, clean: u64) -> String {
    format!("ROWS id={id} rows={rows} cols={cols} applied={applied} clean={clean}")
}

pub fn parse_rows_ok(line: &str) -> Result<(u64, usize, usize, u64, u64)> {
    let rest = line.trim().strip_prefix("ROWS ").context("expected ROWS reply")?;
    let mut it = rest.split_whitespace();
    let id = parse_kv(it.next(), "id", line)?;
    let rows = parse_kv(it.next(), "rows", line)?;
    let cols = parse_kv(it.next(), "cols", line)?;
    let applied = parse_kv(it.next(), "applied", line)?;
    let clean = parse_kv(it.next(), "clean", line)?;
    Ok((id, rows, cols, applied, clean))
}

pub fn format_closed(id: u64) -> String {
    format!("CLOSED id={id}")
}

pub fn parse_closed(line: &str) -> Result<u64> {
    let rest = line.trim().strip_prefix("CLOSED ").context("expected CLOSED reply")?;
    parse_kv(rest.split_whitespace().next(), "id", line)
}

// --------------------------------------------------------- iterative verbs

/// Hard cap on `rounds=` — far above any converging job; bounds the
/// work a single hostile header can demand.
pub const MAX_WIRE_ROUNDS: usize = 10_000;

/// `ITER2` header: an `EMBED2`-shaped request (same two body frames —
/// the labels frame carries the *initial* labels, usually random) whose
/// reply is a self-clustering run: per-round `ROUND` progress lines,
/// then `OK` + the final Z frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IterHeader {
    pub id: u64,
    pub options: GeeOptions,
    pub n: usize,
    pub k: usize,
    /// `rounds=` — embed→kmeans→relabel round cap; server default when 0.
    pub rounds: usize,
    /// `tol=` — stop once the changed-label fraction drops to this; 0
    /// demands a full fixpoint.
    pub tol: f64,
}

pub fn format_iter_header(h: &IterHeader) -> String {
    let mut s = format!("ITER2 id={} code={} n={} k={}", h.id, h.options.code(), h.n, h.k);
    if h.rounds > 0 {
        s.push_str(&format!(" rounds={}", h.rounds));
    }
    if h.tol > 0.0 {
        s.push_str(&format!(" tol={}", h.tol));
    }
    s
}

/// Parse an `ITER2` header (fatality contract of
/// [`parse_request_header`]; `rounds`/`tol` are range-checked here like
/// `SESS2`'s `thresh`).
pub fn parse_iter_header(line: &str) -> Result<IterHeader> {
    let mut parts = line.split_whitespace();
    if parts.next() != Some("ITER2") {
        bail!("expected ITER2, got '{line}'");
    }
    let mut id: Option<u64> = None;
    let mut code = "---".to_string();
    let mut n = 0usize;
    let mut k = 0usize;
    let mut rounds = 0usize;
    let mut tol = 0.0f64;
    for p in parts {
        let (key, val) = p.split_once('=').context("ITER2 args are key=val")?;
        match key {
            "id" => id = Some(val.parse().context("bad id")?),
            "code" => code = val.to_string(),
            "n" => n = val.parse().context("bad n")?,
            "k" => k = val.parse().context("bad k")?,
            "rounds" => {
                rounds = val.parse().context("bad rounds")?;
                if rounds > MAX_WIRE_ROUNDS {
                    bail!("rounds {rounds} over the cap {MAX_WIRE_ROUNDS}");
                }
            }
            "tol" => {
                tol = val.parse().context("bad tol")?;
                if !(0.0..=1.0).contains(&tol) {
                    bail!("tol {tol} outside 0..=1");
                }
            }
            other => bail!("unknown ITER2 arg '{other}'"),
        }
    }
    let id = id.context("ITER2 requires id=<u64>")?;
    let options = GeeOptions::from_code(&code).context("bad options code")?;
    Ok(IterHeader { id, options, n, k, rounds, tol })
}

/// One per-round progress line of an `ITER2` reply:
/// `ROUND id= r= changed= ari= inertia= iters=`. Floats travel as Rust's
/// shortest round-trippable decimal, so parse recovers the exact bits.
pub fn format_round(id: u64, rs: &crate::gee::iterate::RoundState) -> String {
    format!(
        "ROUND id={id} r={} changed={} ari={} inertia={} iters={}",
        rs.round, rs.changed, rs.ari_vs_prev, rs.inertia, rs.kmeans_iters
    )
}

pub fn parse_round(line: &str) -> Result<(u64, crate::gee::iterate::RoundState)> {
    let rest = line.trim().strip_prefix("ROUND ").context("expected ROUND reply")?;
    let mut it = rest.split_whitespace();
    let id = parse_kv(it.next(), "id", line)?;
    let round = parse_kv(it.next(), "r", line)?;
    let changed = parse_kv(it.next(), "changed", line)?;
    let ari_vs_prev = parse_kv(it.next(), "ari", line)?;
    let inertia = parse_kv(it.next(), "inertia", line)?;
    let kmeans_iters = parse_kv(it.next(), "iters", line)?;
    Ok((
        id,
        crate::gee::iterate::RoundState { round, changed, ari_vs_prev, inertia, kmeans_iters },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn hello_round_trip() {
        assert_eq!(parse_hello(&format_hello(None)).unwrap(), DEFAULT_TENANT);
        assert_eq!(parse_hello(&format_hello(Some("acme"))).unwrap(), "acme");
        assert!(parse_hello("HELLO2 tenant=").is_err());
        assert!(parse_hello("HELLO2 tenant=two words").is_err());
        assert!(parse_hello("HELLO2 color=red").is_err());
        assert!(parse_hello("HELLO").is_err());
    }

    #[test]
    fn request_header_round_trip_and_bounds() {
        let h = RequestHeader { id: 42, options: GeeOptions::ALL, n: 30, k: 3 };
        let parsed = parse_request_header(&format_request_header(&h)).unwrap();
        assert_eq!(parsed, h);
        assert!(parse_request_header("EMBED2 code=ldc n=3 k=2").is_err(), "id is mandatory");
        assert!(parse_request_header("EMBED2 id=1 code=ldc n=3 k=2 zap=1").is_err());
        assert!(parse_request_header("EMBED code=ldc n=3 k=2").is_err());
        // oversize dims still *parse* — the server's read loop bounds
        // them, so it can drain the body and fail just that request
        let huge = format!("EMBED2 id=1 code=--- n={} k=2", MAX_WIRE_VERTICES + 1);
        assert_eq!(parse_request_header(&huge).unwrap().n, MAX_WIRE_VERTICES + 1);
    }

    #[test]
    fn body_round_trip_into_warm_graph() {
        let labels = vec![0, 1, -1, 2];
        let edges = vec![(0u32, 1u32, 1.5f64), (2, 3, 0.25), (3, 3, 2.0)];
        let mut buf = Vec::new();
        write_request_body(&mut buf, &labels, &edges).unwrap();
        let h = RequestHeader { id: 1, options: GeeOptions::NONE, n: 4, k: 3 };
        let mut g = Graph::new(0, 0);
        let mut scratch = Vec::new();
        read_request_body_into(&mut Cursor::new(&buf), &h, &mut g, &mut scratch).unwrap();
        assert_eq!((g.n, g.k), (4, 3));
        assert_eq!(g.labels, labels);
        assert_eq!(g.src, vec![0, 2, 3]);
        assert_eq!(g.dst, vec![1, 3, 3]);
        assert_eq!(g.w, vec![1.5, 0.25, 2.0]);
        // decode again into the same graph: same result, buffers reused
        read_request_body_into(&mut Cursor::new(&buf), &h, &mut g, &mut scratch).unwrap();
        assert_eq!(g.labels, labels);
        assert_eq!(g.w, vec![1.5, 0.25, 2.0]);
    }

    #[test]
    fn body_rejects_bad_records() {
        let h = RequestHeader { id: 1, options: GeeOptions::NONE, n: 2, k: 2 };
        let mut g = Graph::new(0, 0);
        let mut scratch = Vec::new();
        // wrong label count (frame length != n records)
        let mut buf = Vec::new();
        write_request_body(&mut buf, &[0, 1, 0], &[]).unwrap();
        assert!(read_request_body_into(&mut Cursor::new(&buf), &h, &mut g, &mut scratch).is_err());
        // label out of range
        let mut buf = Vec::new();
        write_request_body(&mut buf, &[0, 5], &[]).unwrap();
        assert!(read_request_body_into(&mut Cursor::new(&buf), &h, &mut g, &mut scratch).is_err());
        // edge endpoint out of range
        let mut buf = Vec::new();
        write_request_body(&mut buf, &[0, 1], &[(0, 9, 1.0)]).unwrap();
        assert!(read_request_body_into(&mut Cursor::new(&buf), &h, &mut g, &mut scratch).is_err());
    }

    #[test]
    fn drain_consumes_exactly_one_body() {
        let mut buf = Vec::new();
        write_request_body(&mut buf, &[0, 1], &[(0, 1, 1.0)]).unwrap();
        write_request_body(&mut buf, &[1, 0], &[(1, 0, 2.0)]).unwrap();
        let mut cur = Cursor::new(&buf);
        let mut scratch = Vec::new();
        drain_request_body(&mut cur, &mut scratch).unwrap();
        // the second body is intact after the first is drained
        let h = RequestHeader { id: 2, options: GeeOptions::NONE, n: 2, k: 2 };
        let mut g = Graph::new(0, 0);
        read_request_body_into(&mut cur, &h, &mut g, &mut scratch).unwrap();
        assert_eq!(g.labels, vec![1, 0]);
        assert_eq!(g.w, vec![2.0]);
    }

    #[test]
    fn reply_lines_round_trip() {
        assert_eq!(
            parse_reply(&format_ok(7, 30, 3)).unwrap(),
            Reply::Ok { id: 7, rows: 30, cols: 3 }
        );
        assert_eq!(
            parse_reply(&format_err(9, "bad label\nline two")).unwrap(),
            Reply::Err { id: 9, msg: "bad label line two".into() }
        );
        assert_eq!(
            parse_reply(&format_busy(3, 50)).unwrap(),
            Reply::Busy { id: 3, retry_ms: 50 }
        );
        assert_eq!(parse_reply("PONG").unwrap(), Reply::Pong);
        assert_eq!(
            parse_reply(&format_fatal("duplicate in-flight id 4")).unwrap(),
            Reply::Fatal("duplicate in-flight id 4".into())
        );
        // an ERR whose message merely *starts* with id-like text but has
        // no parseable id stays fatal
        assert_eq!(
            parse_reply("ERR id=x broken").unwrap(),
            Reply::Fatal("id=x broken".into())
        );
        assert!(parse_reply("WAT 1 2").is_err());
    }

    #[test]
    fn oversized_frame_prefix_is_rejected_before_read() {
        // a drained body must still honor the codec caps: a declared-huge
        // labels frame fails at the prefix, no body bytes consumed
        let mut buf = Vec::new();
        codec::write_frame_len(&mut buf, max_labels_frame_bytes() + 4).unwrap();
        let mut scratch = Vec::new();
        let err = drain_request_body(&mut Cursor::new(&buf), &mut scratch).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds the wire limit"), "{err:#}");
    }

    #[test]
    fn session_header_round_trip() {
        let h = SessionHeader {
            id: 11,
            options: GeeOptions::ALL,
            n: 40,
            k: 3,
            rescale_threshold: Some(0.5),
        };
        assert_eq!(parse_session_header(&format_session_header(&h)).unwrap(), h);
        let bare = SessionHeader { rescale_threshold: None, ..h };
        assert_eq!(parse_session_header(&format_session_header(&bare)).unwrap(), bare);
        assert!(parse_session_header("SESS2 code=ldc n=3 k=2").is_err(), "id mandatory");
        assert!(parse_session_header("SESS2 id=1 code=ldc n=3 k=2 thresh=1.5").is_err());
        assert!(parse_session_header("SESS2 id=1 code=zzz n=3 k=2").is_err());
        assert!(parse_session_header("EMBED2 id=1 code=ldc n=3 k=2").is_err());
    }

    #[test]
    fn session_op_headers_round_trip() {
        let h = SessionOpHeader { id: 4, sess: 9, count: 128 };
        assert_eq!(parse_session_op(&format_delta_header(&h), "DELTA2").unwrap(), h);
        assert_eq!(parse_session_op(&format_rows_header(&h), "ROWS2").unwrap(), h);
        let c = parse_session_op(&format_close_header(5, 9), "CLOSE2").unwrap();
        assert_eq!((c.id, c.sess, c.count), (5, 9, 0));
        assert!(parse_session_op("DELTA2 id=1 count=2", "DELTA2").is_err(), "sess mandatory");
        assert!(parse_session_op("CLOSE2 id=1 sess=2 count=3", "CLOSE2").is_err());
        assert!(parse_session_op("ROWS2 id=1 sess=2 zap=3", "ROWS2").is_err());
    }

    #[test]
    fn delta_frame_round_trips_bitwise() {
        let deltas = vec![
            Delta::Insert { a: 1, b: 2, w: 0.1 + 0.2 },
            Delta::Delete { a: 2, b: 2 },
            Delta::Relabel { v: 7, label: -1 },
            Delta::Relabel { v: 8, label: 3 },
        ];
        let mut buf = Vec::new();
        write_delta_frame(&mut buf, &deltas).unwrap();
        let (mut scratch, mut out) = (Vec::new(), Vec::new());
        read_delta_frame(&mut Cursor::new(&buf), 4, &mut scratch, &mut out).unwrap();
        assert_eq!(out, deltas);
        // count mismatch is a framing error
        assert!(read_delta_frame(&mut Cursor::new(&buf), 3, &mut scratch, &mut out).is_err());
        // unknown op code inside a well-formed frame errors after the
        // body is consumed (request-scoped at the server)
        let mut buf = Vec::new();
        codec::write_frame_len(&mut buf, DELTA_RECORD_BYTES as u64).unwrap();
        codec::write_delta_record(&mut buf, 99, 0, 1, 1.0).unwrap();
        let err =
            read_delta_frame(&mut Cursor::new(&buf), 1, &mut scratch, &mut out).unwrap_err();
        assert!(err.to_string().contains("unknown delta op 99"), "{err:#}");
    }

    #[test]
    fn rows_frame_round_trips() {
        let ids = vec![0u32, 7, 3, u32::MAX];
        let mut buf = Vec::new();
        write_rows_frame(&mut buf, &ids).unwrap();
        let (mut scratch, mut out) = (Vec::new(), Vec::new());
        read_rows_frame(&mut Cursor::new(&buf), 4, &mut scratch, &mut out).unwrap();
        assert_eq!(out, ids);
        assert!(read_rows_frame(&mut Cursor::new(&buf), 5, &mut scratch, &mut out).is_err());
    }

    #[test]
    fn iter_header_round_trip_and_bounds() {
        let h = IterHeader {
            id: 13,
            options: GeeOptions::ALL,
            n: 50,
            k: 4,
            rounds: 12,
            tol: 0.01,
        };
        assert_eq!(parse_iter_header(&format_iter_header(&h)).unwrap(), h);
        // defaults (rounds=0, tol=0) are omitted from the line and
        // recovered on parse
        let bare = IterHeader { rounds: 0, tol: 0.0, ..h };
        let line = format_iter_header(&bare);
        assert!(!line.contains("rounds=") && !line.contains("tol="), "{line}");
        assert_eq!(parse_iter_header(&line).unwrap(), bare);
        assert!(parse_iter_header("ITER2 code=ldc n=3 k=2").is_err(), "id mandatory");
        assert!(parse_iter_header("ITER2 id=1 code=ldc n=3 k=2 tol=1.5").is_err());
        assert!(
            parse_iter_header(&format!(
                "ITER2 id=1 code=ldc n=3 k=2 rounds={}",
                MAX_WIRE_ROUNDS + 1
            ))
            .is_err()
        );
        assert!(parse_iter_header("ITER2 id=1 code=ldc n=3 k=2 zap=1").is_err());
        assert!(parse_iter_header("EMBED2 id=1 code=ldc n=3 k=2").is_err());
    }

    #[test]
    fn round_line_round_trips_float_bits() {
        let rs = crate::gee::iterate::RoundState {
            round: 3,
            changed: 17,
            ari_vs_prev: 0.1 + 0.2, // not exactly representable in decimal
            inertia: 12345.678901234567,
            kmeans_iters: 9,
        };
        let (id, back) = parse_round(&format_round(7, &rs)).unwrap();
        assert_eq!(id, 7);
        assert_eq!(back.round, rs.round);
        assert_eq!(back.changed, rs.changed);
        assert_eq!(back.ari_vs_prev.to_bits(), rs.ari_vs_prev.to_bits());
        assert_eq!(back.inertia.to_bits(), rs.inertia.to_bits());
        assert_eq!(back.kmeans_iters, rs.kmeans_iters);
        assert!(parse_round("OK id=1 rows=2 cols=3").is_err());
        assert!(parse_round("ROUND id=1 r=x changed=0 ari=0 inertia=0 iters=0").is_err());
    }

    #[test]
    fn session_reply_lines_round_trip() {
        assert_eq!(parse_sess_ok(&format_sess_ok(1, 9, 40, 3)).unwrap(), (1, 9, 40, 3));
        assert_eq!(parse_dack(&format_dack(2, 17, 5)).unwrap(), (2, 17, 5));
        assert_eq!(
            parse_rows_ok(&format_rows_ok(3, 8, 3, 17, 12)).unwrap(),
            (3, 8, 3, 17, 12)
        );
        assert_eq!(parse_closed(&format_closed(4)).unwrap(), 4);
        assert!(parse_sess_ok("DACK id=1 applied=2 stale=0").is_err());
        assert!(parse_dack("DACK id=1 applied=x stale=0").is_err());
    }
}
