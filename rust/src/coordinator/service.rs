//! The embedding service: bounded admission queue → worker lanes →
//! dynamic batcher → engine (native sparse GEE or PJRT artifacts) →
//! reply channels + metrics.
//!
//! Lanes:
//! * **native** — a pool of threads running the in-process engines
//!   (`Engine::Sparse*` etc.). Handles any graph size.
//! * **pjrt** — one dedicated thread owning the PJRT [`Runtime`] (its
//!   handles are not `Send`); serves graphs that fit an artifact bucket
//!   and falls back to the native engine for oversize requests.
//!
//! Batching: workers drain the queue for up to `batch_linger`, group
//! drained jobs by option combo, pack each group into disjoint-union
//! batches (see [`super::batcher`] for why the union is exact), embed
//! once per batch, and split the replies. With batching off every job is
//! solo. Shutdown is graceful: queued work completes, then workers exit.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{
    build_union_into, plan_batches, split_member, BatchCapacity, PackedBatch, UnionPool,
};
use super::metrics::Metrics;
use super::queue::{AdmitError, BoundedQueue, PushError, TenantGovernor, TenantPermit};
use super::session::SessionRegistry;
use crate::gee::workspace::WorkspacePool;
use crate::gee::{Engine, GeeOptions};
use crate::graph::Graph;
use crate::runtime::Runtime;
use crate::sparse::Dense;
use crate::util::retry::Deadlines;

/// Which compute lane serves requests.
#[derive(Clone, Debug)]
pub enum Lane {
    /// In-process engines only.
    Native(Engine),
    /// PJRT artifacts from this directory, native fallback for oversize.
    Pjrt { artifact_dir: std::path::PathBuf, fallback: Engine },
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub lane: Lane,
    /// Native worker threads (the PJRT lane always adds its own single
    /// dedicated thread).
    pub workers: usize,
    pub queue_depth: usize,
    /// Enable disjoint-union dynamic batching.
    pub batching: bool,
    pub batch_capacity: BatchCapacity,
    /// How long a worker lingers draining the queue to fill a batch.
    pub batch_linger: Duration,
    /// Intra-op threads for large solo graphs: a graph too big for the
    /// batcher (the `pack_graphs` oversize lane) with at least
    /// `intra_op_min_edges` directed edges is embedded by the row-parallel
    /// engine (`Engine::SparsePar`) with this many threads, instead of
    /// pinning a single worker while the rest of the pool idles.
    /// 0 or 1 disables intra-op parallelism. Each busy worker can route
    /// independently, so burst compute concurrency is up to
    /// `workers × intra_op_threads` (the engine additionally caps its
    /// thread count at the machine's available parallelism); size the two
    /// knobs together.
    pub intra_op_threads: usize,
    /// Directed-edge threshold for the intra-op routing above.
    pub intra_op_min_edges: usize,
    /// Directed-edge count above which an oversize solo graph routes to
    /// the vertex-range-sharded engine (`Engine::Sharded`) instead of the
    /// in-core lanes. Defaults to the u32 index budget: graphs the
    /// in-core engines would *reject* with `IndexOverflow` now embed via
    /// the sharded lane (each shard's structure fits u32 even when the
    /// whole graph does not). Lower it to shard earlier, e.g. for memory
    /// headroom.
    pub shard_min_directed_edges: usize,
    /// Shard count for the sharded lane (0 = auto: one per core).
    pub shard_count: usize,
    /// Remote shard-fleet endpoints (`host:port` of `gee shard-serve`
    /// daemons). When non-empty, a job past `shard_min_directed_edges`
    /// is spilled and dispatched across the fleet (`via =
    /// "sharded-remote"`, bitwise-identical to the local lanes) instead
    /// of embedding on this machine; if the *whole* fleet is
    /// unreachable the job falls back to the local sharded engine and
    /// `Metrics::remote_fallbacks` is incremented. Empty = keep
    /// everything local.
    pub shard_remote_workers: Vec<String>,
    /// Force the v1 *text* wire to the shard fleet instead of letting
    /// each connection negotiate the binary protocol (`HELLO2`) — the
    /// ops escape hatch while a protocol regression is diagnosed.
    /// Numerics are identical either way; only bytes moved differ
    /// (compare `Metrics::remote_bytes` across the two settings).
    pub shard_wire_text: bool,
    /// Per-tenant in-flight token budget for wire admission
    /// ([`EmbedService::try_admit`]). Each admitted request holds one of
    /// its tenant's tokens until the reply is sent; a tenant at quota
    /// gets `BUSY` from the request header alone. v1 text clients share
    /// the "default" tenant bucket.
    pub tenant_tokens: usize,
    /// Background fast-lane threads draining dirty resident sessions
    /// ([`super::session::SessionRegistry`]). 0 disables the session
    /// lane entirely: `SESS2`/`DELTA2`/`ROWS2`/`CLOSE2` earn a
    /// request-scoped `ERR` instead of a registry.
    pub session_workers: usize,
    /// Per-tenant cap on concurrently open sessions (each held for the
    /// session's lifetime — long-lived, so separate from the per-request
    /// `tenant_tokens` budget).
    pub session_quota: usize,
    /// Default affected-row fraction above which a session delta
    /// escalates to a full rescale pass; a `SESS2 thresh=` overrides it
    /// per session.
    pub session_rescale_threshold: f64,
    /// Per-phase wire budgets applied to every accepted connection
    /// ([`super::server::TcpServer`]): `header` bounds the silent wait
    /// for the next verb line (idle reap / slow-loris defence), `frame`
    /// bounds each read while a request body streams, and writes. The
    /// `connect`/`hello`/`compute` fields are client-side knobs and are
    /// ignored here.
    pub wire_deadlines: Deadlines,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            lane: Lane::Native(Engine::SparseFast),
            workers: 2,
            queue_depth: 256,
            batching: true,
            batch_capacity: BatchCapacity::from_bucket(2_048, 16_384, 16),
            batch_linger: Duration::from_millis(2),
            intra_op_threads: 0,
            intra_op_min_edges: 500_000,
            shard_min_directed_edges: crate::sparse::MAX_INDEX,
            shard_count: 0,
            shard_remote_workers: Vec::new(),
            shard_wire_text: false,
            tenant_tokens: 64,
            session_workers: 0,
            session_quota: 4,
            session_rescale_threshold: 0.25,
            wire_deadlines: Deadlines::default(),
        }
    }
}

/// One embedding request.
#[derive(Clone, Debug)]
pub struct EmbedRequest {
    pub graph: Graph,
    pub options: GeeOptions,
}

/// The reply.
#[derive(Clone, Debug)]
pub struct EmbedResponse {
    pub z: Dense,
    /// Queue + compute time, as observed by the worker.
    pub latency: Duration,
    /// "native" / "native-par" / "pjrt" / "native-fallback".
    pub via: &'static str,
    /// How many requests shared the execution (1 = solo).
    pub batch_size: usize,
}

/// Where a job's reply goes. The blocking `submit` API hands back an
/// mpsc receiver (one reply per channel); the multiplexed wire instead
/// registers a callback that forwards the reply — tagged with its
/// request id — to the connection's writer thread, so many in-flight
/// requests share one socket without a thread parked per request.
#[derive(Clone)]
pub enum ReplySink {
    Channel(mpsc::Sender<Result<EmbedResponse>>),
    Callback(Arc<dyn Fn(Result<EmbedResponse>) + Send + Sync>),
}

impl ReplySink {
    /// A sink/receiver pair for one-shot request/response callers.
    pub fn channel() -> (ReplySink, mpsc::Receiver<Result<EmbedResponse>>) {
        let (tx, rx) = mpsc::channel();
        (ReplySink::Channel(tx), rx)
    }

    /// A sink that invokes `f` on the worker thread when the reply is
    /// ready. `f` must be cheap and non-blocking (typically an mpsc send
    /// to a writer thread).
    pub fn callback<F>(f: F) -> ReplySink
    where
        F: Fn(Result<EmbedResponse>) + Send + Sync + 'static,
    {
        ReplySink::Callback(Arc::new(f))
    }

    fn send(&self, r: Result<EmbedResponse>) {
        match self {
            // receiver may have hung up; dropping the reply is correct
            ReplySink::Channel(tx) => {
                let _ = tx.send(r);
            }
            ReplySink::Callback(f) => f(r),
        }
    }
}

/// Parameters of a multi-round self-clustering job (the `ITER2` wire
/// verb and `--engine cluster`). One admission covers the whole job:
/// the tenant permit is held from submit to final reply and the job
/// occupies exactly one queue slot — rounds run inside the worker and
/// never re-enter the queue.
#[derive(Clone)]
pub struct IterSpec {
    /// Embed→kmeans→relabel round cap (0 = the driver default).
    pub rounds: usize,
    /// Stop once the changed-label fraction drops to this (0 = full
    /// fixpoint).
    pub tol: f64,
    /// Invoked on the worker thread after every round — must be cheap
    /// and non-blocking (typically an mpsc send to a writer thread).
    pub on_round: Arc<dyn Fn(&crate::gee::iterate::RoundState) + Send + Sync>,
}

struct Job {
    req: EmbedRequest,
    submitted: Instant,
    reply: ReplySink,
    /// Tenant quota token held until the job (and thus its reply) is
    /// done; `None` for the legacy in-process submit APIs. Never read —
    /// it exists for its Drop.
    _permit: Option<TenantPermit>,
    /// `Some` turns the request into an iterative self-clustering job:
    /// the labels in `req.graph` seed the loop, the reply carries the
    /// final-round Z.
    iter: Option<IterSpec>,
}

/// Handle to a running service.
pub struct EmbedService {
    queue: Arc<BoundedQueue<Job>>,
    metrics: Arc<Metrics>,
    /// Shared pool of warmed embed workspaces: each worker checks one out
    /// for its lifetime, so steady-state serving performs no per-request
    /// scratch allocation (only the response Z buffer is fresh).
    pool: Arc<WorkspacePool>,
    /// Shared pool of warmed union buffers — the batching twin of `pool`
    /// (ROADMAP "pool build_union"): workers hold one for their lifetime
    /// so steady-state batch packing reuses union-graph capacity.
    unions: Arc<UnionPool>,
    /// Per-tenant token quotas for the wire admission path.
    governor: Arc<TenantGovernor>,
    /// Resident-session registry + fast-lane refresh workers; `None`
    /// when the config asked for zero session workers.
    sessions: Option<Arc<SessionRegistry>>,
    /// Default rescale threshold for sessions opened without `thresh=`.
    session_rescale_threshold: f64,
    /// Per-phase wire budgets the TCP front door applies to every
    /// accepted connection.
    wire_deadlines: Deadlines,
    handles: Vec<JoinHandle<()>>,
}

/// A granted admission: one reserved queue slot plus (for wire callers)
/// one tenant token. Dropping it unconsumed returns the slot; passing it
/// to [`EmbedService::submit_admitted`] converts it into a queued job.
/// Holding an `Admission` performs no allocation proportional to the
/// request body — that is the point: it is acquired from the request
/// *header*, before any edge buffer exists.
pub struct Admission {
    queue: Arc<BoundedQueue<Job>>,
    permit: Option<TenantPermit>,
    consumed: bool,
}

impl Drop for Admission {
    fn drop(&mut self) {
        if !self.consumed {
            self.queue.cancel_reservation();
        }
    }
}

impl EmbedService {
    /// Spawn workers and return the handle.
    pub fn start(cfg: ServiceConfig) -> EmbedService {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_depth));
        let metrics = Arc::new(Metrics::new());
        let pool = WorkspacePool::new();
        let unions = UnionPool::new();
        let governor = TenantGovernor::new(cfg.tenant_tokens.max(1));
        let sessions = if cfg.session_workers > 0 {
            Some(SessionRegistry::start(cfg.session_workers, cfg.session_quota, metrics.clone()))
        } else {
            None
        };
        let session_rescale_threshold = cfg.session_rescale_threshold.clamp(0.0, 1.0);
        let wire_deadlines = cfg.wire_deadlines.clone();
        let mut handles = Vec::new();

        match &cfg.lane {
            Lane::Native(engine) => {
                for _ in 0..cfg.workers.max(1) {
                    let q = queue.clone();
                    let m = metrics.clone();
                    let cfg = cfg.clone();
                    let p = pool.clone();
                    let u = unions.clone();
                    let engine = *engine;
                    handles.push(std::thread::spawn(move || {
                        native_worker(&q, &m, &cfg, engine, &p, &u);
                    }));
                }
            }
            Lane::Pjrt { artifact_dir, fallback } => {
                let q = queue.clone();
                let m = metrics.clone();
                let cfg_pjrt = cfg.clone();
                let dir = artifact_dir.clone();
                let p = pool.clone();
                let u = unions.clone();
                let fallback = *fallback;
                handles.push(std::thread::spawn(move || {
                    pjrt_worker(&q, &m, &cfg_pjrt, &dir, fallback, &p, &u);
                }));
                // extra native workers drain overflow alongside
                for _ in 1..cfg.workers {
                    let q = queue.clone();
                    let m = metrics.clone();
                    let cfg = cfg.clone();
                    let p = pool.clone();
                    let u = unions.clone();
                    handles.push(std::thread::spawn(move || {
                        native_worker(&q, &m, &cfg, fallback, &p, &u);
                    }));
                }
            }
        }
        EmbedService {
            queue,
            metrics,
            pool,
            unions,
            governor,
            sessions,
            session_rescale_threshold,
            wire_deadlines,
            handles,
        }
    }

    /// Submit with backpressure: `Err` means the queue is full/closed and
    /// the caller should retry or shed load.
    pub fn try_submit(
        &self,
        req: EmbedRequest,
    ) -> Result<mpsc::Receiver<Result<EmbedResponse>>, PushError> {
        let (reply, rx) = ReplySink::channel();
        let job = Job { req, submitted: Instant::now(), reply, _permit: None, iter: None };
        match self.queue.try_push(job) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err((_, e)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Blocking submit (waits for queue space).
    pub fn submit(
        &self,
        req: EmbedRequest,
    ) -> Result<mpsc::Receiver<Result<EmbedResponse>>, PushError> {
        let (reply, rx) = ReplySink::channel();
        let job = Job { req, submitted: Instant::now(), reply, _permit: None, iter: None };
        match self.queue.push(job) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err((_, e)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Wire-path admission from the request *header* alone: take one of
    /// `tenant`'s quota tokens and reserve one queue slot, before any
    /// request body is read or allocated. Rejections are counted against
    /// the tenant ([`super::metrics::TenantCounters`]) and the global
    /// `rejected` gauge; the caller turns them into `BUSY` on the wire.
    pub fn try_admit(&self, tenant: &str) -> Result<Admission, AdmitError> {
        let tc = self.metrics.tenant(tenant);
        let permit = match self.governor.try_admit(tenant) {
            Ok(p) => p,
            Err(e) => {
                tc.rejected_quota.fetch_add(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        match self.queue.try_reserve() {
            Ok(()) => {
                tc.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(Admission { queue: self.queue.clone(), permit: Some(permit), consumed: false })
            }
            Err(PushError::Full) => {
                tc.rejected_backpressure.fetch_add(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(AdmitError::Backpressure)
            }
            Err(PushError::Closed) => Err(AdmitError::Closed),
        }
    }

    /// Queue a request under a previously granted [`Admission`]. Cannot
    /// hit backpressure (the slot was reserved); fails only if the
    /// service shut down in between.
    pub fn submit_admitted(
        &self,
        admission: Admission,
        req: EmbedRequest,
        reply: ReplySink,
    ) -> Result<(), PushError> {
        self.push_admitted(admission, req, reply, None)
    }

    /// [`submit_admitted`](Self::submit_admitted) for a multi-round
    /// self-clustering job: `req.graph.labels` seed the loop, `spec`
    /// bounds it, and `spec.on_round` streams per-round progress. The
    /// single [`Admission`] covers every round — the tenant permit and
    /// queue slot are held for the job's whole lifetime.
    pub fn submit_admitted_iter(
        &self,
        admission: Admission,
        req: EmbedRequest,
        spec: IterSpec,
        reply: ReplySink,
    ) -> Result<(), PushError> {
        self.push_admitted(admission, req, reply, Some(spec))
    }

    fn push_admitted(
        &self,
        mut admission: Admission,
        req: EmbedRequest,
        reply: ReplySink,
        iter: Option<IterSpec>,
    ) -> Result<(), PushError> {
        admission.consumed = true;
        let job = Job {
            req,
            submitted: Instant::now(),
            reply,
            _permit: admission.permit.take(),
            iter,
        };
        match self.queue.push_reserved(job) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err((_, e)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Per-tenant token quotas (set per-tenant overrides here).
    pub fn governor(&self) -> &Arc<TenantGovernor> {
        &self.governor
    }

    /// The resident-session registry, when the session lane is enabled
    /// (`session_workers > 0`).
    pub fn sessions(&self) -> Option<&Arc<SessionRegistry>> {
        self.sessions.as_ref()
    }

    /// Default rescale threshold for sessions opened without `thresh=`.
    pub fn session_rescale_threshold(&self) -> f64 {
        self.session_rescale_threshold
    }

    /// Per-phase wire budgets the TCP front door should apply to every
    /// accepted connection.
    pub fn wire_deadlines(&self) -> &Deadlines {
        &self.wire_deadlines
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Idle workspaces currently in the shared pool (observability; while
    /// workers run, each holds one checked out).
    pub fn idle_workspaces(&self) -> usize {
        self.pool.idle()
    }

    /// Handle to the shared workspace pool (it outlives `shutdown`, so
    /// callers can verify warm buffers were returned).
    pub fn workspace_pool(&self) -> Arc<WorkspacePool> {
        self.pool.clone()
    }

    /// Handle to the shared union-buffer pool (same lifecycle contract as
    /// [`workspace_pool`](Self::workspace_pool)).
    pub fn union_pool(&self) -> Arc<UnionPool> {
        self.unions.clone()
    }

    /// Drain queued work, stop workers, return final metrics.
    pub fn shutdown(self) -> Arc<Metrics> {
        if let Some(sessions) = &self.sessions {
            sessions.shutdown();
        }
        self.queue.close();
        for h in self.handles {
            let _ = h.join();
        }
        self.metrics
    }
}

/// Drain up to a batch worth of extra jobs (same linger deadline).
fn gather(q: &BoundedQueue<Job>, cfg: &ServiceConfig, first: Job) -> Vec<Job> {
    let mut jobs = vec![first];
    if !cfg.batching {
        return jobs;
    }
    let deadline = Instant::now() + cfg.batch_linger;
    while jobs.len() < cfg.batch_capacity.max_requests {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match q.pop_timeout(deadline - now) {
            Ok(Some(job)) => jobs.push(job),
            Ok(None) | Err(()) => break,
        }
    }
    jobs
}

/// Group → plan → pack into the worker's pooled union buffer → run →
/// reply, for one drained set of jobs.
fn process_jobs<F>(
    jobs: Vec<Job>,
    cfg: &ServiceConfig,
    metrics: &Metrics,
    union_buf: &mut PackedBatch,
    mut run: F,
) where
    F: FnMut(&Graph, &GeeOptions) -> (Result<Dense>, &'static str),
{
    // iterative jobs run solo (their rounds loop inside the worker);
    // everything else proceeds through the batcher
    let mut plain = Vec::new();
    for job in jobs {
        if job.iter.is_some() {
            run_iter_job(job, metrics, &mut run);
        } else {
            plain.push(job);
        }
    }
    let jobs = plain;
    // group by option combo (batches must share the transform)
    let mut groups: std::collections::HashMap<GeeOptions, Vec<Job>> =
        std::collections::HashMap::new();
    for job in jobs {
        groups.entry(job.req.options).or_default().push(job);
    }
    for (opts, group) in groups {
        let graphs: Vec<&Graph> = group.iter().map(|j| &j.req.graph).collect();
        let (plans, oversize) = if cfg.batching {
            plan_batches(&graphs, &cfg.batch_capacity)
        } else {
            (Vec::new(), (0..graphs.len()).collect())
        };

        for member_idx in &plans {
            let size = member_idx.len();
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
            let members: Vec<&Graph> = member_idx.iter().map(|&mi| graphs[mi]).collect();
            build_union_into(&members, union_buf);
            let (result, via) = run(&union_buf.union, &opts);
            match result {
                Ok(zu) => {
                    for (slot, &mi) in member_idx.iter().enumerate() {
                        let z = split_member(&zu, &union_buf.placements[slot]);
                        finish(&group[mi], z, via, size, metrics);
                    }
                }
                Err(e) => {
                    for &mi in member_idx {
                        fail(&group[mi], format!("{e:#}"), metrics);
                    }
                }
            }
        }
        for &mi in &oversize {
            let job = &group[mi];
            let g = &job.req.graph;
            // routing ladder for solo graphs: past the u32/memory budget
            // the vertex-range-sharded engine takes it (the in-core lanes
            // would reject it with IndexOverflow); past the intra-op
            // threshold the row-parallel engine uses the whole machine
            // instead of pinning one worker; otherwise the worker's lane.
            // num_directed is an O(E) scan — compute it once per job.
            let directed = g.num_directed();
            let (result, via) = if directed > cfg.shard_min_directed_edges {
                if cfg.shard_remote_workers.is_empty() {
                    (
                        Engine::Sharded(cfg.shard_count).embed(g, &opts),
                        "native-shard",
                    )
                } else {
                    match remote_shard_embed(g, &opts, cfg, metrics) {
                        Ok(z) => (Ok(z), "sharded-remote"),
                        Err(RemoteError::Fleet(e)) => {
                            // whole fleet unreachable: degrade to the
                            // local sharded engine (same numerics),
                            // raise the alarm counter, and keep the
                            // per-endpoint failure detail in the log —
                            // the error names every dead endpoint
                            metrics
                                .remote_fallbacks
                                .fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "shard fleet unreachable, falling back to local sharded engine: {e:#}"
                            );
                            (
                                Engine::Sharded(cfg.shard_count).embed(g, &opts),
                                "native-shard",
                            )
                        }
                        Err(RemoteError::Spill(e)) => {
                            // the *local* spill failed (disk full, bad
                            // temp dir) — the fleet was never contacted,
                            // so this must not trip the fleet-down
                            // alarm; the in-memory sharded engine needs
                            // no disk, so the job still completes
                            eprintln!(
                                "remote spill failed, using local sharded engine: {e:#}"
                            );
                            (
                                Engine::Sharded(cfg.shard_count).embed(g, &opts),
                                "native-shard",
                            )
                        }
                    }
                }
            } else if cfg.intra_op_threads > 1
                && directed >= cfg.intra_op_min_edges
            {
                (
                    Engine::SparsePar(cfg.intra_op_threads).embed(g, &opts),
                    "native-par",
                )
            } else {
                run(g, &opts)
            };
            match result {
                Ok(z) => finish(job, z, via, 1, metrics),
                Err(e) => fail(job, format!("{e:#}"), metrics),
            }
        }
    }
}

/// One self-clustering job: drive [`IterativeJob`] through the worker's
/// `run` closure (so every round reuses the worker's pooled workspace
/// and compute lane), streaming per-round progress through the spec's
/// callback and the `iter_rounds` counter. The job's tenant permit is
/// released only when `finish`/`fail` drops it with the job.
///
/// [`IterativeJob`]: crate::gee::iterate::IterativeJob
fn run_iter_job<F>(job: Job, metrics: &Metrics, run: &mut F)
where
    F: FnMut(&Graph, &GeeOptions) -> (Result<Dense>, &'static str),
{
    let spec = job.iter.clone().expect("run_iter_job requires an iter spec");
    let mut g = job.req.graph.clone();
    let driver = crate::gee::iterate::IterativeJob {
        rounds: spec.rounds,
        tol: spec.tol,
        ..crate::gee::iterate::IterativeJob::new(g.n, g.k)
    };
    let labels0 = g.labels.clone();
    let opts = job.req.options;
    let mut via: &'static str = "native";
    let result = driver.run(
        Some(labels0),
        |labels| {
            g.labels.copy_from_slice(labels);
            let (r, v) = run(&g, &opts);
            via = v;
            r
        },
        |rs| {
            metrics.iter_rounds.fetch_add(1, Ordering::Relaxed);
            (spec.on_round)(rs);
        },
    );
    match result {
        Ok(out) => {
            metrics.iter_jobs.fetch_add(1, Ordering::Relaxed);
            finish(&job, out.z, via, 1, metrics);
        }
        Err(e) => fail(&job, format!("{e:#}"), metrics),
    }
}

/// Why a remote shard embed failed — the caller's degradation policy
/// (and the `remote_fallbacks` alarm) depends on whether the fleet was
/// even reached.
enum RemoteError {
    /// The local spill failed; no endpoint was contacted.
    Spill(anyhow::Error),
    /// The spill succeeded but the fleet could not finish the work.
    Fleet(anyhow::Error),
}

/// Spill an oversize in-memory graph and dispatch it across the remote
/// shard fleet. The spill lands in a unique per-spill subdirectory of
/// the system temp dir and is removed when the dispatch finishes. Every
/// byte moved over the fleet wire — in either direction, whether the
/// dispatch succeeds or not — lands in `Metrics::remote_bytes`, so the
/// binary wire's traffic (and a regression back toward text volumes)
/// shows up on the dashboard, not just in benches.
fn remote_shard_embed(
    g: &Graph,
    opts: &GeeOptions,
    cfg: &ServiceConfig,
    metrics: &Metrics,
) -> Result<Dense, RemoteError> {
    let parent = std::env::temp_dir().join("gee_service_remote");
    let sp = crate::shard::spill::spill_from_graph(
        g,
        &crate::shard::SpillConfig {
            shards: cfg.shard_count,
            ..crate::shard::SpillConfig::new(parent)
        },
    )
    .map_err(RemoteError::Spill)?;
    let counters = std::sync::Arc::new(crate::shard::codec::ByteCounters::default());
    let result = crate::shard::dispatch::embed_remote(
        &sp,
        opts,
        &crate::shard::DispatchConfig {
            force_text: cfg.shard_wire_text,
            counters: Some(counters.clone()),
            ..crate::shard::DispatchConfig::new(cfg.shard_remote_workers.clone())
        },
    )
    .map_err(RemoteError::Fleet);
    metrics.remote_bytes.fetch_add(counters.total(), Ordering::Relaxed);
    result
}

fn finish(job: &Job, z: Dense, via: &'static str, batch_size: usize, metrics: &Metrics) {
    let latency = job.submitted.elapsed();
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    metrics.vertices.fetch_add(job.req.graph.n as u64, Ordering::Relaxed);
    metrics.edges.fetch_add(job.req.graph.num_directed() as u64, Ordering::Relaxed);
    metrics.observe_latency(latency);
    job.reply.send(Ok(EmbedResponse { z, latency, via, batch_size }));
}

fn fail(job: &Job, msg: String, metrics: &Metrics) {
    metrics.failed.fetch_add(1, Ordering::Relaxed);
    job.reply.send(Err(anyhow::anyhow!(msg)));
}

fn native_worker(
    q: &BoundedQueue<Job>,
    metrics: &Metrics,
    cfg: &ServiceConfig,
    engine: Engine,
    pool: &Arc<WorkspacePool>,
    unions: &Arc<UnionPool>,
) {
    // one warmed workspace + union buffer for this worker's lifetime;
    // both return to their pools (capacity intact) when the worker exits
    let mut ws = pool.checkout();
    let mut ub = unions.checkout();
    while let Some(first) = q.pop() {
        let jobs = gather(q, cfg, first);
        process_jobs(jobs, cfg, metrics, &mut ub, |g, opts| {
            (engine.embed_pooled(g, opts, &mut ws), "native")
        });
    }
}

fn pjrt_worker(
    q: &BoundedQueue<Job>,
    metrics: &Metrics,
    cfg: &ServiceConfig,
    artifact_dir: &std::path::Path,
    fallback: Engine,
    pool: &Arc<WorkspacePool>,
    unions: &Arc<UnionPool>,
) {
    let runtime = match Runtime::new(artifact_dir) {
        Ok(rt) => rt,
        Err(e) => {
            // fail every job with a clear message; service stays up on the
            // native fallback workers
            while let Some(job) = q.pop() {
                fail(&job, format!("pjrt runtime unavailable: {e:#}"), metrics);
            }
            return;
        }
    };
    let mut ws = pool.checkout();
    let mut ub = unions.checkout();
    while let Some(first) = q.pop() {
        let jobs = gather(q, cfg, first);
        process_jobs(jobs, cfg, metrics, &mut ub, |g, opts| {
            if runtime.fits(g, opts) {
                (runtime.embed(g, opts), "pjrt")
            } else {
                (fallback.embed_pooled(g, opts, &mut ws), "native-fallback")
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_graph(seed: u64, n: usize, m: usize, k: usize) -> Graph {
        let mut rng = Rng::new(seed);
        let mut g = Graph::new(n, k);
        for l in g.labels.iter_mut() {
            *l = rng.below(k) as i32;
        }
        for _ in 0..m {
            g.add_edge(rng.below(n) as u32, rng.below(n) as u32, 1.0);
        }
        g
    }

    #[test]
    fn serves_correct_embeddings() {
        let svc = EmbedService::start(ServiceConfig::default());
        let g = random_graph(401, 40, 100, 3);
        let opts = GeeOptions::ALL;
        let rx = svc.submit(EmbedRequest { graph: g.clone(), options: opts }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        let expect = Engine::SparseFast.embed(&g, &opts).unwrap();
        assert!(expect.max_abs_diff(&resp.z) < 1e-10);
        let m = svc.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 3,
            ..ServiceConfig::default()
        });
        let graphs: Vec<Graph> = (0..40).map(|i| random_graph(410 + i, 25, 60, 3)).collect();
        let rxs: Vec<_> = graphs
            .iter()
            .map(|g| {
                svc.submit(EmbedRequest { graph: g.clone(), options: GeeOptions::NONE })
                    .unwrap()
            })
            .collect();
        for (g, rx) in graphs.iter().zip(rxs) {
            let resp = rx.recv().unwrap().unwrap();
            let expect = Engine::SparseFast.embed(g, &GeeOptions::NONE).unwrap();
            assert!(expect.max_abs_diff(&resp.z) < 1e-10);
        }
        let m = svc.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 40);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn batching_packs_multiple_requests() {
        // single worker + generous linger -> requests coalesce
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            batch_linger: Duration::from_millis(50),
            ..ServiceConfig::default()
        });
        let graphs: Vec<Graph> = (0..8).map(|i| random_graph(420 + i, 20, 40, 2)).collect();
        let rxs: Vec<_> = graphs
            .iter()
            .map(|g| {
                svc.submit(EmbedRequest { graph: g.clone(), options: GeeOptions::NONE })
                    .unwrap()
            })
            .collect();
        let mut max_batch = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(max_batch > 1, "no coalescing observed");
        let m = svc.shutdown();
        assert!(m.avg_batch_fill() > 1.0);
    }

    #[test]
    fn mixed_options_never_share_a_union() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            batch_linger: Duration::from_millis(30),
            ..ServiceConfig::default()
        });
        let g = random_graph(430, 30, 80, 3);
        let combos = GeeOptions::table_order();
        let rxs: Vec<_> = combos
            .iter()
            .map(|o| svc.submit(EmbedRequest { graph: g.clone(), options: *o }).unwrap())
            .collect();
        for (o, rx) in combos.iter().zip(rxs) {
            let resp = rx.recv().unwrap().unwrap();
            let expect = Engine::SparseFast.embed(&g, o).unwrap();
            assert!(expect.max_abs_diff(&resp.z) < 1e-10, "combo {o:?}");
        }
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // zero workers is not allowed; use 1 worker + tiny queue + slow
        // feed via large graphs to observe rejection
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            queue_depth: 1,
            batching: false,
            ..ServiceConfig::default()
        });
        let g = random_graph(440, 400, 4_000, 4);
        let mut rejected = false;
        let mut rxs = Vec::new();
        for _ in 0..50 {
            match svc.try_submit(EmbedRequest { graph: g.clone(), options: GeeOptions::ALL }) {
                Ok(rx) => rxs.push(rx),
                Err(PushError::Full) => {
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(rejected, "queue never filled");
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        let m = svc.shutdown();
        assert!(m.rejected.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn intra_op_routes_large_solo_graphs_to_parallel_engine() {
        // tiny batch capacity -> the graph is oversize -> solo lane; with
        // the intra-op knob on, the solo lane must use the parallel engine
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            intra_op_threads: 2,
            intra_op_min_edges: 1,
            batch_capacity: BatchCapacity::from_bucket(8, 16, 2),
            ..ServiceConfig::default()
        });
        let g = random_graph(460, 60, 200, 3);
        let opts = GeeOptions::ALL;
        let rx = svc.submit(EmbedRequest { graph: g.clone(), options: opts }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.via, "native-par");
        let expect = Engine::Sparse.embed(&g, &opts).unwrap();
        assert!(expect.max_abs_diff(&resp.z) < 1e-10);
        let m = svc.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn intra_op_disabled_keeps_solo_lane_on_worker_engine() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            intra_op_threads: 0,
            batch_capacity: BatchCapacity::from_bucket(8, 16, 2),
            ..ServiceConfig::default()
        });
        let g = random_graph(461, 60, 200, 3);
        let rx = svc
            .submit(EmbedRequest { graph: g, options: GeeOptions::NONE })
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.via, "native");
        svc.shutdown();
    }

    #[test]
    fn oversize_graphs_route_to_sharded_lane() {
        // tiny batch capacity makes the graph oversize; a lowered shard
        // threshold stands in for the u32 budget (a real >4B-edge graph
        // is not buildable in a test) — the lane and numerics must match
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            shard_min_directed_edges: 100,
            shard_count: 3,
            batch_capacity: BatchCapacity::from_bucket(8, 16, 2),
            ..ServiceConfig::default()
        });
        let g = random_graph(480, 60, 200, 3);
        assert!(g.num_directed() > 100);
        let opts = GeeOptions::ALL;
        let rx = svc.submit(EmbedRequest { graph: g.clone(), options: opts }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.via, "native-shard");
        let expect = Engine::Sparse.embed(&g, &opts).unwrap();
        assert!(expect.max_abs_diff(&resp.z) < 1e-10);
        let m = svc.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn oversize_graphs_route_to_remote_fleet_when_configured() {
        // two in-process fleet daemons; a lowered shard threshold stands
        // in for the u32 budget, as in the local-shard routing test
        let s1 = crate::shard::ShardServer::start("127.0.0.1:0").unwrap();
        let s2 = crate::shard::ShardServer::start("127.0.0.1:0").unwrap();
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            shard_min_directed_edges: 100,
            shard_count: 4,
            shard_remote_workers: vec![
                s1.addr().to_string(),
                s2.addr().to_string(),
            ],
            batch_capacity: BatchCapacity::from_bucket(8, 16, 2),
            ..ServiceConfig::default()
        });
        let g = random_graph(482, 70, 250, 3);
        assert!(g.num_directed() > 100);
        let opts = GeeOptions::ALL;
        let rx = svc.submit(EmbedRequest { graph: g.clone(), options: opts }).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.via, "sharded-remote");
        let expect = Engine::SparseFast.embed(&g, &opts).unwrap();
        assert_eq!(resp.z.data, expect.data, "remote lane must stay bitwise");
        let m = svc.shutdown();
        assert_eq!(m.remote_fallbacks.load(Ordering::Relaxed), 0);
        assert!(
            m.remote_bytes.load(Ordering::Relaxed) > 0,
            "fleet traffic must land in the remote_bytes counter"
        );
        s1.stop();
        s2.stop();
    }

    #[test]
    fn dead_fleet_falls_back_to_local_sharded_lane() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            shard_min_directed_edges: 50,
            shard_count: 2,
            // reserved ports: nothing listens, every connect fails
            shard_remote_workers: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            batch_capacity: BatchCapacity::from_bucket(8, 16, 2),
            ..ServiceConfig::default()
        });
        let g = random_graph(483, 50, 160, 3);
        let rx = svc
            .submit(EmbedRequest { graph: g.clone(), options: GeeOptions::NONE })
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.via, "native-shard", "dead fleet must degrade locally");
        let expect = Engine::SparseFast.embed(&g, &GeeOptions::NONE).unwrap();
        assert_eq!(resp.z.data, expect.data);
        let m = svc.shutdown();
        assert_eq!(m.remote_fallbacks.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shard_routing_takes_priority_over_intra_op() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            intra_op_threads: 2,
            intra_op_min_edges: 1,
            shard_min_directed_edges: 1,
            batch_capacity: BatchCapacity::from_bucket(8, 16, 2),
            ..ServiceConfig::default()
        });
        let g = random_graph(481, 40, 120, 3);
        let rx = svc
            .submit(EmbedRequest { graph: g, options: GeeOptions::NONE })
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.via, "native-shard");
        svc.shutdown();
    }

    #[test]
    fn workers_return_union_buffers_to_pool_on_shutdown() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 2,
            batch_linger: Duration::from_millis(20),
            ..ServiceConfig::default()
        });
        let unions = svc.union_pool();
        assert_eq!(unions.idle(), 0, "workers hold their buffers while running");
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                let g = random_graph(490 + i, 20, 40, 2);
                svc.submit(EmbedRequest { graph: g, options: GeeOptions::NONE })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        svc.shutdown();
        assert_eq!(unions.idle(), 2, "each worker must return its union buffer");
    }

    #[test]
    fn workers_return_workspaces_to_pool_on_shutdown() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 3,
            ..ServiceConfig::default()
        });
        let pool = svc.workspace_pool();
        // workers hold their workspaces while running
        assert_eq!(svc.idle_workspaces(), 0);
        let g = random_graph(470, 30, 80, 3);
        let rx = svc
            .submit(EmbedRequest { graph: g, options: GeeOptions::ALL })
            .unwrap();
        rx.recv().unwrap().unwrap();
        svc.shutdown();
        assert_eq!(pool.idle(), 3, "each worker must return its workspace");
    }

    #[test]
    fn shutdown_completes_queued_work() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 2,
            ..ServiceConfig::default()
        });
        let mut rxs = Vec::new();
        for i in 0..10 {
            let g = random_graph(450 + i, 30, 60, 3);
            rxs.push(svc.submit(EmbedRequest { graph: g, options: GeeOptions::NONE }).unwrap());
        }
        let m = svc.shutdown();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn admitted_requests_complete_and_release_tokens() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            tenant_tokens: 1,
            ..ServiceConfig::default()
        });
        let g = random_graph(500, 30, 80, 3);
        let adm = svc.try_admit("acme").unwrap();
        // one token: a second concurrent admission must be refused
        match svc.try_admit("acme") {
            Err(AdmitError::OverQuota) => {}
            other => panic!("expected OverQuota, got {:?}", other.err()),
        }
        let (reply, rx) = ReplySink::channel();
        svc.submit_admitted(adm, EmbedRequest { graph: g.clone(), options: GeeOptions::NONE }, reply)
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        let expect = Engine::SparseFast.embed(&g, &GeeOptions::NONE).unwrap();
        assert_eq!(resp.z.data, expect.data);
        // the token comes back when the worker drops the job (just after
        // the reply) — poll briefly rather than race it
        let adm2 = loop {
            match svc.try_admit("acme") {
                Ok(a) => break a,
                Err(AdmitError::OverQuota) => std::thread::sleep(Duration::from_millis(1)),
                Err(e) => panic!("unexpected {e:?}"),
            }
        };
        drop(adm2);
        let m = svc.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        let tenants = m.tenant_snapshot();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].0, "acme");
        assert!(tenants[0].1.admitted.load(Ordering::Relaxed) >= 2);
        assert!(tenants[0].1.rejected_quota.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn dropped_admission_returns_its_queue_slot() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            queue_depth: 1,
            ..ServiceConfig::default()
        });
        let adm = svc.try_admit("t").unwrap();
        // the reservation occupies the only slot
        match svc.try_admit("t") {
            Err(AdmitError::Backpressure) => {}
            other => panic!("expected Backpressure, got {:?}", other.err()),
        }
        drop(adm);
        let adm2 = svc.try_admit("t").unwrap();
        drop(adm2);
        let m = svc.shutdown();
        assert_eq!(
            m.tenant("t").rejected_backpressure.load(Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn iter_job_runs_rounds_under_one_admission() {
        let svc = EmbedService::start(ServiceConfig {
            workers: 1,
            tenant_tokens: 1,
            ..ServiceConfig::default()
        });
        let g = random_graph(510, 60, 240, 3);
        let opts = GeeOptions::new(true, false, true);

        let adm = svc.try_admit("iter").unwrap();
        let rounds_seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let rs_sink = rounds_seen.clone();
        let spec = IterSpec {
            rounds: 3,
            tol: 0.0,
            on_round: Arc::new(move |rs| rs_sink.lock().unwrap().push(*rs)),
        };
        let (reply, rx) = ReplySink::channel();
        svc.submit_admitted_iter(
            adm,
            EmbedRequest { graph: g.clone(), options: opts },
            spec,
            reply,
        )
        .unwrap();
        let resp = rx.recv().unwrap().unwrap();

        // mirror the loop locally: same seed labels, same engine → the
        // service's final Z must be bitwise identical
        let driver = crate::gee::iterate::IterativeJob {
            rounds: 3,
            ..crate::gee::iterate::IterativeJob::new(g.n, g.k)
        };
        let mut lg = g.clone();
        let expect = driver
            .run(
                Some(g.labels.clone()),
                |labels| {
                    lg.labels.copy_from_slice(labels);
                    Engine::SparseFast.embed(&lg, &opts)
                },
                |_| {},
            )
            .unwrap();
        assert_eq!(resp.z.data, expect.z.data, "iter lane must stay bitwise");

        let seen = rounds_seen.lock().unwrap().clone();
        assert_eq!(seen.len(), expect.rounds.len());
        for (a, b) in seen.iter().zip(expect.rounds.iter()) {
            assert_eq!(a, b);
        }

        let m = svc.shutdown();
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.iter_jobs.load(Ordering::Relaxed), 1);
        assert_eq!(m.iter_rounds.load(Ordering::Relaxed), seen.len() as u64);
    }

    #[test]
    fn callback_sink_delivers_reply() {
        let svc = EmbedService::start(ServiceConfig::default());
        let g = random_graph(501, 25, 60, 2);
        let (tx, rx) = mpsc::channel();
        let adm = svc.try_admit("cb").unwrap();
        let sink = ReplySink::callback(move |r| {
            let _ = tx.send(r.map(|resp| resp.z));
        });
        svc.submit_admitted(adm, EmbedRequest { graph: g.clone(), options: GeeOptions::ALL }, sink)
            .unwrap();
        let z = rx.recv().unwrap().unwrap();
        let expect = Engine::SparseFast.embed(&g, &GeeOptions::ALL).unwrap();
        assert_eq!(z.data, expect.data);
        svc.shutdown();
    }
}
