//! L3 coordinator — the serving layer around the embedding engines:
//!
//! * [`queue`] — bounded admission queue with backpressure
//! * [`batcher`] — exact disjoint-union dynamic batching (class-offset
//!   trick keeps per-graph `1/n_k` normalization intact)
//! * [`service`] — worker lanes (native pool / dedicated PJRT thread),
//!   request lifecycle, graceful shutdown
//! * [`streaming`] — incremental GEE under edge/vertex/label updates
//! * [`session`] — resident [`session::GeeSession`]s: O(Δ) dirty-row
//!   refresh through the shared kernel dispatch, session registry with
//!   per-tenant quotas, background fast-lane refresh workers
//! * [`metrics`] — counters + latency histogram (p50/p95/p99), per-tenant
//!   admission/byte counters
//! * [`server`] / [`wire`] / [`client`] — TCP front-end: v1 text lockstep
//!   and the v2 binary multiplexed wire with per-tenant admission

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod queue;
pub mod server;
pub mod service;
pub mod session;
pub mod streaming;
pub mod wire;

pub use client::{ClientConfig, ClientReply, EmbedClient};
pub use server::TcpServer;
pub use service::{EmbedRequest, EmbedResponse, EmbedService, Lane, ReplySink, ServiceConfig};
pub use session::{Delta, GeeSession, SessionConfig, SessionRegistry};
pub use streaming::StreamingGee;
