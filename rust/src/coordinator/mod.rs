//! L3 coordinator — the serving layer around the embedding engines:
//!
//! * [`queue`] — bounded admission queue with backpressure
//! * [`batcher`] — exact disjoint-union dynamic batching (class-offset
//!   trick keeps per-graph `1/n_k` normalization intact)
//! * [`service`] — worker lanes (native pool / dedicated PJRT thread),
//!   request lifecycle, graceful shutdown
//! * [`streaming`] — incremental GEE under edge/vertex/label updates
//! * [`metrics`] — counters + latency histogram (p50/p95/p99)

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod server;
pub mod service;
pub mod streaming;

pub use server::TcpServer;
pub use service::{EmbedRequest, EmbedResponse, EmbedService, Lane, ServiceConfig};
pub use streaming::StreamingGee;
